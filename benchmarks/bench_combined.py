"""Paper Fig 10: memory footprint of cudaMalloc / CnMem / SmartPool /
SmartPool+AutoSwap across batch sizes, driven through the repro.plan
pipeline (PoolPlacement over registry methods + the program's swap planner)."""

from __future__ import annotations

from repro.core.simulator import GTX_1080TI
from repro.plan import MemoryProgram, PassContext, Pipeline, PoolPlacement, TimingAssign

from .common import cnn_trace, emit


def run(models=("vgg16", "resnet50"), batches=(50, 100, 200)):
    rows = []
    ctx = PassContext(hw=GTX_1080TI)
    for name in models:
        for b in batches:
            tr = cnn_trace(name, b)
            prog = Pipeline([
                TimingAssign(),
                PoolPlacement(("best_fit", "cnmem")),
            ]).run(MemoryProgram.from_trace(tr), ctx)
            sp = prog.pool_plans["best_fit"]
            cn = prog.baselines["cnmem"]
            pl = prog.swap_planner(ctx.hw, ctx.size_threshold)
            zero_limit, _ = pl.max_zero_overhead_reduction(method="swdoa", grid=16)
            # the "<=15% overhead" point (paper: ~60% footprint reduction)
            best15 = zero_limit
            lmin = pl.load_min()
            for k in range(1, 17):
                limit = int(zero_limit - (zero_limit - lmin) * k / 16)
                if pl.evaluate(limit, method="swdoa").overhead <= 0.15:
                    best15 = limit
            rows.append((
                f"fig10/{name}/b{b}",
                "0",
                f"cuda_MiB={tr.peak_load()/2**20:.0f}"
                f"|cnmem_MiB={cn.footprint/2**20:.0f}"
                f"|smartpool_MiB={sp.footprint/2**20:.0f}"
                f"|swap0_MiB={zero_limit/2**20:.0f}"
                f"|swap15_MiB={best15/2**20:.0f}",
            ))
    return rows


def main():
    emit(run())


if __name__ == "__main__":
    main()
