"""Runtime benchmark: DMA channel scaling + multi-tenant colocation.

Two experiments over the ``repro.runtime`` engine, on CNN training traces
(the paper's workloads, deterministic simulated time):

  * **channel scaling** — one tenant, its AutoSwap schedule simulated over
    K = 1, 2, 4 DMA channels at several HBM limits.  K=1 serializes swap-out
    and swap-in onto one channel (the overlap-free worst case); K=2 is the
    paper's one-out/one-in configuration.  Acceptance: K=2 strictly reduces
    simulated overhead vs K=1 on at least one arch, and never increases it.

  * **colocation** — two tenants co-scheduled under one shared budget set to
    ``--budget-frac`` of their summed natural peaks.  Acceptance: aggregate
    peak HBM stays below the sum of the tenants' isolated peaks (static
    per-tenant provisioning) with bounded per-tenant overhead.

Writes a machine-readable ``BENCH_runtime.json`` (``--out``) so future PRs
have a perf trajectory to regress against; exits non-zero when an acceptance
flag fails, which is how ``tools/ci.sh`` gates it.

Usage:
  PYTHONPATH=src python -m benchmarks.bench_runtime [--smoke] [--out BENCH_runtime.json]
"""

from __future__ import annotations

import argparse
import sys

from benchmarks.common import cnn_trace, write_bench_json
from repro.core.autoswap import AutoSwapPlanner
from repro.core.simulator import GTX_1080TI
from repro.plan import MemoryProgram, PlanKey
from repro.runtime import colocate_programs, simulate_program

CHANNEL_FRACS = (0.5, 0.6, 0.7, 0.8)
CHANNEL_KS = (1, 2, 4)


def bench_channel_scaling(arch: str, batch: int, threshold: int) -> dict:
    hw = GTX_1080TI
    tr = cnn_trace(arch, batch)
    pl = AutoSwapPlanner(tr, hw, size_threshold=threshold)
    rows = []
    for frac in CHANNEL_FRACS:
        limit = int(pl.peak_load * frac)
        dec = pl.select(limit, "swdoa")
        overheads = {
            f"k{k}": simulate_program(tr, dec, hw, limit, channels=k).overhead
            for k in CHANNEL_KS
        }
        rows.append({
            "limit_frac": frac,
            "limit_bytes": limit,
            "num_decisions": len(dec),
            **overheads,
        })
    strict = any(r["k1"] > r["k2"] + 1e-12 for r in rows)
    never_worse = all(r["k2"] <= r["k1"] + 1e-12 for r in rows)
    return {
        "arch": arch,
        "batch": batch,
        "peak_load": pl.peak_load,
        "rows": rows,
        "k2_strictly_better_somewhere": strict,
        "k2_never_worse": never_worse,
    }


def bench_colocation(archs: tuple[str, str], batch: int, threshold: int,
                     budget_frac: float, channels: int) -> dict:
    hw = GTX_1080TI
    programs = {}
    for arch in archs:
        trace = cnn_trace(arch, batch)
        key = PlanKey(arch, f"train:b{batch}", hw.name)
        programs[arch] = MemoryProgram.from_trace(trace, key)
    result = colocate_programs(
        programs, hw, budget_frac=budget_frac, channels=channels,
        size_threshold=threshold,
    )
    d = result.as_dict()
    # Gate on the *isolated* (swapped, per-share) peaks, not the natural
    # peaks: budget = frac * sum_natural makes the latter true by
    # construction, while this one can genuinely regress.  And the sharing
    # claim only means anything if the tenants actually ran concurrently —
    # a queued (serialized) run has a low aggregate peak for free.
    tenants = result.report.tenants
    concurrent = (
        all(t.status == "completed" and t.queue_wait_s == 0.0 for t in tenants)
        and min(t.finished_at for t in tenants) > max(t.admitted_at for t in tenants)
    )
    d["tenants_ran_concurrently"] = concurrent
    d["aggregate_below_sum_isolated"] = (
        concurrent and d["aggregate_peak"] < d["sum_isolated_peaks"]
    )
    d["tenant_overheads"] = {
        t["name"]: t["overhead"] for t in d["runtime"]["tenants"]
    }
    return d


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small models/batch for CI (still exercises both experiments)")
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--budget-frac", type=float, default=0.8)
    ap.add_argument("--channels", type=int, default=2)
    ap.add_argument("--out", default="BENCH_runtime.json")
    args = ap.parse_args(argv)

    if args.smoke:
        archs, batch, threshold = ("vgg11", "resnet18"), args.batch or 16, 1 << 18
    else:
        archs, batch, threshold = ("vgg16", "resnet50"), args.batch or 100, 1 << 20

    channel_scaling = [bench_channel_scaling(a, batch, threshold) for a in archs]
    colocate = bench_colocation(archs, batch, threshold, args.budget_frac, args.channels)

    ok_channels = (
        any(r["k2_strictly_better_somewhere"] for r in channel_scaling)
        and all(r["k2_never_worse"] for r in channel_scaling)
    )
    ok_colocate = colocate["aggregate_below_sum_isolated"]
    report = {
        "mode": "smoke" if args.smoke else "full",
        "hardware": GTX_1080TI.name,
        "batch": batch,
        "channel_scaling": channel_scaling,
        "colocate": colocate,
        "acceptance": {
            "k2_reduces_overhead": ok_channels,
            "colocate_below_sum_of_isolated_peaks": ok_colocate,
        },
    }
    write_bench_json(args.out, report)

    for r in channel_scaling:
        best = min(r["rows"], key=lambda row: row["k2"] - row["k1"])
        print(
            f"{r['arch']:>9} b{batch}: peak {r['peak_load']/2**20:7.1f}MiB  "
            f"best K1->K2 gain @{best['limit_frac']:.1f} limit: "
            f"{best['k1']*100:6.2f}% -> {best['k2']*100:6.2f}% "
            f"(K4 {best['k4']*100:6.2f}%)"
        )
    print(
        f"colocate {'+'.join(archs)}: aggregate {colocate['aggregate_peak']/2**20:.1f}MiB "
        f"vs {colocate['sum_natural_peaks']/2**20:.1f}MiB isolated provisioning "
        f"(gain {colocate['sharing_gain']*100:.1f}%), overheads "
        + ", ".join(f"{n}={o*100:.2f}%" for n, o in colocate["tenant_overheads"].items())
    )
    print(f"wrote {args.out}; acceptance: {report['acceptance']}")
    return 0 if (ok_channels and ok_colocate) else 1


if __name__ == "__main__":
    sys.exit(main())
