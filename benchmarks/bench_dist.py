"""Distributed planning benchmark: per-shard plans + host-link contention.

Three claims over the ``repro.dist`` subsystem, all on simulated devices
(the capture walks abstract jaxprs — no multi-device runtime needed):

  * **per-device peak** — planning on the per-shard trace of a ``--shards``-
    way data-parallel mesh lands at or below the replicated single-device
    plan's peak scaled by the shard fraction, plus the bytes that stay
    replicated (weights/optimizer state).  Sharded serving can provision
    per-host HBM from the per-shard plan instead of the full-model peak.

  * **contention changes schedules** — running the per-device tenants over a
    shared host link (one PCIe/NVLink budget for all devices, collectives
    blacking the link out) moves at least one swap transfer relative to the
    contention-free baseline: bandwidth sharing is load-bearing, not
    decorative.

  * **collective-aware ≥ blind** — back-scheduling swap-ins around the
    tagged collective windows never ends up with *more* mean overhead than
    scheduling blind on the same contended link.

Plus the degenerate-mesh pin: a 1x1-mesh capture solves to a plan
byte-identical (``dumps_canonical``) to the single-device pipeline's.

Writes ``BENCH_dist.json`` (``--out``); exits non-zero when an acceptance
flag fails — ``tools/ci.sh`` runs ``--smoke``.

Usage:
  PYTHONPATH=src python -m benchmarks.bench_dist [--smoke] [--out BENCH_dist.json]
"""

from __future__ import annotations

import argparse
import sys

from benchmarks.common import write_bench_json
from repro.core.simulator import TPU_V5E
from repro.dist import (
    MeshSpec,
    capture_sharded_trace,
    gradient_sync_collective,
    run_mesh,
    schedules_differ,
    solve_sharded,
)
from repro.launch.shardplan import SpecMesh, build_probe
from repro.launch.steps import batch_specs, param_specs
from repro.plan import PlanKey, dumps_canonical
from repro.plan.passes import (
    PassContext,
    Pipeline,
    PoolPlacement,
    SwapSelection,
    TimingAssign,
    TraceCapture,
)

HW = TPU_V5E
PEAK_SLACK = 0.01          # 1% tolerance on the shard-fraction peak bound
OVERHEAD_EPS = 1e-9        # aware may not be worse than blind beyond fp noise


def capture_pair(arch: str, batch: int, seq: int, shards: int,
                 fsdp_gathers: int):
    """(single-device capture, sharded capture, probe pieces) for one arch.

    Mid-iteration ``all_gather`` collectives model FSDP-style parameter
    gathers spread through the step; the tail ``all_reduce`` is the
    data-parallel gradient sync (the same ``gradient_sync_collective`` cost
    model the shardplan CLI prices).  Both are cost-model synthesized — a
    GSPMD-jitted jaxpr holds no collective eqns (XLA inserts them at
    compile time).
    """
    cfg, _, step_probe, example_args = build_probe(arch, True, batch, seq)
    pshapes, probe = example_args

    def specs_for(mesh: MeshSpec):
        sm = SpecMesh(mesh)
        return (param_specs(cfg, pshapes, sm), batch_specs(cfg, probe, sm))

    mesh1 = MeshSpec.make(data=1)
    single = capture_sharded_trace(
        step_probe, *example_args, mesh=mesh1, hw=HW,
        in_specs=specs_for(mesh1), arg_names=["params", "batch"],
    )

    mesh = MeshSpec.make(data=shards)
    pspecs, bspecs = specs_for(mesh)
    sync = gradient_sync_collective(pshapes, pspecs, mesh)
    grad_bytes = sync[1]
    extra = [sync]
    for k in range(fsdp_gathers):
        extra.append(
            ("all_gather", grad_bytes // max(1, fsdp_gathers),
             (k + 1) / (fsdp_gathers + 1), shards)
        )
    sharded = capture_sharded_trace(
        step_probe, *example_args, mesh=mesh, hw=HW,
        in_specs=(pspecs, bspecs), arg_names=["params", "batch"],
        extra_collectives=extra,
    )
    return single, sharded, (step_probe, example_args)


def replicated_bytes_peak(single, sharded) -> int:
    """Peak load of the variables sharding does NOT divide (same size in both
    captures) — the provable tolerance on the shard-fraction peak bound."""
    from repro.core.events import IterationTrace

    st = single.groups["spmd"].trace
    dt = sharded.groups["spmd"].trace
    d_size = {v.var: v.size for v in dt.variables}
    replicated = [v for v in st.variables if d_size.get(v.var) == v.size]
    return IterationTrace(list(replicated), st.num_indices).peak_load()


def bench_peak(arch: str, batch: int, seq: int, shards: int,
               fsdp_gathers: int, limit_frac: float, size_threshold: int) -> dict:
    single, sharded, (step_probe, example_args) = capture_pair(
        arch, batch, seq, shards, fsdp_gathers
    )
    single_peak = single.groups["spmd"].trace.peak_load()
    shard_peak = sharded.groups["spmd"].trace.peak_load()
    tolerance = replicated_bytes_peak(single, sharded)
    bound = single_peak / shards + tolerance
    solved = solve_sharded(sharded, HW, limit_frac=limit_frac,
                           size_threshold=size_threshold)
    return {
        "arch": arch,
        "shards": shards,
        "single_device_peak": single_peak,
        "per_device_peak": shard_peak,
        "shard_fraction_bound": int(bound),
        "replicated_bytes_tolerance": tolerance,
        "collectives": len(sharded.groups["spmd"].collectives),
        "collective_s_per_iter": sum(
            c.seconds for c in sharded.groups["spmd"].collectives
        ),
        "peak_within_shard_bound": shard_peak <= bound * (1 + PEAK_SLACK),
        "_solved": solved,
        "_captures": (single, sharded, step_probe, example_args),
    }


def bench_contention(solved, budget_frac: float, iterations: int,
                     link_bw_frac: float, link_lanes: int) -> dict:
    from repro.dist import mesh_tenants

    shard_peak = solved.capture.groups["spmd"].trace.peak_load()
    # The budget targets budget_frac of the shard peak but must admit the
    # solved plan's resident floor (selection is best-effort at its limit).
    floor = max(t.resident_floor() for t in mesh_tenants(solved))
    budget = max(int(shard_peak * budget_frac), floor)
    kw = dict(budget_per_device=budget, channels=2, iterations=iterations,
              link_bw=HW.link_bw * link_bw_frac, link_lanes=link_lanes)
    uncontended = run_mesh(solved, HW, contended=False,
                           budget_per_device=budget, channels=2,
                           iterations=iterations)
    aware = run_mesh(solved, HW, contended=True, contention_aware=True, **kw)
    blind = run_mesh(solved, HW, contended=True, contention_aware=False, **kw)
    return {
        "budget_per_device": budget,
        "link_lanes": link_lanes,
        "link_bw_frac": link_bw_frac,
        "mean_overhead": {
            "uncontended": uncontended.mean_overhead(),
            "contended_aware": aware.mean_overhead(),
            "contended_blind": blind.mean_overhead(),
        },
        "makespan_s": {
            "uncontended": uncontended.makespan_s,
            "contended_aware": aware.makespan_s,
            "contended_blind": blind.makespan_s,
        },
        "link": aware.report.link,
        "device_peaks": aware.report.device_peaks,
        "contention_changes_schedules": schedules_differ(uncontended, aware),
        "aware_not_worse_than_blind": (
            aware.mean_overhead() <= blind.mean_overhead() + OVERHEAD_EPS
        ),
        "aware_vs_blind_schedules_differ": schedules_differ(aware, blind),
    }


def bench_identity(arch: str, batch: int, seq: int, step_probe, example_args,
                   limit_frac: float, size_threshold: int) -> dict:
    """1x1-mesh dist capture must solve to the byte-identical plan the
    single-device pipeline produces for the same step."""
    key = PlanKey(arch, f"train:b{batch}s{seq}:smoke", HW.name)
    mesh1 = MeshSpec.make(data=1)
    cap = capture_sharded_trace(
        step_probe, *example_args, mesh=mesh1, hw=HW,
        arg_names=["params", "batch"],
    )
    limit = int(cap.groups["spmd"].trace.peak_load() * limit_frac)
    dist_solved = solve_sharded(cap, HW, base_key=key, limit=limit,
                                size_threshold=size_threshold)
    ctx = PassContext(hw=HW, key=key, size_threshold=size_threshold)
    single_prog = Pipeline([
        TraceCapture(step_fn=step_probe, example_args=example_args,
                     arg_names=["params", "batch"]),
        TimingAssign(),
        PoolPlacement(),
        SwapSelection(limit=limit),
    ]).run(None, ctx)
    same = dumps_canonical(dist_solved.programs["spmd"]) == dumps_canonical(single_prog)
    return {"plans_byte_identical_on_1x1": same, "limit": limit}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small step / short run for CI (same acceptance gates)")
    ap.add_argument("--shards", type=int, default=4)
    ap.add_argument("--budget-frac", type=float, default=0.7)
    ap.add_argument("--limit-frac", type=float, default=0.6)
    ap.add_argument("--link-lanes", type=int, default=2)
    ap.add_argument("--link-bw-frac", type=float, default=1.0,
                    help="shared host-link bandwidth / one device's link bw")
    ap.add_argument("--out", default="BENCH_dist.json")
    args = ap.parse_args(argv)

    if args.smoke:
        arch, batch, seq, iterations, gathers, threshold = "qwen3-4b", 4, 64, 2, 4, 1 << 12
    else:
        arch, batch, seq, iterations, gathers, threshold = "qwen3-4b", 8, 128, 3, 8, 1 << 16

    peak = bench_peak(arch, batch, seq, args.shards, gathers,
                      args.limit_frac, threshold)
    solved = peak.pop("_solved")
    single, sharded, step_probe, example_args = peak.pop("_captures")
    contention = bench_contention(
        solved, args.budget_frac, iterations, args.link_bw_frac, args.link_lanes
    )
    identity = bench_identity(arch, batch, seq, step_probe, example_args,
                              args.limit_frac, threshold)

    ok_peak = peak["peak_within_shard_bound"]
    ok_sched = contention["contention_changes_schedules"]
    ok_aware = contention["aware_not_worse_than_blind"]
    ok_ident = identity["plans_byte_identical_on_1x1"]
    report = {
        "mode": "smoke" if args.smoke else "full",
        "hardware": HW.name,
        "mesh": {"data": args.shards},
        "per_device_peak": peak,
        "contention": contention,
        "identity_1x1": identity,
        "acceptance": {
            "per_device_peak_within_shard_bound": ok_peak,
            "contention_changes_schedules": ok_sched,
            "contention_aware_not_worse_than_blind": ok_aware,
            "plans_byte_identical_on_1x1": ok_ident,
        },
    }
    write_bench_json(args.out, report)

    mo = contention["mean_overhead"]
    print(
        f"dist ({report['mode']}): {arch} b{batch}s{seq} on data={args.shards} — "
        f"per-device peak {peak['per_device_peak']/2**20:.1f}MiB vs bound "
        f"{peak['shard_fraction_bound']/2**20:.1f}MiB "
        f"(replicated single-device {peak['single_device_peak']/2**20:.1f}MiB), "
        f"{peak['collectives']} collectives"
    )
    print(
        f"  mean overhead: uncontended {mo['uncontended']*100:.2f}% | shared link "
        f"{mo['contended_aware']*100:.2f}% aware vs {mo['contended_blind']*100:.2f}% blind; "
        f"schedules moved by contention: {ok_sched}"
    )
    print(f"  1x1 plan byte-identical to single-device pipeline: {ok_ident}")
    print(f"wrote {args.out}; acceptance: {report['acceptance']}")
    return 0 if (ok_peak and ok_sched and ok_aware and ok_ident) else 1


if __name__ == "__main__":
    sys.exit(main())
