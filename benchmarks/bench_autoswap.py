"""Paper Fig 9 + Table II: AutoSwap overhead vs memory-load limit per
priority score (+Bayesian-optimized combination), and the maximum
zero-overhead load reduction per model."""

from __future__ import annotations

import numpy as np

from repro.core.autoswap import AutoSwapPlanner
from repro.core.bayesopt import tune_swap_weights
from repro.core.simulator import GTX_1080TI

from .common import CNN_MODELS, cnn_trace, emit, timer


def fig9(model: str = "vgg16", n_points: int = 8, bo_iters: int = 16):
    tr = cnn_trace(model)
    pl = AutoSwapPlanner(tr, GTX_1080TI)
    peak, lmin = pl.peak_load, pl.load_min()
    rows = []
    limits = [int(peak - (peak - lmin) * k / n_points) for k in range(1, n_points + 1)]
    for limit in limits:
        per = {}
        for m in ("doa", "aoa", "wdoa", "swdoa"):
            per[m] = pl.evaluate(limit, method=m).overhead
        with timer() as t:
            bo = tune_swap_weights(pl, limit, n_iter=bo_iters)
        per["bo"] = min(bo.best_y, min(per.values()))  # BO safeguards to the best PS
        rows.append((
            f"fig9/{model}/limit_{limit//2**20}MiB",
            f"{t.elapsed*1e6:.0f}",
            "|".join(f"{k}={v*100:.2f}%" for k, v in per.items()),
        ))
    return rows


def table2():
    rows = []
    for name in CNN_MODELS:
        tr = cnn_trace(name)
        pl = AutoSwapPlanner(tr, GTX_1080TI)
        best_limit, best = pl.peak_load, 0.0
        for m in ("doa", "aoa", "wdoa", "swdoa"):
            limit, ov = pl.max_zero_overhead_reduction(method=m, grid=24)
            if limit < best_limit:
                best_limit, best = limit, ov
        red = 100 * (1 - best_limit / pl.peak_load)
        rows.append((
            f"table2/{name}",
            "0",
            f"orig_MiB={pl.peak_load/2**20:.0f}"
            f"|reduced_MiB={best_limit/2**20:.0f}"
            f"|reduction={red:.1f}%|overhead={best*100:.2f}%",
        ))
    return rows


def main():
    emit(fig9() + table2())


if __name__ == "__main__":
    main()
