"""Engine throughput benchmark: vectorized event core vs frozen reference.

PR 6 rewrote ``runtime/engine.py``'s hot paths onto precomputed structures
(prefetch index, pending-out heap, bisected collective windows, heapq event
frontier, per-decision due constants); ``runtime/_engine_reference.py`` is
the pre-vectorization engine, frozen verbatim.  This benchmark runs the same
workloads through both and reports events/sec plus the speedup, with every
cell checked for *identical* simulated reports (``simulated_report_dict``):

  * **churn** — a seeded 1000-tenant Poisson arrival storm (the fleet shape
    from the ROADMAP's "thousand-tenant meshes" item).  The reference's
    min-over-running-tenants scan is O(N) per event, so this is where
    near-linear matters.  The fast engine runs in fleet configuration
    (``record_events=False``); the events-recorded figure is reported too.
  * **churn_reneg** — a tighter budget with renegotiation on and
    ``capture_snapshots=True``: every barrier snapshot is resumed and the
    suffix-only replay must reproduce the full-horizon report byte for byte.
  * **churn_obs** — the observability cell: the obs-off hot path is the
    quantity ``check_enginetime`` gates (instrumentation must not regress
    it); the cost of an attached ``ObsRecorder``, report purity obs-on vs
    obs-off, and the attribution-ledger sum invariant ride along.
  * **mesh_data4** — a data=4 mesh shape (per-device pools, tagged
    collectives, contended ``HostLink``) built directly from Tenants.

Acceptance (gated in ``tools/ci.sh`` via smoke mode; the committed
``BENCH_engine.json`` comes from a full run):
  * every cell reports ``reports_equal: true``;
  * suffix replay is byte-identical to full replay;
  * full mode only: >=10x events/sec on the 1000-tenant churn workload
    (wall-time assertions are left out of smoke — ``tools/check_enginetime.py``
    gates the timing ratio against its committed baseline with a noise
    floor and retry instead).

Usage:
  PYTHONPATH=src python -m benchmarks.bench_engine [--smoke] [--out BENCH_engine.json]
"""

from __future__ import annotations

import argparse
import json
import sys
import time

from benchmarks.common import write_bench_json
from repro.core.autoswap import AutoSwapPlanner
from repro.core.simulator import GTX_1080TI
from repro.runtime import _engine_reference as ref_engine
from repro.runtime import engine as fast_engine
from repro.runtime import planned_peak, poisson_workload, synthetic_train_trace
from repro.runtime.engine import simulated_report_dict

HW = GTX_1080TI
SIZE_THRESHOLD = 1 << 20
LIMIT_FRAC = 0.7
SPEEDUP_TARGET = 10.0     # full-mode churn cell, fast vs frozen reference

TEMPLATE_LAYERS = {"small": 4, "medium": 6, "large": 8}


def solve_template(trace):
    pl = AutoSwapPlanner(trace, HW, size_threshold=SIZE_THRESHOLD)
    limit = int(pl.peak_load * LIMIT_FRAC)
    return limit, pl.select(limit, "swdoa")


def build_templates():
    templates = {n: synthetic_train_trace(l) for n, l in TEMPLATE_LAYERS.items()}
    plans = {n: solve_template(t) for n, t in templates.items()}
    floors = {n: planned_peak(templates[n], p[1]) for n, p in plans.items()}
    return templates, plans, floors


def canon(report) -> str:
    return json.dumps(simulated_report_dict(report), sort_keys=True)


def churn_tenants(mod, templates, plans, items):
    out = []
    for it in items:
        limit, decisions = plans[it.template]
        out.append(
            mod.Tenant(
                it.name, templates[it.template], list(decisions), limit=limit,
                iterations=it.iterations, arrival_t=it.arrival_t,
                priority=it.priority,
            )
        )
    return out


def mesh_tenants(mod, templates, plans, devices=4, iterations=3):
    """Data-parallel mesh shape built directly from Tenants (jax-free):
    one shard per device, tagged collectives, first device owns blackouts."""
    out = []
    names = list(TEMPLATE_LAYERS)
    for i in range(devices):
        name = names[i % len(names)]
        trace = templates[name]
        limit, decisions = plans[name]
        colls = {2: 0.004, trace.num_indices - 2: 0.006}
        out.append(
            mod.Tenant(
                f"shard{i}", trace, list(decisions), limit=limit,
                iterations=iterations, device=f"d{i}", collectives=colls,
                collective_owner=(i == 0),
            )
        )
    return out


def timed_run(mod, make_tenants, **kw):
    """Build fresh tenants, run one engine, return (report, wall_seconds)."""
    link = kw.pop("link", None)
    rt = mod.MemoryRuntime(
        HW,
        link=mod.HostLink.make(*link) if link else None,
        replan_size_threshold=SIZE_THRESHOLD,
        **kw,
    )
    tenants = make_tenants(mod)
    t0 = time.perf_counter()
    report = rt.run(tenants)
    return rt, report, time.perf_counter() - t0


def churn_cell(templates, plans, floors, smoke: bool, seed: int) -> dict:
    """The headline cell: a Poisson arrival storm at fleet concurrency."""
    if smoke:
        n, rate_hz, iters, conc = 120, 20_000.0, (2, 3), 150
    else:
        n, rate_hz, iters, conc = 1000, 100_000.0, (3, 5), 1100
    items = poisson_workload(
        list(TEMPLATE_LAYERS), n, rate_hz, seed=seed, iterations=iters
    )
    mean_floor = sum(floors.values()) / len(floors)
    budget = int(mean_floor * conc)
    mk = lambda mod: churn_tenants(mod, templates, plans, items)

    _, fast_rep, fast_s = timed_run(
        fast_engine, mk, budget=budget, channels=2, record_events=False)
    _, fast_ev_rep, fast_events_s = timed_run(
        fast_engine, mk, budget=budget, channels=2, record_events=True)
    _, ref_rep, ref_s = timed_run(ref_engine, mk, budget=budget, channels=2)

    events = fast_rep.engine["events"]
    return {
        "tenants": n,
        "budget": budget,
        "events": events,
        "fast_s": fast_s,
        "fast_events_recorded_s": fast_events_s,
        "ref_s": ref_s,
        "fast_events_per_s": events / fast_s if fast_s else 0.0,
        "ref_events_per_s": events / ref_s if ref_s else 0.0,
        "speedup": ref_s / fast_s if fast_s else 0.0,
        "speedup_events_recorded": ref_s / fast_events_s if fast_events_s else 0.0,
        "reports_equal": canon(fast_rep) == canon(ref_rep)
        and canon(fast_ev_rep) == canon(ref_rep),
    }


def churn_reneg_cell(templates, plans, floors, smoke: bool, seed: int) -> dict:
    """Tight budget + renegotiation + barrier snapshots: correctness of the
    suffix-only replay next to the fast-vs-reference report equality."""
    n = 12 if smoke else 120
    items = poisson_workload(
        ["small", "medium"], n, 50.0, seed=seed, iterations=(1, 3))
    base = fast_engine.Tenant(
        "base", templates["large"], list(plans["large"][1]),
        limit=plans["large"][0], iterations=max(6, n // 2), priority=0.5)
    budget = floors["large"] + (floors["small"] + floors["medium"]) // 2

    def mk(mod):
        ts = [mod.Tenant(
            "base", templates["large"], list(plans["large"][1]),
            limit=plans["large"][0], iterations=base.iterations, priority=0.5)]
        return ts + churn_tenants(mod, templates, plans, items)

    # Timing run without snapshots (capturing deepcopies the whole engine at
    # every applied barrier — that cost belongs to the feature, not the
    # engine); a second, untimed capture run drives the suffix-replay check.
    _, fast_rep, fast_s = timed_run(
        fast_engine, mk, budget=budget, channels=2, renegotiate=True)
    _, ref_rep, ref_s = timed_run(
        ref_engine, mk, budget=budget, channels=2, renegotiate=True)
    frt, cap_rep, _ = timed_run(
        fast_engine, mk, budget=budget, channels=2, renegotiate=True,
        capture_snapshots=True)

    full = canon(fast_rep)
    assert canon(cap_rep) == full, "capture_snapshots changed the run"
    replayed = 0
    suffix_ok = True
    for snap in frt.barrier_snapshots:
        resumed = snap.resume()
        suffix_ok &= canon(resumed) == full
        replayed += 1

    events = fast_rep.engine["events"]
    return {
        "tenants": n + 1,
        "budget": budget,
        "events": events,
        "renegotiations": fast_rep.renegotiations,
        "snapshots_replayed": replayed,
        "fast_s": fast_s,
        "ref_s": ref_s,
        "fast_events_per_s": events / fast_s if fast_s else 0.0,
        "speedup": ref_s / fast_s if fast_s else 0.0,
        "reports_equal": full == canon(ref_rep),
        "suffix_replay_identical": suffix_ok and replayed > 0,
    }


LEDGER_INFORMATIONAL = {"overhead_s", "queue_wait_s", "renegotiation_solve_s"}


def ledger_sums(report) -> bool:
    """Every completed tenant's attribution buckets sum to its overhead_s."""
    for t in report.tenants:
        if t.status != "completed" or not t.attribution:
            continue
        total = t.attribution["overhead_s"]
        summed = sum(
            v for k, v in t.attribution.items() if k not in LEDGER_INFORMATIONAL
        )
        if abs(summed - total) > 1e-6 + 1e-9 * abs(total):
            return False
    return True


def churn_obs_cell(templates, plans, floors, smoke: bool, seed: int) -> dict:
    """Observability cell: the obs-off hot path is the gated quantity
    (``check_enginetime`` cell ``churn_obs`` — instrumentation must never
    regress it), with the obs-on cost, report purity (bit-identical
    simulated reports with a recorder attached) and the ledger-sum
    invariant reported alongside."""
    from repro.obs import ObsRecorder

    n, rate_hz, iters, conc = (60, 20_000.0, (2, 3), 80) if smoke else (
        300, 50_000.0, (3, 5), 330)
    items = poisson_workload(
        list(TEMPLATE_LAYERS), n, rate_hz, seed=seed + 1, iterations=iters
    )
    mean_floor = sum(floors.values()) / len(floors)
    budget = int(mean_floor * conc)
    mk = lambda mod: churn_tenants(mod, templates, plans, items)

    _, fast_rep, fast_s = timed_run(
        fast_engine, mk, budget=budget, channels=2, renegotiate=True)
    recorder = ObsRecorder()
    _, obs_rep, obs_s = timed_run(
        fast_engine, mk, budget=budget, channels=2, renegotiate=True,
        obs=recorder)
    _, ref_rep, ref_s = timed_run(
        ref_engine, mk, budget=budget, channels=2, renegotiate=True)

    events = fast_rep.engine["events"]
    return {
        "tenants": n,
        "budget": budget,
        "events": events,
        "fast_s": fast_s,                 # obs off: the gated hot path
        "obs_s": obs_s,                   # ObsRecorder attached
        "ref_s": ref_s,
        "obs_cost": obs_s / fast_s if fast_s else 0.0,
        "speedup": ref_s / fast_s if fast_s else 0.0,
        "recorded_spans": len(recorder.ops) + len(recorder.transfers)
        + len(recorder.stalls),
        "reports_equal": canon(fast_rep) == canon(ref_rep)
        and canon(obs_rep) == canon(ref_rep),
        "ledger_sums": ledger_sums(fast_rep) and ledger_sums(obs_rep),
    }


def tune_cell(templates, plans, floors, smoke: bool, seed: int) -> dict:
    """Ledger victim policy vs floor-greedy on the reneg churn shape.

    ``fast_s`` — the gated quantity — is the ledger-policy run: every
    renegotiation snapshots the engine at the loop top and replays the
    suffix once per candidate, so this cell bounds the probing overhead
    relative to the greedy baseline (``ref_s``) on the same workload.
    ``reports_equal`` pins the greedy default against the frozen reference
    engine (the ledger run legitimately diverges — it picks different
    victims)."""
    from repro.tune import LedgerVictimPolicy

    n = 12 if smoke else 120
    items = poisson_workload(
        ["small", "medium"], n, 50.0, seed=seed + 2, iterations=(1, 3))
    budget = floors["large"] + (floors["small"] + floors["medium"]) // 2

    def mk(mod):
        ts = [mod.Tenant(
            "base", templates["large"], list(plans["large"][1]),
            limit=plans["large"][0], iterations=max(6, n // 2), priority=0.5)]
        return ts + churn_tenants(mod, templates, plans, items)

    policy = LedgerVictimPolicy()
    _, ledger_rep, ledger_s = timed_run(
        fast_engine, mk, budget=budget, channels=2, renegotiate=True,
        victim_policy=policy, record_events=False)
    _, greedy_rep, greedy_s = timed_run(
        fast_engine, mk, budget=budget, channels=2, renegotiate=True,
        record_events=False)
    _, ref_rep, _ = timed_run(
        ref_engine, mk, budget=budget, channels=2, renegotiate=True)

    events = ledger_rep.engine["events"]
    return {
        "tenants": n + 1,
        "budget": budget,
        "events": events,
        "fast_s": ledger_s,               # ledger probing path: gated
        "ref_s": greedy_s,                # floor-greedy on the same workload
        "probes": policy.probes,
        "staged": policy.staged,
        "probe_cost": ledger_s / greedy_s if greedy_s else 0.0,
        "renegotiations": ledger_rep.renegotiations,
        "reports_equal": canon(greedy_rep) == canon(ref_rep),
    }


def mesh_cell(templates, plans, smoke: bool) -> dict:
    """data=4 mesh: per-device pools, collectives, contended HostLink."""
    iterations = 3 if smoke else 50
    mk = lambda mod: mesh_tenants(mod, templates, plans, 4, iterations)
    _, fast_rep, fast_s = timed_run(
        fast_engine, mk, channels=2, link=(HW.link_bw, 2))
    _, ref_rep, ref_s = timed_run(
        ref_engine, mk, channels=2, link=(HW.link_bw, 2))
    events = fast_rep.engine["events"]
    return {
        "devices": 4,
        "iterations": iterations,
        "events": events,
        "fast_s": fast_s,
        "ref_s": ref_s,
        "fast_events_per_s": events / fast_s if fast_s else 0.0,
        "speedup": ref_s / fast_s if fast_s else 0.0,
        "reports_equal": canon(fast_rep) == canon(ref_rep),
    }


def run(smoke: bool = False, seed: int = 11) -> dict:
    """All cells; importable by tools/check_enginetime.py."""
    templates, plans, floors = build_templates()
    churn = churn_cell(templates, plans, floors, smoke, seed)
    reneg = churn_reneg_cell(templates, plans, floors, smoke, seed)
    obs = churn_obs_cell(templates, plans, floors, smoke, seed)
    mesh = mesh_cell(templates, plans, smoke)
    tune = tune_cell(templates, plans, floors, smoke, seed)
    all_equal = (
        churn["reports_equal"] and reneg["reports_equal"]
        and obs["reports_equal"] and mesh["reports_equal"]
        and tune["reports_equal"]
    )
    return {
        "mode": "smoke" if smoke else "full",
        "hardware": HW.name,
        "seed": seed,
        "limit_frac": LIMIT_FRAC,
        "churn": churn,
        "churn_reneg": reneg,
        "churn_obs": obs,
        "mesh_data4": mesh,
        "tune": tune,
        "all_reports_equal": all_equal,
        "suffix_replay_identical": reneg["suffix_replay_identical"],
        "ledger_sums": obs["ledger_sums"],
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small workloads for CI; skips the wall-time gate")
    ap.add_argument("--seed", type=int, default=11)
    ap.add_argument("--out", default="BENCH_engine.json")
    args = ap.parse_args(argv)

    result = run(smoke=args.smoke, seed=args.seed)

    ok_equal = result["all_reports_equal"]
    ok_suffix = result["suffix_replay_identical"]
    ok_ledger = result["ledger_sums"]
    # Wall time is too noisy to gate at smoke scale (check_enginetime gates
    # the ratio with a noise floor + retry); the full run must hit 10x.
    ok_speedup = args.smoke or result["churn"]["speedup"] >= SPEEDUP_TARGET
    result["acceptance"] = {
        "all_reports_equal": ok_equal,
        "suffix_replay_identical": ok_suffix,
        "ledger_sums": ok_ledger,
        "churn_speedup_10x": ok_speedup,
    }
    write_bench_json(args.out, result)

    c, r, m = result["churn"], result["churn_reneg"], result["mesh_data4"]
    print(f"engine ({result['mode']}): fast vs frozen reference")
    print(
        f"  churn      {c['tenants']:5d} tenants  {c['events']:7d} events  "
        f"{c['fast_events_per_s']:10.0f} ev/s fast  {c['ref_events_per_s']:9.0f} ev/s ref  "
        f"speedup {c['speedup']:5.2f}x  equal={c['reports_equal']}"
    )
    print(
        f"  churn+reneg {r['tenants']:4d} tenants  {r['events']:7d} events  "
        f"speedup {r['speedup']:5.2f}x  re-plans {r['renegotiations']}  "
        f"suffix replays {r['snapshots_replayed']} identical={r['suffix_replay_identical']}"
    )
    o = result["churn_obs"]
    print(
        f"  churn+obs  {o['tenants']:5d} tenants  {o['events']:7d} events  "
        f"obs cost {o['obs_cost']:5.2f}x ({o['recorded_spans']} spans)  "
        f"equal={o['reports_equal']} ledger_sums={o['ledger_sums']}"
    )
    print(
        f"  mesh data=4 {m['iterations']:4d} iters  {m['events']:7d} events  "
        f"speedup {m['speedup']:5.2f}x  equal={m['reports_equal']}"
    )
    t = result["tune"]
    print(
        f"  tune       {t['tenants']:5d} tenants  {t['events']:7d} events  "
        f"probe cost {t['probe_cost']:5.2f}x ({t['probes']} probes, "
        f"{t['staged']} staged)  equal={t['reports_equal']}"
    )
    print(f"wrote {args.out}; acceptance: {result['acceptance']}")
    return 0 if (ok_equal and ok_suffix and ok_ledger and ok_speedup) else 1


if __name__ == "__main__":
    sys.exit(main())
