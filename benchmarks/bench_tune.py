"""Tuning benchmark: ledger-guided runtime decisions vs the static defaults.

Three cells, one per ``repro.tune`` decision surface:

  * **victim** — an anchor tenant (lowest priority, transfer-bound: squeezing
    it is expensive) and a nimble tenant (slightly higher priority,
    compute-rich: swaps hide under compute, squeezing it is nearly free)
    share one HBM budget with a seeded Poisson newcomer stream.  Floor-greedy
    victim selection always shrinks the anchor (lowest priority first); the
    ledger policy probes each candidate by replaying the suffix from the
    loop-top snapshot and picks the squeeze with the lowest SLO-weighted
    marginal stall.  Gate: ledger beats greedy on mean newcomer queue wait at
    equal-or-lower total added victim overhead, with zero overflow events.
  * **budget_split** — colocation cells whose programs have unequal
    priorities.  ``proportional_shares`` ignores priority entirely; the
    coordinate-descent tuner moves budget toward the high-priority program
    until SLO-weighted marginal stall equalizes.  Gate: tuned never worse on
    any cell and strictly better on at least one.
  * **lanes** — a contended ``data=4`` mesh where swap-ins queue behind
    swap-outs on the shared host-link lane pool.  ``run_mesh`` probes the
    per-direction queue-wait decomposition and carves the lanes
    asymmetrically.  Gate: the directional carve is never worse than the
    static pool on this workload.

A fourth check pins the defaults: with every tuning knob at its default the
victim workload's report stays bit-identical to the frozen
``runtime/_engine_reference.py`` engine.

Writes ``BENCH_tune.json`` (``--out``); exits non-zero when an acceptance
flag fails.

Usage:
  PYTHONPATH=src python -m benchmarks.bench_tune [--smoke] [--out BENCH_tune.json]
"""

from __future__ import annotations

import argparse
import json
import sys

from benchmarks.common import write_bench_json
from repro.core.autoswap import AutoSwapPlanner
from repro.core.simulator import GTX_1080TI
from repro.plan import MemoryProgram
from repro.runtime import (
    MemoryRuntime,
    Tenant,
    colocate_programs,
    planned_peak,
    poisson_workload,
    synthetic_train_trace,
)
from repro.runtime import _engine_reference as ref_engine
from repro.runtime.engine import simulated_report_dict
from repro.tune import LedgerVictimPolicy, slo_weighted_stall

HW = GTX_1080TI
SIZE_THRESHOLD = 1 << 20
LIMIT_FRAC = 0.7          # each plan solved at 70% of its trace peak


def solve_template(trace):
    pl = AutoSwapPlanner(trace, HW, size_threshold=SIZE_THRESHOLD)
    limit = int(pl.peak_load * LIMIT_FRAC)
    decisions = pl.select(limit, "swdoa")
    return limit, decisions, planned_peak(trace, decisions)


# ------------------------------------------------------------- victim cell
def build_victim_workload(smoke: bool, seed: int):
    """Anchor (cheap to pick, expensive to squeeze) + nimble (the reverse)
    + a Poisson newcomer stream that doesn't fit next to both floors."""
    if smoke:
        anchor_layers, anchor_iters = 10, 5
        nimble_iters = 12
        n_arrivals, rate_hz = 4, 60.0
    else:
        anchor_layers, anchor_iters = 14, 8
        nimble_iters = 20
        n_arrivals, rate_hz = 8, 40.0
    templates = {
        # Transfer-bound: little compute to hide extra swaps under, so a
        # lower limit costs real stall.  Lowest priority -> greedy's pick.
        "anchor": synthetic_train_trace(anchor_layers, flops_per_op=2e8),
        # Compute-rich with a large floor: swaps overlap compute, so the
        # same squeeze is nearly free -- the ledger finds this by probing.
        "nimble": synthetic_train_trace(
            5, act_bytes=24 << 20, weight_bytes=12 << 20, flops_per_op=4e9
        ),
        "small": synthetic_train_trace(4),
        "medium": synthetic_train_trace(6),
    }
    plans = {n: solve_template(tr) for n, tr in templates.items()}
    floors = {n: p[2] for n, p in plans.items()}
    items = poisson_workload(
        ["small", "medium"], n_arrivals, rate_hz, seed=seed, iterations=(1, 3)
    )
    iters = {"anchor": anchor_iters, "nimble": nimble_iters}
    budget = floors["anchor"] + floors["nimble"] + floors["small"] // 2
    return templates, plans, items, iters, budget


def make_victim_tenants(templates, plans, items, iters):
    tenants = [
        Tenant(
            name, templates[name], list(plans[name][1]), limit=plans[name][0],
            iterations=iters[name], priority=priority,
        )
        for name, priority in (("anchor", 0.4), ("nimble", 0.5))
    ]
    for it in items:
        limit, decisions, _ = plans[it.template]
        tenants.append(
            Tenant(
                it.name, templates[it.template], list(decisions), limit=limit,
                iterations=it.iterations, arrival_t=it.arrival_t, priority=2.0,
            )
        )
    return tenants


def run_victim_policy(workload, renegotiate: bool, policy=None):
    templates, plans, items, iters, budget = workload
    rt = MemoryRuntime(
        HW, budget=budget, channels=2, renegotiate=renegotiate,
        replan_size_threshold=SIZE_THRESHOLD, victim_policy=policy,
    )
    report = rt.run(make_victim_tenants(templates, plans, items, iters))
    waits = [t.queue_wait_s for t in report.tenants if t.arrival_t > 0.0]
    return report, {
        "policy": "fifo" if not renegotiate else
                  (policy.name if policy is not None else "greedy"),
        "makespan_s": report.makespan_s,
        "overflow_events": report.overflow_events,
        "newcomer_mean_wait_s": sum(waits) / len(waits) if waits else 0.0,
        "newcomer_max_wait_s": max(waits) if waits else 0.0,
        "renegotiations": report.renegotiations,
        "renegotiations_cancelled": report.renegotiations_cancelled,
        "renegotiation_freed_bytes": report.renegotiation_freed_bytes,
        "victim_overhead": {
            t.name: t.overhead for t in report.tenants if t.arrival_t == 0.0
        },
        "tenants": [t.as_dict() for t in report.tenants],
    }


def victim_cell(workload) -> dict:
    _, fifo = run_victim_policy(workload, renegotiate=False)
    _, greedy = run_victim_policy(workload, renegotiate=True)
    policy = LedgerVictimPolicy()
    _, ledger = run_victim_policy(workload, renegotiate=True, policy=policy)

    def added_overhead(row):
        return {
            name: oh - fifo["victim_overhead"][name]
            for name, oh in row["victim_overhead"].items()
        }
    greedy_added, ledger_added = added_overhead(greedy), added_overhead(ledger)
    cell = {
        "fifo": fifo,
        "greedy": greedy,
        "ledger": ledger,
        "greedy_added_victim_overhead": greedy_added,
        "ledger_added_victim_overhead": ledger_added,
        "ledger_probes": policy.probes,
        "ledger_staged": policy.staged,
        "ledger_decisions": policy.decision_log,
        "acceptance": {
            "ledger_beats_greedy_mean_wait":
                ledger["newcomer_mean_wait_s"] < greedy["newcomer_mean_wait_s"],
            "ledger_victim_overhead_not_worse":
                sum(ledger_added.values()) <= sum(greedy_added.values()) + 1e-12,
            "zero_overflow_events": ledger["overflow_events"] == 0,
        },
    }
    return cell


# ------------------------------------------------------- budget-split cells
def split_cell(layer_sets: dict, priorities: dict, budget_frac: float,
               split_evals: int = 24) -> dict:
    progs = {
        name: MemoryProgram.from_trace(synthetic_train_trace(n))
        for name, n in layer_sets.items()
    }
    kw = dict(hw=HW, budget_frac=budget_frac, channels=2,
              size_threshold=SIZE_THRESHOLD, iterations=2,
              priorities=priorities)
    prop = colocate_programs(progs, **kw)
    tuned = colocate_programs(progs, budget_split="tuned",
                              split_evals=split_evals, **kw)
    prop_stall = slo_weighted_stall(prop.report)
    tuned_stall = slo_weighted_stall(tuned.report)
    return {
        "programs": {n: {"layers": l, "priority": priorities[n]}
                     for n, l in layer_sets.items()},
        "budget_frac": budget_frac,
        "budget": tuned.budget,
        "proportional_shares": prop.shares,
        "tuned_shares": tuned.shares,
        "proportional_stall_s": prop_stall,
        "tuned_stall_s": tuned_stall,
        "split_tuning": tuned.split_tuning,
        "strict_win": tuned_stall < prop_stall,
        "not_worse": tuned_stall <= prop_stall + 1e-12,
        "all_completed": all(t.status == "completed"
                             for t in tuned.report.tenants),
    }


def budget_split_cells(smoke: bool) -> dict:
    cells = {
        "hi_lo": split_cell({"big": 12, "small": 4},
                            {"big": 4.0, "small": 0.5}, 0.6),
    }
    if not smoke:
        cells["three_way"] = split_cell(
            {"big": 12, "mid": 8, "small": 4},
            {"big": 4.0, "mid": 1.0, "small": 0.25}, 0.6,
        )
    return {
        "cells": cells,
        "acceptance": {
            "tuned_never_worse": all(c["not_worse"] for c in cells.values()),
            "tuned_strictly_better_somewhere":
                any(c["strict_win"] for c in cells.values()),
            "all_completed": all(c["all_completed"] for c in cells.values()),
        },
    }


# --------------------------------------------------------------- lanes cell
def lanes_cell(smoke: bool) -> dict:
    """Contended data=4 mesh where swap-ins queue behind swap-outs on the
    shared lane pool; ``lane_split="directional"`` probes and carves."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.dist import MeshSpec, capture_sharded_trace, run_mesh, solve_sharded

    def step(w, x):
        g = jax.grad(lambda w: ((jax.nn.relu(x @ w)) ** 2).sum())(w)
        return w - 0.01 * g

    dim = 128
    w = jax.ShapeDtypeStruct((dim, dim), jnp.float32)
    x = jax.ShapeDtypeStruct((dim // 2, dim), jnp.float32)
    cap = capture_sharded_trace(
        step, w, x, mesh=MeshSpec.make(data=4), hw=HW,
        in_specs=(P(None, None), P("data", None)), arg_names=["w", "x"],
        extra_collectives=[("all_reduce", dim * dim * 4)],
    )
    solved = solve_sharded(cap, HW, limit_frac=0.5, size_threshold=1)
    kw = dict(channels=2, iterations=2 if smoke else 3, link_lanes=3,
              link_bw=HW.link_bw * 0.5, record_events=False)
    static = run_mesh(solved, HW, lane_split="static", **kw)
    directional = run_mesh(solved, HW, lane_split="directional", **kw)
    return {
        "mesh": "data=4",
        "link_lanes": 3,
        "static_makespan_s": static.makespan_s,
        "directional_makespan_s": directional.makespan_s,
        "static_mean_overhead": static.mean_overhead(),
        "directional_mean_overhead": directional.mean_overhead(),
        "lane_info": directional.lane_info,
        "acceptance": {
            "directional_not_worse":
                directional.makespan_s <= static.makespan_s + 1e-12,
            "probe_carved_lanes":
                (directional.lane_info or {}).get("out_lanes") is not None,
        },
    }


# ------------------------------------------------------- defaults identity
def defaults_identity(workload) -> dict:
    """Victim workload at all-default knobs: fast engine vs the frozen
    reference engine, byte-identical canonical reports."""
    templates, plans, items, iters, budget = workload

    def run_engine(mod):
        rt = mod.MemoryRuntime(
            HW, budget=budget, channels=2, renegotiate=True,
            replan_size_threshold=SIZE_THRESHOLD,
        )
        tenants = [
            mod.Tenant(
                name, templates[name], list(plans[name][1]),
                limit=plans[name][0], iterations=iters[name], priority=pri,
            )
            for name, pri in (("anchor", 0.4), ("nimble", 0.5))
        ] + [
            mod.Tenant(
                it.name, templates[it.template], list(plans[it.template][1]),
                limit=plans[it.template][0], iterations=it.iterations,
                arrival_t=it.arrival_t, priority=2.0,
            )
            for it in items
        ]
        return rt.run(tenants)

    import repro.runtime.engine as fast_engine

    fast_canon = json.dumps(
        simulated_report_dict(run_engine(fast_engine)), sort_keys=True)
    ref_canon = json.dumps(
        simulated_report_dict(run_engine(ref_engine)), sort_keys=True)
    return {"bit_for_bit_equal": fast_canon == ref_canon}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small traces / short stream for CI")
    ap.add_argument("--seed", type=int, default=42)
    ap.add_argument("--out", default="BENCH_tune.json")
    args = ap.parse_args(argv)

    workload = build_victim_workload(args.smoke, args.seed)
    victim = victim_cell(workload)
    split = budget_split_cells(args.smoke)
    lanes = lanes_cell(args.smoke)
    identity = defaults_identity(workload)

    acceptance = {
        **{f"victim_{k}": v for k, v in victim["acceptance"].items()},
        **{f"split_{k}": v for k, v in split["acceptance"].items()},
        **{f"lanes_{k}": v for k, v in lanes["acceptance"].items()},
        "defaults_bit_identical_to_reference": identity["bit_for_bit_equal"],
    }
    report = {
        "mode": "smoke" if args.smoke else "full",
        "hardware": HW.name,
        "seed": args.seed,
        "limit_frac": LIMIT_FRAC,
        "budget": workload[4],
        "victim": victim,
        "budget_split": split,
        "lanes": lanes,
        "defaults_identity": identity,
        "acceptance": acceptance,
    }
    write_bench_json(args.out, report)

    g, l = victim["greedy"], victim["ledger"]
    print(
        f"tune ({report['mode']}): victim cell -- "
        f"greedy mean wait {g['newcomer_mean_wait_s']*1e3:.2f}ms, "
        f"ledger {l['newcomer_mean_wait_s']*1e3:.2f}ms "
        f"({victim['ledger_probes']} probes, {victim['ledger_staged']} staged)"
    )
    print(
        f"  added victim overhead: greedy "
        f"{sum(victim['greedy_added_victim_overhead'].values())*100:.2f}pp, "
        f"ledger {sum(victim['ledger_added_victim_overhead'].values())*100:.2f}pp; "
        f"overflow greedy {g['overflow_events']} / ledger {l['overflow_events']}"
    )
    for name, c in split["cells"].items():
        print(
            f"  split[{name}]: proportional {c['proportional_stall_s']*1e3:.3f}ms "
            f"-> tuned {c['tuned_stall_s']*1e3:.3f}ms "
            f"({len(c['split_tuning']['moves'])} moves, "
            f"{c['split_tuning']['evals']} trial colocations)"
        )
    carve = (lanes["lane_info"] or {}).get("out_lanes")
    print(
        f"  lanes: static {lanes['static_makespan_s']*1e3:.3f}ms -> "
        f"directional {lanes['directional_makespan_s']*1e3:.3f}ms "
        f"(carve {carve} out / {lanes['link_lanes'] - carve if carve else '-'} in)"
    )
    print(f"  defaults bit-identical to reference: {identity['bit_for_bit_equal']}")
    print(f"wrote {args.out}; acceptance: {acceptance}")
    return 0 if all(acceptance.values()) else 1


if __name__ == "__main__":
    sys.exit(main())
