"""Shared benchmark plumbing: CNN trace cache + CSV/JSON emission."""

from __future__ import annotations

import functools
import json
import os
import sys
import time

import jax

from repro.core.simulator import GTX_1080TI, assign_times
from repro.core.trace import trace_step_fn
from repro.models.cnn import CNN

CNN_MODELS = ("resnet18", "resnet34", "resnet50", "resnet101",
              "vgg11", "vgg13", "vgg16", "vgg19")


@functools.lru_cache(maxsize=None)
def cnn_trace(name: str, batch: int = 100, remat: bool = False):
    """One-iteration trace of <name>'s SGD train step at CIFAR batch size."""
    cnn = CNN(name)
    params = jax.eval_shape(cnn.init, jax.random.PRNGKey(0))
    x, y = cnn.trace_inputs(batch)

    if remat:
        def step(p, m, xx, yy):
            g = jax.grad(lambda pp: cnn.loss_remat(pp, xx, yy))(p)
            upd = lambda pp, mm, gg: (pp - 0.01 * (0.9 * mm + gg), 0.9 * mm + gg)
            out = jax.tree.map(upd, p, m, g)
            two = lambda t: isinstance(t, tuple) and len(t) == 2
            return (jax.tree.map(lambda t: t[0], out, is_leaf=two),
                    jax.tree.map(lambda t: t[1], out, is_leaf=two))
    else:
        def step(p, m, xx, yy):
            return cnn.train_step(p, m, xx, yy)

    tr = trace_step_fn(step, params, params, x, y)
    assign_times(tr, GTX_1080TI)
    return tr


BENCH_SCHEMA_VERSION = 1


def _git_sha() -> str | None:
    import subprocess

    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            timeout=10, cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        )
    except OSError:
        return None
    sha = out.stdout.strip()
    return sha if out.returncode == 0 and sha else None


def write_bench_json(path: str, payload: dict) -> None:
    """Write one benchmark's machine-readable report (`BENCH_*.json`).

    One canonical shape (indent=2, sorted keys) shared by every bench_*.py
    so reports diff cleanly across PRs.  Every report is stamped with a
    ``_meta`` block — schema version, the git SHA it was produced at, and an
    ISO timestamp — which is what lets ``tools/bench_history.py`` line the
    committed reports up into one trajectory."""
    payload = dict(payload)
    payload["_meta"] = {
        "schema_version": BENCH_SCHEMA_VERSION,
        "git_sha": _git_sha(),
        "written_at": time.strftime("%Y-%m-%dT%H:%M:%S%z", time.localtime()),
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)


def emit(rows: list[tuple], header: str = "name,us_per_call,derived"):
    print(header)
    for r in rows:
        print(",".join(str(x) for x in r))
    sys.stdout.flush()


class timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.elapsed = time.time() - self.t0
