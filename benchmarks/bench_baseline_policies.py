"""Paper Fig 11: SmartPool+AutoSwap vs the three baseline policies.

  * MXNet-memonger-style   — trading compute for memory: re-trace the CNN
    with jax.checkpoint (recompute in backward); footprint drops, overhead
    is the recompute time.
  * SuperNeurons-style     — swapping restricted to convolution outputs.
  * GeePS-style            — user-chosen swap set: weights/momentum only
    (the "end user decides which tensors" policy).
  * ours                   — full AutoSwap (all candidates, SWDOA).

All four run on identical traces + the identical simulator, so the
comparison isolates policy quality exactly as the paper's Fig 11 intends.
"""

from __future__ import annotations

from repro.core.autoswap import AutoSwapPlanner
from repro.core.simulator import GTX_1080TI, iteration_time, simulate_swap_schedule
from repro.core.smartpool import solve

from .common import cnn_trace, emit


def _swap_policy_rows(name, tr, keep_fn, tag):
    pl = AutoSwapPlanner(tr, GTX_1080TI)
    pl.candidates = [c for c in pl.candidates if keep_fn(c, tr)]
    if not pl.candidates:
        return [(f"fig11/{name}/{tag}", "0", "reduction=0.0%|overhead=0.00%")]
    limit, ov = pl.max_zero_overhead_reduction(method="swdoa", grid=16)
    red = 100 * (1 - limit / pl.peak_load)
    # plus a deeper point with overhead
    lmin = pl.load_min()
    deep = int(lmin + 0.1 * (pl.peak_load - lmin))
    r2 = pl.evaluate(deep, method="swdoa")
    red2 = 100 * (1 - deep / pl.peak_load)
    return [(
        f"fig11/{name}/{tag}",
        "0",
        f"zero_ov_reduction={red:.1f}%"
        f"|deep_reduction={red2:.1f}%|deep_overhead={r2.overhead*100:.1f}%",
    )]


def run(models=("vgg16", "resnet50")):
    rows = []
    for name in models:
        tr = cnn_trace(name)

        # memonger-style: recompute via jax.checkpoint
        tr_rm = cnn_trace(name, remat=True)
        base_t = iteration_time(tr, GTX_1080TI)
        rm_t = iteration_time(tr_rm, GTX_1080TI)
        red = 100 * (1 - tr_rm.peak_load() / tr.peak_load())
        rows.append((
            f"fig11/{name}/memonger",
            "0",
            f"reduction={red:.1f}%|overhead={(rm_t/base_t-1)*100:.1f}%",
        ))

        by_id = tr.by_id()
        rows += _swap_policy_rows(
            name, tr,
            lambda c, t: "conv" in (by_id[c.var].name or ""),
            "superneurons_conv_only",
        )
        rows += _swap_policy_rows(
            name, tr,
            lambda c, t: c.wraps,  # weights/momentum: the user-pickable set
            "geeps_manual_weights",
        )
        rows += _swap_policy_rows(name, tr, lambda c, t: True, "ours_autoswap")
    return rows


def main():
    emit(run())


if __name__ == "__main__":
    main()
