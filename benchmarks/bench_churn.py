"""Churn benchmark: preemptive plan renegotiation vs FIFO queueing.

One long-running base tenant (the victim candidate) plus a seeded Poisson
stream of newcomers share one HBM budget.  The same workload runs twice
through the ``repro.runtime`` engine:

  * **fifo** — a newcomer whose resident floor doesn't fit waits until a
    running tenant finishes and releases its reservation;
  * **renegotiate** — the runtime re-solves the victim's swap plan at a
    lower limit (the near-linear SwapSelection path) and applies it at the
    victim's next iteration barrier, admitting the newcomer into the freed
    reservation.

Acceptance (how ``tools/ci.sh`` gates the smoke mode):
  * renegotiation strictly reduces the newcomers' mean queue wait under the
    same Poisson workload;
  * the victim's added overhead stays bounded (it swaps more at a lower
    limit, it is not starved);
  * zero ``overflow_events`` in both runs (the budget is never force-
    exceeded);
  * the 1-tenant/K=2/eager path stays bit-for-bit equal to the frozen
    pre-runtime reference simulator (``core/_solver_reference.py``).

Writes ``BENCH_churn.json`` (``--out``); exits non-zero when an acceptance
flag fails.

Usage:
  PYTHONPATH=src python -m benchmarks.bench_churn [--smoke] [--out BENCH_churn.json]
"""

from __future__ import annotations

import argparse
import json
import sys
from bisect import bisect_left, bisect_right

from benchmarks.common import write_bench_json
from repro.core._solver_reference import reference_simulate_swap_schedule
from repro.core.autoswap import AutoSwapPlanner
from repro.core.simulator import GTX_1080TI
from repro.obs import MonitoredRecorder, priority_class
from repro.runtime import (
    MemoryRuntime,
    Tenant,
    planned_peak,
    poisson_workload,
    simulate_program,
    synthetic_train_trace,
)
from repro.runtime.engine import simulated_report_dict

HW = GTX_1080TI
SIZE_THRESHOLD = 1 << 20
LIMIT_FRAC = 0.7          # each plan solved at 70% of its trace peak
VICTIM_OVERHEAD_BOUND = 0.5   # added victim overhead (absolute) allowed

REFERENCE_FIELDS = ("baseline_s", "duration_s", "peak_resident", "stalls",
                    "delayed_mallocs", "tail_spill_s", "out_events", "in_events")


def solve_template(trace):
    pl = AutoSwapPlanner(trace, HW, size_threshold=SIZE_THRESHOLD)
    limit = int(pl.peak_load * LIMIT_FRAC)
    decisions = pl.select(limit, "swdoa")
    return limit, decisions, planned_peak(trace, decisions)


def build_workload(smoke: bool, seed: int):
    """Templates + one base tenant + a Poisson newcomer stream."""
    if smoke:
        layers = {"base": 10, "small": 4, "medium": 6}
        n_arrivals, rate_hz, base_iters = 4, 60.0, 6
    else:
        layers = {"base": 14, "small": 6, "medium": 10}
        n_arrivals, rate_hz, base_iters = 8, 40.0, 10
    templates = {n: synthetic_train_trace(l) for n, l in layers.items()}
    plans = {n: solve_template(tr) for n, tr in templates.items()}
    items = poisson_workload(
        ["small", "medium"], n_arrivals, rate_hz, seed=seed, iterations=(1, 3)
    )
    floors = {n: p[2] for n, p in plans.items()}
    # A small newcomer fits next to the base's full floor; a medium one does
    # not — under FIFO it waits for the base to finish, under renegotiation
    # the base shrinks at its next iteration barrier.
    budget = floors["base"] + (floors["small"] + floors["medium"]) // 2
    return templates, plans, items, base_iters, budget


def make_tenants(templates, plans, items, base_iters):
    """Fresh Tenant objects per run (floors are cached on the instance)."""
    tenants = [
        Tenant(
            "base", templates["base"], list(plans["base"][1]),
            limit=plans["base"][0], iterations=base_iters, priority=0.5,
        )
    ]
    for it in items:
        limit, decisions, _ = plans[it.template]
        tenants.append(
            Tenant(
                it.name, templates[it.template], list(decisions), limit=limit,
                iterations=it.iterations, arrival_t=it.arrival_t,
                priority=it.priority,
            )
        )
    return tenants


def run_policy(templates, plans, items, base_iters, budget, renegotiate: bool):
    rt = MemoryRuntime(
        HW, budget=budget, channels=2, renegotiate=renegotiate,
        replan_size_threshold=SIZE_THRESHOLD,
    )
    report = rt.run(make_tenants(templates, plans, items, base_iters))
    newcomers = [t for t in report.tenants if t.arrival_t > 0.0]
    waits = [t.queue_wait_s for t in newcomers]
    return report, {
        "policy": report.policy,
        "makespan_s": report.makespan_s,
        "overflow_events": report.overflow_events,
        "aggregate_peak": report.aggregate_peak,
        "newcomer_mean_wait_s": sum(waits) / len(waits) if waits else 0.0,
        "newcomer_max_wait_s": max(waits) if waits else 0.0,
        "renegotiations": report.renegotiations,
        "renegotiations_cancelled": report.renegotiations_cancelled,
        "renegotiation_freed_bytes": report.renegotiation_freed_bytes,
        "renegotiation_solve_ms": round(report.renegotiation_solve_ms, 3),
        "tenants": [t.as_dict() for t in report.tenants],
    }


SLO_QUANTILES = (0.50, 0.95, 0.99)
SLO_PRIORITIES = (0.5, 1.0, 2.0)
SLO_SKETCH_BUFFER = 128  # small enough that the full cell actually compacts

# The guard SLO sits far above any achievable wait: a single alert from it
# is a false alarm and fails the cell.  The tight SLO sits below every
# nonzero wait at storm concurrency, proving the detector does fire.
SLO_GUARD = "queue_wait.p99<10,name=guard"
SLO_TIGHT = "queue_wait.p99<1e-6,short=0.005,long=0.02,min=4,name=tight"


def slo_cell(smoke: bool, seed: int) -> dict:
    """SLO-percentile cell: a >=1000-arrival Poisson storm with the
    streaming monitor armed.  Validates (a) per-priority-class p50/p95/p99
    queue waits from the quantile sketch against exact post-hoc
    percentiles within the sketch's self-reported rank-error bound,
    (b) monitor purity — the simulated report is bit-identical with the
    monitor armed — and (c) a clean alert track: the generous guard SLO
    never fires (zero false alarms) while the tight one does."""
    if smoke:
        layers = {"base": 10, "small": 4, "medium": 6}
        n, rate_hz, conc = 150, 20_000.0, 20
    else:
        layers = {"base": 14, "small": 6, "medium": 10}
        n, rate_hz, conc = 1000, 100_000.0, 60
    templates = {nm: synthetic_train_trace(ly) for nm, ly in layers.items()}
    plans = {nm: solve_template(tr) for nm, tr in templates.items()}
    floors = {nm: p[2] for nm, p in plans.items()}
    items = poisson_workload(
        ["small", "medium"], n, rate_hz, seed=seed, iterations=(1, 2),
        priorities=SLO_PRIORITIES,
    )
    mean_floor = sum(floors.values()) / len(floors)
    budget = int(mean_floor * conc)  # overloaded: real queueing, real tails

    def run(obs):
        rt = MemoryRuntime(HW, budget=budget, channels=2, obs=obs,
                           record_events=False)
        return rt.run(make_tenants(templates, plans, items, base_iters=6))

    plain = run(None)
    recorder = MonitoredRecorder(slos=(SLO_GUARD, SLO_TIGHT),
                                 sketch_buffer=SLO_SKETCH_BUFFER)
    monitored = run(recorder)
    pure = (json.dumps(simulated_report_dict(plain), sort_keys=True)
            == json.dumps(simulated_report_dict(monitored), sort_keys=True))

    # Exact post-hoc waits per priority class, straight from the report.
    exact: dict[str, list] = {}
    for t in monitored.tenants:
        if t.status == "unschedulable":
            continue
        exact.setdefault(priority_class(t.priority), []).append(t.queue_wait_s)
    for waits in exact.values():
        waits.sort()

    classes = {}
    all_within = True
    for cls in sorted(exact):
        waits = exact[cls]
        sk = recorder.monitor.sketches.get(f"queue_wait.{cls}")
        entry = {"count": len(waits), "sketch_count": 0 if sk is None else sk.count,
                 "rank_error_bound": 0 if sk is None else sk.rank_error_bound()}
        for q in SLO_QUANTILES:
            key = f"p{format(q * 100, 'g')}"
            target = round(q * (len(waits) - 1))
            ev = waits[target]
            sv = None if sk is None else sk.quantile(q)
            entry[key] = {"sketch": sv, "exact": ev}
            if sv is None or sk.count != len(waits):
                within = False
            else:
                # Rank distance from the target to the sketch value's rank
                # interval in the exact order statistics (+1 discretization).
                lo, hi = bisect_left(waits, sv), bisect_right(waits, sv) - 1
                err = 0 if lo <= target <= hi else min(
                    abs(target - lo), abs(target - hi))
                entry[key]["rank_error"] = err
                within = err <= sk.rank_error_bound() + 1
            entry[key]["within_bound"] = within
            all_within = all_within and within
        classes[cls] = entry

    alerts = [a.as_dict() for a in recorder.alerts]
    guard_alerts = [a for a in alerts if a["slo"] == "guard"]
    tight_alerts = [a for a in alerts if a["slo"] == "tight"]
    ts_sorted = all(alerts[i]["t"] <= alerts[i + 1]["t"]
                    for i in range(len(alerts) - 1))

    summary = recorder.finalize()
    return {
        "arrivals": n,
        "rate_hz": rate_hz,
        "budget": budget,
        "sketch_buffer": SLO_SKETCH_BUFFER,
        "slos": summary["slos"],
        "classes": classes,
        "quantiles": summary["quantiles"],
        "alerts": {"guard": len(guard_alerts), "tight": len(tight_alerts),
                   "total": len(alerts), "ts_sorted": ts_sorted},
        "acceptance": {
            "monitor_pure": pure,
            "sketch_within_bounds": all_within,
            "zero_false_alarms": not guard_alerts,
            "tight_slo_fires": bool(tight_alerts),
            "alerts_ts_sorted": ts_sorted,
        },
    }


def reference_check(templates, plans) -> dict:
    """The engine's 1-tenant/2-channel/eager path vs the frozen simulator."""
    diffs = []
    for name, trace in templates.items():
        limit, decisions, _ = plans[name]
        ref = reference_simulate_swap_schedule(trace, decisions, HW, limit)
        got = simulate_program(trace, decisions, HW, limit, channels=2, prefetch="eager")
        for f in REFERENCE_FIELDS:
            if getattr(got, f) != getattr(ref, f):
                diffs.append(f"{name}.{f}")
    return {"bit_for_bit_equal": not diffs, "mismatches": diffs}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small traces / short stream for CI")
    ap.add_argument("--seed", type=int, default=42)
    ap.add_argument("--out", default="BENCH_churn.json")
    args = ap.parse_args(argv)

    templates, plans, items, base_iters, budget = build_workload(args.smoke, args.seed)
    _, fifo = run_policy(templates, plans, items, base_iters, budget, renegotiate=False)
    reneg_rep, reneg = run_policy(templates, plans, items, base_iters, budget, renegotiate=True)
    ref = reference_check(templates, plans)
    slo = slo_cell(args.smoke, args.seed)

    fifo_oh = {t["name"]: t["overhead"] for t in fifo["tenants"]}
    added_overhead = max(
        (t["overhead"] - fifo_oh.get(t["name"], 0.0) for t in reneg["tenants"]),
        default=0.0,
    )

    ok_wait = reneg["newcomer_mean_wait_s"] < fifo["newcomer_mean_wait_s"]
    ok_overflow = fifo["overflow_events"] == 0 and reneg["overflow_events"] == 0
    ok_victim = added_overhead <= VICTIM_OVERHEAD_BOUND
    ok_ref = ref["bit_for_bit_equal"]

    report = {
        "mode": "smoke" if args.smoke else "full",
        "hardware": HW.name,
        "seed": args.seed,
        "limit_frac": LIMIT_FRAC,
        "budget": budget,
        "floors": {n: p[2] for n, p in plans.items()},
        "workload": [it.as_dict() for it in items],
        "base_iterations": base_iters,
        "fifo": fifo,
        "renegotiate": reneg,
        "added_victim_overhead": added_overhead,
        "reference_check": ref,
        "slo": slo,
        "acceptance": {
            "renegotiation_reduces_queue_wait": ok_wait,
            "zero_overflow_events": ok_overflow,
            "victim_overhead_bounded": ok_victim,
            "single_tenant_matches_reference": ok_ref,
            **{f"slo_{k}": v for k, v in slo["acceptance"].items()},
        },
    }
    write_bench_json(args.out, report)

    print(
        f"churn ({report['mode']}): {len(items)} Poisson newcomers over a "
        f"{base_iters}-iteration base tenant, budget {budget/2**20:.1f}MiB"
    )
    print(
        f"  fifo:        mean wait {fifo['newcomer_mean_wait_s']*1e3:8.2f}ms  "
        f"max {fifo['newcomer_max_wait_s']*1e3:8.2f}ms  "
        f"makespan {fifo['makespan_s']*1e3:8.2f}ms  overflow {fifo['overflow_events']}"
    )
    print(
        f"  renegotiate: mean wait {reneg['newcomer_mean_wait_s']*1e3:8.2f}ms  "
        f"max {reneg['newcomer_max_wait_s']*1e3:8.2f}ms  "
        f"makespan {reneg['makespan_s']*1e3:8.2f}ms  overflow {reneg['overflow_events']}  "
        f"re-plans {reneg['renegotiations']} "
        f"({reneg['renegotiation_freed_bytes']/2**20:.1f}MiB freed, "
        f"{reneg['renegotiation_solve_ms']:.1f}ms solve)"
    )
    print(
        f"  added victim overhead {added_overhead*100:.2f}pp; "
        f"reference bit-for-bit: {ok_ref}"
    )
    print(
        f"  slo cell:    {slo['arrivals']} arrivals, "
        f"{len(slo['classes'])} priority classes, sketch buffer "
        f"{slo['sketch_buffer']}; alerts guard={slo['alerts']['guard']} "
        f"tight={slo['alerts']['tight']}"
    )
    for cls in sorted(slo["classes"]):
        e = slo["classes"][cls]
        print(
            f"    {cls}: n={e['count']} bound±{e['rank_error_bound']} ranks  "
            + "  ".join(
                f"{k}={e[k]['sketch']*1e3:.3f}/{e[k]['exact']*1e3:.3f}ms"
                for k in ("p50", "p95", "p99")
            )
        )
    print(f"wrote {args.out}; acceptance: {report['acceptance']}")
    ok_slo = all(slo["acceptance"].values())
    return 0 if (ok_wait and ok_overflow and ok_victim and ok_ref and ok_slo) else 1


if __name__ == "__main__":
    sys.exit(main())
