# One function per paper table. Prints ``name,us_per_call,derived`` CSV.
"""Benchmark runner: every paper table/figure + the roofline analysis.

  PYTHONPATH=src python -m benchmarks.run [--fast]
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="shorter BO sweep (fig9)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from benchmarks import (
        bench_autoswap,
        bench_baseline_policies,
        bench_combined,
        bench_planner_lm,
        bench_roofline,
        bench_smartpool,
    )
    from benchmarks.common import emit

    suites = {
        "smartpool": lambda: bench_smartpool.run(),
        "autoswap_table2": lambda: bench_autoswap.table2(),
        "autoswap_fig9": (lambda: bench_autoswap.fig9(bo_iters=4 if args.fast else 16)),
        "combined_fig10": lambda: bench_combined.run(),
        "baselines_fig11": lambda: bench_baseline_policies.run(),
        "planner_lm": lambda: bench_planner_lm.run(),
    }
    rows: list[tuple] = []
    for name, fn in suites.items():
        if args.only and args.only != name:
            continue
        t0 = time.time()
        try:
            rows += fn()
            print(f"# {name}: {time.time()-t0:.1f}s", file=sys.stderr)
        except Exception as e:  # keep the run going; surface the failure
            rows.append((f"{name}/ERROR", "0", repr(e)))
    emit(rows)
    if not args.only or args.only == "roofline":
        try:
            bench_roofline.main()
        except (FileNotFoundError, IndexError):
            print("# roofline: dry-run artifacts missing (run launch/dryrun.py --all)",
                  file=sys.stderr)


if __name__ == "__main__":
    main()
