"""Beyond-paper: the planner applied to the assigned LM architectures.

For a selection of smoke-scale LM archs, reports SmartPool vs online-pool
ratios and the AutoSwap zero-overhead reduction of the *training step*
(TPU v5e hardware model, host-DMA link), plus the offload-name plan the
training launcher would apply.  Runs through the repro.plan pass pipeline:
TraceCapture -> TimingAssign -> PoolPlacement -> OffloadLowering."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.core.simulator import TPU_V5E
from repro.models import build_model
from repro.plan import (
    IterationDetect,
    OffloadLowering,
    PassContext,
    Pipeline,
    PoolPlacement,
    TimingAssign,
    TraceCapture,
    swap_key,
)

from .common import emit

ARCHS = ("qwen3-4b", "gemma2-9b", "deepseek-v2-lite-16b", "mamba2-370m", "hymba-1.5b")


def run():
    rows = []
    for arch in ARCHS:
        # proxy scale: modest width, small vocab so the chunked-CE transient
        # (negligible per-device at full scale) doesn't mask the shoulder
        cfg = get_smoke_config(arch).reduced(d_model=256, vocab_size=2048)
        model = build_model(cfg)
        pshapes = model.init_shapes()
        B, S = 8, 256
        batch = {
            "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
            "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
        }
        if cfg.is_encoder_decoder:
            batch["frames"] = jax.ShapeDtypeStruct((B, cfg.enc_seq, cfg.d_model), jnp.float32)

        def step(params, batch):
            return model.loss(params, batch)[0]

        ctx = PassContext(hw=TPU_V5E, size_threshold=1 << 18)
        prog = Pipeline([
            TraceCapture(step, (pshapes, batch), max_scan_unroll=16),
            IterationDetect(),
            TimingAssign(),
            PoolPlacement(("best_fit", "cnmem", "exact")),
        ]).run(None, ctx)
        sp = prog.pool_plans["best_fit"]
        cn = prog.baselines["cnmem"]
        cnmem_ratio = cn.footprint / sp.peak_load if sp.peak_load else 1.0
        num_vars = len([v for v in prog.variables if v.size > 0])

        swap = prog.swap_planner(ctx.hw, ctx.size_threshold)
        limit, ov = swap.max_zero_overhead_reduction(method="swdoa", grid=12)
        red = 100 * (1 - limit / max(swap.peak_load, 1))
        off_limit = int(swap.peak_load * 0.8)
        prog = Pipeline([OffloadLowering(off_limit)]).run(prog, ctx)
        plan = prog.offload_plans[swap_key("swdoa", off_limit)]
        rows.append((
            f"planner_lm/{arch}",
            "0",
            f"vars={num_vars}"
            f"|smartpool={sp.competitive_ratio:.4f}|cnmem={cnmem_ratio:.4f}"
            f"|zero_ov_reduction={red:.1f}%"
            f"|offload={'+'.join(plan.offload_names) or 'none'}",
        ))
    return rows


def main():
    emit(run())


if __name__ == "__main__":
    main()
