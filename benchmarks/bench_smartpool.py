"""Paper Table I: competitive ratio + time complexity of SmartPool vs
CnMem-style pool vs cudaMalloc, on VGG/ResNet traces at batch 100.

CLI accepts ``--models`` / ``--batch`` so CI can run a tiny smoke subset
(e.g. ``--models vgg11 --batch 4``) and regression-check the ratios."""

from __future__ import annotations

import argparse
import time

from repro.core.baseline_pools import CnMemPool, exact_allocator
from repro.core.simulator import CUDA_MALLOC_COST_S, GTX_1080TI, POOL_LOOKUP_COST_S, iteration_time
from repro.core.smartpool import solve

from .common import CNN_MODELS, cnn_trace, emit


def run(batch: int = 100, models=CNN_MODELS):
    rows = []
    for name in models:
        tr = cnn_trace(name, batch)
        t0 = time.time()
        sp = solve(tr, "best_fit")
        solve_us = (time.time() - t0) * 1e6
        cn = CnMemPool().run(tr)
        ex = exact_allocator(tr)

        it_cuda = iteration_time(tr, GTX_1080TI, malloc_cost_s=CUDA_MALLOC_COST_S)
        it_pool = iteration_time(tr, GTX_1080TI, malloc_cost_s=POOL_LOOKUP_COST_S)
        rows.append((
            f"table1/{name}",
            f"{solve_us:.0f}",
            f"peak_MiB={tr.peak_load()/2**20:.0f}"
            f"|smartpool_ratio={sp.competitive_ratio:.4f}"
            f"|cnmem_ratio={cn.footprint/sp.peak_load:.4f}"
            f"|cuda_iter_ms={it_cuda*1e3:.1f}"
            f"|pool_speedup={it_cuda/it_pool:.2f}x",
        ))
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--models", nargs="+", default=list(CNN_MODELS), choices=CNN_MODELS)
    ap.add_argument("--batch", type=int, default=100)
    args = ap.parse_args(argv)
    emit(run(batch=args.batch, models=tuple(args.models)))


if __name__ == "__main__":
    main()
