"""Solve-time benchmark: old-vs-new trace->plan solve latency (Issue 3).

Times the frozen reference solvers (core/_solver_reference.py, the pre-fast-
path implementations) against the production solvers on CNN, LM and MoE
traces up to production scale (tens of thousands of variables), per stage:

  smartpool   offline-DSA placement, best_fit and first_fit
  autoswap    candidate scoring incl. the SWDOA submodular re-rank
  pipeline    end-to-end solve: placement + scoring + selection + simulated
              cost at an HBM limit (what tenant admission pays)

Every cell also checks *plan equality*: placements must match the reference
bit-for-bit, swap decisions exactly, SWDOA scores to float tolerance.

Writes BENCH_solvetime.json.  Exits non-zero when acceptance fails:
end-to-end speedup >= 10x on the largest trace, every plans_equal true.

  python -m benchmarks.bench_solvetime                 # full (minutes)
  python -m benchmarks.bench_solvetime --smoke         # CI-sized (seconds)

The reference AutoSwap scorer is O(k^2 T); on the largest trace the candidate
threshold is raised so one reference run stays measurable (minutes, not
hours) — the threshold is recorded in the JSON and both solvers see the same
instance, so the comparison stays apples-to-apples.
"""

from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np

from repro.core._solver_reference import ReferenceAutoSwapPlanner, reference_solve
from repro.core.autoswap import AutoSwapPlanner
from repro.core.simulator import GTX_1080TI, TPU_V5E, assign_times, simulate_swap_schedule
from repro.core.smartpool import solve
from repro.plan.passes import PassContext, Pipeline, PoolPlacement, SwapSelection, TimingAssign
from repro.plan.program import MemoryProgram, swap_key

LIMIT_FRAC = 0.6  # HBM limit for the selection stage, as a fraction of peak


def lm_trace(arch: str, layers: int | None = None, batch: int = 8, seq: int = 512,
             vocab: int = 8192, smoke: bool = False):
    import jax
    import jax.numpy as jnp

    from repro.configs import LayerSpec, get_config, get_smoke_config, uniform_program
    from repro.core.trace import trace_step_fn
    from repro.models import build_model

    if smoke:
        cfg = get_smoke_config(arch).reduced(d_model=256, vocab_size=2048)
    else:
        cfg = get_config(arch).reduced(vocab_size=vocab)
    if layers is not None:
        cfg = cfg.reduced(
            num_layers=layers,
            program=uniform_program(LayerSpec(attn="full", ffn="dense"), layers),
        )
    model = build_model(cfg)
    batch_spec = {
        "tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
    }

    def step(params, b):
        return model.loss(params, b)[0]

    tr = trace_step_fn(step, model.init_shapes(), batch_spec,
                       max_scan_unroll=max(256, layers or 0))
    assign_times(tr, TPU_V5E)
    return tr


def cnn_trace_case(name: str, batch: int):
    from .common import cnn_trace

    return cnn_trace(name, batch)


def _plans_equal(a, b) -> bool:
    return (
        a.offsets == b.offsets
        and a.footprint == b.footprint
        and a.peak_load == b.peak_load
        and a.lookup == b.lookup
    )


def _decisions_key(decisions):
    return [(d.var, d.size, d.out_after, d.in_before, d.wraps) for d in decisions]


def _time(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return time.perf_counter() - t0, out


def bench_trace(name: str, trace, hw, size_threshold: int) -> dict:
    n_vars = len([v for v in trace.variables if v.size > 0])
    row: dict = {
        "name": name,
        "n_vars": n_vars,
        "n_ops": trace.num_indices,
        "size_threshold": size_threshold,
        "hardware": hw.name,
    }
    ok = True

    # ------------------------------------------------------------ smartpool
    sp = {}
    ref_sp_plans = {}
    for method in ("best_fit", "first_fit"):
        sp_ref_s, ref_plan = _time(reference_solve, trace, method)
        fast_s, fast_plan = _time(solve, trace, method)
        equal = _plans_equal(ref_plan, fast_plan)
        ok &= equal
        ref_sp_plans[method] = ref_plan
        sp[method] = {
            "ref_s": round(sp_ref_s, 4),
            "fast_s": round(fast_s, 4),
            "speedup": round(sp_ref_s / fast_s, 2) if fast_s else float("inf"),
            "plans_equal": equal,
        }
    row["smartpool"] = sp

    # ------------------------------------------------------------- autoswap
    ref_s, ref_pl = _time(ReferenceAutoSwapPlanner, trace, hw, size_threshold)
    fast_s, fast_pl = _time(AutoSwapPlanner, trace, hw, size_threshold)
    scores_close = len(ref_pl.candidates) == len(fast_pl.candidates)
    if scores_close:
        for s, rtol in (("doa", 0), ("aoa", 0), ("wdoa", 1e-6), ("swdoa", 1e-6)):
            a = np.array([c.scores[s] for c in ref_pl.candidates])
            b = np.array([c.scores[s] for c in fast_pl.candidates])
            scores_close &= bool(np.allclose(a, b, rtol=rtol, atol=1e-12))
    limit = int(fast_pl.peak_load * LIMIT_FRAC)
    sel_ref_s, dec_ref = _time(ref_pl.select, limit, "swdoa")
    sel_fast_s, dec_fast = _time(fast_pl.select, limit, "swdoa")
    decisions_equal = _decisions_key(dec_ref) == _decisions_key(dec_fast)
    ok &= scores_close and decisions_equal
    row["autoswap"] = {
        "n_candidates": len(fast_pl.candidates),
        "limit": limit,
        "ref_s": round(ref_s + sel_ref_s, 4),
        "fast_s": round(fast_s + sel_fast_s, 4),
        "speedup": round((ref_s + sel_ref_s) / (fast_s + sel_fast_s), 2)
        if fast_s + sel_fast_s
        else float("inf"),
        "scores_close": scores_close,
        "decisions_equal": decisions_equal,
    }

    # -------------------------------------------------- pipeline end-to-end
    # Reference: placement + scoring + selection + simulated cost, composed
    # from the frozen-copy stage timings measured above (the expensive
    # reference scorer runs once per trace).  Fast: the actual repro.plan
    # pass pipeline, timed as one run — what tenant admission pays.
    sim_ref_s, _ = _time(simulate_swap_schedule, trace, dec_ref, hw, limit)
    e2e_ref_s = sp["best_fit"]["ref_s"] + ref_s + sel_ref_s + sim_ref_s

    def fast_end_to_end():
        program = MemoryProgram.from_trace(trace)
        ctx = PassContext(hw=hw, size_threshold=size_threshold)
        Pipeline(
            [TimingAssign(), PoolPlacement(("best_fit",)), SwapSelection(limit, "swdoa")]
        ).run(program, ctx)
        return program

    ref_plan = ref_sp_plans["best_fit"]
    e2e_fast_s, program = _time(fast_end_to_end)
    fast_plan = program.pool_plans["best_fit"]
    fast_dec = program.swap_summaries[swap_key("swdoa", limit)].decisions
    e2e_equal = _plans_equal(ref_plan, fast_plan) and (
        _decisions_key(dec_ref) == _decisions_key(fast_dec)
    )
    ok &= e2e_equal
    row["pipeline"] = {
        "ref_s": round(e2e_ref_s, 4),
        "fast_s": round(e2e_fast_s, 4),
        "speedup": round(e2e_ref_s / e2e_fast_s, 2) if e2e_fast_s else float("inf"),
        "plans_equal": e2e_equal,
        "solve_ms": {k: round(v, 3) for k, v in program.solve_ms.items()},
    }
    row["all_equal"] = ok
    return row


def run(smoke: bool = False) -> dict:
    cases = []
    if smoke:
        cases.append(("vgg11/b4", cnn_trace_case("vgg11", 4), GTX_1080TI, 1 << 20))
        cases.append(("qwen3-4b/smoke", lm_trace("qwen3-4b", smoke=True), TPU_V5E, 1 << 18))
    else:
        cases.append(("vgg16/b64", cnn_trace_case("vgg16", 64), GTX_1080TI, 1 << 20))
        cases.append(("qwen3-4b/36L", lm_trace("qwen3-4b"), TPU_V5E, 1 << 20))
        cases.append(
            ("deepseek-v2-lite-16b/27L", lm_trace("deepseek-v2-lite-16b", batch=4), TPU_V5E, 1 << 20)
        )
        # Production-scale: ~20k variables.  The reference scorer is O(k^2 T),
        # so the candidate floor is raised to keep its one timed run in
        # minutes; both solvers see the identical instance.
        cases.append(("qwen3-4b/144L", lm_trace("qwen3-4b", layers=144), TPU_V5E, 1 << 26))

    rows = [bench_trace(name, tr, hw, thr) for name, tr, hw, thr in cases]
    largest = max(rows, key=lambda r: r["n_vars"])
    all_equal = all(r["all_equal"] for r in rows)
    e2e = largest["pipeline"]["speedup"]
    out = {
        "mode": "smoke" if smoke else "full",
        "limit_frac": LIMIT_FRAC,
        "traces": rows,
        "largest": largest["name"],
        "largest_end_to_end_speedup": e2e,
        "all_plans_equal": all_equal,
        "acceptance": {
            # >=10x end-to-end on the largest trace is a full-mode claim;
            # smoke instances are too small to amortize setup, so the smoke
            # gate is plan equality (the regression gate on absolute solve
            # time lives in tools/check_solvetime.py).
            "end_to_end_10x": bool(e2e >= 10.0) if not smoke else True,
            "plans_equal": all_equal,
        },
    }
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="CI-sized instances")
    ap.add_argument("--out", default="BENCH_solvetime.json")
    args = ap.parse_args(argv)

    result = run(smoke=args.smoke)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2, sort_keys=True)
    for r in result["traces"]:
        print(
            f"{r['name']}: n={r['n_vars']} "
            f"smartpool {r['smartpool']['best_fit']['speedup']}x "
            f"autoswap {r['autoswap']['speedup']}x "
            f"end-to-end {r['pipeline']['speedup']}x "
            f"equal={r['all_equal']}"
        )
    print(
        f"largest={result['largest']} end_to_end={result['largest_end_to_end_speedup']}x "
        f"plans_equal={result['all_plans_equal']} -> wrote {args.out}"
    )
    failed = [k for k, v in result["acceptance"].items() if not v]
    if failed:
        print(f"ACCEPTANCE FAILED: {failed}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
