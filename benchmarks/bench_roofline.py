"""Roofline analysis per (arch x shape x mesh) from the dry-run artifacts.

Three terms per cell (TPU v5e constants: 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI):

  compute_s    = HLO_FLOPs / (chips * peak)     [analytic, loop-aware — see
                                                 core/costmodel.py for why
                                                 compiled.cost_analysis()
                                                 undercounts scans]
  memory_s     = HLO_bytes / (chips * hbm_bw)   [analytic unfused bound]
  collective_s = collective_bytes / link_bw     [loop-aware census of the
                                                 compiled per-device HLO]

Also: MODEL_FLOPS = 6*N_active*tokens (train) / 2*N_active*tokens (serve),
the useful-compute ratio MODEL_FLOPS/HLO_FLOPs, the dominant term, and the
roofline fraction  ideal_compute_s / dominant_term  (the §Perf score).

Writes results/roofline.csv; prints one row per cell.
"""

from __future__ import annotations

import glob
import json
import os

PEAK = 197e12
HBM = 819e9
LINK = 50e9


def analyze(rec: dict) -> dict:
    chips = 1
    for v in rec["mesh_shape"].values():
        chips *= v
    flops_g = rec["analytic"]["flops"]
    # fusion-aware HBM traffic when available (see core/costmodel.py); the
    # unfused sum is an upper bound and is also reported
    bytes_g = rec["analytic"].get("bytes_fused") or rec["analytic"]["bytes"]
    coll_dev = rec["collectives_loop_aware"]["total_bytes"]

    compute_s = flops_g / chips / PEAK
    memory_s = bytes_g / chips / HBM
    memory_unfused_s = rec["analytic"]["bytes"] / chips / HBM
    collective_s = coll_dev / LINK

    mult = 6 if rec["kind"] == "train" else 2
    model_flops = mult * rec["n_active_params"] * rec["tokens_per_step"]
    ideal_s = model_flops / chips / PEAK
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)
    frac = ideal_s / max(terms.values()) if max(terms.values()) > 0 else 0.0

    mem = rec["memory"]
    hbm_gib = (mem.get("argument_size_in_bytes", 0) + mem.get("temp_size_in_bytes", 0)) / 2**30
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "chips": chips,
        "compute_s": compute_s, "memory_s": memory_s,
        "memory_unfused_s": memory_unfused_s, "collective_s": collective_s,
        "dominant": dominant,
        "model_flops": model_flops,
        "hlo_flops": flops_g,
        "useful_ratio": model_flops / flops_g if flops_g else 0.0,
        "roofline_frac": frac,
        "hbm_gib_per_dev": hbm_gib,
    }


def run(dryrun_dir: str = "results/dryrun", out_csv: str = "results/roofline.csv",
        baseline_only: bool = True):
    rows = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        parts = os.path.basename(path)[:-5].split("__")
        if baseline_only and len(parts) != 3:
            continue  # __<profile> cells are reported in EXPERIMENTS §Perf
        rec = json.load(open(path))
        rows.append(analyze(rec))
    if out_csv:
        os.makedirs(os.path.dirname(out_csv), exist_ok=True)
        with open(out_csv, "w") as f:
            cols = list(rows[0].keys())
            f.write(",".join(cols) + "\n")
            for r in rows:
                f.write(",".join(f"{r[c]:.6g}" if isinstance(r[c], float) else str(r[c])
                                 for c in cols) + "\n")
    return rows


def main():
    rows = run()
    print("name,us_per_call,derived")
    for r in rows:
        print(
            f"roofline/{r['arch']}/{r['shape']}/{r['mesh']},"
            f"{max(r['compute_s'], r['memory_s'], r['collective_s'])*1e6:.0f},"
            f"dom={r['dominant']}|frac={r['roofline_frac']:.3f}"
            f"|c={r['compute_s']*1e3:.1f}ms|m={r['memory_s']*1e3:.1f}ms"
            f"|coll={r['collective_s']*1e3:.1f}ms|useful={r['useful_ratio']:.2f}"
            f"|hbm={r['hbm_gib_per_dev']:.1f}GiB"
        )


if __name__ == "__main__":
    main()
