"""Per-architecture smoke tests: reduced config, one train step on CPU,
output shapes + finite values.  (Deliverable f: one smoke per assigned arch.)"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_smoke_config, list_archs, SHAPES, input_specs, supports_shape
from repro.models import build_model


def smoke_batch(cfg, B=2, S=32):
    batch = {"tokens": jnp.arange(B * S, dtype=jnp.int32).reshape(B, S) % cfg.vocab_size}
    batch["labels"] = batch["tokens"]
    if cfg.frontend == "vision_stub":
        npatch = cfg.num_patch_tokens
        batch["patch_embeds"] = jnp.full((B, npatch, cfg.d_model), 0.01, jnp.float32)
        St = S + npatch
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(St, dtype=jnp.int32)[None, None], (3, B, St)
        )
    if cfg.is_encoder_decoder:
        batch["frames"] = jnp.full((B, cfg.enc_seq, cfg.d_model), 0.01, jnp.float32)
    return batch


@pytest.mark.parametrize("arch", list_archs())
def test_arch_train_step_smoke(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = smoke_batch(cfg)

    (loss, metrics), grads = jax.value_and_grad(
        lambda p: model.loss(p, batch), has_aux=True
    )(params)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch} loss not finite"
    gsq = jax.tree.reduce(
        lambda a, b: a + b, jax.tree.map(lambda g: jnp.sum(g.astype(jnp.float32) ** 2), grads)
    )
    assert bool(jnp.isfinite(gsq)), f"{arch} grads not finite"
    # loss should be near ln(vocab) for random init
    assert 0.5 * np.log(cfg.vocab_size) < float(loss) < 2.5 * np.log(cfg.vocab_size)


@pytest.mark.parametrize("arch", list_archs())
def test_arch_decode_smoke(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 24
    batch = {k: v for k, v in smoke_batch(cfg, B, S).items() if k != "labels"}
    extra = cfg.num_patch_tokens if cfg.frontend == "vision_stub" else 0
    logits, cache = model.prefill(params, batch, max_seq=S + extra + 8)
    assert logits.shape[0] == B and logits.shape[-1] == cfg.vocab_size
    tok = jnp.zeros((B, 1), jnp.int32)
    logits2, cache = model.decode_step(params, cache, tok, jnp.asarray(S + extra, jnp.int32))
    assert logits2.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits2).all()), f"{arch} decode logits not finite"


@pytest.mark.parametrize("arch", list_archs())
def test_full_config_exact_assignment(arch):
    """The FULL configs match the assigned table (no allocation: shapes only)."""
    cfg = get_config(arch)
    expected = {
        "qwen3-4b": (36, 2560, 32, 8, 9728, 151_936),
        "gemma3-4b": (34, 2560, 8, 4, 10240, 262_144),
        "starcoder2-7b": (32, 4608, 36, 4, 18432, 49_152),
        "gemma2-9b": (42, 3584, 16, 8, 14336, 256_000),
        "deepseek-v2-lite-16b": (27, 2048, 16, 16, 10944, 102_400),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 16384, 202_048),
        "qwen2-vl-7b": (28, 3584, 28, 4, 18944, 152_064),
        "mamba2-370m": (48, 1024, 0, 0, 0, 50_280),
        "whisper-large-v3": (32, 1280, 20, 20, 5120, 51_866),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32_001),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.d_ff, cfg.vocab_size)
    assert got == expected
    # input specs exist for every supported shape
    for shape in SHAPES:
        if supports_shape(cfg, shape):
            specs = input_specs(cfg, shape)
            assert specs


def test_long500k_only_for_subquadratic():
    assert supports_shape(get_config("mamba2-370m"), "long_500k")
    assert supports_shape(get_config("hymba-1.5b"), "long_500k")
    for arch in ("qwen3-4b", "gemma2-9b", "whisper-large-v3"):
        assert not supports_shape(get_config(arch), "long_500k")


def test_param_counts_in_expected_band():
    """Full-config parameter counts should be near the nameplate sizes."""
    bands = {
        "qwen3-4b": (3.5e9, 4.5e9),
        "starcoder2-7b": (6.5e9, 7.9e9),
        "gemma2-9b": (8.0e9, 10.5e9),
        "deepseek-v2-lite-16b": (14e9, 18e9),
        "llama4-maverick-400b-a17b": (380e9, 420e9),
        "mamba2-370m": (0.30e9, 0.45e9),
        "hymba-1.5b": (1.2e9, 2.0e9),
    }
    for arch, (lo, hi) in bands.items():
        cfg = get_config(arch)
        model = build_model(cfg)
        shapes = model.init_shapes()
        n = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(shapes))
        assert lo < n < hi, f"{arch}: {n/1e9:.2f}B params out of band ({lo/1e9}-{hi/1e9})"
