"""Shared fixtures. NOTE: no XLA_FLAGS here — tests see the 1 real device;
multi-device lowering is tested via subprocess (test_distributed.py)."""

import jax
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
