"""Prefill+decode == full forward: the KV-cache/state handoff is exact.

For each family, the next-token logits from (prefill T tokens, decode token
T) must match the last-position logits of a full (T+1)-token forward.
Exercises: full cache, sliding-window ring cache past the window, MLA
compressed cache (absorbed decode), Mamba recurrent state, hybrid both.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config, list_archs
from repro.models import build_model

TOL = dict(rtol=2e-3, atol=2e-3)


def _batch_for(cfg, tokens):
    B, S = tokens.shape
    batch = {"tokens": tokens}
    if cfg.frontend == "vision_stub":
        npatch = cfg.num_patch_tokens
        batch["patch_embeds"] = jnp.full((B, npatch, cfg.d_model), 0.01, jnp.float32)
        St = S + npatch
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(St, dtype=jnp.int32)[None, None], (3, B, St)
        )
    if cfg.is_encoder_decoder:
        batch["frames"] = jnp.full((B, cfg.enc_seq, cfg.d_model), 0.01, jnp.float32)
    return batch


@pytest.mark.parametrize("arch", list_archs())
def test_decode_matches_full_forward(arch):
    import dataclasses

    cfg = get_smoke_config(arch)
    if cfg.num_experts:
        # Capacity dropping is batch-composition-dependent by design (same
        # tokens rank differently in a 25- vs 1-token batch); raise capacity
        # so the equivalence check isolates the cache/state handoff.
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    B, T = 2, 24  # > smoke window (16): exercises the ring cache wrap
    key = jax.random.PRNGKey(7)
    toks = jax.random.randint(key, (B, T + 1), 0, cfg.vocab_size, jnp.int32)

    extra = cfg.num_patch_tokens if cfg.frontend == "vision_stub" else 0
    full_logits, _ = model.prefill(params, _batch_for(cfg, toks), max_seq=T + 1 + extra)

    _, cache = model.prefill(params, _batch_for(cfg, toks[:, :T]), max_seq=T + 1 + extra)
    dec_logits, _ = model.decode_step(
        params, cache, toks[:, T : T + 1], jnp.asarray(T + extra, jnp.int32)
    )
    np.testing.assert_allclose(
        np.asarray(dec_logits[:, -1]), np.asarray(full_logits[:, -1]), **TOL
    )
