"""Pallas kernels vs ref.py oracles: shape/dtype/flag sweeps (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from repro.testing import given, settings, st  # hypothesis or deterministic fallback

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ops import flash_mha, fused_rmsnorm, ssd
from repro.kernels.rmsnorm import rmsnorm
from repro.kernels.ssd_scan import ssd_scan


def _qkv(key, B, Sq, Sk, H, KV, hd, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (B, Sq, H, hd), dtype)
    k = jax.random.normal(k2, (B, Sk, KV, hd), dtype)
    v = jax.random.normal(k3, (B, Sk, KV, hd), dtype)
    return q, k, v


TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5), jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,S,H,KV,hd,block",
    [
        (1, 128, 2, 2, 64, 128),    # MHA
        (2, 256, 4, 2, 64, 128),    # GQA
        (1, 256, 4, 1, 128, 128),   # MQA, wide head
        (2, 512, 2, 2, 64, 256),    # bigger blocks
    ],
)
def test_flash_causal_sweep(dtype, B, S, H, KV, hd, block):
    q, k, v = _qkv(jax.random.PRNGKey(0), B, S, S, H, KV, hd, dtype)
    out = flash_attention(q, k, v, causal=True, block_q=block, block_k=block)
    exp = ref.mha_reference(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(exp, np.float32), **TOL[dtype]
    )


@pytest.mark.parametrize("window", [32, 100, 512])
def test_flash_window(window):
    q, k, v = _qkv(jax.random.PRNGKey(1), 1, 256, 256, 2, 2, 64, jnp.float32)
    out = flash_attention(q, k, v, causal=True, window=window, block_q=128, block_k=128)
    exp = ref.mha_reference(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=2e-5, atol=2e-5)


def test_flash_softcap_and_noncausal():
    q, k, v = _qkv(jax.random.PRNGKey(2), 2, 128, 128, 2, 2, 64, jnp.float32)
    out = flash_attention(q, k, v, causal=False, softcap=30.0, block_q=128, block_k=128)
    exp = ref.mha_reference(q, k, v, causal=False, softcap=30.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=2e-5, atol=2e-5)


def test_flash_cross_lengths():
    """Sq != Sk (cross-attention shape)."""
    q, _, _ = _qkv(jax.random.PRNGKey(3), 1, 128, 128, 4, 4, 64, jnp.float32)
    _, k, v = _qkv(jax.random.PRNGKey(4), 1, 128, 256, 4, 4, 64, jnp.float32)
    out = flash_attention(q, k, v, causal=False, block_q=128, block_k=128)
    exp = ref.mha_reference(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=2e-5, atol=2e-5)


def test_flash_ops_fallback_on_odd_shapes():
    # 1500 (whisper) isn't block-divisible: ops.flash_mha must fall back.
    q, k, v = _qkv(jax.random.PRNGKey(5), 1, 96, 96, 2, 2, 64, jnp.float32)
    out = flash_mha(q, k, v, causal=False)
    exp = ref.mha_reference(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("chunk", [16, 32, 64])
@pytest.mark.parametrize("g", [1, 2])
def test_ssd_sweep(chunk, g):
    b, s, h, p, n = 2, 128, 4, 16, 8
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(jax.random.fold_in(key, 1), (b, s, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 2), (b, s, h)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 3), (h,)) * 0.3)
    Bm = jax.random.normal(jax.random.fold_in(key, 4), (b, s, g, n)) * 0.5
    Cm = jax.random.normal(jax.random.fold_in(key, 5), (b, s, g, n)) * 0.5
    y = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk)
    exp = ref.ssd_reference(x, dt, A, Bm, Cm)
    scale = float(jnp.abs(exp).max()) + 1e-9
    assert float(jnp.abs(y - exp).max()) / scale < 1e-4


def test_ssd_matches_model_ssd():
    """The model's pure-jnp chunked SSD and the kernel agree too."""
    from repro.models.ssm import ssd_chunked

    b, s, h, p, g, n = 1, 64, 2, 8, 1, 4
    key = jax.random.PRNGKey(9)
    x = jax.random.normal(jax.random.fold_in(key, 1), (b, s, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 2), (b, s, h)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 3), (h,)) * 0.3)
    Bm = jax.random.normal(jax.random.fold_in(key, 4), (b, s, g, n)) * 0.5
    Cm = jax.random.normal(jax.random.fold_in(key, 5), (b, s, g, n)) * 0.5
    y_model, _ = ssd_chunked(x, dt, A, Bm, Cm, 16)
    y_kernel = ssd_scan(x, dt, A, Bm, Cm, chunk=16)
    np.testing.assert_allclose(np.asarray(y_model), np.asarray(y_kernel), rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(
    rows=st.sampled_from([1, 4, 37, 256]),
    d=st.sampled_from([64, 256, 1024]),
    scale_val=st.floats(0.5, 2.0),
)
def test_rmsnorm_property(rows, d, scale_val):
    x = jax.random.normal(jax.random.PRNGKey(rows * d), (rows, d), jnp.float32)
    s = jnp.full((d,), scale_val, jnp.float32)
    out = rmsnorm(x, s)
    exp = ref.rmsnorm_reference(x, s)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=1e-5, atol=1e-5)


def test_rmsnorm_bf16_and_3d():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 128), jnp.bfloat16)
    s = jnp.ones((128,), jnp.float32)
    out = fused_rmsnorm(x, s)
    exp = ref.rmsnorm_reference(x, s)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(exp, np.float32), rtol=2e-2, atol=2e-2
    )
