"""Data pipeline determinism/sharding + optimizer correctness."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import Prefetcher, SyntheticTokens, host_shard_info
from repro.optim import adamw_init, adamw_step, clip_by_global_norm, linear_warmup_cosine


def test_data_deterministic_per_step():
    ds = SyntheticTokens(1000, 16, 8, seed=3)
    a = ds.batch_at(5)
    b = ds.batch_at(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = ds.batch_at(6)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_data_labels_are_shifted_tokens():
    ds = SyntheticTokens(1000, 16, 4)
    b = ds.batch_at(0)
    assert b["tokens"].shape == (4, 16)
    assert b["labels"].shape == (4, 16)


def test_host_sharding_disjoint_and_complete():
    full = SyntheticTokens(1000, 8, 8, seed=1, num_hosts=1, host_id=0).batch_at(2)
    parts = [
        SyntheticTokens(1000, 8, 8, seed=1, num_hosts=4, host_id=h).batch_at(2)
        for h in range(4)
    ]
    for h, p in enumerate(parts):
        assert p["tokens"].shape == (2, 8)
    # shard offsets are disjoint and cover the batch
    offs = [host_shard_info(8, 4, h) for h in range(4)]
    assert sorted(o for _, o in offs) == [0, 2, 4, 6]


def test_prefetcher_yields_in_order():
    ds = SyntheticTokens(100, 4, 2)
    it = iter(ds)
    pf = Prefetcher(it, depth=2)
    seen = [next(pf) for _ in range(3)]
    expect = [ds.batch_at(i) for i in range(3)]
    for s, e in zip(seen, expect):
        np.testing.assert_array_equal(s["tokens"], e["tokens"])
    pf.close()


def test_adamw_matches_manual():
    p = {"w": jnp.asarray([1.0, -2.0])}
    g = {"w": jnp.asarray([0.1, 0.2])}
    st = adamw_init(p)
    lr, b1, b2, eps, wd = 0.01, 0.9, 0.95, 1e-8, 0.1
    newp, newst, m = adamw_step(p, g, st, lr, b1=b1, b2=b2, eps=eps, weight_decay=wd,
                                max_grad_norm=None)
    mm = (1 - b1) * np.asarray(g["w"])
    vv = (1 - b2) * np.asarray(g["w"]) ** 2
    step = (mm / (1 - b1)) / (np.sqrt(vv / (1 - b2)) + eps)
    expect = np.asarray(p["w"]) * (1 - lr * wd) - lr * step
    np.testing.assert_allclose(np.asarray(newp["w"]), expect, rtol=1e-6)
    assert int(newst.count) == 1


def test_grad_clip():
    g = {"w": jnp.asarray([3.0, 4.0])}  # norm 5
    clipped, norm = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(norm), 5.0, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(clipped["w"]), [0.6, 0.8], rtol=1e-5)


def test_schedule_warmup_then_decay():
    lr = linear_warmup_cosine(1e-3, 10, 100)
    assert float(lr(jnp.asarray(0))) == 0.0
    assert float(lr(jnp.asarray(10))) <= 1e-3 + 1e-9
    assert float(lr(jnp.asarray(95))) < float(lr(jnp.asarray(20)))
