"""AutoSwap: candidates, priority scores, selection, schedule validity."""

import numpy as np
import pytest
from repro.testing import given, settings, st  # hypothesis or deterministic fallback

from repro.core.autoswap import AutoSwapPlanner
from repro.core.events import IterationTrace, VariableInfo
from repro.core.simulator import GTX_1080TI, HardwareSpec, simulate_swap_schedule

HW = HardwareSpec("test", peak_flops=1e12, hbm_bw=1e12, link_bw=1e10, efficiency=1.0)


def synth_trace(n_layers=8, act_bytes=8 << 20, weight_bytes=4 << 20):
    """Forward/backward-shaped trace: weights read early+late, activations
    produced in forward and consumed in reverse order in backward."""
    vs = []
    idx = 0
    var = 0
    n_ops = 4 * n_layers + 2
    fwd_w, fwd_a = [], []
    for l in range(n_layers):
        # weight: lives whole iteration, accessed in fwd at 2l and bwd late
        w = VariableInfo(var, weight_bytes, 0, n_ops, [2 * l], [False]); var += 1
        a = VariableInfo(var, act_bytes, 2 * l, 0, [2 * l + 1], [True]); var += 1
        vs.append(w); fwd_w.append(w)
        vs.append(a); fwd_a.append(a)
    peak_idx = 2 * n_layers
    for l in reversed(range(n_layers)):
        bwd_idx = 2 * n_layers + 2 * (n_layers - 1 - l) + 1
        fwd_w[l].accesses.append(bwd_idx)
        fwd_w[l].access_is_write.append(False)
        fwd_a[l].accesses.append(bwd_idx)
        fwd_a[l].access_is_write.append(False)
        fwd_a[l].free_index = bwd_idx + 1
    tr = IterationTrace(vs, n_ops)
    tr.op_costs = {i: (1e9, 1e6) for i in range(n_ops)}  # 1 ms per op
    return tr


def test_candidates_filter_size_and_peak():
    tr = synth_trace()
    pl = AutoSwapPlanner(tr, HW, size_threshold=5 << 20)
    # only activations (8 MiB) pass the 5 MiB threshold; early-layer ones span peak
    assert all(c.size == 8 << 20 for c in pl.candidates if not c.wraps)
    assert len(pl.candidates) > 0


def test_scores_prefer_early_layers():
    """Earlier-layer activations have wider gaps -> higher DOA/AOA."""
    tr = synth_trace()
    pl = AutoSwapPlanner(tr, HW, size_threshold=1 << 20)
    acts = [c for c in pl.candidates if c.size == 8 << 20 and not c.wraps]
    acts_sorted = sorted(acts, key=lambda c: c.out_after)
    doas = [c.scores["doa"] for c in acts_sorted]
    assert doas == sorted(doas, reverse=True)


def test_selection_meets_limit_synchronously():
    tr = synth_trace()
    pl = AutoSwapPlanner(tr, HW, size_threshold=1 << 20)
    limit = int(pl.peak_load * 0.7)
    dec = pl.select(limit, "swdoa")
    assert pl.updated_load(dec).max() <= limit


def test_schedule_validity_invariants():
    tr = synth_trace()
    pl = AutoSwapPlanner(tr, HW, size_threshold=1 << 20)
    limit = int(pl.peak_load * 0.7)
    dec = pl.select(limit, "swdoa")
    r = simulate_swap_schedule(tr, dec, HW, limit)
    times = tr.op_times
    by_var = {d.var: d for d in dec}
    # swap-out starts only after the trigger access's original start time
    for var, start, end in r.out_events:
        d = by_var[var]
        assert end > start
        assert start >= times[d.out_after] - 1e-12
    # out stream is serialized
    outs = sorted(r.out_events, key=lambda e: e[1])
    for k in range(1, len(outs)):
        assert outs[k][1] >= outs[k - 1][2] - 1e-12
    ins = sorted(r.in_events, key=lambda e: e[1])
    for k in range(1, len(ins)):
        assert ins[k][1] >= ins[k - 1][2] - 1e-12
    # every decision got swapped in before iteration end or stalled the access
    assert r.overhead >= 0.0


def test_zero_decisions_zero_overhead():
    tr = synth_trace()
    pl = AutoSwapPlanner(tr, HW)
    r = simulate_swap_schedule(tr, [], HW, None)
    assert r.overhead == 0.0
    assert r.duration_s == pytest.approx(r.baseline_s)


def test_load_min_leq_peak():
    tr = synth_trace()
    pl = AutoSwapPlanner(tr, HW, size_threshold=1 << 20)
    assert pl.load_min() <= pl.peak_load


def test_swdoa_reranks_with_updated_load():
    tr = synth_trace()
    pl = AutoSwapPlanner(tr, HW, size_threshold=1 << 20)
    for c in pl.candidates:
        assert "swdoa" in c.scores


def test_wrap_candidates_for_weights():
    tr = synth_trace()
    pl = AutoSwapPlanner(tr, HW, size_threshold=1 << 20)
    wraps = [c for c in pl.candidates if c.wraps]
    assert wraps, "weights alive across the boundary should yield wrap candidates"
    for c in wraps:
        assert c.in_before <= c.out_after


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 12), st.floats(0.5, 0.95))
def test_property_overhead_nonnegative_and_peak_respected(n_layers, frac):
    tr = synth_trace(n_layers=n_layers)
    pl = AutoSwapPlanner(tr, HW, size_threshold=1 << 20)
    limit = int(pl.peak_load * frac)
    dec = pl.select(limit, "aoa")
    r = simulate_swap_schedule(tr, dec, HW, limit)
    assert r.overhead >= 0.0
    assert r.duration_s >= r.baseline_s - 1e-9
