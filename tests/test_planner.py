"""MemoryPlanner on a real model: pooling report, swap report, offload plan."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.core.planner import MemoryPlanner
from repro.core.offload import OffloadPlan, remat_policy_for
from repro.models import build_model


@pytest.fixture(scope="module")
def planner():
    cfg = get_smoke_config("qwen3-4b").reduced(d_model=128, d_ff=512, vocab_size=2048)
    model = build_model(cfg)
    pshapes = model.init_shapes()
    batch = {
        "tokens": jax.ShapeDtypeStruct((4, 128), jnp.int32),
        "labels": jax.ShapeDtypeStruct((4, 128), jnp.int32),
    }

    def step(params, batch):
        return model.loss(params, batch)[0]

    return MemoryPlanner(step, pshapes, batch, size_threshold=1 << 16)


def test_pool_report(planner):
    rep = planner.report()
    assert rep.num_variables > 50
    assert rep.smartpool_footprint >= rep.peak_load
    assert rep.smartpool_ratio <= rep.cnmem_ratio + 1e-9
    # exact allocator footprint == raw peak load (report's peak is aligned)
    assert rep.exact_footprint <= rep.peak_load


def test_swap_report_limit_respected(planner):
    limit = int(planner.swap.peak_load * 0.85)
    rep = planner.swap_report(limit)
    assert rep.num_selected > 0
    assert rep.selected_bytes > 0
    assert rep.overhead >= 0.0
    assert rep.load_min <= rep.peak_load


def test_offload_plan_names_are_known(planner):
    limit = int(planner.swap.peak_load * 0.7)
    plan = planner.offload_plan(limit)
    from repro.core.offload import KNOWN_NAMES

    assert all(n in KNOWN_NAMES for n in plan.offload_names)


def test_offload_policy_builds_and_applies():
    plan = remat_policy_for(["block_in"])
    pol = plan.policy()
    assert pol is not None

    # a remat'd fn with the policy still differentiates correctly
    from jax.ad_checkpoint import checkpoint_name

    def f(w, x):
        h = checkpoint_name(jnp.tanh(x @ w), "block_in")
        return jnp.sum(h * h)

    w = jax.random.normal(jax.random.PRNGKey(0), (8, 8))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8))
    # The offload policy moves residuals via TransferToMemoryKind, which JAX
    # only permits under jit (the launchers always jit their steps).
    g1 = jax.jit(jax.grad(jax.checkpoint(f, policy=pol)))(w, x)
    g2 = jax.grad(f)(w, x)
    assert jnp.allclose(g1, g2, atol=1e-6)


def test_unknown_offload_name_rejected():
    with pytest.raises(ValueError):
        remat_policy_for(["not_a_name"])
