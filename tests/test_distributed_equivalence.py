"""Numerical equivalence of the §Perf distribution strategies (subprocess,
8 host devices, REAL execution — not just lowering).

The optimized paths must be placement-only transforms: identical loss to the
single-device reference within float tolerance:
  * baseline GSPMD sharding on a (4, 2) mesh,
  * batch-full activation sharding (fsdp_act profile),
  * hand-written shard_map expert-parallel MoE (moe_shardmap profile).
Capacity factor is raised so MoE token dropping (legitimately layout-
dependent: per-rank capacity pools) does not enter the comparison.
"""

import pytest

from distributed_env import run_child_or_skip

CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_smoke_config
from repro.distributed.sharding import use_mesh
from repro.launch.mesh import make_mesh
from repro.launch.steps import batch_specs, param_specs, with_sharding
from repro.models import build_model

cfg = get_smoke_config("ARCH")
if cfg.num_experts:
    cfg = dataclasses.replace(cfg, capacity_factor=16.0)
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
B, S = 8, 32
toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size, jnp.int32)
batch = {"tokens": toks, "labels": toks}

ref = float(model.loss(params, batch)[1]["ce"])  # single device (CE only:
# the EP path computes the load-balance aux loss as 0 by design)

mesh = make_mesh((4, 2), ("data", "model"))
RULES = {
    "baseline": None,
    "fsdp_act": {"batch": ("pod", "data", "model")},
    "moe_shardmap": {"moe_impl": "shard_map"},
}["MODE"]
with use_mesh(mesh, rules=RULES):
    pspecs = param_specs(cfg, jax.eval_shape(lambda: params), mesh)
    bspecs = batch_specs(cfg, jax.eval_shape(lambda: batch), mesh)
    p_sh = jax.device_put(params, jax.tree.map(
        lambda s: jax.sharding.NamedSharding(mesh, s), pspecs))
    b_sh = jax.device_put(batch, jax.tree.map(
        lambda s: jax.sharding.NamedSharding(mesh, s), bspecs))
    dist = float(jax.jit(lambda p, b: model.loss(p, b)[1]["ce"])(p_sh, b_sh))

err = abs(dist - ref) / max(abs(ref), 1e-9)
print(f"ref={ref:.6f} dist={dist:.6f} relerr={err:.2e}")
assert err < 5e-4, (ref, dist)
print("CHILD_OK")
"""


@pytest.mark.parametrize(
    "arch,mode",
    [
        ("qwen3-4b", "baseline"),
        ("qwen3-4b", "fsdp_act"),
        ("deepseek-v2-lite-16b", "baseline"),
        ("deepseek-v2-lite-16b", "moe_shardmap"),
        ("llama4-maverick-400b-a17b", "moe_shardmap"),
        ("mamba2-370m", "baseline"),
    ],
)
def test_distribution_preserves_loss(arch, mode):
    # Environmental child failures (jax API/backend/device count missing in
    # the sandbox) skip with the reason; real code errors still fail.
    run_child_or_skip(CHILD.replace("ARCH", arch).replace("MODE", mode))
