"""Checkpointing: roundtrip, atomicity, keep-k, async, resume."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, restore_pytree, save_pytree


def tree():
    return {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "nested": {"b": jnp.ones((5,), jnp.int32), "c": [jnp.zeros((2, 2))] * 2},
    }


def test_roundtrip(tmp_path):
    t = tree()
    save_pytree(t, str(tmp_path), 7)
    out, step = restore_pytree(jax.tree.map(lambda x: x, t), str(tmp_path))
    assert step == 7
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_keep_k(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 5, 9):
        mgr.save(tree(), s)
    assert mgr.latest_step() == 9
    names = sorted(os.listdir(tmp_path))
    assert names == ["step_00000005", "step_00000009"]


def test_async_save_then_restore(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.async_save(tree(), 3)
    mgr.wait()
    out, step = mgr.restore(tree())
    assert step == 3


def test_partial_write_is_invisible(tmp_path):
    """A .tmp dir from a crashed writer must not be picked up."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(tree(), 1)
    os.makedirs(tmp_path / "step_00000002.tmp")
    assert mgr.latest_step() == 1
    # a step dir without MANIFEST (mid-rename crash) is also skipped
    os.makedirs(tmp_path / "step_00000003")
    assert mgr.latest_step() == 1


def test_restore_missing_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        restore_pytree(tree(), str(tmp_path / "nope"))


def test_template_dtype_cast(tmp_path):
    t = {"w": jnp.ones((4,), jnp.float32)}
    save_pytree(t, str(tmp_path), 0)
    tpl = {"w": jnp.zeros((4,), jnp.bfloat16)}
    out, _ = restore_pytree(tpl, str(tmp_path))
    assert out["w"].dtype == jnp.bfloat16
