"""Loop-aware analytic cost model + HLO collective census."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.costmodel import jaxpr_flops_bytes, loop_aware_collectives, _shape_bytes


def test_matmul_flops_exact():
    M, K, N = 64, 128, 32

    def f(a, b):
        return a @ b

    j = jax.make_jaxpr(f)(
        jax.ShapeDtypeStruct((M, K), jnp.float32), jax.ShapeDtypeStruct((K, N), jnp.float32)
    )
    c = jaxpr_flops_bytes(j)
    assert c["flops"] == 2 * M * K * N


def test_scan_multiplies_flops():
    M = 32

    def body(c, w):
        return jnp.tanh(c @ w), None

    def f(c, ws):
        return jax.lax.scan(body, c, ws)[0]

    c0 = jax.ShapeDtypeStruct((M, M), jnp.float32)
    ws = jax.ShapeDtypeStruct((10, M, M), jnp.float32)
    one = jaxpr_flops_bytes(jax.make_jaxpr(lambda c, w: jnp.tanh(c @ w))(c0, jax.ShapeDtypeStruct((M, M), jnp.float32)))
    ten = jaxpr_flops_bytes(jax.make_jaxpr(f)(c0, ws))
    assert abs(ten["flops"] - 10 * one["flops"]) / (10 * one["flops"]) < 0.05


def test_shape_bytes_parser():
    assert _shape_bytes("bf16[2560,9728]{1,0}") == 2560 * 9728 * 2
    assert _shape_bytes("(f32[16], f32[16])") == 128
    assert _shape_bytes("pred[]") == 1


def test_loop_aware_census_multiplies_body():
    hlo = """
HloModule m

%cond.1 (p: (s32[], f32[16])) -> pred[] {
  %p = (s32[], f32[16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %k = s32[] constant(36)
  ROOT %lt = pred[] compare(%i, %k), direction=LT
}

%body.1 (p: (s32[], f32[16])) -> (s32[], f32[16]) {
  %p = (s32[], f32[16]) parameter(0)
  %x = f32[16] get-tuple-element(%p), index=1
  %ar = f32[16]{0} all-reduce(%x), replica_groups={}
  %i = s32[] get-tuple-element(%p), index=0
  ROOT %t = (s32[], f32[16]) tuple(%i, %ar)
}

ENTRY %main (a: f32[16]) -> f32[16] {
  %a = f32[16] parameter(0)
  %init = (s32[], f32[16]) tuple(s32[] constant(0), %a)
  %w = (s32[], f32[16]) while(%init), condition=%cond.1, body=%body.1
  %g = f32[32]{0} all-gather(%a), dimensions={0}
  ROOT %r = f32[16] get-tuple-element(%w), index=1
}
"""
    out = loop_aware_collectives(hlo)
    assert out["all-reduce"]["count"] == 36
    assert out["all-reduce"]["bytes"] == 36 * 64
    assert out["all-gather"]["count"] == 1
    assert out["all-gather"]["bytes"] == 128
