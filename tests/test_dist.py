"""Tests for the repro.dist subsystem: sharded capture, per-device plans,
mesh-wide execution with shared host-link contention.

Everything except the shard_map child test runs on abstract values (no
multi-device runtime needed); the child test reuses the
``tests/distributed_env.py`` skip classification so sandboxes without
multi-device jax skip with a reason instead of failing.
"""

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from distributed_env import run_child_or_skip
from repro.core.simulator import GTX_1080TI, assign_times
from repro.core.trace import trace_step_fn
from repro.dist import (
    MeshSpec,
    capture_sharded_trace,
    collective_seconds,
    run_mesh,
    schedules_differ,
    shard_divisor,
    shard_existing_trace,
    solve_sharded,
)
from repro.dist.program import group_key
from repro.plan import PlanCache, PlanKey, dumps_canonical
from repro.plan.passes import (
    PassContext,
    Pipeline,
    PoolPlacement,
    SwapSelection,
    TimingAssign,
    TraceCapture,
)
from repro.runtime import HostLink

HW = GTX_1080TI


def small_step():
    def step(w, x):
        g = jax.grad(lambda w: ((jax.nn.relu(x @ w)) ** 2).sum())(w)
        return w - 0.01 * g

    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    return step, (w, x)


# ------------------------------------------------------------ mesh + divisors
def test_mesh_spec_parse_and_signature():
    m = MeshSpec.parse("data=4,model=2")
    assert m.num_devices == 8
    assert m.signature() == "data4xmodel2"
    assert MeshSpec.make(data=1).signature() == ""
    with pytest.raises(ValueError):
        MeshSpec.parse("nonsense")


def test_shard_divisor_divisibility_guard():
    m = MeshSpec.make(data=4, model=2)
    assert shard_divisor((32, 64), P("data", None), m) == 4
    assert shard_divisor((32, 64), P("data", "model"), m) == 8
    # 30 % 4 != 0: that dim degrades to replicated, the other still divides.
    assert shard_divisor((30, 64), P("data", "model"), m) == 2
    assert shard_divisor((32, 64), P(None, None), m) == 1


# --------------------------------------------------- 1x1 equivalence (pinned)
def test_1x1_capture_events_byte_identical_to_single_device():
    """On a 1x1 mesh repro.dist capture must reproduce trace_step_fn exactly:
    same variables, sizes, lifetimes, accesses, names, op costs."""
    step, args = small_step()
    ref = trace_step_fn(step, *args, arg_names=["w", "x"])
    cap = capture_sharded_trace(
        step, *args, mesh=MeshSpec.make(data=1), hw=HW,
        in_specs=(P(None, None), P("data", None)), arg_names=["w", "x"],
    )
    got = cap.groups["spmd"].trace
    assert got.num_indices == ref.num_indices
    assert len(got.variables) == len(ref.variables)
    for a, b in zip(ref.variables, got.variables):
        assert (a.var, a.size, a.alloc_index, a.free_index, a.accesses,
                a.access_is_write, a.name) == (
            b.var, b.size, b.alloc_index, b.free_index, b.accesses,
            b.access_is_write, b.name)
    assert got.op_costs == ref.op_costs
    assert not cap.groups["spmd"].collectives
    assert cap.plan_topology() == ""


def test_1x1_solved_plan_byte_identical_to_pipeline():
    step, args = small_step()
    key = PlanKey("toy", "train:t", HW.name)
    cap = capture_sharded_trace(step, *args, mesh=MeshSpec.make(data=1),
                                hw=HW, arg_names=["w", "x"])
    limit = int(cap.groups["spmd"].trace.peak_load() * 0.7)
    solved = solve_sharded(cap, HW, base_key=key, limit=limit, size_threshold=1)
    ctx = PassContext(hw=HW, key=key, size_threshold=1)
    single = Pipeline([
        TraceCapture(step_fn=step, example_args=args, arg_names=["w", "x"]),
        TimingAssign(),
        PoolPlacement(),
        SwapSelection(limit=limit),
    ]).run(None, ctx)
    assert dumps_canonical(solved.programs["spmd"]) == dumps_canonical(single)


# --------------------------------------------------------- sharded semantics
def test_sharded_capture_divides_batch_sharded_sizes():
    step, args = small_step()
    m = MeshSpec.make(data=4)
    cap = capture_sharded_trace(
        step, *args, mesh=m, hw=HW,
        in_specs=(P(None, None), P("data", None)), arg_names=["w", "x"],
    )
    by_name = {}
    for v in cap.groups["spmd"].trace.variables:
        by_name.setdefault(v.name, v)
    # x is batch-sharded 4 ways; w replicated.
    assert by_name["x"].size == 32 * 64 * 4 // 4
    assert by_name["w"].size == 64 * 64 * 4
    # Per-device peak strictly below the replicated peak.
    ref = trace_step_fn(step, *args, arg_names=["w", "x"])
    assert cap.groups["spmd"].trace.peak_load() < ref.peak_load()


def test_collective_tagging_from_jaxpr_psum():
    """Explicit lax.psum eqns in the jaxpr are tagged with durations and
    folded into the timing model via op_extra_s."""

    def traced(x):
        return jax.lax.psum((x * 2.0).sum(axis=1), "data")

    x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    m = MeshSpec.make(data=4)
    try:
        cap = capture_sharded_trace(
            traced, x, mesh=m, hw=HW,
            in_specs=(P("data", None),), arg_names=["x"],
        )
    except Exception:
        pytest.skip("jaxpr tracing of unbound psum unsupported in this jax")
    group = cap.groups["spmd"]
    assert group.collectives, "psum eqn not tagged"
    c = group.collectives[0]
    assert c.kind == "all_reduce" and c.seconds > 0.0
    trace = group.trace
    assert trace.op_extra_s and trace.op_extra_s.get(c.index) == pytest.approx(
        c.seconds
    )


def test_collective_tagging_via_patched_primitive(monkeypatch):
    """The eqn-tagging path itself, independent of jax's axis-env rules:
    treat an ordinary primitive as a collective and check it is tagged,
    sized from its per-shard inputs, and folded into op_times."""
    from repro.dist import capture as capmod

    monkeypatch.setitem(capmod.COLLECTIVE_PRIMS, "sin", "all_reduce")

    def step(x):
        return jnp.sin(x).sum()

    x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    cap = capture_sharded_trace(
        step, x, mesh=MeshSpec.make(data=4), hw=HW,
        in_specs=(P("data", None),), arg_names=["x"],
    )
    group = cap.groups["spmd"]
    sins = [c for c in group.collectives if c.kind == "all_reduce"]
    assert len(sins) == 1
    c = sins[0]
    assert c.nbytes == 32 * 64 * 4 // 4  # per-shard input bytes
    assert c.seconds == pytest.approx(
        collective_seconds("all_reduce", c.nbytes, 4, HW)
    )
    trace = group.trace
    assert trace.op_extra_s.get(c.index) == pytest.approx(c.seconds)
    # assign_times folds the collective into op_times.
    assign_times(trace, HW)
    with_extra = trace.op_times[-1]
    trace.op_extra_s = None
    trace.op_times = None
    assign_times(trace, HW)
    assert with_extra == pytest.approx(trace.op_times[-1] + c.seconds)


def test_scan_xs_slices_keep_their_own_sharding():
    """Replicated stacked weights scanned over layers must NOT inherit the
    batch-sharded carry's divisor: per-trip weight slices stay full-size."""

    def step(ws, x):
        def body(h, w):
            return jax.nn.relu(h @ w), ()

        h, _ = jax.lax.scan(body, x, ws)
        return h.sum()

    ws = jax.ShapeDtypeStruct((3, 64, 64), jnp.float32)  # stacked, replicated
    x = jax.ShapeDtypeStruct((32, 64), jnp.float32)      # batch-sharded
    cap = capture_sharded_trace(
        step, ws, x, mesh=MeshSpec.make(data=4), hw=HW,
        in_specs=(P(None, None, None), P("data", None)), arg_names=["ws", "x"],
    )
    slices = [v for v in cap.groups["spmd"].trace.variables
              if v.name.startswith("scan_x[")]
    assert slices, "no xs slices captured"
    assert all(v.size == 64 * 64 * 4 for v in slices)  # full layer, undivided


def test_capture_unroll_matches_plan_pipeline_default():
    """1x1 captures share the single-device PlanKey, so the tracer settings
    must agree with plan.passes.TraceCapture or the same cache name would
    hold two different traces."""
    from repro.dist.capture import _CAPTURE_MAX_SCAN_UNROLL

    assert TraceCapture().max_scan_unroll == _CAPTURE_MAX_SCAN_UNROLL


def test_gradient_sync_scoped_to_data_axes():
    """The gradient all-reduce prices only its participating data axis, not
    the whole mesh."""
    from repro.dist import gradient_sync_collective

    shapes = {"w": jax.ShapeDtypeStruct((64, 64), jnp.float32)}
    specs = {"w": P(None, None)}
    entry = gradient_sync_collective(shapes, specs, MeshSpec.make(data=4, model=2))
    assert entry == ("all_reduce", 64 * 64 * 4, None, 4)
    assert gradient_sync_collective(shapes, specs, MeshSpec.make(model=2)) is None


def test_mesh_blackout_registered_once_per_logical_collective():
    """N SPMD tenants execute the same mesh-wide collective: the link is
    blacked out once per iteration, not once per device."""
    solved = _solved_toy()
    group = solved.capture.groups["spmd"]
    per_iter = sum(c.seconds for c in group.collectives)
    peak = group.trace.peak_load()
    res = run_mesh(solved, HW, budget_per_device=peak, iterations=2)
    assert res.report.link["blackout_s"] == pytest.approx(2 * per_iter)


def test_collective_seconds_cost_model():
    assert collective_seconds("all_reduce", 1 << 20, 1, HW) == 0.0
    ar = collective_seconds("all_reduce", 1 << 20, 4, HW)
    ag = collective_seconds("all_gather", 1 << 20, 4, HW)
    assert ar > ag > 0.0  # all-reduce moves twice the gather volume


def test_synthesized_collectives_positions():
    step, args = small_step()
    m = MeshSpec.make(data=4)
    cap = capture_sharded_trace(
        step, *args, mesh=m, hw=HW, arg_names=["w", "x"],
        extra_collectives=[("all_reduce", 1 << 20),
                           ("all_gather", 1 << 20, 0.5)],
    )
    group = cap.groups["spmd"]
    tail = group.trace.num_indices - 1
    kinds = {c.kind: c for c in group.collectives}
    assert kinds["all_reduce"].index == tail
    assert 0 < kinds["all_gather"].index < tail


# ------------------------------------------------------- plan keys + caching
def test_plan_key_topology_distinguishes_meshes(tmp_path):
    """A plan solved on a 1-device trace is never served to a sharded step
    (and different meshes never alias) in one PlanCache."""
    step, args = small_step()
    key = PlanKey("toy", "train:t", HW.name)
    cache = PlanCache(tmp_path)
    names = set()
    for axes in ({"data": 1}, {"data": 2}, {"data": 4}):
        cap = capture_sharded_trace(
            step, *args, mesh=MeshSpec.make(**axes), hw=HW,
            in_specs=(P(None, None), P("data", None)), arg_names=["w", "x"],
        )
        solved = solve_sharded(cap, HW, base_key=key, cache=cache)
        names.add(solved.programs["spmd"].key.cache_name())
    assert len(names) == 3
    assert len(cache.keys()) == 3
    # Legacy single-device keys are unchanged by the topology field.
    assert PlanKey("a", "s", "h").cache_name() == PlanKey("a", "s", "h", "").cache_name()
    assert PlanKey("a", "s", "h", "data4").cache_name() != PlanKey("a", "s", "h").cache_name()


def test_partition_spec_signature_in_topology():
    """Same mesh, different input PartitionSpecs -> different plan keys."""
    step, args = small_step()
    m = MeshSpec.make(data=4)
    key = PlanKey("toy", "train:t", HW.name)
    caps = [
        capture_sharded_trace(step, *args, mesh=m, hw=HW,
                              in_specs=specs, arg_names=["w", "x"])
        for specs in [(P(None, None), P("data", None)),
                      (P("data", None), P("data", None))]
    ]
    keys = {group_key(key, c, "spmd").cache_name() for c in caps}
    assert len(keys) == 2


def test_sharded_solve_cache_roundtrip(tmp_path):
    step, args = small_step()
    key = PlanKey("toy", "train:t", HW.name)
    cache = PlanCache(tmp_path)
    m = MeshSpec.make(data=4)

    def capture():
        return capture_sharded_trace(
            step, *args, mesh=m, hw=HW,
            in_specs=(P(None, None), P("data", None)), arg_names=["w", "x"],
        )

    cap = capture()
    limit = int(cap.groups["spmd"].trace.peak_load() * 0.7)
    first = solve_sharded(cap, HW, base_key=key, cache=cache,
                          limit=limit, size_threshold=1)
    assert not first.cache_hits["spmd"]
    second = solve_sharded(capture(), HW, base_key=key, cache=cache,
                           limit=limit, size_threshold=1)
    assert second.cache_hits["spmd"]
    assert dumps_canonical(first.programs["spmd"]) == dumps_canonical(
        second.programs["spmd"]
    )


# ------------------------------------------------------------ mesh execution
def _solved_toy(shards: int = 4, with_collectives: bool = True):
    step, args = small_step()
    extra = []
    if with_collectives:
        extra = [("all_reduce", 64 * 64 * 4), ("all_gather", 64 * 64 * 2, 0.4)]
    cap = capture_sharded_trace(
        step, *args, mesh=MeshSpec.make(data=shards), hw=HW,
        in_specs=(P(None, None), P("data", None)), arg_names=["w", "x"],
        extra_collectives=extra,
    )
    return solve_sharded(cap, HW, limit_frac=0.6, size_threshold=1)


def test_run_mesh_per_device_pools_and_fanout():
    solved = _solved_toy()
    peak = solved.capture.groups["spmd"].trace.peak_load()
    res = run_mesh(solved, HW, budget_per_device=peak, iterations=2)
    rep = res.report
    assert len(rep.tenants) == 4
    assert all(t.status == "completed" for t in rep.tenants)
    assert rep.device_peaks is not None and len(rep.device_peaks) == 4
    # SPMD: every device pool sees the identical peak.
    assert len(set(rep.device_peaks.values())) == 1
    # aggregate = sum over per-device pools.
    assert rep.aggregate_peak == sum(rep.device_peaks.values())
    assert rep.overflow_events == 0


def test_shared_link_contention_changes_schedules_and_never_free():
    solved = _solved_toy()
    peak = solved.capture.groups["spmd"].trace.peak_load()
    kw = dict(budget_per_device=peak, iterations=2)
    free = run_mesh(solved, HW, contended=False, **kw)
    shared = run_mesh(solved, HW, contended=True, link_lanes=2, **kw)
    assert schedules_differ(free, shared)
    assert shared.report.link is not None
    assert shared.report.link["transfers"] > 0
    # Contention can only slow tenants down.
    assert shared.makespan_s >= free.makespan_s - 1e-12


def test_contention_aware_not_worse_than_blind():
    solved = _solved_toy()
    peak = solved.capture.groups["spmd"].trace.peak_load()
    kw = dict(budget_per_device=peak, iterations=3, link_lanes=2)
    aware = run_mesh(solved, HW, contended=True, contention_aware=True, **kw)
    blind = run_mesh(solved, HW, contended=True, contention_aware=False, **kw)
    assert aware.mean_overhead() <= blind.mean_overhead() + 1e-9


def test_collective_blackout_blocks_link():
    """A collective blacks the shared link out: transfers scheduled into the
    blackout are shifted past its end."""
    link = HostLink.make(total_bw=1e9, lanes=1)
    link.add_blackout(1.0, 2.0)
    assert link.next_clear(0.0, 0.5) == 0.0       # fits before
    assert link.next_clear(0.9, 0.5) == 2.0       # overlaps -> after
    assert link.next_clear(1.5, 0.1) == 2.0       # inside -> after
    link.add_blackout(2.0, 2.5)
    assert link.next_clear(1.5, 0.1) == 2.5       # chained blackouts


def test_single_device_runtime_unaffected_by_link_default():
    """Without a HostLink the engine is bit-for-bit the legacy runtime even
    for tenants carrying collective tags (clock advances, no blackouts)."""
    from repro.core._solver_reference import reference_simulate_swap_schedule
    from repro.core.autoswap import AutoSwapPlanner
    from repro.runtime import simulate_program, synthetic_train_trace

    tr = synthetic_train_trace(8)
    pl = AutoSwapPlanner(tr, HW, size_threshold=1 << 20)
    limit = int(pl.peak_load * 0.7)
    dec = pl.select(limit, "swdoa")
    ref = reference_simulate_swap_schedule(tr, dec, HW, limit)
    got = simulate_program(tr, dec, HW, limit, channels=2, prefetch="eager")
    for f in ("baseline_s", "duration_s", "peak_resident", "stalls",
              "delayed_mallocs", "tail_spill_s", "out_events", "in_events"):
        assert getattr(got, f) == getattr(ref, f)


def test_shard_existing_trace_rule_route():
    from repro.runtime import synthetic_train_trace

    tr = synthetic_train_trace(6)
    m = MeshSpec.make(data=4)
    cap = shard_existing_trace(
        tr, m, HW,
        divisor_fn=lambda name, size: 4 if name.startswith("act") else 1,
        extra_collectives=[("all_reduce", 1 << 20)],
    )
    got = cap.groups["spmd"].trace
    by_var = {v.var: v for v in tr.variables}
    for v in got.variables:
        orig = by_var[v.var]
        if orig.name.startswith("act") and orig.size % 4 == 0:
            assert v.size == orig.size // 4
        else:
            assert v.size == orig.size
    assert cap.groups["spmd"].collectives


# ------------------------------------------------- multi-device child (skip)
CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp
from functools import partial
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.launch.mesh import make_mesh
from repro.dist import MeshSpec, capture_sharded_trace

mesh = make_mesh((4,), ("data",))

@partial(shard_map, mesh=mesh, in_specs=(P(None, None), P("data", None)),
         out_specs=P(None, None), check_rep=False)
def step(w, x):
    h = jax.nn.relu(x @ w)
    g = jax.lax.psum(h.T @ h, "data")
    return g

w = jnp.zeros((64, 64), jnp.float32)
x = jnp.zeros((32, 64), jnp.float32)
# The partitioned jaxpr: per-shard block shapes inside, psum tagged.
cap = capture_sharded_trace(
    step, w, x, mesh=MeshSpec.from_mesh(mesh), hw=None or __import__(
        "repro.core.simulator", fromlist=["GTX_1080TI"]).GTX_1080TI,
    arg_names=["w", "x"],
)
group = cap.groups["spmd"]
assert group.trace.num_indices > 0
assert any(c.kind == "all_reduce" for c in group.collectives), group.collectives
print("CHILD_OK")
"""


def test_shard_map_partitioned_jaxpr_capture():
    """Walking the jaxpr of a real shard_map step (child process with forced
    host devices) tags its psum; skips where the sandbox can't force
    multi-device XLA — classified by tests/distributed_env.py."""
    run_child_or_skip(CHILD)
