"""SmartPool offline-DSA: validity, bounds, baselines, property tests."""

import numpy as np
import pytest
from repro.testing import given, settings, st  # hypothesis or deterministic fallback

from repro.core.events import Event, EventKind, IterationTrace, VariableInfo, build_trace
from repro.core.baseline_pools import CnMemPool, exact_allocator
from repro.core.smartpool import brute_force_optimal, solve


def make_trace(intervals):
    """intervals: list of (size, alloc, free)."""
    vs = [
        VariableInfo(i, s, a, f, accesses=[a], access_is_write=[True])
        for i, (s, a, f) in enumerate(intervals)
    ]
    end = max(f for _, _, f in intervals)
    return IterationTrace(vs, end)


def assert_valid(trace, plan, alignment=256):
    vs = [v for v in trace.variables if v.size > 0]
    align = lambda x: (x + alignment - 1) // alignment * alignment
    for i in range(len(vs)):
        for j in range(i + 1, len(vs)):
            a, b = vs[i], vs[j]
            if a.overlaps(b):
                a0, a1 = plan.offsets[a.var], plan.offsets[a.var] + align(a.size)
                b0, b1 = plan.offsets[b.var], plan.offsets[b.var] + align(b.size)
                assert a1 <= b0 or b1 <= a0, (a.var, b.var)


def test_disjoint_lifetimes_share_memory():
    tr = make_trace([(1000, 0, 5), (1000, 5, 10), (1000, 10, 15)])
    plan = solve(tr)
    assert plan.footprint == 1024  # all three share one aligned slot
    assert plan.competitive_ratio == 1.0


def test_overlapping_lifetimes_stack():
    tr = make_trace([(1000, 0, 10), (1000, 0, 10), (1000, 0, 10)])
    plan = solve(tr)
    assert plan.footprint == 3 * 1024
    assert_valid(tr, plan)


def test_many_to_one_sharing():
    """A big dead variable's space hosts several small ones (paper §III-C)."""
    tr = make_trace([(10_000, 0, 5)] + [(2_000, 5, 10)] * 4)
    plan = solve(tr)
    assert plan.footprint == 10240  # four 2 KiB vars fit inside the big slot
    assert_valid(tr, plan)


def test_best_fit_vs_first_fit_validity():
    tr = make_trace([(5000, 0, 4), (3000, 2, 8), (1000, 5, 9), (4000, 4, 9), (2500, 1, 3)])
    for method in ("best_fit", "first_fit"):
        plan = solve(tr, method)
        assert_valid(tr, plan)
        assert plan.footprint >= plan.peak_load


def test_footprint_between_peak_and_sum():
    rng = np.random.default_rng(0)
    intervals = [
        (int(rng.integers(100, 10_000)), int(a := rng.integers(0, 50)), int(a + rng.integers(1, 40)))
        for _ in range(60)
    ]
    tr = make_trace(intervals)
    plan = solve(tr)
    assert_valid(tr, plan)
    assert plan.peak_load <= plan.footprint <= sum(((s + 255) // 256) * 256 for s, _, _ in intervals)


def test_matches_brute_force_on_tiny():
    tr = make_trace([(3, 0, 4), (2, 2, 6), (4, 3, 7), (1, 5, 9), (2, 0, 9)])
    plan = solve(tr, alignment=1)
    best = brute_force_optimal(tr, alignment=1)
    assert plan.footprint <= 1.5 * best  # WIC guarantee band for tiny cases


def test_beats_or_ties_cnmem_on_varied_sizes():
    rng = np.random.default_rng(1)
    intervals = []
    t = 0
    for _ in range(100):
        t += int(rng.integers(0, 3))
        intervals.append((int(rng.integers(64, 65536)), t, t + int(rng.integers(1, 60))))
    tr = make_trace(intervals)
    sp = solve(tr)
    cn = CnMemPool().run(tr)
    assert sp.footprint <= cn.footprint * 1.001


def test_exact_allocator_is_peak():
    tr = make_trace([(1000, 0, 5), (2000, 3, 8)])
    st_ = exact_allocator(tr)
    assert st_.footprint == tr.peak_load()
    assert st_.competitive_ratio == 1.0


@settings(max_examples=60, deadline=None)
@given(
    st.lists(
        st.tuples(
            st.integers(1, 100_000),   # size
            st.integers(0, 40),        # alloc
            st.integers(1, 40),        # duration
        ),
        min_size=1,
        max_size=40,
    )
)
def test_property_always_valid_and_bounded(items):
    intervals = [(s, a, a + d) for s, a, d in items]
    tr = make_trace(intervals)
    for method in ("best_fit", "first_fit"):
        plan = solve(tr, method)
        assert_valid(tr, plan)
        assert plan.footprint >= plan.peak_load
        # WIC-style sanity bound: never worse than stacking everything.
        assert plan.footprint <= sum(((s + 255) // 256) * 256 for s, _, _ in intervals)


def test_lookup_table_maps_alloc_index_to_offset():
    tr = make_trace([(1000, 0, 5), (2000, 5, 9)])
    plan = solve(tr)
    for v in tr.variables:
        assert plan.lookup[v.alloc_index] == plan.offsets[v.var]
