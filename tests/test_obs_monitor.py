"""repro.obs.monitor: streaming telemetry, SLOs, diffing (Issue 10).

Invariants pinned here:

  1. The quantile sketch answers within its *self-reported* rank-error
     bound on adversarial streams (sorted, reversed, constant,
     heavy-tailed, random) — property-tested via the repro.testing shim —
     and its state is a pure function of the input stream (deterministic
     compaction and merge).
  2. Window machinery handles the boundary cases: empty windows emit
     nothing, a single sample closes correctly, a sample exactly on a
     tumble boundary opens the next window; sliding sums match a brute
     force over the trailing width.
  3. The monitor is a pure observer: with ``MonitoredRecorder`` armed the
     simulated report stays bit-identical to the frozen
     ``runtime/_engine_reference.py`` across the churn, renegotiation and
     contended-mesh shapes, and alert emission is deterministic.
  4. Exported traces carry the alerts track (pid 5) only for monitored
     runs, pass the extended ``tools/check_trace.py`` validation, and
     every alert names a registered SLO.
  5. ``repro.obs.diffing`` classifies all artifact shapes and ranks the
     regression tables with correct signs.
"""

from __future__ import annotations

import importlib.util
import json
import random
from bisect import bisect_left, bisect_right
from pathlib import Path

import pytest

from repro.core.planner import AutoSwapPlanner
from repro.core.simulator import GTX_1080TI
from repro.obs import (
    Alert,
    ExactDistribution,
    HysteresisBand,
    MonitoredRecorder,
    ObsRecorder,
    QuantileSketch,
    SLOMonitor,
    SlidingWindow,
    TumblingWindow,
    chrome_trace,
    diff_runs,
    load_run,
    parse_slo,
    priority_class,
)
from repro.obs.diffing import view_from_payload
from repro.runtime import _engine_reference as ref
from repro.runtime import engine as fast
from repro.runtime.engine import planned_peak, simulated_report_dict
from repro.runtime.workload import poisson_workload, synthetic_train_trace
from repro.testing import given, settings, st  # hypothesis or deterministic fallback

HW = GTX_1080TI
SIZE_THRESHOLD = 1 << 20


def solve(trace, frac=0.7, scorer="swdoa"):
    pl = AutoSwapPlanner(trace, HW, size_threshold=SIZE_THRESHOLD)
    limit = int(pl.peak_load * frac)
    return limit, pl.select(limit, scorer)


TEMPLATES = {
    "small": synthetic_train_trace(4),
    "medium": synthetic_train_trace(6),
    "base": synthetic_train_trace(10),
}
PLANS = {name: solve(tr) for name, tr in TEMPLATES.items()}
FLOORS = {n: planned_peak(TEMPLATES[n], PLANS[n][1]) for n in TEMPLATES}
BUDGET = FLOORS["base"] + (FLOORS["small"] + FLOORS["medium"]) // 2

MONITOR_SLOS = (
    "queue_wait.p99<0.001,short=0.02,long=0.08,min=2,name=tight",
    "queue_wait.p99<100,name=guard",
    "link.out_in_wait_ratio>2,low=1.2,window=0.05,name=asym",
)


def canon(report) -> str:
    return json.dumps(simulated_report_dict(report), sort_keys=True)


def churn_tenants(mod, items, base_iters=6):
    ts = [
        mod.Tenant(
            "base", TEMPLATES["base"], list(PLANS["base"][1]),
            limit=PLANS["base"][0], iterations=base_iters, priority=0.5,
        )
    ]
    for it in items:
        limit, decisions = PLANS[it.template]
        ts.append(
            mod.Tenant(
                it.name, TEMPLATES[it.template], list(decisions), limit=limit,
                iterations=it.iterations, arrival_t=it.arrival_t,
                priority=it.priority,
            )
        )
    return ts


def mesh_tenants(mod, devices=4):
    ts = []
    for i in range(devices):
        name = "small" if i % 2 else "medium"
        trace = TEMPLATES[name]
        limit, decisions = PLANS[name]
        colls = {2: 0.004, trace.num_indices - 2: 0.006}
        ts.append(
            mod.Tenant(
                f"shard{i}", trace, list(decisions), limit=limit,
                iterations=3, device=f"d{i}", collectives=colls,
                collective_owner=(i == 0),
            )
        )
    return ts


def churn_run(mod, obs=None, renegotiate=True):
    items = poisson_workload(
        ["small", "medium"], 6, 50.0, seed=11, iterations=(1, 3),
        priorities=(0.5, 1.0, 2.0),
    )
    kw = {"obs": obs} if obs is not None else {}
    rt = mod.MemoryRuntime(
        HW, budget=BUDGET, channels=2, renegotiate=renegotiate,
        replan_size_threshold=SIZE_THRESHOLD, **kw,
    )
    return rt.run(churn_tenants(mod, items))


def mesh_run(mod, obs=None):
    kw = {"obs": obs} if obs is not None else {}
    rt = mod.MemoryRuntime(
        HW, channels=2, link=mod.HostLink.make(HW.link_bw, 2), **kw,
    )
    return rt.run(mesh_tenants(mod, 4))


def _load_tool(name):
    path = Path(__file__).resolve().parents[1] / "tools" / f"{name}.py"
    spec = importlib.util.spec_from_file_location(name, path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


# ------------------------------------------------------------------ sketch
def assert_within_bound(values, sketch, quantiles=(0.01, 0.5, 0.95, 0.99)):
    ordered = sorted(values)
    bound = sketch.rank_error_bound()
    for q in quantiles:
        got = sketch.quantile(q)
        target = round(q * (len(ordered) - 1))
        lo = bisect_left(ordered, got)
        hi = bisect_right(ordered, got) - 1
        err = 0 if lo <= target <= hi else min(abs(target - lo), abs(target - hi))
        assert err <= bound + 1, (
            f"q={q}: value {got} at rank distance {err} > bound {bound}")


@pytest.mark.parametrize("shape", ["sorted", "reversed", "constant", "heavy", "random"])
def test_sketch_within_reported_bound_adversarial(shape):
    rng = random.Random(7)
    n = 6000
    if shape == "constant":
        values = [2.5] * n
    elif shape == "heavy":
        values = [rng.paretovariate(1.1) for _ in range(n)]
    else:
        values = [rng.random() for _ in range(n)]
        if shape == "sorted":
            values.sort()
        elif shape == "reversed":
            values.sort(reverse=True)
    sk = QuantileSketch(64)
    sk.extend(values)
    assert sk.count == n
    assert sk.rank_error_bound() > 0  # n >> buffer: it really compacted
    assert_within_bound(values, sk)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.floats(min_value=-1e6, max_value=1e6), min_size=1, max_size=400),
       st.integers(min_value=2, max_value=32))
def test_sketch_property_bound_and_determinism(values, buffer_size):
    a = QuantileSketch(buffer_size)
    b = QuantileSketch(buffer_size)
    a.extend(values)
    b.extend(values)
    # Pure function of the stream: identical state, identical answers.
    assert a.levels == b.levels and a.compactions == b.compactions
    assert a.quantile(0.5) == b.quantile(0.5)
    assert a.min == min(values) and a.max == max(values)
    assert_within_bound(values, a, quantiles=(0.0, 0.25, 0.5, 0.9, 1.0))


def test_sketch_exact_mode_is_exact():
    rng = random.Random(3)
    # n = 501 keeps q*(n-1) integral for the probed quantiles, so the
    # sketch's ceiling-rank and ExactDistribution's round-rank coincide.
    values = [rng.gauss(0, 1) for _ in range(501)]
    sk = QuantileSketch(16, exact=True)
    ex = ExactDistribution()
    sk.extend(values)
    ex.extend(values)
    assert sk.rank_error_bound() == 0
    for q in (0.0, 0.1, 0.5, 0.9, 0.99, 1.0):
        assert sk.quantile(q) == ex.quantile(q)


def test_sketch_merge_deterministic_and_bounded():
    rng = random.Random(5)
    xs = [rng.random() for _ in range(1500)]
    parts = [xs[0:500], xs[500:1000], xs[1000:1500]]

    def merged():
        out = QuantileSketch(32)
        for part in parts:  # fixed, documented order
            piece = QuantileSketch(32)
            piece.extend(part)
            out.merge(piece)
        return out

    m1, m2 = merged(), merged()
    assert m1.levels == m2.levels
    assert m1.count == len(xs)
    assert_within_bound(xs, m1)


def test_sketch_empty_and_single():
    sk = QuantileSketch(8)
    with pytest.raises(ValueError):
        sk.quantile(0.5)
    sk.add(42.0)
    assert sk.quantile(0.0) == sk.quantile(0.5) == sk.quantile(1.0) == 42.0
    assert sk.rank_error_bound() == 0


# ----------------------------------------------------------------- windows
def test_tumbling_window_boundaries():
    w = TumblingWindow(1.0)
    assert w.flush() == []          # empty: nothing ever emitted
    w.observe(0.5, 10.0)            # single sample
    assert w.flush() == [(0.0, 1, 10.0, 10.0, 10.0)]

    w = TumblingWindow(1.0)
    w.observe(0.25, 1.0)
    w.observe(1.0, 2.0)             # exactly on the boundary: next window
    w.observe(1.75, 3.0)
    w.observe(5.5, 4.0)             # windows 2..4 are empty: no entries
    closed = w.flush()
    assert closed == [
        (0.0, 1, 1.0, 1.0, 1.0),
        (1.0, 2, 5.0, 2.0, 3.0),
        (5.0, 1, 4.0, 4.0, 4.0),
    ]


def test_sliding_window_matches_brute_force():
    rng = random.Random(9)
    events = []
    t = 0.0
    for _ in range(300):
        t += rng.expovariate(40.0)
        events.append((t, rng.random()))
    win = SlidingWindow(0.5, resolution=10)
    for i, (ti, vi) in enumerate(events):
        win.add(ti, vi)
        got = win.total()
        # Bucket-quantized trailing edge: covers [t - width - bucket, t].
        exact_lo = sum(v for tt, v in events[:i + 1] if tt > ti - 0.5)
        exact_hi = sum(v for tt, v in events[:i + 1] if tt > ti - 0.5 - 0.05 - 1e-12)
        assert exact_lo - 1e-9 <= got <= exact_hi + 1e-9


def test_hysteresis_band_dead_band():
    band = HysteresisBand(1.5, 3.0)
    assert band.update(2.9) is None        # below hi: nothing
    assert band.update(3.0) == "enter"
    assert band.update(2.0) is None        # inside the dead band: holds
    assert band.update(3.5) is None        # already engaged
    assert band.update(1.5) == "exit"
    assert band.update(1.0) is None        # already out


# --------------------------------------------------------------- SLO specs
def test_parse_slo_quantile_and_options():
    s = parse_slo("queue_wait.p99<0.005,prio=1.0,short=0.01,long=0.04,burn=2,min=5")
    assert (s.stream, s.quantile, s.threshold) == ("queue_wait", 0.99, 0.005)
    assert s.cls == "prio1" and s.short_s == 0.01 and s.long_s == 0.04
    assert s.burn == 2.0 and s.min_count == 5
    s = parse_slo("stall.p95<0.01,cause=swap_in_wait")
    assert s.stream == "stall" and s.cause == "swap_in_wait"
    s = parse_slo("link.out_in_wait_ratio>3,low=1.5,window=0.02")
    assert s.stream == "asymmetry" and s.threshold == 3.0 and s.low == 1.5


def test_parse_slo_rejects_malformed():
    for bad in ("queue_wait.p99", "nope.p99<1", "queue_wait.p0<1",
                "queue_wait.p99<0.005,bogus", "link.asym>2"):
        with pytest.raises(ValueError):
            parse_slo(bad)
    with pytest.raises(ValueError):
        SLOMonitor(["queue_wait.p99<1", "queue_wait.p99<2"])  # duplicate name


def test_burn_rate_fires_and_rearms_deterministically():
    def run():
        mon = SLOMonitor(["queue_wait.p99<0.001,short=0.01,long=0.05,min=4,name=s"])
        t = 0.0
        for i in range(120):
            t += 0.0005
            # One violation burst mid-stream, clean elsewhere.
            wait = 0.01 if 20 <= i < 40 else 0.0
            mon.observe_queue_wait(t, "prio1", wait)
        return mon.alerts

    a1, a2 = run(), run()
    assert a1 == a2                      # deterministic emission
    assert len(a1) == 1                  # fires once, hysteresis holds it
    assert a1[0].kind == "burn_rate" and a1[0].slo == "s"
    ts = [a.t for a in a1]
    assert ts == sorted(ts)


def test_guard_slo_never_false_alarms():
    mon = SLOMonitor(["queue_wait.p99<100,name=guard"])
    t = 0.0
    rng = random.Random(1)
    for _ in range(500):
        t += 0.001
        mon.observe_queue_wait(t, "prio1", rng.random())
    assert mon.alerts == []


def test_asymmetry_alerts_at_blackout_boundaries():
    mon = SLOMonitor(["link.out_in_wait_ratio>2,low=1.2,window=0.1,name=asym"])
    t = 0.0
    for _ in range(40):                    # out-dominated traffic
        t += 0.002
        mon.observe_transfer(t, "out", 0.004)
        mon.observe_transfer(t, "in", 0.0005)
    assert mon.alerts == []                # no boundary yet: no evaluation
    mon.on_blackout_boundary(t)
    assert [a.kind for a in mon.alerts] == ["asymmetry_enter"]
    for _ in range(200):                   # traffic balances out
        t += 0.002
        mon.observe_transfer(t, "in", 0.004)
        mon.observe_transfer(t, "out", 0.004)
    mon.on_blackout_boundary(t)
    assert [a.kind for a in mon.alerts] == ["asymmetry_enter", "asymmetry_exit"]
    assert all(a.slo == "asym" for a in mon.alerts)


# ------------------------------------------------------------------ purity
def test_monitor_is_pure_observer_churn_vs_reference():
    rec = MonitoredRecorder(slos=MONITOR_SLOS)
    assert canon(churn_run(fast, obs=rec)) == canon(churn_run(ref))
    assert rec.admissions and rec.monitor.sketches["queue_wait.all"].count > 0


def test_monitor_is_pure_observer_fifo_churn_vs_reference():
    rec = MonitoredRecorder(slos=MONITOR_SLOS)
    got = canon(churn_run(fast, obs=rec, renegotiate=False))
    assert got == canon(churn_run(ref, renegotiate=False))


def test_monitor_is_pure_observer_mesh_vs_reference():
    rec = MonitoredRecorder(slos=MONITOR_SLOS)
    assert canon(mesh_run(fast, obs=rec)) == canon(mesh_run(ref))
    assert rec.monitor.sketches.get("link.wait_in") is not None


def test_monitor_alert_stream_deterministic_across_runs():
    def alerts():
        rec = MonitoredRecorder(slos=MONITOR_SLOS)
        churn_run(fast, obs=rec)
        return [a.as_dict() for a in rec.alerts]

    assert alerts() == alerts()


def test_admissions_tuple_shape_and_priorities():
    rec = MonitoredRecorder(slos=())
    churn_run(fast, obs=rec)
    assert all(len(t) == 4 for t in rec.admissions)  # schedule_check unpacks 4
    assert rec.priorities["base"] == 0.5
    assert set(rec.priorities) == {name for name, *_ in rec.admissions}
    classes = {priority_class(p) for p in rec.priorities.values()}
    sketch_classes = {k.split(".", 1)[1] for k in rec.monitor.sketches
                      if k.startswith("queue_wait.") and k != "queue_wait.all"}
    assert sketch_classes == classes


def test_plain_recorder_still_accepts_priority_hook():
    rec = ObsRecorder()
    churn_run(fast, obs=rec)              # engine now passes priority
    assert rec.priorities and all(len(t) == 4 for t in rec.admissions)


# ----------------------------------------------------------- trace export
def test_trace_alerts_track_and_check_trace(tmp_path):
    rec = MonitoredRecorder(slos=MONITOR_SLOS)
    report = churn_run(fast, obs=rec)
    trace = chrome_trace(rec, report)
    alerts = [e for e in trace["traceEvents"]
              if e.get("pid") == 5 and e.get("ph") == "i"]
    assert alerts, "monitored churn run should raise at least the tight SLO"
    registered = {s["name"] for s in trace["otherData"]["slos"]}
    assert {a["args"]["slo"] for a in alerts} <= registered
    ts = [a["ts"] for a in alerts]
    assert ts == sorted(ts)
    assert "monitor" in trace["otherData"]
    # metrics got the monitor gauges folded in
    assert any(k.startswith("monitor.queue_wait.all.")
               for k in trace["otherData"]["metrics"])

    path = tmp_path / "monitored.trace.json"
    path.write_text(json.dumps(trace))
    check_trace = _load_tool("check_trace")
    assert check_trace.check_trace(str(path)) == []

    # Corrupting an alert's SLO name must be caught.
    for e in trace["traceEvents"]:
        if e.get("pid") == 5 and e.get("ph") == "i":
            e["args"]["slo"] = "never-registered"
            break
    bad = tmp_path / "bad.trace.json"
    bad.write_text(json.dumps(trace))
    errs = check_trace.check_trace(str(bad))
    assert any("unregistered SLO" in e for e in errs)


def test_plain_recorder_trace_has_no_alerts_track(tmp_path):
    rec = ObsRecorder()
    report = churn_run(fast, obs=rec)
    trace = chrome_trace(rec, report)
    assert not any(e.get("pid") == 5 for e in trace["traceEvents"])
    assert "slos" not in trace["otherData"]
    path = tmp_path / "plain.trace.json"
    path.write_text(json.dumps(trace))
    check_trace = _load_tool("check_trace")
    assert check_trace.check_trace(str(path)) == []


# ---------------------------------------------------------------- diffing
def _report_payload(extra_stall=0.0):
    return {
        "makespan_s": 1.0 + extra_stall,
        "tenants": [
            {"name": "a", "status": "completed", "overhead": 0.1,
             "attribution": {"overhead_s": 0.1 + extra_stall,
                             "swap_in_transfer_s": 0.06 + extra_stall,
                             "channel_contention_s": 0.04,
                             "residual_s": 0.0}},
        ],
    }


def test_diff_runs_ledger_signs_and_ranking():
    a = view_from_payload("a", _report_payload(0.0))
    b = view_from_payload("b", _report_payload(0.05))
    d = diff_runs(a, b)
    by_cause = {r["cause"]: r for r in d["ledger_delta"]}
    assert by_cause["swap_in_transfer_s"]["delta"] == pytest.approx(0.05)
    assert by_cause["channel_contention_s"]["delta"] == 0.0
    assert by_cause["overhead_s"]["informational"]
    # top regression table is ranked by |relative| change; tenant lists are
    # not flattened into scalars, so only makespan_s lands here — the
    # per-cause movement is the ledger_delta's job, asserted above.
    rels = [abs(r["rel"]) for r in d["top_regressions"]]
    assert rels == sorted(rels, reverse=True)
    assert d["top_regressions"][0]["metric"] == "makespan_s"
    assert d["top_regressions"][0]["delta"] == pytest.approx(0.05)


def test_load_run_classifies_all_shapes(tmp_path):
    # report
    rp = tmp_path / "report.json"
    rp.write_text(json.dumps(_report_payload()))
    assert load_run(str(rp)).kind == "report"
    # bench
    bp = tmp_path / "BENCH_x.json"
    bp.write_text(json.dumps({"mode": "full", "cell": {"events_per_s": 5.0},
                              "_meta": {"schema_version": 1}}))
    view = load_run(str(bp))
    assert view.kind == "bench" and view.scalars["cell.events_per_s"] == 5.0
    # trace with monitor summary
    rec = MonitoredRecorder(slos=MONITOR_SLOS)
    report = churn_run(fast, obs=rec)
    tp = tmp_path / "t.trace.json"
    tp.write_text(json.dumps(chrome_trace(rec, report)))
    view = load_run(str(tp))
    assert view.kind == "trace" and view.ledger is not None
    assert view.quantiles and "queue_wait.all" in view.quantiles
    # monitor JSONL (last record wins)
    jp = tmp_path / "m.jsonl"
    rec.metrics.append_jsonl(str(jp), {"monitor": rec.finalize()})
    view = load_run(str(jp))
    assert view.kind == "jsonl" and view.quantiles is not None
    # quantile shift between two monitored runs diffs cleanly
    d = diff_runs(load_run(str(tp)), view)
    assert {r["stream"] for r in d["quantile_shift"]} >= {"queue_wait.all"}


def test_diff_quantile_shift_detects_distribution_move(tmp_path):
    def monitored(budget):
        items = poisson_workload(["small", "medium"], 6, 50.0, seed=11,
                                 iterations=(1, 3))
        rec = MonitoredRecorder(slos=())
        rt = fast.MemoryRuntime(HW, budget=budget, channels=2, obs=rec)
        rt.run(churn_tenants(fast, items))
        rec.finalize()
        return {"quantiles": rec.monitor.quantile_summary()}

    loose = view_from_payload("loose", {"slo": monitored(BUDGET * 4)})
    tight = view_from_payload("tight", {"slo": monitored(BUDGET)})
    d = diff_runs(loose, tight)
    shift = {(r["stream"], r["stat"]): r["delta"] for r in d["quantile_shift"]}
    # Queue waits can only get worse when the budget shrinks 4x.
    assert shift[("queue_wait.all", "p99")] >= 0.0


# ------------------------------------------------------------- CLI surface
def test_recorder_for_upgrades_with_slo_args():
    import argparse

    from repro.obs import add_obs_args, recorder_for

    ap = argparse.ArgumentParser()
    add_obs_args(ap)
    args = ap.parse_args(["--slo", "queue_wait.p99<0.005"])
    rec = recorder_for(args)
    assert isinstance(rec, MonitoredRecorder)
    assert rec.slo_specs[0].threshold == 0.005
    args = ap.parse_args([])
    assert recorder_for(args) is None
    args = ap.parse_args(["--trace-out", "/tmp/x.json"])
    rec = recorder_for(args)
    assert isinstance(rec, ObsRecorder) and not isinstance(rec, MonitoredRecorder)


def test_export_monitor_writes_jsonl(tmp_path):
    import argparse

    from repro.obs import add_obs_args, export_monitor, recorder_for

    out = tmp_path / "monitor.jsonl"
    ap = argparse.ArgumentParser()
    add_obs_args(ap)
    args = ap.parse_args(["--slo", "queue_wait.p99<100,name=guard",
                          "--monitor-out", str(out)])
    rec = recorder_for(args)
    churn_run(fast, obs=rec)
    export_monitor(args, rec)
    lines = out.read_text().splitlines()
    assert len(lines) == 1
    record = json.loads(lines[0])
    assert record["monitor"]["slos"][0]["name"] == "guard"
    assert "queue_wait.all" in record["monitor"]["quantiles"]
    assert record["monitor"]["alerts"] == []  # guard must stay silent
    assert any(k.startswith("monitor.") for k in record["metrics"])


def test_alert_dataclass_roundtrip():
    a = Alert(t=1.5, slo="s", kind="burn_rate", value=2.0, threshold=1.0,
              detail={"cls": "prio1"})
    d = a.as_dict()
    assert d["t"] == 1.5 and d["detail"]["cls"] == "prio1"
    assert json.loads(json.dumps(d)) == d
