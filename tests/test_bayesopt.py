"""GP + EI Bayesian optimization (paper §IV-C)."""

import numpy as np

from repro.core.bayesopt import BOResult, GaussianProcess, minimize


def test_gp_interpolates():
    x = np.linspace(-1, 1, 9)[:, None]
    y = np.sin(3 * x[:, 0])
    gp = GaussianProcess(noise=1e-6).fit(x, y)
    mu, sigma = gp.predict(x)
    assert np.allclose(mu, y, atol=1e-3)
    assert (sigma < 0.05).all()


def test_minimize_quadratic():
    target = np.array([0.3, -0.5, 0.1, 0.7])

    def obj(w):
        return float(((np.asarray(w) - target) ** 2).sum())

    res = minimize(obj, n_init=8, n_iter=30, seed=1)
    assert res.best_y < 0.15
    assert len(res.history_y) == 38


def test_minimize_respects_bounds():
    res = minimize(lambda w: float(np.sum(np.asarray(w))), n_iter=10, seed=0)
    assert (res.history_x >= -1.0).all() and (res.history_x <= 1.0).all()


def test_bo_no_worse_than_best_individual_score():
    """Paper claim: BO 'safeguards the overhead to be no larger than the
    minimum of the 4 PS' — on a synthetic trace, within tolerance."""
    from repro.core.autoswap import AutoSwapPlanner
    from repro.core.bayesopt import tune_swap_weights
    from tests.test_autoswap import HW, synth_trace

    tr = synth_trace(n_layers=10)
    pl = AutoSwapPlanner(tr, HW, size_threshold=1 << 20)
    limit = int(pl.peak_load * 0.55)
    individual = min(
        pl.evaluate(limit, method=m).overhead for m in ("doa", "aoa", "wdoa", "swdoa")
    )
    res = tune_swap_weights(pl, limit, n_iter=12, seed=0)
    assert res.best_y <= individual + 0.01
