"""Solve-time fast path: fast solvers pinned against the frozen references.

The production SmartPool/AutoSwap solvers were rewritten for near-linear
solve time (Issue 3); core/_solver_reference.py keeps verbatim copies of the
originals.  These tests pin:

  * SmartPool placements bit-for-bit, for both fit methods and both query
    engines, on randomized traces;
  * AutoSwap scores (DOA/AOA exactly, WDOA/SWDOA to float tolerance — the
    incremental rescore accumulates O(k*eps) rounding) and selections exactly;
  * the memoized IterationTrace load curve, including invalidation;
  * solve_ms provenance through the pass pipeline and artifacts.
"""

import numpy as np
import pytest
from repro.testing import given, settings, st  # hypothesis or deterministic fallback

from repro.core._solver_reference import ReferenceAutoSwapPlanner, reference_solve
from repro.core.autoswap import AutoSwapPlanner
from repro.core.events import IterationTrace, VariableInfo
from repro.core.simulator import HardwareSpec
from repro.core.smartpool import solve

HW = HardwareSpec("test", peak_flops=1e12, hbm_bw=1e12, link_bw=1e10, efficiency=1.0)


def make_trace(intervals):
    """intervals: list of (size, alloc, free)."""
    vs = [
        VariableInfo(i, s, a, f, accesses=[a], access_is_write=[True])
        for i, (s, a, f) in enumerate(intervals)
    ]
    end = max(f for _, _, f in intervals)
    return IterationTrace(vs, end)


def synth_trace(n_layers=8, act_bytes=8 << 20, weight_bytes=4 << 20):
    """Forward/backward-shaped trace (same shape as tests/test_autoswap.py)."""
    vs = []
    var = 0
    n_ops = 4 * n_layers + 2
    fwd_w, fwd_a = [], []
    for l in range(n_layers):
        w = VariableInfo(var, weight_bytes, 0, n_ops, [2 * l], [False]); var += 1
        a = VariableInfo(var, act_bytes, 2 * l, 0, [2 * l + 1], [True]); var += 1
        vs.append(w); fwd_w.append(w)
        vs.append(a); fwd_a.append(a)
    for l in reversed(range(n_layers)):
        bwd_idx = 2 * n_layers + 2 * (n_layers - 1 - l) + 1
        fwd_w[l].accesses.append(bwd_idx)
        fwd_w[l].access_is_write.append(False)
        fwd_a[l].accesses.append(bwd_idx)
        fwd_a[l].access_is_write.append(False)
        fwd_a[l].free_index = bwd_idx + 1
    tr = IterationTrace(vs, n_ops)
    tr.op_costs = {i: (1e9, 1e6) for i in range(n_ops)}  # 1 ms per op
    return tr


def assert_plans_identical(ref, fast):
    assert ref.offsets == fast.offsets
    assert ref.footprint == fast.footprint
    assert ref.peak_load == fast.peak_load
    assert ref.lookup == fast.lookup
    assert ref.method == fast.method


# ------------------------------------------------------------- SmartPool pin
@settings(max_examples=60, deadline=None)
@given(
    st.lists(
        st.tuples(
            st.integers(1, 100_000),   # size
            st.integers(0, 40),        # alloc
            st.integers(1, 40),        # duration
        ),
        min_size=1,
        max_size=40,
    )
)
def test_property_fast_placements_match_reference(items):
    intervals = [(s, a, a + d) for s, a, d in items]
    tr = make_trace(intervals)
    for method in ("best_fit", "first_fit"):
        ref = reference_solve(tr, method)
        for engine in ("event", "bulk", "auto"):
            assert_plans_identical(ref, solve(tr, method, engine=engine))


def test_fast_matches_reference_with_duplicate_allocs_and_weights():
    # many same-alloc variables + whole-iteration weights: stresses both the
    # stab path (same-leaf inserts) and the alloc-window slice.
    intervals = (
        [(4096, 0, 100)] * 3                       # weight-like, full lifetime
        + [(1000 + 13 * i, 5, 5 + i + 1) for i in range(20)]   # same alloc index
        + [(777, 30, 60), (512, 59, 61), (2048, 60, 90)]
    )
    tr = make_trace(intervals)
    for method in ("best_fit", "first_fit"):
        ref = reference_solve(tr, method)
        for engine in ("event", "bulk"):
            assert_plans_identical(ref, solve(tr, method, engine=engine))


def test_fast_matches_reference_zero_and_inverted_lifetimes():
    # Degenerate records (free <= alloc) can appear in malformed device
    # streams; the reference mask is strict on both sides, and the event
    # engine's stab filter must apply alloc_j < free_i, not alloc_j < a_i.
    intervals = [
        (1000, 0, 10), (2000, 0, 10), (500, 2, 8), (266, 3, 3),   # zero-length
        (1536, 4, 9), (266, 5, 1), (700, 1, 0),                   # inverted
        (4096, 0, 12), (128, 6, 7),
    ]
    vs = [VariableInfo(i, s, a, f) for i, (s, a, f) in enumerate(intervals)]
    tr = IterationTrace(vs, 12)
    for method in ("best_fit", "first_fit"):
        ref = reference_solve(tr, method)
        for engine in ("event", "bulk"):
            assert_plans_identical(ref, solve(tr, method, engine=engine))


def test_fast_matches_reference_dense_overlap():
    # everything alive at once: the dense regime the bulk engine targets,
    # and the event engine must still be exact there.
    intervals = [(1024 * (i + 1), 0, 50) for i in range(30)]
    tr = make_trace(intervals)
    for method in ("best_fit", "first_fit"):
        ref = reference_solve(tr, method)
        for engine in ("event", "bulk"):
            assert_plans_identical(ref, solve(tr, method, engine=engine))


def test_unknown_engine_and_method_raise():
    tr = make_trace([(1000, 0, 5)])
    with pytest.raises(ValueError):
        solve(tr, engine="nope")
    for engine in ("event", "bulk", "auto"):
        with pytest.raises(ValueError):
            solve(tr, method="middle_fit", engine=engine)


# -------------------------------------------------------------- AutoSwap pin
def test_swdoa_scores_pinned_against_reference():
    tr = synth_trace(n_layers=10)
    ref = ReferenceAutoSwapPlanner(tr, HW, size_threshold=1 << 20)
    new = AutoSwapPlanner(tr, HW, size_threshold=1 << 20)
    assert len(ref.candidates) == len(new.candidates)
    for s in ("doa", "aoa"):
        a = [c.scores[s] for c in ref.candidates]
        b = [c.scores[s] for c in new.candidates]
        assert a == b  # identical arithmetic -> exact
    for s in ("wdoa", "swdoa"):
        a = np.array([c.scores[s] for c in ref.candidates])
        b = np.array([c.scores[s] for c in new.candidates])
        assert np.allclose(a, b, rtol=1e-6, atol=1e-12), s


@settings(max_examples=12, deadline=None)
@given(st.integers(2, 12), st.floats(0.45, 0.95))
def test_property_selections_match_reference(n_layers, frac):
    tr = synth_trace(n_layers=n_layers)
    ref = ReferenceAutoSwapPlanner(tr, HW, size_threshold=1 << 20)
    new = AutoSwapPlanner(tr, HW, size_threshold=1 << 20)
    limit = int(new.peak_load * frac)
    key = lambda ds: [(d.var, d.size, d.out_after, d.in_before, d.wraps) for d in ds]
    for scorer in ("swdoa", "wdoa", "aoa", "doa"):
        assert key(ref.select(limit, scorer)) == key(new.select(limit, scorer))
    assert ref.load_min() == new.load_min()


def test_weighted_ranking_matches_reference():
    tr = synth_trace(n_layers=6)
    ref = ReferenceAutoSwapPlanner(tr, HW, size_threshold=1 << 20)
    new = AutoSwapPlanner(tr, HW, size_threshold=1 << 20)
    w = [0.4, 0.1, 0.2, 0.3]
    limit = int(new.peak_load * 0.7)
    key = lambda ds: [(d.var, d.out_after, d.in_before, d.wraps) for d in ds]
    assert key(ref.select(limit, None, w)) == key(new.select(limit, None, w))


def test_max_zero_overhead_reduction_matches_reference():
    tr = synth_trace(n_layers=6)
    ref = ReferenceAutoSwapPlanner(tr, HW, size_threshold=1 << 20)
    new = AutoSwapPlanner(tr, HW, size_threshold=1 << 20)
    assert ref.max_zero_overhead_reduction(method="swdoa", grid=8) == \
        new.max_zero_overhead_reduction(method="swdoa", grid=8)


def test_select_is_memoized_and_isolated():
    tr = synth_trace()
    pl = AutoSwapPlanner(tr, HW, size_threshold=1 << 20)
    limit = int(pl.peak_load * 0.7)
    a = pl.select(limit, "swdoa")
    b = pl.select(limit, "swdoa")
    assert a == b and a is not b  # cached value, fresh list per caller
    a.append("sentinel")
    assert pl.select(limit, "swdoa") == b  # caller mutation can't poison cache


# ------------------------------------------------------- load-curve memoizing
def test_load_curve_cached_and_returns_fresh_list():
    tr = make_trace([(1000, 0, 5), (2000, 3, 8)])
    c1 = tr.load_curve()
    arr1 = tr.load_curve_array()
    assert c1 == list(arr1)
    c1[0] = -1  # caller-side mutation (runtime's planned_peak does this)
    assert tr.load_curve()[0] != -1
    assert tr.load_curve_array() is arr1  # memoized


def test_load_curve_invalidated_by_structural_change():
    tr = make_trace([(1000, 0, 5)])
    before = tr.peak_load()
    tr.variables.append(VariableInfo(99, 5000, 0, 5))
    assert tr.peak_load() == before + 5000  # len(variables) guard catches it


def test_load_curve_explicit_invalidation_for_inplace_mutation():
    tr = make_trace([(1000, 0, 5), (2000, 3, 8)])
    assert tr.peak_load() == 3000
    tr.variables[0].size = 11_000  # in-place edit: guard can't see it
    tr.invalidate_cache()
    assert tr.peak_load() == 13_000


# ------------------------------------------------------- solve_ms provenance
def test_passes_record_solve_ms_and_artifact_roundtrip(tmp_path):
    from repro.plan.artifact import PlanCache, dumps_canonical
    from repro.plan.passes import PassContext, Pipeline, PoolPlacement, SwapSelection, TimingAssign
    from repro.plan.program import MemoryProgram, PlanKey

    tr = synth_trace()
    key = PlanKey("synth", "test:solvems", HW.name)
    cache = PlanCache(tmp_path)
    prog = MemoryProgram.from_trace(tr, key=key)
    ctx = PassContext(hw=HW, cache=cache, key=key, size_threshold=1 << 20)
    limit = int(tr.peak_load() * 0.7)
    Pipeline([TimingAssign(), PoolPlacement(("best_fit",)), SwapSelection(limit)]).run(prog, ctx)
    assert "pool:best_fit" in prog.solve_ms
    assert any(k.startswith("swap:swdoa@") for k in prog.solve_ms)
    assert all(v >= 0 for v in prog.solve_ms.values())

    cache.store(prog)
    restored = cache.load(key)
    assert set(restored.solve_ms) == set(prog.solve_ms)
    for k2, v in prog.solve_ms.items():
        assert restored.solve_ms[k2] == pytest.approx(v, abs=1e-3)  # stored rounded
    # Timing is provenance, not plan identity: canonical bytes exclude it.
    assert "solve_ms" not in dumps_canonical(prog)
    assert dumps_canonical(prog) == dumps_canonical(restored)
