"""Distributed lowering tests (subprocess: needs its own XLA device count).

The main test process sees 1 CPU device; these tests exec a child python
with --xla_force_host_platform_device_count to verify that the sharding
specs, mesh builders and step functions lower+compile multi-device — a
miniature of the 512-device production dry-run (which runs via
launch/dryrun.py and is recorded under results/dryrun)."""

import os

import pytest

from distributed_env import run_child_or_skip

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_smoke_config
from repro.distributed.sharding import use_mesh
from repro.launch.mesh import make_mesh
from repro.launch.steps import (batch_specs, build_train_step, build_serve_step,
                                cache_specs_tree, init_optimizer_shapes,
                                opt_specs_like, param_specs, with_sharding)
from repro.models import build_model

cfg = get_smoke_config("ARCH")
model = build_model(cfg)
mesh = make_mesh((4, 2), ("data", "model"))
with use_mesh(mesh):
    pshapes = model.init_shapes()
    pspecs = param_specs(cfg, pshapes, mesh)
    params_in = with_sharding(mesh, pshapes, pspecs)
    B, S = 8, 32
    batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
             "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    if cfg.frontend == "vision_stub":
        batch["patch_embeds"] = jax.ShapeDtypeStruct((B, cfg.num_patch_tokens, cfg.d_model), jnp.float32)
        batch["positions"] = jax.ShapeDtypeStruct((3, B, S + cfg.num_patch_tokens), jnp.int32)
    if cfg.is_encoder_decoder:
        batch["frames"] = jax.ShapeDtypeStruct((B, cfg.enc_seq, cfg.d_model), jnp.float32)
    batch_in = with_sharding(mesh, batch, batch_specs(cfg, batch, mesh))
    opt_in = with_sharding(mesh, init_optimizer_shapes(pshapes), opt_specs_like(pspecs))
    step_in = jax.ShapeDtypeStruct((), jnp.int32, sharding=NamedSharding(mesh, P()))
    fn = build_train_step(model, cfg)
    compiled = jax.jit(fn, donate_argnums=(0, 1)).lower(params_in, opt_in, batch_in, step_in).compile()
    assert compiled.memory_analysis() is not None

    cache_shapes = jax.eval_shape(lambda: model.init_cache(B, S))
    cache_in = with_sharding(mesh, cache_shapes, cache_specs_tree(cfg, cache_shapes, mesh))
    toks = jax.ShapeDtypeStruct((B, 1), jnp.int32, sharding=NamedSharding(mesh, P(("data",), None)))
    pos = jax.ShapeDtypeStruct((), jnp.int32, sharding=NamedSharding(mesh, P()))
    jax.jit(build_serve_step(model, cfg), donate_argnums=(1,)).lower(
        params_in, cache_in, toks, pos).compile()
print("CHILD_OK")
"""


@pytest.mark.parametrize("arch", ["qwen3-4b", "deepseek-v2-lite-16b", "mamba2-370m", "hymba-1.5b"])
def test_multidevice_lowering_smoke(arch):
    # Skips (with the matched reason) when the child fails for environmental
    # reasons — jax API/backend/device-count unavailable in the sandbox —
    # and still fails hard on real code errors.
    run_child_or_skip(CHILD.replace("ARCH", arch))


def test_production_dryrun_artifacts_exist():
    """The 512-device sweep ran: every supported (arch x shape x mesh) cell
    has a result JSON with memory + cost + collective records."""
    import json

    from repro.configs import SHAPES, get_config, list_archs, supports_shape

    root = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")
    if not os.path.isdir(root):
        pytest.skip("dry-run sweep results not present")
    missing = []
    for arch in list_archs():
        cfg = get_config(arch)
        for shape in SHAPES:
            if not supports_shape(cfg, shape):
                continue
            for mesh in ("single", "multi"):
                p = os.path.join(root, f"{arch}__{shape}__{mesh}.json")
                if not os.path.exists(p):
                    missing.append(os.path.basename(p))
                    continue
                rec = json.load(open(p))
                assert rec["memory"]["temp_size_in_bytes"] >= 0
                assert rec["analytic"]["flops"] > 0
    assert not missing, f"missing dry-run cells: {missing}"
