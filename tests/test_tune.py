"""repro.tune — ledger-guided runtime tuning (Issue 8).

Pins the three tuners and their engine plumbing:

  * ``LedgerVictimPolicy`` candidate probes are isolated by construction —
    two simultaneous waiters at one decision point can never leak staged
    reservations between sibling probes (the double-counting regression),
    probing never mutates the live engine, and defaults stay bit-identical
    to the frozen reference;
  * ``max_snapshots`` bounds the barrier-snapshot ring without perturbing
    the run, and the surviving snapshots still resume byte-identically;
  * ``tuned_shares`` coordinate descent is monotone, conserves the budget,
    and respects peak caps; ``colocate_programs(budget_split="tuned")``
    is never worse than proportional on SLO-weighted stall;
  * directional ``HostLink`` lane carving: the split heuristic, the lane
    partition itself, and the gated report keys.
"""

from __future__ import annotations

import json

import pytest

from repro.core.autoswap import AutoSwapPlanner
from repro.core.simulator import HardwareSpec
from repro.plan import MemoryProgram
from repro.runtime import (
    FloorGreedyVictim,
    HostLink,
    MemoryRuntime,
    Tenant,
    colocate_programs,
    planned_peak,
    simulated_report_dict,
    synthetic_train_trace,
)
from repro.runtime import _engine_reference as ref
from repro.tune import (
    LedgerVictimPolicy,
    binding_constraint,
    lane_split_from_waits,
    slo_weighted_stall,
    tuned_shares,
)

HW = HardwareSpec("test", peak_flops=1e12, hbm_bw=1e12, link_bw=1e10, efficiency=1.0)
MB = 1 << 20
ST = 1 << 20


def solved_tenant(name, layers=8, frac=0.7, **kw):
    tr = synthetic_train_trace(layers)
    pl = AutoSwapPlanner(tr, HW, size_threshold=ST)
    limit = int(pl.peak_load * frac)
    return Tenant(name, tr, pl.select(limit, "swdoa"), limit=limit, **kw)


def canon(report) -> str:
    return json.dumps(simulated_report_dict(report), sort_keys=True)


def churn_tenants():
    """One long-running low-priority victim + a newcomer that doesn't fit."""
    a = solved_tenant("A", layers=12, frac=0.8, iterations=6, priority=0.5)
    b = solved_tenant("B", layers=6, frac=0.7, iterations=2, arrival_t=0.005)
    budget = planned_peak(a.trace, a.decisions) + \
        planned_peak(b.trace, b.decisions) // 2
    return [a, b], budget


def two_victim_tenants():
    """Two shrinkable victims + two simultaneous waiters — the shape where
    the policy probes candidates across victims at one decision point."""
    lo = solved_tenant("lo", layers=12, frac=0.8, iterations=6, priority=0.5)
    hi = solved_tenant("hi", layers=10, frac=0.8, iterations=6, priority=1.0)
    n1 = solved_tenant("n1", layers=6, frac=0.7, iterations=1,
                       arrival_t=0.005, priority=2.0)
    n2 = solved_tenant("n2", layers=4, frac=0.7, iterations=1,
                       arrival_t=0.005, priority=2.0)
    floors = {t.name: planned_peak(t.trace, t.decisions)
              for t in (lo, hi, n1, n2)}
    budget = floors["lo"] + floors["hi"] + floors["n1"] // 2
    return [lo, hi, n1, n2], budget


def run(tenants, budget, policy=None, **kw):
    rt = MemoryRuntime(HW, budget=budget, channels=2, renegotiate=True,
                       replan_size_threshold=ST, victim_policy=policy, **kw)
    rt.report = rt.run(tenants)
    return rt


# ----------------------------------------------------------- default identity
def test_default_and_explicit_greedy_bit_identical_to_reference():
    """victim_policy=None and an explicit FloorGreedyVictim both reproduce
    the frozen reference engine byte for byte."""
    want = None
    for policy in (None, FloorGreedyVictim()):
        tenants, budget = churn_tenants()
        got = canon(run(tenants, budget, policy).report)
        if want is None:
            rrt = ref.MemoryRuntime(HW, budget=budget, channels=2,
                                    renegotiate=True,
                                    replan_size_threshold=ST)
            want = canon(rrt.run(churn_tenants()[0]))
        assert got == want


# ------------------------------------------------------------ probe isolation
class _RecordingPolicy(LedgerVictimPolicy):
    """Records each candidate's score and watches the live engine for
    probe-time mutations."""

    def __init__(self, reverse=False, **kw):
        super().__init__(**kw)
        self.reverse = reverse
        self.first_scores: dict | None = None

    def candidates(self, engine, head, needed, victims):
        cands = super().candidates(engine, head, needed, victims)
        return list(reversed(cands)) if self.reverse else cands

    def choose(self, engine, head, needed, victims):
        before_promised = dict(engine._promised)
        before_pending = {r.name: r.replan_pending for r in engine._running}
        scores = {}
        for cand in self.candidates(engine, head, needed, victims):
            score, _ = self.probe(engine, cand)
            scores[(cand[0].name, cand[1])] = score
            # Probing must never touch the live engine's staged state.
            assert engine._promised == before_promised
            assert {r.name: r.replan_pending
                    for r in engine._running} == before_pending
        if self.first_scores is None:
            self.first_scores = scores
        return super().choose(engine, head, needed, victims)


def test_sibling_probes_never_observe_each_other():
    """Two simultaneous waiters: probing candidate A then B must score B
    exactly as probing B then A — a probe that leaked its staged
    reservation into a sibling (the double-counting bug) would shift every
    later candidate's simulated future."""
    fwd = _RecordingPolicy(reverse=False)
    rev = _RecordingPolicy(reverse=True)
    reports = []
    for pol in (fwd, rev):
        tenants, budget = two_victim_tenants()
        reports.append(run(tenants, budget, pol).report)
    assert fwd.first_scores, "no candidates were probed"
    assert len(fwd.first_scores) >= 2, "need >= 2 candidates to detect leaks"
    assert fwd.first_scores == rev.first_scores
    # Same scores -> same argmin -> identical staged decision and run.
    assert canon(reports[0]) == canon(reports[1])
    for rep in reports:
        assert rep.overflow_events == 0
        assert all(t.status == "completed" for t in rep.tenants)


def test_ledger_policy_counts_and_decision_log():
    tenants, budget = two_victim_tenants()
    pol = LedgerVictimPolicy()
    rep = run(tenants, budget, pol).report
    assert pol.staged == rep.renegotiations + rep.renegotiations_cancelled
    assert pol.probes >= pol.staged
    assert len(pol.decision_log) == pol.staged
    for entry in pol.decision_log:
        assert entry["candidates"] >= 1
        assert entry["binding_constraint"] != ""
        assert entry["score"] < float("inf")


def test_probes_do_not_pollute_observer():
    """The live ObsRecorder must see the run's own events only — never the
    phantom ops/transfers/renegotiations of candidate probes."""
    from repro.obs import ObsRecorder

    obs_pol, obs_greedy = ObsRecorder(), ObsRecorder()
    tenants, budget = churn_tenants()
    pol = LedgerVictimPolicy()
    rep_pol = run(tenants, budget, pol, obs=obs_pol).report
    tenants, budget = churn_tenants()
    rep_greedy = run(tenants, budget, None, obs=obs_greedy).report
    assert pol.probes > 0
    # Op/transfer streams match the unprobed run's volume exactly (the two
    # runs stage the same victim here, so the horizons are identical).
    assert canon(rep_pol) == canon(rep_greedy)
    assert len(obs_pol.ops) == len(obs_greedy.ops)
    assert len(obs_pol.transfers) == len(obs_greedy.transfers)
    staged_events = [e for e in obs_pol.renegotiations if e[0] == "staged"]
    assert len(staged_events) == pol.staged


# ------------------------------------------------------------- snapshot ring
def staggered_tenants():
    """Two newcomers far enough apart that each forces its own applied
    barrier — a two-snapshot shape for the ring-buffer test."""
    lo = solved_tenant("lo", layers=12, frac=0.8, iterations=6, priority=0.5)
    hi = solved_tenant("hi", layers=10, frac=0.8, iterations=6, priority=1.0)
    n1 = solved_tenant("n1", layers=6, frac=0.7, iterations=2,
                       arrival_t=0.005, priority=2.0)
    n2 = solved_tenant("n2", layers=4, frac=0.7, iterations=2,
                       arrival_t=0.05, priority=2.0)
    floors = {t.name: planned_peak(t.trace, t.decisions)
              for t in (lo, hi, n1, n2)}
    budget = floors["lo"] + floors["hi"] + floors["n1"] // 2
    return [lo, hi, n1, n2], budget


def test_max_snapshots_ring_buffer():
    """The ring keeps the most recent N snapshots, doesn't perturb the run,
    and the survivors still resume byte-identically."""
    tenants, budget = staggered_tenants()
    uncapped = run(tenants, budget, None, capture_snapshots=True)
    full = canon(uncapped.report)
    total = len(uncapped.barrier_snapshots)
    assert total >= 2, "shape must capture at least two barriers"
    tenants, budget = staggered_tenants()
    capped = run(tenants, budget, None, capture_snapshots=True,
                 max_snapshots=1)
    assert canon(capped.report) == full
    assert len(capped.barrier_snapshots) == 1
    # The survivor is the most recent barrier (largest simulated prefix).
    assert capped.barrier_snapshots[0]._events == \
        uncapped.barrier_snapshots[-1]._events
    assert canon(capped.barrier_snapshots[0].resume()) == full


# ---------------------------------------------------------------- objective
def test_slo_weighted_stall_and_binding_constraint():
    tenants, budget = churn_tenants()
    rep = run(tenants, budget, None).report
    stall = slo_weighted_stall(rep)
    want = sum(t.priority * (max(0.0, t.duration_s - t.baseline_s)
                             + t.queue_wait_s) for t in rep.tenants)
    assert stall == pytest.approx(want)
    assert binding_constraint(rep.attribution) in (
        "transfer", "channel_contention", "blackout", "barrier", "residual")
    assert binding_constraint(None) == "none"
    assert binding_constraint({"overhead_s": 1.0, "queue_wait_s": 2.0}) == "none"
    assert binding_constraint({"swap_in_transfer_s": 1.0,
                               "channel_contention_s": 0.2}) == "transfer"
    assert binding_constraint({"link_blackout_s": 3.0,
                               "swap_in_transfer_s": 1.0}) == "blackout"


def test_slo_weighted_stall_infeasible():
    class T:
        status = "unschedulable"
        priority = duration_s = baseline_s = queue_wait_s = 1.0

    class R:
        overflow_events = 0
        tenants = [T()]

    assert slo_weighted_stall(R()) == float("inf")
    R.tenants, R.overflow_events = [], 3
    assert slo_weighted_stall(R()) == float("inf")


# ------------------------------------------------------------- budget tuner
def test_tuned_shares_descends_and_conserves():
    peaks = {"a": 100 * MB, "b": 100 * MB}
    budget = 120 * MB
    target = 90 * MB

    def evaluate(shares):
        return abs(shares["a"] - target) / MB

    res = tuned_shares(peaks, budget, evaluate, min_delta=MB, max_evals=40)
    assert res.tuned_stall <= res.initial_stall
    assert res.improved
    assert sum(res.shares.values()) == budget
    assert all(0 <= res.shares[n] <= peaks[n] for n in peaks)
    assert abs(res.shares["a"] - target) <= 2 * MB
    assert res.evals <= 40 and res.moves
    d = res.as_dict()
    assert d["tuned_stall_s"] == res.tuned_stall
    assert d["initial_shares"] == res.initial_shares


def test_tuned_shares_keeps_start_when_nothing_helps():
    peaks = {"a": 64 * MB, "b": 64 * MB}

    def evaluate(shares):
        return 1.0  # flat objective: no strict improvement anywhere

    res = tuned_shares(peaks, 96 * MB, evaluate, min_delta=MB, max_evals=40)
    assert res.shares == res.initial_shares
    assert res.tuned_stall == res.initial_stall == 1.0
    assert not res.moves


def test_colocate_tuned_split_never_worse():
    progs = {
        "big": MemoryProgram.from_trace(synthetic_train_trace(12)),
        "small": MemoryProgram.from_trace(synthetic_train_trace(4)),
    }
    kw = dict(hw=HW, budget_frac=0.7, channels=2, size_threshold=ST,
              iterations=2, priorities={"big": 2.0, "small": 0.5})
    prop = colocate_programs(progs, **kw)
    tuned = colocate_programs(progs, budget_split="tuned", **kw)
    assert prop.budget_split == "proportional" and prop.split_tuning is None
    assert tuned.budget_split == "tuned" and tuned.split_tuning is not None
    assert sum(tuned.shares.values()) == tuned.budget
    assert tuned.split_tuning["tuned_stall_s"] <= \
        tuned.split_tuning["initial_stall_s"]
    assert slo_weighted_stall(tuned.report) <= \
        slo_weighted_stall(prop.report) + 1e-12
    assert all(t.status == "completed" for t in tuned.report.tenants)
    with pytest.raises(ValueError, match="budget_split"):
        colocate_programs(progs, budget_split="bogus", **kw)


# ------------------------------------------------------------------- lanes
def test_lane_split_from_waits():
    assert lane_split_from_waits(1.0, 1.0, 1) is None       # nothing to carve
    assert lane_split_from_waits(0.0, 0.0, 4) is None       # no evidence
    assert lane_split_from_waits(1.0, 1.0, 4) == 2          # symmetric demand
    assert lane_split_from_waits(3.0, 1.0, 4) == 1          # in-heavy: 1 out
    assert lane_split_from_waits(0.0, 5.0, 4) == 3          # out-heavy, clamped
    assert lane_split_from_waits(5.0, 0.0, 4) == 1          # in keeps >= 1 out
    # Byte fallback when the probe saw no queueing at all.
    assert lane_split_from_waits(0.0, 0.0, 4, bytes_in=3, bytes_out=1) == 1
    assert lane_split_from_waits(0.0, 0.0, 4, bytes_in=0, bytes_out=0) is None


def test_hostlink_directional_partition():
    link = HostLink.make(1e10, 4, out_lanes=1)
    assert link.out_lane_ids == (0,)
    assert link.in_lane_ids == (1, 2, 3)
    assert list(link.lane_ids("out")) == [0]
    assert list(link.lane_ids("in")) == [1, 2, 3]
    shared = HostLink.make(1e10, 4)
    assert shared.out_lane_ids is None
    assert list(shared.lane_ids("in")) == list(range(4))
    # out_lanes is clamped so each direction keeps at least one lane.
    assert HostLink.make(1e10, 2, out_lanes=5).out_lane_ids == (0,)
    assert HostLink.make(1e10, 1, out_lanes=1).out_lane_ids is None


def mesh_pair(mod=None):
    ts = []
    for i, layers in enumerate((8, 8)):
        t = solved_tenant(f"shard{i}", layers=layers, frac=0.6, iterations=3)
        t.device = f"d{i}"
        ts.append(t)
    return ts


def test_directional_link_report_keys_gated():
    """Directional runs report the carve + per-direction counters; default
    shared-pool runs keep the exact legacy link dict (reference identity)."""
    rt = MemoryRuntime(HW, channels=2, link=HostLink.make(1e9, 4))
    rep = rt.run(mesh_pair())
    assert set(rep.link) == {"total_bw", "lanes", "lane_bw", "bytes_moved",
                             "transfers", "blackout_s"}
    assert rt.link.bytes_in + rt.link.bytes_out == rt.link.bytes_moved
    rt2 = MemoryRuntime(HW, channels=2, link=HostLink.make(1e9, 4, out_lanes=2))
    rep2 = rt2.run(mesh_pair())
    assert rep2.link["out_lanes"] == 2 and rep2.link["in_lanes"] == 2
    assert rep2.link["bytes_in"] + rep2.link["bytes_out"] == \
        rep2.link["bytes_moved"]
    assert rep2.link["wait_in_s"] >= 0.0 and rep2.link["wait_out_s"] >= 0.0


def test_run_mesh_directional_probe_and_carve():
    pytest.importorskip("jax")
    from repro.dist import run_mesh
    from test_dist import _solved_toy

    solved = _solved_toy()
    peak = solved.capture.groups["spmd"].trace.peak_load()
    kw = dict(budget_per_device=peak, iterations=2, link_lanes=4)
    static = run_mesh(solved, HW, **kw)
    directional = run_mesh(solved, HW, lane_split="directional", **kw)
    assert static.lane_split == "static" and static.lane_info is None
    assert directional.lane_split == "directional"
    info = directional.lane_info
    assert info is not None and info["lanes"] == 4
    if info["out_lanes"] is not None:
        assert 1 <= info["out_lanes"] <= 3
        assert directional.report.link["out_lanes"] == info["out_lanes"]
    with pytest.raises(ValueError, match="lane_split"):
        run_mesh(solved, HW, lane_split="bogus", **kw)
