"""RecordingDevice (paper §V), iteration detection, jaxpr lifetime tracer."""

import jax
import jax.numpy as jnp
import pytest

from repro.core.events import EventKind, build_trace
from repro.core.iteration import IterationDetector, detect_repeating_suffix
from repro.core.trace import RecordingDevice, trace_step_fn


def run_fake_iterations(dev, n_iters=3, n_blocks=5, size=1 << 20):
    for _ in range(n_iters):
        blocks = [dev.malloc(size * (i + 1)) for i in range(n_blocks)]
        for b in blocks:
            dev.exec(None, [b], [b])
        for b in blocks:
            dev.free(b)


def test_device_detects_iteration():
    dev = RecordingDevice()
    run_fake_iterations(dev)
    dev._detector.finalize()
    assert dev.iteration_detected
    # one iteration = n_blocks * (malloc + read + write + free)
    assert dev._detector.period == 5 * 4


def test_iteration_requires_malloc_and_free():
    # A pure read/write loop must NOT be detected as an iteration.
    sigs = [(int(EventKind.READ), 64), (int(EventKind.WRITE), 64)] * 20
    assert detect_repeating_suffix(sigs) is None


def test_detected_trace_has_lifetimes():
    dev = RecordingDevice()
    run_fake_iterations(dev, n_iters=4)
    tr = dev.iteration_trace()
    assert len(tr.variables) >= 5
    assert tr.peak_load() > 0


def test_jaxpr_tracer_mlp():
    def step(w1, w2, x):
        h = jnp.tanh(x @ w1)
        y = h @ w2
        return jnp.sum(y * y)

    w1 = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    w2 = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    x = jax.ShapeDtypeStruct((16, 64), jnp.float32)
    tr = trace_step_fn(step, w1, w2, x)
    assert tr.peak_load() > 0
    # args are the first mallocs of the stream
    args = [v for v in tr.variables if v.alloc_index < 3]
    assert len(args) >= 3
    # every var's free is after its last access
    for v in tr.variables:
        if v.accesses:
            assert v.free_index >= max(v.accesses)


def test_jaxpr_tracer_scan_unroll():
    def step(carry, xs):
        def body(c, x):
            return c * x + 1.0, c
        return jax.lax.scan(body, carry, xs)

    c = jax.ShapeDtypeStruct((8,), jnp.float32)
    xs = jax.ShapeDtypeStruct((12, 8), jnp.float32)
    tr = trace_step_fn(step, c, xs, max_scan_unroll=12)
    # each trip mallocs fresh buffers: at least one var per trip
    assert len(tr.variables) >= 12


def test_jaxpr_tracer_grad_has_backward_phase():
    def loss(w, x):
        return jnp.sum(jnp.tanh(x @ w) ** 2)

    def step(w, x):
        return jax.grad(loss)(w, x)

    w = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 32), jnp.float32)
    tr = trace_step_fn(step, w, x)
    # load profile should rise then fall (residuals held for backward)
    curve = tr.load_curve()
    peak_at = curve.index(max(curve))
    assert 0 < peak_at < len(curve) - 1


def test_checkpoint_name_labels_survive():
    from jax.ad_checkpoint import checkpoint_name

    def step(w, x):
        def f(w):
            h = checkpoint_name(jnp.tanh(x @ w), "block_in")
            return jnp.sum(h * h)
        return jax.grad(jax.checkpoint(f, policy=None))(w)

    w = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 32), jnp.float32)
    tr = trace_step_fn(step, w, x)
    names = {v.name for v in tr.variables}
    assert "block_in" in names
