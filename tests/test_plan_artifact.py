"""repro.plan: MemoryProgram IR, pass pipeline, registry, artifact cache.

Covers the tentpole invariants: canonical byte-identical round trips of a
solved program, no-overlap placement driven through PoolPlacement, registry
dispatch, the RecordingDevice front-end, and the cross-process contract (a
plan solved in one process reloads from the artifact cache in a second
process without re-running the trace)."""

import json
import subprocess
import sys
from pathlib import Path

import pytest

from repro.core.events import IterationTrace, VariableInfo
from repro.core.planner import MemoryPlanner
from repro.core.simulator import HardwareSpec
from repro.core.trace import RecordingDevice
from repro.plan import (
    IterationDetect,
    MemoryProgram,
    OffloadLowering,
    PassContext,
    Pipeline,
    PlanCache,
    PlanCacheMiss,
    PlanKey,
    PoolPlacement,
    SwapSelection,
    TimingAssign,
    TraceCapture,
    dumps_canonical,
    pool_names,
    program_from_json,
    program_to_json,
    scorer_names,
    swap_key,
)

HW = HardwareSpec("test", peak_flops=1e12, hbm_bw=1e12, link_bw=1e10, efficiency=1.0)

REPO = Path(__file__).resolve().parent.parent


def make_trace(intervals):
    """intervals: list of (size, alloc, free); one access at alloc, one before free."""
    vs = [
        VariableInfo(i, s, a, f, accesses=[a, max(a, f - 1)], access_is_write=[True, False])
        for i, (s, a, f) in enumerate(intervals)
    ]
    end = max(f for _, _, f in intervals)
    tr = IterationTrace(vs, end)
    tr.op_costs = {i: (1e9, 1e6) for i in range(end)}
    return tr


def solved_program(key=None):
    tr = make_trace([
        (4 << 20, 0, 3), (2 << 20, 1, 6), (8 << 20, 2, 9),
        (1 << 20, 4, 8), (4 << 20, 5, 10), (2 << 20, 7, 10),
    ])
    ctx = PassContext(hw=HW, size_threshold=1 << 20)
    prog = Pipeline([
        TimingAssign(),
        PoolPlacement(("best_fit", "first_fit", "cnmem", "exact")),
        SwapSelection(limit=int(tr.peak_load() * 0.8), scorer="swdoa"),
        OffloadLowering(limit=int(tr.peak_load() * 0.8)),
    ]).run(MemoryProgram.from_trace(tr, key), ctx)
    return prog


# ------------------------------------------------------------- round trips
def test_round_trip_is_byte_identical():
    prog = solved_program(PlanKey("synthetic", "unit", HW.name))
    blob = dumps_canonical(prog)
    restored = program_from_json(json.loads(blob))
    assert dumps_canonical(restored) == blob


def test_round_trip_preserves_lookup_and_schedule():
    prog = solved_program()
    restored = program_from_json(program_to_json(prog))
    for method in ("best_fit", "first_fit"):
        assert restored.pool_plans[method].lookup == prog.pool_plans[method].lookup
        assert restored.pool_plans[method].offsets == prog.pool_plans[method].offsets
    k = next(iter(prog.swap_summaries))
    assert restored.swap_summaries[k].decisions == prog.swap_summaries[k].decisions
    assert restored.swap_summaries[k].overhead == prog.swap_summaries[k].overhead
    assert restored.offload_plans == prog.offload_plans or (
        restored.offload_plans[k].offload_names == prog.offload_plans[k].offload_names
    )


# ------------------------------------------------- placement via the pipeline
def assert_no_overlap(trace, plan, alignment=256):
    align = lambda x: (x + alignment - 1) // alignment * alignment
    vs = [v for v in trace.variables if v.size > 0]
    for i in range(len(vs)):
        for j in range(i + 1, len(vs)):
            a, b = vs[i], vs[j]
            if a.overlaps(b):
                a0, a1 = plan.offsets[a.var], plan.offsets[a.var] + align(a.size)
                b0, b1 = plan.offsets[b.var], plan.offsets[b.var] + align(b.size)
                assert a1 <= b0 or b1 <= a0, (a.var, b.var)


def test_pool_placement_no_overlap_through_pipeline():
    """smartpool._place invariant, driven end-to-end through PoolPlacement."""
    intervals = [
        (10_000, 0, 5), (2_000, 1, 9), (2_000, 2, 4), (50_000, 3, 6),
        (2_000, 5, 10), (2_000, 5, 10), (7_000, 0, 10), (300, 6, 8),
    ]
    tr = make_trace(intervals)
    prog = Pipeline([PoolPlacement(("best_fit", "first_fit"))]).run(
        MemoryProgram.from_trace(tr), PassContext(hw=HW)
    )
    for method in ("best_fit", "first_fit"):
        plan = prog.pool_plans[method]
        assert_no_overlap(tr, plan)
        assert plan.footprint >= plan.peak_load


def test_registry_exposes_canonical_strategies():
    assert set(pool_names()) >= {"best_fit", "first_fit", "cnmem", "exact"}
    assert set(scorer_names()) >= {"doa", "aoa", "wdoa", "swdoa", "bo"}


def test_swap_summary_invalidated_on_threshold_change():
    """A cached schedule solved under one candidate threshold must not be
    served for a query under another (different candidate sets)."""
    prog = solved_program()
    k, s = next(iter(prog.swap_summaries.items()))
    assert s.size_threshold == 1 << 20
    prog = Pipeline([SwapSelection(limit=s.limit)]).run(
        prog, PassContext(hw=HW, size_threshold=1 << 23)
    )
    assert prog.swap_summaries[k].size_threshold == 1 << 23


def test_passes_are_idempotent():
    prog = solved_program()
    before = dumps_canonical(prog)
    limit = next(iter(prog.swap_summaries.values())).limit
    again = Pipeline([
        TimingAssign(),
        PoolPlacement(("best_fit", "cnmem")),
        SwapSelection(limit=limit),
    ]).run(prog, PassContext(hw=HW))
    assert dumps_canonical(again) == before


# --------------------------------------------------- device-event front-end
def test_device_events_pipeline():
    """RecordingDevice events -> TraceCapture -> IterationDetect -> pool."""
    dev = RecordingDevice(min_period=4)
    for _ in range(3):  # three identical iterations
        blocks = [dev.malloc(1024 * (i + 1)) for i in range(3)]
        for b in blocks:
            dev.exec(None, [b], [b])
        for b in blocks:
            dev.free(b)
    prog = Pipeline([
        TraceCapture(events=dev.events),
        IterationDetect(),
        PoolPlacement(("best_fit",)),
    ]).run(None, PassContext(hw=HW))
    assert prog.trace is not None and prog.raw_events is None
    assert prog.pool_plans["best_fit"].footprint > 0


# ------------------------------------------------------------ cache contract
SOLVE_SNIPPET = """
import sys, jax, jax.numpy as jnp
from repro.core.planner import MemoryPlanner
from repro.plan import PlanCache, PlanKey

def step(w, x):
    h = jnp.tanh(x @ w)
    return jnp.sum(h * h)

w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
key = PlanKey("toy", "train:b32", "tpu_v5e")
p = MemoryPlanner(step, w, x, size_threshold=1, cache=PlanCache(sys.argv[1]), key=key)
rep = p.report()
sw = p.swap_report(int(p.swap.peak_load * 0.9))
print("SOLVED", rep.peak_load, rep.smartpool_footprint, sw.limit, sw.num_selected)
"""

RELOAD_SNIPPET = """
import sys
from repro.core.planner import MemoryPlanner
from repro.plan import PlanCache, PlanKey

key = PlanKey("toy", "train:b32", "tpu_v5e")
# step_fn=None: reloading must NOT re-run the trace (it cannot).
# size_threshold must match the solve; a mismatch invalidates swap summaries.
p = MemoryPlanner(None, cache=PlanCache(sys.argv[1]), key=key, size_threshold=1)
assert p.from_cache
rep = p.report()
limit = next(iter(p.program.swap_summaries.values())).limit
sw = p.swap_report(limit)
print("RELOADED", rep.peak_load, rep.smartpool_footprint, sw.limit, sw.num_selected)
"""


def _run(snippet: str, cache_dir: str) -> str:
    out = subprocess.run(
        [sys.executable, "-c", snippet, cache_dir],
        capture_output=True, text=True, cwd=REPO,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin:/usr/local/bin"},
    )
    assert out.returncode == 0, out.stderr
    return out.stdout.strip().splitlines()[-1]


def test_plan_solved_in_one_process_reloads_in_another(tmp_path):
    cache_dir = str(tmp_path / "plans")
    solved = _run(SOLVE_SNIPPET, cache_dir)
    reloaded = _run(RELOAD_SNIPPET, cache_dir)
    assert solved.split()[1:] == reloaded.split()[1:]
    assert len(list((tmp_path / "plans").glob("*.json"))) == 1


# --------------------------------------------------- eviction + versioning
def _store_n(cache, n):
    paths = []
    for i in range(n):
        prog = solved_program(PlanKey("synthetic", f"unit{i}", HW.name))
        paths.append(cache.store(prog))
    return paths


def test_cache_evicts_oldest_past_size_bound(tmp_path):
    probe = PlanCache(tmp_path / "probe")
    size = _store_n(probe, 1)[0].stat().st_size
    cache = PlanCache(tmp_path / "bound", max_bytes=int(2.5 * size))
    _store_n(cache, 4)
    kept = cache.keys()
    assert len(kept) == 2, kept
    # Newest artifacts survive; the earliest-stored were evicted.
    assert cache.load(PlanKey("synthetic", "unit3", HW.name)) is not None
    assert cache.load(PlanKey("synthetic", "unit0", HW.name)) is None
    assert cache.total_bytes() <= int(2.5 * size)


def test_cache_eviction_is_lru_not_fifo(tmp_path):
    import os

    cache = PlanCache(tmp_path, max_bytes=None)
    p0, p1 = _store_n(cache, 2)
    size = p0.stat().st_size
    # Backdate both, then *load* unit0: the hit must refresh its recency.
    os.utime(p0, (1000, 1000))
    os.utime(p1, (2000, 2000))
    assert cache.load(PlanKey("synthetic", "unit0", HW.name)) is not None
    cache.max_bytes = int(2.5 * size)
    cache.store(solved_program(PlanKey("synthetic", "unit2", HW.name)))
    assert cache.load(PlanKey("synthetic", "unit0", HW.name)) is not None, "recently-used survives"
    assert cache.load(PlanKey("synthetic", "unit1", HW.name)) is None, "LRU artifact evicted"


def test_cache_never_evicts_just_written_artifact(tmp_path):
    probe = PlanCache(tmp_path / "probe")
    size = _store_n(probe, 1)[0].stat().st_size
    cache = PlanCache(tmp_path / "tiny", max_bytes=size // 2)  # nothing fits
    _store_n(cache, 2)
    assert cache.keys() == [PlanKey("synthetic", "unit1", HW.name).cache_name()]


def test_cache_version_mismatch_is_silent_miss(tmp_path):
    import warnings

    cache = PlanCache(tmp_path)
    key = PlanKey("synthetic", "unit-v", HW.name)
    path = cache.store(solved_program(key))
    blob = json.loads(path.read_text())
    blob["version"] = blob["version"] + 1
    path.write_text(json.dumps(blob))
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        assert cache.load(key) is None
    assert not caught, "a schema-version miss must not warn (it is the upgrade path)"
    assert cache.version_misses == 1
    # Direct deserialization still refuses loudly (library contract).
    with pytest.raises(ValueError):
        program_from_json(blob)


def test_cache_corrupt_artifact_warns_and_misses(tmp_path):
    import warnings

    cache = PlanCache(tmp_path)
    key = PlanKey("synthetic", "unit-c", HW.name)
    path = cache.store(solved_program(key))
    for corrupt in ("{not json", "null", "[]"):
        path.write_text(corrupt)
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            assert cache.load(key) is None
        assert caught, f"corruption {corrupt!r} (unlike versioning) should be surfaced"
    assert cache.version_misses == 0, "corruption must not masquerade as a version miss"


def test_cache_miss_without_step_fn_raises(tmp_path):
    with pytest.raises(PlanCacheMiss):
        MemoryPlanner(None, cache=PlanCache(tmp_path), key=PlanKey("a", "b", "c"))


def test_cache_requires_key(tmp_path):
    with pytest.raises(ValueError):
        MemoryPlanner(lambda x: x, cache=PlanCache(tmp_path))


# --------------------------------------------- verification certificates
def test_artifact_save_stamps_certificate(tmp_path):
    from repro.plan import ArtifactSave

    cache = PlanCache(tmp_path)
    prog = solved_program(PlanKey("synthetic", "cert", HW.name))
    ArtifactSave().run(prog, PassContext(hw=HW, cache=cache))
    assert prog.certificate is not None
    assert all(c["violations"] == [] for c in prog.certificate["checks"].values())
    payload = json.loads(cache.path_for(prog.key).read_text())
    assert payload["certificate"] == prog.certificate


def test_certificate_excluded_from_plan_identity():
    from repro.analyze import verify_program

    prog = solved_program(PlanKey("synthetic", "cert-id", HW.name))
    blob = dumps_canonical(prog)
    prog.certificate = verify_program(prog).to_dict()
    assert dumps_canonical(prog) == blob, "certificate must be provenance, not identity"


def test_cache_load_reverifies_and_stamps(tmp_path):
    cache = PlanCache(tmp_path)
    key = PlanKey("synthetic", "cert-load", HW.name)
    cache.store(solved_program(key))
    restored = cache.load(key)
    assert restored is not None and restored.from_cache
    assert restored.certificate is not None
    assert all(c["violations"] == [] for c in restored.certificate["checks"].values())
    assert cache.certificate_misses == 0


def test_cache_demotes_artifact_failing_reverification(tmp_path):
    import warnings

    cache = PlanCache(tmp_path)
    key = PlanKey("synthetic", "cert-bad", HW.name)
    path = cache.store(solved_program(key))
    # Tamper with the stored bytes: drop every swap decision while keeping
    # the committed planned_floor — the re-proved floor no longer matches.
    payload = json.loads(path.read_text())
    assert payload["swap_summaries"]
    for s in payload["swap_summaries"].values():
        s["decisions"] = []
    path.write_text(json.dumps(payload, sort_keys=True, separators=(",", ":")))
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        assert cache.load(key) is None, "a failing certificate is a cache miss"
    assert any("failed re-verification" in str(w.message) for w in caught)
    assert cache.certificate_misses == 1
