"""Dynamic tenant churn: arrivals, renegotiation, and attribution fixes.

Covers the churn tentpole and its satellites:
  * seeded workload generation is reproducible (same seed, same workload);
  * ``queue_wait_s``/``admitted_at`` are pinned against explicit arrival
    times (waits are measured from ``arrival_t``, not from t=0);
  * with renegotiation disabled — or unable to create room — the runtime is
    byte-for-byte the FIFO-queue baseline;
  * renegotiation admits a blocked newcomer earlier by shrinking a running
    victim at its iteration barrier, with the victim picked lowest-priority
    first and all budget invariants intact;
  * the 1-tenant ``simulate_program`` path stays bit-for-bit equal to the
    frozen pre-runtime reference simulator;
  * ``tail_spill_s`` is attributed per tenant (not the global out-channel
    drain) and colocation shares use largest-remainder rounding.
"""

import pytest

from repro.core._solver_reference import reference_simulate_swap_schedule
from repro.core.autoswap import AutoSwapPlanner
from repro.core.events import IterationTrace, VariableInfo
from repro.core.simulator import HardwareSpec, SwapDecision
from repro.plan import MemoryProgram
from repro.runtime import (
    MemoryRuntime,
    Tenant,
    colocate_programs,
    planned_peak,
    poisson_workload,
    proportional_shares,
    simulate_program,
    synthetic_train_trace,
)
from repro.runtime.workload import parse_arrivals

HW = HardwareSpec("test", peak_flops=1e12, hbm_bw=1e12, link_bw=1e10, efficiency=1.0)
MB = 1 << 20


def solved_tenant(name, layers=8, frac=0.7, **kw):
    tr = synthetic_train_trace(layers)
    pl = AutoSwapPlanner(tr, HW, size_threshold=1 << 20)
    limit = int(pl.peak_load * frac)
    return Tenant(name, tr, pl.select(limit, "swdoa"), limit=limit, **kw)


# ----------------------------------------------------------------- workload
def test_poisson_workload_reproducible_by_seed():
    a = poisson_workload(["s", "m"], 16, 200.0, seed=7, iterations=(1, 4),
                         priorities=(0.5, 1.0, 2.0))
    b = poisson_workload(["s", "m"], 16, 200.0, seed=7, iterations=(1, 4),
                         priorities=(0.5, 1.0, 2.0))
    assert a == b, "same seed must reproduce the workload bit-for-bit"
    c = poisson_workload(["s", "m"], 16, 200.0, seed=8, iterations=(1, 4),
                         priorities=(0.5, 1.0, 2.0))
    assert a != c, "different seeds must differ"
    assert all(x.arrival_t < y.arrival_t for x, y in zip(a, a[1:]))
    assert all(1 <= x.iterations <= 4 for x in a)


def test_parse_arrivals_explicit_and_poisson():
    assert parse_arrivals("0, 0.002, 0.005", 3) == [0.0, 0.002, 0.005]
    with pytest.raises(ValueError, match="3 times for 2 tenants"):
        parse_arrivals("0,0.1,0.2", 2)
    p1 = parse_arrivals("poisson:rate=500,seed=3", 5)
    p2 = parse_arrivals("poisson:rate=500,seed=3", 5)
    assert p1 == p2 and len(p1) == 5
    assert all(a < b for a, b in zip(p1, p1[1:]))
    with pytest.raises(ValueError, match="bad poisson arrival parameter"):
        parse_arrivals("poisson:bogus=1", 2)


# ---------------------------------------------------------- arrival accounting
def test_queue_wait_pinned_to_arrival_when_fitting():
    """A newcomer whose floor fits is admitted at its arrival instant and
    waits zero — today's t=0 assumption (queue_wait = admit_t) would report
    the arrival time itself as wait."""
    a = solved_tenant("A", layers=8, iterations=2)
    b = solved_tenant("B", layers=4, iterations=1, arrival_t=0.005)
    budget = planned_peak(a.trace, a.decisions) + planned_peak(b.trace, b.decisions)
    rep = MemoryRuntime(HW, budget=budget, channels=2).run([a, b])
    tb = rep.tenant("B")
    assert tb.arrival_t == 0.005
    assert tb.admitted_at == 0.005
    assert tb.queue_wait_s == 0.0


def test_queue_wait_pinned_to_release_when_blocked():
    """A blocked newcomer is admitted exactly when the running tenant
    finishes; its wait is measured from its own arrival."""
    a = solved_tenant("A", layers=8, iterations=2)
    b = solved_tenant("B", layers=8, iterations=1, arrival_t=0.004)
    floor_a = planned_peak(a.trace, a.decisions)
    floor_b = planned_peak(b.trace, b.decisions)
    budget = floor_a + floor_b - 1  # B cannot fit while A runs
    rep = MemoryRuntime(HW, budget=budget, channels=2).run([a, b])
    ta, tb = rep.tenant("A"), rep.tenant("B")
    assert tb.admitted_at == ta.finished_at
    assert tb.queue_wait_s == pytest.approx(ta.finished_at - 0.004, abs=0.0)
    assert tb.queue_wait_s > 0.0


def test_arrival_during_idle_gap_starts_at_arrival():
    """With nothing running, the clock jumps to the arrival event."""
    t = solved_tenant("late", layers=4, arrival_t=1.5)
    rep = MemoryRuntime(HW, channels=2).run([t])
    tr = rep.tenant("late")
    assert tr.admitted_at == 1.5 and tr.queue_wait_s == 0.0
    assert tr.finished_at > 1.5
    assert rep.makespan_s == tr.finished_at


# ------------------------------------------------- renegotiation vs queueing
def churn_pair(arrival=0.005):
    """A long-running victim + a newcomer that doesn't fit beside it."""
    a = solved_tenant("A", layers=12, frac=0.8, iterations=6, priority=0.5)
    b = solved_tenant("B", layers=6, frac=0.7, iterations=2, arrival_t=arrival)
    floor_a = planned_peak(a.trace, a.decisions)
    floor_b = planned_peak(b.trace, b.decisions)
    budget = floor_a + floor_b // 2
    return a, b, budget


def fresh(t: Tenant) -> Tenant:
    return Tenant(t.name, t.trace, list(t.decisions), limit=t.limit,
                  iterations=t.iterations, arrival_t=t.arrival_t,
                  priority=t.priority, departure_t=t.departure_t)


def run_pair(a, b, budget, **kw):
    rt = MemoryRuntime(HW, budget=budget, channels=2,
                       replan_size_threshold=1 << 20, **kw)
    return rt.run([fresh(a), fresh(b)])


def test_renegotiation_admits_newcomer_earlier():
    a, b, budget = churn_pair()
    fifo = run_pair(a, b, budget, renegotiate=False)
    reneg = run_pair(a, b, budget, renegotiate=True)
    assert fifo.policy == "fifo" and reneg.policy == "renegotiate"
    assert reneg.tenant("B").queue_wait_s < fifo.tenant("B").queue_wait_s
    victim = reneg.tenant("A")
    assert victim.renegotiations == 1
    assert victim.renegotiation_freed_bytes > 0
    assert victim.floor < fifo.tenant("A").floor, "victim reservation shrank"
    assert reneg.renegotiations == 1
    assert reneg.renegotiation_freed_bytes == victim.renegotiation_freed_bytes
    # Invariants survive the shrink.
    assert reneg.overflow_events == 0
    assert reneg.aggregate_peak <= budget


def test_renegotiation_disabled_matches_fifo_exactly():
    """The event-driven engine with renegotiate=False IS the FIFO baseline."""
    a, b, budget = churn_pair()
    r1 = run_pair(a, b, budget, renegotiate=False).as_dict()
    r2 = run_pair(a, b, budget, renegotiate=False).as_dict()
    # The engine block carries wall-clock throughput, different every run.
    r1.pop("engine"), r2.pop("engine")
    assert r1 == r2, "FIFO runs are deterministic"


def test_failed_renegotiation_falls_back_to_fifo():
    """A replanner that cannot free any bytes must leave the run identical
    to plain FIFO queueing (modulo the policy label)."""
    a, b, budget = churn_pair()
    fifo = run_pair(a, b, budget, renegotiate=False).as_dict()
    noop = run_pair(a, b, budget, renegotiate=True,
                    replanner=lambda tenant, new_limit: (list(tenant.decisions), 0.0))
    noop_d = noop.as_dict()
    assert noop.renegotiations == 0
    fifo.pop("policy"), noop_d.pop("policy")
    fifo.pop("engine"), noop_d.pop("engine")  # wall clock differs per run
    assert noop_d == fifo


def test_victim_selection_prefers_lowest_priority():
    lo = solved_tenant("lo", layers=10, frac=0.8, iterations=6, priority=0.5)
    hi = solved_tenant("hi", layers=10, frac=0.8, iterations=6, priority=2.0)
    new = solved_tenant("new", layers=6, frac=0.7, iterations=1, arrival_t=0.005)
    floors = {t.name: planned_peak(t.trace, t.decisions) for t in (lo, hi, new)}
    budget = floors["lo"] + floors["hi"] + floors["new"] // 2
    rt = MemoryRuntime(HW, budget=budget, channels=2, renegotiate=True,
                       replan_size_threshold=1 << 20)
    rep = rt.run([fresh(lo), fresh(hi), fresh(new)])
    assert rep.tenant("lo").renegotiations == 1, "lowest priority is the victim"
    assert rep.tenant("hi").renegotiations == 0
    assert rep.overflow_events == 0


def test_departure_bounds_open_ended_tenant():
    t = solved_tenant("open", layers=4, iterations=1)
    one = MemoryRuntime(HW, channels=2).run([fresh(t)])
    iter_s = one.tenant("open").finished_at
    t2 = fresh(t)
    t2.departure_t = 2.5 * iter_s
    rep = MemoryRuntime(HW, channels=2).run([t2])
    r = rep.tenant("open")
    assert r.iterations == 3, "departs at the first barrier past departure_t"
    assert r.finished_at >= t2.departure_t


# ----------------------------------------------------- reference stability
def test_single_tenant_path_bit_for_bit_vs_reference():
    """The churn-capable engine must not perturb the paper's 1-tenant
    2-channel eager-prefetch semantics at all."""
    for layers, frac in ((4, 0.6), (8, 0.7), (12, 0.85)):
        tr = synthetic_train_trace(layers)
        pl = AutoSwapPlanner(tr, HW, size_threshold=1 << 20)
        limit = int(pl.peak_load * frac)
        dec = pl.select(limit, "swdoa")
        ref = reference_simulate_swap_schedule(tr, dec, HW, limit)
        got = simulate_program(tr, dec, HW, limit, channels=2, prefetch="eager")
        for f in ("baseline_s", "duration_s", "peak_resident", "stalls",
                  "delayed_mallocs", "tail_spill_s", "out_events", "in_events"):
            assert getattr(got, f) == getattr(ref, f), f


def _planned_peak_reference(trace, decisions):
    """Frozen copy of the original O(decisions x span) python loop."""
    curve = trace.load_curve()
    n = len(curve)
    for d in decisions:
        if d.wraps:
            spans = (range(0, min(d.in_before, n)), range(min(d.out_after, n), n))
        else:
            spans = (range(min(d.out_after, n), min(d.in_before, n)),)
        for span in spans:
            for i in span:
                curve[i] -= d.size
    return max(curve) if curve else 0


def test_planned_peak_delta_rewrite_matches_reference():
    for layers, frac in ((4, 0.5), (8, 0.7), (12, 0.9)):
        tr = synthetic_train_trace(layers)
        pl = AutoSwapPlanner(tr, HW, size_threshold=1 << 20)
        dec = pl.select(int(pl.peak_load * frac), "swdoa")
        assert planned_peak(tr, dec) == _planned_peak_reference(tr, dec)
        # Wrap coverage: a weight absent across the iteration boundary
        # (swapped out after its last access, back before its first).
        w = tr.variables[0]
        wrap = SwapDecision(w.var, w.size, max(w.accesses), min(w.accesses), wraps=True)
        assert planned_peak(tr, dec + [wrap]) == _planned_peak_reference(tr, dec + [wrap])
    assert planned_peak(IterationTrace([], 0), []) == 0


# ------------------------------------------------------ attribution bugfixes
def test_tail_spill_attributed_per_tenant_not_global():
    """Tenant B launches no swap traffic: its tail_spill_s must be zero even
    while tenant A's swap-outs are still draining on the shared channel."""
    n_ops = 6
    big = 32 * MB
    vs_a = [
        VariableInfo(0, big, 0, n_ops, [0, 1], [True, False]),
        VariableInfo(1, MB, 0, n_ops, [i for i in range(n_ops)], [True] * n_ops),
    ]
    tr_a = IterationTrace(vs_a, n_ops)
    tr_a.op_costs = {i: (1e6, 0.0) for i in range(n_ops)}  # fast compute
    # Swap-out after op 1 with in_before past the end: pure tail traffic.
    dec_a = [SwapDecision(0, big, 1, n_ops)]
    vs_b = [VariableInfo(0, MB, 0, n_ops, [0], [True])]
    tr_b = IterationTrace(vs_b, n_ops)
    tr_b.op_costs = {i: (1e6, 0.0) for i in range(n_ops)}
    rt = MemoryRuntime(HW, budget=None, channels=2)
    rt.run([Tenant("A", tr_a, dec_a, floor=0), Tenant("B", tr_b, floor=0)])
    res_a = rt.runs["A"].sim_result()
    res_b = rt.runs["B"].sim_result()
    assert res_a.tail_spill_s > 0.0, "A's own swap-out drains past its compute"
    # Regression: B used to inherit A's drain via channels.drain_time("out").
    assert rt.channels.drain_time("out") > rt.runs["B"].t
    assert res_b.tail_spill_s == 0.0


def test_proportional_shares_sum_to_budget():
    peaks = {"a": 3, "b": 3, "c": 3}
    shares = proportional_shares(peaks, 100)
    assert sum(shares.values()) == 100, "truncation must not withhold bytes"
    assert max(shares.values()) - min(shares.values()) <= 1
    # Deterministic largest-remainder assignment and proportionality.
    peaks = {"a": 5, "b": 3, "c": 2}
    shares = proportional_shares(peaks, 101)
    assert sum(shares.values()) == 101
    assert shares["a"] >= shares["b"] >= shares["c"]


def test_colocate_shares_grant_full_budget():
    progs = {
        "a": MemoryProgram.from_trace(synthetic_train_trace(8)),
        "b": MemoryProgram.from_trace(synthetic_train_trace(6)),
        "c": MemoryProgram.from_trace(synthetic_train_trace(4)),
    }
    peaks = {n: p.require_trace().peak_load() for n, p in progs.items()}
    budget = sum(peaks.values()) * 2 // 3 + 1  # indivisible on purpose
    result = colocate_programs(progs, HW, budget=budget, channels=2,
                               size_threshold=1 << 20)
    assert sum(result.shares.values()) == budget
    for n, s in result.shares.items():
        assert result.report.tenant(n).status == "completed"
        assert s <= budget


def test_colocate_with_churn_and_renegotiation():
    """End-to-end: colocate_programs threads arrivals/priorities/renegotiate
    through to the runtime and the pipeline replanner."""
    progs = {
        "victim": MemoryProgram.from_trace(synthetic_train_trace(12)),
        "newcomer": MemoryProgram.from_trace(synthetic_train_trace(6)),
    }
    result = colocate_programs(
        progs, HW, budget_frac=0.75, channels=2, size_threshold=1 << 20,
        iterations=5,
        arrivals={"newcomer": 0.02},
        priorities={"victim": 0.5, "newcomer": 1.0},
        renegotiate=True,
    )
    rep = result.report
    assert rep.policy == "renegotiate"
    assert all(t.status == "completed" for t in rep.tenants)
    assert rep.tenant("newcomer").arrival_t == 0.02
    assert rep.aggregate_peak <= result.budget
    assert rep.overflow_events == 0
