"""repro.runtime: engine equivalence, channel scaling, multi-tenant invariants.

Covers the runtime tentpole:
  * the refactored engine reproduces the pre-runtime simulator bit-for-bit
    (1 tenant, 2 channels, eager prefetch) against a frozen reference copy;
  * property: more DMA channels never increase simulated overhead;
  * per-channel transfers are serialized (no overlap), directions partitioned;
  * the shared budget is never exceeded by guarded admissions across tenants,
    including the two-in-channel double-admission hazard;
  * admission control queues (not kills) tenants whose floor doesn't fit.
"""

import pytest
from repro.testing import given, settings, st  # hypothesis or deterministic fallback

from repro.core._solver_reference import reference_simulate_swap_schedule
from repro.core.autoswap import AutoSwapPlanner
from repro.core.events import IterationTrace, VariableInfo
from repro.core.simulator import HardwareSpec, SwapDecision, simulate_swap_schedule
from repro.plan import MemoryProgram, PassContext, Pipeline, PlanCache, PlanKey, SwapSelection, swap_key
from repro.runtime import (
    ChannelPool,
    MemoryRuntime,
    Tenant,
    colocate_programs,
    planned_peak,
    simulate_program,
    tenant_from_program,
)

HW = HardwareSpec("test", peak_flops=1e12, hbm_bw=1e12, link_bw=1e10, efficiency=1.0)


def synth_trace(n_layers=8, act_bytes=8 << 20, weight_bytes=4 << 20):
    """Forward/backward-shaped trace (same family as test_autoswap)."""
    vs = []
    var = 0
    n_ops = 4 * n_layers + 2
    fwd_w, fwd_a = [], []
    for l in range(n_layers):
        w = VariableInfo(var, weight_bytes, 0, n_ops, [2 * l], [False]); var += 1
        a = VariableInfo(var, act_bytes, 2 * l, 0, [2 * l + 1], [True]); var += 1
        vs.append(w); fwd_w.append(w)
        vs.append(a); fwd_a.append(a)
    for l in reversed(range(n_layers)):
        bwd_idx = 2 * n_layers + 2 * (n_layers - 1 - l) + 1
        fwd_w[l].accesses.append(bwd_idx)
        fwd_w[l].access_is_write.append(False)
        fwd_a[l].accesses.append(bwd_idx)
        fwd_a[l].access_is_write.append(False)
        fwd_a[l].free_index = bwd_idx + 1
    tr = IterationTrace(vs, n_ops)
    tr.op_costs = {i: (1e9, 1e6) for i in range(n_ops)}
    return tr


# --------------------------------------------------------------- reference
# Frozen copy of the pre-runtime ``simulate_swap_schedule`` event loop, now
# shared with benchmarks/bench_churn.py via core/_solver_reference.py.
_reference_simulate = reference_simulate_swap_schedule


FIELDS = ("baseline_s", "duration_s", "peak_resident", "stalls",
          "delayed_mallocs", "tail_spill_s", "out_events", "in_events")


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 12), st.floats(0.45, 0.95))
def test_engine_matches_reference_simulator_exactly(n_layers, frac):
    tr = synth_trace(n_layers=n_layers)
    pl = AutoSwapPlanner(tr, HW, size_threshold=1 << 20)
    limit = int(pl.peak_load * frac)
    dec = pl.select(limit, "swdoa")
    ref = _reference_simulate(tr, dec, HW, limit)
    got = simulate_swap_schedule(tr, dec, HW, limit)
    for f in FIELDS:
        assert getattr(got, f) == getattr(ref, f), f


def test_engine_matches_reference_no_limit_no_decisions():
    tr = synth_trace()
    ref = _reference_simulate(tr, [], HW, None)
    got = simulate_swap_schedule(tr, [], HW, None)
    for f in FIELDS:
        assert getattr(got, f) == getattr(ref, f), f


# --------------------------------------------------------- channel scaling
@settings(max_examples=25, deadline=None)
@given(st.integers(2, 12), st.floats(0.45, 0.95), st.sampled_from(["swdoa", "aoa"]))
def test_property_more_channels_never_increase_overhead(n_layers, frac, scorer):
    """2 DMA channels never simulate *higher* overhead than 1, nor 4 than 2."""
    tr = synth_trace(n_layers=n_layers)
    pl = AutoSwapPlanner(tr, HW, size_threshold=1 << 20)
    limit = int(pl.peak_load * frac)
    dec = pl.select(limit, scorer)
    o1 = simulate_program(tr, dec, HW, limit, channels=1).overhead
    o2 = simulate_program(tr, dec, HW, limit, channels=2).overhead
    o4 = simulate_program(tr, dec, HW, limit, channels=4).overhead
    assert o2 <= o1 + 1e-12
    assert o4 <= o2 + 1e-12


def test_channel_pool_direction_partition():
    one = ChannelPool.make(1)
    assert one.out_ids == one.in_ids == (0,)
    two = ChannelPool.make(2)
    assert two.out_ids == (0,) and two.in_ids == (1,)
    five = ChannelPool.make(5)
    assert set(five.out_ids) | set(five.in_ids) == set(range(5))
    assert not set(five.out_ids) & set(five.in_ids)


def test_channels_are_serialized_and_direction_partitioned():
    """No two transfers overlap on one channel; outs/ins stay on their side."""
    tenants = []
    for name, layers, frac in (("A", 8, 0.6), ("B", 6, 0.6)):
        tr = synth_trace(layers)
        pl = AutoSwapPlanner(tr, HW, size_threshold=1 << 20)
        lim = int(pl.peak_load * frac)
        tenants.append(Tenant(name, tr, pl.select(lim, "swdoa"), limit=lim))
    budget = sum(t.limit for t in tenants)
    rt = MemoryRuntime(HW, budget=budget, channels=4)
    rt.run(tenants)
    per_channel = {}
    for run in rt.runs.values():
        for var, s, e, ch in run.out_events:
            assert ch in rt.channels.out_ids
            per_channel.setdefault(ch, []).append((s, e))
        for var, s, e, ch in run.in_events:
            assert ch in rt.channels.in_ids
            per_channel.setdefault(ch, []).append((s, e))
    assert per_channel, "expected swap traffic"
    for ch, spans in per_channel.items():
        spans.sort()
        for (s0, e0), (s1, e1) in zip(spans, spans[1:]):
            assert s1 >= e0 - 1e-12, f"channel {ch} transfers overlap"


# ------------------------------------------------------ multi-tenant budget
def test_colocated_tenants_never_exceed_budget():
    tenants = []
    for name, layers in (("A", 8), ("B", 6), ("C", 4)):
        tr = synth_trace(layers)
        pl = AutoSwapPlanner(tr, HW, size_threshold=1 << 20)
        lim = int(pl.peak_load * 0.7)
        tenants.append(Tenant(name, tr, pl.select(lim, "swdoa"), limit=lim))
    budget = sum(planned_peak(t.trace, t.decisions) for t in tenants)
    rt = MemoryRuntime(HW, budget=budget, channels=2)
    rep = rt.run(tenants)
    assert all(t.status == "completed" for t in rep.tenants)
    assert rep.overflow_events == 0
    assert rep.aggregate_peak <= budget


def test_two_in_channels_do_not_double_admit():
    """Two prefetches due together on two in-channels, headroom for one:
    schedule-time reservation must keep the second out until room appears."""
    MB = 1 << 20
    n_ops = 8
    vs = [
        VariableInfo(0, 1 * MB, 0, n_ops, [0], [True]),              # D: always resident
        VariableInfo(1, 10 * MB, 0, n_ops, [1, 6], [True, False]),   # A
        VariableInfo(2, 10 * MB, 2, n_ops, [3, 6], [True, False]),   # B
    ]
    tr = IterationTrace(vs, n_ops)
    tr.op_costs = {i: (1e9, 0.0) for i in range(n_ops)}
    dec = [SwapDecision(1, 10 * MB, 1, 6), SwapDecision(2, 10 * MB, 3, 6)]
    budget = 21 * MB  # D + both swapped vars: feasible at the deadline only
    rt = MemoryRuntime(HW, budget=budget, channels=4)  # 2 out + 2 in channels
    rep = rt.run([Tenant("t", tr, dec, floor=0)])
    assert rep.overflow_events == 0
    assert rep.aggregate_peak <= budget
    ins = sorted(rt.runs["t"].in_events, key=lambda e: e[1])
    assert len(ins) == 2
    # Despite two free in-channels the transfers must be staggered: the
    # second may only start once the first tenant byte count leaves room
    # (here: after B's own swap-out retires).
    assert ins[1][1] >= ins[0][1] + 1e-12


def test_admission_queues_third_tenant_and_runs_it_later():
    tr = synth_trace(8)
    pl = AutoSwapPlanner(tr, HW, size_threshold=1 << 20)
    lim = int(pl.peak_load * 0.7)
    dec = pl.select(lim, "swdoa")
    floor = planned_peak(tr, dec)
    tenants = [Tenant(f"T{i}", synth_trace(8), list(dec), limit=lim) for i in range(3)]
    budget = int(floor * 2.5)  # fits two floors, not three
    rep = MemoryRuntime(HW, budget=budget, channels=2).run(tenants)
    assert [t.status for t in rep.tenants] == ["completed"] * 3
    waits = [t.queue_wait_s for t in rep.tenants]
    assert waits[0] == 0.0 and waits[1] == 0.0
    assert waits[2] > 0.0, "third tenant should queue for admission"
    t2 = rep.tenant("T2")
    assert t2.admitted_at >= min(rep.tenant("T0").finished_at, rep.tenant("T1").finished_at) - 1e-12


def test_finished_tenants_release_residency_to_later_admissions():
    """Sequential admission: a finished tenant's persistent bytes (freed at
    delta[num_indices], which the op loop never applies) must leave the
    shared accountant, or every later tenant runs in a shrunken budget."""
    tr = synth_trace(8)
    pl = AutoSwapPlanner(tr, HW, size_threshold=1 << 20)
    lim = int(pl.peak_load * 0.7)
    dec = pl.select(lim, "swdoa")
    floor = planned_peak(tr, dec)
    tenants = [Tenant(f"T{i}", synth_trace(8), list(dec), limit=lim) for i in range(4)]
    budget = 2 * floor  # two at a time; T2/T3 admitted after T0/T1 finish
    rep = MemoryRuntime(HW, budget=budget, channels=2).run(tenants)
    assert [t.status for t in rep.tenants] == ["completed"] * 4
    assert rep.overflow_events == 0
    assert rep.aggregate_peak <= budget
    # Later-admitted tenants see the same effective budget: their overhead
    # stays in the same band as the first wave's (channel-contention phase
    # differences aside).  Before the residency-release fix they ran inside
    # a budget shrunken by the finishers' dead bytes (26%+ overhead vs 4%).
    oh = [t.overhead for t in rep.tenants]
    assert max(oh[2], oh[3]) <= max(oh[0], oh[1]) + 0.02


def test_duplicate_tenant_names_rejected():
    """Accounting is keyed by tenant name; two tenants sharing one would
    silently merge residency (and release_residency would free the survivor's
    bytes), so the engine refuses up front."""
    tr = synth_trace(4)
    with pytest.raises(ValueError, match="unique"):
        MemoryRuntime(HW, channels=2).run([Tenant("t", tr), Tenant("t", synth_trace(4))])


def test_unschedulable_tenant_reported_not_killed():
    big = synth_trace(12)
    small = synth_trace(2)
    pl = AutoSwapPlanner(small, HW, size_threshold=1 << 20)
    lim = int(pl.peak_load * 0.8)
    tenants = [
        Tenant("big", big, [], limit=None),           # floor == full peak
        Tenant("small", small, pl.select(lim, "swdoa"), limit=lim),
    ]
    budget = planned_peak(small, tenants[1].decisions)
    rep = MemoryRuntime(HW, budget=budget, channels=2).run(tenants)
    assert rep.tenant("big").status == "unschedulable"
    assert rep.tenant("small").status == "completed"


def test_multi_iteration_tenant_accumulates_duration():
    tr = synth_trace(4)
    one = MemoryRuntime(HW, channels=2).run([Tenant("t", tr, iterations=1)])
    two = MemoryRuntime(HW, channels=2).run([Tenant("t", tr, iterations=2)])
    d1, d2 = one.tenant("t").duration_s, two.tenant("t").duration_s
    assert d2 == pytest.approx(2 * d1, rel=1e-9)
    assert two.aggregate_peak == one.aggregate_peak


# -------------------------------------------------------- plan integration
def test_tenant_from_program_uses_cached_schedule(tmp_path):
    tr = synth_trace(6)
    key = PlanKey("synthetic", "runtime-unit", HW.name)
    prog = MemoryProgram.from_trace(tr, key)
    pl = AutoSwapPlanner(tr, HW, size_threshold=1 << 20)
    limit = int(pl.peak_load * 0.7)
    cache = PlanCache(tmp_path)
    tenant = tenant_from_program("t", prog, HW, limit, cache=cache)
    assert tenant.decisions, "expected a non-empty schedule at 70% limit"
    assert cache.load(key) is not None, "schedule should persist to the cache"
    # A second build from the restored artifact reuses the stored decisions.
    restored = cache.load(key)
    tenant2 = tenant_from_program("t", restored, HW, limit, cache=cache)
    assert tenant2.decisions == tenant.decisions
    assert restored.swap_summaries[swap_key("swdoa", limit)].decisions == tenant.decisions


def test_colocate_programs_shares_budget_below_isolated_sum():
    progs = {
        "a": MemoryProgram.from_trace(synth_trace(8)),
        "b": MemoryProgram.from_trace(synth_trace(6)),
    }
    result = colocate_programs(progs, HW, budget_frac=0.75, channels=2,
                               size_threshold=1 << 20)
    rep = result.report
    assert all(t.status == "completed" for t in rep.tenants)
    assert rep.aggregate_peak <= result.budget
    assert rep.aggregate_peak < result.sum_natural_peaks
    assert 0.0 < result.sharing_gain < 1.0


def test_planned_peak_subtracts_absence_windows():
    MB = 1 << 20
    vs = [
        VariableInfo(0, 4 * MB, 0, 10, [1, 8], [True, False]),
        VariableInfo(1, 2 * MB, 0, 10, [0], [True]),
    ]
    tr = IterationTrace(vs, 10)
    assert planned_peak(tr, []) == 6 * MB
    assert planned_peak(tr, [SwapDecision(0, 4 * MB, 1, 8)]) == 6 * MB  # ends outside window
    # inside the absence window only var 1 remains
    curve_peak = planned_peak(tr, [SwapDecision(0, 4 * MB, 0, 10)])
    assert curve_peak == 2 * MB
