"""repro.analyze: static plan verifier + event-log race detector.

Two halves:

  * Clean-pass (zero false positives): pipeline-solved programs, the
    committed example traces, and a synthetic clean schedule all certify
    with every invariant green.
  * Mutation kill (the ISSUE's acceptance oracle): take a valid plan or
    event log, inject exactly one hazard per detector class, and assert
    exactly that detector fires — so every detector is proven live and
    every clean verdict is proven discriminating.
"""

import dataclasses
from pathlib import Path

from repro.testing import given, settings, st  # hypothesis or deterministic fallback

from repro.analyze import (
    Certificate,
    ScheduleView,
    Violation,
    check_view,
    verify_pool_plan,
    verify_program,
    verify_swap_summary,
    verify_trace_file,
)
from repro.analyze.plan_check import ALL_INVARIANTS
from repro.analyze.schedule_check import SCHEDULE_INVARIANTS, Transfer
from repro.core.events import IterationTrace, VariableInfo
from repro.core.simulator import HardwareSpec, SwapDecision
from repro.core.smartpool import AllocationPlan
from repro.plan import (
    MemoryProgram,
    PassContext,
    Pipeline,
    PlanKey,
    PoolPlacement,
    SwapSelection,
    SwapSummary,
    TimingAssign,
)

HW = HardwareSpec("test", peak_flops=1e12, hbm_bw=1e12, link_bw=1e10, efficiency=1.0)
REPO = Path(__file__).resolve().parent.parent
MiB = 1 << 20


def make_trace(intervals):
    """intervals: (size, alloc, free); one write at alloc, one read before free."""
    vs = [
        VariableInfo(i, s, a, f, accesses=[a, max(a, f - 1)],
                     access_is_write=[True, False])
        for i, (s, a, f) in enumerate(intervals)
    ]
    end = max(f for _, _, f in intervals)
    tr = IterationTrace(vs, end)
    tr.op_costs = {i: (1e9, 1e6) for i in range(end)}
    return tr


def solved_program(limit_frac=0.8):
    tr = make_trace([
        (4 * MiB, 0, 3), (2 * MiB, 1, 6), (8 * MiB, 2, 9),
        (1 * MiB, 4, 8), (4 * MiB, 5, 10), (2 * MiB, 7, 10),
    ])
    ctx = PassContext(hw=HW, size_threshold=1 * MiB)
    return Pipeline([
        TimingAssign(),
        PoolPlacement(("best_fit", "first_fit")),
        SwapSelection(limit=int(tr.peak_load() * limit_frac), scorer="swdoa"),
    ]).run(MemoryProgram.from_trace(tr, PlanKey("synthetic", "unit", HW.name)), ctx)


def failing(violations):
    return sorted({v.invariant for v in violations})


# ---------------------------------------------------------------- clean pass
def test_solved_program_certifies_clean():
    cert = verify_program(solved_program())
    assert cert.ok
    assert set(cert.checks) == set(ALL_INVARIANTS)
    assert all(c["violations"] == [] for c in cert.checks.values())
    # pools and one swap summary actually swept, not vacuous
    assert cert.checks["pool_disjoint_lifetimes"]["subjects"] == 2
    assert cert.checks["swap_budget"]["subjects"] == 1


@settings(max_examples=12, deadline=None)
@given(st.integers(min_value=50, max_value=95))
def test_solved_program_certifies_clean_across_limits(pct):
    assert verify_program(solved_program(limit_frac=pct / 100)).ok


def test_committed_example_traces_certify_clean():
    for name in ("churn", "mesh_data4"):
        cert = verify_trace_file(str(REPO / "examples" / "traces" / f"{name}.trace.json"))
        assert cert.ok, cert.failed()
        assert set(cert.checks) == set(SCHEDULE_INVARIANTS)


def test_certificate_round_trip():
    cert = verify_program(solved_program())
    back = Certificate.from_dict(cert.to_dict())
    assert back.ok and back.to_dict() == cert.to_dict()
    cert.add("pool_bounds", 1, [Violation("pool_bounds", "pool:x", "boom")])
    assert not cert.ok and cert.failed() == ["pool_bounds"]


# ------------------------------------------------- plan mutations: pool side
def overlapping_pair(trace, plan):
    vs = [v for v in trace.variables if v.size > 0 and v.var in plan.offsets]
    for a in vs:
        for b in vs:
            if a.var < b.var and a.overlaps(b):
                return a, b
    raise AssertionError("fixture needs two lifetime-overlapping variables")


def test_mutation_overlapping_placements_kills_pool_disjoint():
    prog = solved_program()
    trace = prog.require_trace()
    plan = prog.pool_plans["best_fit"]
    a, b = overlapping_pair(trace, plan)
    plan.offsets[b.var] = plan.offsets[a.var]          # collide two live ranges
    plan.lookup[b.alloc_index] = plan.offsets[a.var]   # keep lookup consistent
    assert failing(verify_pool_plan(trace, plan)) == ["pool_disjoint_lifetimes"]


def test_mutation_offset_past_footprint_kills_pool_bounds():
    prog = solved_program()
    trace = prog.require_trace()
    plan = prog.pool_plans["best_fit"]
    v = max(trace.variables, key=lambda v: v.var)
    plan.offsets[v.var] = plan.footprint + 4096
    plan.lookup[v.alloc_index] = plan.offsets[v.var]
    assert failing(verify_pool_plan(trace, plan)) == ["pool_bounds"]


def test_mutation_stale_lookup_kills_pool_lookup():
    prog = solved_program()
    trace = prog.require_trace()
    plan = prog.pool_plans["best_fit"]
    v = trace.variables[0]
    plan.lookup[v.alloc_index] = plan.offsets[v.var] + 256
    assert failing(verify_pool_plan(trace, plan)) == ["pool_lookup"]


# ------------------------------------------------- plan mutations: swap side
def swap_fixture():
    """One variable with a write, then two reads; one valid absence window
    between the write and the first read.  The filler variable's lifetime
    [3, 5) creates the 8 MiB peak *inside* that window, so absenting v0
    brings the resident floor down to 4 MiB — the floor the schedule
    commits to via ``planned_floor``."""
    v = VariableInfo(0, 4 * MiB, 2, 11, accesses=[2, 6, 10],
                     access_is_write=[True, False, False])
    filler = VariableInfo(1, 4 * MiB, 3, 5, accesses=[3, 4],
                          access_is_write=[True, False])
    tr = IterationTrace([v, filler], 12)
    tr.op_costs = {i: (1e9, 1e6) for i in range(12)}
    d = SwapDecision(var=0, size=4 * MiB, out_after=2, in_before=6)
    summary = SwapSummary(
        scorer="swdoa", limit=5 * MiB, decisions=[d],
        peak_load=8 * MiB, load_min=4 * MiB, overhead=0.0, stalls=0,
        planned_floor=4 * MiB,
    )
    return tr, summary


def test_swap_fixture_is_clean():
    tr, summary = swap_fixture()
    assert verify_swap_summary(tr, summary) == []


def test_mutation_in_before_past_read_kills_read_hazard():
    tr, summary = swap_fixture()
    summary.decisions[0] = dataclasses.replace(summary.decisions[0], in_before=10)
    assert failing(verify_swap_summary(tr, summary)) == ["swap_in_before_read"]


def test_mutation_out_before_last_write_kills_write_hazard():
    tr, summary = swap_fixture()
    v = tr.variables[0]
    v.access_is_write[1] = True   # op 6 becomes the last write
    summary.decisions[0] = dataclasses.replace(
        summary.decisions[0], out_after=2, in_before=10
    )
    assert failing(verify_swap_summary(tr, summary)) == ["swap_out_after_write"]


def test_mutation_double_decision_kills_single_residency():
    tr, summary = swap_fixture()
    summary.decisions.append(
        dataclasses.replace(summary.decisions[0], out_after=6, in_before=10)
    )
    assert failing(verify_swap_summary(tr, summary)) == ["swap_single_residency"]


def test_mutation_inverted_window_kills_well_formed():
    tr, summary = swap_fixture()
    summary.decisions[0] = dataclasses.replace(
        summary.decisions[0], out_after=6, in_before=2
    )
    assert failing(verify_swap_summary(tr, summary)) == ["swap_well_formed"]


def test_mutation_dropped_decision_kills_budget():
    tr, summary = swap_fixture()
    summary.decisions.clear()      # floor returns to the full 8 MiB peak
    assert failing(verify_swap_summary(tr, summary)) == ["swap_budget"]


def test_infeasible_limit_makes_budget_vacuous():
    # Legacy summary (no committed floor) at a limit the candidate set
    # provably cannot reach: the budget obligation is vacuous.
    tr, summary = swap_fixture()
    summary.planned_floor = None
    summary.decisions.clear()
    summary.limit = 2 * MiB        # < load_min: recorded-infeasible schedule
    assert verify_swap_summary(tr, summary) == []


def test_legacy_summary_over_feasible_limit_kills_budget():
    # Without a committed floor the verifier falls back to floor <= limit
    # whenever the limit was feasible (limit >= load_min).
    tr, summary = swap_fixture()
    summary.planned_floor = None
    summary.decisions.clear()      # floor returns to the full 8 MiB peak
    assert failing(verify_swap_summary(tr, summary)) == ["swap_budget"]


def test_best_effort_floor_above_limit_is_clean():
    # Greedy selection is best-effort: a committed floor above the limit is
    # a legitimate solver outcome as long as the decisions reproduce it.
    tr, summary = swap_fixture()
    summary.limit = 3 * MiB        # below the committed 4 MiB floor
    assert verify_swap_summary(tr, summary) == []


# -------------------------------------------------------- schedule mutations
def clean_view():
    """Two tenants, one device, serialized transfers, consistent ledgers."""
    report = {
        "budget": 10 * MiB,
        "overflow_events": 0,
        "aggregate_peak": 9 * MiB,
        "tenants": [
            {"name": "a", "status": "completed", "device": None,
             "floor": 4 * MiB, "renegotiation_freed_bytes": 0,
             "attribution": {"overhead_s": 0.5, "swap_in_transfer_s": 0.3,
                             "residual_s": 0.2, "queue_wait_s": 0.1}},
            {"name": "b", "status": "completed", "device": None,
             "floor": 5 * MiB, "renegotiation_freed_bytes": 0,
             "attribution": {"overhead_s": 0.1, "swap_in_transfer_s": 0.1,
                             "residual_s": 0.0, "queue_wait_s": 0.0}},
        ],
        "attribution": {"overhead_s": 0.6, "swap_in_transfer_s": 0.4,
                        "residual_s": 0.2, "queue_wait_s": 0.1},
    }
    view = ScheduleView(source="unit", report=report)
    view.transfers = [
        Transfer("a", "default", "out", 0, 1.0, 2.0, 0, lane=0, ready=1.0, size=MiB),
        Transfer("a", "default", "in", 0, 4.0, 5.0, 0, lane=0, ready=3.5, size=MiB),
        Transfer("b", "default", "out", 1, 2.5, 3.5, 1, lane=1, ready=2.5, size=MiB),
    ]
    view.blackouts = [(2.1, 2.4)]
    view.admissions = [("a", "default", 0.0, 0.0), ("b", "default", 0.0, 0.1)]
    view.finishes = [("a", "default", 6.0), ("b", "default", 7.0)]
    view.hbm_samples = {"default": [3 * MiB, 9 * MiB, 5 * MiB]}
    return view


def test_clean_view_certifies():
    cert = check_view(clean_view())
    assert cert.ok, cert.failed()
    assert set(cert.checks) == set(SCHEDULE_INVARIANTS)


def test_mutation_channel_overlap_kills_channel_exclusive():
    view = clean_view()
    t = view.transfers[1]
    view.transfers.append(dataclasses.replace(t, var=7, lane=None,
                                              start=t.start + 0.2, end=t.end + 0.2))
    cert = check_view(view)
    assert cert.failed() == ["channel_exclusive"]


def test_mutation_lane_overlap_kills_lane_exclusive():
    view = clean_view()
    t = view.transfers[2]
    view.transfers.append(dataclasses.replace(t, var=8, channel=None,
                                              start=t.start + 0.2, end=t.end + 0.2))
    cert = check_view(view)
    assert cert.failed() == ["lane_exclusive"]


def test_mutation_transfer_into_known_blackout_kills_blackout_exclusion():
    view = clean_view()
    # The blackout was registered (start 2.8) before this out transfer
    # acquired its lane (ready 3.0), yet the transfer [4.0, 5.5) crosses it:
    # the scheduler must have skipped the exclusion window.
    view.transfers.append(
        Transfer("b", "default", "out", 9, 4.0, 5.5, 1, lane=1, ready=3.0, size=MiB)
    )
    view.blackouts.append((2.8, 4.6))
    cert = check_view(view)
    assert "blackout_exclusion" in cert.failed()


def test_blackout_after_acquisition_is_legitimate():
    view = clean_view()
    # same overlap, but the blackout starts after ready: registered later
    view.transfers.append(
        Transfer("b", "default", "out", 9, 4.0, 5.5, 1, lane=1, ready=3.0, size=MiB)
    )
    view.blackouts.append((4.5, 5.0))
    view.transfers[-1] = dataclasses.replace(view.transfers[-1], ready=4.0)
    assert check_view(view).ok


def test_mutation_overbudget_sample_kills_budget_monotone():
    view = clean_view()
    view.hbm_samples["default"].append(11 * MiB)
    cert = check_view(view)
    assert cert.failed() == ["budget_monotone"]


def test_mutation_double_admit_kills_reservation_isolation():
    view = clean_view()
    view.admissions.append(("a", "default", 0.0, 0.2))
    cert = check_view(view)
    assert cert.failed() == ["reservation_isolation"]


def test_mutation_floor_oversubscription_kills_reservation_isolation():
    view = clean_view()
    view.report["tenants"][1]["floor"] = 7 * MiB   # 4 + 7 > 10 MiB budget
    cert = check_view(view)
    assert cert.failed() == ["reservation_isolation"]


def test_mutation_leaky_ledger_kills_ledger_closure():
    view = clean_view()
    view.report["tenants"][0]["attribution"]["swap_in_transfer_s"] = 0.4
    cert = check_view(view)
    assert "ledger_closure" in cert.failed()


@settings(max_examples=12, deadline=None)
@given(st.integers(min_value=0, max_value=2), st.floats(min_value=0.05, max_value=0.8))
def test_mutation_any_channel_shift_is_caught_or_harmless(idx, shift):
    """Property form: shifting one transfer's start earlier either keeps the
    schedule exclusive (no overlap created) or trips exactly the
    channel/lane detectors — never a silent pass with an overlap present."""
    view = clean_view()
    t = view.transfers[idx]
    moved = dataclasses.replace(t, start=t.start - shift, ready=None)
    view.transfers[idx] = moved
    overlap = any(
        o is not moved and o.channel == moved.channel
        and moved.start < o.end and o.start < moved.end
        for o in view.transfers
    )
    cert = check_view(view)
    if overlap:
        assert not cert.ok
        assert set(cert.failed()) <= {"channel_exclusive", "lane_exclusive"}
    else:
        assert cert.ok


# ------------------------------------------------------------- CLI classifier
def test_analyze_cli_classifies_plan_and_trace(tmp_path):
    import json

    from repro.launch.analyze import main as analyze_main
    from repro.plan.artifact import program_to_json

    plan_path = tmp_path / "plan.json"
    plan_path.write_text(json.dumps(program_to_json(solved_program())))
    trace_path = REPO / "examples" / "traces" / "mesh_data4.trace.json"
    assert analyze_main(["-q", str(plan_path), str(trace_path)]) == 0
    assert analyze_main([str(tmp_path / "missing.json")]) == 1
