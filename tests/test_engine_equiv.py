"""Bit-for-bit equivalence of the vectorized engine vs the frozen reference.

PR 6 rewrote ``runtime/engine.py``'s hot paths onto precomputed structures
(prefetch index, pending-out heap, bisected collective windows, event
frontier, per-decision due constants).  ``runtime/_engine_reference.py`` is
the pre-vectorization engine, frozen verbatim; every simulated quantity the
two produce must be *identical* — not approximately equal — across channel
counts, budgets, seeded churn workloads, renegotiation on/off, and mesh
shapes with a contended HostLink.  The same pinning discipline PR 3 applied
to the solvers (tests/test_solver_equiv.py).
"""

from __future__ import annotations

import json

import pytest

from repro.core.planner import AutoSwapPlanner
from repro.core.simulator import GTX_1080TI
from repro.runtime import _engine_reference as ref
from repro.runtime import engine as fast
from repro.runtime.engine import planned_peak, simulated_report_dict
from repro.runtime.workload import poisson_workload, synthetic_train_trace
from repro.testing import given, settings, st

HW = GTX_1080TI
SIZE_THRESHOLD = 1 << 20


def solve(trace, frac=0.7, scorer="swdoa"):
    pl = AutoSwapPlanner(trace, HW, size_threshold=SIZE_THRESHOLD)
    limit = int(pl.peak_load * frac)
    return limit, pl.select(limit, scorer)


# Templates and plans are immutable once solved: build them once.
TEMPLATES = {
    "small": synthetic_train_trace(4),
    "medium": synthetic_train_trace(6),
    "base": synthetic_train_trace(10),
}
PLANS = {name: solve(tr) for name, tr in TEMPLATES.items()}
FLOORS = {n: planned_peak(TEMPLATES[n], PLANS[n][1]) for n in TEMPLATES}
# A medium newcomer doesn't fit next to the base's full floor; a small one
# does — the budget that exercises queueing AND renegotiation.
BUDGET = FLOORS["base"] + (FLOORS["small"] + FLOORS["medium"]) // 2


def canon(report) -> str:
    """Reports reduced to simulated quantities, as a comparable string.

    ``simulated_report_dict`` strips wall-clock counters (engine throughput,
    renegotiation solve ms) and the per-tenant event counts the reference
    engine doesn't track; it accepts reports from either engine.
    """
    return json.dumps(simulated_report_dict(report), sort_keys=True)


def churn_tenants(mod, items, base_iters=6):
    ts = [
        mod.Tenant(
            "base", TEMPLATES["base"], list(PLANS["base"][1]),
            limit=PLANS["base"][0], iterations=base_iters, priority=0.5,
        )
    ]
    for it in items:
        limit, decisions = PLANS[it.template]
        ts.append(
            mod.Tenant(
                it.name, TEMPLATES[it.template], list(decisions), limit=limit,
                iterations=it.iterations, arrival_t=it.arrival_t,
                priority=it.priority,
            )
        )
    return ts


def run_both(make_tenants, **kw):
    """One run per engine with identical config; returns (fast, reference)
    MemoryRuntime instances with their reports attached as ``.report``."""
    out = []
    for mod in (fast, ref):
        rt = mod.MemoryRuntime(
            HW,
            budget=kw.get("budget"),
            channels=kw.get("channels", 2),
            prefetch=kw.get("prefetch", "backsched"),
            renegotiate=kw.get("renegotiate", False),
            replan_size_threshold=SIZE_THRESHOLD,
            link=mod.HostLink.make(*kw["link"]) if kw.get("link") else None,
            contention_aware=kw.get("contention_aware", True),
        )
        rt.report = rt.run(make_tenants(mod))
        out.append(rt)
    return out


# ------------------------------------------------------------- single tenant
@pytest.mark.parametrize("channels", [1, 2, 3, 4])
@pytest.mark.parametrize("prefetch", ["eager", "backsched"])
def test_single_tenant_facade_bit_for_bit(channels, prefetch):
    trace = TEMPLATES["medium"]
    limit, decisions = PLANS["medium"]
    got = fast.simulate_program(trace, decisions, HW, limit,
                                channels=channels, prefetch=prefetch)
    want = ref.simulate_program(trace, decisions, HW, limit,
                                channels=channels, prefetch=prefetch)
    assert got == want


def test_core_simulator_facade_unchanged():
    from repro.core.simulator import simulate_swap_schedule

    trace = TEMPLATES["small"]
    limit, decisions = PLANS["small"]
    got = simulate_swap_schedule(trace, decisions, HW, limit)
    want = ref.simulate_program(trace, decisions, HW, limit,
                                channels=2, prefetch="eager")
    assert got == want


# ------------------------------------------------------------ churn property
@settings(max_examples=12, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=10_000),
    channels=st.sampled_from([1, 2, 3]),
    renegotiate=st.sampled_from([False, True]),
    budget_kind=st.sampled_from(["tight", "roomy", "none"]),
)
def test_churn_workloads_bit_for_bit(seed, channels, renegotiate, budget_kind):
    budget = {"tight": BUDGET, "roomy": BUDGET * 4, "none": None}[budget_kind]
    if budget is None and renegotiate:
        renegotiate = False  # renegotiation needs a budget to defend
    items = poisson_workload(
        ["small", "medium"], 6, 50.0, seed=seed, iterations=(1, 3)
    )
    frt, rrt = run_both(
        lambda mod: churn_tenants(mod, items),
        budget=budget, channels=channels, renegotiate=renegotiate,
    )
    assert canon(frt.report) == canon(rrt.report)


def test_eager_prefetch_multi_tenant_bit_for_bit():
    items = poisson_workload(["small", "medium"], 6, 50.0, seed=3, iterations=(1, 3))
    frt, rrt = run_both(
        lambda mod: churn_tenants(mod, items), budget=BUDGET, prefetch="eager"
    )
    assert canon(frt.report) == canon(rrt.report)


def test_departure_churn_bit_for_bit():
    def mk(mod):
        ts = churn_tenants(mod, poisson_workload(
            ["small", "medium"], 4, 80.0, seed=5, iterations=(1, 2)))
        ts[0].departure_t = 0.08  # open-ended base departs mid-horizon
        ts[0].iterations = 1
        return ts

    frt, rrt = run_both(mk, budget=BUDGET, renegotiate=True)
    assert canon(frt.report) == canon(rrt.report)


# --------------------------------------------------------------------- mesh
def mesh_tenants(mod, devices=4):
    """A data-parallel mesh shape built directly from Tenants (no jax):
    one tenant per device, tagged collectives, first device owns blackouts."""
    ts = []
    for i in range(devices):
        name = "small" if i % 2 else "medium"
        trace = TEMPLATES[name]
        limit, decisions = PLANS[name]
        colls = {2: 0.004, trace.num_indices - 2: 0.006}
        ts.append(
            mod.Tenant(
                f"shard{i}", trace, list(decisions), limit=limit,
                iterations=3, device=f"d{i}", collectives=colls,
                collective_owner=(i == 0),
            )
        )
    return ts


@pytest.mark.parametrize("devices", [1, 4])
@pytest.mark.parametrize("lanes", [1, 2])
@pytest.mark.parametrize("contention_aware", [True, False])
def test_mesh_contended_link_bit_for_bit(devices, lanes, contention_aware):
    frt, rrt = run_both(
        lambda mod: mesh_tenants(mod, devices),
        link=(HW.link_bw, lanes), contention_aware=contention_aware,
    )
    assert canon(frt.report) == canon(rrt.report)
    # The per-transfer schedules (what repro.dist compares) match too.
    for name in frt.runs:
        assert frt.runs[name].out_events == rrt.runs[name].out_events
        assert frt.runs[name].in_events == rrt.runs[name].in_events


def test_mesh_budgeted_bit_for_bit():
    frt, rrt = run_both(
        lambda mod: mesh_tenants(mod, 4),
        budget=max(FLOORS.values()) * 2, link=(HW.link_bw, 2),
    )
    assert canon(frt.report) == canon(rrt.report)


def mesh_churn_tenants(mod, newcomer_arrival=0.02, devices=4):
    """The data=4 contended mesh plus a late newcomer on shard0's device —
    the shape where renegotiation, collectives, and the shared link all
    interact in one run."""
    ts = mesh_tenants(mod, devices)
    limit, decisions = PLANS["small"]
    ts.append(
        mod.Tenant(
            "late", TEMPLATES["small"], list(decisions), limit=limit,
            iterations=1, device="d0", arrival_t=newcomer_arrival,
            priority=2.0,
        )
    )
    return ts


@pytest.mark.parametrize("newcomer_arrival", [0.005, 0.02])
def test_mesh_resume_contended_data4_byte_identical(newcomer_arrival):
    """resume() coverage on a contended data=4 mesh: a newcomer on d0 forces
    a renegotiation barrier while all four shards contend on the HostLink
    (collective blackouts included) — the suffix replay must still be byte
    identical to the full horizon, and the full horizon to the reference."""
    budget = FLOORS["medium"] + FLOORS["small"] // 2
    frt, rrt = run_both(
        lambda mod: mesh_churn_tenants(mod, newcomer_arrival),
        budget=budget, renegotiate=True, link=(HW.link_bw, 2),
    )
    full = canon(frt.report)
    assert full == canon(rrt.report)
    capturing = fast.MemoryRuntime(
        HW, budget=budget, channels=2, renegotiate=True,
        replan_size_threshold=SIZE_THRESHOLD, capture_snapshots=True,
        link=fast.HostLink.make(HW.link_bw, 2))
    assert canon(capturing.run(mesh_churn_tenants(fast, newcomer_arrival))) == full
    assert frt.report.renegotiations >= 1, "shape must exercise renegotiation"
    assert capturing.barrier_snapshots, "no barrier snapshot captured"
    for snap in capturing.barrier_snapshots:
        assert canon(snap.resume()) == full


# ------------------------------------------------------- engine-only features
def test_record_events_off_same_simulated_report():
    items = poisson_workload(["small", "medium"], 6, 50.0, seed=9, iterations=(1, 3))
    on = fast.MemoryRuntime(HW, budget=BUDGET, channels=2, record_events=True)
    r_on = on.run(churn_tenants(fast, items))
    off = fast.MemoryRuntime(HW, budget=BUDGET, channels=2, record_events=False)
    r_off = off.run(churn_tenants(fast, items))
    assert canon(r_on) == canon(r_off)
    assert all(not r.out_events and not r.in_events for r in off.runs.values())
    assert any(r.out_events or r.in_events for r in on.runs.values())
    # Tail spill is derived from out events; it must survive the gating.
    for name in on.runs:
        assert on.runs[name].sim_result().tail_spill_s == \
            off.runs[name].sim_result().tail_spill_s


def test_engine_counters_in_report():
    items = poisson_workload(["small", "medium"], 4, 50.0, seed=1, iterations=(1, 2))
    rt = fast.MemoryRuntime(HW, budget=BUDGET, channels=2)
    rep = rt.run(churn_tenants(fast, items))
    d = rep.as_dict()
    assert d["engine"]["events"] > 0
    assert d["engine"]["run_wall_s"] > 0
    assert d["engine"]["events_per_s"] > 0
    assert sum(t["events"] for t in d["tenants"]) == d["engine"]["events"]
    # The reference engine reports no engine block — and the canonical
    # simulated view strips it from both, so the dicts stay comparable.
    assert "engine" not in simulated_report_dict(rep)


def test_suffix_replay_byte_identical():
    """resume() on a barrier snapshot must reproduce the full-horizon report
    byte for byte — and capturing snapshots must not change the run."""
    replayed = 0
    for seed in range(6):
        items = poisson_workload(
            ["small", "medium"], 6, 50.0, seed=seed, iterations=(1, 3))
        capturing = fast.MemoryRuntime(
            HW, budget=BUDGET, channels=2, renegotiate=True,
            replan_size_threshold=SIZE_THRESHOLD, capture_snapshots=True)
        full = canon(capturing.run(churn_tenants(fast, items)))
        plain = fast.MemoryRuntime(
            HW, budget=BUDGET, channels=2, renegotiate=True,
            replan_size_threshold=SIZE_THRESHOLD)
        assert canon(plain.run(churn_tenants(fast, items))) == full
        for snap in capturing.barrier_snapshots:
            resumed = snap.resume()
            assert canon(resumed) == full
            replayed += 1
    assert replayed > 0, "no renegotiation barrier fired across the seeds"


def test_snapshot_replays_fewer_events():
    """Suffix-only means the snapshot simulates strictly fewer events than
    the full horizon (that's the whole point of resuming at the barrier)."""
    for seed in range(6):
        items = poisson_workload(
            ["small", "medium"], 6, 50.0, seed=seed, iterations=(1, 3))
        rt = fast.MemoryRuntime(
            HW, budget=BUDGET, channels=2, renegotiate=True,
            replan_size_threshold=SIZE_THRESHOLD, capture_snapshots=True)
        rep = rt.run(churn_tenants(fast, items))
        for snap in rt.barrier_snapshots:
            prefix = snap._events  # events already simulated at the barrier
            assert prefix > 0
            resumed = snap.resume()
            # The cumulative count matches the full run (reports agree), so
            # the resume itself executed only the suffix.
            assert resumed.engine["events"] == rep.engine["events"]
            assert resumed.engine["events"] - prefix < rep.engine["events"]
        if rt.barrier_snapshots:
            return
    pytest.fail("no renegotiation barrier fired across the seeds")
