"""Shared child-process runner for the distributed tests.

The distributed tests exec a child python with
``--xla_force_host_platform_device_count`` to get multi-device XLA.  In
sandboxes that can't provide that (jax/jaxlib too old for the sharding API,
no backend, too few devices, OOM-killed child, or a machine too slow to
finish in the timeout) the child fails for reasons that say nothing about
this repo's code.  ``run_child_or_skip`` distinguishes those environmental
failures (-> ``pytest.skip`` with the matched reason, so tier-1 signal stays
deterministic across environments) from real code errors (-> a normal
assertion failure with the child's output attached).
"""

from __future__ import annotations

import os
import re
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

# Patterns that mean "this sandbox cannot run the child", not "the code is
# wrong".  Checked against the child's stderr (last match wins the message).
_ENV_PATTERNS = [
    # jax/jaxlib too old or missing pieces of the API the repo targets.
    r"cannot import name '\w+' from 'jax[\w.]*'",
    r"No module named 'jax[\w.]*'",
    r"module 'jax[\w.]*' has no attribute",
    # Backend / platform unavailable.
    r"Unable to initialize backend",
    r"No visible \w+ devices",
    r"failed to initialize \w* ?backend",
    r"No such platform",
    # Forced host device count did not take effect: mesh creation fails
    # reshaping the single visible device into the (4, 2) grid.  Size 1
    # only — a larger size means the forcing worked and the mesh code
    # itself is wrong, which must fail, not skip.
    r"cannot reshape array of size 1 into shape",
    r"[Rr]equires \d+ devices",
    # Sandbox resource limits (XLA's allocator, not a python-level bug).
    r"RESOURCE_EXHAUSTED",
]


def classify_env_failure(proc: subprocess.CompletedProcess) -> str | None:
    """Return a human-readable environmental reason, or None for real bugs."""
    if proc.returncode is not None and proc.returncode < 0:
        return f"child killed by signal {-proc.returncode} (sandbox resource limit?)"
    text = proc.stderr or ""
    for pat in _ENV_PATTERNS:
        m = re.search(pat, text)
        if m:
            return m.group(0)
    return None


def run_child_or_skip(src: str, timeout: int = 420) -> subprocess.CompletedProcess:
    """Run child code that must print CHILD_OK; skip on environmental failure."""
    env = dict(os.environ, PYTHONPATH=SRC)
    try:
        proc = subprocess.run(
            [sys.executable, "-c", src],
            capture_output=True, text=True, env=env, timeout=timeout,
        )
    except subprocess.TimeoutExpired:
        pytest.skip(f"distributed child exceeded {timeout}s (environment too slow)")
    if "CHILD_OK" in proc.stdout:
        return proc
    reason = classify_env_failure(proc)
    if reason:
        pytest.skip(f"distributed child unavailable in this environment: {reason}")
    pytest.fail(
        "distributed child failed:\n"
        f"--- stdout (tail) ---\n{proc.stdout[-800:]}\n"
        f"--- stderr (tail) ---\n{proc.stderr[-2000:]}"
    )
