"""End-to-end system tests: train loop with checkpoints, failure injection,
elastic resume, serve loop, planner-integrated training."""

import os
import subprocess
import sys

import numpy as np
import pytest

from repro.launch import serve as serve_mod
from repro.launch import train as train_mod


def test_train_loss_decreases(tmp_path):
    losses = train_mod.main([
        "--arch", "qwen3-4b", "--smoke", "--steps", "30",
        "--batch", "4", "--seq", "64",
    ])
    assert losses[-1] < losses[0]


def test_train_failure_injection_and_resume(tmp_path):
    ckpt = str(tmp_path / "ckpt")
    args = ["--arch", "qwen3-4b", "--smoke", "--steps", "24", "--batch", "2",
            "--seq", "32", "--ckpt-dir", ckpt, "--ckpt-every", "10"]
    with pytest.raises(RuntimeError, match="injected failure"):
        train_mod.main(args + ["--fail-at", "15"])
    # relaunch: resumes from step 10's checkpoint and completes
    losses = train_mod.main(args)
    assert len(losses) > 0
    # checkpoints exist and the final one is step 23
    from repro.checkpoint.manager import latest_step

    assert latest_step(ckpt) == 23


def test_train_with_planner_offload(tmp_path):
    """--hbm-limit engages AutoSwap-driven offload remat; training still runs."""
    losses = train_mod.main([
        "--arch", "qwen3-4b", "--smoke", "--steps", "8", "--batch", "4",
        "--seq", "64", "--plan", "--hbm-limit-gb", "0.001",
    ])
    assert np.isfinite(losses).all()


def test_serve_generates(tmp_path):
    gen = serve_mod.main([
        "--arch", "qwen3-4b", "--smoke", "--batch", "2",
        "--prompt-len", "16", "--gen", "6",
    ])
    assert gen.shape == (2, 6)
    assert (np.asarray(gen) >= 0).all()


def test_deterministic_restart_same_loss(tmp_path):
    """Determinism: two runs from scratch produce identical loss curves."""
    args = ["--arch", "mamba2-370m", "--smoke", "--steps", "6", "--batch", "2",
            "--seq", "32"]
    l1 = train_mod.main(args)
    l2 = train_mod.main(args)
    np.testing.assert_allclose(l1, l2, rtol=1e-6)
