"""repro.obs: pure-observer tracing, stall attribution, metrics (Issue 7).

Three invariants pinned here:

  1. Observation is free of side effects — attaching an ``ObsRecorder``
     (or toggling ``record_events``/``capture_snapshots``) must leave the
     canonical simulated report byte-identical.
  2. The stall-attribution ledger decomposes exactly: per tenant and for
     the report-level rollup, the named cause buckets sum to ``overhead_s``
     (``residual_s`` closes the float sum; informational keys excluded).
  3. Exported traces satisfy ``tools/check_trace.py``: well-formed Chrome
     trace events, non-overlapping slices per track, paired flow arrows.
"""

from __future__ import annotations

import argparse
import importlib.util
import json
from pathlib import Path

import pytest

from repro.core.planner import AutoSwapPlanner
from repro.core.simulator import GTX_1080TI
from repro.obs import (
    MetricsRegistry,
    ObsRecorder,
    TRACE_SCHEMA_VERSION,
    add_obs_args,
    chrome_trace,
    export_trace,
    recorder_for,
    write_trace,
)
from repro.runtime import engine as fast
from repro.runtime.engine import planned_peak, simulated_report_dict
from repro.runtime.workload import poisson_workload, synthetic_train_trace

HW = GTX_1080TI
SIZE_THRESHOLD = 1 << 20
LEDGER_INFORMATIONAL = {"overhead_s", "queue_wait_s", "renegotiation_solve_s"}


def solve(trace, frac=0.7, scorer="swdoa"):
    pl = AutoSwapPlanner(trace, HW, size_threshold=SIZE_THRESHOLD)
    limit = int(pl.peak_load * frac)
    return limit, pl.select(limit, scorer)


TEMPLATES = {
    "small": synthetic_train_trace(4),
    "medium": synthetic_train_trace(6),
    "base": synthetic_train_trace(10),
}
PLANS = {name: solve(tr) for name, tr in TEMPLATES.items()}
FLOORS = {n: planned_peak(TEMPLATES[n], PLANS[n][1]) for n in TEMPLATES}
BUDGET = FLOORS["base"] + (FLOORS["small"] + FLOORS["medium"]) // 2


def canon(report) -> str:
    return json.dumps(simulated_report_dict(report), sort_keys=True)


def churn_tenants(mod, items, base_iters=6):
    ts = [
        mod.Tenant(
            "base", TEMPLATES["base"], list(PLANS["base"][1]),
            limit=PLANS["base"][0], iterations=base_iters, priority=0.5,
        )
    ]
    for it in items:
        limit, decisions = PLANS[it.template]
        ts.append(
            mod.Tenant(
                it.name, TEMPLATES[it.template], list(decisions), limit=limit,
                iterations=it.iterations, arrival_t=it.arrival_t,
                priority=it.priority,
            )
        )
    return ts


def mesh_tenants(mod, devices=4):
    ts = []
    for i in range(devices):
        name = "small" if i % 2 else "medium"
        trace = TEMPLATES[name]
        limit, decisions = PLANS[name]
        colls = {2: 0.004, trace.num_indices - 2: 0.006}
        ts.append(
            mod.Tenant(
                f"shard{i}", trace, list(decisions), limit=limit,
                iterations=3, device=f"d{i}", collectives=colls,
                collective_owner=(i == 0),
            )
        )
    return ts


def churn_run(obs=None, **kw):
    items = poisson_workload(["small", "medium"], 6, 50.0, seed=11, iterations=(1, 3))
    rt = fast.MemoryRuntime(
        HW, budget=kw.pop("budget", BUDGET), channels=2,
        renegotiate=kw.pop("renegotiate", True),
        replan_size_threshold=SIZE_THRESHOLD, obs=obs, **kw,
    )
    return rt.run(churn_tenants(fast, items))


def mesh_run(obs=None):
    rt = fast.MemoryRuntime(
        HW, channels=2, link=fast.HostLink.make(HW.link_bw, 2), obs=obs,
    )
    return rt.run(mesh_tenants(fast, 4))


def _load_check_trace():
    path = Path(__file__).resolve().parents[1] / "tools" / "check_trace.py"
    spec = importlib.util.spec_from_file_location("check_trace", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


# ---------------------------------------------------------------- purity
def test_obs_is_a_pure_observer_churn():
    rec = ObsRecorder()
    assert canon(churn_run(obs=rec)) == canon(churn_run(obs=None))
    assert rec.ops and rec.transfers and rec.admissions
    assert any(k == "staged" for k, *_ in rec.renegotiations)


def test_obs_is_a_pure_observer_mesh():
    rec = ObsRecorder()
    assert canon(mesh_run(obs=rec)) == canon(mesh_run(obs=None))
    assert rec.blackouts and rec.collectives
    assert {r[1] for r in rec.ops} == {f"d{i}" for i in range(4)}


def test_op_slices_off_still_records_stalls_and_transfers():
    rec = ObsRecorder(op_slices=False)
    churn_run(obs=rec)
    assert not rec.ops
    assert rec.transfers and rec.admissions
    assert rec.metrics.snapshot()["engine.ops"] > 0


# ------------------------------------------------------------ attribution
def ledger_closes(ledger: dict) -> bool:
    total = ledger["overhead_s"]
    named = sum(v for k, v in ledger.items() if k not in LEDGER_INFORMATIONAL)
    return abs(named - total) <= 1e-6 + 1e-9 * abs(total)


def test_ledger_sums_exactly_per_tenant_and_total():
    report = churn_run()
    assert report.attribution is not None and ledger_closes(report.attribution)
    checked = 0
    for t in report.tenants:
        if t.attribution is None:
            continue
        assert ledger_closes(t.attribution), t.name
        assert t.attribution["overhead_s"] >= 0.0
        assert t.attribution["queue_wait_s"] == t.queue_wait_s
        checked += 1
    assert checked == len(report.tenants)
    # A budgeted churn run is not overhead-free: some named cause is hot.
    named = {
        k: v for k, v in report.attribution.items()
        if k not in LEDGER_INFORMATIONAL and k != "residual_s"
    }
    assert any(v > 0 for v in named.values()), named


def test_ledger_mesh_contention_shows_link_causes():
    report = mesh_run()
    assert report.attribution is not None and ledger_closes(report.attribution)
    # Tagged collectives on a shared link: the excess is attributed, and the
    # blackout windows the non-owner shards stall behind land in the ledger.
    assert report.attribution["collective_excess_s"] >= 0.0
    for t in report.tenants:
        assert t.attribution is not None and ledger_closes(t.attribution)


def test_attribution_stripped_from_simulated_report():
    report = churn_run()
    d = simulated_report_dict(report)
    assert "attribution" not in d
    assert all("attribution" not in t for t in d["tenants"])
    assert report.as_dict()["attribution"] == report.attribution


# ------------------------------------------------------------ trace export
def test_trace_export_passes_checker(tmp_path):
    checker = _load_check_trace()
    rec = ObsRecorder()
    report = churn_run(obs=rec)
    path = tmp_path / "churn.trace.json"
    trace = write_trace(str(path), rec, report)
    assert checker.check_trace(str(path)) == []
    assert trace["otherData"]["schema_version"] == TRACE_SCHEMA_VERSION
    names = {e["name"] for e in trace["traceEvents"]}
    assert {"process_name", "thread_name", "renegotiation staged"} <= names
    # Counter tracks for memory occupancy made it in.
    assert any(e["ph"] == "C" and e["name"].startswith("HBM")
               for e in trace["traceEvents"])


def test_trace_export_mesh_passes_checker(tmp_path):
    checker = _load_check_trace()
    rec = ObsRecorder()
    report = mesh_run(obs=rec)
    path = tmp_path / "mesh.trace.json"
    trace = write_trace(str(path), rec, report)
    assert checker.check_trace(str(path)) == []
    # Per-device DMA rows and the link blackout track are present.
    pids = {e["pid"] for e in trace["traceEvents"]}
    assert {1, 2, 3, 4} <= pids
    assert any(e.get("name") == "blackout"
               for e in trace["traceEvents"] if e["ph"] == "X")


def test_committed_example_traces_validate():
    checker = _load_check_trace()
    traces = sorted(
        (Path(__file__).resolve().parents[1] / "examples" / "traces").glob("*.trace.json")
    )
    assert len(traces) >= 2
    for p in traces:
        assert checker.check_trace(str(p)) == [], p.name


def test_chrome_trace_events_sorted_by_ts():
    rec = ObsRecorder()
    churn_run(obs=rec)
    trace = chrome_trace(rec)
    stamped = [e["ts"] for e in trace["traceEvents"] if e["ph"] != "M"]
    assert stamped == sorted(stamped)


# ------------------------------------------------- simulated_report_dict (S3)
def test_simulated_report_stable_across_observability_toggles():
    base = canon(churn_run())
    assert canon(churn_run(record_events=False)) == base
    assert canon(churn_run(capture_snapshots=True)) == base
    assert canon(churn_run(obs=ObsRecorder(op_slices=False))) == base


def test_simulated_report_strips_wall_clock_and_round_trips():
    report = churn_run()
    d = simulated_report_dict(report)
    assert "engine" not in d
    assert all("events" not in t for t in d["tenants"])
    for t in d["tenants"]:
        assert t.get("renegotiation_solve_ms", 0.0) == 0.0
    assert json.loads(json.dumps(d, sort_keys=True)) == d


# ------------------------------------------------------------------ metrics
def test_metrics_registry_counters_gauges_and_collisions(tmp_path):
    reg = MetricsRegistry()
    reg.counter("a.b").inc()
    reg.counter("a.b").inc(2.5)
    reg.gauge("g").set(3.0)
    reg.gauge("g").set_max(1.0)  # no-op: running max
    assert reg.snapshot() == {"a.b": 3.5, "g": 3.0}
    with pytest.raises(ValueError):
        reg.gauge("a.b")
    with pytest.raises(ValueError):
        reg.counter("g")
    out = tmp_path / "metrics.jsonl"
    reg.append_jsonl(str(out), extra={"cell": "t1"})
    reg.counter("a.b").inc()
    reg.append_jsonl(str(out))
    lines = [json.loads(l) for l in out.read_text().splitlines()]
    assert [l["metrics"]["a.b"] for l in lines] == [3.5, 4.5]
    assert lines[0]["cell"] == "t1" and "written_at" in lines[1]


def test_recorder_folds_hooks_into_metrics():
    rec = ObsRecorder()
    report = churn_run(obs=rec)
    snap = rec.metrics.snapshot()
    assert snap["engine.ops"] == len(rec.ops)
    assert snap["admission.admitted"] == len(rec.admissions)
    assert snap["engine.transfers.in"] + snap["engine.transfers.out"] == len(rec.transfers)
    assert snap["engine.makespan_s"] == pytest.approx(report.makespan_s)


# ---------------------------------------------------------------- CLI glue
def test_cli_obs_args_and_export(tmp_path, capsys):
    ap = argparse.ArgumentParser()
    add_obs_args(ap)
    args = ap.parse_args([])
    assert args.record_events is True and args.trace_out is None
    assert recorder_for(args) is None

    out = tmp_path / "t.trace.json"
    args = ap.parse_args(["--no-record-events", "--trace-out", str(out)])
    assert args.record_events is False
    rec = recorder_for(args)
    assert isinstance(rec, ObsRecorder)
    report = churn_run(obs=rec, record_events=args.record_events)
    export_trace(args, rec, report)
    assert "wrote" in capsys.readouterr().out
    checker = _load_check_trace()
    assert checker.check_trace(str(out)) == []
