"""Mamba-2 (SSD, state-space duality) mixer — chunked parallel scan.

Training/prefill uses the SSD block decomposition (arXiv:2405.21060 §6):
intra-chunk quadratic attention-like term + inter-chunk state recurrence,
with the cross-chunk scan done by ``lax.associative_scan`` (log-depth on
TPU).  Decode keeps a constant-size recurrent state: [B, H, P, N] SSM state
plus a [B, conv_dim, K-1] convolution tail — this is what makes the
``long_500k`` cell linear-cost for SSM models.

Layout: d_inner = expand*d_model, heads H = d_inner/headdim (P=headdim),
state N = ssm_state, G groups share B/C across H/G heads.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from .layers import rmsnorm


def _dims(cfg: ModelConfig):
    di = cfg.d_inner
    H = cfg.ssm_heads
    P = cfg.ssm_headdim
    N = cfg.ssm_state
    G = cfg.ssm_groups
    conv_dim = di + 2 * G * N
    return di, H, P, N, G, conv_dim


def init_mamba(key, cfg: ModelConfig):
    d = cfg.d_model
    di, H, P, N, G, conv_dim = _dims(cfg)
    ks = jax.random.split(key, 4)
    s = 1.0 / np.sqrt(d)
    proj_out = 2 * di + 2 * G * N + H  # z, x, B, C, dt
    return {
        "in_proj": jax.random.normal(ks[0], (d, proj_out), jnp.float32) * s,
        "conv_w": jax.random.normal(ks[1], (cfg.conv_kernel, conv_dim), jnp.float32) * 0.1,
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": jnp.ones((di,), jnp.float32),
        "out_proj": jax.random.normal(ks[2], (di, d), jnp.float32) / np.sqrt(di),
    }


def _split_proj(zxbcdt, cfg: ModelConfig):
    di, H, P, N, G, _ = _dims(cfg)
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di : 2 * di + 2 * G * N]
    dt = zxbcdt[..., 2 * di + 2 * G * N :]
    return z, xBC, dt


def _split_xbc(xBC, cfg: ModelConfig):
    di, H, P, N, G, _ = _dims(cfg)
    x = xBC[..., :di]
    Bm = xBC[..., di : di + G * N]
    Cm = xBC[..., di + G * N :]
    B_, S = x.shape[:2]
    return (
        x.reshape(B_, S, H, P),
        Bm.reshape(B_, S, G, N),
        Cm.reshape(B_, S, G, N),
    )


def _causal_conv(xBC, w, b):
    """Depthwise causal conv over the sequence axis. xBC [B,S,C], w [K,C]."""
    K = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(xBC)
    for i in range(K):  # K is 4: unrolled taps beat a conv op for this shape
        out = out + pad[:, i : i + xBC.shape[1], :] * w[i]
    return jax.nn.silu(out + b)


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int):
    """SSD over chunks.  x [b,s,h,p] (pre-scaled by nothing), dt [b,s,h] >0,
    A [h] < 0, Bm/Cm [b,s,g,n].  Returns y [b,s,h,p]."""
    b, s, h, p = x.shape
    g, n = Bm.shape[2], Bm.shape[3]
    rep = h // g
    nc = s // chunk
    assert nc * chunk == s, (s, chunk)

    xc = (x * dt[..., None]).reshape(b, nc, chunk, h, p)     # input contribution
    dA = (dt * A).reshape(b, nc, chunk, h)                   # negative increments
    Bc = Bm.reshape(b, nc, chunk, g, n)
    Cc = Cm.reshape(b, nc, chunk, g, n)

    cum = jnp.cumsum(dA, axis=2)                             # [b,nc,c,h]
    # --- intra-chunk (quadratic, attention-like) ---
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]     # [b,nc,c,c,h]
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    # Mask BEFORE exp: upper-triangular diffs are positive and would overflow,
    # poisoning gradients through the where (NaN * 0). exp(-inf) == 0 is safe.
    diff = jnp.where(tri[None, None, :, :, None], diff, -jnp.inf)
    L = jnp.exp(diff).astype(x.dtype)
    CB = jnp.einsum("bzcgn,bzdgn->bzcdg", Cc, Bc)            # [b,nc,c,c,g]
    CB = jnp.repeat(CB, rep, axis=-1)                        # -> heads
    y_diag = jnp.einsum("bzcdh,bzcdh,bzdhp->bzchp", CB, L, xc)

    # --- chunk states ---
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum).astype(x.dtype)   # [b,nc,c,h]
    Bh = jnp.repeat(Bc, rep, axis=3)                         # [b,nc,c,h,n]
    states = jnp.einsum("bzchn,bzch,bzchp->bzhpn", Bh, decay_to_end, xc)

    # --- inter-chunk recurrence (associative scan over chunks) ---
    chunk_decay = jnp.exp(cum[:, :, -1, :]).astype(x.dtype)  # [b,nc,h]

    def combine(a, c):
        d1, s1 = a
        d2, s2 = c
        return d1 * d2, s1 * d2[..., None, None] + s2

    run_decay, run_state = jax.lax.associative_scan(
        combine, (chunk_decay, states), axis=1
    )
    # state entering chunk z is the running state after chunk z-1
    prev = jnp.concatenate(
        [jnp.zeros_like(run_state[:, :1]), run_state[:, :-1]], axis=1
    )
    state_decay_in = jnp.exp(cum).astype(x.dtype)            # decay from chunk start
    Ch = jnp.repeat(Cc, rep, axis=3)                         # [b,nc,c,h,n]
    y_off = jnp.einsum("bzchn,bzhpn,bzch->bzchp", Ch, prev, state_decay_in)

    y = (y_diag + y_off).reshape(b, s, h, p)
    final_state = run_state[:, -1].astype(jnp.float32)       # [b,h,p,n]
    return y, final_state


def apply_mamba(p, x_in, cfg: ModelConfig, *, return_cache: bool = False):
    """x_in [B,S,D] -> [B,S,D] (training / prefill).

    ``return_cache=True`` additionally emits the recurrent decode cache
    (final SSM state + conv tail) so prefill can hand off to decode_mamba.
    """
    dt_ = x_in.dtype
    B_, S = x_in.shape[:2]
    zxbcdt = jnp.einsum("bsd,dk->bsk", x_in, p["in_proj"].astype(dt_))
    z, xBC_raw, dt_raw = _split_proj(zxbcdt, cfg)
    xBC = _causal_conv(xBC_raw, p["conv_w"].astype(dt_), p["conv_b"].astype(dt_))
    x, Bm, Cm = _split_xbc(xBC, cfg)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    # Pad the sequence to a chunk multiple; padded steps get dt == 0, which
    # makes them exact no-ops in the recurrence (no decay, no contribution).
    pad = (-S) % cfg.ssm_chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    A = -jnp.exp(p["A_log"])
    y, final_state = ssd_chunked(x, dt.astype(dt_), A.astype(dt_), Bm, Cm, cfg.ssm_chunk)
    y = y + p["D"].astype(dt_)[:, None] * x
    y = y[:, :S]
    x = x[:, :S]
    y = y.reshape(B_, S, -1)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = jnp.einsum("bsk,kd->bsd", y, p["out_proj"].astype(dt_))
    if not return_cache:
        return out
    K = cfg.conv_kernel
    cache = {"state": final_state, "conv": xBC_raw[:, S - (K - 1) :, :]}
    return out, cache


# ------------------------------------------------------------------ decode
def init_mamba_cache(cfg: ModelConfig, batch: int, dtype):
    di, H, P, N, G, conv_dim = _dims(cfg)
    return {
        "state": jnp.zeros((batch, H, P, N), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1, conv_dim), dtype),
    }


def decode_mamba(p, x_in, cache, cfg: ModelConfig):
    """One-token recurrent step. x_in [B,1,D] -> ([B,1,D], new_cache)."""
    dt_ = x_in.dtype
    di, H, P, N, G, conv_dim = _dims(cfg)
    zxbcdt = jnp.einsum("bsd,dk->bsk", x_in, p["in_proj"].astype(dt_))
    z, xBC_new, dt_raw = _split_proj(zxbcdt, cfg)

    # conv over [cached K-1 tail, new column]
    window = jnp.concatenate([cache["conv"], xBC_new], axis=1)     # [B,K,conv]
    conv_out = (window * p["conv_w"].astype(dt_)[None]).sum(1, keepdims=True)
    xBC = jax.nn.silu(conv_out + p["conv_b"].astype(dt_))
    new_conv = window[:, 1:]

    x, Bm, Cm = _split_xbc(xBC, cfg)                                # S == 1
    x, Bm, Cm = x[:, 0], Bm[:, 0], Cm[:, 0]                         # [B,H,P],[B,G,N]
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=1)                                # [B,H,N]
    Ch = jnp.repeat(Cm, rep, axis=1)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])  # [B,H]
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A)                                            # [B,H]

    state = cache["state"] * dA[..., None, None] + jnp.einsum(
        "bhp,bhn,bh->bhpn", x.astype(jnp.float32), Bh.astype(jnp.float32), dt
    )
    y = jnp.einsum("bhpn,bhn->bhp", state, Ch.astype(jnp.float32)).astype(dt_)
    y = y + p["D"].astype(dt_)[:, None] * x
    y = y.reshape(x_in.shape[0], 1, di)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = jnp.einsum("bsk,kd->bsd", y, p["out_proj"].astype(dt_))
    return out, {"state": state, "conv": new_conv}
