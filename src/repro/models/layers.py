"""Shared layer primitives: norms, FFNs, embeddings.

Everything is functional: ``init_*`` builds a param pytree, ``apply``-style
functions consume it.  Params default to float32 masters; activations run in
``cfg.dtype`` (bf16 on TPU) with f32 softmax/log-softmax.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


def dtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ----------------------------------------------------------------- norms
def init_norm(cfg: ModelConfig, width: int | None = None):
    d = width or cfg.d_model
    if cfg.norm_type == "layer":
        return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}
    return {"scale": jnp.ones((d,), jnp.float32)}


def apply_norm(p, x, cfg: ModelConfig):
    xf = x.astype(jnp.float32)
    if cfg.norm_type == "layer":
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"] + p["bias"]
    else:
        var = (xf * xf).mean(-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + cfg.norm_eps) * p["scale"]
    return y.astype(x.dtype)


def rmsnorm(scale, x, eps: float = 1e-6):
    """Bare RMSNorm used for qk-norm and hybrid branch norms."""
    xf = x.astype(jnp.float32)
    var = (xf * xf).mean(-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


# ------------------------------------------------------------------ FFN
def init_dense_ffn(key, cfg: ModelConfig, d_ff: int | None = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    scale_in = 1.0 / np.sqrt(d)
    scale_out = 1.0 / np.sqrt(f)
    if cfg.ffn_act == "swiglu":
        return {
            "w_gate": jax.random.normal(k1, (d, f), jnp.float32) * scale_in,
            "w_up": jax.random.normal(k2, (d, f), jnp.float32) * scale_in,
            "w_down": jax.random.normal(k3, (f, d), jnp.float32) * scale_out,
        }
    return {
        "w_up": jax.random.normal(k1, (d, f), jnp.float32) * scale_in,
        "b_up": jnp.zeros((f,), jnp.float32),
        "w_down": jax.random.normal(k2, (f, d), jnp.float32) * scale_out,
        "b_down": jnp.zeros((cfg.d_model,), jnp.float32),
    }


def apply_dense_ffn(p, x, cfg: ModelConfig):
    dt = x.dtype
    if "w_gate" in p:
        g = jnp.einsum("...d,df->...f", x, p["w_gate"].astype(dt))
        u = jnp.einsum("...d,df->...f", x, p["w_up"].astype(dt))
        h = jax.nn.silu(g) * u
        return jnp.einsum("...f,fd->...d", h, p["w_down"].astype(dt))
    h = jnp.einsum("...d,df->...f", x, p["w_up"].astype(dt)) + p["b_up"].astype(dt)
    h = jax.nn.gelu(h)
    return jnp.einsum("...f,fd->...d", h, p["w_down"].astype(dt)) + p["b_down"].astype(dt)


# ------------------------------------------------------------ embeddings
def init_embedding(key, cfg: ModelConfig):
    p = {"tok": jax.random.normal(key, (cfg.vocab_size, cfg.d_model), jnp.float32) * 0.02}
    if not cfg.tie_embeddings:
        k2 = jax.random.fold_in(key, 1)
        p["head"] = jax.random.normal(k2, (cfg.vocab_size, cfg.d_model), jnp.float32) * 0.02
    return p


def embed_tokens(p, tokens, cfg: ModelConfig):
    return p["tok"][tokens].astype(dtype_of(cfg))


def lm_logits(p, x, cfg: ModelConfig):
    table = p.get("head", p["tok"])
    logits = jnp.einsum("...d,vd->...v", x, table.astype(x.dtype))
    logits = logits.astype(jnp.float32)
    if cfg.final_softcap:
        c = cfg.final_softcap
        logits = c * jnp.tanh(logits / c)
    return logits


def cross_entropy(logits, labels, ignore_index: int = -1):
    """Mean CE over non-ignored positions.  logits f32 [..., V], labels int."""
    mask = labels != ignore_index
    safe = jnp.where(mask, labels, 0)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    total = jnp.maximum(mask.sum(), 1)
    return -(ll * mask).sum() / total


def chunked_softmax_xent(
    x, params, labels, cfg: ModelConfig, chunk: int = 256, ignore_index: int = -1
):
    """CE without ever materializing [B, S, V] logits.

    Scans over token chunks; each chunk's logits are computed, reduced to
    (sum CE, count), and *rematerialized* in backward (jax.checkpoint), so
    live logits are [B, chunk, V] — at gemma3's 262k vocab this is the
    difference between ~4 TB and ~0.3 GB per device.  x is pre-final-norm
    hidden states aligned so position i predicts labels[i] (callers shift).
    """
    from repro.distributed.sharding import shard as _shard

    table = params.get("head", params["tok"])
    B, S, D = x.shape
    c = min(chunk, S)
    pad = (-S) % c
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=ignore_index)
    nc = (S + pad) // c
    xs = x.reshape(B, nc, c, D).transpose(1, 0, 2, 3)
    ls = labels.reshape(B, nc, c).transpose(1, 0, 2)

    def body(acc, inp):
        xc, lc = inp
        logits = jnp.einsum("bcd,vd->bcv", xc, table.astype(xc.dtype))
        logits = logits.astype(jnp.float32)
        if cfg.final_softcap:
            cap = cfg.final_softcap
            logits = cap * jnp.tanh(logits / cap)
        logits = _shard(logits, "batch_pd", None, "vocab")
        mask = lc != ignore_index
        safe = jnp.where(mask, lc, 0)
        logz = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
        ce_sum = ((logz - ll) * mask).sum()
        return (acc[0] + ce_sum, acc[1] + mask.sum()), None

    body = jax.checkpoint(body)
    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32)), (xs, ls))
    return tot / jnp.maximum(cnt, 1).astype(jnp.float32)
