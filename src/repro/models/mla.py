"""Multi-head Latent Attention (DeepSeek-V2) with compressed KV cache.

Train/prefill: decompress c_kv into per-head K_nope/V and run standard MHA.
Decode: the *absorbed* formulation — W_uk folds into the query and W_uv into
the output so attention runs directly against the [B, S, kv_lora] compressed
cache plus the shared [B, S, qk_rope] rope key.  Cache bytes per token:
(kv_lora + qk_rope) vs 2*H*head_dim for vanilla GQA — the 512+64 vs 4096
compression that makes 32k decode cheap.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LayerSpec, ModelConfig
from repro.distributed.sharding import shard_pick
from .layers import rmsnorm
from .rope import apply_rope


def init_mla(key, cfg: ModelConfig, spec: LayerSpec):
    d, H = cfg.d_model, cfg.num_heads
    nope, rope_d, vh, lora = (
        cfg.qk_nope_head_dim,
        cfg.qk_rope_head_dim,
        cfg.v_head_dim,
        cfg.kv_lora_rank,
    )
    ks = jax.random.split(key, 6)
    s = 1.0 / np.sqrt(d)
    sl = 1.0 / np.sqrt(lora)
    return {
        "wq": jax.random.normal(ks[0], (d, H, nope + rope_d), jnp.float32) * s,
        "w_dkv": jax.random.normal(ks[1], (d, lora), jnp.float32) * s,
        "kv_norm": jnp.ones((lora,), jnp.float32),
        "w_kr": jax.random.normal(ks[2], (d, rope_d), jnp.float32) * s,
        "w_uk": jax.random.normal(ks[3], (lora, H, nope), jnp.float32) * sl,
        "w_uv": jax.random.normal(ks[4], (lora, H, vh), jnp.float32) * sl,
        "wo": jax.random.normal(ks[5], (H, vh, d), jnp.float32) / np.sqrt(H * vh),
    }


def _mla_scale(cfg: ModelConfig) -> float:
    return 1.0 / np.sqrt(cfg.qk_nope_head_dim + cfg.qk_rope_head_dim)


def _project_q(p, x, cfg: ModelConfig, angles):
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    q_nope = q[..., : cfg.qk_nope_head_dim]
    q_rope = apply_rope(q[..., cfg.qk_nope_head_dim :], angles)
    return q_nope, q_rope


def _compress_kv(p, x, cfg: ModelConfig, angles):
    dt = x.dtype
    c_kv = jnp.einsum("bsd,dl->bsl", x, p["w_dkv"].astype(dt))
    c_kv = rmsnorm(p["kv_norm"], c_kv, cfg.norm_eps)
    k_rope = jnp.einsum("bsd,dk->bsk", x, p["w_kr"].astype(dt))
    k_rope = apply_rope(k_rope, angles)
    return c_kv, k_rope


def apply_mla(p, x, cfg: ModelConfig, spec: LayerSpec, angles, *, causal=True):
    """Training/prefill MLA (decompressed). x [B,S,D] -> [B,S,D]."""
    dt = x.dtype
    B, S, _ = x.shape
    q_nope, q_rope = _project_q(p, x, cfg, angles)
    c_kv, k_rope = _compress_kv(p, x, cfg, angles)
    k_nope = jnp.einsum("bsl,lhk->bshk", c_kv, p["w_uk"].astype(dt))
    v = jnp.einsum("bsl,lhk->bshk", c_kv, p["w_uv"].astype(dt))

    scores = (
        jnp.einsum("bqhk,bshk->bhqs", q_nope, k_nope)
        + jnp.einsum("bqhk,bsk->bhqs", q_rope, k_rope)
    ) * _mla_scale(cfg)
    scores = scores.astype(jnp.float32)
    if causal:
        mask = jnp.arange(S)[:, None] >= jnp.arange(S)[None, :]
        scores = jnp.where(mask[None, None], scores, -1e30)
    scores = shard_pick(
        scores,
        ("batch", "heads", None, None),
        ("batch", None, "seq_model", None),
        ("batch", None, None, "seq_model"),
    )
    w = jax.nn.softmax(scores, axis=-1).astype(dt)
    out = jnp.einsum("bhqs,bshk->bqhk", w, v)
    return jnp.einsum("bqhk,hkd->bqd", out, p["wo"].astype(dt))


def prefill_mla(p, x, cfg: ModelConfig, spec: LayerSpec, angles, max_seq: int):
    """MLA prefill emitting the compressed cache."""
    out = apply_mla(p, x, cfg, spec, angles, causal=True)
    c_kv, k_rope = _compress_kv(p, x, cfg, angles)
    S = x.shape[1]
    pad = [(0, 0), (0, max_seq - S), (0, 0)]
    return out, {"c_kv": jnp.pad(c_kv, pad), "k_rope": jnp.pad(k_rope, pad)}


def init_mla_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype):
    return {
        "c_kv": jnp.zeros((batch, max_seq, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_seq, cfg.qk_rope_head_dim), dtype),
    }


def decode_mla(p, x, cache, pos, cfg: ModelConfig, spec: LayerSpec, angles):
    """Absorbed one-token decode against the compressed cache."""
    dt = x.dtype
    q_nope, q_rope = _project_q(p, x, cfg, angles)          # [B,1,H,*]
    c_new, kr_new = _compress_kv(p, x, cfg, angles)         # [B,1,lora], [B,1,rope]
    c_kv = jax.lax.dynamic_update_slice_in_dim(cache["c_kv"], c_new, pos, axis=1)
    k_rope = jax.lax.dynamic_update_slice_in_dim(cache["k_rope"], kr_new, pos, axis=1)

    # Absorb W_uk into q: score_nope = (q_nope W_uk^T) . c_kv
    q_abs = jnp.einsum("bqhk,lhk->bqhl", q_nope, p["w_uk"].astype(dt))  # [B,1,H,lora]
    scores = (
        jnp.einsum("bqhl,bsl->bhqs", q_abs, c_kv)
        + jnp.einsum("bqhk,bsk->bhqs", q_rope, k_rope)
    ) * _mla_scale(cfg)
    mask = jnp.arange(c_kv.shape[1]) <= pos
    scores = jnp.where(mask[None, None, None], scores.astype(jnp.float32), -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(dt)
    ctx = jnp.einsum("bhqs,bsl->bqhl", w, c_kv)             # [B,1,H,lora]
    out = jnp.einsum("bqhl,lhk->bqhk", ctx, p["w_uv"].astype(dt))  # absorb W_uv
    out = jnp.einsum("bqhk,hkd->bqd", out, p["wo"].astype(dt))
    return out, {"c_kv": c_kv, "k_rope": k_rope}
