"""Attention family: GQA full/sliding-window, softcap, qk-norm; KV caches.

Three execution paths share exact semantics:
  * dense   — materialized scores; small sequences (training at 4k).
  * chunked — lax.scan over query blocks with online masking; bounds live
              memory to O(q_block * S) and, for window layers, slices K/V to
              the reachable window only (true FLOP reduction, not just mask).
  * decode  — single-token step against a full or ring KV cache.

Keys are cached post-RoPE.  Ring caches (window layers) store absolute slot
positions implicitly: slot j at decode position p was written at
q = p - ((p - j) mod W), valid iff q >= 0.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LayerSpec, ModelConfig
from repro.distributed.sharding import shard_pick
from .layers import rmsnorm
from .rope import apply_rope

# Sequences at or above this length use the chunked path in train/prefill.
CHUNKED_THRESHOLD = 8192
Q_BLOCK = 1024


# ------------------------------------------------------------------- init
def init_attention(key, cfg: ModelConfig, spec: LayerSpec):
    d, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    s = 1.0 / np.sqrt(d)
    p = {
        "wq": jax.random.normal(ks[0], (d, H, hd), jnp.float32) * s,
        "wk": jax.random.normal(ks[1], (d, KV, hd), jnp.float32) * s,
        "wv": jax.random.normal(ks[2], (d, KV, hd), jnp.float32) * s,
        "wo": jax.random.normal(ks[3], (H, hd, d), jnp.float32) / np.sqrt(H * hd),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), jnp.float32)
        p["k_norm"] = jnp.ones((hd,), jnp.float32)
    return p


def init_cross_attention(key, cfg: ModelConfig):
    return init_attention(key, cfg, LayerSpec())


# ---------------------------------------------------------------- scoring
def _scale(cfg: ModelConfig) -> float:
    return cfg.attn_scale if cfg.attn_scale is not None else 1.0 / np.sqrt(cfg.head_dim)


def _softcap(scores, cap):
    if cap:
        return cap * jnp.tanh(scores / cap)
    return scores


def _expand_kv(k, G: int):
    """[B,S,KV,hd] -> [B,S,KV*G,hd] broadcast (fused into the matmul by XLA)."""
    if G == 1:
        return k
    B, S, KV, hd = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (B, S, KV, G, hd)).reshape(B, S, KV * G, hd)


def _sdpa(q, k, v, mask, cfg: ModelConfig):
    """q [B,Sq,H,hd], k/v [B,Sk,KV,hd], mask broadcastable to [B,H,Sq,Sk].

    Scores are [B, H, Sq, Sk] over *fused* q-heads so the partitioner can
    shard them on H; when H doesn't divide the model axis (llama4: 40 heads,
    hymba: 25), shard_pick falls back to query-seq then key-seq sharding
    (context-parallel / split-KV) — otherwise scores replicate at
    O(S^2 * H) per device.
    """
    B, Sq, H, hd = q.shape
    G = H // k.shape[2]
    k, v = _expand_kv(k, G), _expand_kv(v, G)
    scores = jnp.einsum("bqhd,bshd->bhqs", q, k) * _scale(cfg)
    scores = _softcap(scores.astype(jnp.float32), cfg.attn_softcap)
    if mask is not None:
        scores = jnp.where(mask, scores, -1e30)
    scores = shard_pick(
        scores,
        ("batch", "heads", None, None),
        ("batch_full", None, None, None),
        ("batch", None, "seq_model", None),
        ("batch", None, None, "seq_model"),
    )
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhqs,bshd->bqhd", w, v)
    return out


def _causal_window_mask(q_pos, k_pos, causal: bool, window: int | None):
    """[Sq, Sk] boolean mask from absolute positions."""
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        m &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        m &= q_pos[:, None] - k_pos[None, :] < window
    return m


def mha_dense(q, k, v, cfg: ModelConfig, *, causal=True, window=None):
    Sq, Sk = q.shape[1], k.shape[1]
    mask = _causal_window_mask(jnp.arange(Sq), jnp.arange(Sk), causal, window)
    return _sdpa(q, k, v, mask[None, None], cfg)


def mha_chunked(q, k, v, cfg: ModelConfig, *, causal=True, window=None, q_block=Q_BLOCK):
    """Scan over query blocks; window layers slice K/V to the reachable range."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    nq = S // q_block
    assert nq * q_block == S, (S, q_block)
    qb = q.reshape(B, nq, q_block, H, hd).transpose(1, 0, 2, 3, 4)  # [nq,B,qb,H,hd]

    if window is not None and causal:
        # K/V reachable from q block i: [i*qb - (W-1), i*qb + qb)
        span = q_block + _round_up(window, 128)

        def block(carry, inp):
            i, qi = inp
            start = jnp.maximum(i * q_block + q_block - span, 0)
            ks = jax.lax.dynamic_slice_in_dim(k, start, span, axis=1)
            vs = jax.lax.dynamic_slice_in_dim(v, start, span, axis=1)
            q_pos = i * q_block + jnp.arange(q_block)
            k_pos = start + jnp.arange(span)
            mask = _causal_window_mask(q_pos, k_pos, causal, window)
            return carry, _sdpa(qi, ks, vs, mask[None, None], cfg)

        _, out = jax.lax.scan(block, None, (jnp.arange(nq), qb))
    else:

        def block(carry, inp):
            i, qi = inp
            q_pos = i * q_block + jnp.arange(q_block)
            k_pos = jnp.arange(k.shape[1])
            mask = _causal_window_mask(q_pos, k_pos, causal, window)
            return carry, _sdpa(qi, k, v, mask[None, None], cfg)

        _, out = jax.lax.scan(block, None, (jnp.arange(nq), qb))
    return out.transpose(1, 0, 2, 3, 4).reshape(B, S, H, hd)


def _round_up(x, m):
    return (x + m - 1) // m * m


# ----------------------------------------------------------- train/prefill
def apply_attention(
    p,
    x,
    cfg: ModelConfig,
    spec: LayerSpec,
    angles,
    *,
    causal: bool = True,
    impl: str | None = None,
):
    """Full-sequence attention (training / prefill). Returns [B,S,D]."""
    dt = x.dtype
    B, S, _ = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    if angles is not None:
        q = apply_rope(q, angles)
        k = apply_rope(k, angles)
    window = spec.window if spec.attn in ("window", "hybrid") else None
    use_chunked = impl == "chunked" or (impl is None and S >= CHUNKED_THRESHOLD)
    fn = mha_chunked if use_chunked else mha_dense
    out = fn(q, k, v, cfg, causal=causal, window=window)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt))


def apply_cross_attention(p, x, enc_kv, cfg: ModelConfig):
    """Decoder cross-attention; enc_kv = (k, v) precomputed from the encoder."""
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k, v = enc_kv
    out = _sdpa(q, k, v, None, cfg)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt))


def encode_cross_kv(p, enc_out, cfg: ModelConfig):
    dt = enc_out.dtype
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"].astype(dt))
    return k, v


# ------------------------------------------------------------------ cache
def cache_len(cfg: ModelConfig, spec: LayerSpec, max_seq: int) -> int:
    if spec.attn in ("window", "hybrid") and spec.window is not None:
        return min(max_seq, spec.window)
    return max_seq


def prefill_attention(p, x, cfg: ModelConfig, spec: LayerSpec, angles, max_seq: int):
    """Full-sequence attention that also emits the filled KV cache."""
    dt = x.dtype
    B, S, _ = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    if angles is not None:
        q = apply_rope(q, angles)
        k = apply_rope(k, angles)
    window = spec.window if spec.attn in ("window", "hybrid") else None
    fn = mha_chunked if S >= CHUNKED_THRESHOLD else mha_dense
    out = fn(q, k, v, cfg, causal=True, window=window)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt))

    W = cache_len(cfg, spec, max_seq)
    if W >= S:
        pad = [(0, 0), (0, W - S), (0, 0), (0, 0)]
        cache = {"k": jnp.pad(k, pad), "v": jnp.pad(v, pad)}
    else:
        # Ring: slots hold the last W positions p in [S-W, S), slot = p % W.
        pos = S - W + jnp.arange(W)
        slots = pos % W
        cache = {
            "k": jnp.zeros((B, W) + k.shape[2:], dt).at[:, slots].set(k[:, pos]),
            "v": jnp.zeros((B, W) + v.shape[2:], dt).at[:, slots].set(v[:, pos]),
        }
    return out, cache


def init_kv_cache(cfg: ModelConfig, spec: LayerSpec, batch: int, max_seq: int, dtype):
    """Zeroed cache for one layer. Window layers get a ring of size window."""
    size = max_seq
    if spec.attn in ("window", "hybrid") and spec.window is not None:
        size = min(max_seq, spec.window)
    shape = (batch, size, cfg.num_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def decode_attention(p, x, cache, pos, cfg: ModelConfig, spec: LayerSpec, angles):
    """One-token decode. x [B,1,D]; pos scalar int32; returns (out, new_cache)."""
    dt = x.dtype
    B = x.shape[0]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    if angles is not None:
        q = apply_rope(q, angles)
        k = apply_rope(k, angles)

    # Unified ring-buffer update: full caches are rings of size max_seq, so
    # slot == pos and the validity mask reduces to idx <= pos; window caches
    # wrap and the mask keeps exactly the last `window` positions.
    W = cache["k"].shape[1]
    slot = pos % W
    new_k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=1)
    new_v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=1)

    idx = jnp.arange(W)
    written_at = pos - jnp.mod(pos - idx, W)  # last write position of slot idx
    mask = written_at >= 0
    out = _sdpa(q, new_k, new_v, mask[None, None, None, :], cfg)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt))
    return out, {"k": new_k, "v": new_v}
