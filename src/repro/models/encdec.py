"""Encoder-decoder LM (whisper-large-v3 backbone).

The audio frontend is a STUB per the assignment: ``input_specs()`` provides
post-conv frame embeddings [B, enc_seq, d_model].  Positions are sinusoidal
for both stacks (whisper uses sinusoidal encoder / learned decoder positions;
we use sinusoidal on both so parameters are independent of the lowered
sequence length — recorded as a deviation in DESIGN.md).

Decode keeps per-layer self-attn KV caches plus the cross-attention K/V
computed once from the encoder output at prefill.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard
from .layers import (
    apply_norm,
    chunked_softmax_xent,
    cross_entropy,
    dtype_of,
    embed_tokens,
    init_embedding,
    init_norm,
    lm_logits,
)
from .transformer import (
    apply_program,
    decode_program,
    init_program,
    init_program_cache,
    prefill_program,
)


def sinusoid(seq: int, d: int, dtype) -> jnp.ndarray:
    pos = np.arange(seq)[:, None]
    i = np.arange(d // 2)[None, :]
    ang = pos / (10000 ** (2 * i / d))
    table = np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)
    return jnp.asarray(table, dtype)


@dataclass
class EncDecModel:
    cfg: ModelConfig

    def init(self, key):
        cfg = self.cfg
        ke, kenc, kdec = jax.random.split(key, 3)
        return {
            "embed": init_embedding(ke, cfg),
            "encoder": init_program(kenc, cfg, cfg.enc_program),
            "enc_norm": init_norm(cfg),
            "decoder": init_program(kdec, cfg, cfg.program),
            "final_norm": init_norm(cfg),
        }

    def init_shapes(self):
        return jax.eval_shape(self.init, jax.random.PRNGKey(0))

    def encode(self, params, frames):
        """frames [B, enc_seq, D] (stub frontend output) -> enc_out."""
        cfg = self.cfg
        x = frames.astype(dtype_of(cfg))
        x = x + sinusoid(x.shape[1], cfg.d_model, x.dtype)[None]
        x = shard(x, "batch", "seq", "embed")
        x, _ = apply_program(params["encoder"], x, cfg, cfg.enc_program, None, causal=False)
        return apply_norm(params["enc_norm"], x, cfg)

    def _embed_dec(self, params, tokens, pos0: int = 0):
        cfg = self.cfg
        x = embed_tokens(params["embed"], tokens, cfg)
        table = sinusoid(pos0 + x.shape[1], cfg.d_model, x.dtype)
        return x + table[pos0:][None]

    def loss(self, params, batch, remat: bool = True, remat_policy=None):
        cfg = self.cfg
        enc_out = self.encode(params, batch["frames"])
        x = self._embed_dec(params, batch["tokens"])
        x = shard(x, "batch", "seq", "embed")
        x, aux = apply_program(
            params["decoder"], x, cfg, cfg.program, None,
            enc_out=enc_out, causal=True, remat=remat, remat_policy=remat_policy,
        )
        x = apply_norm(params["final_norm"], x, cfg)
        ce = chunked_softmax_xent(x[:, :-1], params["embed"], batch["labels"][:, 1:], cfg)
        return ce, {"ce": ce, "aux": aux}

    def init_cache(self, batch: int, max_seq: int):
        return init_program_cache(self.cfg, self.cfg.program, batch, max_seq, dtype_of(self.cfg))

    def prefill(self, params, batch, max_seq: int | None = None):
        cfg = self.cfg
        enc_out = self.encode(params, batch["frames"])
        x = self._embed_dec(params, batch["tokens"])
        S = x.shape[1]
        x, cache = prefill_program(
            params["decoder"], x, cfg, cfg.program, None, max_seq or S, enc_out=enc_out
        )
        x = apply_norm(params["final_norm"], x, cfg)
        return lm_logits(params["embed"], x[:, -1:], cfg), cache

    def decode_step(self, params, cache, tokens, pos):
        cfg = self.cfg
        x = embed_tokens(params["embed"], tokens, cfg)
        # decode position embedding: one sinusoid row at `pos`
        half = cfg.d_model // 2
        i = jnp.arange(half, dtype=jnp.float32)
        ang = pos.astype(jnp.float32) / (10000 ** (2 * i / cfg.d_model))
        row = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)]).astype(x.dtype)
        x = x + row[None, None, :]
        x, new_cache = decode_program(params["decoder"], cache, x, pos, cfg, cfg.program, None)
        x = apply_norm(params["final_norm"], x, cfg)
        return lm_logits(params["embed"], x, cfg), new_cache
