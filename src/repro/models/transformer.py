"""Decoder-only LM assembly: program segments under lax.scan, all families.

One ``Model`` class serves the 8 decoder-only architectures (dense, MoE, MLA,
SSM, hybrid, VLM); ``encdec.py`` wraps it for whisper.  Execution modes:

  loss(params, batch)                      training forward+CE
  prefill(params, batch)                   full forward -> (last logits, cache)
  decode_step(params, cache, tokens, pos)  one token against the cache

Layers are grouped into program segments (configs/base.py); segments with
repeats > 1 run under ``lax.scan`` with stacked params, which keeps compile
time flat in depth and makes remat/offload policies uniform per layer class
(the granularity AutoSwap's planner operates on — see core/offload.py).
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from repro.configs.base import LayerSpec, ModelConfig, Segment
from repro.distributed.sharding import shard
from . import attention as attn_mod
from . import mla as mla_mod
from . import moe as moe_mod
from . import ssm as ssm_mod
from .layers import (
    apply_dense_ffn,
    apply_norm,
    chunked_softmax_xent,
    cross_entropy,
    dtype_of,
    embed_tokens,
    init_dense_ffn,
    init_embedding,
    init_norm,
    lm_logits,
    rmsnorm,
)
from .rope import mrope_angles, rope_angles

# ---------------------------------------------------------------- layers


def init_layer(key, cfg: ModelConfig, spec: LayerSpec):
    ks = jax.random.split(key, 6)
    p: dict[str, Any] = {"ln1": init_norm(cfg)}
    if spec.attn in ("full", "window"):
        p["attn"] = attn_mod.init_attention(ks[0], cfg, spec)
    elif spec.attn == "mla":
        p["attn"] = mla_mod.init_mla(ks[0], cfg, spec)
    elif spec.attn == "mamba":
        p["mamba"] = ssm_mod.init_mamba(ks[0], cfg)
    elif spec.attn == "hybrid":
        p["attn"] = attn_mod.init_attention(ks[0], cfg, spec)
        p["mamba"] = ssm_mod.init_mamba(ks[1], cfg)
        p["branch_norm_a"] = jnp.ones((cfg.d_model,), jnp.float32)
        p["branch_norm_m"] = jnp.ones((cfg.d_model,), jnp.float32)
    if cfg.sandwich_norms and spec.attn != "none":
        p["ln1_post"] = init_norm(cfg)
    if spec.cross_attn:
        p["ln_cross"] = init_norm(cfg)
        p["cross"] = attn_mod.init_cross_attention(ks[2], cfg)
    if spec.ffn == "dense":
        p["ln2"] = init_norm(cfg)
        p["ffn"] = init_dense_ffn(ks[3], cfg)
        if cfg.sandwich_norms:
            p["ln2_post"] = init_norm(cfg)
    elif spec.ffn == "moe":
        p["ln2"] = init_norm(cfg)
        p["moe"] = moe_mod.init_moe(ks[4], cfg)
        if cfg.sandwich_norms:
            p["ln2_post"] = init_norm(cfg)
    return p


def _mix(p, h, cfg, spec, angles, causal):
    """The token-mixing sublayer (attention family)."""
    if spec.attn in ("full", "window"):
        return attn_mod.apply_attention(p["attn"], h, cfg, spec, angles, causal=causal)
    if spec.attn == "mla":
        return mla_mod.apply_mla(p["attn"], h, cfg, spec, angles, causal=causal)
    if spec.attn == "mamba":
        return ssm_mod.apply_mamba(p["mamba"], h, cfg)
    if spec.attn == "hybrid":
        a = attn_mod.apply_attention(p["attn"], h, cfg, spec, angles, causal=causal)
        m = ssm_mod.apply_mamba(p["mamba"], h, cfg)
        return 0.5 * (
            rmsnorm(p["branch_norm_a"], a, cfg.norm_eps)
            + rmsnorm(p["branch_norm_m"], m, cfg.norm_eps)
        )
    return None


def apply_layer(p, x, cfg: ModelConfig, spec: LayerSpec, angles, enc_out=None, causal=True):
    """Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if spec.attn != "none":
        h = apply_norm(p["ln1"], x, cfg)
        h = _mix(p, h, cfg, spec, angles, causal)
        h = checkpoint_name(h, "attn_out")
        if cfg.sandwich_norms:
            h = apply_norm(p["ln1_post"], h, cfg)
        x = x + h
    if spec.cross_attn:
        h = apply_norm(p["ln_cross"], x, cfg)
        kv = attn_mod.encode_cross_kv(p["cross"], enc_out, cfg)
        h = attn_mod.apply_cross_attention(p["cross"], h, kv, cfg)
        x = x + h
    if spec.ffn == "dense":
        h = apply_norm(p["ln2"], x, cfg)
        h = apply_dense_ffn(p["ffn"], h, cfg)
        h = checkpoint_name(h, "ffn_out")
        if cfg.sandwich_norms:
            h = apply_norm(p["ln2_post"], h, cfg)
        x = x + h
    elif spec.ffn == "moe":
        h = apply_norm(p["ln2"], x, cfg)
        h, aux = moe_mod.apply_moe(p["moe"], h, cfg)
        h = checkpoint_name(h, "ffn_out")
        if cfg.sandwich_norms:
            h = apply_norm(p["ln2_post"], h, cfg)
        x = x + h
    x = shard(x, "batch", "seq", "embed")
    return x, aux


# ---------------------------------------------------------------- caches


def prefill_layer(p, x, cfg: ModelConfig, spec: LayerSpec, angles, max_seq: int, enc_out=None):
    """Forward one layer over the whole prompt, emitting its decode cache."""
    cache: dict[str, Any] = {}
    if spec.attn in ("full", "window"):
        h = apply_norm(p["ln1"], x, cfg)
        h, cache["kv"] = attn_mod.prefill_attention(p["attn"], h, cfg, spec, angles, max_seq)
        if cfg.sandwich_norms:
            h = apply_norm(p["ln1_post"], h, cfg)
        x = x + h
    elif spec.attn == "mla":
        h = apply_norm(p["ln1"], x, cfg)
        h, cache["kv"] = mla_mod.prefill_mla(p["attn"], h, cfg, spec, angles, max_seq)
        x = x + h
    elif spec.attn == "mamba":
        h = apply_norm(p["ln1"], x, cfg)
        h, cache["ssm"] = ssm_mod.apply_mamba(p["mamba"], h, cfg, return_cache=True)
        x = x + h
    elif spec.attn == "hybrid":
        h = apply_norm(p["ln1"], x, cfg)
        a, cache["kv"] = attn_mod.prefill_attention(p["attn"], h, cfg, spec, angles, max_seq)
        m, cache["ssm"] = ssm_mod.apply_mamba(p["mamba"], h, cfg, return_cache=True)
        h = 0.5 * (
            rmsnorm(p["branch_norm_a"], a, cfg.norm_eps)
            + rmsnorm(p["branch_norm_m"], m, cfg.norm_eps)
        )
        x = x + h
    if spec.cross_attn:
        cache["enc_kv"] = attn_mod.encode_cross_kv(p["cross"], enc_out, cfg)
        h = apply_norm(p["ln_cross"], x, cfg)
        h = attn_mod.apply_cross_attention(p["cross"], h, cache["enc_kv"], cfg)
        x = x + h
    if spec.ffn == "dense":
        h = apply_norm(p["ln2"], x, cfg)
        h = apply_dense_ffn(p["ffn"], h, cfg)
        if cfg.sandwich_norms:
            h = apply_norm(p["ln2_post"], h, cfg)
        x = x + h
    elif spec.ffn == "moe":
        h = apply_norm(p["ln2"], x, cfg)
        h, _ = moe_mod.apply_moe(p["moe"], h, cfg)
        if cfg.sandwich_norms:
            h = apply_norm(p["ln2_post"], h, cfg)
        x = x + h
    x = shard(x, "batch", "seq", "embed")
    return x, cache


def prefill_program(segs, x, cfg, program, angles, max_seq: int, enc_out=None):
    caches = []
    for (unit, reps), seg_params in zip(program, segs):

        def unit_fn(params, x):
            cache = {}
            for i, spec in enumerate(unit):
                x, cache[f"l{i}"] = prefill_layer(
                    params[f"l{i}"], x, cfg, spec, angles, max_seq, enc_out
                )
            return x, cache

        if reps > 1:

            def body(x, params):
                return unit_fn(params, x)

            x, seg_cache = jax.lax.scan(body, x, seg_params)
        else:
            x, seg_cache = unit_fn(seg_params, x)
        caches.append(seg_cache)
    return x, caches


def init_layer_cache(cfg: ModelConfig, spec: LayerSpec, batch: int, max_seq: int, dtype):
    c: dict[str, Any] = {}
    if spec.attn in ("full", "window"):
        c["kv"] = attn_mod.init_kv_cache(cfg, spec, batch, max_seq, dtype)
    elif spec.attn == "mla":
        c["kv"] = mla_mod.init_mla_cache(cfg, batch, max_seq, dtype)
    elif spec.attn == "mamba":
        c["ssm"] = ssm_mod.init_mamba_cache(cfg, batch, dtype)
    elif spec.attn == "hybrid":
        c["kv"] = attn_mod.init_kv_cache(cfg, spec, batch, max_seq, dtype)
        c["ssm"] = ssm_mod.init_mamba_cache(cfg, batch, dtype)
    if spec.cross_attn:
        # enc k/v get filled at prefill time
        H, hd = cfg.num_kv_heads, cfg.head_dim
        c["enc_kv"] = (
            jnp.zeros((batch, cfg.enc_seq, H, hd), dtype),
            jnp.zeros((batch, cfg.enc_seq, H, hd), dtype),
        )
    return c


def decode_layer(p, x, cache, pos, cfg: ModelConfig, spec: LayerSpec, angles):
    new_cache = dict(cache)
    if spec.attn in ("full", "window"):
        h = apply_norm(p["ln1"], x, cfg)
        h, new_cache["kv"] = attn_mod.decode_attention(
            p["attn"], h, cache["kv"], pos, cfg, spec, angles
        )
        if cfg.sandwich_norms:
            h = apply_norm(p["ln1_post"], h, cfg)
        x = x + h
    elif spec.attn == "mla":
        h = apply_norm(p["ln1"], x, cfg)
        h, new_cache["kv"] = mla_mod.decode_mla(p["attn"], h, cache["kv"], pos, cfg, spec, angles)
        x = x + h
    elif spec.attn == "mamba":
        h = apply_norm(p["ln1"], x, cfg)
        h, new_cache["ssm"] = ssm_mod.decode_mamba(p["mamba"], h, cache["ssm"], cfg)
        x = x + h
    elif spec.attn == "hybrid":
        h = apply_norm(p["ln1"], x, cfg)
        a, new_cache["kv"] = attn_mod.decode_attention(
            p["attn"], h, cache["kv"], pos, cfg, spec, angles
        )
        m, new_cache["ssm"] = ssm_mod.decode_mamba(p["mamba"], h, cache["ssm"], cfg)
        h = 0.5 * (
            rmsnorm(p["branch_norm_a"], a, cfg.norm_eps)
            + rmsnorm(p["branch_norm_m"], m, cfg.norm_eps)
        )
        x = x + h
    if spec.cross_attn:
        h = apply_norm(p["ln_cross"], x, cfg)
        h = attn_mod.apply_cross_attention(p["cross"], h, cache["enc_kv"], cfg)
        x = x + h
    if spec.ffn == "dense":
        h = apply_norm(p["ln2"], x, cfg)
        h = apply_dense_ffn(p["ffn"], h, cfg)
        if cfg.sandwich_norms:
            h = apply_norm(p["ln2_post"], h, cfg)
        x = x + h
    elif spec.ffn == "moe":
        h = apply_norm(p["ln2"], x, cfg)
        h, _ = moe_mod.apply_moe(p["moe"], h, cfg)
        if cfg.sandwich_norms:
            h = apply_norm(p["ln2_post"], h, cfg)
        x = x + h
    return x, new_cache


# --------------------------------------------------------------- program


def init_program(key, cfg: ModelConfig, program: tuple[Segment, ...]):
    """Returns a list of segment params; repeats > 1 get stacked leaves."""
    segs = []
    for si, (unit, reps) in enumerate(program):
        kseg = jax.random.fold_in(key, si)

        def init_unit(k):
            return {
                f"l{i}": init_layer(jax.random.fold_in(k, i), cfg, spec)
                for i, spec in enumerate(unit)
            }

        if reps > 1:
            segs.append(jax.vmap(init_unit)(jax.random.split(kseg, reps)))
        else:
            segs.append(init_unit(kseg))
    return segs


def apply_program(
    segs,
    x,
    cfg: ModelConfig,
    program: tuple[Segment, ...],
    angles,
    enc_out=None,
    causal=True,
    remat: bool = False,
    remat_policy=None,
):
    """Returns (x, total_aux).

    ``remat_policy`` is a jax.checkpoint policy (e.g. the offload policies
    built by core/offload.py); ``remat=True, remat_policy=None`` is full
    per-unit rematerialization.
    """
    total_aux = jnp.zeros((), jnp.float32)
    for (unit, reps), seg_params in zip(program, segs):

        def unit_fn(params, x):
            x = checkpoint_name(x, "block_in")
            aux = jnp.zeros((), jnp.float32)
            for i, spec in enumerate(unit):
                x, a = apply_layer(params[f"l{i}"], x, cfg, spec, angles, enc_out, causal)
                aux = aux + a
            return x, aux

        if remat:
            unit_fn = jax.checkpoint(unit_fn, policy=remat_policy)

        if reps > 1:

            def body(carry, params):
                x, aux = carry
                x, a = unit_fn(params, x)
                return (x, aux + a), None

            (x, total_aux), _ = jax.lax.scan(body, (x, total_aux), seg_params)
        else:
            x, a = unit_fn(seg_params, x)
            total_aux = total_aux + a
    return x, total_aux


def init_program_cache(cfg, program, batch, max_seq, dtype):
    caches = []
    for unit, reps in program:
        unit_cache = {
            f"l{i}": init_layer_cache(cfg, spec, batch, max_seq, dtype)
            for i, spec in enumerate(unit)
        }
        if reps > 1:
            unit_cache = jax.tree.map(
                lambda a: jnp.zeros((reps,) + a.shape, a.dtype), unit_cache
            )
        caches.append(unit_cache)
    return caches


def decode_program(segs, caches, x, pos, cfg, program, angles):
    new_caches = []
    for (unit, reps), seg_params, seg_cache in zip(program, segs, caches):

        def unit_fn(params, cache, x):
            new_cache = {}
            for i, spec in enumerate(unit):
                x, new_cache[f"l{i}"] = decode_layer(
                    params[f"l{i}"], x, cache[f"l{i}"], pos, cfg, spec, angles
                )
            return x, new_cache

        if reps > 1:

            def body(x, pc):
                params, cache = pc
                x, nc = unit_fn(params, cache, x)
                return x, nc

            x, nc = jax.lax.scan(body, x, (seg_params, seg_cache))
        else:
            x, nc = unit_fn(seg_params, seg_cache, x)
        new_caches.append(nc)
    return x, new_caches


# ------------------------------------------------------------------ model
@dataclass
class Model:
    cfg: ModelConfig

    # ---- parameters ----
    def init(self, key):
        cfg = self.cfg
        ke, kp = jax.random.split(key)
        params = {
            "embed": init_embedding(ke, cfg),
            "blocks": init_program(kp, cfg, cfg.program),
            "final_norm": init_norm(cfg),
        }
        return params

    def init_shapes(self):
        return jax.eval_shape(self.init, jax.random.PRNGKey(0))

    # ---- positions/angles ----
    def _angles(self, positions):
        cfg = self.cfg
        if cfg.num_heads == 0:
            return None
        hd = cfg.qk_rope_head_dim if cfg.kv_lora_rank else cfg.head_dim
        if cfg.mrope_sections is not None:
            return mrope_angles(positions, hd, cfg.rope_theta, cfg.mrope_sections)
        return rope_angles(positions, hd, cfg.rope_theta)

    def _embed_inputs(self, params, batch):
        """tokens (+ VLM patch embeds) -> (x [B,S,D], positions)."""
        cfg = self.cfg
        x = embed_tokens(params["embed"], batch["tokens"], cfg)
        if cfg.frontend == "vision_stub" and "patch_embeds" in batch:
            patches = batch["patch_embeds"].astype(x.dtype)
            x = jnp.concatenate([patches, x], axis=1)
        if cfg.scale_embed:
            x = x * jnp.asarray(cfg.d_model**0.5, x.dtype)
        B, S, _ = x.shape
        if "positions" in batch:
            positions = batch["positions"]
        else:
            positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        return x, positions

    # ---- training ----
    def loss(self, params, batch, remat: bool = True, remat_policy=None):
        cfg = self.cfg
        x, positions = self._embed_inputs(params, batch)
        x = shard(x, "batch", "seq", "embed")
        angles = self._angles(positions)
        x, aux = apply_program(
            params["blocks"], x, cfg, cfg.program, angles,
            remat=remat, remat_policy=remat_policy,
        )
        x = apply_norm(params["final_norm"], x, cfg)
        labels = batch["labels"]
        if cfg.frontend == "vision_stub" and "patch_embeds" in batch:
            npatch = batch["patch_embeds"].shape[1]
            pad = jnp.full(labels.shape[:1] + (npatch,), -1, labels.dtype)
            labels = jnp.concatenate([pad, labels], axis=1)
        # chunked CE: position i predicts labels[i+1]; never materializes BSV
        ce = chunked_softmax_xent(x[:, :-1], params["embed"], labels[:, 1:], cfg)
        loss = ce + 0.01 * aux
        return loss, {"ce": ce, "aux": aux}

    # ---- serving ----
    def init_cache(self, batch: int, max_seq: int):
        return init_program_cache(
            self.cfg, self.cfg.program, batch, max_seq, dtype_of(self.cfg)
        )

    def prefill(self, params, batch, max_seq: int | None = None):
        """Forward the prompt, return (last-position logits, filled cache)."""
        cfg = self.cfg
        x, positions = self._embed_inputs(params, batch)
        x = shard(x, "batch", "seq", "embed")
        angles = self._angles(positions)
        S = x.shape[1]
        max_seq = max_seq or S
        x, cache = prefill_program(
            params["blocks"], x, cfg, cfg.program, angles, max_seq
        )
        x = apply_norm(params["final_norm"], x, cfg)
        logits = lm_logits(params["embed"], x[:, -1:], cfg)
        return logits, cache

    def decode_step(self, params, cache, tokens, pos):
        """tokens [B,1] int32, pos scalar int32 -> (logits [B,1,V], cache)."""
        cfg = self.cfg
        x = embed_tokens(params["embed"], tokens, cfg)
        if cfg.scale_embed:
            x = x * jnp.asarray(cfg.d_model**0.5, x.dtype)
        B = x.shape[0]
        positions = jnp.broadcast_to(pos[None, None], (B, 1))
        if cfg.mrope_sections is not None:
            positions = jnp.broadcast_to(pos[None, None, None], (3, B, 1))
        angles = self._angles(positions)
        x, new_cache = decode_program(
            params["blocks"], cache, x, pos, cfg, cfg.program, angles
        )
        x = apply_norm(params["final_norm"], x, cfg)
        logits = lm_logits(params["embed"], x, cfg)
        return logits, new_cache
