"""Mixture-of-Experts FFN with sort-based capacity dispatch (EP-friendly).

Dispatch is gather/scatter (no [T, E, C] one-hot matmul): token-expert pairs
are sorted by expert, ranked within their expert group, and dropped beyond
capacity C = ceil(T * top_k / E * capacity_factor).  FLOPs are therefore the
honest E*C*(3*2*d*f) expert compute — crucial for roofline fidelity (a dense
one-hot dispatch would inflate llama4's compute 128x).

Expert weights are [E, d, f]; sharding E over the `model` mesh axis gives
expert parallelism (llama4: 128/16 = 8 experts per shard); the scatter/gather
lowers to all-to-all under GSPMD.

Routers: softmax top-k with renormalization (deepseek) or sigmoid top-1
(llama4).  An auxiliary load-balance loss (Switch-style) is returned for
training.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard
from .layers import init_dense_ffn, apply_dense_ffn


def init_moe(key, cfg: ModelConfig):
    d, f, E = cfg.d_model, cfg.moe_d_ff or cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 5)
    s_in, s_out = 1.0 / np.sqrt(d), 1.0 / np.sqrt(f)
    p = {
        "router": jax.random.normal(ks[0], (d, E), jnp.float32) * s_in,
        "w_gate": jax.random.normal(ks[1], (E, d, f), jnp.float32) * s_in,
        "w_up": jax.random.normal(ks[2], (E, d, f), jnp.float32) * s_in,
        "w_down": jax.random.normal(ks[3], (E, f, d), jnp.float32) * s_out,
    }
    if cfg.num_shared_experts:
        shared_f = f * cfg.num_shared_experts
        p["shared"] = init_dense_ffn(ks[4], cfg, d_ff=shared_f)
    return p


def _route(p, xt, cfg: ModelConfig):
    """xt [T, D] -> (gates [T,k], idx [T,k], aux_loss scalar)."""
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), p["router"])
    k, E = cfg.top_k, cfg.num_experts
    if cfg.router_type == "sigmoid":
        probs = jax.nn.sigmoid(logits)
        gates, idx = jax.lax.top_k(probs, k)
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        gates, idx = jax.lax.top_k(probs, k)
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance aux: E * sum_e f_e * p_e.
    onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32).sum(1)        # [T, E]
    f_e = onehot.mean(0)
    p_e = probs.mean(0) if cfg.router_type != "sigmoid" else jax.nn.softmax(logits, -1).mean(0)
    aux = E * jnp.sum(f_e * p_e)
    return gates, idx, aux


def apply_moe_shardmap(p, x, cfg: ModelConfig):
    """Explicit expert-parallel MoE under shard_map (the "moe_shardmap"
    §Perf profile).

    GSPMD cannot partition the data-dependent dispatch scatter without
    resorting to full-tensor all-gathers/all-reduces (measured: 1.8-12 TB
    per device per step on deepseek train_4k).  Here the collective schedule
    is written by hand instead:

      per (data, model) rank: route OWN seq-slice tokens -> local sort ->
      send buffer [E, C, D] -> all_to_all over "model" (the EP exchange) ->
      local expert GEMMs on the rank's E/M experts -> reverse all_to_all ->
      local combine.

    Per-device collective volume is exactly 2 * T_local * k * D bytes of
    all-to-all per layer — the EP floor.  The shared expert and the aux loss
    run outside (plain GSPMD).  Output is seq-sharded over "model" (each
    rank computed its seq slice); the residual add re-gathers it.
    """
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    from repro.distributed.sharding import _CTX, batch_axes

    mesh = _CTX.mesh
    dt = x.dtype
    B, S, D = x.shape
    k, E = cfg.top_k, cfg.num_experts
    M = mesh.shape["model"]
    b_axes = batch_axes(mesh)
    DP = 1
    for a in b_axes:
        DP *= mesh.shape[a]
    T_lm = (B // DP) * (S // M)              # tokens per rank
    C = int(np.ceil(T_lm * k / E * cfg.capacity_factor))
    C = max(8, -(-C // 8) * 8)
    E_loc = E // M

    # llama4-scale models keep expert weights FSDP-sharded (F over "data") at
    # rest; the body all-gathers them in bf16 per layer (ZeRO-3 semantics).
    fsdp_gather = bool((_CTX.rules or {}).get("moe_fsdp_gather"))

    def body(xb, router, wg, wu, wd):
        # xb [B_l, S, D] (replicated over model); take this rank's seq slice
        m = jax.lax.axis_index("model")
        B_l = xb.shape[0]
        xs = jax.lax.dynamic_slice_in_dim(xb, m * (S // M), S // M, axis=1)
        xt = xs.reshape(T_lm, D)
        if fsdp_gather:
            wg = jax.lax.all_gather(wg.astype(dt), "data", axis=2, tiled=True)
            wu = jax.lax.all_gather(wu.astype(dt), "data", axis=2, tiled=True)
            wd = jax.lax.all_gather(wd.astype(dt), "data", axis=1, tiled=True)

        logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), router)
        if cfg.router_type == "sigmoid":
            probs = jax.nn.sigmoid(logits)
            gates, idx = jax.lax.top_k(probs, k)
        else:
            probs = jax.nn.softmax(logits, axis=-1)
            gates, idx = jax.lax.top_k(probs, k)
            gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

        flat_e = idx.reshape(-1)
        order = jnp.argsort(flat_e, stable=True)
        sorted_e = flat_e[order]
        counts = jnp.zeros((E,), jnp.int32).at[flat_e].add(1)
        start = jnp.cumsum(counts) - counts
        rank_ = jnp.arange(T_lm * k) - start[sorted_e]
        token_of = order // k

        send = jnp.zeros((E, C, D), dt).at[sorted_e, rank_].set(
            xt[token_of], mode="drop"
        )
        # EP exchange: expert e lives on rank e // E_loc
        recv = jax.lax.all_to_all(
            send.reshape(M, E_loc, C, D), "model", split_axis=0, concat_axis=0,
            tiled=True,
        )                                     # [M_src, E_loc, C, D]
        h = recv.transpose(1, 0, 2, 3).reshape(E_loc, M * C, D)
        g_ = jnp.einsum("ecd,edf->ecf", h, wg.astype(dt))
        u_ = jnp.einsum("ecd,edf->ecf", h, wu.astype(dt))
        y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g_) * u_, wd.astype(dt))
        back = jax.lax.all_to_all(
            y.reshape(E_loc, M, C, D).transpose(1, 0, 2, 3), "model",
            split_axis=0, concat_axis=0, tiled=True,
        )                                     # [M, E_loc, C, D] expert-major
        rows_all = back.reshape(E, C, D)
        rows = rows_all.at[sorted_e, rank_].get(mode="fill", fill_value=0)
        contrib = rows * gates.reshape(-1)[order][:, None].astype(dt)
        out = jnp.zeros((T_lm, D), dt).at[token_of].add(contrib)
        return out.reshape(B_l, S // M, D)

    bspec = P(b_axes if len(b_axes) > 1 else (b_axes[0] if b_axes else None), None, None)
    if fsdp_gather:
        w_specs = (P("model", None, "data"), P("model", None, "data"),
                   P("model", "data", None))
    else:
        w_specs = (P("model", None, None),) * 3
    out = shard_map(
        body,
        mesh=mesh,
        in_specs=(bspec, P(None, None)) + w_specs,
        out_specs=P(bspec[0], "model", None),
        check_rep=False,
    )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])

    aux = jnp.zeros((), jnp.float32)  # load-balance loss skipped in EP mode
    if cfg.num_shared_experts:
        out = out + apply_dense_ffn(p["shared"], x.reshape(B * S, D), cfg).reshape(
            B, S, D
        )
    return out, aux


def apply_moe(p, x, cfg: ModelConfig):
    """x [B, S, D] -> (y [B, S, D], aux_loss).

    Dispatch is *grouped*: tokens are split into G groups matching the batch
    sharding (G = token_group_count(); 1 on a single device / baseline
    profile).  Sorting, capacity ranking, scatter and gather all use
    group-local indices, so under the "moe_local" profile every index
    operation is shard-local — no cross-device all-reduce of [T, D] scatter
    partials (the dominant collective of the naive global dispatch; see
    EXPERIMENTS.md §Perf cell B).  Capacity is per group (C/G each), which
    slightly raises drop variance vs a global capacity pool — recorded in
    DESIGN.md.
    """
    from repro.distributed.sharding import _CTX, token_group_count

    rules = _CTX.rules or {}
    if rules.get("moe_impl") == "shard_map" and _CTX.mesh is not None and not _CTX.mesh.empty:
        return apply_moe_shardmap(p, x, cfg)

    dt = x.dtype
    B, S, D = x.shape
    T = B * S
    k, E = cfg.top_k, cfg.num_experts
    G = token_group_count()
    if T % G:
        G = 1
    Tg = T // G
    xt = x.reshape(T, D)

    gates, idx, aux = _route(p, xt, cfg)
    C = int(np.ceil(Tg * k / E * cfg.capacity_factor))
    C = max(8, -(-C // 8) * 8)  # multiple of 8, >= 8

    flat_e = idx.reshape(G, Tg * k)                           # [G, Tg*k]
    order = jnp.argsort(flat_e, axis=-1, stable=True)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)
    counts = jax.nn.one_hot(flat_e, E, dtype=jnp.int32).sum(1)  # [G, E]
    start = jnp.cumsum(counts, axis=-1) - counts
    rank = jnp.arange(Tg * k)[None] - jnp.take_along_axis(start, sorted_e, axis=-1)
    token_of = order // k                                     # group-local ids
    g_idx = jnp.arange(G)[:, None] * jnp.ones((1, Tg * k), jnp.int32)

    # Group x expert layout: buf [G, E, C, D] sharded (batch, experts) — each
    # device scatters its own tokens into its own experts' rows; rank >= C
    # drops (mode="drop").
    xg = shard(xt.reshape(G, Tg, D), "tokens", None, None)
    xin = jnp.take_along_axis(xg, token_of[..., None], axis=1)  # [G, Tg*k, D]
    buf = shard(jnp.zeros((G, E, C, D), dt), "tokens", "experts", None, None)
    buf = buf.at[g_idx, sorted_e, rank].set(xin, mode="drop")
    g_ = jnp.einsum("gecd,edf->gecf", buf, p["w_gate"].astype(dt))
    u = jnp.einsum("gecd,edf->gecf", buf, p["w_up"].astype(dt))
    y = jnp.einsum("gecf,efd->gecd", jax.nn.silu(g_) * u, p["w_down"].astype(dt))
    y = shard(y, "tokens", "experts", None, None)

    gate_sorted = jnp.take_along_axis(gates.reshape(G, Tg * k), order, axis=-1)
    rows = y.at[g_idx, sorted_e, rank].get(mode="fill", fill_value=0)  # [G,Tg*k,D]
    contrib = rows * gate_sorted[..., None].astype(dt)
    out = jnp.zeros((G, Tg, D), dt).at[g_idx, token_of].add(contrib)
    out = shard(out, "tokens", None, None).reshape(T, D)

    if cfg.num_shared_experts:
        out = out + apply_dense_ffn(p["shared"], xt, cfg)
    return out.reshape(B, S, D), aux
