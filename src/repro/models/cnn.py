"""VGG and ResNet on CIFAR-10 — the paper's own benchmark networks (§VI).

These exist to reproduce the paper's tables: their jaxpr traces (via
core/trace.py) are the offline-DSA / AutoSwap problem instances for Table I,
Table II and Figs 9-11.  Implemented with lax.conv so they also *run* (the
allocator benchmarks never execute them; the smoke tests do, at tiny batch).

Depth configs follow the torch blogs the paper cites: VGG-style convs with
BN-free plain conv+relu (paper's SINGA lacks BN fusions anyway), ResNet
basic/bottleneck blocks.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

VGG_PLANS = {
    "vgg11": [64, "M", 128, "M", 256, 256, "M", 512, 512, "M", 512, 512, "M"],
    "vgg13": [64, 64, "M", 128, 128, "M", 256, 256, "M", 512, 512, "M", 512, 512, "M"],
    "vgg16": [64, 64, "M", 128, 128, "M", 256, 256, 256, "M",
              512, 512, 512, "M", 512, 512, 512, "M"],
    "vgg19": [64, 64, "M", 128, 128, "M", 256, 256, 256, 256, "M",
              512, 512, 512, 512, "M", 512, 512, 512, 512, "M"],
}

# (block, layers per stage, bottleneck?)
RESNET_PLANS = {
    "resnet18": ([2, 2, 2, 2], False),
    "resnet34": ([3, 4, 6, 3], False),
    "resnet50": ([3, 4, 6, 3], True),
    "resnet101": ([3, 4, 23, 3], True),
}


def _conv(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    )


# ----------------------------------------------------------------- VGG
def init_vgg(key, name: str, num_classes: int = 10):
    plan = VGG_PLANS[name]
    params = []
    cin = 3
    for i, item in enumerate(plan):
        if item == "M":
            params.append(None)
            continue
        k = jax.random.fold_in(key, i)
        w = jax.random.normal(k, (3, 3, cin, item), jnp.float32) * np.sqrt(2.0 / (9 * cin))
        params.append({"w": w, "b": jnp.zeros((item,), jnp.float32)})
        cin = item
    kf = jax.random.fold_in(key, 10_000)
    params.append({
        "w": jax.random.normal(kf, (cin, num_classes), jnp.float32) * 0.01,
        "b": jnp.zeros((num_classes,), jnp.float32),
    })
    return {"layers": params}


def apply_vgg(params, x, name: str):
    plan = VGG_PLANS[name]
    for item, p in zip(plan, params["layers"]):
        if item == "M":
            x = jax.lax.reduce_window(
                x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
            )
        else:
            x = jax.nn.relu(_conv(x, p["w"]) + p["b"])
    x = x.mean(axis=(1, 2))
    head = params["layers"][-1]
    return x @ head["w"] + head["b"]


# --------------------------------------------------------------- ResNet
def _init_block(key, cin, cout, stride, bottleneck):
    ks = jax.random.split(key, 4)

    def w(k, kh, kw, ci, co):
        return jax.random.normal(k, (kh, kw, ci, co), jnp.float32) * np.sqrt(
            2.0 / (kh * kw * ci)
        )

    p = {}
    if bottleneck:
        mid = cout // 4
        p["c1"] = w(ks[0], 1, 1, cin, mid)
        p["c2"] = w(ks[1], 3, 3, mid, mid)
        p["c3"] = w(ks[2], 1, 1, mid, cout)
    else:
        p["c1"] = w(ks[0], 3, 3, cin, cout)
        p["c2"] = w(ks[1], 3, 3, cout, cout)
    if stride != 1 or cin != cout:
        p["proj"] = w(ks[3], 1, 1, cin, cout)
    return p


def _apply_block(p, x, stride, bottleneck):
    identity = x
    if bottleneck:
        h = jax.nn.relu(_conv(x, p["c1"]))
        h = jax.nn.relu(_conv(h, p["c2"], stride))
        h = _conv(h, p["c3"])
    else:
        h = jax.nn.relu(_conv(x, p["c1"], stride))
        h = _conv(h, p["c2"])
    if "proj" in p:
        identity = _conv(x, p["proj"], stride)
    return jax.nn.relu(h + identity)


def init_resnet(key, name: str, num_classes: int = 10):
    stages, bottleneck = RESNET_PLANS[name]
    widths = [64, 128, 256, 512]
    if bottleneck:
        widths = [w * 4 for w in widths]
    params = {"stem": jax.random.normal(key, (3, 3, 3, 64), jnp.float32) * np.sqrt(2.0 / 27)}
    cin = 64
    blocks = []
    for si, (n, cout) in enumerate(zip(stages, widths)):
        for bi in range(n):
            stride = 2 if (si > 0 and bi == 0) else 1
            k = jax.random.fold_in(key, si * 100 + bi)
            blocks.append(_init_block(k, cin, cout, stride, bottleneck))
            cin = cout
    params["blocks"] = blocks
    kf = jax.random.fold_in(key, 99_999)
    params["head"] = {
        "w": jax.random.normal(kf, (cin, num_classes), jnp.float32) * 0.01,
        "b": jnp.zeros((num_classes,), jnp.float32),
    }
    return params


def apply_resnet(params, x, name: str):
    stages, bottleneck = RESNET_PLANS[name]
    x = jax.nn.relu(_conv(x, params["stem"]))
    i = 0
    for si, n in enumerate(stages):
        for bi in range(n):
            stride = 2 if (si > 0 and bi == 0) else 1
            x = _apply_block(params["blocks"][i], x, stride, bottleneck)
            i += 1
    x = x.mean(axis=(1, 2))
    return x @ params["head"]["w"] + params["head"]["b"]


# ------------------------------------------------------------ train step
@dataclass
class CNN:
    name: str

    def init(self, key):
        if self.name.startswith("vgg"):
            return init_vgg(key, self.name)
        return init_resnet(key, self.name)

    def apply(self, params, x):
        if self.name.startswith("vgg"):
            return apply_vgg(params, x, self.name)
        return apply_resnet(params, x, self.name)

    def loss(self, params, x, y):
        logits = self.apply(params, x)
        logp = jax.nn.log_softmax(logits)
        return -jnp.take_along_axis(logp, y[:, None], axis=1).mean()

    def loss_remat(self, params, x, y, segments: int = 4):
        """Memonger-style segmented recompute: the network is cut into
        `segments` checkpointed chunks; only chunk boundaries survive the
        forward pass (trading compute for memory, paper Fig 11 baseline)."""
        if self.name.startswith("vgg"):
            plan = VGG_PLANS[self.name]
            entries = list(zip(plan, params["layers"]))
            per = max(1, len(entries) // segments)
            h = x
            for s0 in range(0, len(entries), per):
                chunk = entries[s0 : s0 + per]

                def seg(h, chunk=chunk):
                    for item, p in chunk:
                        if item == "M":
                            h = jax.lax.reduce_window(
                                h, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
                            )
                        else:
                            h = jax.nn.relu(_conv(h, p["w"]) + p["b"])
                    return h

                h = jax.checkpoint(seg)(h)
            h = h.mean(axis=(1, 2))
            head = params["layers"][-1]
            logits = h @ head["w"] + head["b"]
        else:
            stages, bottleneck = RESNET_PLANS[self.name]
            order = []
            for si, n in enumerate(stages):
                for bi in range(n):
                    order.append((2 if (si > 0 and bi == 0) else 1))
            h = jax.nn.relu(_conv(x, params["stem"]))
            per = max(1, len(order) // segments)
            for s0 in range(0, len(order), per):
                idxs = list(range(s0, min(s0 + per, len(order))))

                def seg(h, idxs=idxs):
                    for i in idxs:
                        h = _apply_block(params["blocks"][i], h, order[i], bottleneck)
                    return h

                h = jax.checkpoint(seg)(h)
            h = h.mean(axis=(1, 2))
            logits = h @ params["head"]["w"] + params["head"]["b"]
        logp = jax.nn.log_softmax(logits)
        return -jnp.take_along_axis(logp, y[:, None], axis=1).mean()

    def train_step(self, params, momentum, x, y, lr=0.01, mu=0.9):
        """SGD+momentum step (the paper trains with SGD on CIFAR-10)."""
        g = jax.grad(self.loss)(params, x, y)

        def upd(p, m, gg):
            if gg is None:
                return p, m
            m2 = mu * m + gg
            return p - lr * m2, m2

        new = jax.tree.map(upd, params, momentum, g)
        new_p = jax.tree.map(lambda t: t[0], new, is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree.map(lambda t: t[1], new, is_leaf=lambda t: isinstance(t, tuple))
        return new_p, new_m

    def trace_inputs(self, batch: int = 100):
        return (
            jax.ShapeDtypeStruct((batch, 32, 32, 3), jnp.float32),
            jax.ShapeDtypeStruct((batch,), jnp.int32),
        )
