"""Model zoo: one builder for every assigned architecture family."""

from __future__ import annotations

from repro.configs.base import ModelConfig

from .cnn import CNN  # noqa: F401
from .encdec import EncDecModel
from .transformer import Model


def build_model(cfg: ModelConfig):
    """Returns the family-appropriate model object (shared API:
    init/loss/prefill/decode_step/init_cache)."""
    if cfg.is_encoder_decoder:
        return EncDecModel(cfg)
    return Model(cfg)
