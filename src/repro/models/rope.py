"""Rotary position embeddings: standard RoPE and M-RoPE (qwen2-vl).

Positions are explicit everywhere so that decode (single position), prefill
(arange) and M-RoPE (3-channel t/h/w positions) share one code path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    """[head_dim/2] inverse frequencies."""
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def rope_angles(positions: jnp.ndarray, head_dim: int, theta: float) -> jnp.ndarray:
    """positions [...] -> angles [..., head_dim/2] (f32)."""
    inv = rope_freqs(head_dim, theta)
    return positions.astype(jnp.float32)[..., None] * inv


def mrope_angles(
    positions: jnp.ndarray, head_dim: int, theta: float, sections: tuple[int, int, int]
) -> jnp.ndarray:
    """M-RoPE: positions [3, ...] (t/h/w) -> angles [..., head_dim/2].

    The frequency spectrum is partitioned into ``sections`` (in units of
    freq pairs, summing to head_dim/2); each section takes its position from
    the corresponding channel.  Text tokens carry identical t/h/w positions,
    which makes M-RoPE coincide with RoPE for them.
    """
    assert sum(sections) == head_dim // 2, (sections, head_dim)
    inv = rope_freqs(head_dim, theta)
    full = positions.astype(jnp.float32)[..., None] * inv  # [3, ..., half]
    chunks = []
    start = 0
    for ch, width in enumerate(sections):
        chunks.append(full[ch, ..., start : start + width])
        start += width
    return jnp.concatenate(chunks, axis=-1)


def apply_rope(x: jnp.ndarray, angles: jnp.ndarray) -> jnp.ndarray:
    """x [..., S, n, head_dim] (or [..., S, head_dim]); angles [..., S, head_dim/2]."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if angles.ndim == x.ndim - 1:       # broadcast over the head axis
        angles = angles[..., None, :]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    cos, sin = cos.astype(x.dtype), sin.astype(x.dtype)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
