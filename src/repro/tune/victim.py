"""Ledger-driven renegotiation victim selection (Issue 8 tentpole, part 1).

``FloorGreedyVictim`` (the engine default) shrinks the first eligible
victim by exactly the bytes the newcomer needs — it never asks what that
shrink *costs*.  ``LedgerVictimPolicy`` does: for each candidate
(victim, limit) pair it clones the live engine at the current loop-top
(``MemoryRuntime._probe_clone``), stages the candidate re-plan on the
clone, ``resume()``s the remaining horizon, and scores the simulated
future by SLO-weighted total stall.  The candidate minimizing the
objective is staged for real; the winner's attribution ledger names the
binding constraint (transfer / channel_contention / blackout) in the
policy's decision log.

Probe isolation is by construction: every candidate gets a *fresh* clone
of the pristine live state, so concurrent candidate probes at the same
barrier can never observe each other's staged reservations (the
double-counting bug this Issue's satellite pins with a regression test).
The clone swaps in a ``FloorGreedyVictim`` so downstream renegotiations
inside a probe never recurse into probing.
"""

from __future__ import annotations

from ..runtime.engine import VictimPolicy, planned_peak
from .objective import binding_constraint, slo_weighted_stall


class LedgerVictimPolicy(VictimPolicy):
    """Score K candidate (victim, limit) pairs by simulated marginal ledger.

    ``deferred=True``: the engine invokes ``choose`` at the next event-loop
    top, the only point where a snapshot/resume probe sees a consistent
    between-events state.  Candidates are the first ``max_victims`` eligible
    victims crossed with ``limit_fracs`` shrink depths (1.0 = exactly the
    bytes needed, lower = shrink deeper so the *next* newcomer may not need
    a renegotiation at all); infeasible solves (new floor doesn't free
    ``needed`` bytes) are dropped.  Ties keep the earliest candidate —
    which is floor-greedy's own choice, so the policy never does worse than
    greedy *on the probed objective*.
    """

    name = "ledger"
    deferred = True

    def __init__(self, max_victims: int = 3,
                 limit_fracs: tuple[float, ...] = (1.0, 0.85, 0.7),
                 objective=slo_weighted_stall):
        self.max_victims = max_victims
        self.limit_fracs = tuple(limit_fracs)
        self.objective = objective
        self.probes = 0          # candidate suffixes re-simulated
        self.staged = 0          # renegotiations actually staged
        self.decision_log: list[dict] = []

    # ------------------------------------------------------------ candidates
    def candidates(self, engine, head, needed, victims):
        """Feasible (victim, new_limit, decisions, new_floor, solve_ms)
        tuples in probe order: greedy's own pick is always first."""
        out = []
        seen = set()
        for v in victims[: self.max_victims]:
            base_limit = v.floor - needed
            if base_limit <= 0:
                continue
            for frac in self.limit_fracs:
                new_limit = int(base_limit * frac)
                if new_limit <= 0:
                    continue
                decisions, solve_ms = engine._replan(v.tenant, new_limit)
                new_floor = planned_peak(v.trace, decisions)
                if new_floor > new_limit:
                    continue  # solver could not push the floor low enough
                if v.floor - new_floor < needed:
                    continue  # shrink frees fewer bytes than the head needs
                key = (v.name, new_floor)
                if key in seen:
                    continue  # deeper frac solved to the same floor
                seen.add(key)
                out.append((v, new_limit, decisions, new_floor, solve_ms))
        return out

    # ---------------------------------------------------------------- probes
    def probe(self, engine, candidate):
        """Stage ``candidate`` on a fresh clone, resume the suffix, score it.

        Returns ``(score, report)``.  The clone is pristine per candidate —
        no staged state leaks between probes or back into the live engine.
        """
        v, new_limit, decisions, new_floor, _solve_ms = candidate
        clone = engine._probe_clone()
        run = next(r for r in clone._running if r.name == v.name)
        # Stage exactly as _stage_victim would (solve_ms 0: wall clock is
        # not simulated state and the objective never reads it).
        run.replan_pending = (list(decisions), new_floor, 0.0)
        clone._promised[run.device] = (
            clone._promised.get(run.device, 0) + run.floor - new_floor
        )
        self.probes += 1
        report = clone.resume()
        return self.objective(report), report

    # ---------------------------------------------------------------- choose
    def choose(self, engine, head, needed, victims):
        cands = self.candidates(engine, head, needed, victims)
        if not cands:
            return None
        best = best_report = None
        best_score = None
        for cand in cands:
            score, report = self.probe(engine, cand)
            if best_score is None or score < best_score:
                best, best_score, best_report = cand, score, report
        if best_score == float("inf"):
            return None  # every candidate future is infeasible
        attr = best_report.attribution or {}
        self.decision_log.append({
            "t": engine._now,
            "head": head.name,
            "needed": needed,
            "candidates": len(cands),
            "victim": best[0].name,
            "new_limit": best[1],
            "score": best_score,
            "binding_constraint": binding_constraint(attr),
        })
        self.staged += 1
        return best
