"""SLO-weighted stall objective over runtime reports (Issue 8).

The tuners in this package all minimize the same scalar: each completed
tenant's excess seconds (overhead beyond its isolated baseline, plus the
queue wait it spent un-admitted), weighted by its SLO ``priority``.  The
PR 7 attribution ledger decomposes the same overhead into named causes, so
``binding_constraint`` can report *why* the winning candidate's stall is
what it is: ``transfer`` means the plan swaps too much, ``channel_contention``
means the K DMA channels bind, ``blackout`` means the collective link
schedule binds.
"""

from __future__ import annotations

INFEASIBLE = float("inf")

# Ledger buckets (sum exactly to overhead_s) mapped to the constraint each
# one names.  Informational keys are excluded from the argmax.
_BUCKET_CONSTRAINT = {
    "swap_in_transfer_s": "transfer",
    "swap_out_pending_s": "transfer",
    "swap_out_drain_s": "transfer",
    "channel_contention_s": "channel_contention",
    "link_blackout_s": "blackout",
    "collective_excess_s": "blackout",
    "barrier_drain_s": "barrier",
    "residual_s": "residual",
}
_INFORMATIONAL = ("overhead_s", "queue_wait_s", "renegotiation_solve_s")


def slo_weighted_stall(report) -> float:
    """SLO-weighted total stall of a ``RuntimeReport``.

    sum over tenants of priority * (overhead_s + queue_wait_s), where
    overhead is seconds beyond the tenant's isolated baseline.  A tenant
    that never completed (unschedulable) or a pool overflow makes the
    configuration infeasible — returns ``inf`` so tuners reject it.
    """
    if report.overflow_events:
        return INFEASIBLE
    total = 0.0
    for t in report.tenants:
        if t.status != "completed":
            return INFEASIBLE
        excess = max(0.0, t.duration_s - t.baseline_s)
        total += t.priority * (excess + t.queue_wait_s)
    return total


def binding_constraint(attribution: dict | None) -> str:
    """Name the constraint behind the largest attribution bucket.

    ``attribution`` is a tenant (or report-aggregate) stall ledger; returns
    one of ``transfer`` / ``channel_contention`` / ``blackout`` / ``barrier``
    / ``residual``, or ``none`` when there is no ledger or no stall at all.
    """
    if not attribution:
        return "none"
    best_k, best_v = None, 0.0
    for k, v in attribution.items():
        if k in _INFORMATIONAL:
            continue
        if v > best_v:
            best_k, best_v = k, v
    if best_k is None:
        return "none"
    return _BUCKET_CONSTRAINT.get(best_k, best_k)
