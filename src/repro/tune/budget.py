"""SLO-equalized colocation budget splits (Issue 8 tentpole, part 2).

``proportional_shares`` splits a shared HBM budget by isolated peak bytes —
a byte heuristic blind to how *sensitive* each tenant's stall is to its
share.  ``tuned_shares`` is a coordinate-descent tuner over the split: it
starts from the proportional split and repeatedly moves ``delta`` bytes
from a donor tenant to a receiver, keeping any move that strictly reduces
SLO-weighted total stall (measured by re-simulating the colocation under
the trial split), halving ``delta`` when a full sweep finds nothing.  At
convergence no +/-delta transfer helps — the discrete form of equalized
SLO-weighted *marginal* stall across tenants.

The tuner is simulation-agnostic: ``evaluate(shares) -> float`` is any
callback returning the objective for a split (``inf`` = infeasible).
``runtime.tenants.colocate_programs(budget_split="tuned")`` wires it to a
full colocation re-simulation.
"""

from __future__ import annotations

from dataclasses import dataclass, field


@dataclass
class BudgetSplitResult:
    """A tuned split next to its proportional starting point."""

    shares: dict[str, int]
    initial_shares: dict[str, int]
    initial_stall: float
    tuned_stall: float
    rounds: int = 0
    evals: int = 0
    moves: list[dict] = field(default_factory=list)

    @property
    def improved(self) -> bool:
        return self.tuned_stall < self.initial_stall

    def as_dict(self) -> dict:
        return {
            "shares": dict(self.shares),
            "initial_shares": dict(self.initial_shares),
            "initial_stall_s": self.initial_stall,
            "tuned_stall_s": self.tuned_stall,
            "rounds": self.rounds,
            "evals": self.evals,
            "moves": list(self.moves),
        }


def tuned_shares(
    peaks: dict[str, int],
    budget: int,
    evaluate,
    start: dict[str, int] | None = None,
    delta_frac: float = 0.125,
    min_delta: int = 1 << 20,
    max_evals: int = 64,
) -> BudgetSplitResult:
    """Coordinate descent on the budget split, minimizing ``evaluate``.

    ``peaks`` caps each tenant's share (bytes above its natural peak are
    wasted); shares always sum to ``budget``.  ``start`` defaults to the
    proportional split.  Descent is monotone — every accepted move strictly
    reduces the objective — so the result is never worse than the start.
    """
    from ..runtime.tenants import proportional_shares

    names = sorted(peaks)
    if start is None:
        start = proportional_shares(peaks, budget)
    cur = {n: min(start[n], peaks[n]) for n in names}
    cur_score = evaluate(cur)
    result = BudgetSplitResult(
        shares=dict(cur), initial_shares=dict(cur),
        initial_stall=cur_score, tuned_stall=cur_score, evals=1,
    )
    delta = max(int(min_delta), int(budget * delta_frac))
    while delta >= min_delta and result.evals < max_evals:
        result.rounds += 1
        improved = False
        for donor in names:
            for receiver in names:
                if receiver == donor or result.evals >= max_evals:
                    continue
                move = min(delta, peaks[receiver] - cur[receiver], cur[donor])
                if move <= 0:
                    continue
                trial = dict(cur)
                trial[donor] -= move
                trial[receiver] += move
                score = evaluate(trial)
                result.evals += 1
                if score < cur_score:  # strict: ties keep the simpler split
                    cur, cur_score = trial, score
                    improved = True
                    result.moves.append({
                        "from": donor, "to": receiver,
                        "bytes": move, "stall_s": score,
                    })
        if not improved:
            delta //= 2
    result.shares = dict(cur)
    result.tuned_stall = cur_score
    return result
