"""repro.tune — ledger-guided runtime tuning (Issue 8).

Closes the simulate -> attribute -> decide loop: the engine's barrier
snapshots + ``resume()`` (PR 6) make candidate futures cheap to simulate,
and the stall-attribution ledger (PR 7) scores them by *named cause*.
Three tuners consume that machinery:

  * ``LedgerVictimPolicy`` — renegotiation victim selection by simulated
    marginal SLO-weighted stall (``MemoryRuntime(victim_policy=...)``);
  * ``tuned_shares`` — coordinate-descent colocation budget splits
    (``colocate_programs(budget_split="tuned")``);
  * ``lane_split_from_waits`` — directional HostLink lane carving from a
    probe run's per-direction queue wait
    (``run_mesh(lane_split="directional")``).

Every default stays untouched: with no tuner engaged, reports remain
bit-identical to the frozen ``runtime/_engine_reference.py``.
"""

from .budget import BudgetSplitResult, tuned_shares
from .lanes import lane_split_from_waits
from .objective import binding_constraint, slo_weighted_stall
from .victim import LedgerVictimPolicy

__all__ = [
    "BudgetSplitResult",
    "LedgerVictimPolicy",
    "binding_constraint",
    "lane_split_from_waits",
    "slo_weighted_stall",
    "tuned_shares",
]
