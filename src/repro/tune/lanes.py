"""Directional HostLink lane allocation (Issue 8 tentpole, part 3).

A shared lane pool is work-conserving, but it lets a burst of bulk
swap-outs queue ahead of a latency-critical swap-in on every lane at once.
``HostLink.make(..., out_lanes=k)`` carves the pool so swap-ins keep
reserved lanes; this module picks ``k`` from measured evidence — the
per-direction decomposition of the link's queue wait (``wait_in_s`` /
``wait_out_s``, the directional split of what the stall ledger books as
``channel_contention_s``) in a probe run, falling back to the byte split
when the probe saw no queueing at all.

``dist.execute.run_mesh(lane_split="directional")`` runs the probe and
applies the split.
"""

from __future__ import annotations


def lane_split_from_waits(
    wait_in_s: float,
    wait_out_s: float,
    lanes: int,
    bytes_in: int = 0,
    bytes_out: int = 0,
) -> int | None:
    """Out-lane count for a directional split, or ``None`` for no split.

    Lanes go to each direction proportionally to its measured queue wait
    (demand the shared pool failed to serve immediately); when neither
    direction ever waited, proportionally to bytes moved.  Each direction
    always keeps at least one lane.  ``None`` when ``lanes < 2`` or there
    is no directional evidence at all.
    """
    if lanes < 2:
        return None
    demand_in, demand_out = max(0.0, wait_in_s), max(0.0, wait_out_s)
    if demand_in + demand_out <= 0.0:
        demand_in, demand_out = float(bytes_in), float(bytes_out)
    total = demand_in + demand_out
    if total <= 0.0:
        return None
    out_lanes = round(lanes * demand_out / total)
    return max(1, min(int(out_lanes), lanes - 1))
