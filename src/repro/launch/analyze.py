"""repro.launch.analyze — verify plan artifacts and schedule traces offline.

Usage:
  python -m repro.launch.analyze PATH [PATH ...]

Each PATH is classified by shape, not extension:

  * Chrome trace JSON (a ``traceEvents`` list, as written by
    ``repro.obs.write_trace``) — swept by the event-log race detector
    (``repro.analyze.schedule_check``).
  * Plan artifact JSON (a versioned ``MemoryProgram`` payload, as written
    by ``PlanCache.store``) — swept by the static plan verifier
    (``repro.analyze.plan_check``).

Prints one certificate summary per file and exits nonzero if any invariant
failed.  Trace verification is jax-free; plan artifacts lazily import the
plan layer (which pulls the backend) only when one is actually given.
"""

from __future__ import annotations

import argparse
import json
import sys

from repro.analyze import check_view, verify_program, view_from_trace


def classify(payload: dict) -> str:
    if isinstance(payload.get("traceEvents"), list):
        return "trace"
    if "pool_plans" in payload or "swap_summaries" in payload:
        return "plan"
    return "unknown"


def verify_path(path: str):
    """(kind, Certificate | None, error | None) for one input file."""
    try:
        with open(path) as f:
            payload = json.load(f)
    except (OSError, ValueError) as e:
        return "unreadable", None, str(e)
    if not isinstance(payload, dict):
        return "unknown", None, "not a JSON object"
    kind = classify(payload)
    if kind == "trace":
        return kind, check_view(view_from_trace(payload, source=path)), None
    if kind == "plan":
        from repro.plan.artifact import program_from_json

        try:
            program = program_from_json(payload)
        except (KeyError, TypeError, ValueError) as e:
            return kind, None, f"unparseable plan artifact: {e}"
        return kind, verify_program(program), None
    return kind, None, "neither a Chrome trace nor a plan artifact"


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="repro.launch.analyze",
        description="Statically verify plan artifacts and schedule traces.",
    )
    parser.add_argument("paths", nargs="+", metavar="PATH",
                        help="plan artifact or Chrome trace JSON")
    parser.add_argument("-q", "--quiet", action="store_true",
                        help="one verdict line per file, no per-invariant detail")
    args = parser.parse_args(argv)

    failures = 0
    for path in args.paths:
        kind, cert, err = verify_path(path)
        if cert is None:
            failures += 1
            print(f"FAIL {path}: {err}")
            continue
        verdict = "ok  " if cert.ok else "FAIL"
        if not cert.ok:
            failures += 1
        print(f"{verdict} {path} [{kind}]")
        if not args.quiet:
            for line in cert.summary_lines():
                print(f"     {line}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
