"""Step builders + parameter/cache sharding specs for every architecture.

Sharding policy (GSPMD, logical rules in distributed/sharding.py):
  * attention heads / FFN hidden / vocab / experts  -> "model"
  * batch -> ("pod", "data"); gradient reduction crosses pods once per step
  * optional FSDP: the non-"model" weight dim additionally over "data"
    (required for llama4-400b: 12 bytes/param of param+moments do not fit
    16 GB/chip at model-parallel-16 alone)
  * every rule degrades to replication when the dim is not divisible by the
    mesh axis (e.g. hymba's 50 SSM heads on model=16)

Steps return/accept pytrees whose shardings are attached to the
ShapeDtypeStructs, so ``jax.jit(fn).lower(*specs)`` carries the full
distribution contract — this is what the multi-pod dry-run compiles.
"""

from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.distributed.sharding import batch_axes
from repro.optim import adamw_init, adamw_step

# FSDP is on for archs whose param+optimizer bytes exceed single-chip HBM at
# TP-16 (see DESIGN.md §4).
FSDP_ARCHS = {"llama4-maverick-400b-a17b", "deepseek-v2-lite-16b"}


# --------------------------------------------------------------- divisibility
def _ax(mesh: Mesh, name: str | tuple | None, dim: int):
    """Mesh axis (or axes) for one tensor dim, with divisibility guard."""
    if name is None:
        return None
    names = name if isinstance(name, tuple) else (name,)
    names = tuple(n for n in names if n in mesh.axis_names)
    if not names:
        return None
    size = int(np.prod([mesh.shape[n] for n in names]))
    if dim % size != 0:
        # try a prefix that divides
        for k in range(len(names), 0, -1):
            sub = names[:k]
            if dim % int(np.prod([mesh.shape[n] for n in sub])) == 0:
                return sub if len(sub) > 1 else sub[0]
        return None
    return names if len(names) > 1 else names[0]


def _spec(mesh: Mesh, dims: list, shape: tuple[int, ...]) -> P:
    """dims: logical mesh-axis names per tensor dim (right-aligned if stacked)."""
    pad = len(shape) - len(dims)
    dims = [None] * pad + list(dims)
    return P(*[_ax(mesh, d, s) for d, s in zip(dims, shape)])


# ------------------------------------------------------------- param specs
def param_specs(cfg: ModelConfig, param_shapes, mesh: Mesh, fsdp: bool | None = None):
    """PartitionSpec pytree matching the param pytree (stacked dims handled
    by right-alignment: a leading scan-repeat dim is always replicated)."""
    if fsdp is None:
        fsdp = cfg.name in FSDP_ARCHS
    dp = "data"

    def rule(path_keys: list[str], shape: tuple[int, ...]) -> P:
        name = path_keys[-1]
        parent = path_keys[-2] if len(path_keys) > 1 else ""
        if name in ("tok", "head"):                       # [V, D]
            return _spec(mesh, ["model", dp if fsdp else None], shape)
        if name == "wq":                                  # [D, H, hd] (attn + mla)
            return _spec(mesh, [dp if fsdp else None, "model", None], shape)
        if name in ("wk", "wv"):                          # [D, KV, hd]
            return _spec(mesh, [dp if fsdp else None, "model", None], shape)
        if name == "wo":                                  # [H, hd, D]
            return _spec(mesh, ["model", None, dp if fsdp else None], shape)
        if name in ("w_uk", "w_uv"):                      # [lora, H, *]
            return _spec(mesh, [None, "model", None], shape)
        if name == "w_dkv":                               # [D, lora]
            return _spec(mesh, [dp if fsdp else None, None], shape)
        if name == "w_kr":                                # [D, rope]
            return _spec(mesh, [dp if fsdp else None, None], shape)
        if name == "router":                              # [D, E]
            return _spec(mesh, [None, "model"], shape)
        if name in ("w_gate", "w_up"):
            if parent == "moe":                           # experts [E, D, F]
                return _spec(mesh, ["model", None, dp if fsdp else None], shape)
            return _spec(mesh, [dp if fsdp else None, "model"], shape)
        if name == "w_down":
            if parent == "moe":                           # [E, F, D]
                return _spec(mesh, ["model", dp if fsdp else None, None], shape)
            return _spec(mesh, ["model", dp if fsdp else None], shape)
        if name == "b_up":
            return _spec(mesh, ["model"], shape)
        if name == "in_proj":                             # [D, 2di+2gn+H]
            return _spec(mesh, [dp if fsdp else None, "model"], shape)
        if name in ("conv_w",):                           # [K, conv_dim]
            return _spec(mesh, [None, "model"], shape)
        if name in ("conv_b",):
            return _spec(mesh, ["model"], shape)
        if name == "out_proj":                            # [di, D]
            return _spec(mesh, ["model", dp if fsdp else None], shape)
        if name == "norm" and parent == "mamba":          # [di]
            return _spec(mesh, ["model"], shape)
        # norms, A_log, D, dt_bias, qk norms, branch norms, biases: replicate
        return P(*([None] * len(shape)))

    def build(path, leaf):
        keys = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        return rule(keys, leaf.shape)

    return jax.tree_util.tree_map_with_path(build, param_shapes)


def cache_specs_tree(cfg: ModelConfig, cache_shapes, mesh: Mesh):
    """Specs for decode caches (right-aligned; stacked layer dim replicated)."""

    def rule(path, leaf) -> P:
        keys = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        name = keys[-1]
        shape = leaf.shape
        b = batch_axes(mesh)
        if name in ("k", "v") or keys[-2] == "enc_kv":    # [B, S, KV, hd]
            spec = _spec(mesh, [b, None, "model", None], shape)
            if spec[2] is None:
                # KV heads don't divide the model axis (e.g. 8 on 16):
                # split-KV — shard the sequence dim of the cache instead,
                # decode softmax handles it (flash-decoding layout).
                spec = _spec(mesh, [b, "model", None, None], shape)
            return spec
        if name in ("c_kv", "k_rope"):                    # [B, S, lora]
            return _spec(mesh, [b, "model", None], shape)
        if name == "state":                               # [B, H, P, N]
            return _spec(mesh, [b, "model", None, None], shape)
        if name == "conv":                                # [B, K-1, conv_dim]
            return _spec(mesh, [b, None, "model"], shape)
        return P(*([None] * len(shape)))

    return jax.tree_util.tree_map_with_path(rule, cache_shapes)


def batch_specs(cfg: ModelConfig, batch_shapes, mesh: Mesh):
    b = batch_axes(mesh)

    def rule(path, leaf) -> P:
        keys = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        name = keys[-1]
        if name == "positions":                           # [3, B, S]
            return _spec(mesh, [None, b, None], leaf.shape)
        if name in ("tokens", "labels"):                  # [B, S]
            return _spec(mesh, [b, None], leaf.shape)
        if name in ("patch_embeds", "frames"):            # [B, S, D]
            return _spec(mesh, [b, None, None], leaf.shape)
        return _spec(mesh, [b] + [None] * (len(leaf.shape) - 1), leaf.shape)

    return jax.tree_util.tree_map_with_path(rule, batch_shapes)


def with_sharding(mesh: Mesh, shapes, specs):
    """Attach NamedShardings to a ShapeDtypeStruct pytree."""
    return jax.tree.map(
        lambda s, p: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=NamedSharding(mesh, p)),
        shapes,
        specs,
    )


# ------------------------------------------------------------ step builders
def build_train_step(model, cfg: ModelConfig, *, lr: float = 3e-4,
                     remat: bool = True, remat_policy=None,
                     accum_steps: int = 1):
    """(params, opt_state, batch, step) -> (params, opt_state, metrics).

    accum_steps > 1 microbatches the global batch over a lax.scan (gradient
    accumulation — the memory-term hillclimb lever)."""

    def loss_fn(p, batch):
        loss, metrics = model.loss(p, batch, remat=remat, remat_policy=remat_policy)
        return loss, metrics

    def train_step(params, opt_state, batch, step):
        if accum_steps == 1:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch
            )
        else:
            def micro(b):
                return jax.tree.map(
                    lambda x: x.reshape((accum_steps, x.shape[0] // accum_steps) + x.shape[1:]),
                    b,
                )

            mb = micro(batch)

            def body(acc, mbatch):
                (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mbatch)
                acc_g, acc_l = acc
                return (jax.tree.map(jnp.add, acc_g, g), acc_l + l), m

            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_sum), metrics = jax.lax.scan(body, (zero, 0.0), mb)
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
            loss = loss_sum / accum_steps
            metrics = jax.tree.map(lambda m: m[-1], metrics)
        params, opt_state, om = adamw_step(params, grads, opt_state, lr)
        metrics = dict(metrics, loss=loss, **om)
        return params, opt_state, metrics

    return train_step


def build_prefill_step(model, cfg: ModelConfig):
    def prefill_step(params, batch):
        return model.prefill(params, batch)

    return prefill_step


def build_serve_step(model, cfg: ModelConfig):
    def serve_step(params, cache, tokens, pos):
        return model.decode_step(params, cache, tokens, pos)

    return serve_step


def init_optimizer_shapes(param_shapes):
    return jax.eval_shape(adamw_init, param_shapes)


def opt_specs_like(param_spec_tree):
    """AdamWState specs: m/v follow params, count replicated."""
    from repro.optim.adamw import AdamWState

    return AdamWState(m=param_spec_tree, v=param_spec_tree, count=P())
