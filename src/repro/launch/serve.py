"""Batched serving driver: prefill then decode with KV caches.

Serves a (smoke or full) model on the available devices: batches requests,
prefim-fills the cache from the prompt, then decodes greedily with the
donated-cache serve step — the same functions the decode dry-run cells
lower.  The AutoSwap planner can report on the serve step too (--plan):
with MoE models its candidate filter picks up inactive expert shards, with
dense models the KV cache dominates and the planner correctly reports
nothing swappable below the threshold (documented behaviour, DESIGN.md §6).

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --smoke \\
      --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke_config, list_archs
from repro.models import build_model


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs(), default="qwen3-4b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))

    B, P = args.batch, args.prompt_len
    max_seq = P + args.gen + (cfg.num_patch_tokens if cfg.frontend == "vision_stub" else 0)
    key = jax.random.PRNGKey(args.seed + 1)
    batch = {"tokens": jax.random.randint(key, (B, P), 0, cfg.vocab_size, jnp.int32)}
    if cfg.frontend == "vision_stub":
        npatch = min(cfg.num_patch_tokens, 8)
        batch["patch_embeds"] = jnp.zeros((B, npatch, cfg.d_model), jnp.float32)
        S = P + npatch
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32)[None, None], (3, B, S)
        )
    if cfg.is_encoder_decoder:
        batch["frames"] = jnp.zeros((B, cfg.enc_seq, cfg.d_model), jnp.float32)

    t0 = time.time()
    prefill = jax.jit(lambda p, b: model.prefill(p, b, max_seq=max_seq))
    logits, cache = prefill(params, batch)
    next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    print(f"prefill: {B}x{P} in {time.time()-t0:.2f}s")

    decode = jax.jit(model.decode_step, donate_argnums=(1,))
    pos0 = P + (min(cfg.num_patch_tokens, 8) if cfg.frontend == "vision_stub" else 0)
    out_tokens = [next_tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        logits, cache = decode(params, cache, next_tok, jnp.asarray(pos0 + i, jnp.int32))
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        out_tokens.append(next_tok)
    dt = time.time() - t0
    gen = jnp.concatenate(out_tokens, axis=1)
    print(f"decode: {args.gen} tokens x {B} seqs in {dt:.2f}s "
          f"({B*(args.gen-1)/max(dt,1e-9):.1f} tok/s)")
    print("sample:", gen[0, :12].tolist())
    return gen


if __name__ == "__main__":
    main()
