"""Batched serving driver: prefill then decode with KV caches.

Serves a (smoke or full) model on the available devices: batches requests,
prefill-fills the cache from the prompt, then decodes greedily with the
donated-cache serve step — the same functions the decode dry-run cells
lower.  The AutoSwap planner can report on the serve step too (--plan):
with MoE models its candidate filter picks up inactive expert shards, with
dense models the KV cache dominates and the planner correctly reports
nothing swappable below the threshold (documented behaviour, DESIGN.md §6).

With ``--plan-cache DIR`` the prefill and decode step plans are solved
through the repro.plan pipeline and persisted as per-arch artifacts keyed
by (arch, step signature, hardware): a second serving process — e.g. a
decode worker next to a prefill worker, or the next restart — restores the
solved plan from DIR instead of re-tracing the step.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --smoke \\
      --batch 4 --prompt-len 32 --gen 16 [--plan] [--plan-cache /tmp/plans]
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke_config, list_archs
from repro.models import build_model


def serve_batch_struct(cfg, B: int, P: int) -> dict:
    """Shape/dtype spec of one serving batch — the single source of truth
    shared by the planner (abstract trace) and main() (concrete arrays)."""
    batch = {"tokens": jax.ShapeDtypeStruct((B, P), jnp.int32)}
    if cfg.frontend == "vision_stub":
        npatch = min(cfg.num_patch_tokens, 8)
        batch["patch_embeds"] = jax.ShapeDtypeStruct((B, npatch, cfg.d_model), jnp.float32)
        batch["positions"] = jax.ShapeDtypeStruct((3, B, P + npatch), jnp.int32)
    if cfg.is_encoder_decoder:
        batch["frames"] = jax.ShapeDtypeStruct((B, cfg.enc_seq, cfg.d_model), jnp.float32)
    return batch


def plan_serve_steps(model, cfg, args, max_seq: int, plan_cache=None):
    """Solve (or restore) the memory plans for the prefill and decode steps.

    Returns {role: (planner, PoolReport)} for "prefill" and "decode".
    """
    from repro.core.planner import MemoryPlanner
    from repro.core.simulator import TPU_V5E
    from repro.plan import PlanCache, PlanKey

    if plan_cache is None and args.plan_cache:
        plan_cache = PlanCache(args.plan_cache)
    B, P = args.batch, args.prompt_len
    pshapes = model.init_shapes()
    batch = serve_batch_struct(cfg, B, P)

    def prefill_fn(params, b):
        return model.prefill(params, b, max_seq=max_seq)

    _, cache_struct = jax.eval_shape(prefill_fn, pshapes, batch)
    tok = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    steps = {
        "prefill": (prefill_fn, (pshapes, batch)),
        "decode": (model.decode_step, (pshapes, cache_struct, tok, pos)),
    }
    smoke = ":smoke" if args.smoke else ""
    out = {}
    for role, (fn, fargs) in steps.items():
        key = PlanKey(args.arch, f"{role}:b{B}p{P}s{max_seq}{smoke}", TPU_V5E.name)
        planner = MemoryPlanner(
            fn, *fargs, hw=TPU_V5E, cache=plan_cache, key=key, size_threshold=1 << 18
        )
        rep = planner.report()
        src = "restored from cache" if planner.from_cache else "solved"
        print(
            f"[plan] {role}: {src}  vars={rep.num_variables} "
            f"peak={rep.peak_load/2**20:.1f}MiB smartpool x{rep.smartpool_ratio:.4f} "
            f"cnmem x{rep.cnmem_ratio:.4f}"
        )
        # AutoSwap at 80% of peak: MoE models surface inactive expert shards
        # here; dense models correctly report nothing swappable (DESIGN.md §6).
        sw = planner.swap_report(int(rep.peak_load * 0.8))
        print(
            f"[plan] {role}: AutoSwap@80%: {sw.num_selected} vars "
            f"({sw.selected_bytes/2**20:.1f}MiB) swappable, "
            f"simulated overhead {sw.overhead*100:.2f}%"
        )
        out[role] = (planner, rep)
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs(), default="qwen3-4b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--plan", action="store_true",
                    help="print SmartPool/AutoSwap reports for prefill + decode steps")
    ap.add_argument("--plan-cache", default=None,
                    help="directory of solved plan artifacts shared across "
                         "prefill/decode processes (solve once, reload after)")
    ap.add_argument("--colocate", action="store_true",
                    help="co-schedule the prefill and decode steps as two "
                         "tenants of the shared-HBM memory runtime and print "
                         "the per-tenant overhead / aggregate peak report")
    ap.add_argument("--colocate-budget-frac", type=float, default=0.8,
                    help="shared budget as a fraction of summed step peaks")
    ap.add_argument("--channels", type=int, default=2,
                    help="DMA channels for the --colocate runtime")
    from repro.obs import add_obs_args

    add_obs_args(ap)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))

    B, P = args.batch, args.prompt_len
    max_seq = P + args.gen + (cfg.num_patch_tokens if cfg.frontend == "vision_stub" else 0)

    if args.plan or args.plan_cache or args.colocate:
        from repro.plan import PlanCache

        plan_cache = PlanCache(args.plan_cache) if args.plan_cache else None
        planned = plan_serve_steps(model, cfg, args, max_seq, plan_cache=plan_cache)
        if args.colocate:
            # The serving colocation case: prefill + decode as two tenants of
            # one shared HBM budget (TENSILE's regime), driven by the same
            # solved programs the planner just produced/restored.
            from repro.core.simulator import TPU_V5E
            from repro.launch.colocate import print_colocation
            from repro.obs import export_monitor, export_trace, recorder_for
            from repro.runtime import colocate_programs

            programs = {
                f"{args.arch}:{role}": planner.program
                for role, (planner, _rep) in planned.items()
            }
            recorder = recorder_for(args)
            result = colocate_programs(
                programs, TPU_V5E,
                budget_frac=args.colocate_budget_frac,
                channels=args.channels,
                size_threshold=1 << 18,
                cache=plan_cache,
                record_events=args.record_events,
                obs=recorder,
            )
            print_colocation(result)
            export_trace(args, recorder, result.report)
            export_monitor(args, recorder)
            if args.verify:
                from repro.analyze import verify_launch

                verify_launch(args, programs=programs, recorder=recorder,
                              report=result.report)
    key = jax.random.PRNGKey(args.seed + 1)
    spec = serve_batch_struct(cfg, B, P)
    batch = {"tokens": jax.random.randint(key, spec["tokens"].shape, 0, cfg.vocab_size, jnp.int32)}
    if "patch_embeds" in spec:
        batch["patch_embeds"] = jnp.zeros(spec["patch_embeds"].shape, spec["patch_embeds"].dtype)
        S = spec["positions"].shape[-1]
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32)[None, None], spec["positions"].shape
        )
    if "frames" in spec:
        batch["frames"] = jnp.zeros(spec["frames"].shape, spec["frames"].dtype)

    t0 = time.time()
    prefill = jax.jit(lambda p, b: model.prefill(p, b, max_seq=max_seq))
    logits, cache = prefill(params, batch)
    next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    print(f"prefill: {B}x{P} in {time.time()-t0:.2f}s")

    decode = jax.jit(model.decode_step, donate_argnums=(1,))
    pos0 = P + (min(cfg.num_patch_tokens, 8) if cfg.frontend == "vision_stub" else 0)
    out_tokens = [next_tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        logits, cache = decode(params, cache, next_tok, jnp.asarray(pos0 + i, jnp.int32))
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        out_tokens.append(next_tok)
    dt = time.time() - t0
    gen = jnp.concatenate(out_tokens, axis=1)
    print(f"decode: {args.gen} tokens x {B} seqs in {dt:.2f}s "
          f"({B*(args.gen-1)/max(dt,1e-9):.1f} tok/s)")
    print("sample:", gen[0, :12].tolist())
    return gen


if __name__ == "__main__":
    main()
