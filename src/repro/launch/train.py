"""End-to-end training driver with fault tolerance.

Runs on whatever devices exist (CPU smoke -> v5e pods): builds the model from
``--arch`` (full or ``--smoke`` reduced config), sharded data pipeline,
AdamW, checkpoint/restart, and the paper's memory planner wired in:

  * ``--plan``       print the SmartPool/AutoSwap report for this exact step
                     function before training (jaxpr-transparent, §III/§IV);
  * ``--plan-cache`` directory of solved plan artifacts: the one-time solve
                     is keyed by (arch, step signature, hardware) and reused
                     across restarts / sibling processes without re-tracing;
  * ``--hbm-limit``  GB budget per device: AutoSwap picks the activation
                     classes to offload (pinned_host) and the train step is
                     rebuilt with that remat policy (§IV applied via XLA).

Fault tolerance:
  * atomic keep-k checkpoints (async), auto-resume from the latest step;
  * step-level failure injection hook (--fail-at) exercised by the tests:
    the process can be killed at any step and relaunched with identical
    results (deterministic data keyed by step);
  * straggler watchdog: steps exceeding ``--step-timeout`` x median are
    logged and counted (on real multi-host runs this triggers re-slicing —
    here it feeds the elastic-resume test).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --smoke \\
      --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, get_smoke_config, list_archs
from repro.core.planner import MemoryPlanner
from repro.data import Prefetcher, SyntheticTokens
from repro.models import build_model
from repro.optim import adamw_init
from repro.launch.steps import build_train_step


def make_batch_fn(cfg, batch: int, seq: int, seed: int):
    ds = SyntheticTokens(cfg.vocab_size, seq, batch, seed=seed)

    def at(step: int) -> dict:
        b = {k: jnp.asarray(v) for k, v in ds.batch_at(step).items()}
        if cfg.frontend == "vision_stub":
            npatch = min(cfg.num_patch_tokens, 8)
            b["patch_embeds"] = jnp.zeros((batch, npatch, cfg.d_model), jnp.float32)
            S = seq + npatch
            b["positions"] = jnp.broadcast_to(
                jnp.arange(S, dtype=jnp.int32)[None, None], (3, batch, S)
            )
        if cfg.is_encoder_decoder:
            b["frames"] = jnp.zeros((batch, cfg.enc_seq, cfg.d_model), jnp.float32)
        return b

    return at


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs(), default="qwen3-4b")
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--fail-at", type=int, default=-1, help="inject a crash at step N (tests)")
    ap.add_argument("--step-timeout", type=float, default=10.0, help="straggler factor vs median")
    ap.add_argument("--plan", action="store_true", help="print SmartPool/AutoSwap report")
    ap.add_argument("--dist-plan", default=None, metavar="MESH",
                    help='solve per-device plans for a mesh (e.g. "data=4") '
                         "before training; cached under a topology-extended key")
    ap.add_argument("--plan-cache", default=None,
                    help="directory of solved plan artifacts (reused across runs)")
    ap.add_argument("--hbm-limit-gb", type=float, default=None,
                    help="AutoSwap offload budget per device (GB)")
    ap.add_argument("--log-every", type=int, default=10)
    from repro.obs import add_obs_args

    add_obs_args(ap)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    batch_fn = make_batch_fn(cfg, args.batch, args.seq, args.seed)

    if args.dist_plan:
        # Mesh-aware planning (repro.dist): per-device trace capture under
        # the launch/steps.py PartitionSpecs, solved once per device group
        # and cached under a topology-extended PlanKey — so this process's
        # sharded plan never aliases the single-device plan below.
        from repro.core.simulator import TPU_V5E
        from repro.dist import MeshSpec, solve_sharded
        from repro.launch.shardplan import capture_for_mesh, probe_from_model
        from repro.plan import PlanCache, PlanKey

        mesh = MeshSpec.parse(args.dist_plan)
        step_probe, example_args = probe_from_model(model, batch_fn)
        capture = capture_for_mesh(cfg, step_probe, example_args, mesh, TPU_V5E)
        smoke = ":smoke" if args.smoke else ""
        base_key = PlanKey(args.arch, f"train:b{args.batch}s{args.seq}{smoke}", TPU_V5E.name)
        dist_cache = PlanCache(args.plan_cache) if args.plan_cache else None
        solved = solve_sharded(
            capture, TPU_V5E, base_key=base_key, cache=dist_cache,
            limit=(int(args.hbm_limit_gb * 2**30) if args.hbm_limit_gb is not None else None),
        )
        for g, program in solved.programs.items():
            trace = program.require_trace()
            src = " (restored from cache)" if solved.cache_hits[g] else ""
            print(
                f"[dist-plan] mesh {mesh.signature() or '1'} group {g}: "
                f"per-device peak {trace.peak_load()/2**20:.1f}MiB, "
                f"{len(capture.groups[g].collectives)} collectives, "
                f"solved in {solved.solve_ms[g]:.1f} ms{src}"
            )
        if args.trace_out or args.verify:
            # Observability run: execute the solved mesh plans through the
            # runtime (contended shared link, the headline configuration)
            # and export the Perfetto trace before training proper starts.
            from repro.dist import run_mesh
            from repro.obs import export_monitor, export_trace, recorder_for

            shard_peak = max(
                p.require_trace().peak_load() for p in solved.programs.values()
            )
            # Default to the full shard peak: smoke traces are too small to
            # swap-plan below their peak, and an unschedulable tenant yields
            # an empty (vacuous) trace.
            budget = (
                int(args.hbm_limit_gb * 2**30)
                if args.hbm_limit_gb is not None
                else int(shard_peak)
            )
            recorder = recorder_for(args)
            mesh_run = run_mesh(
                solved, TPU_V5E, budget_per_device=budget, iterations=2,
                record_events=args.record_events, obs=recorder,
            )
            print(
                f"[dist-plan] mesh run: makespan "
                f"{mesh_run.report.makespan_s*1e3:.2f}ms, mean overhead "
                f"{mesh_run.mean_overhead()*100:.2f}%"
            )
            export_trace(args, recorder, mesh_run.report)
            export_monitor(args, recorder)
            if args.verify:
                from repro.analyze import verify_launch

                verify_launch(args, programs=solved.programs,
                              recorder=recorder, report=mesh_run.report)

    remat_policy = None
    if args.plan or args.plan_cache or args.hbm_limit_gb is not None:
        from repro.core.simulator import TPU_V5E
        from repro.plan import PlanCache, PlanKey

        probe = jax.eval_shape(lambda: batch_fn(0))
        pshapes = model.init_shapes()

        def step_probe(params, batch):
            return model.loss(params, batch)[0]

        plan_cache = PlanCache(args.plan_cache) if args.plan_cache else None
        smoke = ":smoke" if args.smoke else ""
        key = PlanKey(args.arch, f"train:b{args.batch}s{args.seq}{smoke}", TPU_V5E.name)
        planner = MemoryPlanner(step_probe, pshapes, probe, hw=TPU_V5E,
                                cache=plan_cache, key=key)
        rep = planner.report()
        src = " (restored from cache)" if planner.from_cache else ""
        print(
            f"[plan] vars={rep.num_variables} peak={rep.peak_load/2**20:.1f}MiB "
            f"smartpool x{rep.smartpool_ratio:.4f} cnmem x{rep.cnmem_ratio:.4f}{src}"
        )
        if args.hbm_limit_gb is not None:
            limit = int(args.hbm_limit_gb * 2**30)
            plan = planner.offload_plan(limit)
            sw = planner.swap_report(limit)
            print(
                f"[plan] AutoSwap@{args.hbm_limit_gb}GB: offload {plan.offload_names} "
                f"(~{plan.predicted_savings/2**20:.1f}MiB relief, "
                f"simulated overhead {sw.overhead*100:.2f}%)"
            )
            remat_policy = plan.policy()

    train_step = build_train_step(model, cfg, lr=args.lr, remat_policy=remat_policy)
    jit_step = jax.jit(train_step, donate_argnums=(0, 1))

    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    params = model.init(jax.random.PRNGKey(args.seed))
    opt = adamw_init(params)
    start = 0
    if mgr and mgr.latest_step() is not None:
        (params, opt), start = mgr.restore((params, opt))
        start += 1
        print(f"[resume] restored checkpoint, continuing at step {start}")

    losses = []
    times: list[float] = []
    stragglers = 0
    for step in range(start, args.steps):
        if step == args.fail_at:
            raise RuntimeError(f"injected failure at step {step}")
        t0 = time.time()
        batch = batch_fn(step)
        params, opt, metrics = jit_step(params, opt, batch, jnp.asarray(step, jnp.int32))
        loss = float(metrics["loss"])
        dt = time.time() - t0
        if len(times) >= 5 and dt > args.step_timeout * float(np.median(times)):
            stragglers += 1
            print(f"[watchdog] step {step} took {dt:.2f}s (median {np.median(times):.2f}s)")
        times.append(dt)
        losses.append(loss)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d}  loss {loss:.4f}  {dt*1000:.0f} ms")
        if mgr and args.ckpt_every and step and step % args.ckpt_every == 0:
            mgr.async_save((params, opt), step)
    if mgr:
        mgr.wait()
        mgr.save((params, opt), args.steps - 1)
    print(
        f"done: first-loss {losses[0]:.4f} last-loss {losses[-1]:.4f} "
        f"stragglers={stragglers}"
    )
    return losses


if __name__ == "__main__":
    main()
