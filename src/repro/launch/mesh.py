"""Production meshes.

Single pod:  (data=16, model=16) = 256 chips (TPU v5e pod).
Multi-pod:   (pod=2, data=16, model=16) = 512 chips; "pod" is an outer
             data-parallel axis crossed once per step by the gradient
             all-reduce (DCN-friendly ordering: pod axis is major).

Functions, not module constants — importing this module never touches jax
device state.  The dry-run process force-hosts 512 devices (XLA_FLAGS set as
the first statement of launch/dryrun.py); the single-pod mesh then uses the
first 256 of them.
"""

from __future__ import annotations

import numpy as np

import jax
from jax.sharding import AxisType, Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, have {len(devices)} — "
            "run under launch/dryrun.py (it force-hosts 512)."
        )
    arr = np.asarray(devices[:need]).reshape(shape)
    return Mesh(arr, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> Mesh:
    """Arbitrary test mesh over the first prod(shape) devices."""
    need = int(np.prod(shape))
    arr = np.asarray(jax.devices()[:need]).reshape(shape)
    return Mesh(arr, axes, axis_types=(AxisType.Auto,) * len(axes))
