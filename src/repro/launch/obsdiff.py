"""Differential run diffing CLI: ``python -m repro.launch.obsdiff A B``.

A and B are any two run artifacts — runtime report JSON, Chrome-trace
export, metrics/monitor JSONL, or ``BENCH_*.json`` — optionally pinned to
a committed revision with ``PATH@GITREV``:

  python -m repro.launch.obsdiff BENCH_engine.json@HEAD~2 BENCH_engine.json
  python -m repro.launch.obsdiff run_a.trace.json run_b.trace.json --top 20
  python -m repro.launch.obsdiff a.monitor.jsonl b.monitor.jsonl --match p99

Output: per-cause stall-ledger delta, per-quantile distribution shift
(when both sides carry streaming-monitor summaries), and a top-K scalar
regression table ranked by relative change.  Stdlib-only; runs without the
jax backend.
"""

from __future__ import annotations

import argparse
import json
import sys

from repro.obs.diffing import diff_runs, format_diff, load_run


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.obsdiff",
        description="diff two runtime reports / traces / metric JSONL / "
                    "BENCH_*.json (optionally PATH@GITREV)")
    ap.add_argument("a", help="baseline run artifact")
    ap.add_argument("b", help="candidate run artifact")
    ap.add_argument("--top", type=int, default=12,
                    help="rows in the regression attribution table")
    ap.add_argument("--match", default=None,
                    help="only diff scalar metrics whose path contains this")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the machine-readable diff here")
    args = ap.parse_args(argv)

    try:
        view_a = load_run(args.a)
        view_b = load_run(args.b)
    except (OSError, ValueError) as e:
        print(f"obsdiff: {e}", file=sys.stderr)
        return 2

    if args.match:
        view_a.scalars = {k: v for k, v in view_a.scalars.items()
                          if args.match in k}
        view_b.scalars = {k: v for k, v in view_b.scalars.items()
                          if args.match in k}

    diff = diff_runs(view_a, view_b, top_k=args.top)
    print(format_diff(diff))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(diff, f, indent=2, sort_keys=True)
        print(f"wrote {args.json}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
