import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

THE two lines above must execute before any jax import (device count locks on
first init) — hence their position.  Never set that flag globally: smoke
tests and benches must see 1 device.

Per cell this driver:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. builds sharded ShapeDtypeStructs for params, optimizer state, batch or
     KV cache (launch/steps.py),
  3. ``jit(step).lower(...).compile()`` — proving the distribution config is
     coherent (sharding mismatches, OOM-at-compile, unsupported collectives
     all fail here),
  4. records memory_analysis(), cost_analysis(), and the collective-op byte
     census parsed from the compiled HLO into results/dryrun/<cell>.json —
     the roofline analysis (benchmarks/bench_roofline.py) reads these.

Usage:
  python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--skip-existing]
"""

import argparse
import gc
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_config, input_specs, list_archs, supports_shape
from repro.core.costmodel import jaxpr_flops_bytes, loop_aware_collectives
from repro.distributed.sharding import use_mesh
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (
    batch_specs,
    build_prefill_step,
    build_serve_step,
    build_train_step,
    cache_specs_tree,
    init_optimizer_shapes,
    param_specs,
    with_sharding,
)
from repro.models import build_model

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}
_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute",
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(typeexpr: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(typeexpr):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_census(hlo_text: str) -> dict:
    """Per-device collective byte counts from the post-SPMD compiled HLO."""
    out: dict[str, dict] = {c: {"count": 0, "bytes": 0} for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        if "=" not in line:
            continue
        head, _, rest = line.partition("=")
        rest = rest.strip()
        for c in _COLLECTIVES:
            # match `<type> opcode(` including async -start forms; skip -done
            # (same buffer as its -start; counting both would double-count).
            m = re.search(rf"^(.*?)\s{c}(-start)?\(", rest)
            if m:
                out[c]["count"] += 1
                out[c]["bytes"] += _shape_bytes(m.group(1))
                break
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items() if isinstance(v, dict))
    return out


def count_params(shapes) -> int:
    return int(sum(np.prod(l.shape) for l in jax.tree.leaves(shapes)))


def count_active_params(cfg, shapes) -> int:
    """MoE-aware active parameter count (top_k + shared of E experts)."""
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        keys = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        n = int(np.prod(leaf.shape))
        parent = keys[-2] if len(keys) > 1 else ""
        if keys[-1] in ("w_gate", "w_up", "w_down") and parent == "moe" and cfg.num_experts:
            n = int(n * cfg.top_k / cfg.num_experts)
        total += n
    return total


# §Perf profiles: each is (logical-rule overrides, train-step kwargs).
# "baseline" is the paper-faithful-era configuration recorded in §Roofline;
# the others are the beyond-paper optimizations iterated in EXPERIMENTS §Perf.
PROFILES: dict[str, dict] = {
    "baseline": {},
    # batch fully sharded over the whole mesh for activations: turns the
    # Megatron-style per-layer activation all-reduces into tiny b-local ones
    "fsdp_act": {"rules": {"batch": ("pod", "data", "model")}},
    # keep MoE dispatch-row intermediates batch-sharded (see models/moe.py)
    "moe_local": {"rules": {"tokens": ("pod", "data")}},
    "fsdp_moe": {"rules": {"batch": ("pod", "data", "model"),
                           "tokens": ("pod", "data", "model")}},
    # the paper's technique on TPU: offload saved block inputs to pinned_host
    "offload": {"offload_names": ["block_in"]},
    # gradient accumulation: 8 microbatches
    "accum8": {"accum_steps": 8},
    "fsdp_accum8": {"rules": {"batch": ("pod", "data", "model")}, "accum_steps": 8},
    "fsdp_moe_accum8": {"rules": {"batch": ("pod", "data", "model"),
                                  "tokens": ("pod", "data", "model")},
                        "accum_steps": 8},
    "fsdp_offload": {"rules": {"batch": ("pod", "data", "model")},
                     "offload_names": ["block_in"]},
    # flash-style chunked attention even at 4k: bounds the per-layer scores
    # working set to q_block x S instead of S x S
    "fsdp_chunked": {"rules": {"batch": ("pod", "data", "model")},
                     "chunked_attn": True},
    "fsdp_moe_chunked": {"rules": {"batch": ("pod", "data", "model"),
                                   "tokens": ("pod", "data", "model")},
                         "chunked_attn": True},
    "moe_local_accum8": {"rules": {"tokens": ("pod", "data")}, "accum_steps": 8},
    "moe_local_fsdp": {"rules": {"batch": ("pod", "data", "model"),
                                 "tokens": ("pod", "data")}},
    # B6: expert weights sharded over model only (no FSDP F-dim over data):
    # removes the partial-sum all-reduce of [E,C,D] inside every MoE layer
    "moe_local_accum8_nofsdp": {"rules": {"tokens": ("pod", "data")},
                                "accum_steps": 8, "fsdp_params": False},
    # B8: hand-written EP all-to-all under shard_map (models/moe.py)
    "moe_shardmap": {"rules": {"moe_impl": "shard_map"}, "fsdp_params": False},
    "moe_shardmap_accum8": {"rules": {"moe_impl": "shard_map"},
                            "accum_steps": 8, "fsdp_params": False},
    # B9: EP shard_map + batch_full attention activations
    "fsdp_moe_shardmap": {"rules": {"moe_impl": "shard_map",
                                    "batch": ("pod", "data", "model")},
                          "fsdp_params": False},
    "fsdp_moe_shardmap_accum8": {"rules": {"moe_impl": "shard_map",
                                           "batch": ("pod", "data", "model")},
                                 "accum_steps": 8, "fsdp_params": False},
    # C: llama4-scale — EP shard_map + ZeRO-3 weight gather + batch_full attn
    "ep_zero3": {"rules": {"moe_impl": "shard_map", "moe_fsdp_gather": True,
                           "batch": ("pod", "data", "model")}},
    "ep_zero3_accum8": {"rules": {"moe_impl": "shard_map", "moe_fsdp_gather": True,
                                  "batch": ("pod", "data", "model")},
                        "accum_steps": 8},
}


def lower_cell(arch: str, shape: str, mesh_kind: str, profile: str = "baseline"):
    """Returns the JSON record for one (arch, shape, mesh) cell."""
    cfg = get_config(arch)
    model = build_model(cfg)
    sp = SHAPES[shape]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    prof = PROFILES[profile]
    rec: dict = {
        "arch": arch, "shape": shape, "mesh": mesh_kind, "profile": profile,
        "mesh_shape": dict(mesh.shape), "kind": sp.kind,
        "seq_len": sp.seq_len, "global_batch": sp.global_batch,
    }
    step_kwargs = {}
    if prof.get("accum_steps"):
        step_kwargs["accum_steps"] = prof["accum_steps"]
    if prof.get("offload_names"):
        from repro.core.offload import remat_policy_for

        step_kwargs["remat_policy"] = remat_policy_for(prof["offload_names"]).policy()
    from repro.models import attention as attn_mod

    attn_mod.CHUNKED_THRESHOLD = 2048 if prof.get("rules", {}).get("chunked_attn") or prof.get("chunked_attn") else 8192

    with use_mesh(mesh, rules=prof.get("rules")):
        pshapes = model.init_shapes()
        pspecs = param_specs(cfg, pshapes, mesh, fsdp=prof.get("fsdp_params"))
        params_in = with_sharding(mesh, pshapes, pspecs)
        rec["n_params"] = count_params(pshapes)
        rec["n_active_params"] = count_active_params(cfg, pshapes)

        t0 = time.time()
        if sp.kind == "train":
            ospecs = init_optimizer_shapes(pshapes)
            from repro.launch.steps import opt_specs_like
            ospec_tree = opt_specs_like(pspecs)
            opt_in = with_sharding(mesh, ospecs, ospec_tree)
            batch = input_specs(cfg, shape)["batch"]
            bspecs = batch_specs(cfg, batch, mesh)
            batch_in = with_sharding(mesh, batch, bspecs)
            step_in = jax.ShapeDtypeStruct((), jnp.int32, sharding=NamedSharding(mesh, P()))
            fn = build_train_step(model, cfg, **step_kwargs)
            args = (params_in, opt_in, batch_in, step_in)
            lowered = jax.jit(fn, donate_argnums=(0, 1)).lower(*args)
            rec["tokens_per_step"] = sp.global_batch * sp.seq_len
        elif sp.kind == "prefill":
            batch = input_specs(cfg, shape)["batch"]
            bspecs = batch_specs(cfg, batch, mesh)
            batch_in = with_sharding(mesh, batch, bspecs)
            fn = build_prefill_step(model, cfg)
            args = (params_in, batch_in)
            lowered = jax.jit(fn).lower(*args)
            rec["tokens_per_step"] = sp.global_batch * sp.seq_len
        else:  # decode
            cache_shapes = jax.eval_shape(
                lambda: model.init_cache(sp.global_batch, sp.seq_len)
            )
            cspecs = cache_specs_tree(cfg, cache_shapes, mesh)
            cache_in = with_sharding(mesh, cache_shapes, cspecs)
            ns = lambda spec: NamedSharding(mesh, spec)
            toks = jax.ShapeDtypeStruct(
                (sp.global_batch, 1), jnp.int32,
                sharding=ns(batch_specs(cfg, {"tokens": jax.ShapeDtypeStruct((sp.global_batch, 1), jnp.int32)}, mesh)["tokens"]),
            )
            pos = jax.ShapeDtypeStruct((), jnp.int32, sharding=ns(P()))
            fn = build_serve_step(model, cfg)
            args = (params_in, cache_in, toks, pos)
            lowered = jax.jit(fn, donate_argnums=(1,)).lower(*args)
            rec["tokens_per_step"] = sp.global_batch
        rec["lower_s"] = round(time.time() - t0, 2)

        # Analytic global cost (loop-aware; see core/costmodel.py for why
        # compiled.cost_analysis() alone can't be trusted across scans).
        closed = jax.make_jaxpr(fn)(*args)
        rec["analytic"] = jaxpr_flops_bytes(closed)
        del closed

        t0 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t0, 2)

        ma = compiled.memory_analysis()
        rec["memory"] = {
            k: int(getattr(ma, k))
            for k in dir(ma)
            if k.endswith("_in_bytes") and isinstance(getattr(ma, k), (int, np.integer))
        }
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        rec["cost"] = {
            "flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
            "transcendentals": float(ca.get("transcendentals", 0.0)),
        }
        hlo_text = compiled.as_text()
        rec["collectives"] = collective_census(hlo_text)
        rec["collectives_loop_aware"] = loop_aware_collectives(hlo_text)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs())
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--profile", choices=list(PROFILES), default="baseline")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    cells = []
    archs = list_archs() if args.all else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    for a in archs:
        cfg = get_config(a)
        for s in shapes:
            if not supports_shape(cfg, s):
                continue
            for m in meshes:
                cells.append((a, s, m))

    os.makedirs(args.out, exist_ok=True)
    suffix = "" if args.profile == "baseline" else f"__{args.profile}"
    n_ok = n_fail = n_skip = 0
    for arch, shape, mesh_kind in cells:
        path = os.path.join(args.out, f"{arch}__{shape}__{mesh_kind}{suffix}.json")
        if args.skip_existing and os.path.exists(path):
            n_skip += 1
            continue
        print(f"=== {arch} x {shape} x {mesh_kind} x {args.profile} ===", flush=True)
        try:
            rec = lower_cell(arch, shape, mesh_kind, args.profile)
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            mem = rec["memory"]
            per_dev = mem.get("argument_size_in_bytes", 0) + mem.get("temp_size_in_bytes", 0)
            print(
                f"    ok  lower={rec['lower_s']}s compile={rec['compile_s']}s "
                f"args+temp={per_dev/2**30:.2f}GiB/dev "
                f"flops={rec['cost']['flops']/1e12:.2f}TF/dev "
                f"coll={rec['collectives']['total_bytes']/2**20:.0f}MiB/dev",
                flush=True,
            )
            n_ok += 1
        except Exception as e:
            print(f"    FAIL {type(e).__name__}: {e}", flush=True)
            with open(path + ".err", "w") as f:
                f.write(traceback.format_exc())
            n_fail += 1
        gc.collect()
    print(f"\ndone: ok={n_ok} fail={n_fail} skip={n_skip}")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
