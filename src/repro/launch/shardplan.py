"""Mesh-aware planning driver: shard a step, solve per-device, execute.

End-to-end ``repro.dist`` pipeline for one architecture's train step:

  1. **capture** — walk the step's jaxpr with the launch/steps.py
     PartitionSpecs for ``--mesh`` (sizes divided per shard, the
     data-parallel gradient all-reduce tagged from the sharded param bytes);
  2. **solve** — the repro.plan pipeline once per device group, artifacts
     keyed by mesh topology in ``--plan-cache`` (never colliding with
     single-device plans of the same step);
  3. **execute** — one runtime tenant per device over per-device HBM pools
     with every DMA channel contending on a shared host link, compared
     contended vs contention-free and collective-aware vs blind.

No real multi-device runtime is needed: capture walks abstract values, so a
``data=4`` mesh plans fine on a single-CPU sandbox.

Usage:
  PYTHONPATH=src python -m repro.launch.shardplan --arch qwen3-4b --smoke \\
      --mesh data=4 --batch 8 --seq 128 --limit-frac 0.6 \\
      [--plan-cache /tmp/plans] [--json shardplan.json]
"""

from __future__ import annotations

import argparse
import json

import jax

from repro.configs import get_config, get_smoke_config, list_archs
from repro.core.simulator import TPU_V5E
from repro.dist import (
    MeshSpec,
    capture_sharded_trace,
    gradient_sync_collective,
    run_mesh,
    schedules_differ,
    solve_sharded,
)
from repro.launch.steps import batch_specs, param_specs
from repro.models import build_model
from repro.obs import add_obs_args, export_monitor, export_trace, recorder_for
from repro.plan import PlanCache, PlanKey


class SpecMesh:
    """The duck-typed slice of ``jax.sharding.Mesh`` the launch/steps.py
    spec builders read (axis_names + shape) — lets them run without real
    devices, which is all planning needs."""

    def __init__(self, mesh: MeshSpec):
        self.axis_names = tuple(n for n, _ in mesh.axes)
        self.shape = dict(mesh.axes)


def probe_from_model(model, batch_fn):
    """(step_fn, example_args) for an already-built model + batch fn — the
    same step probe train.py plans."""
    probe = jax.eval_shape(lambda: batch_fn(0))
    pshapes = model.init_shapes()

    def step_probe(params, b):
        return model.loss(params, b)[0]

    return step_probe, (pshapes, probe)


def build_probe(arch: str, smoke: bool, batch: int, seq: int):
    """Standalone probe builder: (cfg, model, step_fn, args)."""
    from repro.launch.train import make_batch_fn

    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    model = build_model(cfg)
    step_probe, example_args = probe_from_model(
        model, make_batch_fn(cfg, batch, seq, seed=0)
    )
    return cfg, model, step_probe, example_args


def capture_for_mesh(cfg, step_probe, example_args, mesh: MeshSpec, hw,
                     max_scan_unroll: int = 16):
    """Capture ``step_probe`` under the launch/steps.py specs for ``mesh``,
    tagging the data-parallel gradient all-reduce with the per-device
    sharded parameter bytes."""
    pshapes, probe = example_args
    spec_mesh = SpecMesh(mesh)
    pspecs = param_specs(cfg, pshapes, spec_mesh)
    bspecs = batch_specs(cfg, probe, spec_mesh)
    # Per-device gradient payload: every param shard this device owns is
    # all-reduced across the data axes once per step.
    sync = gradient_sync_collective(pshapes, pspecs, mesh)
    return capture_sharded_trace(
        step_probe, *example_args, mesh=mesh, hw=hw, in_specs=(pspecs, bspecs),
        arg_names=["params", "batch"], max_scan_unroll=max_scan_unroll,
        extra_collectives=[sync] if sync else [],
    )


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs(), default="qwen3-4b")
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU-runnable)")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--mesh", default="data=4", help='e.g. "data=4" or "data=4,model=2"')
    ap.add_argument("--limit-frac", type=float, default=0.6,
                    help="per-device AutoSwap limit as a fraction of the shard peak")
    ap.add_argument("--budget-frac", type=float, default=0.7,
                    help="per-device HBM budget as a fraction of the shard peak")
    ap.add_argument("--channels", type=int, default=2)
    ap.add_argument("--iterations", type=int, default=2)
    ap.add_argument("--link-lanes", type=int, default=2,
                    help="global host-link DMA lanes shared by all devices")
    ap.add_argument("--link-bw-frac", type=float, default=1.0,
                    help="shared host-link bandwidth as a fraction of one device link")
    ap.add_argument("--lane-split", choices=("static", "directional"),
                    default="static",
                    help="host-link lane policy for the contended run: shared "
                         "pool, or lanes carved between swap directions from a "
                         "probe run's queue-wait split (repro.tune)")
    ap.add_argument("--size-threshold", type=int, default=1 << 18)
    ap.add_argument("--plan-cache", default=None)
    ap.add_argument("--json", default=None)
    add_obs_args(ap)
    args = ap.parse_args(argv)

    hw = TPU_V5E
    mesh = MeshSpec.parse(args.mesh)
    cfg, model, step_probe, example_args = build_probe(
        args.arch, args.smoke, args.batch, args.seq
    )
    smoke = ":smoke" if args.smoke else ""
    base_key = PlanKey(args.arch, f"train:b{args.batch}s{args.seq}{smoke}", hw.name)
    cache = PlanCache(args.plan_cache) if args.plan_cache else None

    # 1. capture (the single-device capture doubles as the replicated baseline)
    single = capture_for_mesh(cfg, step_probe, example_args, MeshSpec.make(d=1), hw)
    sharded = capture_for_mesh(cfg, step_probe, example_args, mesh, hw)
    single_peak = single.groups["spmd"].trace.peak_load()
    group = sharded.groups["spmd"]
    shard_peak = group.trace.peak_load()
    print(
        f"[dist] mesh {mesh.signature() or '1'}: per-device peak "
        f"{shard_peak / 2**20:.1f}MiB vs replicated {single_peak / 2**20:.1f}MiB "
        f"(x{shard_peak / single_peak:.3f}), {len(group.collectives)} collectives "
        f"({sum(c.seconds for c in group.collectives) * 1e3:.3f} ms/iter)"
    )

    # 2. per-device solve (once per group, fanned out to every device)
    solved = solve_sharded(
        sharded, hw, base_key=base_key, cache=cache,
        limit_frac=args.limit_frac, size_threshold=args.size_threshold,
    )
    for g, program in solved.programs.items():
        src = " (cache)" if solved.cache_hits[g] else ""
        print(
            f"[dist] group {g}: key {program.key.cache_name() if program.key else '-'} "
            f"solved in {solved.solve_ms[g]:.1f} ms{src}"
        )

    # 3. mesh-wide execution: shared-link contention on/off
    budget = int(shard_peak * args.budget_frac)
    kw = dict(
        budget_per_device=budget, channels=args.channels,
        iterations=args.iterations,
        link_bw=hw.link_bw * args.link_bw_frac, link_lanes=args.link_lanes,
        record_events=args.record_events,
    )
    uncontended = run_mesh(solved, hw, contended=False,
                           budget_per_device=budget, channels=args.channels,
                           iterations=args.iterations,
                           record_events=args.record_events)
    # The trace observes the headline cell: contended + contention-aware.
    recorder = recorder_for(args)
    contended = run_mesh(solved, hw, contended=True, contention_aware=True,
                         obs=recorder, lane_split=args.lane_split, **kw)
    blind = run_mesh(solved, hw, contended=True, contention_aware=False, **kw)
    export_trace(args, recorder, contended.report)
    export_monitor(args, recorder)
    if args.verify:
        from repro.analyze import verify_launch

        verify_launch(args, programs=solved.programs, recorder=recorder,
                      report=contended.report)
    if contended.lane_info is not None:
        info = contended.lane_info
        carve = (
            f"{info['out_lanes']} out / {info['lanes'] - info['out_lanes']} in"
            if info["out_lanes"] is not None else "no carve (no evidence)"
        )
        print(
            f"[tune] directional lanes: probe waited "
            f"in {info['probe_wait_in_s']*1e3:.3f}ms / "
            f"out {info['probe_wait_out_s']*1e3:.3f}ms -> {carve}"
        )
    print(
        f"[dist] mean overhead: uncontended {uncontended.mean_overhead()*100:.2f}% | "
        f"shared link {contended.mean_overhead()*100:.2f}% "
        f"(collective-blind {blind.mean_overhead()*100:.2f}%)"
    )
    print(
        f"[dist] contention changes schedules: {schedules_differ(uncontended, contended)}; "
        f"link moved {contended.report.link['bytes_moved']/2**20:.1f}MiB over "
        f"{contended.report.link['lanes']} lanes, "
        f"blackout {contended.report.link['blackout_s']*1e3:.3f} ms"
    )

    if args.json:
        payload = {
            "arch": args.arch,
            "mesh": dict(mesh.axes),
            "topology": sharded.plan_topology(),
            "single_device_peak": single_peak,
            "per_device_peak": shard_peak,
            "collectives": [c.__dict__ for c in group.collectives],
            "budget_per_device": budget,
            "uncontended": uncontended.report.as_dict(),
            "contended": contended.report.as_dict(),
            "contention_blind": blind.report.as_dict(),
            "schedules_changed_by_contention": schedules_differ(uncontended, contended),
            "lane_split": contended.lane_split,
            **({"lane_info": contended.lane_info}
               if contended.lane_info is not None else {}),
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"[dist] wrote {args.json}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
