"""Multi-tenant colocation driver: N workloads, one device, one HBM budget.

Admits several tenant steps — e.g. a prefill worker, a decode worker and a
training job — to the ``repro.runtime`` memory runtime: each tenant's plan
is solved (or restored from ``--plan-cache``) through the ``repro.plan``
pipeline, given a proportional share of the shared budget as its AutoSwap
limit, and the tenants are co-scheduled over ``--channels`` DMA channels.

Tenant specs are ``role`` or ``arch:role`` with roles ``train``, ``prefill``
and ``decode``, optionally suffixed ``@PRIORITY`` (SLO weight; renegotiation
victims are picked lowest-priority first); plan-cache keys match the
train/serve launchers exactly, so a plan solved by
``python -m repro.launch.serve --plan-cache DIR`` warm-starts colocation in
this process and vice versa.

Churn: ``--arrivals`` staggers tenant entry ("0,0.002,0.005" positional, or
"poisson:rate=500,seed=0"), ``--iterations`` runs each tenant N steps, and
``--renegotiate`` lets the runtime shrink a running victim's plan (online
SwapSelection re-solve) instead of only queueing a newcomer that doesn't fit.

Usage:
  PYTHONPATH=src python -m repro.launch.colocate --arch qwen3-4b --smoke \\
      --tenants prefill,decode@2.0 --budget-frac 0.8 --channels 2 \\
      [--arrivals poisson:rate=500] [--renegotiate] [--iterations 4] \\
      [--plan-cache /tmp/plans] [--json colocate.json]
"""

from __future__ import annotations

import argparse
import json

import jax

from repro.configs import get_config, get_smoke_config, list_archs
from repro.core.planner import MemoryPlanner
from repro.core.simulator import TPU_V5E
from repro.models import build_model
from repro.obs import add_obs_args, export_monitor, export_trace, recorder_for
from repro.plan import PlanCache, PlanKey
from repro.runtime import ColocationResult, colocate_programs

SIZE_THRESHOLD = 1 << 18  # match serve.py: smoke models are far below 1 MiB


def _parse_tenants(spec: str, default_arch: str) -> list[tuple[str, str, float]]:
    """``role`` | ``arch:role``, optional ``@PRIORITY`` suffix per tenant."""
    out = []
    for item in spec.split(","):
        item = item.strip()
        if not item:
            continue
        item, _, prio = item.partition("@")
        try:
            priority = float(prio) if prio else 1.0
        except ValueError:
            raise SystemExit(f"bad tenant priority {prio!r} in {item!r}")
        arch, _, role = item.rpartition(":")
        out.append((arch or default_arch, role, priority))
    if not out:
        raise SystemExit("--tenants needs at least one role")
    for arch, role, _ in out:
        if role not in ("train", "prefill", "decode"):
            raise SystemExit(f"unknown tenant role {role!r} (train|prefill|decode)")
    return out


def build_tenant_program(arch: str, role: str, args, cache: PlanCache | None) -> MemoryPlanner:
    """Trace/restore one tenant step as a MemoryProgram behind a planner.

    Step signatures are byte-identical to the train/serve launchers so all
    three share one artifact per (arch, step, hardware).
    """
    import jax.numpy as jnp

    from repro.launch.serve import serve_batch_struct

    cfg = get_smoke_config(arch) if args.smoke else get_config(arch)
    model = build_model(cfg)
    smoke = ":smoke" if args.smoke else ""
    pshapes = model.init_shapes()

    if role == "train":
        from repro.launch.train import make_batch_fn

        batch_fn = make_batch_fn(cfg, args.batch, args.seq, args.seed)
        probe = jax.eval_shape(lambda: batch_fn(0))

        def step_probe(params, batch):
            return model.loss(params, batch)[0]

        key = PlanKey(arch, f"train:b{args.batch}s{args.seq}{smoke}", TPU_V5E.name)
        return MemoryPlanner(
            step_probe, pshapes, probe, hw=TPU_V5E, cache=cache, key=key,
            size_threshold=SIZE_THRESHOLD,
        )

    B, P = args.batch, args.prompt_len
    max_seq = P + args.gen + (cfg.num_patch_tokens if cfg.frontend == "vision_stub" else 0)
    batch = serve_batch_struct(cfg, B, P)

    def prefill_fn(params, b):
        return model.prefill(params, b, max_seq=max_seq)

    if role == "prefill":
        fn, fargs = prefill_fn, (pshapes, batch)
    else:
        _, cache_struct = jax.eval_shape(prefill_fn, pshapes, batch)
        tok = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        pos = jax.ShapeDtypeStruct((), jnp.int32)
        fn, fargs = model.decode_step, (pshapes, cache_struct, tok, pos)
    key = PlanKey(arch, f"{role}:b{B}p{P}s{max_seq}{smoke}", TPU_V5E.name)
    return MemoryPlanner(
        fn, *fargs, hw=TPU_V5E, cache=cache, key=key, size_threshold=SIZE_THRESHOLD
    )


def print_colocation(result: ColocationResult) -> None:
    rep = result.report
    print(
        f"[runtime] budget {result.budget/2**20:.1f}MiB over {rep.channels} DMA "
        f"channels on {rep.hardware} ({rep.policy}); "
        f"makespan {rep.makespan_s*1000:.2f}ms"
    )
    for t in rep.tenants:
        if t.status != "completed":
            print(f"[runtime]   {t.name}: {t.status} (floor {t.floor/2**20:.1f}MiB)")
            continue
        iso = result.isolated.get(t.name)
        iso_oh = f" (isolated {iso.overhead*100:.2f}%)" if iso else ""
        solve = result.plan_solve_ms.get(t.name)
        solve_s = f"  plan solve {solve:.1f}ms" if solve is not None else ""
        arr = f"  arrived {t.arrival_t*1000:.2f}ms" if t.arrival_t else ""
        reneg = (
            f"  renegotiated x{t.renegotiations} "
            f"(-{t.renegotiation_freed_bytes/2**20:.1f}MiB, "
            f"re-solve {t.renegotiation_solve_ms:.1f}ms)"
            if t.renegotiations else ""
        )
        print(
            f"[runtime]   {t.name}: overhead {t.overhead*100:.2f}%{iso_oh}  "
            f"peak {t.peak_resident/2**20:.1f}MiB  stalls {t.stalls}  "
            f"delayed mallocs {t.delayed_mallocs}  "
            f"queue wait {t.queue_wait_s*1000:.2f}ms{arr}{solve_s}{reneg}"
        )
    print(
        f"[runtime] aggregate peak {rep.aggregate_peak/2**20:.1f}MiB vs "
        f"{result.sum_natural_peaks/2**20:.1f}MiB summed isolated provisioning "
        f"(sharing gain {result.sharing_gain*100:.1f}%); "
        f"over-budget events {rep.overflow_events}"
    )
    if rep.renegotiations or rep.renegotiations_cancelled:
        print(
            f"[runtime] renegotiations: {rep.renegotiations} applied "
            f"({rep.renegotiation_freed_bytes/2**20:.1f}MiB freed, "
            f"{rep.renegotiation_solve_ms:.1f}ms re-solve), "
            f"{rep.renegotiations_cancelled} cancelled"
        )


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs(), default="qwen3-4b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--tenants", default="prefill,decode",
                    help="comma list of role or arch:role (train|prefill|decode)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128, help="train tenant sequence length")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--channels", type=int, default=2, help="DMA channels shared by all tenants")
    ap.add_argument("--budget-frac", type=float, default=0.8,
                    help="shared HBM budget as a fraction of summed tenant peaks")
    ap.add_argument("--budget-gb", type=float, default=None,
                    help="absolute shared HBM budget (overrides --budget-frac)")
    ap.add_argument("--scorer", default="swdoa")
    ap.add_argument("--iterations", type=int, default=1,
                    help="iterations each tenant runs (renegotiation applies at barriers)")
    ap.add_argument("--arrivals", default=None,
                    help='tenant arrival times: "0,0.002,0.005" (positional) '
                         'or "poisson:rate=500[,seed=0][,start=0]"')
    ap.add_argument("--renegotiate", action="store_true",
                    help="shrink a running victim's plan (online re-solve at its next "
                         "iteration barrier) instead of only queueing a newcomer")
    ap.add_argument("--budget-split", choices=("proportional", "tuned"),
                    default="proportional",
                    help="how the shared budget splits across tenants: "
                         "proportional to isolated peaks, or coordinate-descent "
                         "tuned to equalize SLO-weighted marginal stall "
                         "(repro.tune)")
    ap.add_argument("--victim-policy", choices=("greedy", "ledger"),
                    default="greedy",
                    help="renegotiation victim selection: floor-greedy (the "
                         "reference default) or ledger-driven (probe candidate "
                         "(victim, limit) pairs by simulated marginal "
                         "SLO-weighted stall)")
    ap.add_argument("--plan-cache", default=None,
                    help="plan artifact directory shared with the train/serve launchers")
    ap.add_argument("--cache-max-mb", type=float, default=None,
                    help="LRU size bound for --plan-cache")
    ap.add_argument("--json", default=None, help="write the machine-readable report here")
    add_obs_args(ap)
    args = ap.parse_args(argv)

    cache = None
    if args.plan_cache:
        max_bytes = int(args.cache_max_mb * 2**20) if args.cache_max_mb else None
        cache = PlanCache(args.plan_cache, max_bytes=max_bytes)

    programs = {}
    priorities: dict[str, float] = {}
    planners: dict[tuple[str, str], MemoryPlanner] = {}
    for arch, role, priority in _parse_tenants(args.tenants, args.arch):
        # Duplicate specs are distinct tenants (two decode workers on one
        # device) sharing one solved program — trace once, admit N times.
        if (arch, role) not in planners:
            planners[(arch, role)] = build_tenant_program(arch, role, args, cache)
        planner = planners[(arch, role)]
        name = f"{arch}:{role}"
        k = 0
        while name in programs:
            k += 1
            name = f"{arch}:{role}#{k}"
        src = "restored from cache" if planner.from_cache else "solved"
        print(f"[plan] {name}: {src}  peak={planner.trace.peak_load()/2**20:.1f}MiB")
        programs[name] = planner.program
        priorities[name] = priority

    arrivals = None
    if args.arrivals:
        from repro.runtime.workload import parse_arrivals

        times = parse_arrivals(args.arrivals, len(programs))
        arrivals = dict(zip(programs, times))
        for n, t in arrivals.items():
            print(f"[churn] {n}: arrives at {t*1000:.2f}ms")

    victim_policy = None
    if args.victim_policy == "ledger":
        from repro.tune import LedgerVictimPolicy

        victim_policy = LedgerVictimPolicy()

    recorder = recorder_for(args)
    result = colocate_programs(
        programs, TPU_V5E,
        budget_frac=args.budget_frac,
        budget=int(args.budget_gb * 2**30) if args.budget_gb else None,
        channels=args.channels,
        scorer=args.scorer,
        size_threshold=SIZE_THRESHOLD,
        cache=cache,
        iterations=args.iterations,
        arrivals=arrivals,
        priorities=priorities,
        renegotiate=args.renegotiate,
        record_events=args.record_events,
        obs=recorder,
        budget_split=args.budget_split,
        victim_policy=victim_policy,
    )
    print_colocation(result)
    if result.split_tuning is not None:
        st = result.split_tuning
        print(
            f"[tune] budget split tuned: SLO-weighted stall "
            f"{st['initial_stall_s']*1000:.2f}ms -> {st['tuned_stall_s']*1000:.2f}ms "
            f"({st['evals']} trial colocations, {len(st['moves'])} moves kept)"
        )
    if victim_policy is not None and victim_policy.staged:
        for d in victim_policy.decision_log:
            print(
                f"[tune] victim {d['victim']} @ {d['t']*1000:.2f}ms: "
                f"{d['candidates']} candidates probed, staged limit "
                f"{d['new_limit']/2**20:.1f}MiB, binding constraint "
                f"{d['binding_constraint']}"
            )
    export_trace(args, recorder, result.report)
    export_monitor(args, recorder)
    if args.verify:
        from repro.analyze import verify_launch

        verify_launch(args, programs=programs, recorder=recorder,
                      report=result.report)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(result.as_dict(), f, indent=2, sort_keys=True)
        print(f"[runtime] wrote {args.json}")
    return result


if __name__ == "__main__":
    main()
