"""Per-device plan solving over a sharded capture.

``ShardedProgram`` runs the existing ``repro.plan`` pipeline once per
*device group* — SPMD shards are identical, so the solve happens once and
fans out to every device in the group — and keys each group's artifact with
the mesh topology (``PlanKey.topology``), so cached per-shard plans never
collide with single-device plans of the same step (or with other meshes /
other PartitionSpec layouts of the same mesh).

On a 1x1 mesh the topology is empty and the single group's program is
byte-identical (``plan.artifact.dumps_canonical``) to what the single-device
pipeline produces for the same step — the dist layer degrades to exactly the
existing path.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

from ..core.simulator import HardwareSpec
from ..plan.artifact import PlanCache
from ..plan.passes import (
    ArtifactSave,
    PassContext,
    Pipeline,
    PoolPlacement,
    SwapSelection,
    TimingAssign,
)
from ..plan.program import MemoryProgram, PlanKey, swap_key
from .capture import ShardedCapture


def group_key(base: PlanKey | None, capture: ShardedCapture, group: str) -> PlanKey | None:
    """PlanKey for one device group: the base key + mesh/spec topology.

    The group name rides in the topology (not the signature) so the solved
    artifact stays addressable from the step identity alone; the single
    SPMD group keeps the bare topology so 1-group captures need no suffix.
    """
    if base is None:
        return None
    topology = capture.plan_topology()
    if topology and group != "spmd":
        topology = f"{topology}/{group}"
    return PlanKey(base.arch, base.step_signature, base.hardware, topology)


@dataclass
class ShardedProgram:
    """Per-group solved programs over one sharded capture."""

    capture: ShardedCapture
    programs: dict[str, MemoryProgram] = field(default_factory=dict)
    solve_ms: dict[str, float] = field(default_factory=dict)
    cache_hits: dict[str, bool] = field(default_factory=dict)
    # Group -> (swap_summaries key, limit) of the schedule solve_sharded
    # solved, so execution picks the right one off a cache-restored program
    # that may hold summaries at several limits.
    swap_keys: dict[str, tuple[str, int]] = field(default_factory=dict)

    def program_for_device(self, device: int) -> MemoryProgram:
        return self.programs[self.capture.device_group[device]]

    def per_device_peak(self) -> dict[str, int]:
        return {g: p.require_trace().peak_load() for g, p in self.programs.items()}


def solve_sharded(
    capture: ShardedCapture,
    hw: HardwareSpec,
    base_key: PlanKey | None = None,
    cache: PlanCache | None = None,
    methods=("best_fit",),
    limit: int | None = None,
    limit_frac: float | None = None,
    scorer: str = "swdoa",
    size_threshold: int = 1 << 20,
    log=None,
) -> ShardedProgram:
    """Solve every distinct device group of ``capture`` through the plan
    pipeline (placement always; a swap schedule when ``limit`` or
    ``limit_frac`` is given), restoring from / persisting to ``cache`` under
    topology-extended keys.

    Identical groups solve once: the pipeline runs per *group*, and every
    device of the group shares the solved ``MemoryProgram``.
    """
    solved = ShardedProgram(capture=capture)
    for name, sharded in capture.groups.items():
        key = group_key(base_key, capture, name)
        ctx = PassContext(hw=hw, cache=cache, key=key,
                         size_threshold=size_threshold, log=log)
        program = None
        if cache is not None and key is not None:
            program = cache.load(key)
        if program is None:
            program = MemoryProgram.from_trace(sharded.trace, key)
            program.dirty = True
        passes = [TimingAssign(), PoolPlacement(methods=methods)]
        group_limit = limit
        if group_limit is None and limit_frac is not None:
            group_limit = int(sharded.trace.peak_load() * limit_frac)
        if group_limit is not None:
            passes.append(SwapSelection(limit=group_limit, scorer=scorer))
            solved.swap_keys[name] = (swap_key(scorer, group_limit), group_limit)
        if cache is not None and key is not None:
            passes.append(ArtifactSave())
        t0 = time.perf_counter()
        program = Pipeline(passes).run(program, ctx)
        solved.solve_ms[name] = (time.perf_counter() - t0) * 1e3
        solved.cache_hits[name] = program.from_cache
        solved.programs[name] = program
    return solved


def solved_decisions(solved: ShardedProgram, group: str):
    """The (limit, decisions) solve_sharded produced for ``group``, or
    (None, []) when only placement was solved."""
    entry = solved.swap_keys.get(group)
    if entry is None:
        return None, []
    k, limit = entry
    summary = solved.programs[group].swap_summaries[k]
    return limit, list(summary.decisions)
