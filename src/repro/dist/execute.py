"""Mesh-wide execution: N per-device tenant groups over one shared host link.

Builds one runtime tenant per device from a solved ``ShardedProgram`` and
runs them through ``runtime.MemoryRuntime`` with

  * a *per-device* HBM pool (each device gets its own accountant and DMA
    channel pool — the engine's ``Tenant.device`` machinery), and
  * a shared ``HostLink`` bandwidth pool: every device's channels contend on
    one PCIe/NVLink budget, and the collectives tagged by the sharded
    tracer black the link out so swap-ins back-schedule around them.

The contention-blind baseline (``contention_aware=False``) keeps the same
physical link but schedules transfers without looking at the collective
windows — the comparison ``bench_dist.py`` gates on.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from ..core.simulator import HardwareSpec
from ..runtime.engine import HostLink, MemoryRuntime, RuntimeReport, Tenant
from .program import ShardedProgram, solved_decisions


@dataclass
class MeshRunResult:
    """One mesh-wide run plus the per-device schedule for comparisons."""

    report: RuntimeReport
    contended: bool
    contention_aware: bool
    # Per-tenant swap schedules as (var, start, end) triples — the observable
    # the contention acceptance compares across model variants.
    schedules: dict[str, dict[str, list[tuple[int, float, float]]]] = field(
        default_factory=dict
    )
    # HostLink lane policy: "static" (shared pool, the default) or
    # "directional" (lanes carved between swap-out and swap-in from a probe
    # run's per-direction queue-wait split — ``repro.tune.lanes``).
    # ``lane_info`` records the probe evidence and the chosen carve.
    lane_split: str = "static"
    lane_info: dict | None = None

    @property
    def makespan_s(self) -> float:
        return self.report.makespan_s

    def max_overhead(self) -> float:
        return max((t.overhead for t in self.report.tenants), default=0.0)

    def mean_overhead(self) -> float:
        ts = self.report.tenants
        return sum(t.overhead for t in ts) / len(ts) if ts else 0.0


def mesh_tenants(
    solved: ShardedProgram,
    iterations: int = 1,
) -> list[Tenant]:
    """One tenant per device; devices of the same group share the solved
    trace/schedule objects (fan-out, not re-solve)."""
    tenants = []
    owned: set[str] = set()
    for device, group in sorted(solved.capture.device_group.items()):
        program = solved.programs[group]
        limit, decisions = solved_decisions(solved, group)
        sharded = solved.capture.groups[group]
        tenants.append(
            Tenant(
                name=f"{group}.d{device}",
                trace=program.require_trace(),
                decisions=list(decisions),
                limit=limit,
                iterations=iterations,
                device=f"d{device}",
                collectives=sharded.collective_map(),
                # One blackout per mesh-wide collective: the group's first
                # device owns registering it on the shared link.
                collective_owner=group not in owned,
            )
        )
        owned.add(group)
    return tenants


def run_mesh(
    solved: ShardedProgram,
    hw: HardwareSpec,
    budget_per_device: int | None = None,
    channels: int = 2,
    iterations: int = 1,
    link_bw: float | None = None,
    link_lanes: int | None = None,
    contended: bool = True,
    contention_aware: bool = True,
    prefetch: str = "backsched",
    record_events: bool = True,
    obs=None,
    lane_split: str = "static",
) -> MeshRunResult:
    """Execute the solved per-device plans mesh-wide.

    ``link_bw`` defaults to the device link bandwidth — i.e. ONE device's
    worth of host bandwidth shared by all of them, the typical one-root-
    complex host.  ``link_lanes`` defaults to 2 (one out + one in lane
    globally).  ``contended=False`` removes the shared link entirely
    (every device gets its full private bandwidth — the upper bound).

    ``lane_split="directional"`` first runs an unlogged probe over the same
    configuration with the default shared lane pool, reads the link's
    per-direction queue-wait decomposition, and carves the lanes between
    swap-out and swap-in proportionally (``repro.tune.lane_split_from_waits``)
    for the reported run.  Falls back to the shared pool when the probe
    shows no directional evidence (or ``link_lanes < 2``); the chosen carve
    and the probe evidence land in ``MeshRunResult.lane_info``.

    ``record_events=False`` drops the per-transfer logs for long-horizon
    runs; ``schedules`` is then empty (``schedules_differ`` needs the logs,
    so keep the default when comparing schedule variants).

    ``obs`` attaches a ``repro.obs.ObsRecorder`` for Perfetto trace export
    (pure observer: the report is bit-identical with or without it).
    """
    if lane_split not in ("static", "directional"):
        raise ValueError(f"unknown lane_split {lane_split!r}")
    total_bw = link_bw if link_bw is not None else hw.link_bw
    lanes = link_lanes if link_lanes is not None else 2
    out_lanes = None
    lane_info = None
    if lane_split == "directional" and contended:
        from ..tune.lanes import lane_split_from_waits

        probe = MemoryRuntime(
            hw, budget=budget_per_device, channels=channels, prefetch=prefetch,
            link=HostLink.make(total_bw=total_bw, lanes=lanes),
            contention_aware=contention_aware, record_events=False,
        )
        probe.run(mesh_tenants(solved, iterations=iterations))
        out_lanes = lane_split_from_waits(
            probe.link.wait_in_s, probe.link.wait_out_s, lanes,
            bytes_in=probe.link.bytes_in, bytes_out=probe.link.bytes_out,
        )
        lane_info = {
            "probe_wait_in_s": probe.link.wait_in_s,
            "probe_wait_out_s": probe.link.wait_out_s,
            "probe_bytes_in": probe.link.bytes_in,
            "probe_bytes_out": probe.link.bytes_out,
            "lanes": lanes,
            "out_lanes": out_lanes,
        }
    link = None
    if contended:
        link = HostLink.make(total_bw=total_bw, lanes=lanes, out_lanes=out_lanes)
    rt = MemoryRuntime(
        hw,
        budget=budget_per_device,
        channels=channels,
        prefetch=prefetch,
        link=link,
        contention_aware=contention_aware,
        record_events=record_events,
        obs=obs,
    )
    report = rt.run(mesh_tenants(solved, iterations=iterations))
    schedules = (
        {
            name: {
                "out": [(v, s, e) for v, s, e, _ in run.out_events],
                "in": [(v, s, e) for v, s, e, _ in run.in_events],
            }
            for name, run in rt.runs.items()
        }
        if record_events
        else {}
    )
    return MeshRunResult(
        report=report,
        contended=contended,
        contention_aware=contention_aware,
        schedules=schedules,
        lane_split=lane_split,
        lane_info=lane_info,
    )


def schedules_differ(a: MeshRunResult, b: MeshRunResult) -> bool:
    """True when any tenant's swap schedule (transfer start/end times or
    transfer set) differs between two runs — the observable the contention
    acceptance criterion is stated over."""
    if set(a.schedules) != set(b.schedules):
        return True
    for name, sched in a.schedules.items():
        if sched != b.schedules[name]:
            return True
    return False
