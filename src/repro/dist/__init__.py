"""repro.dist: mesh-aware trace capture, per-device planning, execution.

  capture  — MeshSpec (mesh shape as data), sharded jaxpr walking (sizes
             divided by PartitionSpec-derived shard divisors), collective
             tagging with interconnect cost-model durations
  program  — ShardedProgram: the repro.plan Pipeline once per device group
             (identical SPMD shards solve once and fan out), artifacts keyed
             by mesh topology so per-shard plans never collide with
             single-device plans in one PlanCache
  execute  — run_mesh: one runtime tenant per device, per-device HBM pools,
             all DMA channels contending on a shared HostLink with
             collective blackouts

Driven by ``python -m repro.launch.shardplan`` (and ``launch/train.py
--dist-plan``); measured by ``benchmarks/bench_dist.py``.
"""

from .capture import (
    COLLECTIVE_PRIMS,
    Collective,
    MeshSpec,
    ShardedCapture,
    ShardedTrace,
    capture_sharded_trace,
    collective_seconds,
    divisors_from_specs,
    gradient_sync_collective,
    shard_divisor,
    shard_existing_trace,
    sharded_param_bytes,
)
from .execute import MeshRunResult, mesh_tenants, run_mesh, schedules_differ
from .program import ShardedProgram, group_key, solve_sharded, solved_decisions

__all__ = [
    "COLLECTIVE_PRIMS",
    "Collective",
    "MeshSpec",
    "ShardedCapture",
    "ShardedTrace",
    "capture_sharded_trace",
    "collective_seconds",
    "divisors_from_specs",
    "gradient_sync_collective",
    "shard_divisor",
    "shard_existing_trace",
    "sharded_param_bytes",
    "MeshRunResult",
    "mesh_tenants",
    "run_mesh",
    "schedules_differ",
    "ShardedProgram",
    "group_key",
    "solve_sharded",
    "solved_decisions",
]
