"""Sharded trace capture: per-device event streams from a partitioned step.

The paper derives lifetimes and read/write order from the iterative loop of a
*single* device.  Under a ``shard_map``/``jit``-sharded step each device owns
a *fraction* of every partitioned tensor and crosses the interconnect at
every collective — both of which the single-device tracer cannot see.  This
module walks the same jaxpr the single-device tracer walks, but

  * divides every variable's size by its *shard divisor* — derived from the
    step's input ``PartitionSpec``s (the launch/steps.py spec builders) and
    propagated through equations (an output inherits the largest input
    divisor that divides its byte size; anything else is replicated), and
  * tags collective equations (``psum``/``all_gather``/``reduce_scatter``/…)
    with cost-model durations on the device interconnect, so the planner's
    timeline contains the windows a swap may (or may not) overlap.

On a 1x1 mesh every divisor is 1 and no collective fires, so the emitted
event stream — and therefore the solved plan — is byte-identical to the
single-device ``trace_step_fn`` path (pinned by tests/test_dist.py).

SPMD means every device executes the same program over same-shaped shards,
so one capture describes a whole *device group*; ``ShardedCapture`` keeps
the group->devices map explicit so heterogeneous groups (e.g. per-host
parameter servers) slot in without changing consumers.
"""

from __future__ import annotations

import hashlib
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Sequence

from ..core.events import IterationTrace, build_trace
from ..core.simulator import HardwareSpec
from ..core.trace import _MAX_SCAN_UNROLL, _JaxprEventEmitter, _with_frees

# jaxpr primitives that cross the device interconnect.  ``pmean`` lowers to
# psum; reduce_scatter appears as psum_scatter in recent jax.
COLLECTIVE_PRIMS = {
    "psum": "all_reduce",
    "pmax": "all_reduce",
    "pmin": "all_reduce",
    "all_gather": "all_gather",
    "psum_scatter": "reduce_scatter",
    "reduce_scatter": "reduce_scatter",
    "all_to_all": "all_to_all",
    "ppermute": "permute",
    "pbroadcast": "broadcast",
}


# ------------------------------------------------------------------- meshes
@dataclass(frozen=True)
class MeshSpec:
    """Device-mesh shape as data: ordered (axis name, size) pairs.

    A plain-data twin of ``jax.sharding.Mesh`` so planning and benchmarks
    never need real (or force-hosted) devices — the capture walks an
    abstract jaxpr and only the *sizes* matter.
    """

    axes: tuple[tuple[str, int], ...]

    @classmethod
    def make(cls, **axes: int) -> "MeshSpec":
        return cls(tuple((k, int(v)) for k, v in axes.items()))

    @classmethod
    def parse(cls, text: str) -> "MeshSpec":
        """Parse ``"data=4"`` / ``"data=4,model=2"`` (CLI mesh syntax)."""
        pairs = []
        for item in text.split(","):
            item = item.strip()
            if not item:
                continue
            name, _, size = item.partition("=")
            try:
                pairs.append((name.strip(), int(size)))
            except ValueError:
                raise ValueError(f"bad mesh axis {item!r} (want name=size)")
        if not pairs:
            raise ValueError(f"empty mesh spec {text!r}")
        return cls(tuple(pairs))

    @classmethod
    def from_mesh(cls, mesh) -> "MeshSpec":
        """From a live ``jax.sharding.Mesh`` (launch/mesh.py builders)."""
        return cls(tuple((str(n), int(mesh.shape[n])) for n in mesh.axis_names))

    @property
    def num_devices(self) -> int:
        n = 1
        for _, s in self.axes:
            n *= s
        return n

    def axis_size(self, names) -> int:
        """Product of the named axes' sizes (missing axes count as 1)."""
        if names is None:
            return 1
        if isinstance(names, str):
            names = (names,)
        sizes = dict(self.axes)
        n = 1
        for name in names:
            n *= sizes.get(name, 1)
        return n

    def signature(self) -> str:
        """Filesystem/key-safe mesh shape, empty for a single device so 1x1
        captures key identically to the legacy single-device path."""
        if self.num_devices <= 1:
            return ""
        return "x".join(f"{n}{s}" for n, s in self.axes)


def shard_divisor(shape: Sequence[int], spec, mesh: MeshSpec) -> int:
    """How many ways a tensor of ``shape`` is split under ``spec``.

    ``spec`` is a ``jax.sharding.PartitionSpec``-like sequence: one entry per
    dim, each None, an axis name, or a tuple of axis names.  A dim that the
    mesh axes do not divide evenly degrades to replicated for that dim —
    matching the launch/steps.py divisibility guard.
    """
    div = 1
    for dim, part in zip(shape, spec):
        if part is None:
            continue
        k = mesh.axis_size(part)
        if k > 1 and dim % k == 0:
            div *= k
    return div


def divisors_from_specs(shapes, specs, mesh: MeshSpec) -> list[int]:
    """Per-leaf shard divisors for a pytree of (ShapeDtypeStruct, spec) pairs,
    flattened in jaxpr-invars order (the order ``jax.make_jaxpr`` flattens
    arguments)."""
    import jax
    from jax.sharding import PartitionSpec

    shape_leaves = jax.tree_util.tree_leaves(shapes)
    spec_leaves = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: x is None or isinstance(x, PartitionSpec)
    )
    if len(shape_leaves) != len(spec_leaves):
        raise ValueError(
            f"{len(shape_leaves)} shape leaves vs {len(spec_leaves)} spec leaves"
        )
    out = []
    for leaf, spec in zip(shape_leaves, spec_leaves):
        if spec is None:
            out.append(1)
        else:
            out.append(shard_divisor(leaf.shape, spec, mesh))
    return out


# -------------------------------------------------------------- collectives
@dataclass(frozen=True)
class Collective:
    """One tagged interconnect operation within the iteration."""

    index: int          # op index in the per-device event stream
    kind: str           # canonical name (all_reduce / all_gather / ...)
    nbytes: int         # per-device payload bytes
    seconds: float      # modeled interconnect occupancy


def collective_seconds(kind: str, nbytes: int, ndev: int, hw: HardwareSpec) -> float:
    """Ring cost model: all-reduce moves 2(D-1)/D of the payload per device,
    gather/scatter (D-1)/D, permutes one hop."""
    if ndev <= 1 or nbytes <= 0:
        return 0.0
    bw = hw.ici_bw or hw.link_bw
    if kind == "all_reduce":
        factor = 2.0 * (ndev - 1) / ndev
    elif kind in ("all_gather", "reduce_scatter", "all_to_all"):
        factor = (ndev - 1) / ndev
    else:  # permute / broadcast: one hop
        factor = 1.0
    return factor * nbytes / bw + hw.collective_latency_s


# ------------------------------------------------------------------ capture
@dataclass
class ShardedTrace:
    """Per-device-group iteration trace plus its tagged collectives."""

    trace: IterationTrace
    collectives: list[Collective] = field(default_factory=list)

    def collective_map(self) -> dict[int, float]:
        """Op index -> seconds, the shape ``runtime.Tenant.collectives`` takes."""
        out: dict[int, float] = {}
        for c in self.collectives:
            out[c.index] = out.get(c.index, 0.0) + c.seconds
        return out


@dataclass
class ShardedCapture:
    """One sharded capture: the mesh, the per-group streams, and which
    devices run which group (SPMD: one group spanning every device)."""

    mesh: MeshSpec
    groups: dict[str, ShardedTrace]
    device_group: dict[int, str]
    spec_signature: str = ""

    def plan_topology(self) -> str:
        """The ``PlanKey.topology`` value: mesh shape + PartitionSpec
        signature.  Empty on a 1x1 mesh, so single-device plans keep their
        legacy keys (and a sharded plan can never alias one)."""
        mesh_sig = self.mesh.signature()
        if not mesh_sig:
            return ""
        return f"{mesh_sig}-{self.spec_signature}" if self.spec_signature else mesh_sig


class _ShardedEventEmitter(_JaxprEventEmitter):
    """The single-device jaxpr interpreter, re-sized per shard.

    Every variable gets a *divisor*: inputs from their PartitionSpecs,
    intermediates by propagation (largest input divisor that divides the
    output's byte size; otherwise replicated).  The divisor context is a
    stack-restored instance attribute because the parent class allocates ids
    deep inside scan/call handling — every ``_fresh`` sees the divisor of
    the innermost equation being interpreted.
    """

    def __init__(self, mesh: MeshSpec, hw: HardwareSpec,
                 max_scan_unroll: int = _MAX_SCAN_UNROLL):
        super().__init__(max_scan_unroll=max_scan_unroll)
        self.mesh = mesh
        self.hw = hw
        self.divisors: dict[int, int] = {}
        self.collectives: list[Collective] = []
        self._ctx_div = 1
        # Per-input divisors, drained positionally by the first
        # len(jaxpr.invars) _fresh calls — exactly the input mallocs the
        # parent run() emits before anything else.
        self._arg_divs: "deque[int]" = deque()

    # -- sizing ---------------------------------------------------------
    def _fresh(self, size: int, name: str = "") -> int:
        div = self._arg_divs.popleft() if self._arg_divs else self._ctx_div
        if div <= 1 or size <= 0 or size % div != 0:
            div = 1
        vid = super()._fresh(size // div, name)
        self.divisors[vid] = div
        return vid

    def _propagated_div(self, eqn, env) -> int:
        div = 1
        for iv in eqn.invars:
            vid = self._read(env, iv)
            if vid is not None:
                div = max(div, self.divisors.get(vid, 1))
        return div

    # -- interpretation -------------------------------------------------
    def _run_eqn(self, eqn, env: dict) -> None:
        prim = eqn.primitive.name
        kind = COLLECTIVE_PRIMS.get(prim)
        prev = self._ctx_div
        self._ctx_div = self._propagated_div(eqn, env)
        try:
            if kind is not None and self.mesh.num_devices > 1:
                self._run_collective(eqn, env, kind)
            else:
                super()._run_eqn(eqn, env)
        finally:
            self._ctx_div = prev

    def _run_scan(self, eqn, env: dict) -> None:
        """Parent scan unrolling with *per-atom* divisor context.

        The generic eqn hook applies the max input divisor to every output,
        which is wrong inside a scan: a replicated stacked-weights xs input
        must not inherit the batch-sharded carry's divisor (its per-trip
        slices would be undersized by the shard factor, and per-device peak
        would undercount replicated memory).  Mirrors
        ``core.trace._JaxprEventEmitter._run_scan`` event-for-event — the
        1x1 byte-identity tests pin any divergence — inserting only
        ``_ctx_div`` assignments from each atom's own recorded divisor.
        """
        from ..core.events import EventKind
        from ..core.trace import _aval_bytes, jcore

        scan_div = self._ctx_div  # the generic propagated div, for outputs
        p = eqn.params
        body = p["jaxpr"]
        length = int(p["length"])
        n_carry, n_consts = int(p["num_carry"]), int(p["num_consts"])
        trips = min(length, self._max_unroll)

        self._read_inputs(eqn, env)
        const_ids = [self._read(env, iv) for iv in eqn.invars[:n_consts]]
        carry_ids = [self._read(env, iv) for iv in eqn.invars[n_consts:n_consts + n_carry]]
        xs_atoms = eqn.invars[n_consts + n_carry:]
        xs_divs = [
            self.divisors.get(self._read(env, xa), 1) if self._read(env, xa) is not None else 1
            for xa in xs_atoms
        ]
        carry_divs = [
            self.divisors.get(cid, 1) if cid is not None else 1 for cid in carry_ids
        ]

        body_invars = body.jaxpr.invars
        for t in range(trips):
            inner_env: dict = {}
            for bv, cid in zip(body_invars[:n_consts], const_ids):
                if cid is not None:
                    inner_env[bv] = cid
            for bv, cid in zip(body_invars[n_consts:n_consts + n_carry], carry_ids):
                if cid is not None:
                    inner_env[bv] = cid
            # xs slices: one layer's worth of each stacked input, sharded
            # exactly as the stacked input itself is.
            for (bv, xa), xdiv in zip(
                zip(body_invars[n_consts + n_carry:], xs_atoms), xs_divs
            ):
                self._ctx_div = xdiv
                vid = self._fresh(_aval_bytes(bv.aval), f"scan_x[{t}]")
                inner_env[bv] = vid
                self._emit(EventKind.MALLOC, vid)
                self._emit(EventKind.WRITE, vid)
            self._ctx_div = 1
            for cv in body.jaxpr.constvars:
                inner_env[cv] = self._fresh(0, "const")
                self._emit(EventKind.MALLOC, inner_env[cv])
            self._run_jaxpr(body.jaxpr, inner_env)
            # New carries come from body outputs; a literal/missing output
            # keeps the incoming carry's sharding.
            new_carry = []
            for ov, cdiv in zip(body.jaxpr.outvars[:n_carry], carry_divs):
                if isinstance(ov, jcore.Literal) or ov not in inner_env:
                    self._ctx_div = cdiv
                    vid = self._fresh(_aval_bytes(ov.aval), "carry")
                    self._emit(EventKind.MALLOC, vid)
                    self._emit(EventKind.WRITE, vid)
                else:
                    vid = inner_env[ov]
                new_carry.append(vid)
            # ys slices are read (copied into the stacked output).
            for ov in body.jaxpr.outvars[n_carry:]:
                if not isinstance(ov, jcore.Literal) and ov in inner_env:
                    self._emit(EventKind.READ, inner_env[ov])
            carry_ids = new_carry
        self._ctx_div = scan_div
        self._bind_outputs(eqn, env, suffix=f"[{trips}x]")

    def _run_collective(self, eqn, env: dict, kind: str) -> None:
        """A collective reads its (per-shard) inputs, occupies the
        interconnect, and writes its outputs; the payload is the per-shard
        input bytes already divided by the sharding."""
        nbytes = 0
        for iv in eqn.invars:
            vid = self._read(env, iv)
            if vid is not None:
                nbytes += self.sizes.get(vid, 0)
        self._read_inputs(eqn, env)
        cost_index = self._index  # charged to the first output, like compute
        self._bind_outputs(eqn, env)
        ndev = _collective_device_count(eqn, self.mesh)
        seconds = collective_seconds(kind, nbytes, ndev, self.hw)
        if seconds > 0.0:
            self.collectives.append(Collective(cost_index, kind, nbytes, seconds))

    def run_with_divisors(
        self,
        closed,
        arg_names: Sequence[str] | None = None,
        arg_divisors: Sequence[int] | None = None,
    ) -> None:
        """Parent ``run`` with per-input divisors from the PartitionSpecs.

        Delegates to ``_JaxprEventEmitter.run`` (byte-identical event order
        by construction): the divisor queue is drained positionally by the
        input mallocs — the parent's first ``len(invars)`` ``_fresh`` calls.
        """
        n_inputs = len(closed.jaxpr.invars)
        self._arg_divs = deque((arg_divisors or [])[:n_inputs])
        try:
            self.run(closed, arg_names=arg_names)
        finally:
            self._arg_divs = deque()


def _synthesized(
    extra: Sequence[tuple], trace: IterationTrace, mesh: MeshSpec, hw: HardwareSpec
) -> list[Collective]:
    """Cost-model collectives a jitted (GSPMD) jaxpr cannot show: XLA inserts
    them at compile time, so callers name the known ones.  Entries are
    ``(kind, nbytes[, op_index[, ndev]])``: op_index defaults to the
    iteration boundary (the data-parallel gradient sync position; None also
    means that), a float in [0, 1) is a fraction of the iteration (op counts
    aren't known pre-capture), and ``ndev`` scopes the collective to its
    participating axis (e.g. 4 for a data-axis all-reduce on a
    data=4,model=2 mesh) instead of the whole mesh."""
    out: list[Collective] = []
    tail = max(0, trace.num_indices - 1)
    for entry in extra:
        kind, nbytes = entry[0], int(entry[1])
        index = tail
        if len(entry) > 2 and entry[2] is not None:
            pos = entry[2]
            index = int(pos * tail) if isinstance(pos, float) and 0 <= pos < 1 else int(pos)
        index = max(0, min(index, tail))
        ndev = int(entry[3]) if len(entry) > 3 and entry[3] else mesh.num_devices
        seconds = collective_seconds(kind, nbytes, ndev, hw)
        if seconds > 0.0:
            out.append(Collective(index, kind, nbytes, seconds))
    return out


def sharded_param_bytes(shapes, specs, mesh: MeshSpec) -> int:
    """Per-device bytes of a (shapes, PartitionSpecs) pytree pair — what one
    device holds of the parameters, i.e. its gradient-sync payload."""
    import jax
    import numpy as np

    divs = divisors_from_specs(shapes, specs, mesh)
    leaves = jax.tree_util.tree_leaves(shapes)
    return sum(
        int(np.prod(leaf.shape)) * np.dtype(leaf.dtype).itemsize // d
        for leaf, d in zip(leaves, divs)
    )


def gradient_sync_collective(
    pshapes, pspecs, mesh: MeshSpec, axes=("pod", "data")
) -> "tuple | None":
    """The data-parallel gradient all-reduce as an ``extra_collectives``
    entry (iteration boundary, scoped to the data axes), or None when the
    mesh has no data parallelism.  One definition shared by the shardplan
    CLI and the benchmarks so both price the same cost model."""
    ndev = mesh.axis_size(tuple(axes))
    if ndev <= 1:
        return None
    return ("all_reduce", sharded_param_bytes(pshapes, pspecs, mesh), None, ndev)


def _collective_device_count(eqn, mesh: MeshSpec) -> int:
    """Devices participating in a collective: the product of its axis-name
    params' sizes, falling back to the whole mesh."""
    names = eqn.params.get("axes") or eqn.params.get("axis_name")
    if names is None:
        return mesh.num_devices
    if isinstance(names, (str, int)):
        names = (names,)
    n = 1
    for a in names:
        n *= mesh.axis_size(a) if isinstance(a, str) else 1
    return n if n > 1 else mesh.num_devices


def _spec_signature_from_divisors(divisors: Sequence[int]) -> str:
    """Stable short hash of the per-input shard pattern: two captures of the
    same step under different PartitionSpecs must key differently."""
    raw = ",".join(str(d) for d in divisors)
    return hashlib.sha256(raw.encode()).hexdigest()[:8]


# Must match plan.passes.TraceCapture's default: on a 1x1 mesh the capture
# shares the single-device PlanKey (empty topology), so any tracer setting
# that changes the event stream has to agree or the two paths would write
# different plans under one cache name.
_CAPTURE_MAX_SCAN_UNROLL = 16


def capture_sharded_trace(
    fn: Callable,
    *example_args,
    mesh: MeshSpec,
    hw: HardwareSpec,
    in_specs=None,
    arg_names: Sequence[str] | None = None,
    max_scan_unroll: int = _CAPTURE_MAX_SCAN_UNROLL,
    extra_collectives: Sequence[tuple[str, int]] = (),
) -> ShardedCapture:
    """Capture the per-device event stream of one sharded step.

    ``in_specs`` is a pytree of PartitionSpecs matching ``example_args``
    (the launch/steps.py builders produce exactly this), or None for fully
    replicated inputs.  ``extra_collectives`` appends cost-model collectives
    the jaxpr does not contain explicitly — a GSPMD-jitted train step holds
    no collective eqns (XLA inserts them at compile time), so callers name
    the known ones, e.g. ``[("all_reduce", grad_bytes)]`` for the data-
    parallel gradient sync at the iteration boundary.

    Works entirely on abstract values: no real (or force-hosted) multi-device
    runtime is required, which is what lets benchmarks and CI capture 4-way
    meshes on a single-CPU sandbox.
    """
    import jax

    closed = jax.make_jaxpr(fn)(*example_args)
    arg_divisors = None
    if in_specs is not None:
        arg_divisors = divisors_from_specs(example_args, in_specs, mesh)
    em = _ShardedEventEmitter(mesh, hw, max_scan_unroll=max_scan_unroll)
    em.run_with_divisors(closed, arg_names=arg_names, arg_divisors=arg_divisors)
    events, index_map = _with_frees(em.events)
    trace = build_trace(events)
    trace.op_costs = {
        index_map[i]: cost for i, cost in em.op_costs.items() if i in index_map
    }
    info_by_id = trace.by_id()
    for vid, name in em.names.items():
        if vid in info_by_id:
            info_by_id[vid].name = name
    collectives = [
        Collective(index_map[c.index], c.kind, c.nbytes, c.seconds)
        for c in em.collectives
        if c.index in index_map
    ]
    collectives.extend(_synthesized(extra_collectives, trace, mesh, hw))
    if mesh.num_devices > 1 and collectives:
        trace.op_extra_s = {}
        for c in collectives:
            trace.op_extra_s[c.index] = trace.op_extra_s.get(c.index, 0.0) + c.seconds
    sharded = ShardedTrace(trace=trace, collectives=sorted(collectives, key=lambda c: c.index))
    spec_sig = (
        _spec_signature_from_divisors(arg_divisors)
        if arg_divisors and mesh.num_devices > 1
        else ""
    )
    return ShardedCapture(
        mesh=mesh,
        groups={"spmd": sharded},
        device_group={d: "spmd" for d in range(mesh.num_devices)},
        spec_signature=spec_sig,
    )


def shard_existing_trace(
    trace: IterationTrace,
    mesh: MeshSpec,
    hw: HardwareSpec,
    divisor_fn: Callable[[str, int], int],
    extra_collectives: Sequence[tuple[str, int]] = (),
) -> ShardedCapture:
    """Re-size an already-captured single-device trace by a per-variable
    divisor rule ``divisor_fn(name, size) -> int`` (e.g. batch-sharded
    activations / replicated weights for the CNN benchmark traces).

    The cheap route into ``repro.dist`` for workloads whose trace exists but
    whose step function is not at hand; the jaxpr route above is the
    faithful one.
    """
    variables = []
    applied: list[int] = []
    for v in trace.variables:
        div = max(1, int(divisor_fn(v.name, v.size)))
        if v.size % div != 0:
            div = 1
        applied.append(div)
        variables.append(
            type(v)(
                var=v.var,
                size=v.size // div,
                alloc_index=v.alloc_index,
                free_index=v.free_index,
                accesses=list(v.accesses),
                access_is_write=list(v.access_is_write),
                name=v.name,
            )
        )
    sharded = IterationTrace(variables, trace.num_indices)
    if trace.op_costs is not None:
        # Per-device compute touches per-device bytes; flops scale the same
        # way for batch-parallel work.
        ndev = mesh.num_devices
        sharded.op_costs = {
            i: (f / ndev, b / ndev) for i, (f, b) in trace.op_costs.items()
        }
    collectives = _synthesized(extra_collectives, sharded, mesh, hw)
    if collectives:
        sharded.op_extra_s = {}
        for c in collectives:
            sharded.op_extra_s[c.index] = sharded.op_extra_s.get(c.index, 0.0) + c.seconds
    return ShardedCapture(
        mesh=mesh,
        groups={"spmd": ShardedTrace(sharded, collectives)},
        device_group={d: "spmd" for d in range(mesh.num_devices)},
        # Signed by the divisors actually applied, so two different rules
        # (or an edited rule) over the same trace never share a PlanKey.
        spec_signature=f"rule{_spec_signature_from_divisors(applied)}",
    )
