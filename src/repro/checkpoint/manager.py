"""Fault-tolerant checkpointing: atomic, async, keep-k, mesh-independent.

Layout:  <dir>/step_<N>/  shard_<host>.npz  +  MANIFEST.json
Writes go to ``step_<N>.tmp`` and are renamed only after fsync — a host dying
mid-write can never corrupt the latest checkpoint (restore picks the highest
complete step).  Saves can run on a background thread (``async_save``) so the
train loop overlaps serialization with compute; ``wait()`` joins before the
next save or exit.

Checkpoints store *host-local, unsharded* numpy arrays keyed by pytree path,
so a restart may use a different mesh shape / device count (elastic resume):
the loader builds whatever sharding the new mesh prescribes via
``jax.device_put`` against the restored host arrays.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_pytree(tree, directory: str, step: int) -> str:
    """Atomic synchronous save; returns the final directory."""
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(tree)
    np.savez(os.path.join(tmp, "shard_0.npz"), **flat)
    manifest = {
        "step": step,
        "num_leaves": len(flat),
        "keys": sorted(flat),
        "format": 1,
    }
    with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def restore_pytree(template, directory: str, step: int | None = None):
    """Restore into the structure (and shardings) of ``template``.

    ``template`` supplies the pytree structure + dtypes; leaves may be arrays
    or ShapeDtypeStructs.  Returns (tree, step).
    """
    step = latest_step(directory) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no complete checkpoint under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "MANIFEST.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "shard_0.npz"))
    flat_t, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, leaf in flat_t:
        key = "/".join(str(getattr(q, "key", getattr(q, "idx", q))) for q in p)
        arr = data[key]
        if hasattr(leaf, "sharding") and not isinstance(leaf, jax.ShapeDtypeStruct):
            arr = jax.device_put(arr.astype(leaf.dtype), leaf.sharding)
        elif isinstance(leaf, jax.ShapeDtypeStruct) and leaf.sharding is not None:
            arr = jax.device_put(arr.astype(leaf.dtype), leaf.sharding)
        leaves.append(arr)
    assert len(leaves) == manifest["num_leaves"], "checkpoint/template mismatch"
    return jax.tree_util.tree_unflatten(treedef, leaves), step


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    best = None
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, name, "MANIFEST.json")):
                best = max(best or -1, int(name.split("_")[1]))
    return best


class CheckpointManager:
    """keep-k retention + async background saves + resume."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    # -- save ---------------------------------------------------------------
    def save(self, tree, step: int) -> None:
        save_pytree(tree, self.directory, step)
        self._gc()

    def async_save(self, tree, step: int) -> None:
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)  # snapshot before returning

        def run():
            try:
                save_pytree(host_tree, self.directory, step)
                self._gc()
            except BaseException as e:
                self._error = e

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    # -- restore ------------------------------------------------------------
    def latest_step(self) -> int | None:
        return latest_step(self.directory)

    def restore(self, template, step: int | None = None):
        return restore_pytree(template, self.directory, step)

    # -- retention ----------------------------------------------------------
    def _gc(self) -> None:
        steps = sorted(
            int(n.split("_")[1])
            for n in os.listdir(self.directory)
            if n.startswith("step_") and not n.endswith(".tmp")
        )
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"), ignore_errors=True)
