"""MemoryProgram: the staged IR between trace capture and plan execution.

The paper's contract is "observe one iteration, solve once, reuse forever"
(§III solve, §V lookup).  ``MemoryProgram`` is that contract made first-class:
one object that carries

  * the normalized iteration semantics (variables, lifetimes, access order,
    timing) as an ``IterationTrace``,
  * provenance — which (arch, step signature, hardware) instance this is the
    solution of, so solved plans can be cached and shared across processes,
  * every solved artifact attached so far: SmartPool placements per method,
    baseline pool footprints, AutoSwap schedules + simulated cost per
    (scorer, limit), and lowered offload plans.

Passes (plan/passes.py) consume and extend a program; plan/artifact.py
persists it.  A program restored from disk answers every already-solved
query without re-tracing or re-solving.
"""

from __future__ import annotations

import hashlib
import re
from dataclasses import dataclass, field

from ..core.autoswap import AutoSwapPlanner
from ..core.baseline_pools import PoolStats
from ..core.events import Event, IterationTrace
from ..core.offload import OffloadPlan
from ..core.simulator import SwapDecision
from ..core.smartpool import AllocationPlan


@dataclass(frozen=True)
class PlanKey:
    """Identity of a solved-plan artifact: (arch, step signature, hardware,
    topology).

    ``step_signature`` is a caller-chosen string naming the step instance
    (e.g. ``train:b8s128`` or ``prefill:b4p32``) — it must be computable
    *without* tracing, otherwise a cache hit could never skip the trace.
    Anything that changes the captured event stream (batch/seq shape, model
    config, tracer settings like max_scan_unroll) belongs in the signature.

    ``topology`` names the device topology the trace was captured for: the
    mesh shape plus the PartitionSpec signature of the step's inputs
    (``repro.dist.MeshSpec.plan_topology``).  Empty string means
    single-device — the legacy key shape, so existing artifacts keep their
    cache names — and a sharded capture always sets it non-empty, so a plan
    solved on a 1-device trace is never served to a sharded step (or a
    2-device plan to an 8-device mesh) from the same ``PlanCache``.
    """

    arch: str
    step_signature: str
    hardware: str
    topology: str = ""

    def cache_name(self) -> str:
        """Filesystem-safe artifact name, collision-guarded by a short hash."""
        raw = f"{self.arch}|{self.step_signature}|{self.hardware}"
        if self.topology:
            raw += f"|{self.topology}"
        slug = re.sub(r"[^A-Za-z0-9_.-]+", "_", raw).strip("_")
        digest = hashlib.sha256(raw.encode()).hexdigest()[:10]
        return f"{slug}-{digest}"


def swap_key(scorer: str, limit: int, weights=None) -> str:
    """Artifact-dict key for one solved swap schedule."""
    if weights is not None:
        wsig = hashlib.sha256(
            ",".join(f"{float(w):.12g}" for w in weights).encode()
        ).hexdigest()[:8]
        return f"{scorer}@{limit}#{wsig}"
    return f"{scorer}@{limit}"


@dataclass
class SwapSummary:
    """One solved swap schedule plus its simulated cost (paper Fig 9 row)."""

    scorer: str
    limit: int
    decisions: list[SwapDecision]
    peak_load: int
    load_min: int
    overhead: float
    stalls: int
    per_name_bytes: dict[str, int] = field(default_factory=dict)
    # Solve-context parameters the schedule depends on; a query under a
    # different threshold or hardware model invalidates the cached summary
    # (re-solve).  Cross-process reuse is already hw-safe via PlanKey.
    size_threshold: int = 0
    hardware: str = ""
    # The resident floor (load curve minus absence windows) the solver
    # committed to — the runtime's admission reservation (planned_peak).
    # Greedy selection is best-effort, so the floor may legitimately exceed
    # ``limit``; the static verifier (repro.analyze) proves the decisions
    # reproduce exactly this claim, which catches any dropped or tampered
    # decision.  None on hand-built or legacy summaries (pre-floor format).
    planned_floor: int | None = None

    @property
    def selected_bytes(self) -> int:
        return sum(d.size for d in self.decisions)


@dataclass
class MemoryProgram:
    """The IR.  ``trace`` is None only between TraceCapture (device-event
    source) and IterationDetect; every later pass requires it."""

    trace: IterationTrace | None = None
    key: PlanKey | None = None
    # Raw device events awaiting iteration detection (RecordingDevice path).
    raw_events: list[Event] | None = None
    # Solved artifacts, keyed by strategy name / swap_key().
    pool_plans: dict[str, AllocationPlan] = field(default_factory=dict)
    baselines: dict[str, PoolStats] = field(default_factory=dict)
    swap_summaries: dict[str, SwapSummary] = field(default_factory=dict)
    offload_plans: dict[str, OffloadPlan] = field(default_factory=dict)
    # Solve-time provenance: pass-stage name ("pool:best_fit",
    # "swap:swdoa@<limit>") -> wall milliseconds the stage took to solve.
    # Persisted with the artifact, so a cache-restored program reports the
    # *original* solving process's timings (from_cache distinguishes them).
    # Excluded from the canonical plan bytes (timing is not plan identity),
    # so two solves of the same instance still compare byte-equal.
    solve_ms: dict[str, float] = field(default_factory=dict)
    # Static-verification certificate (repro.analyze Certificate.to_dict()),
    # stamped by ArtifactSave and re-derived on every cache load.  Like
    # solve_ms it is provenance, not identity: excluded from the canonical
    # plan bytes so stamping a certificate never changes plan equality.
    certificate: dict | None = None
    from_cache: bool = False          # True when restored by plan/artifact.py
    dirty: bool = False               # True when a pass added new results
    _swap_planner: AutoSwapPlanner | None = field(default=None, repr=False)
    _swap_planner_sig: tuple | None = field(default=None, repr=False)

    @classmethod
    def from_trace(cls, trace: IterationTrace, key: PlanKey | None = None) -> "MemoryProgram":
        return cls(trace=trace, key=key)

    # ------------------------------------------------------------ accessors
    @property
    def variables(self):
        assert self.trace is not None, "program has no trace yet (run IterationDetect)"
        return self.trace.variables

    @property
    def num_indices(self) -> int:
        assert self.trace is not None
        return self.trace.num_indices

    def require_trace(self) -> IterationTrace:
        if self.trace is None:
            raise ValueError(
                "MemoryProgram has raw events but no trace; run IterationDetect first"
            )
        return self.trace

    def swap_planner(self, hw, size_threshold: int) -> AutoSwapPlanner:
        """Memoized AutoSwapPlanner over this program's trace (scoring is
        deterministic, so one instance serves every selection query)."""
        sig = (hw.name, size_threshold)
        if self._swap_planner is None or self._swap_planner_sig != sig:
            self._swap_planner = AutoSwapPlanner(
                self.require_trace(), hw, size_threshold=size_threshold
            )
            self._swap_planner_sig = sig
        return self._swap_planner
