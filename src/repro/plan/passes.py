"""Pass pipeline: trace -> plan -> execute as explicit, composable stages.

Canonical order (each pass is idempotent and skips work already present):

    TraceCapture      acquire the event stream (jaxpr interpreter or the
                      paper's RecordingDevice), or restore a cached program
    IterationDetect   fold raw device events into the canonical iteration
                      (no-op on the jaxpr path — the iteration is compiled-in)
    TimingAssign      give every op index a wall-clock time (hardware model)
    PoolPlacement     offline-DSA placements + baseline pool footprints
    SwapSelection     AutoSwap schedule + simulated cost at an HBM limit
    OffloadLowering   coarsen the selection to checkpoint_name classes
    ArtifactSave      persist newly-solved results to the plan cache

``Pipeline([...]).run(program, ctx)`` threads one ``MemoryProgram`` through
the stages.  Strategy names resolve through plan/registry.py, so a pipeline
is configured entirely by data — the property that lets launchers, the
planner facade, and serialized artifacts all describe the same computation.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Protocol, Sequence, runtime_checkable

from ..core.baseline_pools import PoolStats
from ..core.events import Event, build_trace
from ..core.iteration import IterationDetector
from ..core.offload import KNOWN_NAMES, OffloadPlan
from ..core.simulator import TPU_V5E, HardwareSpec, assign_times, simulate_swap_schedule
from ..core.smartpool import AllocationPlan
from .program import MemoryProgram, PlanKey, SwapSummary, swap_key
from .registry import get_pool, get_scorer


class PlanCacheMiss(LookupError):
    """Raised when a cache-only pipeline finds no artifact for its key."""


@dataclass
class PassContext:
    """Ambient state shared by every pass in one pipeline run."""

    hw: HardwareSpec = TPU_V5E
    cache: "object | None" = None          # plan.artifact.PlanCache
    key: PlanKey | None = None
    size_threshold: int = 1 << 20          # AutoSwap candidate floor (paper §IV-A)
    log: Callable[[str], None] | None = None

    def note(self, msg: str) -> None:
        if self.log:
            self.log(msg)


@runtime_checkable
class Pass(Protocol):
    name: str

    def run(self, program: MemoryProgram | None, ctx: PassContext) -> MemoryProgram: ...


class Pipeline:
    def __init__(self, passes: Sequence[Pass]):
        self.passes = list(passes)

    def run(
        self, program: MemoryProgram | None = None, ctx: PassContext | None = None
    ) -> MemoryProgram:
        ctx = ctx or PassContext()
        for p in self.passes:
            program = p.run(program, ctx)
            ctx.note(f"[plan] pass {p.name}: done")
        assert program is not None, "pipeline produced no program (no front-end pass?)"
        return program


# ----------------------------------------------------------------- front-ends
@dataclass
class TraceCapture:
    """Front-end: cached artifact > raw device events > jaxpr trace.

    Exactly one source is used per run.  When ``ctx.cache`` holds an artifact
    for ``ctx.key`` the program is restored as-is and *nothing* is re-traced —
    the paper's solve-once contract across processes.
    """

    step_fn: Callable | None = None
    example_args: tuple = ()
    arg_names: Sequence[str] | None = None
    # Must match MemoryPlanner's default: programs cached under the same
    # PlanKey have to come from identical tracer settings (anything that
    # changes the trace belongs in the key's step_signature).
    max_scan_unroll: int = 16
    events: Sequence[Event] | None = None
    name: str = "TraceCapture"

    def run(self, program: MemoryProgram | None, ctx: PassContext) -> MemoryProgram:
        if program is not None:
            return program
        if ctx.cache is not None and ctx.key is not None:
            cached = ctx.cache.load(ctx.key)
            if cached is not None:
                ctx.note(f"[plan] {ctx.key.cache_name()}: restored from cache")
                return cached
        if self.events is not None:
            return MemoryProgram(trace=None, raw_events=list(self.events), key=ctx.key)
        if self.step_fn is None:
            raise PlanCacheMiss(
                f"no step_fn given and no cached plan for key {ctx.key!r}"
            )
        from ..core.trace import trace_step_fn

        trace = trace_step_fn(
            self.step_fn,
            *self.example_args,
            arg_names=self.arg_names,
            max_scan_unroll=self.max_scan_unroll,
        )
        prog = MemoryProgram(trace=trace, key=ctx.key)
        prog.dirty = True
        return prog


@dataclass
class IterationDetect:
    """Fold raw device events into the canonical one-iteration trace (§V).

    No-op for jaxpr-captured programs: under XLA one jaxpr IS the iteration.
    """

    min_period: int = 4
    name: str = "IterationDetect"

    def run(self, program: MemoryProgram | None, ctx: PassContext) -> MemoryProgram:
        assert program is not None
        if program.trace is not None or program.raw_events is None:
            return program
        det = IterationDetector(min_period=self.min_period)
        for ev in program.raw_events:
            det.feed(ev)
        det.finalize()
        events = det.iteration_events()
        program.trace = build_trace(events)
        program.raw_events = None
        program.dirty = True
        return program


# ----------------------------------------------------------------- middle-ends
@dataclass
class TimingAssign:
    """Attach the hardware timing model (op_times) to the trace."""

    name: str = "TimingAssign"

    def run(self, program: MemoryProgram | None, ctx: PassContext) -> MemoryProgram:
        assert program is not None
        trace = program.require_trace()
        if trace.op_times is None:
            assign_times(trace, ctx.hw)
            program.dirty = True
        return program


@dataclass
class PoolPlacement:
    """Solve pool placements for each named method (registry-dispatched).

    ``AllocationPlan`` results land in ``program.pool_plans``; baseline
    ``PoolStats`` (cnmem/exact) land in ``program.baselines``.
    """

    methods: Sequence[str] = ("best_fit",)
    name: str = "PoolPlacement"

    def run(self, program: MemoryProgram | None, ctx: PassContext) -> MemoryProgram:
        assert program is not None
        trace = program.require_trace()
        for m in self.methods:
            if m in program.pool_plans or m in program.baselines:
                continue
            t0 = time.perf_counter()
            result = get_pool(m)(trace)
            ms = (time.perf_counter() - t0) * 1e3
            if isinstance(result, AllocationPlan):
                program.pool_plans[m] = result
            elif isinstance(result, PoolStats):
                program.baselines[m] = result
            else:
                raise TypeError(f"pool {m!r} returned {type(result).__name__}")
            program.solve_ms[f"pool:{m}"] = ms
            ctx.note(f"[plan] pool {m}: solved in {ms:.1f} ms")
            program.dirty = True
        return program


@dataclass
class SwapSelection:
    """Select a swap schedule at an HBM limit and simulate its cost (§IV)."""

    limit: int = 0
    scorer: str = "swdoa"
    weights: Sequence[float] | None = None
    name: str = "SwapSelection"

    def key(self) -> str:
        return swap_key(self.scorer, self.limit, self.weights)

    def run(self, program: MemoryProgram | None, ctx: PassContext) -> MemoryProgram:
        assert program is not None
        k = self.key()
        prior = program.swap_summaries.get(k)
        if prior is not None and (prior.size_threshold, prior.hardware) == (
            ctx.size_threshold,
            ctx.hw.name,
        ):
            return program
        t0 = time.perf_counter()
        planner = program.swap_planner(ctx.hw, ctx.size_threshold)
        if self.weights is not None:
            decisions = planner.select(self.limit, None, list(self.weights))
        else:
            decisions = get_scorer(self.scorer)(planner, self.limit)
        sim = simulate_swap_schedule(program.require_trace(), decisions, ctx.hw, self.limit)
        ms = (time.perf_counter() - t0) * 1e3
        program.solve_ms[f"swap:{k}"] = ms
        ctx.note(f"[plan] swap {k}: solved in {ms:.1f} ms")
        by_id = program.require_trace().by_id()
        per_name: dict[str, int] = {}
        for d in decisions:
            nm = by_id[d.var].name or "?"
            per_name[nm] = per_name.get(nm, 0) + d.size
        from ..analyze.plan_check import resident_floor

        program.swap_summaries[k] = SwapSummary(
            scorer=self.scorer,
            limit=self.limit,
            decisions=decisions,
            peak_load=planner.peak_load,
            load_min=planner.load_min(),
            overhead=sim.overhead,
            stalls=sim.stalls,
            per_name_bytes=per_name,
            size_threshold=ctx.size_threshold,
            hardware=ctx.hw.name,
            planned_floor=resident_floor(program.require_trace(), decisions)[0],
        )
        program.dirty = True
        return program


@dataclass
class OffloadLowering:
    """Coarsen a per-variable selection to checkpoint_name classes.

    A name class is offloaded when the planner selected a majority of its
    candidate bytes — the scan-uniformity coarsening documented in
    DESIGN.md §2.  Requires the matching SwapSelection result (it is solved
    here if missing).
    """

    limit: int = 0
    scorer: str = "swdoa"
    weights: Sequence[float] | None = None
    name: str = "OffloadLowering"

    def key(self) -> str:
        return swap_key(self.scorer, self.limit, self.weights)

    def run(self, program: MemoryProgram | None, ctx: PassContext) -> MemoryProgram:
        assert program is not None
        k = self.key()
        prior = program.swap_summaries.get(k)
        if k in program.offload_plans and (
            prior is not None
            and (prior.size_threshold, prior.hardware)
            == (ctx.size_threshold, ctx.hw.name)
        ):
            return program
        program = SwapSelection(self.limit, self.scorer, self.weights).run(program, ctx)
        decisions = program.swap_summaries[k].decisions
        planner = program.swap_planner(ctx.hw, ctx.size_threshold)
        by_id = program.require_trace().by_id()
        selected: dict[str, int] = {}
        total: dict[str, int] = {}
        chosen_vars = {d.var for d in decisions}
        for c in planner.candidates:
            nm = by_id[c.var].name or ""
            if nm not in KNOWN_NAMES:
                continue
            total[nm] = total.get(nm, 0) + c.size
            if c.var in chosen_vars:
                selected[nm] = selected.get(nm, 0) + c.size
        names = [n for n, b in selected.items() if b >= 0.5 * total.get(n, 1)]
        plan = OffloadPlan(offload_names=sorted(names))
        plan.predicted_savings = sum(selected.values())
        plan.transfer_bytes = 2 * plan.predicted_savings
        program.offload_plans[k] = plan
        program.dirty = True
        return program


# ------------------------------------------------------------------ back-end
@dataclass
class ArtifactSave:
    """Persist the program when it gained results and a cache is configured.

    Before writing, the solved plan is swept by the static verifier and the
    resulting certificate embedded in the artifact (outside the canonical
    plan-identity bytes).  The artifact is stored either way — a failing
    certificate is surfaced as a note here and demoted to a cache miss on
    every future ``PlanCache.load``."""

    name: str = "ArtifactSave"

    def run(self, program: MemoryProgram | None, ctx: PassContext) -> MemoryProgram:
        assert program is not None
        if ctx.cache is not None and program.key is not None and program.dirty:
            from ..analyze.plan_check import verify_program

            cert = verify_program(program)
            program.certificate = cert.to_dict()
            if not cert.ok:
                ctx.note(
                    f"[plan] certificate FAILED: {', '.join(cert.failed())}"
                )
            path = ctx.cache.store(program)
            program.dirty = False
            ctx.note(f"[plan] saved artifact {path}")
        return program
