"""Plan artifacts: JSON persistence of solved MemoryPrograms.

The one-time solve (SmartPool placement, AutoSwap schedules, offload
lowering) is serialized keyed by (arch, step signature, hardware) so a
second process — the next training run, or the decode server next to the
prefill server — reloads the solution instead of re-tracing and re-solving.

Serialization is *canonical* (sorted keys, fixed separators) so equality of
plans is equality of bytes; tests round-trip on that property.  Writes are
atomic (tmp file + rename) so concurrent processes sharing one cache
directory never observe a torn artifact.
"""

from __future__ import annotations

import json
import os
import tempfile
from pathlib import Path

from ..core.baseline_pools import PoolStats
from ..core.events import IterationTrace, VariableInfo
from ..core.offload import OffloadPlan
from ..core.simulator import SwapDecision
from ..core.smartpool import AllocationPlan
from .program import MemoryProgram, PlanKey, SwapSummary

PLAN_FORMAT_VERSION = 1


# ------------------------------------------------------------- to JSON dicts
def _trace_to_json(trace: IterationTrace) -> dict:
    return {
        "num_indices": trace.num_indices,
        "variables": [
            [
                v.var,
                v.size,
                v.alloc_index,
                v.free_index,
                list(v.accesses),
                [1 if w else 0 for w in v.access_is_write],
                v.name,
            ]
            for v in trace.variables
        ],
        "op_times": trace.op_times,
        "op_costs": (
            {str(i): [f, b] for i, (f, b) in sorted(trace.op_costs.items())}
            if trace.op_costs is not None
            else None
        ),
    }


def _trace_from_json(d: dict) -> IterationTrace:
    variables = [
        VariableInfo(
            var=var,
            size=size,
            alloc_index=alloc,
            free_index=free,
            accesses=list(acc),
            access_is_write=[bool(w) for w in wr],
            name=name,
        )
        for var, size, alloc, free, acc, wr, name in d["variables"]
    ]
    trace = IterationTrace(variables, d["num_indices"])
    trace.op_times = d["op_times"]
    if d["op_costs"] is not None:
        trace.op_costs = {int(i): (fb[0], fb[1]) for i, fb in d["op_costs"].items()}
    return trace


def _alloc_plan_to_json(p: AllocationPlan) -> dict:
    return {
        "offsets": {str(k): v for k, v in p.offsets.items()},
        "footprint": p.footprint,
        "peak_load": p.peak_load,
        "method": p.method,
        "lookup": {str(k): v for k, v in p.lookup.items()},
    }


def _alloc_plan_from_json(d: dict) -> AllocationPlan:
    return AllocationPlan(
        offsets={int(k): v for k, v in d["offsets"].items()},
        footprint=d["footprint"],
        peak_load=d["peak_load"],
        method=d["method"],
        lookup={int(k): v for k, v in d["lookup"].items()},
    )


def _summary_to_json(s: SwapSummary) -> dict:
    return {
        "scorer": s.scorer,
        "limit": s.limit,
        "decisions": [
            [d.var, d.size, d.out_after, d.in_before, 1 if d.wraps else 0]
            for d in s.decisions
        ],
        "peak_load": s.peak_load,
        "load_min": s.load_min,
        "overhead": s.overhead,
        "stalls": s.stalls,
        "per_name_bytes": dict(sorted(s.per_name_bytes.items())),
        "size_threshold": s.size_threshold,
        "hardware": s.hardware,
        "planned_floor": s.planned_floor,
    }


def _summary_from_json(d: dict) -> SwapSummary:
    return SwapSummary(
        scorer=d["scorer"],
        limit=d["limit"],
        decisions=[
            SwapDecision(var, size, out_after, in_before, bool(wraps))
            for var, size, out_after, in_before, wraps in d["decisions"]
        ],
        peak_load=d["peak_load"],
        load_min=d["load_min"],
        overhead=d["overhead"],
        stalls=d["stalls"],
        per_name_bytes=dict(d["per_name_bytes"]),
        size_threshold=d["size_threshold"],
        hardware=d["hardware"],
        planned_floor=d.get("planned_floor"),
    )


def _offload_to_json(p: OffloadPlan) -> dict:
    return {
        "offload_names": list(p.offload_names),
        "save_names": list(p.save_names),
        "predicted_savings": p.predicted_savings,
        "transfer_bytes": p.transfer_bytes,
    }


def _offload_from_json(d: dict) -> OffloadPlan:
    plan = OffloadPlan(
        offload_names=list(d["offload_names"]), save_names=list(d["save_names"])
    )
    plan.predicted_savings = d["predicted_savings"]
    plan.transfer_bytes = d["transfer_bytes"]
    return plan


def program_to_json(program: MemoryProgram) -> dict:
    trace = program.require_trace()
    payload = {
        "version": PLAN_FORMAT_VERSION,
        "key": (
            {
                "arch": program.key.arch,
                "step_signature": program.key.step_signature,
                "hardware": program.key.hardware,
                "topology": program.key.topology,
            }
            if program.key
            else None
        ),
        "trace": _trace_to_json(trace),
        "pool_plans": {m: _alloc_plan_to_json(p) for m, p in sorted(program.pool_plans.items())},
        "baselines": {
            m: {"footprint": s.footprint, "peak_load": s.peak_load, "num_mallocs": s.num_mallocs}
            for m, s in sorted(program.baselines.items())
        },
        "swap_summaries": {k: _summary_to_json(s) for k, s in sorted(program.swap_summaries.items())},
        "offload_plans": {k: _offload_to_json(p) for k, p in sorted(program.offload_plans.items())},
        # Solve-time provenance (ms per solved stage).  Stored for
        # observability; dumps_canonical() strips it, because wall-time is
        # process state, not plan identity.
        "solve_ms": {k: round(v, 3) for k, v in sorted(program.solve_ms.items())},
    }
    # Verification provenance (repro.analyze certificate).  Like solve_ms,
    # stripped from the canonical bytes: a certificate describes the plan,
    # it is not part of the plan.
    if program.certificate is not None:
        payload["certificate"] = program.certificate
    return payload


def program_from_json(d: dict) -> MemoryProgram:
    if d.get("version") != PLAN_FORMAT_VERSION:
        raise ValueError(f"unsupported plan artifact version {d.get('version')!r}")
    key = PlanKey(**d["key"]) if d.get("key") else None
    program = MemoryProgram(trace=_trace_from_json(d["trace"]), key=key)
    program.pool_plans = {m: _alloc_plan_from_json(p) for m, p in d["pool_plans"].items()}
    program.baselines = {
        m: PoolStats(s["footprint"], s["peak_load"], s["num_mallocs"])
        for m, s in d["baselines"].items()
    }
    program.swap_summaries = {k: _summary_from_json(s) for k, s in d["swap_summaries"].items()}
    program.offload_plans = {k: _offload_from_json(p) for k, p in d["offload_plans"].items()}
    program.solve_ms = {k: float(v) for k, v in d.get("solve_ms", {}).items()}
    program.certificate = d.get("certificate")
    return program


def dumps_canonical(program: MemoryProgram) -> str:
    """Canonical byte form: plans are equal iff their dumps are equal.

    Solve-time provenance is excluded — two byte-equal plans may have been
    solved at different speeds."""
    payload = program_to_json(program)
    payload.pop("solve_ms", None)
    payload.pop("certificate", None)
    return json.dumps(payload, sort_keys=True, separators=(",", ":"))


class PlanCache:
    """Directory of solved-plan artifacts, one JSON file per PlanKey.

    ``max_bytes`` bounds the cache for long-lived serving fleets with many
    tenant models: after each store, least-recently-used artifacts (by file
    mtime — a hit touches the file) are evicted until the directory fits.
    A schema-version mismatch is an expected upgrade-path event and degrades
    to a silent cache miss (the caller re-solves and overwrites); corrupt
    artifacts additionally warn.  Every load re-derives the static
    verification certificate (``repro.analyze``) over the restored plan —
    an artifact whose invariants no longer prove out (bit-rot, hand edits,
    a stale solver bug) is demoted to a miss and counted in
    ``certificate_misses`` rather than admitted to the runtime.
    """

    def __init__(self, root: "str | Path", max_bytes: int | None = None):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.max_bytes = max_bytes
        self.version_misses = 0
        self.certificate_misses = 0

    def path_for(self, key: PlanKey) -> Path:
        return self.root / f"{key.cache_name()}.json"

    def load(self, key: PlanKey) -> MemoryProgram | None:
        path = self.path_for(key)
        if not path.exists():
            return None
        try:
            with path.open() as f:
                payload = json.load(f)
            if not isinstance(payload, dict):
                raise ValueError("artifact is not a JSON object")  # corrupt: warn below
            if payload.get("version") != PLAN_FORMAT_VERSION:
                # Artifact written by an older/newer schema: a plain miss.
                self.version_misses += 1
                return None
            program = program_from_json(payload)
        except (OSError, json.JSONDecodeError, KeyError, TypeError, ValueError) as e:
            # A corrupt/stale artifact is a cache miss, not a crash: the
            # caller re-solves and overwrites it.
            import warnings

            warnings.warn(f"ignoring unreadable plan artifact {path}: {e}")
            return None
        program.key = key
        program.from_cache = True
        # Re-prove the invariants on the restored bytes; never trust the
        # stored verdict.  A failing plan is a miss — the caller re-solves.
        from ..analyze.plan_check import verify_program

        cert = verify_program(program)
        if not cert.ok:
            self.certificate_misses += 1
            import warnings

            warnings.warn(
                f"plan artifact {path} failed re-verification "
                f"({', '.join(cert.failed())}); treating as a cache miss"
            )
            return None
        program.certificate = cert.to_dict()
        # LRU touch: a hit keeps the artifact at the back of the evict queue.
        try:
            os.utime(path)
        except OSError:
            pass
        return program

    def store(self, program: MemoryProgram) -> Path:
        if program.key is None:
            raise ValueError("cannot store a MemoryProgram without a PlanKey")
        path = self.path_for(program.key)
        fd, tmp = tempfile.mkstemp(dir=self.root, suffix=".tmp")
        try:
            # mkstemp creates 0600; artifacts are shared between processes
            # (prefill/decode workers may run as different users).
            os.fchmod(fd, 0o644)
            with os.fdopen(fd, "w") as f:
                # Full payload (canonical plan + solve-time provenance).
                f.write(
                    json.dumps(
                        program_to_json(program), sort_keys=True, separators=(",", ":")
                    )
                )
            os.replace(tmp, path)
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise
        self._evict(keep=path)
        return path

    def _evict(self, keep: Path | None = None) -> list[Path]:
        """Drop least-recently-used artifacts until the directory fits
        ``max_bytes``.  The just-written artifact is never evicted, so one
        oversized plan degrades to a one-entry cache rather than none."""
        if self.max_bytes is None:
            return []
        entries = []
        for p in self.root.glob("*.json"):
            try:
                st = p.stat()
            except OSError:
                continue
            entries.append((st.st_mtime, st.st_size, p))
        total = sum(size for _, size, _ in entries)
        evicted: list[Path] = []
        for _, size, p in sorted(entries):
            if total <= self.max_bytes:
                break
            if keep is not None and p == keep:
                continue
            try:
                p.unlink()
            except OSError:
                continue
            total -= size
            evicted.append(p)
        return evicted

    def total_bytes(self) -> int:
        return sum(p.stat().st_size for p in self.root.glob("*.json"))

    def keys(self) -> list[str]:
        return sorted(p.stem for p in self.root.glob("*.json"))
