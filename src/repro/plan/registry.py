"""Strategy registry: pool methods and swap scorers addressable by name.

Pool methods map ``IterationTrace -> AllocationPlan`` (offline solvers) or
``IterationTrace -> PoolStats`` (online/exact baselines).  Swap scorers map
``(AutoSwapPlanner, limit, weights) -> list[SwapDecision]``.  Registering by
name is what lets launchers, benchmarks, and serialized artifacts refer to
strategies without importing their implementations — the seam where future
allocators/scorers plug in.
"""

from __future__ import annotations

from typing import Callable

from ..core.autoswap import AutoSwapPlanner
from ..core.baseline_pools import CnMemPool, PoolStats, exact_allocator
from ..core.events import IterationTrace
from ..core.simulator import SwapDecision
from ..core.smartpool import AllocationPlan, solve as smartpool_solve

PoolFn = Callable[[IterationTrace], "AllocationPlan | PoolStats"]
ScorerFn = Callable[..., "list[SwapDecision]"]

_POOLS: dict[str, PoolFn] = {}
_SCORERS: dict[str, ScorerFn] = {}


def register_pool(name: str):
    def deco(fn: PoolFn) -> PoolFn:
        _POOLS[name] = fn
        return fn

    return deco


def register_scorer(name: str):
    def deco(fn: ScorerFn) -> ScorerFn:
        _SCORERS[name] = fn
        return fn

    return deco


def get_pool(name: str) -> PoolFn:
    if name not in _POOLS:
        raise KeyError(f"unknown pool method {name!r}; known: {pool_names()}")
    return _POOLS[name]


def get_scorer(name: str) -> ScorerFn:
    if name not in _SCORERS:
        raise KeyError(f"unknown swap scorer {name!r}; known: {scorer_names()}")
    return _SCORERS[name]


def pool_names() -> tuple[str, ...]:
    return tuple(sorted(_POOLS))


def scorer_names() -> tuple[str, ...]:
    return tuple(sorted(_SCORERS))


# ----------------------------------------------------------- built-in pools
@register_pool("best_fit")
def _best_fit(trace: IterationTrace) -> AllocationPlan:
    return smartpool_solve(trace, "best_fit")


@register_pool("first_fit")
def _first_fit(trace: IterationTrace) -> AllocationPlan:
    return smartpool_solve(trace, "first_fit")


@register_pool("cnmem")
def _cnmem(trace: IterationTrace) -> PoolStats:
    return CnMemPool().run(trace)


@register_pool("exact")
def _exact(trace: IterationTrace) -> PoolStats:
    return exact_allocator(trace)


# --------------------------------------------------------- built-in scorers
def _priority_scorer(method: str) -> ScorerFn:
    def scorer(planner: AutoSwapPlanner, limit: int, weights=None) -> list[SwapDecision]:
        # Explicit weights override the named score (combined-score semantics,
        # same as AutoSwapPlanner.select / the "bo" scorer).
        return planner.select(limit, method, weights)

    return scorer


for _m in ("doa", "aoa", "wdoa", "swdoa"):
    register_scorer(_m)(_priority_scorer(_m))


@register_scorer("bo")
def _bo(planner: AutoSwapPlanner, limit: int, weights=None) -> list[SwapDecision]:
    """Bayesian-optimized combined score (paper §IV-C).  Explicit weights skip
    the tuner; otherwise GP-EI minimizes simulated overhead at this limit."""
    if weights is None:
        from ..core.bayesopt import tune_swap_weights

        weights = list(tune_swap_weights(planner, limit, n_iter=16).best_x)
    return planner.select(limit, None, list(weights))
