"""repro.plan: the trace -> plan -> execute pipeline as a staged artifact.

  program   — MemoryProgram IR + PlanKey identity + SwapSummary results
  passes    — Pass protocol, Pipeline runner, canonical stages
              (TraceCapture, IterationDetect, TimingAssign, PoolPlacement,
               SwapSelection, OffloadLowering, ArtifactSave)
  registry  — pool methods and swap scorers addressable by name
  artifact  — canonical JSON persistence + on-disk PlanCache

core/planner.py's MemoryPlanner is a facade over this package; launchers and
benchmarks compose pipelines directly.
"""

from .artifact import PLAN_FORMAT_VERSION, PlanCache, dumps_canonical, program_from_json, program_to_json
from .passes import (
    ArtifactSave,
    IterationDetect,
    OffloadLowering,
    Pass,
    PassContext,
    Pipeline,
    PlanCacheMiss,
    PoolPlacement,
    SwapSelection,
    TimingAssign,
    TraceCapture,
)
from .program import MemoryProgram, PlanKey, SwapSummary, swap_key
from .registry import get_pool, get_scorer, pool_names, register_pool, register_scorer, scorer_names

__all__ = [
    "PLAN_FORMAT_VERSION",
    "PlanCache",
    "dumps_canonical",
    "program_from_json",
    "program_to_json",
    "ArtifactSave",
    "IterationDetect",
    "OffloadLowering",
    "Pass",
    "PassContext",
    "Pipeline",
    "PlanCacheMiss",
    "PoolPlacement",
    "SwapSelection",
    "TimingAssign",
    "TraceCapture",
    "MemoryProgram",
    "PlanKey",
    "SwapSummary",
    "swap_key",
    "get_pool",
    "get_scorer",
    "pool_names",
    "register_pool",
    "register_scorer",
    "scorer_names",
]
