"""AdamW + global-norm clipping, pure JAX (no optax dependency offline).

Optimizer state lives as two pytrees (m, v) mirroring the params.  Dtype of
the moments is configurable: fp32 (default) or bf16 (a distributed-memory
hillclimb lever — see EXPERIMENTS.md §Perf).  Sharding of the state follows
the params; the ZeRO-1 variant re-shards m/v over the data axis (see
launch/train.py).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp


@dataclass
class AdamWState:
    m: Any
    v: Any
    count: jnp.ndarray


def adamw_init(params, dtype=jnp.float32) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, dtype)
    return AdamWState(
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
        count=jnp.zeros((), jnp.int32),
    )


def clip_by_global_norm(grads, max_norm: float):
    sq = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), grads),
        jnp.zeros((), jnp.float32),
    )
    norm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def adamw_step(
    params,
    grads,
    state: AdamWState,
    lr,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    max_grad_norm: float | None = 1.0,
):
    """Returns (new_params, new_state, metrics)."""
    if max_grad_norm is not None:
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
    else:
        _, gnorm = clip_by_global_norm(grads, 1e30)
    count = state.count + 1
    c = count.astype(jnp.float32)
    bc1 = 1.0 - b1**c
    bc2 = 1.0 - b2**c

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m2 = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        v2 = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        step = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + eps)
        p2 = p.astype(jnp.float32) * (1.0 - lr * weight_decay) - lr * step
        return p2.astype(p.dtype), m2.astype(m.dtype), v2.astype(v.dtype)

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    is3 = lambda t: isinstance(t, tuple) and len(t) == 3
    new_p = jax.tree.map(lambda t: t[0], out, is_leaf=is3)
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=is3)
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=is3)
    return new_p, AdamWState(new_m, new_v, count), {"grad_norm": gnorm}


jax.tree_util.register_pytree_node(
    AdamWState,
    lambda s: ((s.m, s.v, s.count), None),
    lambda _, c: AdamWState(*c),
)
