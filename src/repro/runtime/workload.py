"""Seeded workload generation for dynamic-churn runtime experiments.

The runtime's churn model (tenants with ``arrival_t``/``priority``/optional
``departure_t``) needs arrival processes to drive it.  This module keeps the
generators deterministic and dependency-free:

* ``poisson_workload`` — the classic open-arrival model: exponential
  inter-arrival gaps at a given rate, templates/iteration counts/priorities
  drawn from a seeded ``random.Random``.  Same seed, same workload —
  bit-for-bit, which is what lets ``benchmarks/bench_churn.py`` compare
  renegotiation against FIFO queueing *under the same arrivals*.
* ``parse_arrivals`` — CLI surface (``repro.launch.colocate --arrivals``):
  either an explicit comma list of arrival times matched positionally to the
  tenant list, or ``poisson:rate=R[,seed=S][,start=T]``.
* ``synthetic_train_trace`` — a forward/backward-shaped ``IterationTrace``
  (weights live across the step, activations die in the backward pass) used
  as a tenant template when benchmarking the runtime without tracing a real
  model.
"""

from __future__ import annotations

import random
from dataclasses import dataclass

from ..core.events import IterationTrace, VariableInfo


@dataclass(frozen=True)
class WorkloadItem:
    """One tenant of a generated workload, before plans are solved."""

    name: str
    template: str          # which trace/program template instantiates it
    arrival_t: float
    iterations: int = 1
    priority: float = 1.0

    def as_dict(self) -> dict:
        return {
            "name": self.name,
            "template": self.template,
            "arrival_t": self.arrival_t,
            "iterations": self.iterations,
            "priority": self.priority,
        }


def poisson_workload(
    templates: "list[str] | tuple[str, ...]",
    n: int,
    rate_hz: float,
    seed: int = 0,
    iterations: tuple[int, int] = (1, 1),
    priorities: "tuple[float, ...]" = (1.0,),
    start_t: float = 0.0,
) -> list[WorkloadItem]:
    """``n`` arrivals with Exp(rate) gaps starting from ``start_t``.

    Template, iteration count (uniform over the inclusive ``iterations``
    range) and priority are drawn from the same seeded stream, so one seed
    pins the entire workload.
    """
    if rate_hz <= 0:
        raise ValueError(f"rate_hz must be positive, got {rate_hz}")
    if not templates:
        raise ValueError("poisson_workload needs at least one template")
    rng = random.Random(seed)
    tpls = list(templates)
    t = float(start_t)
    items: list[WorkloadItem] = []
    for i in range(n):
        t += rng.expovariate(rate_hz)
        tpl = tpls[rng.randrange(len(tpls))]
        iters = rng.randint(iterations[0], iterations[1])
        prio = priorities[rng.randrange(len(priorities))]
        items.append(WorkloadItem(f"{tpl}#{i}", tpl, t, iters, prio))
    return items


def parse_arrivals(spec: str, n: int) -> list[float]:
    """Parse a CLI ``--arrivals`` spec into ``n`` arrival times.

    Two forms:
      * ``"0,0.002,0.005"`` — explicit times, matched positionally to the
        tenant list (must supply exactly ``n``);
      * ``"poisson:rate=500[,seed=0][,start=0]"`` — seeded Poisson process.
    """
    spec = spec.strip()
    if spec.startswith("poisson"):
        params = {"rate": 1000.0, "seed": 0.0, "start": 0.0}
        body = spec.partition(":")[2]
        for kv in body.split(","):
            kv = kv.strip()
            if not kv:
                continue
            k, sep, v = kv.partition("=")
            if not sep or k not in params:
                raise ValueError(
                    f"bad poisson arrival parameter {kv!r} (rate=|seed=|start=)"
                )
            params[k] = float(v)
        rng = random.Random(int(params["seed"]))
        t = params["start"]
        out = []
        for _ in range(n):
            t += rng.expovariate(params["rate"])
            out.append(t)
        return out
    times = [float(x) for x in spec.split(",") if x.strip()]
    if len(times) != n:
        raise ValueError(f"--arrivals lists {len(times)} times for {n} tenants")
    return times


def synthetic_train_trace(
    n_layers: int = 8,
    act_bytes: int = 8 << 20,
    weight_bytes: int = 4 << 20,
    flops_per_op: float = 1e9,
    bytes_per_op: float = 1e6,
) -> IterationTrace:
    """Forward/backward-shaped training trace (deterministic, no tracing).

    Per layer: a weight (lives the whole iteration, read in forward and
    backward) and an activation (written in forward, read by the mirrored
    backward op, freed right after) — the structure AutoSwap exploits, with
    op costs so the timing model produces non-trivial overlap.
    """
    vs: list[VariableInfo] = []
    var = 0
    n_ops = 4 * n_layers + 2
    fwd_w, fwd_a = [], []
    for l in range(n_layers):
        w = VariableInfo(var, weight_bytes, 0, n_ops, [2 * l], [False]); var += 1
        a = VariableInfo(var, act_bytes, 2 * l, 0, [2 * l + 1], [True]); var += 1
        vs.append(w); fwd_w.append(w)
        vs.append(a); fwd_a.append(a)
    for l in reversed(range(n_layers)):
        bwd_idx = 2 * n_layers + 2 * (n_layers - 1 - l) + 1
        fwd_w[l].accesses.append(bwd_idx)
        fwd_w[l].access_is_write.append(False)
        fwd_a[l].accesses.append(bwd_idx)
        fwd_a[l].access_is_write.append(False)
        fwd_a[l].free_index = bwd_idx + 1
    tr = IterationTrace(vs, n_ops)
    tr.op_costs = {i: (flops_per_op, bytes_per_op) for i in range(n_ops)}
    return tr
