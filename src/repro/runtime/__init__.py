"""repro.runtime: the execution layer on top of the repro.plan IR.

  engine   — ChannelPool (K DMA channels), PoolAccountant (shared budget),
             HostLink (shared host-interconnect bandwidth pool with
             collective blackouts), Tenant, MemoryRuntime (N-tenant
             event-driven co-scheduler with arrival churn, preemptive floor
             renegotiation and per-device pools for mesh execution),
             simulate_program (the paper's simulator as a 1-tenant run)
  tenants  — tenant_from_program / colocate_programs: plan-pipeline +
             PlanCache warm-start into the runtime; pipeline_replanner is
             the online re-solve hook renegotiation uses
  workload — seeded Poisson / trace-driven workload generation for churn
             experiments

``core.simulator.simulate_swap_schedule`` is now a thin 1-tenant/2-channel
call into this engine; ``python -m repro.launch.colocate`` drives it from
the command line and ``benchmarks/bench_runtime.py`` measures it.
"""

from .engine import (
    ChannelPool,
    FloorGreedyVictim,
    HostLink,
    MemoryRuntime,
    PoolAccountant,
    RuntimeReport,
    Tenant,
    TenantReport,
    VictimPolicy,
    planned_peak,
    simulate_program,
    simulated_report_dict,
)
from .tenants import (
    ColocationResult,
    colocate_programs,
    pipeline_replanner,
    proportional_shares,
    tenant_from_program,
)
from .workload import WorkloadItem, parse_arrivals, poisson_workload, synthetic_train_trace

__all__ = [
    "ChannelPool",
    "FloorGreedyVictim",
    "HostLink",
    "VictimPolicy",
    "MemoryRuntime",
    "PoolAccountant",
    "RuntimeReport",
    "Tenant",
    "TenantReport",
    "planned_peak",
    "simulate_program",
    "simulated_report_dict",
    "ColocationResult",
    "colocate_programs",
    "pipeline_replanner",
    "proportional_shares",
    "tenant_from_program",
    "WorkloadItem",
    "parse_arrivals",
    "poisson_workload",
    "synthetic_train_trace",
]
