"""Build runtime tenants from solved MemoryPrograms and the plan cache.

A tenant is one (trace, swap schedule) pair drawn from the ``repro.plan``
pipeline.  ``tenant_from_program`` solves (or reuses) a SwapSelection at the
tenant's HBM share; ``colocate_programs`` splits one shared budget across N
programs proportionally to their isolated peaks, solves each tenant's plan
at its share, and runs them together through the ``MemoryRuntime`` — the
serving-fleet shape from TENSILE: several dynamic workloads, one device.

Plans load through ``PlanCache`` warm-start exactly like the launchers: a
program restored from disk contributes its cached schedule without
re-tracing or re-solving.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

from ..core.simulator import HardwareSpec, SimResult
from ..plan.passes import ArtifactSave, PassContext, Pipeline, SwapSelection, TimingAssign
from ..plan.program import MemoryProgram, swap_key
from .engine import MemoryRuntime, RuntimeReport, Tenant, simulate_program


def tenant_from_program(
    name: str,
    program: MemoryProgram,
    hw: HardwareSpec,
    limit: int,
    scorer: str = "swdoa",
    size_threshold: int = 1 << 20,
    cache=None,
    iterations: int = 1,
    arrival_t: float = 0.0,
    priority: float = 1.0,
    departure_t: float | None = None,
) -> Tenant:
    """Solve (or restore) the program's swap schedule at `limit` and wrap it
    as a runtime tenant.  Newly-solved results persist when `cache` is set."""
    ctx = PassContext(hw=hw, cache=cache, key=program.key, size_threshold=size_threshold)
    passes = [TimingAssign(), SwapSelection(limit=limit, scorer=scorer)]
    if cache is not None and program.key is not None:
        passes.append(ArtifactSave())
    program = Pipeline(passes).run(program, ctx)
    summary = program.swap_summaries[swap_key(scorer, limit)]
    return Tenant(
        name=name,
        trace=program.require_trace(),
        decisions=list(summary.decisions),
        limit=limit,
        iterations=iterations,
        arrival_t=arrival_t,
        priority=priority,
        departure_t=departure_t,
    )


def pipeline_replanner(
    hw: HardwareSpec,
    scorer: str = "swdoa",
    size_threshold: int = 1 << 20,
    cache=None,
    programs: "dict[str, MemoryProgram] | None" = None,
):
    """Online re-solve hook for ``MemoryRuntime(renegotiate=True)``.

    Returns ``replan(tenant, new_limit) -> (decisions, solve_wall_ms)``
    running the plan pipeline's SwapSelection pass — the near-linear solve
    path, so renegotiating at admission time is cheap.  When ``programs``
    maps tenant names to their ``MemoryProgram``s (as in
    ``colocate_programs``), re-solves reuse each program's memoized planner
    (rankings are shared across limits) and persist to ``cache``; otherwise
    a program is wrapped around the tenant's trace on first use.
    """
    progs: dict[str, MemoryProgram] = dict(programs or {})

    def replan(tenant: Tenant, new_limit: int) -> tuple[list, float]:
        program = progs.get(tenant.name)
        if program is None:
            program = MemoryProgram.from_trace(tenant.trace)
            progs[tenant.name] = program
        ctx = PassContext(
            hw=hw, cache=cache, key=program.key, size_threshold=size_threshold
        )
        passes = [TimingAssign(), SwapSelection(limit=new_limit, scorer=scorer)]
        if cache is not None and program.key is not None:
            passes.append(ArtifactSave())
        # Time this call, not program.solve_ms[...]: when SwapSelection hits
        # its memoized summary (same limit re-staged after a cancelled
        # renegotiation) the stored figure is the *original* solve's wall
        # time, which this replan did not spend.
        t0 = time.perf_counter()
        program = Pipeline(passes).run(program, ctx)
        ms = (time.perf_counter() - t0) * 1e3
        k = swap_key(scorer, new_limit)
        return list(program.swap_summaries[k].decisions), ms

    return replan


@dataclass
class ColocationResult:
    """A co-located run next to each tenant's isolated baselines.

    Two isolation baselines bracket the comparison: ``natural_peaks`` is what
    static per-tenant provisioning must reserve (the unswapped peak load of
    each program), ``isolated`` is each tenant run alone under its own share
    with its swap schedule.  Co-location wins when ``aggregate_peak`` lands
    below the sum of the natural peaks at acceptable per-tenant overhead.
    """

    report: RuntimeReport
    budget: int
    isolated: dict[str, SimResult] = field(default_factory=dict)
    natural_peaks: dict[str, int] = field(default_factory=dict)
    # Wall ms spent solving each tenant's plan at admission (cache hits are
    # ~0): plans are solved online when a tenant is admitted, so solve
    # latency is part of the serving path and reported next to overhead.
    plan_solve_ms: dict[str, float] = field(default_factory=dict)
    # Budget share each tenant's plan was solved at (largest-remainder
    # proportional split: shares sum to the budget before peak clamping).
    shares: dict[str, int] = field(default_factory=dict)
    # Which split policy produced ``shares`` ("proportional" | "tuned") and,
    # for tuned splits, the ``repro.tune`` descent record (as_dict form).
    budget_split: str = "proportional"
    split_tuning: dict | None = None

    @property
    def sum_isolated_peaks(self) -> int:
        return sum(r.peak_resident for r in self.isolated.values())

    @property
    def sum_natural_peaks(self) -> int:
        return sum(self.natural_peaks.values())

    @property
    def sharing_gain(self) -> float:
        """Fraction of HBM saved by pooling vs statically provisioning each
        tenant its natural peak: 1 - aggregate_peak / sum(natural peaks)."""
        s = self.sum_natural_peaks
        return 1.0 - self.report.aggregate_peak / s if s else 0.0

    def as_dict(self) -> dict:
        return {
            "budget": self.budget,
            "sum_natural_peaks": self.sum_natural_peaks,
            "sum_isolated_peaks": self.sum_isolated_peaks,
            "aggregate_peak": self.report.aggregate_peak,
            "sharing_gain": self.sharing_gain,
            "natural_peaks": dict(self.natural_peaks),
            "shares": dict(self.shares),
            "budget_split": self.budget_split,
            **({"split_tuning": dict(self.split_tuning)}
               if self.split_tuning is not None else {}),
            "plan_solve_ms": {n: round(v, 3) for n, v in self.plan_solve_ms.items()},
            "runtime": self.report.as_dict(),
            "isolated": {
                n: {
                    "peak_resident": r.peak_resident,
                    "overhead": r.overhead,
                    "stalls": r.stalls,
                }
                for n, r in self.isolated.items()
            },
        }


def proportional_shares(peaks: dict[str, int], budget: int) -> dict[str, int]:
    """Split ``budget`` proportionally to ``peaks`` with largest-remainder
    rounding, so the granted shares sum exactly to the budget (plain integer
    truncation silently withholds up to N-1 bytes)."""
    names = list(peaks)
    total = sum(peaks.values())
    if not names or total <= 0:
        return {n: budget for n in names}
    shares = {n: budget * peaks[n] // total for n in names}
    leftover = budget - sum(shares.values())
    by_remainder = sorted(names, key=lambda n: (-((budget * peaks[n]) % total), n))
    for n in by_remainder[:leftover]:
        shares[n] += 1
    return shares


def colocate_programs(
    named_programs: dict[str, MemoryProgram],
    hw: HardwareSpec,
    budget_frac: float = 0.8,
    budget: int | None = None,
    channels: int = 2,
    scorer: str = "swdoa",
    size_threshold: int = 1 << 20,
    cache=None,
    iterations: int = 1,
    arrivals: "dict[str, float] | None" = None,
    priorities: "dict[str, float] | None" = None,
    departures: "dict[str, float] | None" = None,
    renegotiate: bool = False,
    record_events: bool = True,
    obs=None,
    budget_split: str = "proportional",
    split_evals: int = 24,
    victim_policy=None,
) -> ColocationResult:
    """Co-schedule N solved programs under one shared HBM budget.

    The budget defaults to ``budget_frac`` of the sum of isolated peak loads;
    each tenant's swap schedule is solved at its proportional share (clamped
    to its trace peak so an under-committed tenant gets a no-op schedule).
    ``budget_split="tuned"`` instead coordinate-descends the split with
    ``repro.tune.tuned_shares`` (up to ``split_evals`` trial colocations),
    keeping only moves that strictly reduce SLO-weighted total stall; the
    descent record lands in ``ColocationResult.split_tuning``.

    ``victim_policy`` overrides the engine's renegotiation victim policy
    (default floor-greedy; ``repro.tune.LedgerVictimPolicy`` scores
    candidates by simulated marginal ledger).

    Churn: ``arrivals``/``priorities``/``departures`` map tenant names to
    their arrival time, SLO weight, and optional open-ended departure event;
    ``renegotiate=True`` lets the runtime shrink a running victim's plan (an
    online SwapSelection re-solve through this same pipeline and ``cache``)
    instead of only queueing a newcomer that doesn't fit.

    ``record_events=False`` disables the runtime's per-transfer event logs
    for fleet-scale horizons (the report's simulated figures are unchanged).
    ``obs`` attaches a ``repro.obs.ObsRecorder`` to the shared runtime (the
    isolated baselines are never observed): pure observer, identical report.
    """
    arrivals = arrivals or {}
    priorities = priorities or {}
    departures = departures or {}
    peaks = {n: p.require_trace().peak_load() for n, p in named_programs.items()}
    total = sum(peaks.values())
    if budget is None:
        budget = int(total * budget_frac)
    if budget_split not in ("proportional", "tuned"):
        raise ValueError(f"unknown budget_split {budget_split!r}")
    shares = proportional_shares(peaks, budget)
    replanner = pipeline_replanner(
        hw, scorer=scorer, size_threshold=size_threshold, cache=cache,
        programs=named_programs,
    )

    def build_tenants(shs, solve_ms: "dict[str, float] | None" = None):
        tenants = []
        for n, p in named_programs.items():
            share = min(shs[n], peaks[n])
            t0 = time.perf_counter()
            tenants.append(
                tenant_from_program(
                    n, p, hw, share, scorer=scorer,
                    size_threshold=size_threshold, cache=cache,
                    iterations=iterations,
                    arrival_t=arrivals.get(n, 0.0),
                    priority=priorities.get(n, 1.0),
                    departure_t=departures.get(n),
                )
            )
            if solve_ms is not None:
                solve_ms[n] = (time.perf_counter() - t0) * 1e3
        return tenants

    split_tuning = None
    if budget_split == "tuned":
        from ..tune import slo_weighted_stall, tuned_shares

        def evaluate(shs):
            # Trial colocations: no event logs, no observer — only the
            # simulated report matters, and it is unchanged by either.
            rt = MemoryRuntime(
                hw, budget=budget, channels=channels, renegotiate=renegotiate,
                replanner=replanner, record_events=False,
                victim_policy=victim_policy,
            )
            return slo_weighted_stall(rt.run(build_tenants(shs)))

        tuning = tuned_shares(peaks, budget, evaluate,
                              start=shares, max_evals=split_evals)
        shares, split_tuning = tuning.shares, tuning.as_dict()

    plan_solve_ms: dict[str, float] = {}
    tenants = build_tenants(shares, plan_solve_ms)
    isolated = {
        t.name: simulate_program(t.trace, t.decisions, hw, t.limit, channels=channels)
        for t in tenants
    }
    rt = MemoryRuntime(
        hw, budget=budget, channels=channels, renegotiate=renegotiate,
        replanner=replanner, record_events=record_events, obs=obs,
        victim_policy=victim_policy,
    )
    report = rt.run(tenants)
    return ColocationResult(
        report=report, budget=budget, isolated=isolated, natural_peaks=peaks,
        plan_solve_ms=plan_solve_ms, shares=shares,
        budget_split=budget_split, split_tuning=split_tuning,
    )
