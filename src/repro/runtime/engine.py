"""Discrete-event memory runtime: N tenant programs, K DMA channels, one HBM.

This is the execution layer on top of the ``repro.plan`` IR.  The paper's
simulator (formerly the event loop inside ``core/simulator.py``) replayed ONE
iteration of ONE program with one serialized swap-out stream and one
serialized swap-in stream.  This module generalizes that loop along two axes:

* **Channels** — ``ChannelPool`` models K serialized DMA channels,
  direction-partitioned (K=1: a single shared bidirectional channel; K>=2:
  ceil(K/2) out + floor(K/2) in).  K=2 reproduces the paper's
  one-out/one-in streams exactly, which is how
  ``core.simulator.simulate_swap_schedule`` now delegates here.

* **Tenants** — ``MemoryRuntime`` admits N tenant programs (e.g. a prefill
  worker, a decode worker and a training job) against one shared HBM budget.
  Compute is per-tenant (each tenant owns its cores); HBM residency and DMA
  channels are shared.  Tenants are interleaved in global-time order: at each
  step the tenant with the smallest local clock executes its next op using
  the original simulator's per-op semantics (swap-in stall, delayed malloc,
  swap-out launch, deadline-ordered prefetch).

Shared-pool accounting (``PoolAccountant``) charges swap-in bytes at
*schedule* time, so the admission guard sees in-flight transfers on every
channel — with K in-channels two prefetches can no longer both be admitted
into headroom that only fits one (the double-admission hazard a single
serialized in-stream never exposed).

Admission control: a tenant whose resident floor (planned peak under its
swap schedule) does not fit in the unreserved budget is queued FIFO, not
OOM-killed; it starts when a finishing tenant releases its reservation.

* **Devices** — tenants carry an optional ``device``: tenants on distinct
  devices get distinct HBM accountants and DMA channel pools (the mesh
  execution shape ``repro.dist`` builds), while every device's channels
  contend on one shared ``HostLink`` bandwidth pool when configured —
  modeling the paper's swap bandwidth as a genuinely shared host resource,
  with tagged collectives blacking the link out and the contention-aware
  prefetch back-scheduling around them.  ``device=None`` (default) keeps
  the legacy single-pool behavior bit-for-bit.

Dynamic churn: tenants carry an ``arrival_t`` (and optionally an open-ended
iteration count bounded by a ``departure_t`` event), and the run loop is
event-driven — arrivals are interleaved with execution in global-time order
instead of being admitted from a fixed list at t=0.  With
``renegotiate=True`` the runtime does not only queue a newcomer whose floor
does not fit: it picks a running victim (lowest priority first, then the
largest floor), re-solves the victim's swap plan at a lower HBM limit (the
near-linear SwapSelection solve path, so this is cheap enough to do online),
applies the shrunken plan at the victim's next iteration barrier, and admits
the newcomer into the freed reservation.  When no victim can free enough
bytes the newcomer falls back to plain FIFO queueing.

**Vectorized event core** (PR 6): the hot paths run on precomputed,
array-structured state, pinned bit-for-bit against the frozen per-event
engine in ``runtime/_engine_reference.py`` the way PR 3 pinned the solvers:

  * the per-step ``sorted(upcoming)`` prefetch scan is a per-op *prefetch
    index* built once in ``_install_decisions`` (decisions stably pre-sorted
    by deadline, walked with in-place compaction as variables swap back in);
  * the O(P) ``pending.remove`` / ``min(pending, ...)`` walks over in-flight
    swap-outs are a lazy-deletion *done-time heap* (``_PendingQueue``);
  * the ``_planned_blackout_s`` linear collective-window walk is
    bisect-bounded by prefix indexes over ``_coll_windows``;
  * the ``run()`` min-over-running-tenants scan is a heapq *event frontier*
    keyed (clock, admission order), so picking the next event is O(log N)
    instead of O(N) — the term that dominated thousand-tenant horizons.

Renegotiation replay is *suffix-only*: with ``capture_snapshots=True`` the
engine snapshots its whole state (accountants, channels, pending heaps,
tenant runs) at every barrier where a re-plan applies, and ``resume()`` on a
snapshot re-simulates only the horizon after that barrier — byte-identical
to replaying the full horizon from t=0 (``benchmarks/bench_engine.py``
gates this).
"""

from __future__ import annotations

import bisect
import copy
import heapq
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Sequence

from ..core.events import IterationTrace
from ..core.simulator import HardwareSpec, SimResult, SwapDecision, assign_times

# A replanner re-solves one tenant's swap schedule at a new (lower) HBM
# limit: (tenant, new_limit) -> (decisions, solve_wall_ms).
Replanner = Callable[["Tenant", int], "tuple[list[SwapDecision], float]"]


# ----------------------------------------------------------------- channels
@dataclass
class ChannelPool:
    """K serialized DMA channels, direction-partitioned.

    K=1 degrades to a single bidirectional channel (out and in transfers
    contend); K>=2 splits ceil(K/2) channels for swap-out and the rest for
    swap-in, each direction load-balanced onto its earliest-free channel.
    """

    num_channels: int
    free_at: list[float]
    out_ids: tuple[int, ...]
    in_ids: tuple[int, ...]

    @classmethod
    def make(cls, k: int) -> "ChannelPool":
        k = max(1, int(k))
        if k == 1:
            out_ids = in_ids = (0,)
        else:
            split = (k + 1) // 2
            out_ids = tuple(range(split))
            in_ids = tuple(range(split, k))
        return cls(k, [0.0] * k, out_ids, in_ids)

    def acquire(self, direction: str, ready_t: float, duration: float) -> tuple[float, float, int]:
        """Reserve the earliest-free channel of `direction`; returns (start, end, channel)."""
        ids = self.out_ids if direction == "out" else self.in_ids
        if len(ids) == 1:
            ch = ids[0]
        else:
            ch = min(ids, key=lambda c: self.free_at[c])
        start = max(ready_t, self.free_at[ch])
        end = start + duration
        self.free_at[ch] = end
        return start, end, ch

    def drain_time(self, direction: str) -> float:
        ids = self.out_ids if direction == "out" else self.in_ids
        return max(self.free_at[c] for c in ids)


@dataclass
class HostLink:
    """Shared host-interconnect bandwidth pool every device's DMA contends on.

    One host typically fronts several accelerators through one PCIe root
    complex (or one NVLink/ICI bridge to host memory): per-device DMA
    channels do not each get the full link.  ``total_bw`` bytes/s of
    aggregate host-link bandwidth is carved into ``lanes`` serialized lanes
    of ``total_bw / lanes`` each; a swap transfer must hold its device's
    directional DMA channel AND a free lane, and moves at
    ``min(device link_bw, lane_bw)``.  With enough lanes for every channel
    the pool is contention-free; fewer lanes model the paper's swap
    bandwidth as a genuinely shared resource (SuperNeurons' observation that
    co-resident jobs fight for the same PCIe).

    Collectives occupy the interconnect with priority (XLA schedules them;
    swaps are opportunistic): ``add_blackout`` reserves an interval on every
    lane, and a transfer scheduled into a blackout is shifted past its end.
    """

    total_bw: float
    lanes: int
    free_at: list[float] = field(default_factory=list)
    blackouts: list[tuple[float, float]] = field(default_factory=list)
    # Observability counters, surfaced in RuntimeReport.link.
    bytes_moved: int = 0
    transfers: int = 0
    blackout_s: float = 0.0
    # Directional lane carving (``repro.tune.lanes``): ``None`` keeps the
    # legacy work-conserving shared pool — any transfer grabs any free lane —
    # bit-identical to the frozen reference.  When set, swap-outs may only
    # use ``out_lane_ids`` and swap-ins ``in_lane_ids``, so bulk swap-out
    # traffic can never queue a latency-critical swap-in behind it.
    out_lane_ids: tuple[int, ...] | None = None
    in_lane_ids: tuple[int, ...] | None = None
    # Per-direction contention decomposition: how long transfers of each
    # direction queued before starting (channel/lane wait plus blackout
    # shift) and the bytes they moved.  Pure accumulators — they never feed
    # back into scheduling — read by ``repro.tune.lanes`` probe runs to pick
    # a directional split; only emitted in reports when a split is active.
    wait_in_s: float = 0.0
    wait_out_s: float = 0.0
    bytes_in: int = 0
    bytes_out: int = 0

    @classmethod
    def make(cls, total_bw: float, lanes: int,
             out_lanes: int | None = None) -> "HostLink":
        lanes = max(1, int(lanes))
        link = cls(float(total_bw), lanes, [0.0] * lanes)
        if out_lanes is not None and lanes > 1:
            out_lanes = max(1, min(int(out_lanes), lanes - 1))
            link.out_lane_ids = tuple(range(out_lanes))
            link.in_lane_ids = tuple(range(out_lanes, lanes))
        return link

    @property
    def lane_bw(self) -> float:
        return self.total_bw / self.lanes

    def lane_ids(self, direction: str):
        """Lanes a transfer of ``direction`` may use (all, when unsplit)."""
        if self.out_lane_ids is None:
            return range(self.lanes)
        return self.out_lane_ids if direction == "out" else self.in_lane_ids

    def add_blackout(self, start: float, end: float,
                     prune_before: float | None = None) -> None:
        """Register a collective's occupancy.  The list stays sorted by
        start (next_clear early-exits on it) and, so long runs don't
        accumulate dead intervals, is pruned below ``prune_before`` — the
        caller's simulation frontier (the minimum running-tenant clock; no
        future transfer can be scheduled to start before it, and
        later-admitted tenants start at or after the admitting event's
        clock).  The registering tenant's own post-op clock is NOT a safe
        frontier: lagging tenants may still schedule into earlier windows."""
        if end > start:
            bisect.insort(self.blackouts, (start, end))
            self.blackout_s += end - start
            if prune_before is not None and len(self.blackouts) > 256:
                self.blackouts = [
                    (s, e) for s, e in self.blackouts if e > prune_before
                ]

    def next_clear(self, start: float, duration: float) -> float:
        """Earliest start >= ``start`` whose [start, start+duration) window
        overlaps no collective blackout."""
        moved = True
        while moved:
            moved = False
            for s, e in self.blackouts:
                if s >= start + duration:
                    break  # sorted by start: nothing later can overlap
                if start < e:
                    start = e
                    moved = True
        return start


# --------------------------------------------------------------- accounting
@dataclass
class PoolAccountant:
    """Shared-HBM accountant: per-tenant resident bytes against one budget.

    Swap-in bytes are charged when the transfer is *scheduled* (reservation),
    not when it completes, so ``fits()`` sees in-flight swap-ins on all
    channels and the engine cannot double-admit into the same headroom.
    ``overflow_events`` counts forced over-budget charges (late swap-ins at
    an access deadline, mallocs with no pending swap-out to wait for) — zero
    on a well-provisioned tenant set.
    """

    budget: int | None = None
    resident: dict[str, int] = field(default_factory=dict)
    peak: dict[str, int] = field(default_factory=dict)
    total: int = 0
    aggregate_peak: int = 0
    overflow_events: int = 0

    def add(self, tenant: str, nbytes: int) -> None:
        self.resident[tenant] = self.resident.get(tenant, 0) + nbytes
        self.total += nbytes
        if nbytes > 0 and self.budget is not None and self.total > self.budget:
            self.overflow_events += 1

    def fits(self, nbytes: int) -> bool:
        return self.budget is None or self.total + nbytes <= self.budget

    def mark_peak(self, tenant: str) -> None:
        r = self.resident.get(tenant, 0)
        if r > self.peak.get(tenant, 0):
            self.peak[tenant] = r
        if self.total > self.aggregate_peak:
            self.aggregate_peak = self.total


# ------------------------------------------------------------------ tenants
@dataclass
class Tenant:
    """One program admitted to the runtime: a trace + its swap schedule.

    ``limit`` is the HBM target the schedule was solved for (used for
    isolated-baseline comparisons; the shared budget governs execution).
    ``floor`` is the admission-control reservation — the planned peak
    resident bytes under the schedule; computed from the trace when None.
    """

    name: str
    trace: IterationTrace
    decisions: list[SwapDecision] = field(default_factory=list)
    limit: int | None = None
    floor: int | None = None
    iterations: int = 1
    # Churn model: when this tenant enters the system (simulated seconds) and
    # its SLO weight (victim selection prefers renegotiating lower-priority
    # tenants first).  ``departure_t`` makes the iteration count open-ended:
    # the tenant keeps iterating until its clock passes the departure event
    # at an iteration barrier (``iterations`` is then ignored).
    arrival_t: float = 0.0
    priority: float = 1.0
    departure_t: float | None = None
    # Mesh execution: which device pool this tenant's residency and DMA
    # channels belong to.  ``None`` is the default single shared device (the
    # legacy runtime shape); tenants with distinct devices get distinct HBM
    # accountants and channel pools but contend on the engine's HostLink.
    device: str | None = None
    # Collective communication tagged by the sharded tracer: op index ->
    # seconds the interconnect is occupied at that op (repro.dist capture).
    # The engine advances the tenant clock through each collective and, when
    # a HostLink is configured, blacks the link out for its duration.
    collectives: dict[int, float] = field(default_factory=dict)
    # A collective is ONE mesh-wide synchronized operation that every
    # participating tenant executes: exactly one tenant per group (the
    # group's first device) should register the link blackout, or the same
    # logical collective is blacked out once per device.  All tenants still
    # advance their clocks through it.
    collective_owner: bool = True

    def resident_floor(self) -> int:
        if self.floor is None:
            self.floor = planned_peak(self.trace, self.decisions)
        return self.floor


def planned_peak(trace: IterationTrace, decisions: Sequence[SwapDecision]) -> int:
    """Peak of the load curve with the schedule's absence windows subtracted —
    the minimum HBM a tenant needs resident if every transfer lands on time.

    Runs on the admission path (and renegotiation recomputes floors online),
    so the absence windows are subtracted as a delta array folded into one
    cumulative sum off the trace's memoized load curve — O(n + decisions)
    instead of the former O(decisions x span) pure-Python walk.
    """
    import numpy as np

    base = trace.load_curve_array()
    n = int(base.shape[0])
    if n == 0:
        return 0
    delta = np.zeros(n + 1, dtype=np.int64)
    for d in decisions:
        if d.wraps:
            spans = ((0, min(d.in_before, n)), (min(d.out_after, n), n))
        else:
            spans = ((min(d.out_after, n), min(d.in_before, n)),)
        for a, b in spans:
            if a < b:
                delta[a] -= d.size
                delta[b] += d.size
    curve = base + np.cumsum(delta[:n])
    return int(curve.max())


@dataclass
class _PendingOut:
    done_t: float
    owner: "_TenantRun"
    var: int
    size: int
    seq: int = 0          # global append order, the heap tie-break
    retired: bool = False


class _PendingQueue:
    """Done-time-ordered in-flight swap-outs for one device pool.

    The reference engine kept a plain list and ran ``min(pending, key=...)``
    plus ``pending.remove(rec)`` on every budget wait and retirement — O(P)
    per event.  This is a lazy-deletion heap keyed (done_t, seq): ``seq`` is
    the append order, so ties pop exactly the record ``min`` returned (first
    occurrence), and retiring a record marks it dead in place instead of
    scanning the list.  Owners keep their own (done_t, seq) heaps over the
    same records for the per-tenant drains (iteration barriers, finishes).
    """

    __slots__ = ("_heap", "_live")

    def __init__(self) -> None:
        self._heap: list[tuple[float, int, _PendingOut]] = []
        self._live = 0

    def __bool__(self) -> bool:
        return self._live > 0

    def push(self, rec: _PendingOut) -> None:
        heapq.heappush(self._heap, (rec.done_t, rec.seq, rec))
        self._live += 1

    def pop_min(self) -> _PendingOut:
        """Remove and return the earliest-completing live record."""
        heap = self._heap
        while heap:
            rec = heapq.heappop(heap)[2]
            if not rec.retired:
                rec.retired = True
                self._live -= 1
                return rec
        raise IndexError("pop from empty pending queue")

    def retire(self, rec: _PendingOut) -> None:
        """Mark a live record dead; its heap entry is skipped when reached."""
        rec.retired = True
        self._live -= 1


class _TenantRun:
    """Per-tenant replay state: the original simulator loop, one op at a time,
    against the shared channel pool / accountant."""

    def __init__(self, tenant: Tenant, hw: HardwareSpec, engine: "MemoryRuntime", admit_t: float):
        self.tenant = tenant
        self.name = tenant.name
        self.hw = hw
        self.engine = engine
        self.device = tenant.device
        # Per-device shared state: tenants on the same device share one HBM
        # accountant, one DMA channel pool and one pending-swap-out queue;
        # the default device (None) keeps the legacy single-pool shape.
        self.acct = engine.acct_for(tenant.device)
        self.chans = engine.channels_for(tenant.device)
        self.pending = engine.pending_for(tenant.device)
        trace = tenant.trace
        if trace.op_times is None:
            assign_times(trace, hw)
        self.trace = trace
        self.costs = trace.op_costs or {}
        self.baseline_s = trace.op_times[-1]
        self.iterations = max(1, tenant.iterations)
        self.floor = tenant.resident_floor()
        self.arrival_t = tenant.arrival_t
        self.priority = tenant.priority
        self.departure_t = tenant.departure_t
        # Renegotiation: a (decisions, new_floor, solve_ms) triple staged by
        # the engine, applied (or cancelled) at the next iteration barrier.
        self.replan_pending: tuple[list[SwapDecision], int, float] | None = None
        self.renegotiations = 0
        self.reneg_freed_bytes = 0
        self.reneg_solve_ms = 0.0
        self._record = engine.record_events
        self._obs = engine.obs
        # Engine knobs are fixed for the life of a run: cache the attribute
        # chains the per-step hot loop would otherwise chase every event.
        self._budget_guard = engine.budget is not None
        self._backsched = engine.prefetch == "backsched"
        # Stall-attribution ledger (always on; the hooks above are the
        # optional part).  Each accumulator is a named cause of overhead
        # seconds; ``MemoryRuntime._finish`` closes them into
        # ``TenantReport.attribution`` with an exact-sum residual.
        self.attr_xfer_s = 0.0        # swap-in stall: transfer was moving bytes
        self.attr_chan_s = 0.0        # swap-in stall: queued for channel/lane
        self.attr_black_s = 0.0       # swap-in stall: shifted past blackouts
        self.attr_outpend_s = 0.0     # swap-in stall: own swap-out not done
        self.stall_alloc_s = 0.0      # malloc delayed on pending swap-outs
        self.stall_drain_s = 0.0      # iteration-barrier transfer drains
        self.coll_s = 0.0             # collective seconds charged to the clock
        # Collective seconds the baseline already carries per iteration
        # (assign_times folds op_extra_s into op_times): the ledger only
        # attributes the excess the engine charges beyond that.
        self._extra_iter_s = float(sum((trace.op_extra_s or {}).values()))
        # Per-variable swap-in timing detail for the stall decomposition:
        # var -> (transfer seconds, queue wait, blackout shift), written by
        # ``acquire_transfer`` for this iteration's "in" transfers.
        self._in_detail: dict[int, tuple[float, float, float]] = {}

        n = trace.num_indices
        self.delta = [0] * (n + 1)
        self.malloc_size_at: dict[int, int] = {}
        for v in trace.variables:
            self.delta[v.alloc_index] += v.size
            self.malloc_size_at[v.alloc_index] = v.size
            if v.free_index <= n:
                self.delta[v.free_index] -= v.size

        self.bt = trace.op_times  # baseline schedule, for prefetch back-scheduling
        # Op durations are pure functions of the (immutable) cost table:
        # evaluate the roofline expression once per index instead of on
        # every step/_due call.  Same expression, same floats.
        costs = self.costs
        durs = []
        for j in range(len(self.bt)):
            flops, nbytes = costs.get(j, (0.0, 0.0))
            if flops or nbytes:
                durs.append(max(flops / hw.eff_flops, nbytes / hw.hbm_bw) + hw.op_overhead_s)
            else:
                durs.append(0.0)
        self._op_durs = durs
        self._install_decisions(tenant.decisions)

        # Collective windows on the baseline timeline (for contention-aware
        # back-scheduling): the collective at op i occupies the interconnect
        # for the tail of op i's span (its roofline compute runs first).
        self.collectives = dict(tenant.collectives)
        n_bt = len(self.bt) - 1
        self._coll_windows = sorted(
            (max(0.0, self.bt[min(i + 1, n_bt)] - d), self.bt[min(i + 1, n_bt)])
            for i, d in self.collectives.items()
            if d > 0.0
        )
        # Window index for _planned_blackout_s: starts are sorted, and the
        # running max of ends is monotone, so both scan bounds bisect instead
        # of walking every earlier window on every back-scheduling query.
        self._coll_starts = [s for s, _ in self._coll_windows]
        self._coll_maxend: list[float] = []
        m = float("-inf")
        for _, e in self._coll_windows:
            if e > m:
                m = e
            self._coll_maxend.append(m)

        self.admit_t = admit_t
        self.t = admit_t
        self.i = 0
        self.iter_no = 0
        self.stalls = 0
        self.delayed = 0
        self.events = 0                      # simulated op-steps executed
        self.out_events: list[tuple[int, float, float, int]] = []
        self.in_events: list[tuple[int, float, float, int]] = []
        # Tail-spill tracking survives ``record_events=False``: the latest
        # completion among this tenant's own swap-outs, across iterations.
        self._own_out_end = 0.0
        self._has_out = False
        self._own_pending: list[tuple[float, int, _PendingOut]] = []
        self.in_done: dict[int, float] = {}
        self.out_done: dict[int, float] = {}
        self.finished = False
        self._begin_iteration()

    # ------------------------------------------------------------ plumbing
    def _install_decisions(self, decisions: Sequence[SwapDecision]) -> None:
        self.decisions = list(decisions)
        self.out_at: dict[int, list[SwapDecision]] = {}
        self.in_at: dict[int, list[SwapDecision]] = {}
        for d in self.decisions:
            self.out_at.setdefault(d.out_after, []).append(d)
            self.in_at.setdefault(d.in_before, []).append(d)
        # Prefetch index: the reference engine re-filtered and re-sorted the
        # whole decision list on EVERY step.  Deadline order is fixed at
        # install time, so sort once (stably — same-deadline decisions keep
        # install order, exactly what the per-step stable sort produced) and
        # let each iteration walk a compacting copy (``_pf_active``).
        #
        # Each entry carries the decision's precomputed due-check constants:
        # its deadline time on the baseline schedule and its transfer-time
        # budget ``need``.  Without a HostLink (or contention-blind) the
        # reference's ``need`` is ``size / link_bw`` — a per-decision
        # constant; only the contention-aware-link path keeps a dynamic term
        # (the planned collective blackout inside the shrinking window).
        engine = self.engine
        self._pf_dynamic = engine.link is not None and engine.contention_aware
        bt = self.trace.op_times
        order = sorted(self.decisions, key=lambda d: d.in_before)
        if self._pf_dynamic:
            needs = [engine.xfer_seconds(d.size) for d in order]
        else:
            needs = [d.size / self.hw.link_bw for d in order]
        self._pf_order = [
            (d.var, d.in_before, d.size, bt[d.in_before], need)
            for d, need in zip(order, needs)
        ]
        self._pf_active: list[tuple[int, int, int, float, float]] = []

    def _iterations_done(self) -> bool:
        """Called at an iteration barrier, after ``iter_no`` was bumped."""
        if self.departure_t is not None:
            # Zero-duration iterations can never reach a future departure:
            # treat the first barrier as the departure to guarantee progress.
            return self.t >= self.departure_t or self.baseline_s <= 0.0
        return self.iter_no >= self.iterations

    def has_future_barrier(self) -> bool:
        """Will another iteration start after the current one finishes?  A
        renegotiated plan can only take effect at such a barrier."""
        if self.departure_t is not None:
            return self.t < self.departure_t and self.baseline_s > 0.0
        return self.iter_no + 1 < self.iterations

    def _transfer(self, size: int) -> float:
        return self.engine.xfer_seconds(size)

    def _op_dur(self, i: int) -> float:
        return self._op_durs[i]

    def _due(self, d: SwapDecision, i: int) -> bool:
        """Back-scheduling: is it time to start this swap-in?

        The transfer is due at the last op boundary where the baseline compute
        remaining before its deadline access still covers the transfer time —
        deferring one more op would make it late.  Actual compute only runs
        slower than baseline (stalls, delayed mallocs), so a transfer started
        on the baseline schedule never misses an on-time deadline; only
        channel contention can push it late.

        Under a shared HostLink the contention-aware scheduler (default)
        budgets the *effective* lane bandwidth plus the collective blackouts
        inside the window; the contention-blind baseline schedules as if the
        link were private — systematically late on a contended link, which
        is exactly the gap benchmarks measure.
        """
        bt = self.bt
        nxt = min(i + 1, len(bt) - 1)
        slack = bt[d.in_before] - bt[nxt]
        if self.engine.link is not None and not self.engine.contention_aware:
            need = d.size / self.hw.link_bw   # assumes a private, clear link
        else:
            need = self._transfer(d.size)
            if self.engine.link is not None:
                # Collectives black the link out inside the window: the
                # transfer needs that much extra slack to land on time.
                need += self._planned_blackout_s(bt[nxt], bt[d.in_before])
        return slack - self._op_dur(nxt) < need

    def _planned_blackout_s(self, a: float, b: float) -> float:
        """Seconds of [a, b) the baseline schedule spends in collectives.

        Bisect-bounded: windows before ``lo`` all end at or before ``a`` (the
        running-max-of-ends index is monotone) and windows from ``hi`` on
        start at or after ``b`` — exactly the entries the reference walk
        skipped via continue/break.  The surviving overlaps are summed
        left-to-right in the same order with the same float ops, so the
        result is bit-for-bit the reference's.
        """
        windows = self._coll_windows
        if not windows:
            return 0.0
        lo = bisect.bisect_right(self._coll_maxend, a)
        hi = bisect.bisect_left(self._coll_starts, b, lo)
        total = 0.0
        for j in range(lo, hi):
            s, e = windows[j]
            if e <= a:
                continue
            total += min(e, b) - max(s, a)
        return total

    def _begin_iteration(self) -> None:
        self.in_done = {}
        self.out_done = {}
        self._in_detail = {}
        # Wrap decisions: in steady state the variable is already on the host
        # when the iteration starts (swapped out during the previous tail).
        for d in self.decisions:
            if d.wraps:
                self.acct.add(self.name, -d.size)
                self.out_done[d.var] = self.t
        self.i = 0
        self._pf_active = list(self._pf_order)

    def _end_iteration(self) -> bool:
        """Close one iteration; True when the whole tenant is finished."""
        self.iter_no += 1
        if self._iterations_done():
            return True
        # Iteration barrier for multi-iteration replay: drain this tenant's
        # in-flight transfers and reset its residency to zero so the next
        # iteration's deltas (which re-count persistent variables at index 0)
        # don't double-charge the accountant.
        acct = self.acct
        own = self._own_pending
        while own:
            done_t, _, rec = heapq.heappop(own)
            if rec.retired:
                continue
            if done_t > self.t:
                self.stall_drain_s += done_t - self.t
                if self._obs is not None:
                    self._obs.stall(self, "barrier_drain", self.t,
                                    done_t - self.t, rec.var)
                self.t = done_t
            self.pending.retire(rec)
            acct.add(self.name, -rec.size)
        if self.in_done:
            in_max = max(self.in_done.values())
            if in_max > self.t:
                self.stall_drain_s += in_max - self.t
                if self._obs is not None:
                    self._obs.stall(self, "barrier_drain", self.t,
                                    in_max - self.t, -1)
                self.t = in_max
        acct.add(self.name, -acct.resident.get(self.name, 0))
        # The barrier is the only point where the resident set is empty, so a
        # staged renegotiation (shrunken swap plan) swaps in here.
        if self.replan_pending is not None:
            self.engine._on_barrier(self)
        self._begin_iteration()
        return False

    # ---------------------------------------------------------------- step
    def step(self) -> bool:
        """Execute the next op; returns True when the tenant has finished."""
        self.events += 1
        if self.i >= self.trace.num_indices:
            # Degenerate empty trace.
            self.finished = self._end_iteration()
            return self.finished
        i = self.i
        acct = self.acct
        record = self._record

        # 1. If this op needs a swapped variable back, wait for its swap-in.
        for d in self.in_at.get(i, ()):
            if d.var not in self.in_done:
                # Should have been prefetched; schedule now (late prefetch).
                # Still charged at schedule time so concurrent channels see it.
                ready = max(self.t, self.out_done.get(d.var, 0.0))
                start, end, ch = self.engine.acquire_transfer(
                    self, "in", ready, d.size, d.var)
                self.in_done[d.var] = end
                acct.add(self.name, d.size)
                if record:
                    self.in_events.append((d.var, start, end, ch))
            if self.in_done[d.var] > self.t:
                self.stalls += 1
                # Attribute the wait backwards from its components: bytes in
                # flight first, then blackout shift, then channel/lane queue;
                # whatever the transfer timing can't explain is time spent
                # waiting on the variable's own swap-out (the transfer could
                # not even be scheduled until the bytes were host-side).
                wait = self.in_done[d.var] - self.t
                xfer_s, chan_w, black_s = self._in_detail.get(
                    d.var, (0.0, 0.0, 0.0))
                part = min(wait, xfer_s)
                self.attr_xfer_s += part
                rem = wait - part
                part = min(rem, black_s)
                self.attr_black_s += part
                rem -= part
                part = min(rem, chan_w)
                self.attr_chan_s += part
                self.attr_outpend_s += rem - part
                if self._obs is not None:
                    self._obs.stall(self, "swap_in_wait", self.t, wait, d.var)
                self.t = self.in_done[d.var]

        # 2. Budget enforcement on mallocs (paper: delay the Malloc).  Any
        # same-device tenant's pending swap-out frees shared headroom, so the
        # wait is on this device's earliest completion.
        if self._budget_guard and self.delta[i] > 0 and i in self.malloc_size_at:
            while not acct.fits(self.delta[i]) and self.pending:
                rec = self.pending.pop_min()
                if rec.done_t > self.t:
                    self.delayed += 1
                    self.stall_alloc_s += rec.done_t - self.t
                    if self._obs is not None:
                        self._obs.stall(self, "swap_out_drain", self.t,
                                        rec.done_t - self.t, rec.var)
                    self.t = rec.done_t
                acct.add(rec.owner.name, -rec.size)
        acct.add(self.name, self.delta[i])
        acct.mark_peak(self.name)

        # 3. Execute the op (compute is per-tenant; only memory is shared).
        t_op0 = self.t
        self.t += self._op_durs[i]
        # 3b. Collective tagged at this op: it occupies the interconnect for
        # its duration (the tenant's clock advances through it, matching the
        # baseline op_times the sharded tracer folded the duration into),
        # and when a HostLink is configured the link is blacked out — swap
        # transfers of EVERY device route around it.  Only the group's
        # collective owner registers the blackout: the collective is one
        # mesh-wide synchronized op, not one per participating device.
        cdur = self.collectives.get(i)
        if cdur:
            if self.engine.link is not None and self.tenant.collective_owner:
                frontier = min(r.t for r in self.engine._running) if self.engine._running else self.t
                self.engine.link.add_blackout(self.t, self.t + cdur,
                                              prune_before=frontier)
                if self._obs is not None:
                    self._obs.blackout(self.t, self.t + cdur)
            if self._obs is not None:
                self._obs.collective(self, i, self.t, cdur)
            self.coll_s += cdur
            self.t += cdur

        # 4. Launch swap-outs whose trigger access just completed.
        for d in self.out_at.get(i, ()):
            start, end, ch = self.engine.acquire_transfer(
                self, "out", self.t, d.size, d.var)
            self.out_done[d.var] = end
            rec = _PendingOut(end, self, d.var, d.size, self.engine._next_seq())
            self.pending.push(rec)
            heapq.heappush(self._own_pending, (end, rec.seq, rec))
            self._has_out = True
            if end > self._own_out_end:
                self._own_out_end = end
            if record:
                self.out_events.append((d.var, start, end, ch))

        # 5. Retire this tenant's completed swap-outs (frees resident bytes).
        own = self._own_pending
        while own and own[0][0] <= self.t:
            rec = heapq.heappop(own)[2]
            if rec.retired:
                continue
            self.pending.retire(rec)
            acct.add(self.name, -rec.size)

        # 6. Prefetch swapped-out variables back, nearest deadline first.
        # Policy "eager" (the legacy simulator): keep the in-channels busy as
        # soon as data is out and the budget allows it back.  Policy
        # "backsched" (runtime default): start each swap-in just-in-time from
        # its deadline, so readmitted bytes don't crowd the budget that
        # compute mallocs need in the meantime — eager prefetch over fast
        # channels otherwise *increases* malloc delays (scheduling anomaly).
        # Either way a budget-blocked head-of-line transfer stops this
        # tenant's prefetching until room appears — and because bytes are
        # reserved at schedule time in steps 1/6, a second in-channel can
        # never admit into the same headroom.
        #
        # The walk runs over the prefetch index (deadline-ordered at install
        # time) with in-place compaction: entries already swapped back in, or
        # whose deadline has passed, drop permanently; entries not yet
        # swapped out (or not yet due) stay for the next step.
        active = self._pf_active
        if active:
            out_done = self.out_done
            in_done = self.in_done
            guard = self._budget_guard
            backsched = self._backsched
            dynamic = self._pf_dynamic
            # The due check's step-dependent terms are shared by every
            # candidate at this op: hoist them out of the walk.
            bt = self.bt
            nxt = i + 1
            if nxt >= len(bt):
                nxt = len(bt) - 1
            bt_nxt = bt[nxt]
            od_nxt = self._op_durs[nxt]
            n_active = len(active)
            w = r = 0
            while r < n_active:
                ent = active[r]
                var = ent[0]
                if var in in_done or ent[1] <= i:
                    r += 1                      # permanently dead: drop
                    continue
                if var not in out_done:
                    active[w] = ent; w += 1; r += 1   # not swapped out yet: keep
                    continue
                size = ent[2]
                if guard and not acct.fits(size):
                    break                       # head-of-line blocked: stop
                if backsched:
                    # Inlined _due: slack minus the next op's compute,
                    # against the precomputed (plus planned-blackout, on a
                    # contended link) transfer budget — same float ops as
                    # the reference's per-call recomputation.
                    in_t = ent[3]
                    need = ent[4]
                    if dynamic:
                        need = need + self._planned_blackout_s(bt_nxt, in_t)
                    if not ((in_t - bt_nxt) - od_nxt < need):
                        active[w] = ent; w += 1; r += 1   # not due yet: keep
                        continue
                start, end, ch = self.engine.acquire_transfer(
                    self, "in", max(self.t, out_done[var]), size, var
                )
                in_done[var] = end
                acct.add(self.name, size)
                acct.mark_peak(self.name)
                if record:
                    self.in_events.append((var, start, end, ch))
                r += 1                          # now in in_done: drop
            if w != r:
                while r < n_active:             # keep the unexamined tail
                    active[w] = active[r]; w += 1; r += 1
                del active[w:]

        if self._obs is not None:
            # The compute span alone; swap-outs/prefetches launched this
            # step have already settled, so the occupancy sample is the
            # end-of-step state.
            self._obs.op_step(self, i, t_op0, t_op0 + self._op_durs[i], acct)
        self.i += 1
        if self.i >= self.trace.num_indices:
            self.finished = self._end_iteration()
        return self.finished

    def release_residency(self) -> None:
        """Free everything this tenant still has charged to the accountant.

        Called when the tenant finishes: persistent variables (freed at
        ``delta[num_indices]``, which the op loop never applies) and any
        in-flight tail swap-outs would otherwise stay charged to the shared
        pool forever, starving later-admitted tenants.
        """
        acct = self.acct
        own = self._own_pending
        while own:
            rec = heapq.heappop(own)[2]
            if rec.retired:
                continue
            self.pending.retire(rec)
            acct.add(self.name, -rec.size)
        acct.add(self.name, -acct.resident.get(self.name, 0))

    # ------------------------------------------------------------- results
    def sim_result(self) -> SimResult:
        # Tail spill is *this tenant's* swap-out traffic draining past its
        # compute end — tracked as a running max over its own out transfers
        # (so it survives ``record_events=False``).  The shared
        # ``channels.drain_time("out")`` would charge other tenants'
        # in-flight swap-outs to this tenant.
        own_out_end = self._own_out_end if self._has_out else self.t
        res = SimResult(
            baseline_s=self.baseline_s * self.completed_iterations(),
            duration_s=self.t - self.admit_t,
            peak_resident=self.acct.peak.get(self.name, 0),
            stalls=self.stalls,
            delayed_mallocs=self.delayed,
            tail_spill_s=max(0.0, own_out_end - self.t),
            out_events=[(v, s, e) for v, s, e, _ in self.out_events],
            in_events=[(v, s, e) for v, s, e, _ in self.in_events],
        )
        return res

    def completed_iterations(self) -> int:
        return max(1, self.iter_no if self.finished else self.iterations)


# ------------------------------------------------------------------ reports
@dataclass
class TenantReport:
    name: str
    status: str                     # "completed" | "unschedulable"
    baseline_s: float
    duration_s: float               # compute span, excluding queue wait
    overhead: float
    peak_resident: int
    floor: int                      # reservation at finish (after any shrink)
    stalls: int
    delayed_mallocs: int
    admitted_at: float
    finished_at: float
    queue_wait_s: float             # admitted_at - arrival_t
    arrival_t: float = 0.0
    priority: float = 1.0
    iterations: int = 1
    # Times this tenant was the renegotiation victim (plan re-solved at a
    # lower limit and applied at a barrier), the reservation bytes it gave
    # up, and the wall-clock spent in those online re-solves.
    renegotiations: int = 0
    renegotiation_freed_bytes: int = 0
    renegotiation_solve_ms: float = 0.0
    # Device pool this tenant ran against (None = the default shared device).
    device: str | None = None
    # Engine throughput: simulated op-steps this tenant executed.
    events: int = 0
    # Stall-attribution ledger: overhead seconds (duration - baseline)
    # decomposed into named causes.  Every key except ``overhead_s``,
    # ``queue_wait_s`` and ``renegotiation_solve_s`` is a bucket; the
    # buckets (including the float-closure ``residual_s``) sum to
    # ``overhead_s``.  None for unschedulable tenants; stripped by
    # ``simulated_report_dict`` (absent from the frozen reference engine).
    attribution: dict | None = None

    def as_dict(self) -> dict:
        return self.__dict__.copy()


@dataclass
class RuntimeReport:
    hardware: str
    budget: int | None
    channels: int
    tenants: list[TenantReport]
    aggregate_peak: int
    overflow_events: int
    makespan_s: float
    policy: str = "fifo"            # "fifo" | "renegotiate"
    renegotiations: int = 0         # applied victim re-plans
    renegotiations_cancelled: int = 0   # staged but nobody waited at barrier
    renegotiation_freed_bytes: int = 0
    renegotiation_solve_ms: float = 0.0
    # Mesh execution only (None on the legacy single-device shape, so the
    # serialized report is unchanged for existing consumers): per-device
    # aggregate peaks, and the shared HostLink's contention counters.
    device_peaks: dict[str, int] | None = None
    link: dict | None = None
    # Engine throughput counters (simulated events, wall-clock run and
    # renegotiation-solve seconds, events/sec).  Wall clock varies run to
    # run; ``simulated_report_dict`` strips this for equivalence checks.
    engine: dict | None = None
    # Sum of the per-tenant attribution ledgers (completed tenants only);
    # stripped by ``simulated_report_dict`` like the per-tenant ledgers.
    attribution: dict | None = None

    def tenant(self, name: str) -> TenantReport:
        for t in self.tenants:
            if t.name == name:
                return t
        raise KeyError(name)

    def as_dict(self) -> dict:
        d = {
            "hardware": self.hardware,
            "budget": self.budget,
            "channels": self.channels,
            "tenants": [t.as_dict() for t in self.tenants],
            "aggregate_peak": self.aggregate_peak,
            "overflow_events": self.overflow_events,
            "makespan_s": self.makespan_s,
            "policy": self.policy,
            "renegotiations": self.renegotiations,
            "renegotiations_cancelled": self.renegotiations_cancelled,
            "renegotiation_freed_bytes": self.renegotiation_freed_bytes,
            "renegotiation_solve_ms": self.renegotiation_solve_ms,
        }
        if self.device_peaks is not None:
            d["device_peaks"] = dict(self.device_peaks)
        if self.link is not None:
            d["link"] = dict(self.link)
        if self.engine is not None:
            d["engine"] = dict(self.engine)
        if self.attribution is not None:
            d["attribution"] = dict(self.attribution)
        return d


def simulated_report_dict(report: "RuntimeReport") -> dict:
    """``report.as_dict()`` reduced to the *simulated* quantities.

    Drops the wall-clock engine counters (different every run), the
    per-tenant event counts and the attribution ledgers (absent from the
    frozen reference engine's reports; the ledgers also carry the
    wall-clock ``renegotiation_solve_s``), leaving exactly the fields two
    engines must agree on bit-for-bit.  Works on fast and reference
    reports alike.
    """
    d = report.as_dict()
    d.pop("engine", None)
    d.pop("attribution", None)
    d["renegotiation_solve_ms"] = 0.0
    d["tenants"] = [dict(t) for t in d["tenants"]]
    for t in d["tenants"]:
        t.pop("events", None)
        t.pop("attribution", None)
        t["renegotiation_solve_ms"] = 0.0
    return d


# ----------------------------------------------------------- victim policies
class VictimPolicy:
    """Strategy for picking which running tenant a renegotiation shrinks.

    ``choose`` receives the engine, the head-of-line waiter, the bytes its
    admission still ``needed`` on its device pool, and the eligible victims
    in floor-greedy order (lowest priority, then largest floor, then name).
    It returns ``(run, new_limit, decisions, new_floor, solve_ms)`` for the
    staged re-plan, or ``None`` to fall back to plain FIFO queueing.

    ``deferred=False`` policies run synchronously inside the admission path
    (the legacy behavior).  ``deferred=True`` policies are invoked at the
    next event-loop top instead — the only point where the engine state is a
    consistent between-events snapshot, which simulation-probing policies
    (``repro.tune.LedgerVictimPolicy``) need to ``resume()`` candidate
    suffixes.  Deferral costs at most one simulated event of staging delay
    and never changes what the staged plan can observe (re-plans only apply
    at the victim's next iteration barrier either way).
    """

    name = "greedy"
    deferred = False

    def choose(self, engine: "MemoryRuntime", head: "Tenant", needed: int,
               victims: "list[_TenantRun]"):
        raise NotImplementedError


class FloorGreedyVictim(VictimPolicy):
    """The default: first eligible victim, shrunk by exactly ``needed``.

    Byte-for-byte the pre-policy engine loop (and the frozen reference's):
    walk victims in (priority, -floor, name) order, re-solve at
    ``floor - needed``, take the first solve whose new floor actually fits
    the shrunken limit."""

    def choose(self, engine, head, needed, victims):
        for v in victims:
            new_limit = v.floor - needed
            if new_limit <= 0:
                continue
            decisions, solve_ms = engine._replan(v.tenant, new_limit)
            new_floor = planned_peak(v.trace, decisions)
            if new_floor > new_limit:
                continue  # solver could not push the floor low enough
            return v, new_limit, decisions, new_floor, solve_ms
        return None


# ------------------------------------------------------------------- engine
class MemoryRuntime:
    """Co-schedules N tenant programs over K DMA channels under one budget.

    The run loop is event-driven: tenants enter at their ``arrival_t`` and
    are admitted when their resident floor fits the unreserved budget; the
    rest wait FIFO.  With ``renegotiate=True`` a waiting newcomer triggers
    preemptive floor renegotiation of a running victim (see ``Tenant``):
    ``replanner(tenant, new_limit)`` re-solves the victim's swap schedule,
    and the shrunken plan takes effect at the victim's next iteration
    barrier.  ``replanner`` defaults to the plan pipeline's SwapSelection
    pass (``repro.runtime.tenants.pipeline_replanner``).

    ``record_events=False`` turns off per-transfer event logging (the
    ``in_events``/``out_events`` tuples grow unbounded across iterations) —
    keep the default for tests and schedule inspection, turn it off for
    fleet-scale horizons.  ``capture_snapshots=True`` snapshots the full
    engine state at every barrier where a renegotiated plan applies
    (``barrier_snapshots``); ``resume()`` on a snapshot replays only the
    suffix after that barrier, byte-identical to the full horizon.
    """

    def __init__(
        self,
        hw: HardwareSpec,
        budget: int | None = None,
        channels: int = 2,
        prefetch: str = "backsched",
        renegotiate: bool = False,
        replanner: Replanner | None = None,
        replan_scorer: str = "swdoa",
        replan_size_threshold: int = 1 << 20,
        link: HostLink | None = None,
        contention_aware: bool = True,
        record_events: bool = True,
        capture_snapshots: bool = False,
        max_snapshots: int | None = None,
        victim_policy: VictimPolicy | None = None,
        obs=None,
    ):
        if prefetch not in ("backsched", "eager"):
            raise ValueError(f"unknown prefetch policy {prefetch!r}")
        self.hw = hw
        self.budget = budget                 # per device pool
        self.num_channels = channels         # per device pool
        self.prefetch = prefetch
        self.renegotiate = renegotiate
        self.replanner = replanner
        self.replan_scorer = replan_scorer
        self.replan_size_threshold = replan_size_threshold
        # Mesh execution: the shared host-link bandwidth pool every device's
        # channels contend on (None = contention-free, the legacy model).
        # ``contention_aware`` lets prefetch back-scheduling budget the
        # effective lane bandwidth and the planned collective blackouts;
        # with False the link still constrains the physics but transfers are
        # scheduled as if it were private (the contention-blind baseline
        # benchmarks compare against).
        self.link = link
        self.contention_aware = contention_aware
        self.record_events = record_events
        self.capture_snapshots = capture_snapshots
        # Snapshot ring buffer: with churn storms every applied renegotiation
        # barrier deep-copies the whole engine, which grows without bound on
        # long horizons.  ``max_snapshots=N`` keeps only the N most recent —
        # ``resume()`` then replays suffixes from those barriers only;
        # earlier barriers are no longer resumable (the full run's report is
        # unaffected either way).  ``None`` keeps every snapshot.
        self.max_snapshots = max_snapshots
        # Victim selection is pluggable: the default reproduces the frozen
        # reference's floor-greedy loop bit for bit; ``repro.tune`` supplies
        # a ledger-driven policy that probes candidate (victim, limit) pairs
        # by re-simulating the suffix.
        self.victim_policy = (
            victim_policy if victim_policy is not None else FloorGreedyVictim()
        )
        # Optional observer (``repro.obs.ObsRecorder`` or anything with its
        # hook surface).  The engine only *calls* it — never reads from it —
        # so simulated reports are bit-identical obs-on vs obs-off; with
        # ``obs=None`` (default) each hook site costs one predicate, gated
        # exactly like ``record_events``.  Duck-typed on purpose: the engine
        # stays import-free of ``repro.obs``.
        self.obs = obs
        # Default (None) device pool, plus one pool per named Tenant.device.
        # The attribute names acct/channels/pending_outs keep the legacy
        # single-device surface tests and callers rely on.
        self.channels = ChannelPool.make(channels)
        self.acct = PoolAccountant(budget)
        self.pending_outs = _PendingQueue()
        self._accts: dict[str | None, PoolAccountant] = {None: self.acct}
        self._chans: dict[str | None, ChannelPool] = {None: self.channels}
        self._pending: dict[str | None, _PendingQueue] = {None: self.pending_outs}
        self.runs: dict[str, _TenantRun] = {}
        # Run-loop state (owned by run(); instance-level so _TenantRun
        # barrier callbacks can reach it).  Reservation accounting is per
        # device pool.
        self._arrivals: deque[Tenant] = deque()
        self._waiting: deque[Tenant] = deque()
        self._running: list[_TenantRun] = []
        self._reports: dict[str, TenantReport] = {}
        self._reserved: dict[str | None, int] = {}
        self._promised: dict[str | None, int] = {}  # bytes staged replans will free
        self._now = 0.0
        self._reneg_applied = 0
        self._reneg_cancelled = 0
        self._reneg_freed = 0
        self._reneg_solve_ms = 0.0
        # Event frontier: one (clock, admission seq, run) heap entry per
        # running tenant — the next event pops in O(log N) instead of the
        # reference engine's O(N) min-scan.  Ties resolve in admission order,
        # exactly the first-in-list element ``min`` used to return.
        self._event_heap: list[tuple[float, int, _TenantRun]] = []
        self._admit_seq = 0
        self._pending_seq = 0
        self._events = 0
        self.barrier_snapshots: list["MemoryRuntime"] = []
        self._snapshot_due = False
        self._tune_due = False

    # ----------------------------------------------------- device pools
    def acct_for(self, device: str | None) -> PoolAccountant:
        acct = self._accts.get(device)
        if acct is None:
            acct = self._accts[device] = PoolAccountant(self.budget)
        return acct

    def channels_for(self, device: str | None) -> ChannelPool:
        chans = self._chans.get(device)
        if chans is None:
            chans = self._chans[device] = ChannelPool.make(self.num_channels)
        return chans

    def pending_for(self, device: str | None) -> _PendingQueue:
        pending = self._pending.get(device)
        if pending is None:
            pending = self._pending[device] = _PendingQueue()
        return pending

    def _next_seq(self) -> int:
        seq = self._pending_seq
        self._pending_seq = seq + 1
        return seq

    # ------------------------------------------------------- transfers
    def xfer_seconds(self, size: int) -> float:
        """Duration of one swap transfer: the device link, further capped by
        the shared host-link lane bandwidth when a HostLink is configured."""
        if self.link is None:
            return size / self.hw.link_bw
        return size / min(self.hw.link_bw, self.link.lane_bw)

    def acquire_transfer(
        self, run: "_TenantRun", direction: str, ready_t: float, size: int,
        var: int = -1,
    ) -> tuple[float, float, int]:
        """Schedule one swap transfer for ``run``: it must hold the device's
        directional DMA channel and (when a HostLink is configured) a global
        link lane, and is shifted past any collective blackout.  ``var`` is
        the swapped variable, carried for the stall-attribution detail and
        the obs transfer hook (``-1``: unattributed legacy callers)."""
        chans = run.chans
        if self.link is None:
            duration = size / self.hw.link_bw
            start, end, ch = chans.acquire(direction, ready_t, duration)
            if direction == "in":
                run._in_detail[var] = (duration, start - ready_t, 0.0)
            if self.obs is not None:
                self.obs.transfer(run, direction, var, start, end, ch,
                                  None, ready_t, size)
            return start, end, ch
        ids = chans.out_ids if direction == "out" else chans.in_ids
        ch = min(ids, key=lambda c: chans.free_at[c])
        lane = min(self.link.lane_ids(direction),
                   key=lambda l: self.link.free_at[l])
        duration = self.xfer_seconds(size)
        queued = max(ready_t, chans.free_at[ch], self.link.free_at[lane])
        start = self.link.next_clear(queued, duration)
        end = start + duration
        chans.free_at[ch] = end
        self.link.free_at[lane] = end
        self.link.bytes_moved += size
        self.link.transfers += 1
        if direction == "in":
            self.link.wait_in_s += start - ready_t
            self.link.bytes_in += size
        else:
            self.link.wait_out_s += start - ready_t
            self.link.bytes_out += size
        if direction == "in":
            run._in_detail[var] = (duration, queued - ready_t, start - queued)
        if self.obs is not None:
            self.obs.transfer(run, direction, var, start, end, ch,
                              lane, ready_t, size)
        return start, end, ch

    # -------------------------------------------------------- admission path
    def _unschedulable(self, cand: Tenant, floor: int) -> None:
        self._reports[cand.name] = TenantReport(
            name=cand.name, status="unschedulable", baseline_s=0.0,
            duration_s=0.0, overhead=0.0, peak_resident=0, floor=floor,
            stalls=0, delayed_mallocs=0, admitted_at=-1.0,
            finished_at=-1.0, queue_wait_s=0.0, arrival_t=cand.arrival_t,
            priority=cand.priority, iterations=cand.iterations,
            device=cand.device,
        )
        if self.obs is not None:
            self.obs.unschedulable(cand.name, cand.arrival_t)

    def _try_admit(self, clock: float) -> None:
        """Admit waiting tenants FIFO while their floors fit the budget of
        their device pool; ``clock`` is the simulated time of the event that
        may have freed reservation.  The queue stays globally FIFO: a
        head-of-line tenant whose device is full blocks later arrivals even
        to other devices (admission order is part of the contract)."""
        while self._waiting:
            cand = self._waiting[0]
            floor = cand.resident_floor()
            if self.budget is not None and floor > self.budget:
                # Can never fit, even alone: report, do not OOM-kill others.
                self._waiting.popleft()
                self._unschedulable(cand, floor)
                continue
            reserved = self._reserved.get(cand.device, 0)
            if self.budget is not None and reserved + floor > self.budget:
                return  # FIFO: head-of-line waits for floor to free up
            self._waiting.popleft()
            self._reserved[cand.device] = reserved + floor
            run = _TenantRun(cand, self.hw, self, admit_t=max(clock, cand.arrival_t))
            self.runs[cand.name] = run
            self._running.append(run)
            run._admit_seq = self._admit_seq
            self._admit_seq += 1
            heapq.heappush(self._event_heap, (run.t, run._admit_seq, run))
            if self.obs is not None:
                self.obs.admitted(cand.name, cand.device,
                                  cand.arrival_t, run.admit_t,
                                  getattr(cand, "priority", 1.0))

    def _drain_arrivals(self, upto: float) -> None:
        """Move arrivals with ``arrival_t <= upto`` into the admission queue,
        in arrival order, admitting (or staging renegotiation) as they land."""
        while self._arrivals and self._arrivals[0].arrival_t <= upto:
            cand = self._arrivals.popleft()
            self._waiting.append(cand)
            self._try_admit(cand.arrival_t)
            self._maybe_renegotiate()

    # --------------------------------------------------------- renegotiation
    def _replan(self, tenant: Tenant, new_limit: int) -> tuple[list[SwapDecision], float]:
        if self.replanner is None:
            from .tenants import pipeline_replanner  # deferred: tenants imports engine

            self.replanner = pipeline_replanner(
                self.hw, scorer=self.replan_scorer,
                size_threshold=self.replan_size_threshold,
            )
        return self.replanner(tenant, new_limit)

    def _maybe_renegotiate(self) -> None:
        """If the head-of-line waiter doesn't fit, stage a victim re-plan.

        Victim order: lowest priority first, then largest floor (most bytes
        to reclaim).  A victim must have a future iteration barrier — the
        only point a shrunken plan can take effect — and only one staged
        re-plan at a time.  Falls back to FIFO queueing when no single
        victim can free enough.  The actual (victim, limit) pick is the
        ``victim_policy``'s; deferred policies run at the next loop top
        (see ``VictimPolicy``) instead of inside the admission path.
        """
        if not self.renegotiate or self.budget is None or not self._waiting:
            return
        if self.victim_policy.deferred:
            self._tune_due = True
            return
        self._stage_victim()

    def _stage_victim(self) -> None:
        """Ask the victim policy for a (victim, limit) and stage its re-plan.

        Re-validates the waiting state first: by the time a deferred policy
        runs, the head may already have been admitted (or departed victims
        may have freed enough reservation)."""
        if not self._waiting:
            return
        head = self._waiting[0]
        floor = head.resident_floor()
        if floor > self.budget:
            return  # unschedulable; _try_admit reports it
        needed = (
            self._reserved.get(head.device, 0)
            - self._promised.get(head.device, 0)
            + floor
            - self.budget
        )
        if needed <= 0:
            return  # staged re-plans already free enough; wait for barriers
        victims = [
            r for r in self._running
            if r.replan_pending is None and r.has_future_barrier()
            and r.device == head.device  # only same-pool bytes can help
        ]
        victims.sort(key=lambda r: (r.priority, -r.floor, r.name))
        choice = self.victim_policy.choose(self, head, needed, victims)
        if choice is None:
            return
        v, new_limit, decisions, new_floor, solve_ms = choice
        v.replan_pending = (list(decisions), new_floor, solve_ms)
        self._promised[v.device] = (
            self._promised.get(v.device, 0) + v.floor - new_floor
        )
        if self.obs is not None:
            self.obs.renegotiation("staged", v.name, v.t, new_limit)

    def _on_barrier(self, run: _TenantRun) -> None:
        """Iteration barrier of a victim with a staged re-plan (called from
        ``_end_iteration`` with the victim's residency already drained)."""
        # Arrivals up to the barrier precede it; process them first so the
        # apply-or-cancel decision sees the true waiting queue at this time.
        self._drain_arrivals(run.t)
        staged = run.replan_pending
        if staged is None:  # applied recursively while draining
            return
        decisions, new_floor, solve_ms = staged
        run.replan_pending = None
        freed = run.floor - new_floor
        self._promised[run.device] = self._promised.get(run.device, 0) - freed
        if not self._waiting:
            # Nobody waits anymore (a finish admitted them): keep the
            # better plan, don't shrink for no one.
            self._reneg_cancelled += 1
            if self.obs is not None:
                self.obs.renegotiation("cancelled", run.name, run.t, 0)
            return
        run._install_decisions(decisions)
        run.floor = new_floor
        self._reserved[run.device] = self._reserved.get(run.device, 0) - freed
        run.renegotiations += 1
        run.reneg_freed_bytes += freed
        run.reneg_solve_ms += solve_ms
        self._reneg_applied += 1
        self._reneg_freed += freed
        self._reneg_solve_ms += solve_ms
        if self.obs is not None:
            self.obs.renegotiation("applied", run.name, run.t, freed)
        self._try_admit(run.t)
        self._maybe_renegotiate()
        if self.capture_snapshots:
            # Applied at this barrier: snapshot at the next loop-top (a
            # clean between-events point) so resume() replays the suffix.
            self._snapshot_due = True

    # -------------------------------------------------------------- run loop
    def _finish(self, run: _TenantRun) -> None:
        self._running.remove(run)
        self._reserved[run.device] = self._reserved.get(run.device, 0) - run.floor
        if run.replan_pending is not None:
            # Departure beat the barrier: the staged shrink never applied.
            _, new_floor, _ = run.replan_pending
            self._promised[run.device] = (
                self._promised.get(run.device, 0) - (run.floor - new_floor)
            )
            run.replan_pending = None
            self._reneg_cancelled += 1
            if self.obs is not None:
                self.obs.renegotiation("cancelled", run.name, run.t, 0)
        run.release_residency()
        self._now = max(self._now, run.t)
        dur = run.t - run.admit_t
        base = run.baseline_s * run.completed_iterations()
        # Close the stall-attribution ledger: the named buckets plus a
        # float-closure residual sum to the tenant's overhead seconds.
        # ``collective_excess_s`` is only what the engine charged beyond the
        # collective time assign_times already folded into the baseline.
        overhead_s = max(0.0, dur - base)
        coll_excess = run.coll_s - run._extra_iter_s * run.completed_iterations()
        named = (run.attr_xfer_s + run.attr_black_s + run.attr_chan_s
                 + run.attr_outpend_s + run.stall_alloc_s + run.stall_drain_s
                 + coll_excess)
        attribution = {
            "overhead_s": overhead_s,
            "swap_in_transfer_s": run.attr_xfer_s,
            "link_blackout_s": run.attr_black_s,
            "channel_contention_s": run.attr_chan_s,
            "swap_out_pending_s": run.attr_outpend_s,
            "swap_out_drain_s": run.stall_alloc_s,
            "barrier_drain_s": run.stall_drain_s,
            "collective_excess_s": coll_excess,
            "residual_s": overhead_s - named,
            # Informational (outside the overhead sum): admission queueing
            # precedes ``admitted_at`` and the re-solve is host wall-clock.
            "queue_wait_s": run.admit_t - run.arrival_t,
            "renegotiation_solve_s": run.reneg_solve_ms / 1e3,
        }
        self._reports[run.name] = TenantReport(
            name=run.name, status="completed", baseline_s=base,
            duration_s=dur,
            overhead=max(0.0, (dur - base) / base) if base > 0 else 0.0,
            peak_resident=run.acct.peak.get(run.name, 0),
            floor=run.floor, stalls=run.stalls,
            delayed_mallocs=run.delayed, admitted_at=run.admit_t,
            finished_at=run.t, queue_wait_s=run.admit_t - run.arrival_t,
            arrival_t=run.arrival_t, priority=run.priority,
            iterations=run.completed_iterations(),
            renegotiations=run.renegotiations,
            renegotiation_freed_bytes=run.reneg_freed_bytes,
            renegotiation_solve_ms=run.reneg_solve_ms,
            device=run.device,
            events=run.events,
            attribution=attribution,
        )
        if self.obs is not None:
            self.obs.finished(run.name, run.device, run.t)
        self._try_admit(run.t)
        self._maybe_renegotiate()

    def _snapshot(self) -> "MemoryRuntime":
        """Deep-copy the engine mid-run, sharing the immutable heavy state.

        Traces (op times/costs/variables are read-only once assigned), the
        hardware spec and the replanner hook are shared between the live
        engine and the snapshot; everything mutable — accountants, channel
        pools, pending heaps, tenant runs, the event frontier — is copied,
        so ``resume()`` on the snapshot replays the suffix independently.
        """
        memo: dict[int, object] = {id(self.hw): self.hw}
        # The policy is config (plus an optional decision log), not simulated
        # state; prior barrier snapshots are themselves whole engines — both
        # are shared/elided rather than recursively deep-copied.
        memo[id(self.victim_policy)] = self.victim_policy
        memo[id(self.barrier_snapshots)] = []
        if self.replanner is not None:
            memo[id(self.replanner)] = self.replanner
        if self.obs is not None:
            # Shared, not copied: ``resume()`` on a snapshot appends its
            # suffix events to the same recorder (so replayed spans appear
            # twice if the original run also completed — detach obs before
            # resuming when that matters).
            memo[id(self.obs)] = self.obs
        traces = [t.trace for t in self._arrivals]
        traces += [t.trace for t in self._waiting]
        traces += [r.trace for r in self._running]
        for tr in traces:
            memo[id(tr)] = tr
            if tr.op_times is not None:
                memo[id(tr.op_times)] = tr.op_times
            if tr.op_costs is not None:
                memo[id(tr.op_costs)] = tr.op_costs
        snap = copy.deepcopy(self, memo)
        snap.barrier_snapshots = []
        snap.capture_snapshots = False
        snap._snapshot_due = False
        return snap

    def _probe_clone(self) -> "MemoryRuntime":
        """A what-if copy for candidate probing (``repro.tune``).

        Like ``_snapshot`` but detached from everything a probe must not
        touch: no observer (the live recorder would otherwise collect the
        probe's phantom events through the runs' cached ``_obs`` hooks), the
        *default* victim policy (a simulation-probing policy re-probing
        inside its own probes would recurse), and no event recording for
        tenants admitted during the probe.  Each call clones the live
        engine's pristine state, so sibling candidate probes at the same
        decision point can never observe each other's staged reservations.
        """
        snap = self._snapshot()
        snap.obs = None
        for r in snap._running:
            r._obs = None
        snap.victim_policy = FloorGreedyVictim()
        snap._tune_due = False
        snap.record_events = False
        return snap

    def _loop(self) -> None:
        heap = self._event_heap
        while self._arrivals or self._waiting or self._running:
            if self._snapshot_due:
                self._snapshot_due = False
                self.barrier_snapshots.append(self._snapshot())
                if (self.max_snapshots is not None
                        and len(self.barrier_snapshots) > self.max_snapshots):
                    # Ring buffer: drop the oldest barrier.  resume() can
                    # then only replay suffixes from the newest N barriers.
                    del self.barrier_snapshots[0]
            if self._tune_due:
                # Deferred victim staging: the loop top is a consistent
                # between-events point (every unfinished running tenant has a
                # frontier entry), so a probing policy can snapshot + resume
                # candidate suffixes here.
                self._tune_due = False
                self._stage_victim()
            if not self._running:
                if self._arrivals:
                    # Idle gap: jump the clock to the next arrival.
                    self._drain_arrivals(self._arrivals[0].arrival_t)
                else:
                    # Waiting only: nothing is reserved, so the head either
                    # admits now or is unschedulable outright.
                    self._try_admit(self._now)
                continue
            t_event, seq, run = heapq.heappop(heap)
            if run.finished:
                continue  # stale entry: the tenant finished meanwhile
            if run.t != t_event:
                heapq.heappush(heap, (run.t, seq, run))
                continue  # stale entry: the tenant's clock moved
            # Arrivals at or before this run's clock strictly precede its
            # next op (and may admit a tenant with an earlier clock).
            before = len(self._running)
            self._drain_arrivals(run.t)
            if len(self._running) != before:
                heapq.heappush(heap, (run.t, seq, run))
                continue  # the time frontier changed; re-pick the next event
            self._events += 1
            if run.step():
                # Process arrivals that landed inside the op the step just
                # executed *before* exposing the freed reservation: the
                # release happens at run.t, after those arrivals.
                self._drain_arrivals(run.t)
                self._finish(run)
            else:
                heapq.heappush(heap, (run.t, seq, run))

    def _link_dict(self) -> dict | None:
        if self.link is None:
            return None
        d = {
            "total_bw": self.link.total_bw,
            "lanes": self.link.lanes,
            "lane_bw": self.link.lane_bw,
            "bytes_moved": self.link.bytes_moved,
            "transfers": self.link.transfers,
            "blackout_s": self.link.blackout_s,
        }
        if self.link.out_lane_ids is not None:
            # Extra keys only on directionally-partitioned links: the default
            # shared-pool report must stay bit-identical to the frozen
            # reference engine's.
            d["out_lanes"] = len(self.link.out_lane_ids)
            d["in_lanes"] = len(self.link.in_lane_ids)
            d["wait_in_s"] = self.link.wait_in_s
            d["wait_out_s"] = self.link.wait_out_s
            d["bytes_in"] = self.link.bytes_in
            d["bytes_out"] = self.link.bytes_out
        return d

    def _final_report(self, order: list[str], wall_s: float) -> RuntimeReport:
        ordered = [self._reports[n] for n in order if n in self._reports]
        named_devices = sorted(d for d in self._accts if d is not None)
        attr_totals: dict[str, float] = {}
        for t in ordered:
            if t.attribution:
                for k, v in t.attribution.items():
                    attr_totals[k] = attr_totals.get(k, 0.0) + v
        return RuntimeReport(
            hardware=self.hw.name,
            budget=self.budget,
            channels=self.num_channels,
            tenants=ordered,
            # Sum of per-device-pool peaks: on the legacy single-pool shape
            # this is exactly the shared pool's aggregate peak.
            aggregate_peak=sum(a.aggregate_peak for a in self._accts.values()),
            overflow_events=sum(a.overflow_events for a in self._accts.values()),
            makespan_s=self._now,
            policy="renegotiate" if self.renegotiate else "fifo",
            renegotiations=self._reneg_applied,
            renegotiations_cancelled=self._reneg_cancelled,
            renegotiation_freed_bytes=self._reneg_freed,
            renegotiation_solve_ms=self._reneg_solve_ms,
            device_peaks=(
                {d: self._accts[d].aggregate_peak for d in named_devices}
                if named_devices
                else None
            ),
            link=self._link_dict(),
            engine={
                "events": self._events,
                "run_wall_s": wall_s,
                "events_per_s": self._events / wall_s if wall_s > 0 else 0.0,
                "solve_wall_s": self._reneg_solve_ms / 1e3,
            },
            attribution=attr_totals,
        )

    def run(self, tenants: Sequence[Tenant]) -> RuntimeReport:
        names = [t.name for t in tenants]
        if len(set(names)) != len(names):
            # The accountant, runs map and reports are keyed by name; two
            # tenants sharing one would silently merge their residency.
            raise ValueError(f"tenant names must be unique, got {names}")
        self._order = names
        # Stable sort: same-instant arrivals keep submission (FIFO) order.
        self._arrivals = deque(sorted(tenants, key=lambda t: t.arrival_t))
        self._waiting.clear()
        self._running = []
        self._reports = {}
        self._reserved = {}
        self._promised = {}
        self._now = 0.0
        self._event_heap = []
        self._events = 0
        self.barrier_snapshots = []
        self._snapshot_due = False
        self._tune_due = False
        t0 = time.perf_counter()
        self._loop()
        return self._final_report(self._order, time.perf_counter() - t0)

    def resume(self) -> RuntimeReport:
        """Finish the horizon from a barrier snapshot — suffix-only replay.

        Call on an element of a completed run's ``barrier_snapshots``: the
        snapshot holds the full engine state at the barrier where a
        renegotiated plan applied, so only the events *after* that barrier
        are re-simulated.  The returned report is byte-identical (modulo the
        wall-clock ``engine`` counters) to the full-horizon run's.
        """
        t0 = time.perf_counter()
        self._loop()
        return self._final_report(self._order, time.perf_counter() - t0)


# ------------------------------------------------------- single-tenant path
def simulate_program(
    trace: IterationTrace,
    decisions: Sequence[SwapDecision],
    hw: HardwareSpec,
    limit: int | None = None,
    channels: int = 2,
    prefetch: str = "backsched",
    record_events: bool = True,
) -> SimResult:
    """Replay one iteration of one program — the paper's simulator, now as a
    1-tenant run of the runtime engine.  ``channels=2, prefetch="eager"``
    reproduces ``core.simulator.simulate_swap_schedule`` exactly; other K
    values model narrower/wider DMA engines and ``backsched`` (default) is
    the runtime's just-in-time prefetch policy.

    ``floor=0`` disables admission control to match the legacy contract: an
    over-limit schedule runs (with delays), it is not queued.
    """
    rt = MemoryRuntime(hw, budget=limit, channels=channels, prefetch=prefetch,
                       record_events=record_events)
    tenant = Tenant("t0", trace, list(decisions), limit=limit, floor=0)
    rt.run([tenant])
    return rt.runs["t0"].sim_result()
