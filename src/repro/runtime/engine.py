"""Discrete-event memory runtime: N tenant programs, K DMA channels, one HBM.

This is the execution layer on top of the ``repro.plan`` IR.  The paper's
simulator (formerly the event loop inside ``core/simulator.py``) replayed ONE
iteration of ONE program with one serialized swap-out stream and one
serialized swap-in stream.  This module generalizes that loop along two axes:

* **Channels** — ``ChannelPool`` models K serialized DMA channels,
  direction-partitioned (K=1: a single shared bidirectional channel; K>=2:
  ceil(K/2) out + floor(K/2) in).  K=2 reproduces the paper's
  one-out/one-in streams exactly, which is how
  ``core.simulator.simulate_swap_schedule`` now delegates here.

* **Tenants** — ``MemoryRuntime`` admits N tenant programs (e.g. a prefill
  worker, a decode worker and a training job) against one shared HBM budget.
  Compute is per-tenant (each tenant owns its cores); HBM residency and DMA
  channels are shared.  Tenants are interleaved in global-time order: at each
  step the tenant with the smallest local clock executes its next op using
  the original simulator's per-op semantics (swap-in stall, delayed malloc,
  swap-out launch, deadline-ordered prefetch).

Shared-pool accounting (``PoolAccountant``) charges swap-in bytes at
*schedule* time, so the admission guard sees in-flight transfers on every
channel — with K in-channels two prefetches can no longer both be admitted
into headroom that only fits one (the double-admission hazard a single
serialized in-stream never exposed).

Admission control: a tenant whose resident floor (planned peak under its
swap schedule) does not fit in the unreserved budget is queued FIFO, not
OOM-killed; it starts when a finishing tenant releases its reservation.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Sequence

from ..core.events import IterationTrace
from ..core.simulator import HardwareSpec, SimResult, SwapDecision, assign_times


# ----------------------------------------------------------------- channels
@dataclass
class ChannelPool:
    """K serialized DMA channels, direction-partitioned.

    K=1 degrades to a single bidirectional channel (out and in transfers
    contend); K>=2 splits ceil(K/2) channels for swap-out and the rest for
    swap-in, each direction load-balanced onto its earliest-free channel.
    """

    num_channels: int
    free_at: list[float]
    out_ids: tuple[int, ...]
    in_ids: tuple[int, ...]

    @classmethod
    def make(cls, k: int) -> "ChannelPool":
        k = max(1, int(k))
        if k == 1:
            out_ids = in_ids = (0,)
        else:
            split = (k + 1) // 2
            out_ids = tuple(range(split))
            in_ids = tuple(range(split, k))
        return cls(k, [0.0] * k, out_ids, in_ids)

    def acquire(self, direction: str, ready_t: float, duration: float) -> tuple[float, float, int]:
        """Reserve the earliest-free channel of `direction`; returns (start, end, channel)."""
        ids = self.out_ids if direction == "out" else self.in_ids
        ch = min(ids, key=lambda c: self.free_at[c])
        start = max(ready_t, self.free_at[ch])
        end = start + duration
        self.free_at[ch] = end
        return start, end, ch

    def drain_time(self, direction: str) -> float:
        ids = self.out_ids if direction == "out" else self.in_ids
        return max(self.free_at[c] for c in ids)


# --------------------------------------------------------------- accounting
@dataclass
class PoolAccountant:
    """Shared-HBM accountant: per-tenant resident bytes against one budget.

    Swap-in bytes are charged when the transfer is *scheduled* (reservation),
    not when it completes, so ``fits()`` sees in-flight swap-ins on all
    channels and the engine cannot double-admit into the same headroom.
    ``overflow_events`` counts forced over-budget charges (late swap-ins at
    an access deadline, mallocs with no pending swap-out to wait for) — zero
    on a well-provisioned tenant set.
    """

    budget: int | None = None
    resident: dict[str, int] = field(default_factory=dict)
    peak: dict[str, int] = field(default_factory=dict)
    total: int = 0
    aggregate_peak: int = 0
    overflow_events: int = 0

    def add(self, tenant: str, nbytes: int) -> None:
        self.resident[tenant] = self.resident.get(tenant, 0) + nbytes
        self.total += nbytes
        if nbytes > 0 and self.budget is not None and self.total > self.budget:
            self.overflow_events += 1

    def fits(self, nbytes: int) -> bool:
        return self.budget is None or self.total + nbytes <= self.budget

    def mark_peak(self, tenant: str) -> None:
        r = self.resident.get(tenant, 0)
        if r > self.peak.get(tenant, 0):
            self.peak[tenant] = r
        if self.total > self.aggregate_peak:
            self.aggregate_peak = self.total


# ------------------------------------------------------------------ tenants
@dataclass
class Tenant:
    """One program admitted to the runtime: a trace + its swap schedule.

    ``limit`` is the HBM target the schedule was solved for (used for
    isolated-baseline comparisons; the shared budget governs execution).
    ``floor`` is the admission-control reservation — the planned peak
    resident bytes under the schedule; computed from the trace when None.
    """

    name: str
    trace: IterationTrace
    decisions: list[SwapDecision] = field(default_factory=list)
    limit: int | None = None
    floor: int | None = None
    iterations: int = 1

    def resident_floor(self) -> int:
        if self.floor is None:
            self.floor = planned_peak(self.trace, self.decisions)
        return self.floor


def planned_peak(trace: IterationTrace, decisions: Sequence[SwapDecision]) -> int:
    """Peak of the load curve with the schedule's absence windows subtracted —
    the minimum HBM a tenant needs resident if every transfer lands on time."""
    curve = trace.load_curve()
    n = len(curve)
    for d in decisions:
        if d.wraps:
            spans = (range(0, min(d.in_before, n)), range(min(d.out_after, n), n))
        else:
            spans = (range(min(d.out_after, n), min(d.in_before, n)),)
        for span in spans:
            for i in span:
                curve[i] -= d.size
    return max(curve) if curve else 0


@dataclass
class _PendingOut:
    done_t: float
    owner: "_TenantRun"
    var: int
    size: int


class _TenantRun:
    """Per-tenant replay state: the original simulator loop, one op at a time,
    against the shared channel pool / accountant."""

    def __init__(self, tenant: Tenant, hw: HardwareSpec, engine: "MemoryRuntime", admit_t: float):
        self.tenant = tenant
        self.name = tenant.name
        self.hw = hw
        self.engine = engine
        trace = tenant.trace
        if trace.op_times is None:
            assign_times(trace, hw)
        self.trace = trace
        self.costs = trace.op_costs or {}
        self.baseline_s = trace.op_times[-1]
        self.decisions = list(tenant.decisions)
        self.iterations = max(1, tenant.iterations)
        self.floor = tenant.resident_floor()

        self.out_at: dict[int, list[SwapDecision]] = {}
        self.in_at: dict[int, list[SwapDecision]] = {}
        for d in self.decisions:
            self.out_at.setdefault(d.out_after, []).append(d)
            self.in_at.setdefault(d.in_before, []).append(d)

        n = trace.num_indices
        self.delta = [0] * (n + 1)
        self.malloc_size_at: dict[int, int] = {}
        for v in trace.variables:
            self.delta[v.alloc_index] += v.size
            self.malloc_size_at[v.alloc_index] = v.size
            if v.free_index <= n:
                self.delta[v.free_index] -= v.size

        self.bt = trace.op_times  # baseline schedule, for prefetch back-scheduling

        self.admit_t = admit_t
        self.t = admit_t
        self.i = 0
        self.iter_no = 0
        self.stalls = 0
        self.delayed = 0
        self.out_events: list[tuple[int, float, float, int]] = []
        self.in_events: list[tuple[int, float, float, int]] = []
        self.in_done: dict[int, float] = {}
        self.out_done: dict[int, float] = {}
        self.finished = False
        self._begin_iteration()

    # ------------------------------------------------------------ plumbing
    def _transfer(self, size: int) -> float:
        return size / self.hw.link_bw

    def _op_dur(self, i: int) -> float:
        flops, nbytes = self.costs.get(i, (0.0, 0.0))
        if flops or nbytes:
            return max(flops / self.hw.eff_flops, nbytes / self.hw.hbm_bw) + self.hw.op_overhead_s
        return 0.0

    def _due(self, d: SwapDecision, i: int, need: float) -> bool:
        """Back-scheduling: is it time to start this swap-in?

        The transfer is due at the last op boundary where the baseline compute
        remaining before its deadline access still covers the transfer time —
        deferring one more op would make it late.  Actual compute only runs
        slower than baseline (stalls, delayed mallocs), so a transfer started
        on the baseline schedule never misses an on-time deadline; only
        channel contention can push it late.
        """
        bt = self.bt
        nxt = min(i + 1, len(bt) - 1)
        slack = bt[d.in_before] - bt[nxt]
        return slack - self._op_dur(nxt) < need

    def _begin_iteration(self) -> None:
        self.in_done = {}
        self.out_done = {}
        # Wrap decisions: in steady state the variable is already on the host
        # when the iteration starts (swapped out during the previous tail).
        for d in self.decisions:
            if d.wraps:
                self.engine.acct.add(self.name, -d.size)
                self.out_done[d.var] = self.t
        self.i = 0

    def _end_iteration(self) -> bool:
        """Close one iteration; True when the whole tenant is finished."""
        self.iter_no += 1
        if self.iter_no >= self.iterations:
            return True
        # Iteration barrier for multi-iteration replay: drain this tenant's
        # in-flight transfers and reset its residency to zero so the next
        # iteration's deltas (which re-count persistent variables at index 0)
        # don't double-charge the accountant.
        acct = self.engine.acct
        for rec in [r for r in self.engine.pending_outs if r.owner is self]:
            self.t = max(self.t, rec.done_t)
            self.engine.pending_outs.remove(rec)
            acct.add(self.name, -rec.size)
        if self.in_done:
            self.t = max(self.t, max(self.in_done.values()))
        acct.add(self.name, -acct.resident.get(self.name, 0))
        self._begin_iteration()
        return False

    # ---------------------------------------------------------------- step
    def step(self) -> bool:
        """Execute the next op; returns True when the tenant has finished."""
        if self.i >= self.trace.num_indices:
            # Degenerate empty trace.
            self.finished = self._end_iteration()
            return self.finished
        i = self.i
        acct = self.engine.acct
        chans = self.engine.channels

        # 1. If this op needs a swapped variable back, wait for its swap-in.
        for d in self.in_at.get(i, ()):
            if d.var not in self.in_done:
                # Should have been prefetched; schedule now (late prefetch).
                # Still charged at schedule time so concurrent channels see it.
                ready = max(self.t, self.out_done.get(d.var, 0.0))
                start, end, ch = chans.acquire("in", ready, self._transfer(d.size))
                self.in_done[d.var] = end
                acct.add(self.name, d.size)
                self.in_events.append((d.var, start, end, ch))
            if self.in_done[d.var] > self.t:
                self.stalls += 1
                self.t = self.in_done[d.var]

        # 2. Budget enforcement on mallocs (paper: delay the Malloc).  Any
        # tenant's pending swap-out frees shared headroom, so the wait is on
        # the globally earliest completion.
        if self.engine.budget is not None and self.delta[i] > 0 and i in self.malloc_size_at:
            while not acct.fits(self.delta[i]) and self.engine.pending_outs:
                rec = min(self.engine.pending_outs, key=lambda r: r.done_t)
                self.engine.pending_outs.remove(rec)
                if rec.done_t > self.t:
                    self.delayed += 1
                    self.t = rec.done_t
                acct.add(rec.owner.name, -rec.size)
        acct.add(self.name, self.delta[i])
        acct.mark_peak(self.name)

        # 3. Execute the op (compute is per-tenant; only memory is shared).
        self.t += self._op_dur(i)

        # 4. Launch swap-outs whose trigger access just completed.
        for d in self.out_at.get(i, ()):
            start, end, ch = chans.acquire("out", self.t, self._transfer(d.size))
            self.out_done[d.var] = end
            self.engine.pending_outs.append(_PendingOut(end, self, d.var, d.size))
            self.out_events.append((d.var, start, end, ch))

        # 5. Retire this tenant's completed swap-outs (frees resident bytes).
        for rec in [r for r in self.engine.pending_outs if r.owner is self and r.done_t <= self.t]:
            self.engine.pending_outs.remove(rec)
            acct.add(self.name, -rec.size)

        # 6. Prefetch swapped-out variables back, nearest deadline first.
        # Policy "eager" (the legacy simulator): keep the in-channels busy as
        # soon as data is out and the budget allows it back.  Policy
        # "backsched" (runtime default): start each swap-in just-in-time from
        # its deadline, so readmitted bytes don't crowd the budget that
        # compute mallocs need in the meantime — eager prefetch over fast
        # channels otherwise *increases* malloc delays (scheduling anomaly).
        # Either way a budget-blocked head-of-line transfer stops this
        # tenant's prefetching until room appears — and because bytes are
        # reserved at schedule time in steps 1/6, a second in-channel can
        # never admit into the same headroom.
        upcoming = sorted(
            (d for d in self.decisions
             if d.var in self.out_done and d.var not in self.in_done and d.in_before > i),
            key=lambda d: d.in_before,
        )
        for d in upcoming:
            if self.engine.budget is not None and not acct.fits(d.size):
                break
            if self.engine.prefetch == "backsched" and not self._due(d, i, self._transfer(d.size)):
                continue
            start, end, ch = chans.acquire(
                "in", max(self.t, self.out_done[d.var]), self._transfer(d.size)
            )
            self.in_done[d.var] = end
            acct.add(self.name, d.size)
            acct.mark_peak(self.name)
            self.in_events.append((d.var, start, end, ch))

        self.i += 1
        if self.i >= self.trace.num_indices:
            self.finished = self._end_iteration()
        return self.finished

    def release_residency(self) -> None:
        """Free everything this tenant still has charged to the accountant.

        Called when the tenant finishes: persistent variables (freed at
        ``delta[num_indices]``, which the op loop never applies) and any
        in-flight tail swap-outs would otherwise stay charged to the shared
        pool forever, starving later-admitted tenants.
        """
        acct = self.engine.acct
        for rec in [r for r in self.engine.pending_outs if r.owner is self]:
            self.engine.pending_outs.remove(rec)
            acct.add(self.name, -rec.size)
        acct.add(self.name, -acct.resident.get(self.name, 0))

    # ------------------------------------------------------------- results
    def sim_result(self) -> SimResult:
        res = SimResult(
            baseline_s=self.baseline_s * self.iterations,
            duration_s=self.t - self.admit_t,
            peak_resident=self.engine.acct.peak.get(self.name, 0),
            stalls=self.stalls,
            delayed_mallocs=self.delayed,
            tail_spill_s=max(0.0, self.engine.channels.drain_time("out") - self.t),
            out_events=[(v, s, e) for v, s, e, _ in self.out_events],
            in_events=[(v, s, e) for v, s, e, _ in self.in_events],
        )
        return res


# ------------------------------------------------------------------ reports
@dataclass
class TenantReport:
    name: str
    status: str                     # "completed" | "unschedulable"
    baseline_s: float
    duration_s: float               # compute span, excluding queue wait
    overhead: float
    peak_resident: int
    floor: int
    stalls: int
    delayed_mallocs: int
    admitted_at: float
    finished_at: float
    queue_wait_s: float

    def as_dict(self) -> dict:
        return self.__dict__.copy()


@dataclass
class RuntimeReport:
    hardware: str
    budget: int | None
    channels: int
    tenants: list[TenantReport]
    aggregate_peak: int
    overflow_events: int
    makespan_s: float

    def tenant(self, name: str) -> TenantReport:
        for t in self.tenants:
            if t.name == name:
                return t
        raise KeyError(name)

    def as_dict(self) -> dict:
        return {
            "hardware": self.hardware,
            "budget": self.budget,
            "channels": self.channels,
            "tenants": [t.as_dict() for t in self.tenants],
            "aggregate_peak": self.aggregate_peak,
            "overflow_events": self.overflow_events,
            "makespan_s": self.makespan_s,
        }


# ------------------------------------------------------------------- engine
class MemoryRuntime:
    """Co-schedules N tenant programs over K DMA channels under one budget."""

    def __init__(
        self,
        hw: HardwareSpec,
        budget: int | None = None,
        channels: int = 2,
        prefetch: str = "backsched",
    ):
        if prefetch not in ("backsched", "eager"):
            raise ValueError(f"unknown prefetch policy {prefetch!r}")
        self.hw = hw
        self.budget = budget
        self.num_channels = channels
        self.prefetch = prefetch
        self.channels = ChannelPool.make(channels)
        self.acct = PoolAccountant(budget)
        self.pending_outs: list[_PendingOut] = []
        self.runs: dict[str, _TenantRun] = {}

    def run(self, tenants: Sequence[Tenant]) -> RuntimeReport:
        names = [t.name for t in tenants]
        if len(set(names)) != len(names):
            # The accountant, runs map and reports are keyed by name; two
            # tenants sharing one would silently merge their residency.
            raise ValueError(f"tenant names must be unique, got {names}")
        queue: deque[Tenant] = deque(tenants)
        running: list[_TenantRun] = []
        reports: dict[str, TenantReport] = {}
        order = [t.name for t in tenants]
        reserved = 0
        now = 0.0

        def try_admit() -> None:
            nonlocal reserved
            while queue:
                cand = queue[0]
                floor = cand.resident_floor()
                if self.budget is not None and floor > self.budget:
                    # Can never fit, even alone: report, do not OOM-kill others.
                    queue.popleft()
                    reports[cand.name] = TenantReport(
                        name=cand.name, status="unschedulable", baseline_s=0.0,
                        duration_s=0.0, overhead=0.0, peak_resident=0, floor=floor,
                        stalls=0, delayed_mallocs=0, admitted_at=-1.0,
                        finished_at=-1.0, queue_wait_s=0.0,
                    )
                    continue
                if self.budget is not None and reserved + floor > self.budget:
                    return  # FIFO: wait for a running tenant to release floor
                queue.popleft()
                reserved += floor
                run = _TenantRun(cand, self.hw, self, admit_t=now)
                self.runs[cand.name] = run
                running.append(run)

        try_admit()
        while running:
            run = min(running, key=lambda r: r.t)
            if run.step():
                running.remove(run)
                reserved -= run.floor
                run.release_residency()
                now = max(now, run.t)
                dur = run.t - run.admit_t
                base = run.baseline_s * run.iterations
                reports[run.name] = TenantReport(
                    name=run.name, status="completed", baseline_s=base,
                    duration_s=dur,
                    overhead=max(0.0, (dur - base) / base) if base > 0 else 0.0,
                    peak_resident=self.acct.peak.get(run.name, 0),
                    floor=run.floor, stalls=run.stalls,
                    delayed_mallocs=run.delayed, admitted_at=run.admit_t,
                    finished_at=run.t, queue_wait_s=run.admit_t,
                )
                try_admit()

        ordered = [reports[n] for n in order if n in reports]
        return RuntimeReport(
            hardware=self.hw.name,
            budget=self.budget,
            channels=self.num_channels,
            tenants=ordered,
            aggregate_peak=self.acct.aggregate_peak,
            overflow_events=self.acct.overflow_events,
            makespan_s=now,
        )


# ------------------------------------------------------- single-tenant path
def simulate_program(
    trace: IterationTrace,
    decisions: Sequence[SwapDecision],
    hw: HardwareSpec,
    limit: int | None = None,
    channels: int = 2,
    prefetch: str = "backsched",
) -> SimResult:
    """Replay one iteration of one program — the paper's simulator, now as a
    1-tenant run of the runtime engine.  ``channels=2, prefetch="eager"``
    reproduces ``core.simulator.simulate_swap_schedule`` exactly; other K
    values model narrower/wider DMA engines and ``backsched`` (default) is
    the runtime's just-in-time prefetch policy.

    ``floor=0`` disables admission control to match the legacy contract: an
    over-limit schedule runs (with delays), it is not queued.
    """
    rt = MemoryRuntime(hw, budget=limit, channels=channels, prefetch=prefetch)
    tenant = Tenant("t0", trace, list(decisions), limit=limit, floor=0)
    rt.run([tenant])
    return rt.runs["t0"].sim_result()
