"""Mamba-2 SSD chunked scan as a Pallas TPU kernel.

Grid is (batch*heads, chunks) with the chunk axis "arbitrary": the running
[P, N] SSM state lives in VMEM scratch and is carried across chunk steps,
while each step does the intra-chunk quadratic work on the MXU:

    cum   = cumsum(dA)                       [c]
    y     = (C B^T  *  exp(cum_i - cum_j) tril) @ (x*dt)   intra-chunk
          + exp(cum) * (C @ state^T)                        inter-chunk
    state = state * exp(cum[-1]) + (x*dt)^T @ (B * exp(cum[-1]-cum))

The wrapper pre-computes dA = dt*A[h] and xdt = x*dt so the kernel streams
only [c,P]/[c,N]/[c] tiles; groups are broadcast to heads via the B/C index
map (no duplication in VMEM).  Oracle: ref.ssd_reference (sequential
recurrence).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .flash_attention import _tpu_params, _vmem


def _ssd_kernel(xdt_ref, dA_ref, b_ref, c_ref, y_ref, state_scr, *, chunk: int):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    xdt = xdt_ref[0].astype(jnp.float32)       # [c, P]
    dA = dA_ref[0].astype(jnp.float32)         # [c] (as [c, 1] lane layout)
    bm = b_ref[0].astype(jnp.float32)          # [c, N]
    cm = c_ref[0].astype(jnp.float32)          # [c, N]

    cum = jnp.cumsum(dA)                       # [c]
    diff = cum[:, None] - cum[None, :]         # [c, c]
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(tri, jnp.exp(diff), 0.0)
    CB = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [c, c]
    y = jax.lax.dot_general(CB * L, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # [c, P]

    state = state_scr[...]                     # [P, N]
    y += jnp.exp(cum)[:, None] * jax.lax.dot_general(
        cm, state, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                          # [c, N] @ [N, P]^T -> [c, P]

    decay_to_end = jnp.exp(cum[-1] - cum)      # [c]
    new_state = state * jnp.exp(cum[-1]) + jax.lax.dot_general(
        xdt, bm * decay_to_end[:, None], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                          # [P, N]
    state_scr[...] = new_state
    y_ref[0] = y.astype(y_ref.dtype)


def ssd_scan(x, dt, A, Bm, Cm, *, chunk: int = 64, interpret: bool | None = None):
    """x [b,s,h,p], dt [b,s,h] (post-softplus), A [h] (<0), Bm/Cm [b,s,g,n].

    Returns y [b,s,h,p].  s % chunk == 0 required (ops.py guards)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, s, h, p = x.shape
    g, n = Bm.shape[2], Bm.shape[3]
    rep = h // g
    nc = s // chunk
    assert nc * chunk == s, (s, chunk)

    xdt = (x * dt[..., None]).transpose(0, 2, 1, 3).reshape(b * h, s, p)
    dA = (dt * A).transpose(0, 2, 1).reshape(b * h, s)
    bm = Bm.transpose(0, 2, 1, 3).reshape(b * g, s, n)
    cm = Cm.transpose(0, 2, 1, 3).reshape(b * g, s, n)

    grid = (b * h, nc)
    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    y = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, chunk), lambda i, c: (i, c)),
            pl.BlockSpec((1, chunk, n), lambda i, c: ((i // rep) if rep > 1 else i, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda i, c: ((i // rep) if rep > 1 else i, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, p), lambda i, c: (i, c, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, p), x.dtype),
        scratch_shapes=[_vmem((p, n), jnp.float32)],
        interpret=interpret,
        compiler_params=_tpu_params_2d(),
    )(xdt, dA, bm, cm)
    return y.reshape(b, h, s, p).transpose(0, 2, 1, 3)


def _tpu_params_2d():
    try:
        from jax.experimental.pallas import tpu as pltpu

        return pltpu.CompilerParams(dimension_semantics=("parallel", "arbitrary"))
    except Exception:  # pragma: no cover
        return None
