"""Flash attention (forward) as a Pallas TPU kernel.

TPU-native adaptation of blockwise attention: q/k/v tiles live in VMEM via
BlockSpec, the MXU consumes (block_q x head_dim) @ (head_dim x block_k)
tiles, and the online-softmax running state (m, l, acc) persists in VMEM
scratch across the k-block grid dimension (the "arbitrary" innermost axis).

Features needed by the assigned architectures:
  * causal masking with whole-block skipping (upper-triangle blocks never
    enter the MXU — true FLOP savings, not masking),
  * sliding-window attention with both-side block skipping (gemma2/3, hymba),
  * logit softcap (gemma2),
  * GQA via the kv-head index map (no K/V duplication in VMEM).

Block sizes default to 512x512 (bq*hd + 2*bk*hd + bq*bk fp32 tiles fit
comfortably in ~16 MiB VMEM for hd <= 256; MXU dims are multiples of 128).

Validated against ref.mha_reference under interpret=True (CPU) over shape/
dtype/flag sweeps in tests/test_kernels.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, scale: float, causal: bool, window: int | None, softcap: float | None,
    block_q: int, block_k: int, num_k_blocks: int,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # ---- whole-block skip predicates (computed on grid indices) ----
    q_lo = iq * block_q
    q_hi = q_lo + block_q - 1
    k_lo = ik * block_k
    k_hi = k_lo + block_k - 1
    live = jnp.bool_(True)
    if causal:
        live &= k_lo <= q_hi              # block not entirely in the future
    if window is not None:
        live &= q_lo - k_hi < window      # block not entirely out of window

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # [bq, hd]
        k = k_ref[0, 0].astype(jnp.float32)          # [bk, hd]
        v = v_ref[0, 0].astype(jnp.float32)          # [bk, hd]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                     # [bq, bk]
        if softcap:
            s = softcap * jnp.tanh(s / softcap)

        q_pos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        k_pos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask &= q_pos >= k_pos
        if window is not None:
            mask &= q_pos - k_pos < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                           # [bq, 128] (lane-bcast)
        m_cur = jnp.max(s, axis=1, keepdims=True)     # [bq, 1]
        m_new = jnp.maximum(m_prev, jnp.broadcast_to(m_cur, m_prev.shape))
        corr = jnp.exp(m_prev - m_new)                # [bq, 128]
        p = jnp.exp(s - m_new[:, :1])                 # [bq, bk]
        l_scr[...] = l_scr[...] * corr + jnp.broadcast_to(
            jnp.sum(p, axis=1, keepdims=True), l_scr.shape
        )
        acc_scr[...] = acc_scr[...] * corr[:, :1] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = m_new

    @pl.when(ik == num_k_blocks - 1)
    def _finalize():
        l = l_scr[:, :1]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_scr[...] / safe).astype(o_ref.dtype)


def flash_attention(
    q, k, v,
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    scale: float | None = None,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool | None = None,
):
    """q [B,Sq,H,hd], k/v [B,Sk,KV,hd] -> [B,Sq,H,hd].

    Requires Sq % block_q == 0 and Sk % block_k == 0 (ops.py picks divisors
    or falls back to the reference).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, Sk, block_q, block_k)
    G = H // KV
    scale = scale if scale is not None else hd**-0.5

    qt = q.transpose(0, 2, 1, 3)  # [B,H,Sq,hd]
    kt = k.transpose(0, 2, 1, 3)  # [B,KV,Sk,hd]
    vt = v.transpose(0, 2, 1, 3)
    nq, nk = Sq // block_q, Sk // block_k
    grid = (B, H, nq, nk)

    kernel = functools.partial(
        _flash_kernel,
        scale=scale, causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_k=block_k, num_k_blocks=nk,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, iq, ik: (b, h // G if G > 1 else h, ik, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, iq, ik: (b, h // G if G > 1 else h, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[
            pl.MemorySpace.ANY if False else _vmem((block_q, 128), jnp.float32),
            _vmem((block_q, 128), jnp.float32),
            _vmem((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=_tpu_params(),
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)


def _vmem(shape, dtype):
    try:
        from jax.experimental.pallas import tpu as pltpu

        return pltpu.VMEM(shape, dtype)
    except Exception:  # interpret-only environments
        return pl.MemorySpace.ANY  # pragma: no cover


def _tpu_params():
    try:
        from jax.experimental.pallas import tpu as pltpu

        return pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        )
    except Exception:  # pragma: no cover
        return None
