"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def mha_reference(q, k, v, *, causal=True, window=None, softcap=None, scale=None):
    """q [B,Sq,H,hd], k/v [B,Sk,KV,hd] -> [B,Sq,H,hd]."""
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    G = H // KV
    scale = scale if scale is not None else hd**-0.5
    kh = jnp.repeat(k, G, axis=2) if G > 1 else k
    vh = jnp.repeat(v, G, axis=2) if G > 1 else v
    s = jnp.einsum("bqhd,bshd->bhqs", q.astype(jnp.float32), kh.astype(jnp.float32)) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= q_pos - k_pos < window
    s = jnp.where(mask[None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqs,bshd->bqhd", w, vh.astype(jnp.float32)).astype(q.dtype)


def ssd_reference(x, dt, A, Bm, Cm):
    """Sequential SSD recurrence (the definitionally-correct oracle).

    x [b,s,h,p]; dt [b,s,h] (>0, post-softplus); A [h] (<0);
    Bm/Cm [b,s,g,n].  Returns y [b,s,h,p].

      state_t = state_{t-1} * exp(dt_t A) + dt_t * B_t x_t^T
      y_t     = C_t . state_t
    """
    b, s, h, p = x.shape
    g, n = Bm.shape[2], Bm.shape[3]
    rep = h // g
    Bh = jnp.repeat(Bm, rep, axis=2)  # [b,s,h,n]
    Ch = jnp.repeat(Cm, rep, axis=2)

    def step(state, inp):
        xt, dtt, bt, ct = inp  # [b,h,p], [b,h], [b,h,n], [b,h,n]
        dA = jnp.exp(dtt * A)  # [b,h]
        state = state * dA[..., None, None] + jnp.einsum(
            "bhp,bhn,bh->bhpn", xt, bt, dtt
        )
        y = jnp.einsum("bhpn,bhn->bhp", state, ct)
        return state, y

    xs = (
        x.transpose(1, 0, 2, 3).astype(jnp.float32),
        dt.transpose(1, 0, 2).astype(jnp.float32),
        Bh.transpose(1, 0, 2, 3).astype(jnp.float32),
        Ch.transpose(1, 0, 2, 3).astype(jnp.float32),
    )
    state0 = jnp.zeros((b, h, p, n), jnp.float32)
    _, ys = jax.lax.scan(step, state0, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype)


def rmsnorm_reference(x, scale, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = (xf * xf).mean(-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)
