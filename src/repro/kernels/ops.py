"""jit'd public wrappers for the Pallas kernels with shape guards.

Each op validates divisibility constraints, picks block sizes, and falls
back to the ref.py oracle when the kernel's tiling preconditions don't hold
(e.g. whisper's 1500-frame encoder, tiny smoke shapes) — callers never have
to care.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import ref
from .flash_attention import flash_attention
from .rmsnorm import rmsnorm as _rmsnorm_kernel_op
from .ssd_scan import ssd_scan


def _pick_block(s: int, prefer=(512, 256, 128)) -> int | None:
    for b in prefer:
        if s % b == 0:
            return b
    return None


@partial(jax.jit, static_argnames=("causal", "window", "softcap", "scale"))
def flash_mha(q, k, v, *, causal=True, window=None, softcap=None, scale=None):
    """Blockwise attention; kernel when tiles fit, oracle otherwise."""
    Sq, Sk, hd = q.shape[1], k.shape[1], q.shape[-1]
    bq, bk = _pick_block(Sq), _pick_block(Sk)
    if bq is None or bk is None or hd % 64 or hd > 256:
        return ref.mha_reference(
            q, k, v, causal=causal, window=window, softcap=softcap, scale=scale
        )
    return flash_attention(
        q, k, v, causal=causal, window=window, softcap=softcap, scale=scale,
        block_q=bq, block_k=bk,
    )


@partial(jax.jit, static_argnames=("chunk",))
def ssd(x, dt, A, Bm, Cm, *, chunk=64):
    if x.shape[1] % chunk:
        return ref.ssd_reference(x, dt, A, Bm, Cm)
    return ssd_scan(x, dt, A, Bm, Cm, chunk=chunk)


@partial(jax.jit, static_argnames=("eps",))
def fused_rmsnorm(x, scale, *, eps=1e-6):
    return _rmsnorm_kernel_op(x, scale, eps=eps)
