"""Fused RMSNorm Pallas kernel: one VMEM pass per row block.

Unfused XLA does (square -> mean -> rsqrt -> mul -> mul) as separate HBM
round-trips when fusion fails across reshapes; the kernel reads each row
once and writes once (2x d_model bytes per row, the HBM floor).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps) * s_ref[...].astype(jnp.float32)).astype(
        o_ref.dtype
    )


def rmsnorm(x, scale, *, eps: float = 1e-6, block_rows: int = 256,
            interpret: bool | None = None):
    """x [..., D], scale [D] -> same shape/dtype as x."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    orig_shape = x.shape
    d = x.shape[-1]
    rows = 1
    for s in x.shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    br = min(block_rows, rows)
    while rows % br:
        br //= 2
    br = max(br, 1)
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(x2, scale)
    return out.reshape(orig_shape)
