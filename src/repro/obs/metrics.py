"""Lightweight metrics registry: named counters/gauges + a JSONL sink.

No dependencies, no background threads, no label cardinality machinery —
just enough structure that every subsystem increments the same named series
and one ``append_jsonl`` call lands a machine-readable sample on disk.
Names are dotted paths (``engine.transfers.in``); the snapshot is a flat
``{name: value}`` dict, so a run's JSONL history diffs and plots trivially.

The registry is deliberately *not* wired into the engine hot path directly:
``ObsRecorder`` owns one and folds its event hooks into counter updates, so
with no recorder attached the hot path never touches a metric.
"""

from __future__ import annotations

import json
import time


class Counter:
    """Monotone accumulator.  ``inc`` only; resets come from a new registry."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n


class Gauge:
    """Last-written value, with a convenience running max."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = v

    def set_max(self, v: float) -> None:
        if v > self.value:
            self.value = v


class MetricsRegistry:
    """Get-or-create registry of counters and gauges.

    A name is either a counter or a gauge for the registry's lifetime;
    asking for the other kind under the same name raises, which catches the
    typo before it silently forks the series.
    """

    def __init__(self) -> None:
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}

    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            if name in self._gauges:
                raise ValueError(f"{name!r} is already registered as a gauge")
            c = self._counters[name] = Counter(name)
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            if name in self._counters:
                raise ValueError(f"{name!r} is already registered as a counter")
            g = self._gauges[name] = Gauge(name)
        return g

    def snapshot(self) -> dict:
        """Flat ``{name: value}`` over both kinds, sorted by name."""
        out = {n: c.value for n, c in self._counters.items()}
        out.update({n: g.value for n, g in self._gauges.items()})
        return dict(sorted(out.items()))

    def append_jsonl(self, path: str, extra: dict | None = None) -> dict:
        """Append one ``{"written_at": ..., "metrics": {...}}`` line to
        ``path`` (created if missing).  ``extra`` merges into the record
        top-level — run identifiers, bench cell names, and so on.  Returns
        the record written."""
        record = {
            "written_at": time.strftime("%Y-%m-%dT%H:%M:%S%z", time.localtime()),
            "metrics": self.snapshot(),
        }
        if extra:
            record.update(extra)
        with open(path, "a") as f:
            f.write(json.dumps(record, sort_keys=True) + "\n")
        return record
