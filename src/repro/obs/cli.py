"""Uniform observability flags for the launch CLIs.

Every launcher that executes the runtime takes the same pair:

  --record-events / --no-record-events   the engine's per-transfer event
                                         logs (on by default; turn off for
                                         fleet-scale horizons)
  --trace-out PATH                       attach an ObsRecorder and write a
                                         Perfetto-loadable trace JSON here

``add_obs_args`` installs them, ``recorder_for`` builds the recorder (or
None) from the parsed args, and ``export_trace`` writes + announces the
file.  Keeping this in one place is what makes the flags *uniform* —
colocate, serve, shardplan and train all call these three helpers.
"""

from __future__ import annotations

import argparse


def add_obs_args(ap: argparse.ArgumentParser, default_record: bool = True) -> None:
    ap.add_argument(
        "--record-events", action=argparse.BooleanOptionalAction,
        default=default_record,
        help="runtime per-transfer event logs (disable for long horizons)",
    )
    ap.add_argument(
        "--trace-out", default=None, metavar="PATH",
        help="write a Perfetto-loadable .trace.json of the runtime here",
    )
    ap.add_argument(
        "--verify", action="store_true",
        help="statically verify the run: sweep the solved plans "
             "(repro.analyze.plan_check) and the recorded schedule "
             "(repro.analyze.schedule_check); exit nonzero on any violation",
    )


def recorder_for(args):
    """An ObsRecorder when ``--trace-out`` or ``--verify`` was given, else
    None.  ``--verify`` attaches one even without an output path: the
    recorder is a pure observer (reports stay bit-identical) and its streams
    are the race detector's richest input."""
    if getattr(args, "trace_out", None) or getattr(args, "verify", False):
        from .recorder import ObsRecorder

        return ObsRecorder()
    return None


def export_trace(args, recorder, report) -> None:
    """Write the recorder to ``args.trace_out`` with ``report`` embedded."""
    if recorder is None or not getattr(args, "trace_out", None):
        return
    from .trace_export import write_trace

    trace = write_trace(args.trace_out, recorder, report)
    print(
        f"[obs] wrote {args.trace_out} ({len(trace['traceEvents'])} events; "
        f"open at https://ui.perfetto.dev)"
    )
