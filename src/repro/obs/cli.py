"""Uniform observability flags for the launch CLIs.

Every launcher that executes the runtime takes the same pair:

  --record-events / --no-record-events   the engine's per-transfer event
                                         logs (on by default; turn off for
                                         fleet-scale horizons)
  --trace-out PATH                       attach an ObsRecorder and write a
                                         Perfetto-loadable trace JSON here

``add_obs_args`` installs them, ``recorder_for`` builds the recorder (or
None) from the parsed args, and ``export_trace`` writes + announces the
file.  Keeping this in one place is what makes the flags *uniform* —
colocate, serve, shardplan and train all call these three helpers.
"""

from __future__ import annotations

import argparse


def add_obs_args(ap: argparse.ArgumentParser, default_record: bool = True) -> None:
    ap.add_argument(
        "--record-events", action=argparse.BooleanOptionalAction,
        default=default_record,
        help="runtime per-transfer event logs (disable for long horizons)",
    )
    ap.add_argument(
        "--trace-out", default=None, metavar="PATH",
        help="write a Perfetto-loadable .trace.json of the runtime here",
    )
    ap.add_argument(
        "--verify", action="store_true",
        help="statically verify the run: sweep the solved plans "
             "(repro.analyze.plan_check) and the recorded schedule "
             "(repro.analyze.schedule_check); exit nonzero on any violation",
    )
    ap.add_argument(
        "--slo", action="append", default=None, metavar="SPEC",
        help="arm the streaming SLO monitor with this spec (repeatable), "
             "e.g. 'queue_wait.p99<0.005,prio=1' or "
             "'link.out_in_wait_ratio>3,low=1.5'; alerts print at exit and "
             "land in the trace's alerts track",
    )
    ap.add_argument(
        "--monitor-out", default=None, metavar="PATH",
        help="append the streaming-monitor summary (metrics + per-stream "
             "quantiles + alerts) as one JSONL record here",
    )


def recorder_for(args):
    """An ObsRecorder when ``--trace-out`` or ``--verify`` was given, else
    None.  ``--verify`` attaches one even without an output path: the
    recorder is a pure observer (reports stay bit-identical) and its streams
    are the race detector's richest input.  ``--slo`` / ``--monitor-out``
    upgrade it to a ``MonitoredRecorder`` with the streaming SLO monitor
    armed (still a pure observer)."""
    slos = getattr(args, "slo", None)
    if slos is not None or getattr(args, "monitor_out", None):
        from .monitor import MonitoredRecorder

        return MonitoredRecorder(slos=slos or ())
    if getattr(args, "trace_out", None) or getattr(args, "verify", False):
        from .recorder import ObsRecorder

        return ObsRecorder()
    return None


def export_trace(args, recorder, report) -> None:
    """Write the recorder to ``args.trace_out`` with ``report`` embedded."""
    if recorder is None or not getattr(args, "trace_out", None):
        return
    from .trace_export import write_trace

    trace = write_trace(args.trace_out, recorder, report)
    print(
        f"[obs] wrote {args.trace_out} ({len(trace['traceEvents'])} events; "
        f"open at https://ui.perfetto.dev)"
    )


def export_monitor(args, recorder, extra: dict | None = None) -> None:
    """Announce alerts and write the ``--monitor-out`` JSONL record for a
    ``MonitoredRecorder`` (no-op for a plain recorder or when the monitor
    was never armed)."""
    if recorder is None or not hasattr(recorder, "finalize"):
        return
    summary = recorder.finalize()
    alerts = summary["alerts"]
    if getattr(args, "slo", None):
        if alerts:
            print(f"[obs] {len(alerts)} SLO alert(s):")
            for a in alerts:
                print(f"[obs]   t={a['t']:.6f}s {a['slo']} {a['kind']} "
                      f"value={a['value']:.4g} threshold={a['threshold']:.4g}")
        else:
            print(f"[obs] SLO monitor: {len(summary['slos'])} spec(s) armed, "
                  "no alerts")
    out = getattr(args, "monitor_out", None)
    if out:
        record = {"monitor": summary}
        if extra:
            record.update(extra)
        recorder.metrics.append_jsonl(out, record)
        print(f"[obs] wrote monitor summary to {out}")
