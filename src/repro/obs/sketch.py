"""Deterministic streaming quantile sketch (compacting-buffer family).

``QuantileSketch`` is a stdlib-only, merge-able sketch in the MRL/KLL
compacting-buffer style: level ``l`` holds a buffer of items each standing
for ``2**l`` original samples.  When a level fills past ``buffer_size`` it
is *compacted* — sorted, then every second element promoted one level up
with doubled weight.  Unlike randomized KLL, the parity of the surviving
elements is not a coin flip: each level keeps a parity bit that alternates
per compaction, so the same input stream always yields the same sketch
state bit-for-bit (the determinism lint covers this module) while the
alternation cancels the one-sided rank bias a fixed parity would build up.

Error accounting is *self-reported rather than probabilistic*: every
compaction at level ``l`` can shift any rank by at most ``2**l`` (the
weight of one discarded element), so the sketch tracks its compaction
counts and exposes

    rank_error_bound() = sum over levels of  count[l] * 2**l

an absolute worst-case rank error for any quantile query on this specific
stream.  For a buffer of size ``b`` and ``n`` samples this grows as
``O(n/b * log(n/b))`` ranks — with the default ``b=512``, under 1% relative
rank error out past 10^5 samples — and tests assert the *actual* error
against the *reported* bound, adversarial stream orders included.

``exact=True`` keeps every sample (no compaction, bound 0): the oracle
mode tests and benchmarks compare against.
"""

from __future__ import annotations

from bisect import insort

DEFAULT_BUFFER_SIZE = 512


class QuantileSketch:
    """Streaming quantile estimates with a self-reported rank-error bound.

    Parameters
    ----------
    buffer_size:
        Per-level buffer capacity ``b``; memory is ``O(b log(n/b))``.
        Must be >= 2 (and even buffers compact cleanly; odd sizes work,
        the leftover element just stays behind).
    exact:
        Keep all samples and answer exactly (testing / post-hoc oracle).
    """

    __slots__ = ("buffer_size", "exact", "levels", "parity", "compactions", "count",
                 "_min", "_max")

    def __init__(self, buffer_size: int = DEFAULT_BUFFER_SIZE, exact: bool = False):
        if buffer_size < 2:
            raise ValueError("buffer_size must be >= 2")
        self.buffer_size = int(buffer_size)
        self.exact = bool(exact)
        self.levels: list[list[float]] = [[]]  # levels[l]: weight 2**l each
        self.parity: list[int] = [0]
        self.compactions: list[int] = [0]
        self.count = 0
        self._min: float | None = None
        self._max: float | None = None

    # ------------------------------------------------------------------ feed
    def add(self, x: float) -> None:
        x = float(x)
        self.count += 1
        if self._min is None or x < self._min:
            self._min = x
        if self._max is None or x > self._max:
            self._max = x
        self.levels[0].append(x)
        if not self.exact:
            self._compact_cascade()

    def extend(self, xs) -> None:
        for x in xs:
            self.add(x)

    def _grow_to(self, level: int) -> None:
        while len(self.levels) <= level:
            self.levels.append([])
            self.parity.append(0)
            self.compactions.append(0)

    def _compact_cascade(self) -> None:
        level = 0
        while level < len(self.levels) and len(self.levels[level]) >= self.buffer_size:
            buf = sorted(self.levels[level])
            keep = self.parity[level]  # alternate survivor parity per compaction
            self.parity[level] ^= 1
            self.compactions[level] += 1
            promoted = buf[keep::2]
            self._grow_to(level + 1)
            self.levels[level] = []
            self.levels[level + 1].extend(promoted)
            level += 1

    def merge(self, other: "QuantileSketch") -> None:
        """Fold ``other`` into self, level by level (deterministic: the
        merged state depends only on the two operand states and their
        order — ``a.merge(b)`` and ``b.merge(a)`` may differ, so callers
        merge in a fixed, documented order such as sorted stream keys)."""
        self._grow_to(len(other.levels) - 1)
        for l, buf in enumerate(other.levels):
            self.levels[l].extend(buf)
            self.compactions[l] += other.compactions[l]
        self.count += other.count
        for m in (other._min, other._max):
            if m is None:
                continue
            if self._min is None or m < self._min:
                self._min = m
            if self._max is None or m > self._max:
                self._max = m
        if not self.exact:
            self._compact_cascade()

    # ---------------------------------------------------------------- queries
    def _weighted(self) -> list[tuple[float, int]]:
        pairs: list[tuple[float, int]] = []
        for l, buf in enumerate(self.levels):
            w = 1 << l
            for x in buf:
                pairs.append((x, w))
        pairs.sort()
        return pairs

    def quantile(self, q: float) -> float:
        """The value at rank ``q * (count - 1)`` of the sketched stream
        (nearest-rank on the weighted sample; exact when ``exact=True``)."""
        if self.count == 0:
            raise ValueError("quantile() of an empty sketch")
        q = min(1.0, max(0.0, float(q)))
        pairs = self._weighted()
        total = 0
        for _, w in pairs:
            total += w
        target = q * (total - 1)
        cum = 0
        for x, w in pairs:
            cum += w
            if cum - 1 >= target:
                return x
        return pairs[-1][0]

    def quantiles(self, qs) -> list[float]:
        return [self.quantile(q) for q in qs]

    @property
    def min(self) -> float | None:
        return self._min

    @property
    def max(self) -> float | None:
        return self._max

    def rank_error_bound(self) -> int:
        """Worst-case absolute rank error of any ``quantile()`` answer on
        this stream: each compaction at level ``l`` moved any cut rank by
        at most ``2**l``.  0 in exact mode or before the first compaction."""
        bound = 0
        for l, c in enumerate(self.compactions):
            bound += c * (1 << l)
        return bound

    # ------------------------------------------------------------- serialize
    def to_dict(self) -> dict:
        return {
            "buffer_size": self.buffer_size,
            "exact": self.exact,
            "count": self.count,
            "min": self._min,
            "max": self._max,
            "rank_error_bound": self.rank_error_bound(),
        }


class ExactDistribution:
    """Sorted-insert exact order statistics — the post-hoc oracle the sketch
    is validated against (and the ``exact`` backend for small cells)."""

    __slots__ = ("values",)

    def __init__(self):
        self.values: list[float] = []

    def add(self, x: float) -> None:
        insort(self.values, float(x))

    def extend(self, xs) -> None:
        for x in xs:
            self.add(x)

    @property
    def count(self) -> int:
        return len(self.values)

    def quantile(self, q: float) -> float:
        if not self.values:
            raise ValueError("quantile() of an empty distribution")
        q = min(1.0, max(0.0, float(q)))
        idx = round(q * (len(self.values) - 1))
        return self.values[idx]

    def rank_of(self, x: float) -> int:
        """Number of stored values <= x (for rank-error assertions)."""
        lo, hi = 0, len(self.values)
        while lo < hi:
            mid = (lo + hi) // 2
            if self.values[mid] <= x:
                lo = mid + 1
            else:
                hi = mid
        return lo
