"""ObsRecorder: the event sink ``runtime.MemoryRuntime(obs=...)`` feeds.

The engine calls one hook per observable event — op execution, swap
transfer, stall (by named cause), host-link blackout, admission, tenant
finish, renegotiation lifecycle — passing simulated times and the tenant
run objects it already holds.  The recorder is a *pure observer*: it reads
engine state, never writes it, so simulated reports are bit-identical with
a recorder attached or not (tests/test_obs.py pins this).

Storage is flat tuple lists (cheap appends; the export layer does all the
shaping) plus a ``MetricsRegistry`` the hooks fold into, so one run yields
both the full Perfetto timeline and the aggregate counter snapshot.

``op_slices=False`` keeps the per-op span/occupancy stream off for very
long horizons (transfers, stalls, admissions and metrics still record) —
the lists are the only unbounded state here.

Duck-typing note: hooks taking ``run`` only read ``run.name`` and
``run.device`` — any object with those attributes works, which is what
keeps this module import-free of the engine (and the engine import-free of
``repro.obs`` except for the ``obs=`` parameter it never introspects).
"""

from __future__ import annotations

from .metrics import MetricsRegistry


class ObsRecorder:
    def __init__(self, metrics: MetricsRegistry | None = None, op_slices: bool = True):
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.op_slices = op_slices
        # (name, device, op index, t0, t1, resident bytes, device total bytes)
        self.ops: list[tuple] = []
        # (name, device, op index, t0, seconds)
        self.collectives: list[tuple] = []
        # (name, device, cause, t0, seconds, var)
        self.stalls: list[tuple] = []
        # (name, device, direction, var, start, end, channel, lane|None, ready_t, size)
        self.transfers: list[tuple] = []
        # (start, end) on the shared host link
        self.blackouts: list[tuple] = []
        # (name, device, arrival_t, admit_t) — 4-wide on purpose: both
        # trace_export and analyze.schedule_check unpack this shape.
        self.admissions: list[tuple] = []
        # tenant name -> SLO priority as reported at admission
        self.priorities: dict[str, float] = {}
        # (name, arrival_t)
        self.unschedulables: list[tuple] = []
        # (kind: staged|applied|cancelled, victim, t, value: new_limit|freed bytes|0)
        self.renegotiations: list[tuple] = []
        # (name, device, finish_t)
        self.finishes: list[tuple] = []

    # ------------------------------------------------------------ engine hooks
    def op_step(self, run, i: int, t0: float, t1: float, acct) -> None:
        """One executed op: its compute span plus an HBM occupancy sample
        (this tenant's resident bytes and its device pool's total) taken at
        the end of the step, after swap-out launches/retirements and
        prefetches settled."""
        if self.op_slices:
            self.ops.append(
                (run.name, run.device, i, t0, t1,
                 acct.resident.get(run.name, 0), acct.total)
            )
        self.metrics.counter("engine.ops").inc()

    def collective(self, run, i: int, t0: float, seconds: float) -> None:
        if self.op_slices:
            self.collectives.append((run.name, run.device, i, t0, seconds))
        self.metrics.counter("engine.collectives").inc()
        self.metrics.counter("engine.collective_s").inc(seconds)

    def stall(self, run, cause: str, t0: float, seconds: float, var: int) -> None:
        self.stalls.append((run.name, run.device, cause, t0, seconds, var))
        self.metrics.counter(f"engine.stalls.{cause}").inc()
        self.metrics.counter(f"engine.stall_s.{cause}").inc(seconds)

    def transfer(self, run, direction: str, var: int, start: float, end: float,
                 ch: int, lane: "int | None", ready_t: float, size: int) -> None:
        self.transfers.append(
            (run.name, run.device, direction, var, start, end, ch, lane, ready_t, size)
        )
        self.metrics.counter(f"engine.transfers.{direction}").inc()
        self.metrics.counter(f"engine.transfer_bytes.{direction}").inc(size)
        self.metrics.counter("engine.transfer_queue_s").inc(max(0.0, start - ready_t))

    def blackout(self, start: float, end: float) -> None:
        self.blackouts.append((start, end))
        self.metrics.counter("link.blackouts").inc()
        self.metrics.counter("link.blackout_s").inc(end - start)

    def admitted(self, name: str, device: "str | None",
                 arrival_t: float, admit_t: float, priority: float = 1.0) -> None:
        self.admissions.append((name, device, arrival_t, admit_t))
        self.priorities[name] = priority
        self.metrics.counter("admission.admitted").inc()
        self.metrics.counter("admission.queue_wait_s").inc(admit_t - arrival_t)

    def unschedulable(self, name: str, arrival_t: float) -> None:
        self.unschedulables.append((name, arrival_t))
        self.metrics.counter("admission.unschedulable").inc()

    def renegotiation(self, kind: str, victim: str, t: float, value: int) -> None:
        self.renegotiations.append((kind, victim, t, value))
        self.metrics.counter(f"renegotiation.{kind}").inc()
        if kind == "applied":
            self.metrics.counter("renegotiation.freed_bytes").inc(value)

    def finished(self, name: str, device: "str | None", t: float) -> None:
        self.finishes.append((name, device, t))
        self.metrics.counter("admission.finished").inc()
        self.metrics.gauge("engine.makespan_s").set_max(t)

    # --------------------------------------------------------------- shaping
    def tenant_names(self) -> list[str]:
        """Every tenant seen, in first-admission order (then first-event)."""
        seen: dict[str, None] = {}
        for name, *_ in self.admissions:
            seen.setdefault(name)
        for rec in self.ops:
            seen.setdefault(rec[0])
        for rec in self.stalls:
            seen.setdefault(rec[0])
        for rec in self.transfers:
            seen.setdefault(rec[0])
        for name, _ in self.unschedulables:
            seen.setdefault(name)
        return list(seen)
