"""Render an ``ObsRecorder`` into Perfetto-loadable Chrome trace JSON.

Output is the Trace Event Format's JSON *object* flavor — an object with a
``traceEvents`` array plus ``otherData`` — which https://ui.perfetto.dev
and chrome://tracing both open directly.  Times are simulated seconds
scaled to microseconds (the format's native unit).

Track layout (process / thread rows in the viewer):

  pid 1 "tenants"       one row per tenant: ``queued`` admission-wait slice,
                        ``stall:<cause>`` slices, ``op<i>`` compute slices,
                        ``collective@<i>`` slices, and instant events for
                        admission / finish / unschedulable plus
                        renegotiation staged→applied flow arrows.
  pid 2 "dma channels"  one row per (device, channel): ``in:v<var>`` /
                        ``out:v<var>`` swap-transfer slices, plus a
                        ``dma busy [<device>]`` counter of concurrently
                        busy channels per device.
  pid 3 "host link"     one row per lane with the same transfers as seen by
                        the shared link, a merged ``blackout`` row for
                        collective occupancy, and a ``lanes busy`` counter.
  pid 4 "hbm"           counter tracks: ``HBM [<device>]`` total pool
                        occupancy and ``resident [<tenant>]`` per tenant,
                        sampled once per executed op.

Every slice on one row is non-overlapping by construction (tenant time is
sequential; channels and lanes are serialized by the engine's ``free_at``
bookkeeping; blackout windows are merged here) — ``tools/check_trace.py``
validates exactly that, plus the attribution-ledger sum, on the embedded
report.
"""

from __future__ import annotations

import json

from .recorder import ObsRecorder

TRACE_SCHEMA_VERSION = 1

_US = 1e6  # simulated seconds -> trace microseconds

PID_TENANTS = 1
PID_DMA = 2
PID_LINK = 3
PID_MEM = 4
PID_ALERTS = 5

LEGEND = {
    "tracks": {
        "tenants": "per-tenant rows: queued | stall:<cause> | op<i> | collective@<i>",
        "dma channels": "per-(device, channel) swap transfers: in:v<var> / out:v<var>",
        "host link": "per-lane transfers + merged collective 'blackout' row",
        "hbm": "counters: HBM [<device>] pool totals, resident [<tenant>]",
        "alerts": "per-SLO rows of instant events from the streaming monitor "
                  "(burn-rate and asymmetry crossings; args carry slo/kind/value)",
    },
    "stall_causes": {
        "swap_in_wait": "compute blocked on an in-flight (or late) swap-in",
        "swap_out_drain": "malloc delayed until a pending swap-out freed headroom",
        "barrier_drain": "iteration barrier draining this tenant's in-flight transfers",
    },
    "attribution": {
        "swap_in_transfer_s": "stall seconds covered by the swap-in moving bytes",
        "link_blackout_s": "stall seconds the transfer was shifted past collective blackouts",
        "channel_contention_s": "stall seconds the transfer queued for a DMA channel/link lane",
        "swap_out_pending_s": "stall seconds waiting for the variable's own swap-out first",
        "swap_out_drain_s": "malloc-delay seconds waiting on pending swap-outs",
        "barrier_drain_s": "iteration-barrier drain seconds",
        "collective_excess_s": "collective seconds charged beyond the baseline-folded windows",
        "residual_s": "float-closure term; the ledger sums exactly to overhead seconds",
    },
}


def _dev(device) -> str:
    return "default" if device is None else str(device)


def _merged(intervals: list) -> list:
    out: list[list[float]] = []
    for s, e in sorted(intervals):
        if out and s <= out[-1][1]:
            if e > out[-1][1]:
                out[-1][1] = e
        else:
            out.append([s, e])
    return [(s, e) for s, e in out]


def _busy_counter(spans, pid: int, name: str, series: str) -> list:
    """Counter samples from +1/-1 edges of possibly-concurrent spans."""
    edges: list[tuple[float, int]] = []
    for s, e in spans:
        edges.append((s, 1))
        edges.append((e, -1))
    edges.sort()
    events, busy, prev_t = [], 0, None
    for t, d in edges:
        if prev_t is not None and t != prev_t:
            events.append({"ph": "C", "pid": pid, "name": name,
                           "ts": prev_t * _US, "args": {series: busy}})
        busy += d
        prev_t = t
    if prev_t is not None:
        events.append({"ph": "C", "pid": pid, "name": name,
                       "ts": prev_t * _US, "args": {series: busy}})
    return events


def chrome_trace(recorder: ObsRecorder, report=None) -> dict:
    """Build the trace object.  ``report`` (a ``RuntimeReport``, or its
    ``as_dict()``) embeds under ``otherData.report`` so one file carries the
    timeline *and* the attribution ledger ``check_trace`` validates."""
    ev: list[dict] = []
    meta: list[dict] = []

    def proc(pid: int, name: str) -> None:
        meta.append({"ph": "M", "pid": pid, "name": "process_name",
                     "args": {"name": name}})
        meta.append({"ph": "M", "pid": pid, "name": "process_sort_index",
                     "args": {"sort_index": pid}})

    def thread(pid: int, tid: int, name: str) -> None:
        meta.append({"ph": "M", "pid": pid, "tid": tid, "name": "thread_name",
                     "args": {"name": name}})
        meta.append({"ph": "M", "pid": pid, "tid": tid,
                     "name": "thread_sort_index", "args": {"sort_index": tid}})

    proc(PID_TENANTS, "tenants")
    proc(PID_DMA, "dma channels")
    proc(PID_LINK, "host link")
    proc(PID_MEM, "hbm")

    # ------------------------------------------------------------- tenants
    tids = {name: i + 1 for i, name in enumerate(recorder.tenant_names())}
    for name, tid in tids.items():
        thread(PID_TENANTS, tid, name)

    for name, device, arrival_t, admit_t in recorder.admissions:
        tid = tids[name]
        if admit_t > arrival_t:
            ev.append({"ph": "X", "pid": PID_TENANTS, "tid": tid, "name": "queued",
                       "ts": arrival_t * _US, "dur": (admit_t - arrival_t) * _US,
                       "args": {"device": _dev(device)}})
        ev.append({"ph": "i", "s": "t", "pid": PID_TENANTS, "tid": tid,
                   "name": "admitted", "ts": admit_t * _US,
                   "args": {"device": _dev(device)}})
    for name, arrival_t in recorder.unschedulables:
        ev.append({"ph": "i", "s": "t", "pid": PID_TENANTS, "tid": tids[name],
                   "name": "unschedulable", "ts": arrival_t * _US})
    for name, device, t in recorder.finishes:
        if name in tids:
            ev.append({"ph": "i", "s": "t", "pid": PID_TENANTS, "tid": tids[name],
                       "name": "finished", "ts": t * _US})

    for name, device, i, t0, t1, resident, total in recorder.ops:
        ev.append({"ph": "X", "pid": PID_TENANTS, "tid": tids[name],
                   "name": f"op{i}", "ts": t0 * _US, "dur": (t1 - t0) * _US})
    for name, device, i, t0, seconds in recorder.collectives:
        ev.append({"ph": "X", "pid": PID_TENANTS, "tid": tids[name],
                   "name": f"collective@{i}", "ts": t0 * _US,
                   "dur": seconds * _US})
    for name, device, cause, t0, seconds, var in recorder.stalls:
        ev.append({"ph": "X", "pid": PID_TENANTS, "tid": tids[name],
                   "name": f"stall:{cause}", "ts": t0 * _US,
                   "dur": seconds * _US, "args": {"var": var}})

    # Renegotiation lifecycle: instants on the victim's row plus a flow
    # arrow from each staged event to the barrier where it applied.
    flow_id = 0
    pending: dict[str, int] = {}
    for kind, victim, t, value in recorder.renegotiations:
        tid = tids.get(victim)
        if tid is None:
            continue
        args = {"staged": {"new_limit": value}, "applied": {"freed_bytes": value},
                "cancelled": {}}[kind]
        ev.append({"ph": "i", "s": "t", "pid": PID_TENANTS, "tid": tid,
                   "name": f"renegotiation {kind}", "ts": t * _US, "args": args})
        if kind == "staged":
            flow_id += 1
            pending[victim] = flow_id
            ev.append({"ph": "s", "id": flow_id, "pid": PID_TENANTS, "tid": tid,
                       "name": "renegotiation", "ts": t * _US})
        elif victim in pending:
            ev.append({"ph": "f", "bp": "e", "id": pending.pop(victim),
                       "pid": PID_TENANTS, "tid": tid,
                       "name": "renegotiation", "ts": t * _US})

    # ------------------------------------------------- dma channels + link
    chan_tids: dict[tuple, int] = {}
    for rec in recorder.transfers:
        key = (_dev(rec[1]), rec[6])
        if key not in chan_tids:
            chan_tids[key] = len(chan_tids) + 1
    for (dev, ch), tid in sorted(chan_tids.items(), key=lambda kv: kv[1]):
        thread(PID_DMA, tid, f"{dev}/ch{ch}")

    lane_tids: dict[int, int] = {}
    dev_spans: dict[str, list] = {}
    lane_spans: list = []
    for name, device, direction, var, start, end, ch, lane, ready_t, size in recorder.transfers:
        dev = _dev(device)
        ev.append({"ph": "X", "pid": PID_DMA, "tid": chan_tids[(dev, ch)],
                   "name": f"{direction}:v{var}", "ts": start * _US,
                   "dur": (end - start) * _US,
                   "args": {"tenant": name, "bytes": size,
                            "queued_us": (start - ready_t) * _US}})
        dev_spans.setdefault(dev, []).append((start, end))
        if lane is not None:
            if lane not in lane_tids:
                lane_tids[lane] = lane + 2  # tid 1 is the blackout row
            ev.append({"ph": "X", "pid": PID_LINK, "tid": lane_tids[lane],
                       "name": f"{direction}:v{var}", "ts": start * _US,
                       "dur": (end - start) * _US,
                       "args": {"tenant": name, "device": dev, "bytes": size}})
            lane_spans.append((start, end))
    for dev, spans in sorted(dev_spans.items()):
        ev.extend(_busy_counter(spans, PID_DMA, f"dma busy [{dev}]", "channels"))
    if recorder.blackouts or lane_spans:
        thread(PID_LINK, 1, "blackouts")
        for lane, tid in sorted(lane_tids.items()):
            thread(PID_LINK, tid, f"lane{lane}")
        for s, e in _merged(recorder.blackouts):
            ev.append({"ph": "X", "pid": PID_LINK, "tid": 1, "name": "blackout",
                       "ts": s * _US, "dur": (e - s) * _US})
        if lane_spans:
            ev.extend(_busy_counter(lane_spans, PID_LINK, "lanes busy", "lanes"))

    # -------------------------------------------------------- hbm counters
    last_dev: dict[str, int] = {}
    last_res: dict[str, int] = {}
    for name, device, i, t0, t1, resident, total in recorder.ops:
        dev = _dev(device)
        if last_dev.get(dev) != total:
            last_dev[dev] = total
            ev.append({"ph": "C", "pid": PID_MEM, "name": f"HBM [{dev}]",
                       "ts": t1 * _US, "args": {"bytes": total}})
        if last_res.get(name) != resident:
            last_res[name] = resident
            ev.append({"ph": "C", "pid": PID_MEM, "name": f"resident [{name}]",
                       "ts": t1 * _US, "args": {"bytes": resident}})

    # ------------------------------------------------------- alerts (pid 5)
    # Present only for monitored recorders (repro.obs.monitor); a plain
    # ObsRecorder has no ``alerts`` and the track is simply absent.
    alerts = getattr(recorder, "alerts", ())
    slo_specs = getattr(recorder, "slo_specs", None)
    if alerts:
        proc(PID_ALERTS, "alerts")
        slo_tids: dict[str, int] = {}
        for a in alerts:
            if a.slo not in slo_tids:
                slo_tids[a.slo] = len(slo_tids) + 1
                thread(PID_ALERTS, slo_tids[a.slo], a.slo)
            ev.append({"ph": "i", "s": "p", "pid": PID_ALERTS,
                       "tid": slo_tids[a.slo], "name": f"alert:{a.kind}",
                       "ts": a.t * _US,
                       "args": {"slo": a.slo, "kind": a.kind, "value": a.value,
                                "threshold": a.threshold}})

    ev.sort(key=lambda e: (e["ts"], e.get("dur", 0.0)))
    if hasattr(recorder, "finalize"):
        recorder.finalize()  # idempotent: folds monitor gauges into metrics
    other = {
        "schema_version": TRACE_SCHEMA_VERSION,
        "legend": LEGEND,
        "metrics": recorder.metrics.snapshot(),
    }
    if slo_specs is not None:
        other["slos"] = [s.as_dict() for s in slo_specs]
    monitor = getattr(recorder, "monitor", None)
    if monitor is not None:
        other["monitor"] = {"quantiles": monitor.quantile_summary(),
                            "alerts": [a.as_dict() for a in alerts]}
    if report is not None:
        other["report"] = report if isinstance(report, dict) else report.as_dict()
    return {
        "traceEvents": meta + ev,
        "displayTimeUnit": "ms",
        "otherData": other,
    }


def write_trace(path: str, recorder: ObsRecorder, report=None) -> dict:
    """Write ``chrome_trace(recorder, report)`` to ``path`` (compact JSON —
    these files are meant for Perfetto and ``check_trace``, not for eyes).
    Returns the trace object."""
    trace = chrome_trace(recorder, report)
    with open(path, "w") as f:
        json.dump(trace, f, separators=(",", ":"))
    return trace
