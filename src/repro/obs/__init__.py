"""repro.obs: runtime observability for the memory engine.

Pieces, all pure observers of ``runtime.MemoryRuntime``:

  metrics      — ``MetricsRegistry``: named counters/gauges with a JSONL
                 sink, cheap enough to leave attached on long horizons.
  recorder     — ``ObsRecorder``: the hook sink the engine calls when an
                 ``obs=`` recorder is attached (op spans, swap transfers,
                 stalls by cause, link blackouts, admissions,
                 renegotiations, HBM occupancy samples).  Detached
                 (``obs=None``, the default) the engine hot path pays one
                 predicate per event site — gated exactly like
                 ``record_events``.
  sketch       — ``QuantileSketch``: deterministic compacting-buffer
                 streaming quantiles with a self-reported rank-error bound
                 (``ExactDistribution`` is the post-hoc oracle).
  windows      — tumbling/sliding window counters and the hysteresis-banded
                 ``AsymmetryWindow`` over simulated time.
  monitor      — ``MonitoredRecorder``/``SLOMonitor``: streaming telemetry
                 over the hook path (per-class queue-wait, per-cause stall,
                 per-direction link-wait, HBM-headroom streams) plus
                 declarative SLOs (``parse_slo``) emitting typed ``Alert``
                 events.
  diffing      — ``load_run``/``diff_runs``: differential analysis of two
                 run artifacts (reports, traces, metric JSONL, committed
                 ``BENCH_*.json`` revisions); the ``repro.launch.obsdiff``
                 CLI front-ends it.
  trace_export — ``chrome_trace``/``write_trace``: render a recorder into a
                 Chrome-trace-event JSON object that loads directly in
                 Perfetto (https://ui.perfetto.dev) with per-tenant op
                 slices, per-DMA-channel swap slices, host-link lane and
                 blackout tracks, renegotiation flow events, HBM occupancy
                 counter tracks, and an instant-event alerts track when a
                 monitored recorder carried SLO alerts.

The stall-attribution ledger itself (overhead seconds decomposed into named
causes, summing to each tenant's total overhead) is *always on* — it rides
in ``TenantReport.attribution``/``RuntimeReport.attribution`` whether or not
a recorder is attached; ``simulated_report_dict`` strips it alongside the
other non-reference fields.
"""

from .cli import add_obs_args, export_monitor, export_trace, recorder_for
from .diffing import RunView, diff_runs, format_diff, load_run
from .metrics import Counter, Gauge, MetricsRegistry
from .monitor import (
    Alert,
    MonitoredRecorder,
    SLOMonitor,
    SLOSpec,
    parse_slo,
    priority_class,
)
from .recorder import ObsRecorder
from .sketch import ExactDistribution, QuantileSketch
from .trace_export import TRACE_SCHEMA_VERSION, chrome_trace, write_trace
from .windows import AsymmetryWindow, HysteresisBand, SlidingWindow, TumblingWindow

__all__ = [
    "Alert",
    "AsymmetryWindow",
    "Counter",
    "ExactDistribution",
    "Gauge",
    "HysteresisBand",
    "MetricsRegistry",
    "MonitoredRecorder",
    "ObsRecorder",
    "QuantileSketch",
    "RunView",
    "SLOMonitor",
    "SLOSpec",
    "SlidingWindow",
    "TRACE_SCHEMA_VERSION",
    "TumblingWindow",
    "add_obs_args",
    "chrome_trace",
    "diff_runs",
    "export_monitor",
    "export_trace",
    "format_diff",
    "load_run",
    "parse_slo",
    "priority_class",
    "recorder_for",
    "write_trace",
]
