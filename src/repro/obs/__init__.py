"""repro.obs: runtime observability for the memory engine.

Three pieces, all pure observers of ``runtime.MemoryRuntime``:

  metrics      — ``MetricsRegistry``: named counters/gauges with a JSONL
                 sink, cheap enough to leave attached on long horizons.
  recorder     — ``ObsRecorder``: the hook sink the engine calls when an
                 ``obs=`` recorder is attached (op spans, swap transfers,
                 stalls by cause, link blackouts, admissions,
                 renegotiations, HBM occupancy samples).  Detached
                 (``obs=None``, the default) the engine hot path pays one
                 predicate per event site — gated exactly like
                 ``record_events``.
  trace_export — ``chrome_trace``/``write_trace``: render a recorder into a
                 Chrome-trace-event JSON object that loads directly in
                 Perfetto (https://ui.perfetto.dev) with per-tenant op
                 slices, per-DMA-channel swap slices, host-link lane and
                 blackout tracks, renegotiation flow events and HBM
                 occupancy counter tracks.

The stall-attribution ledger itself (overhead seconds decomposed into named
causes, summing to each tenant's total overhead) is *always on* — it rides
in ``TenantReport.attribution``/``RuntimeReport.attribution`` whether or not
a recorder is attached; ``simulated_report_dict`` strips it alongside the
other non-reference fields.
"""

from .cli import add_obs_args, export_trace, recorder_for
from .metrics import Counter, Gauge, MetricsRegistry
from .recorder import ObsRecorder
from .trace_export import TRACE_SCHEMA_VERSION, chrome_trace, write_trace

__all__ = [
    "Counter",
    "Gauge",
    "MetricsRegistry",
    "ObsRecorder",
    "TRACE_SCHEMA_VERSION",
    "add_obs_args",
    "chrome_trace",
    "export_trace",
    "recorder_for",
    "write_trace",
]
