"""Windowed counters over simulated time: tumbling, sliding, hysteresis.

All state advances on the *observation* timestamps the engine hooks carry
(simulated seconds), never wall clock, so windows are as deterministic as
the event stream that feeds them.  Three shapes:

  TumblingWindow      fixed-width consecutive windows; each closes with its
                      (start, count, sum, min, max) tuple once an
                      observation lands past its end.  A sample exactly on
                      a boundary ``k*width`` opens window ``k`` (half-open
                      ``[k*width, (k+1)*width)`` intervals).  Empty windows
                      emit nothing.
  SlidingWindow       sum/count over the trailing ``width`` seconds,
                      bucketed into ``resolution`` sub-windows (a ring, so
                      memory is O(resolution) regardless of horizon).
                      The trailing edge is bucket-quantized: the window
                      covers between ``width`` and ``width * (1 + 1/res)``
                      seconds, which is the standard rate-limiter
                      approximation and keeps updates O(1).
  HysteresisBand      a two-threshold comparator: ``update(t, value)``
                      returns "enter" when value first rises >= hi,
                      "exit" when an entered signal falls <= lo, else
                      None.  The dead band [lo, hi] suppresses chatter.

``AsymmetryWindow`` composes two SlidingWindows (in-wait vs out-wait) into
the windowed out/in wait ratio the adaptive-lane ROADMAP item gates on.
"""

from __future__ import annotations


class TumblingWindow:
    """Fixed-width window aggregator keyed on observation time."""

    __slots__ = ("width", "closed", "_idx", "_count", "_sum", "_min", "_max")

    def __init__(self, width: float):
        if width <= 0:
            raise ValueError("window width must be positive")
        self.width = float(width)
        # closed windows: (window_start, count, sum, min, max)
        self.closed: list[tuple] = []
        self._idx: int | None = None
        self._count = 0
        self._sum = 0.0
        self._min = 0.0
        self._max = 0.0

    def observe(self, t: float, value: float) -> None:
        idx = int(t // self.width)
        if self._idx is None:
            self._idx = idx
        elif idx != self._idx:
            self._close()
            self._idx = idx
        self._count += 1
        self._sum += value
        if self._count == 1:
            self._min = self._max = value
        else:
            if value < self._min:
                self._min = value
            if value > self._max:
                self._max = value

    def _close(self) -> None:
        if self._count:
            self.closed.append(
                (self._idx * self.width, self._count, self._sum, self._min, self._max)
            )
        self._count = 0
        self._sum = 0.0

    def flush(self) -> list[tuple]:
        """Close the in-flight window (end of run) and return all closed."""
        if self._idx is not None:
            self._close()
            self._idx = None
        return self.closed


class SlidingWindow:
    """Trailing-``width`` sum/count with an O(resolution) bucket ring."""

    __slots__ = ("width", "resolution", "_bucket_w", "_sums", "_counts", "_head")

    def __init__(self, width: float, resolution: int = 16):
        if width <= 0:
            raise ValueError("window width must be positive")
        if resolution < 1:
            raise ValueError("resolution must be >= 1")
        self.width = float(width)
        self.resolution = int(resolution)
        self._bucket_w = self.width / self.resolution
        self._sums = [0.0] * (self.resolution + 1)
        self._counts = [0] * (self.resolution + 1)
        self._head: int | None = None  # absolute bucket index of newest bucket

    def _advance(self, t: float) -> None:
        idx = int(t // self._bucket_w)
        if self._head is None:
            self._head = idx
            return
        # Zero every ring slot between the old head and the new one; a jump
        # past a full revolution clears the whole ring.
        steps = idx - self._head
        if steps <= 0:
            return
        n = len(self._sums)
        if steps >= n:
            for i in range(n):
                self._sums[i] = 0.0
                self._counts[i] = 0
        else:
            for k in range(1, steps + 1):
                slot = (self._head + k) % n
                self._sums[slot] = 0.0
                self._counts[slot] = 0
        self._head = idx

    def add(self, t: float, value: float, count: int = 1) -> None:
        self._advance(t)
        slot = self._head % len(self._sums)
        self._sums[slot] += value
        self._counts[slot] += count

    def total(self, t: float | None = None) -> float:
        if t is not None:
            self._advance(t)
        return sum(self._sums)

    def count(self, t: float | None = None) -> int:
        if t is not None:
            self._advance(t)
        return sum(self._counts)


class HysteresisBand:
    """Two-threshold comparator with a dead band against chatter."""

    __slots__ = ("lo", "hi", "engaged")

    def __init__(self, lo: float, hi: float):
        if lo > hi:
            raise ValueError("hysteresis band needs lo <= hi")
        self.lo = float(lo)
        self.hi = float(hi)
        self.engaged = False

    def update(self, value: float) -> str | None:
        if not self.engaged and value >= self.hi:
            self.engaged = True
            return "enter"
        if self.engaged and value <= self.lo:
            self.engaged = False
            return "exit"
        return None


class AsymmetryWindow:
    """Windowed out/in link-wait ratio with a hysteresis band.

    Feed per-transfer queue waits via ``observe``; evaluate at blackout
    boundaries via ``evaluate(t)``, which returns (ratio, crossing) where
    crossing is "enter"/"exit"/None from the hysteresis band.  The ratio is
    ``(out_wait + eps) / (in_wait + eps)`` over the trailing window, so an
    idle direction reads as extreme rather than dividing by zero.
    """

    __slots__ = ("wait_in", "wait_out", "band", "eps", "last_ratio")

    def __init__(self, width: float, lo: float, hi: float,
                 resolution: int = 16, eps: float = 1e-9):
        self.wait_in = SlidingWindow(width, resolution)
        self.wait_out = SlidingWindow(width, resolution)
        self.band = HysteresisBand(lo, hi)
        self.eps = float(eps)
        self.last_ratio = 1.0

    def observe(self, t: float, direction: str, wait_s: float) -> None:
        if direction == "out":
            self.wait_out.add(t, wait_s)
        else:
            self.wait_in.add(t, wait_s)

    def ratio(self, t: float) -> float:
        w_in = self.wait_in.total(t)
        w_out = self.wait_out.total(t)
        return (w_out + self.eps) / (w_in + self.eps)

    def evaluate(self, t: float) -> tuple[float, str | None]:
        r = self.ratio(t)
        self.last_ratio = r
        return r, self.band.update(r)
