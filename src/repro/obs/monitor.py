"""Streaming SLO monitor riding the pure-observer recorder hook path.

``MonitoredRecorder`` subclasses ``ObsRecorder``: every engine hook first
records exactly as before, then feeds the streaming layer — quantile
sketches (``repro.obs.sketch``) and sliding windows (``repro.obs.windows``)
over four streams:

  queue_wait   per-tenant admission wait, keyed by SLO priority class
  stall        per-op stall seconds, keyed by cause
  link         per-direction transfer queue wait (in vs out), plus the
               windowed out/in wait-ratio asymmetry signal
  hbm          per-device headroom (budget - pool total) sampled per op

Nothing here writes engine state: the monitor only observes hook
arguments, so simulated reports stay bit-identical with a monitor armed
(tests pin this against ``runtime/_engine_reference.py``).

SLOs are declarative specs parsed from compact strings (the ``--slo`` CLI
surface)::

    queue_wait.p99<0.005                      overall p99 queue wait SLO
    queue_wait.p95<0.002,prio=2               one priority class only
    stall.p99<0.01,cause=swap_in_wait         per-cause stall SLO
    link.out_in_wait_ratio>3,low=1.5,window=0.02   asymmetry alarm

Quantile SLOs alert on *burn rate* over two window lengths: with error
budget ``1 - q``, burn = (violating fraction in window) / budget; the SLO
fires when burn >= ``burn`` (default 1.0) in BOTH the short and the long
window with at least ``min`` samples in the short one, and re-arms once
the short-window burn falls to half the trigger — classic multi-window
multi-burn alerting, evaluated online at each sample, in event order, so
alert emission is exactly as deterministic as the engine's event stream.
Asymmetry SLOs evaluate the windowed out/in wait ratio at collective-
blackout boundaries through a hysteresis band (enter at >= threshold,
exit at <= ``low``).

Alerts are typed (``Alert``) and land in three sinks: the recorder's
``alerts`` list (consumed by ``trace_export`` as a pid-5 instant track),
the metrics registry (``monitor.alerts.<slo>`` counters), and the monitor
summary embedded in ``--monitor-out`` JSONL records.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from .recorder import ObsRecorder
from .sketch import QuantileSketch
from .windows import AsymmetryWindow, SlidingWindow

PUBLISH_QUANTILES = (0.5, 0.95, 0.99)
REARM_FRACTION = 0.5  # short-window burn must fall to this * burn to re-arm


def priority_class(priority: float) -> str:
    """Stable label for an SLO priority class: 1.0 -> 'prio1'."""
    return "prio" + format(float(priority), "g")


@dataclass(frozen=True)
class SLOSpec:
    """One declarative SLO.  ``stream`` is 'queue_wait', 'stall' or
    'asymmetry'; quantile streams use threshold/quantile/burn windows,
    asymmetry uses threshold (enter) / low (exit) / window_s."""

    name: str
    stream: str
    threshold: float
    quantile: float | None = None
    cls: str | None = None        # priority class label, queue_wait only
    cause: str | None = None      # stall cause filter, stall only
    short_s: float = 0.05
    long_s: float = 0.25
    burn: float = 1.0
    min_count: int = 8
    low: float | None = None      # asymmetry exit threshold
    window_s: float = 0.05        # asymmetry window width

    def as_dict(self) -> dict:
        d = {"name": self.name, "stream": self.stream, "threshold": self.threshold}
        if self.quantile is not None:
            d.update(quantile=self.quantile, short_s=self.short_s,
                     long_s=self.long_s, burn=self.burn, min_count=self.min_count)
            if self.cls is not None:
                d["cls"] = self.cls
            if self.cause is not None:
                d["cause"] = self.cause
        else:
            d.update(low=self.low, window_s=self.window_s)
        return d


@dataclass(frozen=True)
class Alert:
    """One typed alert event (simulated time ``t``)."""

    t: float
    slo: str
    kind: str        # burn_rate | asymmetry_enter | asymmetry_exit
    value: float
    threshold: float
    detail: dict = field(default_factory=dict)

    def as_dict(self) -> dict:
        return {"t": self.t, "slo": self.slo, "kind": self.kind,
                "value": self.value, "threshold": self.threshold,
                "detail": dict(self.detail)}


def parse_slo(spec: str) -> SLOSpec:
    """Parse the compact ``--slo`` string form (see module docstring)."""
    text = spec.strip()
    head, _, tail = text.partition(",")
    opts: dict[str, str] = {}
    if tail:
        for part in tail.split(","):
            k, eq, v = part.partition("=")
            if not eq:
                raise ValueError(f"bad SLO option {part!r} in {spec!r}")
            opts[k.strip()] = v.strip()

    if "<" in head:
        metric, _, thr = head.partition("<")
        metric, thr = metric.strip(), float(thr)
        base, _, qpart = metric.rpartition(".")
        if not base or not qpart.startswith("p"):
            raise ValueError(f"quantile SLO must look like 'stream.pNN<thr': {spec!r}")
        q = float(qpart[1:]) / 100.0
        if not 0.0 < q < 1.0:
            raise ValueError(f"quantile out of range in {spec!r}")
        if base not in ("queue_wait", "stall"):
            raise ValueError(f"unknown SLO stream {base!r} in {spec!r}")
        cls = priority_class(float(opts["prio"])) if "prio" in opts else None
        cause = opts.get("cause")
        name = opts.get("name") or ".".join(
            x for x in (base, cause, cls, qpart) if x)
        return SLOSpec(
            name=name, stream=base, threshold=thr, quantile=q, cls=cls,
            cause=cause,
            short_s=float(opts.get("short", SLOSpec.short_s)),
            long_s=float(opts.get("long", SLOSpec.long_s)),
            burn=float(opts.get("burn", SLOSpec.burn)),
            min_count=int(opts.get("min", SLOSpec.min_count)),
        )
    if ">" in head:
        metric, _, thr = head.partition(">")
        if metric.strip() != "link.out_in_wait_ratio":
            raise ValueError(f"only link.out_in_wait_ratio takes '>': {spec!r}")
        hi = float(thr)
        low = float(opts.get("low", hi / 2.0))
        return SLOSpec(
            name=opts.get("name") or "link.out_in_wait_ratio",
            stream="asymmetry", threshold=hi, low=low,
            window_s=float(opts.get("window", SLOSpec.window_s)),
        )
    raise ValueError(f"SLO spec needs '<' or '>': {spec!r}")


class _BurnState:
    """Two-window burn-rate evaluator for one quantile SLO."""

    __slots__ = ("spec", "short", "long", "firing")

    def __init__(self, spec: SLOSpec):
        self.spec = spec
        self.short = SlidingWindow(spec.short_s)
        self.long = SlidingWindow(spec.long_s)
        self.firing = False

    def observe(self, t: float, value: float) -> "tuple[float, float] | None":
        v = 1.0 if value > self.spec.threshold else 0.0
        self.short.add(t, v)
        self.long.add(t, v)
        budget = 1.0 - self.spec.quantile
        ns, nl = self.short.count(), self.long.count()
        burn_s = (self.short.total() / ns) / budget if ns else 0.0
        burn_l = (self.long.total() / nl) / budget if nl else 0.0
        if not self.firing:
            if (ns >= self.spec.min_count and burn_s >= self.spec.burn
                    and burn_l >= self.spec.burn):
                self.firing = True
                return burn_s, burn_l
        elif burn_s <= self.spec.burn * REARM_FRACTION:
            self.firing = False
        return None


class SLOMonitor:
    """The streaming layer itself: sketches + windows + SLO evaluation.

    Kept separate from the recorder so tests (and future online consumers
    like adaptive lane reassignment) can feed it synthetic streams.
    """

    def __init__(self, slos=(), sketch_buffer: int = 512, exact: bool = False,
                 asymmetry_window_s: float = 0.05):
        self.specs: list[SLOSpec] = [
            parse_slo(s) if isinstance(s, str) else s for s in slos]
        names = [s.name for s in self.specs]
        if len(names) != len(dict.fromkeys(names)):
            raise ValueError(f"duplicate SLO names: {sorted(names)}")
        self.alerts: list[Alert] = []
        self._sketch_buffer = int(sketch_buffer)
        self._exact = bool(exact)
        self.sketches: dict[str, QuantileSketch] = {}
        self._burn: list[_BurnState] = [
            _BurnState(s) for s in self.specs if s.quantile is not None]
        self._asym_specs = [s for s in self.specs if s.stream == "asymmetry"]
        self._asym = {
            s.name: AsymmetryWindow(s.window_s, lo=s.low, hi=s.threshold)
            for s in self._asym_specs}
        # Always-on ratio window backing monitor.link.out_in_wait_ratio.
        self._ratio = AsymmetryWindow(asymmetry_window_s, lo=0.0, hi=float("inf"))
        self._headroom_min: dict[str, float] = {}

    # ------------------------------------------------------------ plumbing
    def sketch(self, key: str) -> QuantileSketch:
        sk = self.sketches.get(key)
        if sk is None:
            sk = self.sketches[key] = QuantileSketch(
                self._sketch_buffer, exact=self._exact)
        return sk

    def _emit(self, alert: Alert) -> None:
        self.alerts.append(alert)

    # --------------------------------------------------------------- feeds
    def observe_queue_wait(self, t: float, cls: str, wait_s: float) -> None:
        self.sketch("queue_wait.all").add(wait_s)
        self.sketch(f"queue_wait.{cls}").add(wait_s)
        for b in self._burn:
            s = b.spec
            if s.stream != "queue_wait" or (s.cls is not None and s.cls != cls):
                continue
            hit = b.observe(t, wait_s)
            if hit is not None:
                self._emit(Alert(
                    t=t, slo=s.name, kind="burn_rate", value=hit[0],
                    threshold=s.burn,
                    detail={"burn_long": hit[1], "cls": cls,
                            "threshold_s": s.threshold}))

    def observe_stall(self, t: float, cause: str, seconds: float) -> None:
        self.sketch(f"stall.{cause}").add(seconds)
        for b in self._burn:
            s = b.spec
            if s.stream != "stall" or (s.cause is not None and s.cause != cause):
                continue
            hit = b.observe(t, seconds)
            if hit is not None:
                self._emit(Alert(
                    t=t, slo=s.name, kind="burn_rate", value=hit[0],
                    threshold=s.burn,
                    detail={"burn_long": hit[1], "cause": cause,
                            "threshold_s": s.threshold}))

    def observe_transfer(self, t: float, direction: str, wait_s: float) -> None:
        self.sketch(f"link.wait_{direction}").add(wait_s)
        self._ratio.observe(t, direction, wait_s)
        for s in self._asym_specs:
            self._asym[s.name].observe(t, direction, wait_s)

    def observe_headroom(self, t: float, dev: str, headroom: float) -> None:
        self.sketch(f"hbm.{dev}.headroom").add(headroom)
        prev = self._headroom_min.get(dev)
        if prev is None or headroom < prev:
            self._headroom_min[dev] = headroom

    def on_blackout_boundary(self, t: float) -> None:
        self._ratio.evaluate(t)
        for s in self._asym_specs:
            ratio, crossing = self._asym[s.name].evaluate(t)
            if crossing is not None:
                self._emit(Alert(
                    t=t, slo=s.name, kind=f"asymmetry_{crossing}", value=ratio,
                    threshold=s.threshold if crossing == "enter" else s.low,
                    detail={"window_s": s.window_s}))

    # ------------------------------------------------------------- publish
    def quantile_summary(self) -> dict:
        """``{stream_key: {count, bound, p50, p95, p99, min, max}}``."""
        out: dict[str, dict] = {}
        for key in sorted(self.sketches):
            sk = self.sketches[key]
            if sk.count == 0:
                continue
            entry = {"count": sk.count, "rank_error_bound": sk.rank_error_bound(),
                     "min": sk.min, "max": sk.max}
            for q in PUBLISH_QUANTILES:
                entry[f"p{format(q * 100, 'g')}"] = sk.quantile(q)
            out[key] = entry
        return out

    def publish(self, metrics) -> None:
        """Fold the streaming state into a ``MetricsRegistry``."""
        for key, entry in self.quantile_summary().items():
            for stat in sorted(entry):
                if stat in ("min", "max"):
                    continue
                metrics.gauge(f"monitor.{key}.{stat}").set(entry[stat])
        metrics.gauge("monitor.link.out_in_wait_ratio").set(self._ratio.last_ratio)
        for dev in sorted(self._headroom_min):
            metrics.gauge(f"monitor.hbm.{dev}.headroom_min").set(
                self._headroom_min[dev])
        for a in self.alerts:
            metrics.counter(f"monitor.alerts.{a.slo}").inc()

    def summary(self) -> dict:
        """JSON-ready digest for ``--monitor-out`` / obsdiff."""
        return {
            "slos": [s.as_dict() for s in self.specs],
            "quantiles": self.quantile_summary(),
            "alerts": [a.as_dict() for a in self.alerts],
        }


class MonitoredRecorder(ObsRecorder):
    """An ``ObsRecorder`` that additionally feeds an ``SLOMonitor``.

    Drop-in wherever ``obs=`` takes a recorder; still a pure observer.
    ``priorities`` maps tenant name -> SLO priority as reported at
    admission (kept out of the ``admissions`` tuples, whose 4-wide shape
    ``trace_export`` and ``schedule_check`` both unpack).
    """

    def __init__(self, slos=(), metrics=None, op_slices: bool = True,
                 sketch_buffer: int = 512, exact: bool = False):
        super().__init__(metrics=metrics, op_slices=op_slices)
        self.monitor = SLOMonitor(slos, sketch_buffer=sketch_buffer, exact=exact)
        self._finalized = False

    @property
    def alerts(self) -> list[Alert]:
        return self.monitor.alerts

    @property
    def slo_specs(self) -> list[SLOSpec]:
        return self.monitor.specs

    # ------------------------------------------------------- hook overrides
    def admitted(self, name, device, arrival_t, admit_t, priority=1.0) -> None:
        super().admitted(name, device, arrival_t, admit_t, priority)
        self.monitor.observe_queue_wait(
            admit_t, priority_class(priority), admit_t - arrival_t)

    def stall(self, run, cause, t0, seconds, var) -> None:
        super().stall(run, cause, t0, seconds, var)
        self.monitor.observe_stall(t0, cause, seconds)

    def transfer(self, run, direction, var, start, end, ch, lane,
                 ready_t, size) -> None:
        super().transfer(run, direction, var, start, end, ch, lane, ready_t, size)
        self.monitor.observe_transfer(start, direction, max(0.0, start - ready_t))

    def op_step(self, run, i, t0, t1, acct) -> None:
        super().op_step(run, i, t0, t1, acct)
        budget = getattr(acct, "budget", None)
        if budget is not None:
            dev = "default" if run.device is None else str(run.device)
            self.monitor.observe_headroom(t1, dev, budget - acct.total)

    def blackout(self, start, end) -> None:
        super().blackout(start, end)
        self.monitor.on_blackout_boundary(end)

    # --------------------------------------------------------------- output
    def finalize(self) -> dict:
        """Publish streaming state into the metrics registry (idempotent)
        and return the monitor summary."""
        if not self._finalized:
            self.monitor.publish(self.metrics)
            self._finalized = True
        return self.monitor.summary()
