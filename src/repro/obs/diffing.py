"""Differential run analysis: two runs in, ranked regression story out.

The unit of comparison is a ``RunView`` — label + flat numeric scalars +
aggregate stall-attribution ledger + streaming-quantile summary — and
``load_run`` builds one from any of the artifact shapes this repo emits:

  runtime report JSON      a saved ``RuntimeReport.as_dict()`` (has
                           ``tenants``); the ledger aggregates per-tenant
                           ``attribution`` buckets
  trace JSON               Chrome-trace export (has ``traceEvents``):
                           reads ``otherData`` — metrics, embedded report,
                           and the monitor quantile summary when present
  metrics JSONL            ``MetricsRegistry.append_jsonl`` /
                           ``--monitor-out`` files: the *last* record wins
  BENCH_*.json             benchmark reports: numeric scalars flattened to
                           dotted paths (same scheme as bench_history)
  PATH@GITREV              any of the above at a committed revision, via
                           ``git show`` (e.g. ``BENCH_engine.json@HEAD~2``)

``diff_runs`` then produces three tables: per-cause ledger delta,
per-quantile distribution shift, and a top-K scalar regression attribution
table ranked by relative change.  Stdlib-only and jax-free on purpose —
``python -m repro.launch.obsdiff`` and ``tools/bench_history.py --diff``
both run where the backend cannot import.
"""

from __future__ import annotations

import json
import os
import subprocess

# Ledger keys excluded from the sums-to-overhead invariant; kept in the
# delta table (they are exactly the headline aggregates) but flagged.
LEDGER_INFORMATIONAL = {"overhead_s", "queue_wait_s", "renegotiation_solve_s"}


class RunView:
    """One run, normalized for diffing."""

    __slots__ = ("label", "kind", "scalars", "ledger", "quantiles")

    def __init__(self, label: str, kind: str, scalars: dict,
                 ledger: "dict | None" = None, quantiles: "dict | None" = None):
        self.label = label
        self.kind = kind
        self.scalars = scalars
        self.ledger = ledger
        self.quantiles = quantiles

    def as_dict(self) -> dict:
        return {"label": self.label, "kind": self.kind, "scalars": self.scalars,
                "ledger": self.ledger, "quantiles": self.quantiles}


def flatten(obj, prefix: str = "", depth: int = 4):
    """Yield (dotted-path, value) for numeric/bool scalars up to ``depth``."""
    if isinstance(obj, bool) or isinstance(obj, (int, float)):
        yield prefix, float(obj)
        return
    if depth <= 0 or not isinstance(obj, dict):
        return
    for k, v in obj.items():
        if k == "_meta":
            continue
        path = f"{prefix}.{k}" if prefix else str(k)
        yield from flatten(v, path, depth - 1)


def _aggregate_ledger(report: dict) -> "dict | None":
    """Sum per-tenant attribution buckets across a runtime report."""
    out: dict[str, float] = {}
    found = False
    for t in report.get("tenants", ()):
        ledger = t.get("attribution")
        if not isinstance(ledger, dict):
            continue
        found = True
        for cause, v in ledger.items():
            if isinstance(v, (int, float)):
                out[cause] = out.get(cause, 0.0) + float(v)
    return dict(sorted(out.items())) if found else None


def _view_from_report(label: str, report: dict) -> RunView:
    return RunView(label, "report", dict(flatten(report)),
                   ledger=_aggregate_ledger(report))


def _view_from_trace(label: str, trace: dict) -> RunView:
    other = trace.get("otherData", {})
    scalars = {f"metrics.{k}": float(v)
               for k, v in other.get("metrics", {}).items()
               if isinstance(v, (int, float))}
    ledger, quantiles = None, None
    report = other.get("report")
    if isinstance(report, dict):
        scalars.update(dict(flatten(report, prefix="report")))
        ledger = _aggregate_ledger(report)
    monitor = other.get("monitor")
    if isinstance(monitor, dict):
        quantiles = monitor.get("quantiles")
    return RunView(label, "trace", scalars, ledger=ledger, quantiles=quantiles)


def _view_from_jsonl(label: str, text: str) -> RunView:
    record = None
    for line in text.splitlines():
        line = line.strip()
        if line:
            record = json.loads(line)
    if record is None:
        raise ValueError(f"{label}: empty JSONL file")
    scalars = {f"metrics.{k}": float(v)
               for k, v in record.get("metrics", {}).items()
               if isinstance(v, (int, float))}
    monitor = record.get("monitor")
    quantiles = monitor.get("quantiles") if isinstance(monitor, dict) else None
    return RunView(label, "jsonl", scalars, quantiles=quantiles)


def classify(payload) -> str:
    if isinstance(payload, dict):
        if "traceEvents" in payload:
            return "trace"
        if "tenants" in payload:
            return "report"
        return "bench"
    raise ValueError("unsupported run payload (expected a JSON object)")


def view_from_payload(label: str, payload: dict) -> RunView:
    kind = classify(payload)
    if kind == "trace":
        return _view_from_trace(label, payload)
    if kind == "report":
        return _view_from_report(label, payload)
    view = RunView(label, "bench", dict(flatten(payload)))
    # A bench cell that embedded a monitor summary (the churn SLO cell
    # does) contributes its quantile streams too.
    q = _find_quantiles(payload)
    if q is not None:
        view.quantiles = q
    return view


def _find_quantiles(obj, depth: int = 3):
    """First ``{"quantiles": {stream: {stat: num}}}`` block, depth-first."""
    if not isinstance(obj, dict) or depth < 0:
        return None
    q = obj.get("quantiles")
    if isinstance(q, dict) and q and all(isinstance(v, dict) for v in q.values()):
        return q
    for v in obj.values():
        found = _find_quantiles(v, depth - 1)
        if found is not None:
            return found
    return None


def _git_show(rev: str, relpath: str, repo: "str | None" = None) -> str:
    out = subprocess.run(
        ["git", "show", f"{rev}:{relpath}"], capture_output=True, text=True,
        cwd=repo or os.getcwd(), timeout=60)
    if out.returncode != 0:
        raise ValueError(f"git show {rev}:{relpath}: {out.stderr.strip()}")
    return out.stdout


def load_run(spec: str, repo: "str | None" = None) -> RunView:
    """Build a RunView from a path, or ``PATH@GITREV`` for a committed
    revision of the file (resolved relative to ``repo`` / the cwd)."""
    path, _, rev = spec.partition("@")
    if rev:
        text = _git_show(rev, path, repo)
        label = spec
    else:
        with open(path) as f:
            text = f.read()
        label = path
    if path.endswith(".jsonl"):
        return _view_from_jsonl(label, text)
    try:
        payload = json.loads(text)
    except ValueError:
        return _view_from_jsonl(label, text)  # JSONL without the extension
    return view_from_payload(label, payload)


# ---------------------------------------------------------------- diffing

def _rel(a: float, b: float) -> float:
    if a == 0.0:
        return 0.0 if b == 0.0 else float("inf")
    return (b - a) / abs(a)


def diff_runs(a: RunView, b: RunView, top_k: int = 12) -> dict:
    """The three diff tables; every list pre-ranked, most movement first."""
    ledger_delta = []
    if a.ledger is not None and b.ledger is not None:
        causes = sorted(dict.fromkeys(list(a.ledger) + list(b.ledger)))
        for cause in causes:
            va, vb = a.ledger.get(cause, 0.0), b.ledger.get(cause, 0.0)
            ledger_delta.append({
                "cause": cause, "a": va, "b": vb, "delta": vb - va,
                "informational": cause in LEDGER_INFORMATIONAL})
        ledger_delta.sort(key=lambda r: (-abs(r["delta"]), r["cause"]))

    quantile_shift = []
    if a.quantiles is not None and b.quantiles is not None:
        streams = sorted(k for k in a.quantiles if k in b.quantiles)
        for stream in streams:
            qa, qb = a.quantiles[stream], b.quantiles[stream]
            for stat in sorted(k for k in qa if k in qb):
                va, vb = qa[stat], qb[stat]
                if not isinstance(va, (int, float)) or not isinstance(vb, (int, float)):
                    continue
                quantile_shift.append({
                    "stream": stream, "stat": stat, "a": va, "b": vb,
                    "delta": vb - va, "rel": _rel(va, vb)})
        quantile_shift.sort(
            key=lambda r: (-abs(r["rel"]), r["stream"], r["stat"]))

    rows = []
    for key in sorted(k for k in a.scalars if k in b.scalars):
        va, vb = a.scalars[key], b.scalars[key]
        if va == vb:
            continue
        rows.append({"metric": key, "a": va, "b": vb, "delta": vb - va,
                     "rel": _rel(va, vb)})
    rows.sort(key=lambda r: (-abs(r["rel"]), r["metric"]))
    only_a = sorted(k for k in a.scalars if k not in b.scalars)
    only_b = sorted(k for k in b.scalars if k not in a.scalars)

    return {
        "a": a.label, "b": b.label,
        "ledger_delta": ledger_delta,
        "quantile_shift": quantile_shift,
        "top_regressions": rows[:top_k],
        "n_changed": len(rows),
        "only_in_a": only_a,
        "only_in_b": only_b,
    }


def _fmt(v: float) -> str:
    if v != v or abs(v) == float("inf"):
        return "new" if v > 0 else str(v)
    if v == 0:
        return "0"
    if abs(v) >= 1000:
        return f"{v:,.0f}"
    if abs(v) >= 1:
        return f"{v:.4g}"
    return f"{v:.3e}"


def format_diff(diff: dict) -> str:
    """Human-readable rendering of a ``diff_runs`` result."""
    lines = [f"obsdiff: A = {diff['a']}", f"         B = {diff['b']}"]
    if diff["ledger_delta"]:
        lines.append("")
        lines.append("per-cause ledger delta (seconds, B - A):")
        for r in diff["ledger_delta"]:
            note = "  [informational]" if r["informational"] else ""
            lines.append(f"  {r['cause']:28s} {_fmt(r['a']):>12s} -> "
                         f"{_fmt(r['b']):>12s}  d={_fmt(r['delta']):>10s}{note}")
    if diff["quantile_shift"]:
        lines.append("")
        lines.append("quantile distribution shift (B - A):")
        for r in diff["quantile_shift"]:
            lines.append(
                f"  {r['stream'] + '.' + r['stat']:36s} "
                f"{_fmt(r['a']):>12s} -> {_fmt(r['b']):>12s}  "
                f"({_fmt(100 * r['rel']):>8s}%)")
    lines.append("")
    lines.append(f"top regressions by relative change "
                 f"({len(diff['top_regressions'])} of {diff['n_changed']} changed):")
    for r in diff["top_regressions"]:
        lines.append(
            f"  {r['metric']:52s} {_fmt(r['a']):>12s} -> {_fmt(r['b']):>12s}  "
            f"({_fmt(100 * r['rel']):>8s}%)")
    if not diff["top_regressions"]:
        lines.append("  (no common scalar moved)")
    for side, keys in (("A", diff["only_in_a"]), ("B", diff["only_in_b"])):
        if keys:
            shown = ", ".join(keys[:6]) + (" ..." if len(keys) > 6 else "")
            lines.append(f"only in {side}: {len(keys)} metric(s): {shown}")
    return "\n".join(lines)
