"""Property-test shim: hypothesis when available, deterministic fallback when not.

The test suite's invariants (pool validity, schedule monotonicity, kernel
oracles) are expressed as properties over generated inputs.  ``hypothesis``
is an optional dependency; this module re-exports its ``given``/``settings``/
``strategies`` when installed and otherwise substitutes a miniature,
deterministic generator so the same property functions still execute against
a fixed, seeded sample set (boundary values first, then pseudo-random draws).

Usage in tests:

    from repro.testing import given, settings, st
"""

from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import functools
    import inspect
    import random

    FALLBACK_EXAMPLES = 12

    class _Strategy:
        """Mini strategy: ``example(rnd, i)`` yields the i-th deterministic
        draw; i == 0/1 hit the boundaries so degenerate cases always run."""

        def example(self, rnd: random.Random, i: int):
            raise NotImplementedError

    class _Integers(_Strategy):
        def __init__(self, lo: int, hi: int):
            self.lo, self.hi = lo, hi

        def example(self, rnd, i):
            if i == 0:
                return self.lo
            if i == 1:
                return self.hi
            return rnd.randint(self.lo, self.hi)

    class _Floats(_Strategy):
        def __init__(self, lo: float, hi: float):
            self.lo, self.hi = lo, hi

        def example(self, rnd, i):
            if i == 0:
                return self.lo
            if i == 1:
                return self.hi
            return rnd.uniform(self.lo, self.hi)

        def filter(self, pred):
            return _Filtered(self, pred)

    class _Filtered(_Strategy):
        def __init__(self, base: _Strategy, pred):
            self.base, self.pred = base, pred

        def example(self, rnd, i):
            for attempt in range(100):
                x = self.base.example(rnd, i if attempt == 0 else 2)
                if self.pred(x):
                    return x
            raise ValueError("fallback filter rejected 100 consecutive draws")

    class _SampledFrom(_Strategy):
        def __init__(self, seq):
            self.seq = list(seq)

        def example(self, rnd, i):
            if i < len(self.seq):
                return self.seq[i]
            return rnd.choice(self.seq)

    class _Tuples(_Strategy):
        def __init__(self, *members):
            self.members = members

        def example(self, rnd, i):
            return tuple(m.example(rnd, i) for m in self.members)

    class _Lists(_Strategy):
        def __init__(self, elem: _Strategy, min_size: int = 0, max_size: int = 10):
            self.elem, self.min_size, self.max_size = elem, min_size, max_size

        def example(self, rnd, i):
            if i == 0:
                size = self.min_size
            elif i == 1:
                size = self.max_size
            else:
                size = rnd.randint(self.min_size, self.max_size)
            # Element draws use index >= 2 so list contents vary even in the
            # boundary-size examples.
            return [self.elem.example(rnd, 2) for _ in range(size)]

    class _StrategiesNamespace:
        @staticmethod
        def integers(min_value: int, max_value: int) -> _Strategy:
            return _Integers(min_value, max_value)

        @staticmethod
        def floats(min_value: float, max_value: float) -> _Strategy:
            return _Floats(min_value, max_value)

        @staticmethod
        def sampled_from(seq) -> _Strategy:
            return _SampledFrom(seq)

        @staticmethod
        def tuples(*members) -> _Strategy:
            return _Tuples(*members)

        @staticmethod
        def lists(elem, min_size: int = 0, max_size: int = 10) -> _Strategy:
            return _Lists(elem, min_size=min_size, max_size=max_size)

    st = _StrategiesNamespace()

    def settings(*_args, **_kwargs):
        """Accepted for source compatibility; the fallback runs a fixed
        number of deterministic examples regardless."""

        def deco(fn):
            return fn

        return deco

    def given(*arg_strategies, **kw_strategies):
        def deco(fn):
            # Positional strategies fill the *trailing* params (hypothesis
            # semantics), so resolve them to names up front and pass every
            # draw by keyword — fixtures bound to leading params stay intact.
            params = list(inspect.signature(fn).parameters.values())
            if arg_strategies:
                pos_names = [p.name for p in params[-len(arg_strategies):]]
                params = params[: -len(arg_strategies)]
            else:
                pos_names = []
            params = [p for p in params if p.name not in kw_strategies]
            strategies = dict(zip(pos_names, arg_strategies)) | kw_strategies

            @functools.wraps(fn)
            def wrapper(*call_args, **call_kwargs):
                rnd = random.Random(fn.__qualname__)
                for i in range(FALLBACK_EXAMPLES):
                    draws = {k: s.example(rnd, i) for k, s in strategies.items()}
                    fn(*call_args, **call_kwargs, **draws)

            # Hide the strategy-supplied parameters from pytest's fixture
            # resolution (hypothesis does the same).
            wrapper.__signature__ = inspect.Signature(params)
            del wrapper.__wrapped__
            return wrapper

        return deco


__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]
