"""Deterministic sharded token pipeline + host-side prefetch.

Design constraints for 1000+-node runs:
  * determinism: batch contents are a pure function of (seed, step, shard) —
    restart/elastic-resize replays identically, no data-loss on failover;
  * host sharding: each host materializes only its slice of the global batch
    (shard = process_index), disjoint by construction;
  * prefetch: a background thread keeps a bounded queue of ready batches so
    host data work overlaps device compute.

Synthetic corpus: a seeded Philox stream over the vocab with a Zipf-ish skew,
plus shifted-label construction.  Swapping in a real tokenized corpus only
requires replacing ``SyntheticTokens._materialize``.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator

import numpy as np


def host_shard_info(global_batch: int, num_hosts: int, host_id: int) -> tuple[int, int]:
    """(local_batch, offset) for this host's slice of the global batch."""
    assert global_batch % num_hosts == 0, (global_batch, num_hosts)
    local = global_batch // num_hosts
    return local, host_id * local


@dataclass
class SyntheticTokens:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    num_hosts: int = 1
    host_id: int = 0

    def batch_at(self, step: int) -> dict:
        """Materialize this host's batch for a given step (pure function)."""
        local, offset = host_shard_info(self.global_batch, self.num_hosts, self.host_id)
        rng = np.random.Generator(
            np.random.Philox(key=self.seed, counter=[0, 0, step, self.host_id])
        )
        # Zipf-ish skew without scipy: mix a geometric head with a uniform tail.
        head = rng.geometric(p=64.0 / self.vocab_size, size=(local, self.seq_len + 1))
        uni = rng.integers(0, self.vocab_size, size=(local, self.seq_len + 1))
        use_head = rng.random((local, self.seq_len + 1)) < 0.5
        toks = np.where(use_head, np.minimum(head, self.vocab_size - 1), uni)
        toks = toks.astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch with a bounded queue (double buffering)."""

    def __init__(self, it: Iterator[dict], depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._err: BaseException | None = None
        self._stop = threading.Event()

        def run():
            try:
                for item in it:
                    if self._stop.is_set():
                        return
                    self._q.put(item)
            except BaseException as e:  # surfaced on next __next__
                self._err = e
            finally:
                self._q.put(None)

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def __iter__(self):
        return self

    def __next__(self) -> dict:
        item = self._q.get()
        if item is None:
            if self._err is not None:
                raise self._err
            raise StopIteration
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
