from .pipeline import Prefetcher, SyntheticTokens, host_shard_info

__all__ = ["Prefetcher", "SyntheticTokens", "host_shard_info"]
