"""Launcher glue: one call implements ``--verify`` for every launch CLI.

``verify_launch(args, programs=..., recorder=..., report=...)`` is a no-op
unless the parsed args carry ``verify=True`` (installed uniformly by
``repro.obs.add_obs_args``).  When active it sweeps every solved
``MemoryProgram`` with the static plan verifier and the attached
``ObsRecorder`` with the event-log race detector, prints one summary per
certificate, and raises ``SystemExit`` if any invariant failed — so a
``--verify`` run is green only when the whole session is proved, not just
simulated.
"""

from __future__ import annotations

from .certificate import Certificate
from .plan_check import verify_program
from .schedule_check import verify_recorder


def _emit(label: str, cert: Certificate) -> None:
    n = len(cert.checks)
    if cert.ok:
        print(f"[verify] {label}: ok ({n} invariants)")
        return
    print(f"[verify] {label}: FAIL ({', '.join(cert.failed())})")
    for line in cert.summary_lines():
        print(f"[verify]   {line}")


def verify_launch(args, programs=None, recorder=None, report=None) -> None:
    """Verify one launcher run; raise ``SystemExit`` on the first failure.

    ``programs`` is a ``{name: MemoryProgram}`` mapping (solved or
    cache-restored), ``recorder`` the run's ``ObsRecorder`` (or None) and
    ``report`` its ``RuntimeReport``.
    """
    if not getattr(args, "verify", False):
        return
    ok = True
    for name, program in sorted((programs or {}).items()):
        cert = verify_program(program)
        _emit(f"plan {name}", cert)
        ok = ok and cert.ok
    if recorder is not None:
        cert = verify_recorder(recorder, report)
        _emit("schedule", cert)
        ok = ok and cert.ok
    if not ok:
        raise SystemExit("[verify] FAILED: invariant violations above")
