"""repro.analyze: static verification of solved plans and simulated schedules.

Three layers, three proof surfaces (ISSUE 9):

  * ``plan_check`` — interval-sweep verifier over a solved ``MemoryProgram``:
    proves pool placements sharing addresses have disjoint lifetimes, swap
    windows contain no reads/writes, no variable is double-resident, and the
    resident floor respects the plan's HBM limit.  Emits a ``Certificate``
    that ``plan.artifact`` embeds in artifacts and re-checks on cache load.
  * ``schedule_check`` — happens-before race detector over runtime event
    logs (``ObsRecorder`` streams, ``record_events`` channel logs, exported
    Chrome traces): channel/lane exclusivity, blackout exclusion,
    accountant monotonicity, reservation isolation, ledger closure.
  * ``tools/lint_determinism.py`` — the jax-free AST lint guarding the
    bit-for-bit reference pins (lives in tools/, not importable state).

Everything here is import-light (stdlib only; the checked objects come in
duck-typed), so verification runs where jax is unavailable.
"""

from .certificate import Certificate, Violation
from .driver import verify_launch
from .plan_check import verify_pool_plan, verify_program, verify_swap_summary
from .schedule_check import (
    ScheduleView,
    check_view,
    verify_recorder,
    verify_trace_file,
    view_from_recorder,
    view_from_runtime,
    view_from_trace,
)

__all__ = [
    "Certificate",
    "Violation",
    "verify_launch",
    "verify_program",
    "verify_pool_plan",
    "verify_swap_summary",
    "ScheduleView",
    "check_view",
    "verify_recorder",
    "verify_trace_file",
    "view_from_recorder",
    "view_from_runtime",
    "view_from_trace",
]
