"""Certificate: the serializable verdict of a static-analysis pass.

One ``Certificate`` summarizes a full verification run: every invariant the
verifier knows about appears in ``checks`` (pass/fail + how many subjects it
swept + capped counterexamples), so a consumer can distinguish "proved" from
"not applicable" — an invariant with zero subjects passed vacuously and says
so.  ``plan.artifact`` embeds the dict form in plan artifacts (outside the
canonical plan-identity bytes, like ``solve_ms``) and re-derives it on every
cache load; the mutation self-tests assert *which* invariant a hazard kills.
"""

from __future__ import annotations

from dataclasses import dataclass, field

CERTIFICATE_VERSION = 1

# Counterexamples kept per invariant: enough to localize the hazard without
# bloating artifacts when a mutation breaks every placement at once.
MAX_VIOLATIONS = 8


@dataclass(frozen=True)
class Violation:
    """One counterexample: which invariant broke, where, and the op/var
    indices that witness it."""

    invariant: str
    subject: str                       # e.g. "pool:best_fit", "swap:swdoa@123"
    message: str
    ops: tuple[int, ...] = ()          # op indices of the counterexample
    vars: tuple[int, ...] = ()         # variable ids involved

    def to_dict(self) -> dict:
        return {
            "invariant": self.invariant,
            "subject": self.subject,
            "message": self.message,
            "ops": list(self.ops),
            "vars": list(self.vars),
        }


@dataclass
class Certificate:
    """Per-invariant pass/fail over one verification sweep."""

    version: int = CERTIFICATE_VERSION
    # invariant name -> {"ok": bool, "subjects": int, "violations": [...]}
    checks: dict[str, dict] = field(default_factory=dict)
    notes: list[str] = field(default_factory=list)

    @property
    def ok(self) -> bool:
        return all(c["ok"] for c in self.checks.values())

    def failed(self) -> list[str]:
        """Names of the invariants that did not hold, sorted."""
        return sorted(n for n, c in self.checks.items() if not c["ok"])

    def add(self, invariant: str, subjects: int, violations: list[Violation]) -> None:
        """Record one invariant's sweep.  Repeated calls for the same
        invariant (one per subject) accumulate."""
        entry = self.checks.setdefault(
            invariant, {"ok": True, "subjects": 0, "violations": []}
        )
        entry["subjects"] += subjects
        for v in violations:
            entry["ok"] = False
            if len(entry["violations"]) < MAX_VIOLATIONS:
                entry["violations"].append(v.to_dict())

    def note(self, msg: str) -> None:
        self.notes.append(msg)

    def violations(self) -> list[dict]:
        out = []
        for name in sorted(self.checks):
            out.extend(self.checks[name]["violations"])
        return out

    # ------------------------------------------------------------- serde
    def to_dict(self) -> dict:
        return {
            "version": self.version,
            "ok": self.ok,
            "checks": {
                n: {
                    "ok": c["ok"],
                    "subjects": c["subjects"],
                    "violations": list(c["violations"]),
                }
                for n, c in sorted(self.checks.items())
            },
            "notes": list(self.notes),
        }

    @classmethod
    def from_dict(cls, d: dict) -> "Certificate":
        cert = cls(version=d.get("version", CERTIFICATE_VERSION))
        for n, c in d.get("checks", {}).items():
            cert.checks[n] = {
                "ok": bool(c.get("ok", False)),
                "subjects": int(c.get("subjects", 0)),
                "violations": list(c.get("violations", ())),
            }
        cert.notes = list(d.get("notes", ()))
        return cert

    # ------------------------------------------------------------ display
    def summary_lines(self) -> list[str]:
        lines = []
        for name in sorted(self.checks):
            c = self.checks[name]
            mark = "ok  " if c["ok"] else "FAIL"
            line = f"{mark} {name}: {c['subjects']} subject(s)"
            if not c["ok"]:
                first = c["violations"][0]
                line += f" — {first['message']}"
            lines.append(line)
        lines.extend(f"note {n}" for n in self.notes)
        return lines
