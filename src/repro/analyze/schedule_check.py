"""Event-log race detector: happens-before checks over simulated schedules.

Input is any of the three event surfaces the runtime produces — a live
``ObsRecorder``, an exported Chrome trace file, or a finished runtime's
``record_events`` transfer logs — normalized into one ``ScheduleView`` and
swept by ``check_view``:

  channel_exclusive      transfers on one (device, channel) DMA queue never
                         overlap — the engine's ``free_at`` serialization
  lane_exclusive         transfers on one host-link lane never overlap
  blackout_exclusion     a swap-out transfer never overlaps a collective
                         blackout that was registered before the transfer
                         was acquired.  Observable registration order: a
                         swap-out's ``ready_t`` equals the acquiring
                         tenant's clock, and the event heap pops in
                         nondecreasing clock order, so ``blackout.start <
                         ready_t`` proves the blackout was already on the
                         link when ``next_clear`` placed the transfer.
                         Blackouts registered *after* acquisition may
                         legitimately overlap ("lagging tenants may still
                         schedule into earlier windows"), and swap-ins have
                         ``ready_t >= clock`` (they also wait on their own
                         swap-out), so only outs are checked.
  budget_monotone        when the accountant reported zero overflow events,
                         every sampled pool total respects the budget; all
                         samples respect the reported peaks unconditionally
  reservation_isolation  per-device admission floors — reconstructed from
                         admissions, finishes and applied renegotiations —
                         never sum past the budget, and no tenant is
                         admitted twice or before it arrived
  ledger_closure         every completed tenant's stall-attribution buckets
                         sum to its ``overhead_s``, and the aggregate
                         ledger is the per-key sum of the tenant ledgers

Everything is stdlib-only and duck-typed so the sweep runs jax-free
(``python -m repro.launch.analyze``) and inside ``tools/check_trace.py``.
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field

from .certificate import Certificate, Violation

SCHEDULE_INVARIANTS = (
    "channel_exclusive",
    "lane_exclusive",
    "blackout_exclusion",
    "budget_monotone",
    "reservation_isolation",
    "ledger_closure",
)

# Attribution keys outside the sums-to-overhead closure (mirrors
# tools/check_trace.py): the total itself, admission queueing (precedes the
# overhead window) and host wall-clock.
LEDGER_INFORMATIONAL = {"overhead_s", "queue_wait_s", "renegotiation_solve_s"}

_US = 1e6


def _tol(x: float) -> float:
    return 1e-6 + 1e-9 * abs(x)


def _dev(device) -> str:
    return "default" if device is None else str(device)


@dataclass(frozen=True)
class Transfer:
    """One swap transfer as scheduled: ``ready`` is the instant the engine
    asked for the channel (None when the source log did not record it)."""

    tenant: str
    device: str
    direction: str                 # "in" | "out"
    var: int
    start: float
    end: float
    channel: "int | None"
    lane: "int | None" = None
    ready: "float | None" = None
    size: int = 0


@dataclass
class ScheduleView:
    """Normalized event log: the one shape every checker consumes."""

    source: str = "?"
    transfers: list = field(default_factory=list)        # [Transfer]
    blackouts: list = field(default_factory=list)        # [(start, end)]
    admissions: list = field(default_factory=list)       # [(name, device, arrival, admit)]
    finishes: list = field(default_factory=list)         # [(name, device, t)]
    renegotiations: list = field(default_factory=list)   # [(kind, victim, t, value)]
    hbm_samples: dict = field(default_factory=dict)      # device -> [total bytes]
    report: "dict | None" = None                         # RuntimeReport.as_dict()


# ------------------------------------------------------------- view builders
def _report_dict(report):
    if report is None or isinstance(report, dict):
        return report
    return report.as_dict()


def view_from_recorder(recorder, report=None) -> ScheduleView:
    """Richest view: the ``ObsRecorder`` streams carry channel, lane and
    ``ready_t`` for every transfer and unmerged blackout windows."""
    view = ScheduleView(source="recorder", report=_report_dict(report))
    for name, device, direction, var, start, end, ch, lane, ready, size in recorder.transfers:
        view.transfers.append(Transfer(
            name, _dev(device), direction, var, start, end, ch, lane, ready, size
        ))
    view.blackouts = list(recorder.blackouts)
    view.admissions = [(n, _dev(d), a, t) for n, d, a, t in recorder.admissions]
    view.finishes = [(n, _dev(d), t) for n, d, t in recorder.finishes]
    view.renegotiations = list(recorder.renegotiations)
    for name, device, _i, _t0, _t1, _resident, total in recorder.ops:
        view.hbm_samples.setdefault(_dev(device), []).append(total)
    return view


def view_from_runtime(rt, report=None) -> ScheduleView:
    """Fallback view from a finished runtime's ``record_events`` logs:
    per-run ``out_events`` / ``in_events`` are ``(var, start, end, ch)`` —
    no lanes, no ``ready_t``, so only channel exclusivity has subjects."""
    view = ScheduleView(source="runtime", report=_report_dict(report))
    for run in getattr(rt, "runs", []):
        dev = _dev(getattr(run, "device", None))
        for direction, events in (("out", getattr(run, "out_events", ())),
                                  ("in", getattr(run, "in_events", ()))):
            for ev in events:
                var, start, end = ev[0], ev[1], ev[2]
                ch = ev[3] if len(ev) > 3 else None
                view.transfers.append(Transfer(
                    run.name, dev, direction, int(var), float(start),
                    float(end), ch,
                ))
    return view


def view_from_trace(trace: dict, source: str = "trace") -> ScheduleView:
    """Rebuild a view from exported Chrome trace JSON (``trace_export``
    layout): DMA rows give channel + ``queued_us`` (hence ``ready``), link
    lane rows are matched back to their DMA slice by (tenant, direction,
    var, ts, dur) — the exporter writes both from the same floats.  The
    trace's blackout row is merged; merging only widens window starts, so
    ``blackout_exclusion`` stays sound for the committed deterministic
    traces but the recorder view is the authoritative surface."""
    view = ScheduleView(source=source)
    events = trace.get("traceEvents", [])
    other = trace.get("otherData", {})
    view.report = other.get("report")

    thread_names: dict[tuple, str] = {}
    for e in events:
        if e.get("ph") == "M" and e.get("name") == "thread_name":
            thread_names[(e["pid"], e.get("tid", 0))] = e["args"]["name"]

    lane_of: dict[tuple, int] = {}
    dma: list[tuple] = []
    for e in events:
        ph, pid = e.get("ph"), e.get("pid")
        name = e.get("name", "")
        tname = thread_names.get((pid, e.get("tid", 0)), "")
        if ph == "X" and pid == 2 and ":v" in name:
            direction, var = name.split(":v", 1)
            dev, _, ch = tname.rpartition("/ch")
            args = e.get("args", {})
            dma.append((args.get("tenant", "?"), dev or "default", direction,
                        int(var), e["ts"], e["dur"],
                        int(ch) if ch.isdigit() else None,
                        args.get("queued_us", 0.0), args.get("bytes", 0)))
        elif ph == "X" and pid == 3 and name == "blackout":
            view.blackouts.append((e["ts"] / _US, (e["ts"] + e["dur"]) / _US))
        elif ph == "X" and pid == 3 and ":v" in name and tname.startswith("lane"):
            direction, var = name.split(":v", 1)
            key = (e.get("args", {}).get("tenant", "?"), direction,
                   int(var), e["ts"], e["dur"])
            lane_of[key] = int(tname[4:])
        elif ph == "C" and pid == 4 and name.startswith("HBM ["):
            dev = name[5:-1]
            view.hbm_samples.setdefault(dev, []).append(
                e.get("args", {}).get("bytes", 0))
        elif ph == "i" and pid == 1:
            tenant = tname or "?"
            if name == "admitted":
                dev = e.get("args", {}).get("device", "default")
                view.admissions.append((tenant, dev, None, e["ts"] / _US))
            elif name == "finished":
                view.finishes.append((tenant, None, e["ts"] / _US))
            elif name.startswith("renegotiation "):
                kind = name.split(" ", 1)[1]
                args = e.get("args", {})
                value = args.get("freed_bytes", args.get("new_limit", 0))
                view.renegotiations.append((kind, tenant, e["ts"] / _US, value))

    arrivals: dict[str, float] = {}
    for e in events:
        if (e.get("ph") == "X" and e.get("pid") == 1
                and e.get("name") == "queued"):
            tenant = thread_names.get((1, e.get("tid", 0)), "?")
            arrivals[tenant] = e["ts"] / _US
    view.admissions = [
        (n, d, arrivals.get(n, t), t) for n, d, _a, t in view.admissions
    ]
    for tenant, dev, direction, var, ts, dur, ch, queued_us, size in dma:
        lane = lane_of.get((tenant, direction, var, ts, dur))
        view.transfers.append(Transfer(
            tenant, dev, direction, var, ts / _US, (ts + dur) / _US, ch,
            lane, (ts - queued_us) / _US, size,
        ))
    return view


# ------------------------------------------------------------------- checks
def _exclusive(groups: dict, invariant: str, what: str) -> list[Violation]:
    out = []
    for key, ts in sorted(groups.items()):
        ts.sort(key=lambda t: (t.start, t.end))
        prev = None
        for t in ts:
            if prev is not None and t.start < prev.end - _tol(prev.end):
                out.append(Violation(
                    invariant, f"{what}:{key}",
                    f"{t.direction}:v{t.var} ({t.tenant}) starts at "
                    f"{t.start:.6f}s before {prev.direction}:v{prev.var} "
                    f"({prev.tenant}) ends at {prev.end:.6f}s on {what} {key}",
                    vars=(t.var, prev.var),
                ))
            if prev is None or t.end > prev.end:
                prev = t
    return out


def check_view(view: ScheduleView) -> Certificate:
    cert = Certificate()
    for name in SCHEDULE_INVARIANTS:
        cert.add(name, 0, [])
    report = view.report

    # -- channel / lane exclusivity
    by_ch: dict = {}
    by_lane: dict = {}
    for t in view.transfers:
        if t.channel is not None:
            by_ch.setdefault(f"{t.device}/ch{t.channel}", []).append(t)
        if t.lane is not None:
            by_lane.setdefault(t.lane, []).append(t)
    cert.add("channel_exclusive", len(by_ch),
             _exclusive(by_ch, "channel_exclusive", "channel"))
    cert.add("lane_exclusive", len(by_lane),
             _exclusive(by_lane, "lane_exclusive", "lane"))

    # -- blackout exclusion (swap-outs with a recorded ready instant only)
    blackouts = sorted(view.blackouts)
    outs = [t for t in view.transfers
            if t.direction == "out" and t.ready is not None and t.lane is not None]
    violations = []
    for t in outs:
        for bs, be in blackouts:
            if bs >= t.end:
                break
            overlaps = bs < t.end - _tol(t.end) and t.start < be - _tol(be)
            if overlaps and bs < t.ready - _tol(t.ready):
                violations.append(Violation(
                    "blackout_exclusion", f"lane:{t.lane}",
                    f"out:v{t.var} ({t.tenant}) on lane {t.lane} spans "
                    f"[{t.start:.6f}, {t.end:.6f})s across a blackout "
                    f"[{bs:.6f}, {be:.6f})s that was already registered at "
                    f"its ready instant {t.ready:.6f}s",
                    vars=(t.var,),
                ))
    cert.add("blackout_exclusion", len(outs), violations)

    # -- accountant monotonicity over the sampled pool totals
    violations = []
    samples = sum(len(v) for v in view.hbm_samples.values())
    if report is None:
        if samples:
            cert.note("budget_monotone: no report attached; "
                      "budget/peak bounds unchecked")
        cert.add("budget_monotone", 0, [])
    else:
        budget = report.get("budget")
        overflow = report.get("overflow_events", 0)
        device_peaks = report.get("device_peaks")
        for dev, totals in sorted(view.hbm_samples.items()):
            top = max(totals)
            if budget is not None and overflow == 0 and top > budget:
                violations.append(Violation(
                    "budget_monotone", f"device:{dev}",
                    f"pool total {top} exceeds budget {budget} on {dev} but "
                    "the accountant reported zero overflow events",
                ))
            peak = (device_peaks or {}).get(dev) if device_peaks else \
                report.get("aggregate_peak")
            if peak is not None and top > peak:
                violations.append(Violation(
                    "budget_monotone", f"device:{dev}",
                    f"sampled pool total {top} on {dev} exceeds the "
                    f"reported peak {peak}",
                ))
        cert.add("budget_monotone", samples, violations)

    # -- reservation isolation: rebuilt admission-floor timeline
    violations = []
    if report is None:
        cert.add("reservation_isolation", 0, [])
        if view.admissions:
            cert.note("reservation_isolation: no report attached; "
                      "floor timeline unchecked")
    else:
        budget = report.get("budget")
        tenants = {t["name"]: t for t in report.get("tenants", ())}
        freed: dict[str, int] = {}
        for kind, victim, _t, value in view.renegotiations:
            if kind == "applied":
                freed[victim] = freed.get(victim, 0) + value

        seen_admit: dict[str, float] = {}
        timeline: list[tuple[float, int, str, str, int]] = []
        for name, device, arrival, admit in view.admissions:
            if name in seen_admit:
                violations.append(Violation(
                    "reservation_isolation", f"tenant:{name}",
                    f"{name} admitted twice (at {seen_admit[name]:.6f}s and "
                    f"{admit:.6f}s) — double-admit double-charges its floor",
                ))
                continue
            seen_admit[name] = admit
            if arrival is not None and admit < arrival - _tol(arrival):
                violations.append(Violation(
                    "reservation_isolation", f"tenant:{name}",
                    f"{name} admitted at {admit:.6f}s before its arrival "
                    f"{arrival:.6f}s",
                ))
            rep = tenants.get(name)
            if rep is None:
                continue
            floor0 = rep.get("floor", 0) + freed.get(name, 0)
            timeline.append((admit, 1, "admit", name, floor0))
        for kind, victim, t, value in view.renegotiations:
            if kind == "applied":
                timeline.append((t, 0, "renegotiate", victim, -value))
        for name, _device, t in view.finishes:
            rep = tenants.get(name)
            if rep is not None and name in seen_admit:
                timeline.append((t, 0, "finish", name, -rep.get("floor", 0)))

        if budget is not None and timeline:
            # Floors live on the tenant's device pool; ties at one instant
            # release (finish/renegotiate, sort key 0) before they admit.
            dev_of = {n: _dev(tenants.get(n, {}).get("device"))
                      for n in set(x[3] for x in timeline)}
            level: dict[str, int] = {}
            for t, _k, what, name, delta in sorted(
                    timeline, key=lambda x: (x[0], x[1])):
                dev = dev_of[name]
                level[dev] = level.get(dev, 0) + delta
                if level[dev] > budget:
                    violations.append(Violation(
                        "reservation_isolation", f"device:{dev}",
                        f"admission floors sum to {level[dev]} > budget "
                        f"{budget} on {dev} after {what} of {name} at "
                        f"{t:.6f}s",
                    ))
        cert.add("reservation_isolation", len(view.admissions), violations)

    # -- ledger closure
    violations = []
    checked = 0
    if report is not None:
        sums: dict[str, float] = {}
        for t in report.get("tenants", ()):
            if t.get("status") != "completed":
                continue
            ledger = t.get("attribution")
            if not isinstance(ledger, dict):
                continue
            checked += 1
            total = ledger.get("overhead_s", 0.0)
            summed = sum(v for k, v in ledger.items()
                         if k not in LEDGER_INFORMATIONAL)
            if abs(summed - total) > _tol(total):
                violations.append(Violation(
                    "ledger_closure", f"tenant:{t.get('name')}",
                    f"attribution buckets sum to {summed!r} but overhead_s "
                    f"is {total!r}",
                ))
            for k, v in ledger.items():
                if isinstance(v, (int, float)):
                    sums[k] = sums.get(k, 0.0) + v
        agg = report.get("attribution")
        if isinstance(agg, dict) and checked:
            for k, v in agg.items():
                if not isinstance(v, (int, float)):
                    continue
                got = sums.get(k, 0.0)
                if abs(got - v) > _tol(v):
                    violations.append(Violation(
                        "ledger_closure", "aggregate",
                        f"aggregate ledger {k}={v!r} but tenant ledgers "
                        f"sum to {got!r}",
                    ))
    cert.add("ledger_closure", checked, violations)
    cert.note(f"source: {view.source}; {len(view.transfers)} transfer(s), "
              f"{len(view.blackouts)} blackout(s), "
              f"{len(view.admissions)} admission(s)")
    return cert


# ------------------------------------------------------------- entry points
def verify_recorder(recorder, report=None) -> Certificate:
    return check_view(view_from_recorder(recorder, report))


def verify_trace_file(path: str) -> Certificate:
    with open(path) as f:
        trace = json.load(f)
    return check_view(view_from_trace(trace, source=path))
