"""Static plan verifier: prove a solved ``MemoryProgram`` safe by sweep.

No simulation, no solver re-run — every invariant is an interval sweep over
the trace's exact lifetime/access semantics (the paper's core premise: the
iterative process makes these known and fixed):

  pool_disjoint_lifetimes  placements whose [offset, offset+size) byte
                           ranges intersect have disjoint lifetimes
  pool_bounds              every placement fits the claimed footprint and
                           chi >= omega (footprint >= aligned peak load)
  pool_lookup              the runtime malloc lookup table agrees with the
                           placement offsets
  swap_well_formed         decisions reference real variables at their real
                           sizes, with window endpoints on real accesses
  swap_in_before_read      no read falls strictly inside an absence window:
                           the swap-in at ``in_before`` precedes the first
                           post-swap-out read by construction
  swap_out_after_write     no write falls strictly inside an absence window:
                           the swap-out at ``out_after`` captures the last
                           write before the gap (no lost update)
  swap_single_residency    at most one absence window per variable — two
                           would double-install transfer events and make the
                           variable transiently double-resident
  swap_budget              the resident floor (load curve minus absence
                           windows, the engine's admission reservation)
                           equals the floor the solver committed to
                           (``planned_floor``) — any dropped or tampered
                           decision changes the recomputed floor and breaks
                           the claim.  Greedy selection is best-effort, so
                           a committed floor above the limit is a legitimate
                           solver outcome (noted, not a violation).  Legacy
                           summaries without a committed floor fall back to
                           floor <= limit, vacuous when the limit is
                           declared infeasible (limit < load_min)

Absence-window accounting matches ``runtime.engine.planned_peak`` exactly:
a non-wrap decision is absent on [out_after, in_before), a wrap decision on
[0, in_before) and [out_after, num_indices).  Hazard windows are strictly
interior — the accesses *at* ``out_after``/``in_before`` are the transfer
triggers, not hazards.
"""

from __future__ import annotations

from .certificate import Certificate, Violation

POOL_INVARIANTS = ("pool_disjoint_lifetimes", "pool_bounds", "pool_lookup")
SWAP_INVARIANTS = (
    "swap_well_formed",
    "swap_in_before_read",
    "swap_out_after_write",
    "swap_single_residency",
    "swap_budget",
)
ALL_INVARIANTS = POOL_INVARIANTS + SWAP_INVARIANTS

DEFAULT_ALIGNMENT = 256  # smartpool.solve's default packing granularity


def _aligned(size: int, alignment: int) -> int:
    a1 = alignment - 1
    return (size + a1) // alignment * alignment


# --------------------------------------------------------------- pool checks
def verify_pool_plan(trace, plan, alignment: int = DEFAULT_ALIGNMENT,
                     subject: str = "pool") -> list[Violation]:
    """Sweep one ``AllocationPlan`` against the trace lifetimes."""
    out: list[Violation] = []
    placed = [v for v in trace.variables if v.size > 0]

    # -- bounds + completeness
    for v in placed:
        off = plan.offsets.get(v.var)
        if off is None:
            out.append(Violation(
                "pool_bounds", subject,
                f"variable v{v.var} ({v.size}B) has no placement",
                ops=(v.alloc_index,), vars=(v.var,),
            ))
            continue
        end = off + _aligned(v.size, alignment)
        if off < 0 or end > plan.footprint:
            out.append(Violation(
                "pool_bounds", subject,
                f"v{v.var} at [{off}, {end}) exceeds footprint {plan.footprint}",
                ops=(v.alloc_index,), vars=(v.var,),
            ))
    if plan.footprint < plan.peak_load:
        out.append(Violation(
            "pool_bounds", subject,
            f"footprint {plan.footprint} < peak load {plan.peak_load} "
            "(chi < omega is impossible)",
        ))

    # -- lookup table agrees with offsets (skip alloc indices two variables
    #    share: the table is keyed by malloc op and cannot represent both)
    alloc_count: dict[int, int] = {}
    for v in placed:
        alloc_count[v.alloc_index] = alloc_count.get(v.alloc_index, 0) + 1
    for v in placed:
        if v.var not in plan.offsets or alloc_count[v.alloc_index] > 1:
            continue
        got = plan.lookup.get(v.alloc_index)
        if got is not None and got != plan.offsets[v.var]:
            out.append(Violation(
                "pool_lookup", subject,
                f"lookup[{v.alloc_index}] = {got} but v{v.var} is placed "
                f"at {plan.offsets[v.var]}",
                ops=(v.alloc_index,), vars=(v.var,),
            ))

    # -- disjointness: interval sweep over (alloc, free) events.  At each
    #    alloc the new byte range is probed against the active set (sorted
    #    by offset); frees at an index precede allocs at the same index
    #    (free_index is exclusive, VariableInfo.overlaps is strict).
    import bisect

    events: list[tuple[int, int, object]] = []  # (index, kind 0=free 1=alloc, var)
    for v in placed:
        if v.var not in plan.offsets:
            continue
        events.append((v.alloc_index, 1, v))
        events.append((v.free_index, 0, v))
    events.sort(key=lambda e: (e[0], e[1], e[2].var))

    active_offs: list[int] = []        # sorted offsets of live placements
    active: dict[int, tuple[int, object]] = {}  # offset -> (end, VariableInfo)
    for _idx, kind, v in events:
        off = plan.offsets[v.var]
        end = off + _aligned(v.size, alignment)
        if kind == 0:
            if active.get(off, (None, None))[1] is v:
                del active[off]
                active_offs.pop(bisect.bisect_left(active_offs, off))
            continue
        i = bisect.bisect_left(active_offs, off)
        for j in (i - 1, i):
            if 0 <= j < len(active_offs):
                o_off = active_offs[j]
                o_end, other = active[o_off]
                if o_off < end and off < o_end:
                    out.append(Violation(
                        "pool_disjoint_lifetimes", subject,
                        f"v{v.var} [{off}, {end}) overlaps v{other.var} "
                        f"[{o_off}, {o_end}) while both are live "
                        f"(lifetimes [{v.alloc_index}, {v.free_index}) and "
                        f"[{other.alloc_index}, {other.free_index}))",
                        ops=(v.alloc_index, other.alloc_index),
                        vars=(v.var, other.var),
                    ))
        # Insert even after a violation (keeps later overlaps detectable);
        # identical offsets would clobber — only keep the first, the
        # violation above already witnessed the clash.
        if off not in active:
            bisect.insort(active_offs, off)
            active[off] = (end, v)
    return out


# --------------------------------------------------------------- swap checks
def _absence_spans(d, n: int) -> tuple[tuple[int, int], ...]:
    """Half-open [a, b) absence spans, matching engine.planned_peak."""
    if d.wraps:
        return ((0, min(d.in_before, n)), (min(d.out_after, n), n))
    return ((min(d.out_after, n), min(d.in_before, n)),)


def resident_floor(trace, decisions) -> tuple[int, int]:
    """(peak, argmax op index) of the load curve minus absence windows —
    an independent pure-Python sweep with ``planned_peak`` semantics."""
    n = trace.num_indices
    if n == 0:
        return 0, 0
    delta = [0] * (n + 1)
    for v in trace.variables:
        a, b = v.alloc_index, min(v.free_index, n)
        if a < b:
            delta[a] += v.size
            delta[b] -= v.size
    for d in decisions:
        for a, b in _absence_spans(d, n):
            if a < b:
                delta[a] -= d.size
                delta[b] += d.size
    peak, at, cur = 0, 0, 0
    for i in range(n):
        cur += delta[i]
        if cur > peak:
            peak, at = cur, i
    return peak, at


def verify_swap_summary(trace, summary, subject: str = "swap") -> list[Violation]:
    """Sweep one ``SwapSummary``'s decisions against the trace accesses."""
    out: list[Violation] = []
    by_id = {v.var: v for v in trace.variables}
    n = trace.num_indices

    seen: dict[int, object] = {}
    valid: list = []  # shape-valid decisions only: the floor sweep's input
    malformed = False  # any well-formedness break leaves the floor unattestable
    for d in summary.decisions:
        v = by_id.get(d.var)
        if v is None:
            out.append(Violation(
                "swap_well_formed", subject,
                f"decision names unknown variable v{d.var}", vars=(d.var,),
            ))
            malformed = True
            continue
        prev = seen.get(d.var)
        if prev is not None:
            out.append(Violation(
                "swap_single_residency", subject,
                f"v{d.var} has two absence windows "
                f"(out_after {prev.out_after} and {d.out_after}) — the swap "
                "events would double-install and double-charge residency",
                ops=(prev.out_after, d.out_after), vars=(d.var,),
            ))
            continue
        seen[d.var] = d

        ok_shape = (
            d.size == v.size
            and 0 <= d.in_before < n
            and 0 <= d.out_after < n
            and (d.in_before <= d.out_after if d.wraps else d.out_after < d.in_before)
            and d.out_after in v.accesses
            and d.in_before in v.accesses
        )
        if not ok_shape:
            out.append(Violation(
                "swap_well_formed", subject,
                f"v{d.var} window (out_after={d.out_after}, "
                f"in_before={d.in_before}, wraps={d.wraps}, size={d.size}) is "
                f"inconsistent with the variable (size={v.size}, "
                f"accesses={v.accesses})",
                ops=(d.out_after, d.in_before), vars=(d.var,),
            ))
            malformed = True
            continue
        valid.append(d)

        # Accesses strictly inside the absence window: the variable is on
        # host there, so a read has nothing resident to read (use before
        # swap-in) and a write is lost when the stale copy swaps back.
        for a, is_write in zip(v.accesses, v.access_is_write):
            if d.wraps:
                inside = a < d.in_before or a > d.out_after
            else:
                inside = d.out_after < a < d.in_before
            if not inside:
                continue
            if is_write:
                out.append(Violation(
                    "swap_out_after_write", subject,
                    f"v{d.var} is written at op {a} inside its absence "
                    f"window — the swap-out at {d.out_after} precedes the "
                    "variable's last write (lost update)",
                    ops=(a, d.out_after), vars=(d.var,),
                ))
            else:
                out.append(Violation(
                    "swap_in_before_read", subject,
                    f"v{d.var} is read at op {a} inside its absence window "
                    f"— the swap-in completes at {d.in_before}, after the "
                    "read (use of non-resident data)",
                    ops=(a, d.in_before), vars=(d.var,),
                ))

    # Resident floor vs the solver's commitment.  The engine's admission
    # reserves the *floor* (planned_peak), not the limit, so the safety
    # obligation is that the decisions reproduce exactly the floor the
    # schedule was solved with: a dropped/tampered decision changes it.
    # Greedy selection is best-effort — it may exhaust its one-window-per-
    # variable candidates with the floor still above the limit (and above
    # ``load_min``, which picks a *different* window combination) — so a
    # committed floor over the limit is not a violation by itself.
    floor, at = resident_floor(trace, valid)
    claimed = getattr(summary, "planned_floor", None)
    if malformed:
        # A malformed decision set already failed well-formedness; the floor
        # cannot be attested either way, so don't stack a budget verdict.
        return out
    if claimed is not None:
        if floor != claimed:
            out.append(Violation(
                "swap_budget", subject,
                f"decisions yield resident floor {floor} (peak at op {at}) "
                f"but the schedule committed to planned_floor {claimed} — "
                "the decision set was dropped or tampered with after solve",
                ops=(at,),
            ))
    elif floor > summary.limit and summary.limit >= summary.load_min:
        # Legacy summary without a committed floor: fall back to the limit,
        # vacuous when the limit is declared infeasible (limit < load_min).
        out.append(Violation(
            "swap_budget", subject,
            f"resident floor {floor} exceeds the schedule's limit "
            f"{summary.limit} at op {at} (load_min {summary.load_min}: the "
            "limit was feasible, so the selection under-covers the peak)",
            ops=(at,),
        ))
    return out


# ------------------------------------------------------------------ program
def verify_program(program, alignment: int = DEFAULT_ALIGNMENT) -> Certificate:
    """Full sweep over every solved artifact a ``MemoryProgram`` carries.

    Every invariant appears in the certificate even with zero subjects, so
    "proved over N placements" and "nothing of that kind to prove" are both
    explicit verdicts.
    """
    cert = Certificate()
    for name in ALL_INVARIANTS:
        cert.add(name, 0, [])
    trace = program.require_trace()

    for method, plan in sorted(program.pool_plans.items()):
        subject = f"pool:{method}"
        by_inv: dict[str, list[Violation]] = {n: [] for n in POOL_INVARIANTS}
        for v in verify_pool_plan(trace, plan, alignment, subject=subject):
            by_inv[v.invariant].append(v)
        for n in POOL_INVARIANTS:
            cert.add(n, 1, by_inv[n])

    for key, summary in sorted(program.swap_summaries.items()):
        subject = f"swap:{key}"
        by_inv = {n: [] for n in SWAP_INVARIANTS}
        for v in verify_swap_summary(trace, summary, subject=subject):
            by_inv[v.invariant].append(v)
        for n in SWAP_INVARIANTS:
            cert.add(n, 1, by_inv[n])
        if summary.limit < summary.load_min:
            cert.note(
                f"{subject}: limit {summary.limit} < load_min "
                f"{summary.load_min}; budget obligation vacuous"
            )
        claimed = getattr(summary, "planned_floor", None)
        if claimed is not None and claimed > summary.limit:
            cert.note(
                f"{subject}: best-effort schedule — committed floor "
                f"{claimed} > limit {summary.limit}; admission reserves "
                "the floor"
            )
    return cert
