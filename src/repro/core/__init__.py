"""Core library: SmartPool + AutoSwap (Zhang et al., 2019) adapted to JAX/TPU.

Public surface:
  events      — Event/VariableInfo/IterationTrace, load curves, omega(G)
  iteration   — repeated-subsequence iteration detection
  trace       — RecordingDevice (paper §V) + jaxpr lifetime extraction
  smartpool   — offline-DSA weighted-interval-coloring pool
  baseline_pools — CnMem-style online pool + cudaMalloc-style exact allocator
  autoswap    — candidates, DOA/AOA/WDOA/SWDOA priority scores, selection
  simulator   — timing model + discrete-event swap-schedule simulator
  bayesopt    — GP+EI tuner for the combined priority score
  planner     — MemoryPlanner: facade over the repro.plan pass pipeline
  offload     — remat/pinned_host offload policies driven by AutoSwap

The staged pipeline itself (MemoryProgram IR, passes, strategy registry,
on-disk plan artifacts) lives in repro.plan.
"""

from . import autoswap, baseline_pools, bayesopt, events, iteration, simulator, smartpool, trace  # noqa: F401
from .autoswap import AutoSwapPlanner
from .events import Event, EventKind, IterationTrace, build_trace
from .simulator import GTX_1080TI, TPU_V5E, HardwareSpec, SwapDecision, simulate_swap_schedule
from .smartpool import AllocationPlan, solve as smartpool_solve
from .trace import RecordingDevice, trace_jaxpr, trace_step_fn

__all__ = [
    "AutoSwapPlanner",
    "Event",
    "EventKind",
    "IterationTrace",
    "build_trace",
    "GTX_1080TI",
    "TPU_V5E",
    "HardwareSpec",
    "SwapDecision",
    "simulate_swap_schedule",
    "AllocationPlan",
    "smartpool_solve",
    "RecordingDevice",
    "trace_jaxpr",
    "trace_step_fn",
]
