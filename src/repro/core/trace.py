"""Trace acquisition: the paper's Device abstraction + a jaxpr-level tracer.

Two ways to obtain the event stream the planner needs:

1. ``RecordingDevice`` — the paper's §V ``Device`` class, verbatim semantics:
   ``Malloc``/``Free``/``Exec(fn, read_blocks, write_blocks)`` record events
   into a list which undergoes the repeatability test (core/iteration.py).
   This is the runtime path: model-transparent, no graph needed.  Used by the
   event-level simulator and for systems whose execution is imperative.

2. ``trace_jaxpr`` — the TPU/JAX adaptation.  Under XLA the "iterative nature"
   is compiled-in: one ``jax.make_jaxpr(step_fn)`` IS the canonical iteration.
   We walk the jaxpr as a virtual interpreter (inlining scan/while/cond/pjit
   bodies the number of times they execute) and emit the same event stream a
   runtime recorder would have seen: MALLOC+WRITE at producer, READ at each
   consumer, FREE after last use (refcount semantics).  This gives the
   offline-DSA instance for *any* jitted step function — every architecture
   in configs/ goes through this path.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, Sequence

import jax
import numpy as np
from jax import core as _jcore_internal
from jax.extend import core as _jex_core


class _JCore:
    """Compat shim: jaxpr datatypes moved to jax.extend.core in newer JAX."""

    Literal = _jex_core.Literal
    ClosedJaxpr = _jex_core.ClosedJaxpr
    Jaxpr = _jex_core.Jaxpr
    DropVar = _jcore_internal.DropVar


jcore = _JCore

from .events import Event, EventKind, IterationTrace, build_trace
from .iteration import IterationDetector


# --------------------------------------------------------------------------
# 1. The paper's Device abstraction (runtime recording path)
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class Block:
    """Handle for a device memory block (the paper's ``Block*``)."""

    var: int
    size: int


class RecordingDevice:
    """Paper §V ``Device``: records Malloc/Free/Exec and detects the iteration.

    In the paper this object fronts cudaMalloc/cudaFree until the pool is
    built.  Here it fronts nothing (we are planning, not allocating) but the
    recorded stream and the repeatability test are identical.
    """

    def __init__(self, min_period: int = 4):
        self._next_var = 0
        self._index = 0
        self._detector = IterationDetector(min_period=min_period)
        self.events: list[Event] = []

    # -- paper API ----------------------------------------------------------
    def malloc(self, size: int) -> Block:
        blk = Block(self._next_var, int(size))
        self._next_var += 1
        self._emit(EventKind.MALLOC, blk)
        return blk

    def free(self, blk: Block) -> None:
        self._emit(EventKind.FREE, blk)

    def exec(
        self,
        fn: Callable[..., Any] | None,
        read_blocks: Sequence[Block],
        write_blocks: Sequence[Block],
        *args: Any,
    ) -> Any:
        """Run an operation, recording its read/write sets (paper's ``Exec``)."""
        for blk in read_blocks:
            self._emit(EventKind.READ, blk)
        for blk in write_blocks:
            self._emit(EventKind.WRITE, blk)
        return fn(*args) if fn is not None else None

    # -- stream plumbing -----------------------------------------------------
    def _emit(self, kind: EventKind, blk: Block) -> None:
        ev = Event(kind, blk.var, blk.size, self._index)
        self._index += 1
        self.events.append(ev)
        self._detector.feed(ev)

    @property
    def iteration_detected(self) -> bool:
        return self._detector.period is not None

    def iteration_trace(self) -> IterationTrace:
        """The canonical one-iteration trace (PoolOpt's input)."""
        self._detector.finalize()
        return build_trace(self._detector.iteration_events())


# --------------------------------------------------------------------------
# 2. jaxpr-level lifetime extraction (the XLA-world adaptation)
# --------------------------------------------------------------------------


def _aval_bytes(aval) -> int:
    try:
        shape = aval.shape
        itemsize = np.dtype(aval.dtype).itemsize
    except Exception:  # tokens, abstract refs
        return 0
    return int(math.prod(shape)) * int(itemsize)


# Inline-expansion caps: scan bodies are unrolled at most this many times so a
# 500k-step decode loop doesn't produce a 500k-long event stream.  Lifetime
# *structure* (what overlaps what) is preserved by unrolling a few periods.
_MAX_SCAN_UNROLL = 64


def _eqn_cost(eqn) -> tuple[float, float]:
    """Rough (flops, bytes_touched) estimate for one jaxpr equation.

    Used only by the swap-schedule timing model; roofline numbers for the real
    system come from ``compiled.cost_analysis()``, never from this.
    """
    out_elems = 0.0
    bytes_touched = 0.0
    for ov in eqn.outvars:
        try:
            out_elems += float(math.prod(ov.aval.shape))
            bytes_touched += _aval_bytes(ov.aval)
        except Exception:
            pass
    for iv in eqn.invars:
        if not isinstance(iv, jcore.Literal):
            try:
                bytes_touched += _aval_bytes(iv.aval)
            except Exception:
                pass
    name = eqn.primitive.name
    flops = out_elems  # elementwise default
    if name == "dot_general":
        dims = eqn.params["dimension_numbers"][0]
        lhs = eqn.invars[0].aval.shape
        k = 1.0
        for d in dims[0]:
            k *= lhs[d]
        flops = 2.0 * out_elems * k
    elif name in ("conv_general_dilated",):
        rhs = eqn.invars[1].aval.shape  # kernel
        k = float(math.prod(rhs[:-1]))  # spatial*in_ch per out channel (approx)
        flops = 2.0 * out_elems * k
    elif name in ("reduce_sum", "reduce_max", "reduce_min", "argmax", "argmin"):
        try:
            flops = float(math.prod(eqn.invars[0].aval.shape))
        except Exception:
            pass
    return (flops, bytes_touched)


class _JaxprEventEmitter:
    """Virtual interpreter over a ClosedJaxpr that emits the event stream."""

    def __init__(self, max_scan_unroll: int = _MAX_SCAN_UNROLL):
        self.events: list[Event] = []
        self.names: dict[int, str] = {}
        self.sizes: dict[int, int] = {}
        self.op_costs: dict[int, tuple[float, float]] = {}  # index -> (flops, bytes)
        self._index = 0
        self._next_var = 0
        self._max_unroll = max_scan_unroll

    # -- var-id management: jaxpr Vars -> fresh integer ids per dynamic scope
    def _fresh(self, size: int, name: str = "") -> int:
        vid = self._next_var
        self._next_var += 1
        self.sizes[vid] = size
        if name:
            self.names[vid] = name
        return vid

    def _emit(self, kind: EventKind, vid: int) -> None:
        self.events.append(Event(kind, vid, self.sizes[vid], self._index))
        self._index += 1

    # -- interpretation -------------------------------------------------------
    def run(self, closed: jcore.ClosedJaxpr, arg_names: Sequence[str] | None = None):
        jaxpr = closed.jaxpr
        env: dict[Any, int] = {}
        # Function inputs (params, batch) pre-exist: lifetime starts at 0.
        for i, invar in enumerate(jaxpr.invars):
            name = arg_names[i] if arg_names and i < len(arg_names) else f"arg{i}"
            vid = self._fresh(_aval_bytes(invar.aval), name)
            env[invar] = vid
            self._emit(EventKind.MALLOC, vid)
        for cv, const in zip(jaxpr.constvars, closed.consts):
            size = int(np.asarray(const).nbytes) if hasattr(const, "nbytes") else 0
            vid = self._fresh(size, "const")
            env[cv] = vid
            self._emit(EventKind.MALLOC, vid)
        self._run_jaxpr(jaxpr, env)
        # Outputs are read once more at the end (returned to caller).
        for outvar in jaxpr.outvars:
            if not isinstance(outvar, jcore.Literal) and outvar in env:
                self._emit(EventKind.READ, env[outvar])

    def _read(self, env, atom) -> int | None:
        if isinstance(atom, jcore.Literal):
            return None
        return env.get(atom)

    def _run_jaxpr(self, jaxpr: jcore.Jaxpr, env: dict) -> None:
        for eqn in jaxpr.eqns:
            self._run_eqn(eqn, env)

    def _bind_outputs(self, eqn, env, suffix: str = "") -> None:
        for ov in eqn.outvars:
            if isinstance(ov, jcore.DropVar):
                continue
            name = f"{eqn.primitive.name}{suffix}"
            if eqn.primitive.name == "name":  # checkpoint_name label
                name = str(eqn.params.get("name", "name"))
            vid = self._fresh(_aval_bytes(ov.aval), name)
            env[ov] = vid
            self._emit(EventKind.MALLOC, vid)
            self._emit(EventKind.WRITE, vid)

    def _read_inputs(self, eqn, env) -> None:
        for iv in eqn.invars:
            vid = self._read(env, iv)
            if vid is not None:
                self._emit(EventKind.READ, vid)

    def _run_eqn(self, eqn, env: dict) -> None:
        prim = eqn.primitive.name
        if prim == "scan":
            self._run_scan(eqn, env)
            return
        if prim == "while":
            self._run_subjaxpr(eqn, env, eqn.params["body_jaxpr"], times=1)
            return
        if prim == "cond":
            self._read_inputs(eqn, env)
            branch = eqn.params["branches"][0]
            inner_env = {}
            # cond invars: [pred, *operands]
            for bv, iv in zip(branch.jaxpr.invars, eqn.invars[1:]):
                vid = self._read(env, iv)
                if vid is not None:
                    inner_env[bv] = vid
            self._run_jaxpr(branch.jaxpr, inner_env)
            self._bind_outputs(eqn, env)
            return
        if prim in ("pjit", "closed_call", "core_call", "remat", "remat2", "checkpoint",
                    "custom_jvp_call", "custom_vjp_call", "custom_vjp_call_jaxpr"):
            sub = (eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr")
                   or eqn.params.get("fun_jaxpr"))
            if sub is not None:
                self._run_call(eqn, env, sub)
                return
        # Default: a primitive compute op.
        self._read_inputs(eqn, env)
        cost_index = self._index  # the eqn's cost is charged to its first output
        self._bind_outputs(eqn, env)
        self.op_costs[cost_index] = _eqn_cost(eqn)

    def _run_call(self, eqn, env, sub) -> None:
        closed = sub if isinstance(sub, jcore.ClosedJaxpr) else jcore.ClosedJaxpr(sub, ())
        inner_env: dict = {}
        for bv, iv in zip(closed.jaxpr.invars, eqn.invars):
            vid = self._read(env, iv)
            if vid is not None:
                inner_env[bv] = vid
        for cv in closed.jaxpr.constvars:
            inner_env[cv] = self._fresh(0, "const")
            self._emit(EventKind.MALLOC, inner_env[cv])
        self._run_jaxpr(closed.jaxpr, inner_env)
        # Map results back out.
        for ov, inner_ov in zip(eqn.outvars, closed.jaxpr.outvars):
            if isinstance(ov, jcore.DropVar):
                continue
            if isinstance(inner_ov, jcore.Literal) or inner_ov not in inner_env:
                vid = self._fresh(_aval_bytes(ov.aval), eqn.primitive.name)
                self._emit(EventKind.MALLOC, vid)
                self._emit(EventKind.WRITE, vid)
            else:
                vid = inner_env[inner_ov]
            env[ov] = vid

    def _run_scan(self, eqn, env: dict) -> None:
        """Unroll a scan: per trip, xs slices are fresh small buffers, carries
        are fresh buffers replacing the previous trip's (refcount-freed), and
        per-trip ys slices accumulate into the stacked outputs."""
        p = eqn.params
        body: jcore.ClosedJaxpr = p["jaxpr"]
        length = int(p["length"])
        n_carry, n_consts = int(p["num_carry"]), int(p["num_consts"])
        trips = min(length, self._max_unroll)

        self._read_inputs(eqn, env)
        const_ids = [self._read(env, iv) for iv in eqn.invars[:n_consts]]
        carry_ids = [self._read(env, iv) for iv in eqn.invars[n_consts:n_consts + n_carry]]
        xs_atoms = eqn.invars[n_consts + n_carry:]

        body_invars = body.jaxpr.invars
        for t in range(trips):
            inner_env: dict = {}
            for bv, cid in zip(body_invars[:n_consts], const_ids):
                if cid is not None:
                    inner_env[bv] = cid
            for bv, cid in zip(body_invars[n_consts:n_consts + n_carry], carry_ids):
                if cid is not None:
                    inner_env[bv] = cid
            # xs slices: one layer's worth of each stacked input.
            for bv, xa in zip(body_invars[n_consts + n_carry:], xs_atoms):
                vid = self._fresh(_aval_bytes(bv.aval), f"scan_x[{t}]")
                inner_env[bv] = vid
                self._emit(EventKind.MALLOC, vid)
                self._emit(EventKind.WRITE, vid)
            for cv in body.jaxpr.constvars:
                inner_env[cv] = self._fresh(0, "const")
                self._emit(EventKind.MALLOC, inner_env[cv])
            self._run_jaxpr(body.jaxpr, inner_env)
            # New carries come from body outputs.
            new_carry = []
            for ov in body.jaxpr.outvars[:n_carry]:
                if isinstance(ov, jcore.Literal) or ov not in inner_env:
                    vid = self._fresh(_aval_bytes(ov.aval), "carry")
                    self._emit(EventKind.MALLOC, vid)
                    self._emit(EventKind.WRITE, vid)
                else:
                    vid = inner_env[ov]
                new_carry.append(vid)
            # ys slices are read (copied into the stacked output).
            for ov in body.jaxpr.outvars[n_carry:]:
                if not isinstance(ov, jcore.Literal) and ov in inner_env:
                    self._emit(EventKind.READ, inner_env[ov])
            carry_ids = new_carry
        self._bind_outputs(eqn, env, suffix=f"[{trips}x]")


def trace_step_fn(
    fn: Callable,
    *example_args,
    arg_names: Sequence[str] | None = None,
    max_scan_unroll: int = _MAX_SCAN_UNROLL,
    add_frees: bool = True,
) -> IterationTrace:
    """Trace ``fn`` at the given (ShapeDtypeStruct or array) args and return
    the one-iteration offline-DSA instance.

    FREE events are synthesized at last-use (refcount semantics), matching
    what the paper's runtime recorder observes from the framework's GC.
    """
    closed = jax.make_jaxpr(fn)(*example_args)
    return trace_jaxpr(closed, arg_names=arg_names, max_scan_unroll=max_scan_unroll)


def trace_jaxpr(
    closed: jcore.ClosedJaxpr,
    arg_names: Sequence[str] | None = None,
    max_scan_unroll: int = _MAX_SCAN_UNROLL,
) -> IterationTrace:
    em = _JaxprEventEmitter(max_scan_unroll=max_scan_unroll)
    em.run(closed, arg_names=arg_names)
    events, index_map = _with_frees(em.events)
    trace = build_trace(events)
    trace.op_costs = {
        index_map[i]: cost for i, cost in em.op_costs.items() if i in index_map
    }
    info_by_id = trace.by_id()
    for vid, name in em.names.items():
        if vid in info_by_id:
            info_by_id[vid].name = name
    return trace


def _with_frees(events: list[Event]) -> tuple[list[Event], dict[int, int]]:
    """Insert FREE events at each variable's last use (refcounting).

    Returns the re-indexed stream plus a map old_index -> new_index so that
    per-op metadata (cost estimates) can follow the re-indexing.
    """
    last_use: dict[int, int] = {}
    size: dict[int, int] = {}
    for ev in events:
        last_use[ev.var] = ev.index
        size[ev.var] = ev.size
    # Re-index: frees occupy fresh op indices interleaved after last uses.
    by_index: dict[int, list[int]] = {}
    for var, idx in last_use.items():
        by_index.setdefault(idx, []).append(var)
    out: list[Event] = []
    index_map: dict[int, int] = {}
    cursor = 0
    for ev in events:
        index_map[ev.index] = cursor
        out.append(Event(ev.kind, ev.var, ev.size, cursor))
        cursor += 1
        for var in by_index.get(ev.index, ()):
            out.append(Event(EventKind.FREE, var, size[var], cursor))
            cursor += 1
    return out, index_map
