"""AutoSwap: automatic variable swapping (paper §IV).

Pipeline:
  candidates (§IV-A)  ->  priority scores (§IV-B)  ->  selection (§IV-D)
  ->  schedule + overhead (§IV-E, simulated in core/simulator.py)

Candidates: size >= threshold (default 1 MB) and an access gap that spans the
peak-load time.  Weights/optimizer state additionally contribute a *wrap*
candidate (absence across the iteration boundary, paper §VI-B3).

Priority scores per candidate (higher = swap first):
  DOA    duration of absence: (t_next - t_prev) - transfer_out - transfer_in
  AOA    DOA * size  (or DOA / size when DOA < 0, per the paper)
  WDOA   integral of the original load curve over (t_prev, t_next)
  SWDOA  WDOA recomputed submodularly against the progressively-updated curve
  BO     a*AOA + b*DOA + c*WDOA + d*SWDOA on standardized scores, with the
         weights tuned by core/bayesopt.py against simulated overhead
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Literal, Sequence

import numpy as np

from .events import IterationTrace, VariableInfo
from .simulator import HardwareSpec, SimResult, SwapDecision, assign_times, simulate_swap_schedule

ScoreName = Literal["doa", "aoa", "wdoa", "swdoa"]
DEFAULT_SIZE_THRESHOLD = 1 << 20  # 1 MB (paper §IV-A)


@dataclass
class Candidate:
    var: int
    size: int
    out_after: int          # op index: access completing before the gap
    in_before: int          # op index: access needing the variable back
    wraps: bool = False
    scores: dict[str, float] = field(default_factory=dict)

    def decision(self) -> SwapDecision:
        return SwapDecision(self.var, self.size, self.out_after, self.in_before, self.wraps)


class AutoSwapPlanner:
    """Computes candidates, scores, selections and schedules for one trace."""

    def __init__(
        self,
        trace: IterationTrace,
        hw: HardwareSpec,
        size_threshold: int = DEFAULT_SIZE_THRESHOLD,
        include_wrap: bool = True,
    ):
        self.trace = trace
        self.hw = hw
        if trace.op_times is None:
            assign_times(trace, hw)
        self.times = np.asarray(trace.op_times)
        self.load = np.asarray(trace.load_curve(), dtype=np.float64)
        self.peak_load = int(self.load.max()) if self.load.size else 0
        self.peak_time = int(self.load.argmax()) if self.load.size else 0
        self.size_threshold = size_threshold
        self.candidates = self._find_candidates(include_wrap)
        self._score_all()

    # ---------------------------------------------------------- candidates
    def _find_candidates(self, include_wrap: bool) -> list[Candidate]:
        """Candidate = (variable, canonical absence window).

        The paper filters to gaps spanning *the* peak index (§IV-A).  That
        works for CNNs (the peak sits on the broad end-of-forward shoulder)
        but collapses for LM steps whose instantaneous peak is a narrow
        CE-chunk spike: almost nothing crosses that single index.  We keep
        each variable's LARGEST access gap as its canonical window and defer
        peak-relevance to selection time (``_active``): a candidate is
        usable at a given limit iff its absence overlaps the over-limit
        region.  The paper's filter is the special case limit -> peak.
        """
        out: list[Candidate] = []
        for v in self.trace.variables:
            if v.size < self.size_threshold:
                continue
            gap = self._largest_gap(v)
            if gap is not None:
                # prefer the gap spanning the global peak when one exists
                span = self._gap_spanning_peak(v)
                a, b = span if span is not None else gap
                out.append(Candidate(v.var, v.size, a, b))
            if include_wrap and v.free_index >= self.trace.num_indices and v.accesses:
                # Persists across iterations (weights/optimizer state/inputs):
                # absence across the iteration boundary (paper §VI-B3).
                out.append(
                    Candidate(v.var, v.size, max(v.accesses), min(v.accesses), wraps=True)
                )
        return out

    def _largest_gap(self, v: VariableInfo) -> tuple[int, int] | None:
        acc = sorted(v.accesses)
        best = None
        for a, b in zip(acc, acc[1:]):
            if b - a > 1 and (best is None or b - a > best[1] - best[0]):
                best = (a, b)
        return best

    def _gap_spanning_peak(self, v: VariableInfo) -> tuple[int, int] | None:
        """The consecutive-access pair (a, b) with a <= peak_time < b."""
        acc = sorted(v.accesses)
        for a, b in zip(acc, acc[1:]):
            if a <= self.peak_time < b:
                return (a, b)
        return None

    def _active(self, limit: int) -> list[Candidate]:
        """Candidates whose absence overlaps the over-limit load region."""
        over = self.load > limit
        if not over.any():
            return []
        return [c for c in self.candidates if bool((self._absence_mask(c) & over).any())]

    # ---------------------------------------------------------- scoring
    def _interval_seconds(self, c: Candidate) -> float:
        if not c.wraps:
            return float(self.times[c.in_before] - self.times[c.out_after])
        # Wrap: tail-of-iteration + head-of-next (same shape in steady state).
        total = float(self.times[-1])
        return (total - float(self.times[c.out_after])) + float(self.times[c.in_before])

    def _load_area(self, load: np.ndarray, c: Candidate) -> float:
        """Integral of `load` over the candidate's absence window (seconds*bytes)."""
        dt = np.diff(self.times)
        if not c.wraps:
            sl = slice(c.out_after, c.in_before)
            return float((load[sl] * dt[sl]).sum())
        head = slice(0, c.in_before)
        tail = slice(c.out_after, len(load))
        return float((load[head] * dt[head]).sum() + (load[tail] * dt[tail]).sum())

    def _absence_mask(self, c: Candidate) -> np.ndarray:
        m = np.zeros(len(self.load), dtype=bool)
        if not c.wraps:
            m[c.out_after : c.in_before] = True
        else:
            m[: c.in_before] = True
            m[c.out_after :] = True
        return m

    def _score_all(self) -> None:
        transfer = lambda c: 2.0 * c.size / self.hw.link_bw  # out + in
        for c in self.candidates:
            doa = self._interval_seconds(c) - transfer(c)
            aoa = doa * c.size if doa >= 0 else doa / c.size
            wdoa = self._load_area(self.load, c)
            c.scores.update(doa=doa, aoa=aoa, wdoa=wdoa)
        # SWDOA: re-rank against the progressively-updated load curve (§IV-B iv).
        work = self.load.copy()
        remaining = list(self.candidates)
        while remaining:
            scored = [(self._load_area(work, c), c) for c in remaining]
            best_score, best = max(scored, key=lambda s: s[0])
            best.scores["swdoa"] = best_score
            work = work - best.size * self._absence_mask(best)
            remaining.remove(best)

    def standardized(self) -> dict[str, np.ndarray]:
        """Z-scored score vectors aligned with ``self.candidates`` (paper §IV-C)."""
        out = {}
        for k in ("doa", "aoa", "wdoa", "swdoa"):
            x = np.array([c.scores[k] for c in self.candidates], dtype=np.float64)
            std = x.std()
            out[k] = (x - x.mean()) / std if std > 0 else np.zeros_like(x)
        return out

    # ---------------------------------------------------------- selection
    def ranked(
        self,
        method: ScoreName | None = None,
        weights: Sequence[float] | None = None,
    ) -> list[Candidate]:
        if weights is not None:
            z = self.standardized()
            combo = (
                weights[0] * z["aoa"] + weights[1] * z["doa"]
                + weights[2] * z["wdoa"] + weights[3] * z["swdoa"]
            )
            order = np.argsort(-combo, kind="stable")
            return [self.candidates[i] for i in order]
        assert method is not None
        return sorted(self.candidates, key=lambda c: -c.scores[method])

    def select(
        self,
        limit: int,
        method: ScoreName | None = "swdoa",
        weights: Sequence[float] | None = None,
    ) -> list[SwapDecision]:
        """Greedy selection until the synchronously-updated peak <= limit (§IV-D)."""
        active_set = {(c.var, c.wraps) for c in self._active(limit)}
        work = self.load.copy()
        chosen: list[SwapDecision] = []
        seen: set[int] = set()
        for c in self.ranked(method, weights):
            if work.max() <= limit:
                break
            if (c.var, c.wraps) not in active_set:
                continue
            if c.var in seen:
                continue  # one absence window per variable
            seen.add(c.var)
            work = work - c.size * self._absence_mask(c)
            chosen.append(c.decision())
        return chosen

    def updated_load(self, decisions: Sequence[SwapDecision]) -> np.ndarray:
        work = self.load.copy()
        for d in decisions:
            c = Candidate(d.var, d.size, d.out_after, d.in_before, d.wraps)
            work = work - d.size * self._absence_mask(c)
        return work

    def load_min(self) -> int:
        """Peak load with *all* candidates absent (paper §VI-B1 load_min)."""
        work = self.load.copy()
        seen: set[int] = set()
        for c in self.candidates:
            if c.var in seen:
                continue
            seen.add(c.var)
            work = work - c.size * self._absence_mask(c)
        return int(work.max()) if work.size else 0

    # ---------------------------------------------------------- evaluation
    def evaluate(
        self,
        limit: int,
        method: ScoreName | None = "swdoa",
        weights: Sequence[float] | None = None,
    ) -> SimResult:
        decisions = self.select(limit, method, weights)
        return simulate_swap_schedule(self.trace, decisions, self.hw, limit)

    def max_zero_overhead_reduction(
        self,
        method: ScoreName | None = "swdoa",
        weights: Sequence[float] | None = None,
        tol: float = 0.005,
        grid: int = 32,
    ) -> tuple[int, float]:
        """Lowest achievable load with ~zero overhead (paper Table II).

        Scans a limit grid from peak down to load_min (overhead is not
        monotone in the limit — paper Fig 9 — so no bisection)."""
        lo, hi = self.load_min(), self.peak_load
        if hi <= lo:
            return hi, 0.0
        best_limit, best_ov = hi, 0.0
        for k in range(1, grid + 1):
            limit = int(hi - (hi - lo) * k / grid)
            r = self.evaluate(limit, method, weights)
            if r.overhead <= tol:
                best_limit, best_ov = limit, r.overhead
            elif r.overhead > 5 * tol and k > grid // 2:
                break
        return best_limit, best_ov
