"""AutoSwap: automatic variable swapping (paper §IV).

Pipeline:
  candidates (§IV-A)  ->  priority scores (§IV-B)  ->  selection (§IV-D)
  ->  schedule + overhead (§IV-E, simulated in core/simulator.py)

Candidates: size >= threshold (default 1 MB) and an access gap that spans the
peak-load time.  Weights/optimizer state additionally contribute a *wrap*
candidate (absence across the iteration boundary, paper §VI-B3).

Priority scores per candidate (higher = swap first):
  DOA    duration of absence: (t_next - t_prev) - transfer_out - transfer_in
  AOA    DOA * size  (or DOA / size when DOA < 0, per the paper)
  WDOA   integral of the original load curve over (t_prev, t_next)
  SWDOA  WDOA recomputed submodularly against the progressively-updated curve
  BO     a*AOA + b*DOA + c*WDOA + d*SWDOA on standardized scores, with the
         weights tuned by core/bayesopt.py against simulated overhead

Solve-time fast path (vs core/_solver_reference.ReferenceAutoSwapPlanner):

  * the load curve comes from the trace's memoized numpy cumsum and every
    window integral is O(1) off a prefix sum of ``load * dt`` (the reference
    re-ran ``np.diff`` over the full time axis per window, O(T) each);
  * the SWDOA re-ranking applies O(1) *delta* updates — subtracting the
    chosen candidate's ``size x overlap-seconds`` from exactly the scores its
    absence window intersects — instead of re-integrating every remaining
    candidate against the updated curve, turning O(k^2 T) into O(k^2) flat
    numpy work (a lazy max-heap degenerates to argmax over a k-vector here,
    which is both simpler and faster at numpy speed);
  * rankings, selections, the active test (via per-candidate window peaks)
    and ``load_min`` are memoized, so the limit-grid scan in
    ``max_zero_overhead_reduction`` never re-ranks or re-scores; only the
    per-limit simulation still runs per grid point (its result genuinely
    depends on the limit through malloc-delay accounting, so skipping it
    would change answers).

SWDOA/WDOA values agree with the reference to float tolerance (the delta
form accumulates O(k*eps) rounding); DOA/AOA are exact.  Selections are
pinned exactly against the reference on every tested and benchmarked trace
— in principle two candidates whose reference scores differ by less than
the O(k*eps) drift could rank in either order, but the comparison is
deterministic (same floats every run), so the CI pin can only trip when a
newly added trace genuinely near-ties, never flakily.
tests/test_solvetime.py pins scores and decisions against the reference.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Literal, Sequence

import numpy as np

from .events import IterationTrace, VariableInfo
from .simulator import HardwareSpec, SimResult, SwapDecision, assign_times, simulate_swap_schedule

ScoreName = Literal["doa", "aoa", "wdoa", "swdoa"]
DEFAULT_SIZE_THRESHOLD = 1 << 20  # 1 MB (paper §IV-A)


@dataclass
class Candidate:
    var: int
    size: int
    out_after: int          # op index: access completing before the gap
    in_before: int          # op index: access needing the variable back
    wraps: bool = False
    scores: dict[str, float] = field(default_factory=dict)

    def decision(self) -> SwapDecision:
        return SwapDecision(self.var, self.size, self.out_after, self.in_before, self.wraps)


class AutoSwapPlanner:
    """Computes candidates, scores, selections and schedules for one trace."""

    def __init__(
        self,
        trace: IterationTrace,
        hw: HardwareSpec,
        size_threshold: int = DEFAULT_SIZE_THRESHOLD,
        include_wrap: bool = True,
    ):
        self.trace = trace
        self.hw = hw
        if trace.op_times is None:
            assign_times(trace, hw)
        self.times = np.asarray(trace.op_times)
        self.load = np.asarray(trace.load_curve_array(), dtype=np.float64)
        self.peak_load = int(self.load.max()) if self.load.size else 0
        self.peak_time = int(self.load.argmax()) if self.load.size else 0
        self.size_threshold = size_threshold
        self.candidates = self._find_candidates(include_wrap)
        # Prefix sums: _area_prefix[x] = integral of load*dt over ops [0, x),
        # so any window integral is one subtraction (O(1) per window).
        dt = np.diff(self.times) if self.times.size > 1 else np.zeros(0)
        self._dt = dt
        self._area_prefix = np.zeros(len(self.load) + 1, dtype=np.float64)
        if self.load.size:
            np.cumsum(self.load * dt[: len(self.load)], out=self._area_prefix[1:])
        self._score_all()
        # Memoized query state (scores are fixed after init, so every ranking
        # and selection is a pure function of its arguments).
        self._ranked_cache: dict = {}
        self._select_cache: dict = {}
        self._load_min: int | None = None
        self._win_peak = self._window_peaks()

    # ---------------------------------------------------------- candidates
    def _find_candidates(self, include_wrap: bool) -> list[Candidate]:
        """Candidate = (variable, canonical absence window).

        The paper filters to gaps spanning *the* peak index (§IV-A).  That
        works for CNNs (the peak sits on the broad end-of-forward shoulder)
        but collapses for LM steps whose instantaneous peak is a narrow
        CE-chunk spike: almost nothing crosses that single index.  We keep
        each variable's LARGEST access gap as its canonical window and defer
        peak-relevance to selection time (``_active``): a candidate is
        usable at a given limit iff its absence overlaps the over-limit
        region.  The paper's filter is the special case limit -> peak.
        """
        out: list[Candidate] = []
        for v in self.trace.variables:
            if v.size < self.size_threshold:
                continue
            acc = sorted(v.accesses)  # sorted once, shared by both gap scans
            gap = self._largest_gap(acc)
            if gap is not None:
                # prefer the gap spanning the global peak when one exists
                span = self._gap_spanning_peak(acc)
                a, b = span if span is not None else gap
                out.append(Candidate(v.var, v.size, a, b))
            if include_wrap and v.free_index >= self.trace.num_indices and acc:
                # Persists across iterations (weights/optimizer state/inputs):
                # absence across the iteration boundary (paper §VI-B3).
                out.append(Candidate(v.var, v.size, acc[-1], acc[0], wraps=True))
        return out

    @staticmethod
    def _largest_gap(acc: list[int]) -> tuple[int, int] | None:
        best = None
        for a, b in zip(acc, acc[1:]):
            if b - a > 1 and (best is None or b - a > best[1] - best[0]):
                best = (a, b)
        return best

    def _gap_spanning_peak(self, acc: list[int]) -> tuple[int, int] | None:
        """The consecutive-access pair (a, b) with a <= peak_time < b."""
        for a, b in zip(acc, acc[1:]):
            if a <= self.peak_time < b:
                return (a, b)
        return None

    def _window_peaks(self) -> np.ndarray:
        """Max original load inside each candidate's absence window.

        ``_active(limit)`` reduces to ``win_peak > limit``: the window
        overlaps the over-limit region iff its load maximum exceeds the
        limit.  Replaces the per-query O(k*T) mask construction."""
        peaks = np.zeros(len(self.candidates), dtype=np.float64)
        for i, c in enumerate(self.candidates):
            if not c.wraps:
                seg = self.load[c.out_after : c.in_before]
                peaks[i] = seg.max() if seg.size else -np.inf
            else:
                head = self.load[: c.in_before]
                tail = self.load[c.out_after :]
                m = -np.inf
                if head.size:
                    m = float(head.max())
                if tail.size:
                    m = max(m, float(tail.max()))
                peaks[i] = m
        return peaks

    def _active(self, limit: int) -> list[Candidate]:
        """Candidates whose absence overlaps the over-limit load region."""
        return [
            c
            for i, c in enumerate(self.candidates)
            if self._win_peak[i] > limit
        ]

    # ---------------------------------------------------------- scoring
    def _interval_seconds(self, c: Candidate) -> float:
        if not c.wraps:
            return float(self.times[c.in_before] - self.times[c.out_after])
        # Wrap: tail-of-iteration + head-of-next (same shape in steady state).
        total = float(self.times[-1])
        return (total - float(self.times[c.out_after])) + float(self.times[c.in_before])

    def _load_area(self, load: np.ndarray, c: Candidate) -> float:
        """Integral of `load` over the candidate's absence window (seconds*bytes)."""
        dt = self._dt
        if not c.wraps:
            sl = slice(c.out_after, c.in_before)
            return float((load[sl] * dt[sl]).sum())
        head = slice(0, c.in_before)
        tail = slice(c.out_after, len(load))
        return float((load[head] * dt[head]).sum() + (load[tail] * dt[tail]).sum())

    def _prefix_area(self, c: Candidate) -> float:
        """O(1) window integral of the *original* curve off the prefix sum."""
        P = self._area_prefix
        if not c.wraps:
            return float(P[c.in_before] - P[c.out_after])
        return float(P[c.in_before] - P[0] + P[-1] - P[c.out_after])

    def _absence_mask(self, c: Candidate) -> np.ndarray:
        m = np.zeros(len(self.load), dtype=bool)
        if not c.wraps:
            m[c.out_after : c.in_before] = True
        else:
            m[: c.in_before] = True
            m[c.out_after :] = True
        return m

    def _segments(self) -> tuple[np.ndarray, ...]:
        """Each candidate's absence window as up to two [s, e) op-index
        segments ((0, in)+(out, T) for wrap candidates; second segment empty
        otherwise), as four parallel int arrays."""
        k = len(self.candidates)
        T = len(self.load)
        out = np.fromiter((c.out_after for c in self.candidates), np.int64, k)
        inb = np.fromiter((c.in_before for c in self.candidates), np.int64, k)
        wraps = np.fromiter((c.wraps for c in self.candidates), bool, k)
        s1 = np.where(wraps, 0, out)
        e1 = inb
        s2 = np.where(wraps, out, 0)
        e2 = np.where(wraps, T, 0)
        return s1, e1, s2, e2

    def _overlap_seconds(self, i: int, segs: tuple[np.ndarray, ...]) -> np.ndarray:
        """Seconds of overlap between candidate i's absence window and every
        candidate's window (vectorized; the SWDOA delta kernel)."""
        s1, e1, s2, e2 = segs
        t = self.times
        out = np.zeros(len(self.candidates), dtype=np.float64)
        for ps, pe in ((int(s1[i]), int(e1[i])), (int(s2[i]), int(e2[i]))):
            if pe <= ps:
                continue
            for qs, qe in ((s1, e1), (s2, e2)):
                lo = np.maximum(qs, ps)
                hi = np.minimum(qe, pe)
                valid = hi > lo
                out += np.where(valid, t[hi] - t[lo], 0.0)
        return out

    def _score_all(self) -> None:
        transfer = lambda c: 2.0 * c.size / self.hw.link_bw  # out + in
        for c in self.candidates:
            doa = self._interval_seconds(c) - transfer(c)
            aoa = doa * c.size if doa >= 0 else doa / c.size
            wdoa = self._prefix_area(c)
            c.scores.update(doa=doa, aoa=aoa, wdoa=wdoa)
        # SWDOA: re-rank against the progressively-updated load curve (§IV-B
        # iv).  The integral is linear in the curve, so the score of c after
        # applying b is  area(c) - b.size * overlap_seconds(b, c)  — an O(1)
        # delta per (chosen, remaining) pair instead of re-integrating the
        # full curve.  Each round applies the delta vector and takes the
        # argmax of still-unscored candidates (ties resolve to the earliest
        # candidate, matching the reference's first-max semantics).
        k = len(self.candidates)
        if not k:
            return
        segs = self._segments()
        area = np.fromiter((c.scores["wdoa"] for c in self.candidates), np.float64, k)
        alive = np.ones(k, dtype=bool)
        for _ in range(k):
            i = int(np.argmax(np.where(alive, area, -np.inf)))
            c = self.candidates[i]
            c.scores["swdoa"] = float(area[i])
            alive[i] = False
            if alive.any():
                area -= c.size * self._overlap_seconds(i, segs)

    def standardized(self) -> dict[str, np.ndarray]:
        """Z-scored score vectors aligned with ``self.candidates`` (paper §IV-C)."""
        out = {}
        for k in ("doa", "aoa", "wdoa", "swdoa"):
            x = np.array([c.scores[k] for c in self.candidates], dtype=np.float64)
            std = x.std()
            out[k] = (x - x.mean()) / std if std > 0 else np.zeros_like(x)
        return out

    # ---------------------------------------------------------- selection
    def ranked(
        self,
        method: ScoreName | None = None,
        weights: Sequence[float] | None = None,
    ) -> list[Candidate]:
        key = (method, tuple(weights) if weights is not None else None)
        hit = self._ranked_cache.get(key)
        if hit is not None:
            return list(hit)
        if weights is not None:
            z = self.standardized()
            combo = (
                weights[0] * z["aoa"] + weights[1] * z["doa"]
                + weights[2] * z["wdoa"] + weights[3] * z["swdoa"]
            )
            order = np.argsort(-combo, kind="stable")
            out = [self.candidates[i] for i in order]
        else:
            assert method is not None
            out = sorted(self.candidates, key=lambda c: -c.scores[method])
        self._ranked_cache[key] = out
        return list(out)

    def select(
        self,
        limit: int,
        method: ScoreName | None = "swdoa",
        weights: Sequence[float] | None = None,
    ) -> list[SwapDecision]:
        """Greedy selection until the synchronously-updated peak <= limit (§IV-D)."""
        key = (limit, method, tuple(weights) if weights is not None else None)
        hit = self._select_cache.get(key)
        if hit is not None:
            return list(hit)
        active_set = {(c.var, c.wraps) for c in self._active(limit)}
        work = self.load.copy()
        peak = work.max() if work.size else 0
        chosen: list[SwapDecision] = []
        seen: set[int] = set()
        for c in self.ranked(method, weights):
            if peak <= limit:
                break
            if (c.var, c.wraps) not in active_set:
                continue
            if c.var in seen:
                continue  # one absence window per variable
            seen.add(c.var)
            work -= c.size * self._absence_mask(c)
            peak = work.max()
            chosen.append(c.decision())
        self._select_cache[key] = chosen
        return list(chosen)

    def updated_load(self, decisions: Sequence[SwapDecision]) -> np.ndarray:
        work = self.load.copy()
        for d in decisions:
            c = Candidate(d.var, d.size, d.out_after, d.in_before, d.wraps)
            work = work - d.size * self._absence_mask(c)
        return work

    def load_min(self) -> int:
        """Peak load with *all* candidates absent (paper §VI-B1 load_min)."""
        if self._load_min is not None:
            return self._load_min
        work = self.load.copy()
        seen: set[int] = set()
        for c in self.candidates:
            if c.var in seen:
                continue
            seen.add(c.var)
            work -= c.size * self._absence_mask(c)
        self._load_min = int(work.max()) if work.size else 0
        return self._load_min

    # ---------------------------------------------------------- evaluation
    def evaluate(
        self,
        limit: int,
        method: ScoreName | None = "swdoa",
        weights: Sequence[float] | None = None,
    ) -> SimResult:
        decisions = self.select(limit, method, weights)
        return simulate_swap_schedule(self.trace, decisions, self.hw, limit)

    def max_zero_overhead_reduction(
        self,
        method: ScoreName | None = "swdoa",
        weights: Sequence[float] | None = None,
        tol: float = 0.005,
        grid: int = 32,
    ) -> tuple[int, float]:
        """Lowest achievable load with ~zero overhead (paper Table II).

        Scans a limit grid from peak down to load_min (overhead is not
        monotone in the limit — paper Fig 9 — so no bisection).  The scan
        reuses one ranking and the memoized active/selection state across
        every grid point; only the discrete-event simulation runs per point,
        because its malloc-delay accounting genuinely depends on the limit
        (two identical selections at different limits can cost differently),
        so skipping it would change the reported reduction."""
        lo, hi = self.load_min(), self.peak_load
        if hi <= lo:
            return hi, 0.0
        best_limit, best_ov = hi, 0.0
        for k in range(1, grid + 1):
            limit = int(hi - (hi - lo) * k / grid)
            r = self.evaluate(limit, method, weights)
            if r.overhead <= tol:
                best_limit, best_ov = limit, r.overhead
            elif r.overhead > 5 * tol and k > grid // 2:
                break
        return best_limit, best_ov
