"""Event model for the paper's unified abstraction (paper §V).

A training process is observed as a flat stream of events over *variables*
(device memory blocks):

    MALLOC(var, size) -> WRITE/READ(var)* -> FREE(var)

Every event carries an *operation index* (the paper's logical time) and an
optional wall-clock timestamp supplied by a timing model (core/simulator.py).

From one detected iteration of this stream we derive the semantics the paper
exploits:
  * lifetime of every variable (malloc index .. free index),
  * read/write order (per-variable access indices),
  * the memory-load curve, its peak value omega(G) and the peak time.
"""

from __future__ import annotations

import bisect
import enum
from dataclasses import dataclass, field
from typing import Iterable, Sequence


class EventKind(enum.IntEnum):
    MALLOC = 0
    FREE = 1
    READ = 2
    WRITE = 3


@dataclass(frozen=True)
class Event:
    kind: EventKind
    var: int          # variable id
    size: int         # bytes; identical for every event of the same var
    index: int        # operation index (logical time within the stream)

    def signature(self) -> tuple:
        """Shape-only signature used by the iteration repeatability test.

        Variable ids differ across iterations (fresh tensors are allocated
        each step) so the signature deliberately excludes ``var``: two
        iterations "repeat" when their (kind, size) sequences match.
        """
        return (int(self.kind), self.size)


@dataclass
class VariableInfo:
    """Lifetime + access semantics of a single variable within one iteration."""

    var: int
    size: int
    alloc_index: int
    free_index: int                       # exclusive end of lifetime
    accesses: list[int] = field(default_factory=list)  # sorted op indices
    # True for entries of `accesses` that are writes (parallel list).
    access_is_write: list[bool] = field(default_factory=list)
    name: str = ""

    @property
    def lifetime(self) -> tuple[int, int]:
        return (self.alloc_index, self.free_index)

    def overlaps(self, other: "VariableInfo") -> bool:
        """Lifetime overlap — the edge predicate of the WIC graph (paper §III-B)."""
        return self.alloc_index < other.free_index and other.alloc_index < self.free_index

    def crosses(self, index: int) -> bool:
        return self.alloc_index <= index < self.free_index


@dataclass
class IterationTrace:
    """One detected training iteration: the offline-DSA problem instance."""

    variables: list[VariableInfo]
    num_indices: int                      # logical-time horizon of the iteration
    # Optional map op index -> wall-clock seconds from a timing model. Entry i
    # is the *start* time of op i; entry num_indices is the iteration end.
    op_times: list[float] | None = None
    # Optional op index -> (flops, bytes_touched): compute-cost estimates from
    # the jaxpr tracer, consumed by core/simulator.py to build op_times.
    op_costs: dict[int, tuple[float, float]] | None = None
    # Optional op index -> seconds of wall time the roofline model cannot
    # derive from (flops, bytes) — collective communication durations tagged
    # by the sharded tracer (repro.dist).  Folded into op_times by
    # ``assign_times``; never serialized (op_times carries the result).
    op_extra_s: dict[int, float] | None = None
    # Memoized load curve: (guard, int64 ndarray).  The guard catches the
    # structural mutations that occur in practice (adding/removing variables,
    # re-detecting the horizon); in-place edits of an existing VariableInfo's
    # lifetime must call ``invalidate_cache()``.
    _load_cache: "tuple | None" = field(default=None, repr=False, compare=False)

    def by_id(self) -> dict[int, VariableInfo]:
        return {v.var: v for v in self.variables}

    # ---------------------------------------------------------------- loads
    def invalidate_cache(self) -> None:
        """Drop the memoized load curve after mutating variable lifetimes."""
        self._load_cache = None

    def _cache_guard(self) -> tuple:
        return (len(self.variables), self.num_indices)

    def load_curve_array(self) -> "object":
        """Memoized load curve as an int64 cumsum over alloc/free deltas.

        One O(n + T) numpy pass, shared by every consumer (AutoSwap scoring,
        the planner facade, the runtime's resident-floor accounting) that
        previously each re-derived it from a pure-Python loop.  Callers must
        treat the returned array as read-only; copy before mutating.
        """
        import numpy as np

        guard = self._cache_guard()
        if self._load_cache is not None and self._load_cache[0] == guard:
            return self._load_cache[1]
        deltas = np.zeros(self.num_indices + 1, dtype=np.int64)
        n = len(self.variables)
        if n:
            alloc = np.fromiter((v.alloc_index for v in self.variables), np.int64, n)
            free = np.fromiter((v.free_index for v in self.variables), np.int64, n)
            size = np.fromiter((v.size for v in self.variables), np.int64, n)
            np.add.at(deltas, alloc, size)
            inb = free <= self.num_indices
            np.subtract.at(deltas, free[inb], size[inb])
        curve = np.cumsum(deltas[: self.num_indices])
        curve.flags.writeable = False
        self._load_cache = (guard, curve)
        return curve

    def load_curve(self) -> list[int]:
        """Memory load (bytes) at every operation index (paper Definition 2).

        Returns a fresh list (callers mutate it, e.g. the runtime's
        ``planned_peak``); the underlying curve is memoized."""
        return self.load_curve_array().tolist()

    def peak_load(self) -> int:
        """omega(G): the largest-clique weight == peak memory load (paper Eq. 1)."""
        curve = self.load_curve_array()
        return int(curve.max()) if curve.size else 0

    def peak_time(self) -> int:
        curve = self.load_curve_array()
        if not curve.size:
            return 0
        return int(curve.argmax())

    def total_bytes(self) -> int:
        return sum(v.size for v in self.variables)

    def time_of(self, index: int) -> float:
        """Wall-clock time of an op index (identity when no timing model)."""
        if self.op_times is None:
            return float(index)
        index = max(0, min(index, len(self.op_times) - 1))
        return self.op_times[index]

    @property
    def duration(self) -> float:
        return self.time_of(self.num_indices)


def build_trace(events: Sequence[Event]) -> IterationTrace:
    """Fold a flat event stream into per-variable lifetime/access semantics.

    Variables seen without a MALLOC (pre-existing, e.g. weights) get lifetime
    starting at index 0; variables never FREEd extend to the stream end —
    matching the paper's treatment of weights, which live across iterations.
    """
    infos: dict[int, VariableInfo] = {}
    end = 0
    for ev in events:
        end = max(end, ev.index + 1)
        info = infos.get(ev.var)
        if info is None:
            start = ev.index if ev.kind == EventKind.MALLOC else 0
            info = VariableInfo(ev.var, ev.size, start, -1)
            infos[ev.var] = info
        if ev.kind == EventKind.FREE:
            info.free_index = ev.index
        elif ev.kind in (EventKind.READ, EventKind.WRITE):
            info.accesses.append(ev.index)
            info.access_is_write.append(ev.kind == EventKind.WRITE)
    for info in infos.values():
        if info.free_index < 0:
            info.free_index = end
    return IterationTrace(sorted(infos.values(), key=lambda v: v.var), end)


def interval_point_loads(
    variables: Iterable[VariableInfo], points: Sequence[int]
) -> list[int]:
    """Memory load restricted to given op indices (sweep-line, O(n log n))."""
    starts = sorted(v.alloc_index for v in variables)
    ends = sorted(v.free_index for v in variables)
    sizes_by_start: dict[int, int] = {}
    # A simple prefix-sum over sorted boundaries keyed by the query points.
    events: list[tuple[int, int]] = []
    for v in variables:
        events.append((v.alloc_index, v.size))
        events.append((v.free_index, -v.size))
    events.sort()
    boundary = [e[0] for e in events]
    prefix, cur = [], 0
    for _, delta in events:
        cur += delta
        prefix.append(cur)
    out = []
    for p in points:
        # load *at* p includes vars with alloc<=p<free: apply all events with
        # boundary <= p (free at p removes the var, matching VariableInfo.crosses).
        k = bisect.bisect_right(boundary, p)
        out.append(prefix[k - 1] if k else 0)
    return out
