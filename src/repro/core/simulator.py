"""Timed execution model + discrete-event swap simulator.

Gives every op index in an ``IterationTrace`` a wall-clock time (roofline-style
``max(flops/peak, bytes/bw)`` per op) and then replays the iteration under an
AutoSwap schedule with the paper's semantics (§IV-E):

* one swap-out stream, one swap-in stream, each serialized;
* swap-out starts when the variable's pre-gap access completes AND the out
  stream is free;
* swap-in is back-scheduled from the next access (prefetch), serialized, and
  may not start while resident load + size would exceed the limit;
* a MALLOC that would push resident load above the limit is *delayed* until a
  pending swap-out completes — this is where visible overhead comes from;
* an access to a variable whose swap-in has not finished stalls compute.

Overhead = (simulated duration - baseline duration) / baseline, the quantity
minimized by the Bayesian-optimized priority score (paper §IV-C, Fig 9).
"""

from __future__ import annotations

from dataclasses import dataclass, field

from .events import IterationTrace


# ---------------------------------------------------------------- hardware
@dataclass(frozen=True)
class HardwareSpec:
    name: str
    peak_flops: float          # FLOP/s for the training dtype
    hbm_bw: float              # device memory bytes/s
    link_bw: float             # device<->host bytes/s (PCIe / DMA), per direction
    op_overhead_s: float = 2e-6    # fixed per-op launch cost
    malloc_cost_s: float = 0.0     # per-malloc driver cost (cudaMalloc path)
    # Device<->device interconnect bytes/s per direction (NVLink / TPU ICI),
    # used by repro.dist's collective cost model.  Defaults to the host link
    # (PCIe peer-to-peer) for GPUs without a dedicated interconnect.
    ici_bw: float = 0.0
    # Per-collective launch/synchronization latency (ring setup, barriers).
    collective_latency_s: float = 5e-6
    # Achieved fraction of peak compute. Calibrated for the paper's testbed
    # against its own Table I iteration times (VGG16 @ batch 100 trains at
    # ~71 ms/iter on the 1080 Ti => ~12.5% of fp32 peak for small CIFAR
    # convs); without this the simulated compute is ~8x too fast and swap
    # transfers can never hide (paper Fig 9 would be unreproducible).
    efficiency: float = 1.0

    @property
    def eff_flops(self) -> float:
        return self.peak_flops * self.efficiency


# The paper's testbed: GTX 1080 Ti (fp32) on PCIe 3.0 x16.  No NVLink: peer
# traffic rides the same PCIe complex as host swaps.
GTX_1080TI = HardwareSpec(
    "gtx1080ti", peak_flops=11.3e12, hbm_bw=484e9, link_bw=12e9, efficiency=0.125,
    ici_bw=12e9,
)
# Our target: TPU v5e (bf16), host DMA modeled at the stated 50 GB/s link
# figure; 0.5 is a typical large-matmul MFU.  ICI at ~100 GB/s per direction
# (1600 Gbps aggregate inter-chip links).
TPU_V5E = HardwareSpec(
    "tpu_v5e", peak_flops=197e12, hbm_bw=819e9, link_bw=50e9, efficiency=0.5,
    ici_bw=100e9,
)
# cudaMalloc-style allocation cost used for the Table I speedup reproduction.
CUDA_MALLOC_COST_S = 180e-6
POOL_LOOKUP_COST_S = 0.4e-6


def assign_times(trace: IterationTrace, hw: HardwareSpec) -> IterationTrace:
    """Populate ``trace.op_times`` from the per-op cost estimates (in place).

    ``trace.op_extra_s`` (op index -> seconds) charges time the roofline
    model cannot see — collective communication tagged by the sharded
    tracer (repro.dist) — so swap windows that overlap a collective are as
    long as the interconnect actually makes them.
    """
    costs = trace.op_costs or {}
    extra = trace.op_extra_s or {}
    times = [0.0] * (trace.num_indices + 1)
    t = 0.0
    for i in range(trace.num_indices):
        times[i] = t
        flops, nbytes = costs.get(i, (0.0, 0.0))
        if flops or nbytes:
            t += max(flops / hw.eff_flops, nbytes / hw.hbm_bw) + hw.op_overhead_s
        t += extra.get(i, 0.0)
    times[trace.num_indices] = t
    trace.op_times = times
    return trace


def iteration_time(
    trace: IterationTrace, hw: HardwareSpec, malloc_cost_s: float = 0.0
) -> float:
    """Baseline iteration wall-time, optionally charging per-malloc driver cost
    (reproduces Table I's cudaMalloc-vs-pool speedup)."""
    if trace.op_times is None:
        assign_times(trace, hw)
    n_mallocs = sum(1 for v in trace.variables if v.size > 0)
    return trace.op_times[-1] + n_mallocs * malloc_cost_s


# ------------------------------------------------------- swap simulation
@dataclass
class SwapDecision:
    """One selected variable with its absence window (op indices)."""

    var: int
    size: int
    out_after: int     # op index of the access after which we swap out
    in_before: int     # op index of the access that needs it back
    # Cross-iteration-boundary absence (paper §VI-B3: weights swapped out after
    # their last access and prefetched before the *next* iteration's first
    # access). in_before < out_after for these.
    wraps: bool = False


@dataclass
class SimResult:
    baseline_s: float
    duration_s: float
    peak_resident: int          # peak resident load under the schedule
    stalls: int = 0             # accesses that waited on swap-in
    delayed_mallocs: int = 0    # mallocs delayed by the limit
    tail_spill_s: float = 0.0   # swap-out stream drain past compute end
    out_events: list[tuple[int, float, float]] = field(default_factory=list)
    in_events: list[tuple[int, float, float]] = field(default_factory=list)

    @property
    def overhead(self) -> float:
        if self.baseline_s <= 0:
            return 0.0
        return max(0.0, (self.duration_s - self.baseline_s) / self.baseline_s)


def simulate_swap_schedule(
    trace: IterationTrace,
    decisions: list[SwapDecision],
    hw: HardwareSpec,
    limit: int | None = None,
) -> SimResult:
    """Replay one iteration under a swap schedule (see module docstring).

    The event loop itself lives in ``repro.runtime.engine`` since the
    multi-tenant runtime landed: this is a 1-tenant run over 2 DMA channels
    (one out + one in — exactly the paper's two serialized streams).  Wider
    or narrower DMA engines, and multiple tenants sharing one budget, go
    through ``repro.runtime`` directly.

    The engine's hot paths were vectorized in PR 6 (prefetch index, pending
    heap, event frontier); this facade's results are pinned bit-for-bit
    against the frozen pre-vectorization engine
    (``runtime/_engine_reference.py``) by tests/test_engine_equiv.py.
    """
    from ..runtime.engine import simulate_program  # deferred: runtime imports core

    return simulate_program(trace, decisions, hw, limit, channels=2, prefetch="eager")
