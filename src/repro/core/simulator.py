"""Timed execution model + discrete-event swap simulator.

Gives every op index in an ``IterationTrace`` a wall-clock time (roofline-style
``max(flops/peak, bytes/bw)`` per op) and then replays the iteration under an
AutoSwap schedule with the paper's semantics (§IV-E):

* one swap-out stream, one swap-in stream, each serialized;
* swap-out starts when the variable's pre-gap access completes AND the out
  stream is free;
* swap-in is back-scheduled from the next access (prefetch), serialized, and
  may not start while resident load + size would exceed the limit;
* a MALLOC that would push resident load above the limit is *delayed* until a
  pending swap-out completes — this is where visible overhead comes from;
* an access to a variable whose swap-in has not finished stalls compute.

Overhead = (simulated duration - baseline duration) / baseline, the quantity
minimized by the Bayesian-optimized priority score (paper §IV-C, Fig 9).
"""

from __future__ import annotations

from dataclasses import dataclass, field

from .events import IterationTrace


# ---------------------------------------------------------------- hardware
@dataclass(frozen=True)
class HardwareSpec:
    name: str
    peak_flops: float          # FLOP/s for the training dtype
    hbm_bw: float              # device memory bytes/s
    link_bw: float             # device<->host bytes/s (PCIe / DMA), per direction
    op_overhead_s: float = 2e-6    # fixed per-op launch cost
    malloc_cost_s: float = 0.0     # per-malloc driver cost (cudaMalloc path)
    # Achieved fraction of peak compute. Calibrated for the paper's testbed
    # against its own Table I iteration times (VGG16 @ batch 100 trains at
    # ~71 ms/iter on the 1080 Ti => ~12.5% of fp32 peak for small CIFAR
    # convs); without this the simulated compute is ~8x too fast and swap
    # transfers can never hide (paper Fig 9 would be unreproducible).
    efficiency: float = 1.0

    @property
    def eff_flops(self) -> float:
        return self.peak_flops * self.efficiency


# The paper's testbed: GTX 1080 Ti (fp32) on PCIe 3.0 x16.
GTX_1080TI = HardwareSpec(
    "gtx1080ti", peak_flops=11.3e12, hbm_bw=484e9, link_bw=12e9, efficiency=0.125
)
# Our target: TPU v5e (bf16), host DMA modeled at the stated 50 GB/s link
# figure; 0.5 is a typical large-matmul MFU.
TPU_V5E = HardwareSpec(
    "tpu_v5e", peak_flops=197e12, hbm_bw=819e9, link_bw=50e9, efficiency=0.5
)
# cudaMalloc-style allocation cost used for the Table I speedup reproduction.
CUDA_MALLOC_COST_S = 180e-6
POOL_LOOKUP_COST_S = 0.4e-6


def assign_times(trace: IterationTrace, hw: HardwareSpec) -> IterationTrace:
    """Populate ``trace.op_times`` from the per-op cost estimates (in place)."""
    costs = trace.op_costs or {}
    times = [0.0] * (trace.num_indices + 1)
    t = 0.0
    for i in range(trace.num_indices):
        times[i] = t
        flops, nbytes = costs.get(i, (0.0, 0.0))
        if flops or nbytes:
            t += max(flops / hw.eff_flops, nbytes / hw.hbm_bw) + hw.op_overhead_s
    times[trace.num_indices] = t
    trace.op_times = times
    return trace


def iteration_time(
    trace: IterationTrace, hw: HardwareSpec, malloc_cost_s: float = 0.0
) -> float:
    """Baseline iteration wall-time, optionally charging per-malloc driver cost
    (reproduces Table I's cudaMalloc-vs-pool speedup)."""
    if trace.op_times is None:
        assign_times(trace, hw)
    n_mallocs = sum(1 for v in trace.variables if v.size > 0)
    return trace.op_times[-1] + n_mallocs * malloc_cost_s


# ------------------------------------------------------- swap simulation
@dataclass
class SwapDecision:
    """One selected variable with its absence window (op indices)."""

    var: int
    size: int
    out_after: int     # op index of the access after which we swap out
    in_before: int     # op index of the access that needs it back
    # Cross-iteration-boundary absence (paper §VI-B3: weights swapped out after
    # their last access and prefetched before the *next* iteration's first
    # access). in_before < out_after for these.
    wraps: bool = False


@dataclass
class SimResult:
    baseline_s: float
    duration_s: float
    peak_resident: int          # peak resident load under the schedule
    stalls: int = 0             # accesses that waited on swap-in
    delayed_mallocs: int = 0    # mallocs delayed by the limit
    tail_spill_s: float = 0.0   # swap-out stream drain past compute end
    out_events: list[tuple[int, float, float]] = field(default_factory=list)
    in_events: list[tuple[int, float, float]] = field(default_factory=list)

    @property
    def overhead(self) -> float:
        if self.baseline_s <= 0:
            return 0.0
        return max(0.0, (self.duration_s - self.baseline_s) / self.baseline_s)


def simulate_swap_schedule(
    trace: IterationTrace,
    decisions: list[SwapDecision],
    hw: HardwareSpec,
    limit: int | None = None,
) -> SimResult:
    """Replay one iteration under a swap schedule (see module docstring)."""
    if trace.op_times is None:
        assign_times(trace, hw)
    times = trace.op_times
    baseline = times[-1]
    costs = trace.op_costs or {}

    # Per-op duration from the timing model.
    def op_dur(i: int) -> float:
        flops, nbytes = costs.get(i, (0.0, 0.0))
        if flops or nbytes:
            return max(flops / hw.eff_flops, nbytes / hw.hbm_bw) + hw.op_overhead_s
        return 0.0

    out_at: dict[int, list[SwapDecision]] = {}
    in_at: dict[int, list[SwapDecision]] = {}
    for d in decisions:
        out_at.setdefault(d.out_after, []).append(d)
        in_at.setdefault(d.in_before, []).append(d)

    # Load deltas per index from lifetimes.
    delta = [0] * (trace.num_indices + 1)
    malloc_size_at: dict[int, int] = {}
    for v in trace.variables:
        delta[v.alloc_index] += v.size
        malloc_size_at[v.alloc_index] = v.size
        if v.free_index <= trace.num_indices:
            delta[v.free_index] -= v.size

    transfer = lambda size: size / hw.link_bw

    t = 0.0
    resident = 0
    peak_resident = 0
    out_stream_free = 0.0
    in_stream_free = 0.0
    out_done: dict[int, float] = {}     # var -> completion time of swap-out
    in_done: dict[int, float] = {}      # var -> completion time of swap-in
    pending_outs: list[tuple[float, int, int]] = []  # (complete_t, var, size)
    stalls = 0
    delayed = 0
    res = SimResult(baseline_s=baseline, duration_s=0.0, peak_resident=0)

    # Wrap-around decisions: in steady state the variable is already on the
    # host when the iteration starts (swapped out during the previous tail).
    for d in decisions:
        if d.wraps:
            resident -= d.size
            out_done[d.var] = 0.0

    for i in range(trace.num_indices):
        # 1. If this op needs a swapped variable back, wait for its swap-in.
        for d in in_at.get(i, ()):  # prefetch deadline == this access
            if d.var not in in_done:
                # Should have been scheduled; schedule now (late prefetch).
                start = max(t, in_stream_free, out_done.get(d.var, 0.0))
                end = start + transfer(d.size)
                in_stream_free = end
                in_done[d.var] = end
                resident += d.size
                res.in_events.append((d.var, start, end))
            if in_done[d.var] > t:
                stalls += 1
                t = in_done[d.var]

        # 2. Memory-limit enforcement on mallocs (paper: delay the Malloc).
        if limit is not None and delta[i] > 0 and i in malloc_size_at:
            while resident + delta[i] > limit and pending_outs:
                # Advance to the next swap-out completion.
                pending_outs.sort()
                done_t, var, size = pending_outs.pop(0)
                if done_t > t:
                    delayed += 1
                    t = done_t
                resident -= size
        resident += delta[i]
        peak_resident = max(peak_resident, resident)

        # 3. Execute the op.
        t += op_dur(i)

        # 4. Launch swap-outs whose trigger access just completed.
        for d in out_at.get(i, ()):
            start = max(t, out_stream_free)
            end = start + transfer(d.size)
            out_stream_free = end
            out_done[d.var] = end
            pending_outs.append((end, d.var, d.size))
            res.out_events.append((d.var, start, end))

        # 5. Retire completed swap-outs (frees resident bytes).
        still = []
        for done_t, var, size in pending_outs:
            if done_t <= t:
                resident -= size
            else:
                still.append((done_t, var, size))
        pending_outs = still

        # 6. Prefetch: keep the in-stream busy with the nearest-deadline
        # swapped-out variable once its data is out and the limit allows it
        # back (paper: "starts swap-in in advance so the access is not
        # delayed"; swap-ins are strictly deadline-ordered, so a limit-blocked
        # head-of-line transfer blocks the stream until a free makes room).
        upcoming = sorted(
            (d for d in decisions
             if d.var in out_done and d.var not in in_done and d.in_before > i),
            key=lambda d: d.in_before,
        )
        for d in upcoming:
            need = transfer(d.size)
            if limit is not None and resident + d.size > limit:
                break  # no room yet; retry at the next op boundary
            start = max(t, in_stream_free, out_done[d.var])
            end = start + need
            in_stream_free = end
            in_done[d.var] = end
            resident += d.size
            peak_resident = max(peak_resident, resident)
            res.in_events.append((d.var, start, end))

    # Iteration ends at compute end.  A tail of in-flight swap-outs (wrap
    # decisions: weights/optimizer state leaving after their last access)
    # overlaps the next iteration's head in steady state and is not charged;
    # it is recorded as `tail_spill_s` for visibility.
    res.duration_s = t
    res.tail_spill_s = max(0.0, out_stream_free - t)
    res.peak_resident = peak_resident
    res.stalls = stalls
    res.delayed_mallocs = delayed
    return res
