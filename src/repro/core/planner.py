"""MemoryPlanner: the paper's pipeline applied to real jitted step functions.

    step_fn --jaxpr--> IterationTrace --SmartPool--> allocation plan
                                     \\--AutoSwap--> swap schedule
                                                 \\--> OffloadPlan (remat names)

This is the model-transparent entry point: it needs only the step function
and example shapes (exactly like the paper's Device needs only the event
stream).  Outputs:

  * ``report()``     — peak load omega(G), SmartPool chi(G) + competitive
                       ratio vs the CnMem-style online pool and the exact
                       allocator (paper Table I quantities);
  * ``swap_report(limit)`` — AutoSwap selection + simulated overhead at an
                       HBM budget (paper Fig 9 / Table II quantities);
  * ``offload_plan(limit)`` — the name-level offload set whose application
                       via core/offload.py realizes the plan under XLA.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

from .autoswap import AutoSwapPlanner, ScoreName
from .baseline_pools import CnMemPool, exact_allocator
from .events import IterationTrace
from .offload import KNOWN_NAMES, OffloadPlan
from .simulator import TPU_V5E, HardwareSpec, assign_times
from .smartpool import AllocationPlan, solve as smartpool_solve
from .trace import trace_step_fn


@dataclass
class PoolReport:
    peak_load: int
    smartpool_footprint: int
    smartpool_ratio: float
    cnmem_footprint: int
    cnmem_ratio: float
    exact_footprint: int
    num_variables: int

    def as_dict(self) -> dict:
        return self.__dict__.copy()


@dataclass
class SwapReport:
    limit: int
    peak_load: int
    load_min: int
    selected_bytes: int
    num_selected: int
    overhead: float
    stalls: int
    per_name_bytes: dict[str, int] = field(default_factory=dict)


class MemoryPlanner:
    def __init__(
        self,
        step_fn: Callable,
        *example_args,
        hw: HardwareSpec = TPU_V5E,
        max_scan_unroll: int = 16,
        size_threshold: int = 1 << 20,
    ):
        self.hw = hw
        self.trace: IterationTrace = trace_step_fn(
            step_fn, *example_args, max_scan_unroll=max_scan_unroll
        )
        assign_times(self.trace, hw)
        self.swap = AutoSwapPlanner(self.trace, hw, size_threshold=size_threshold)

    # ------------------------------------------------------------- pooling
    def report(self, method: str = "best_fit") -> PoolReport:
        plan: AllocationPlan = smartpool_solve(self.trace, method)
        cn = CnMemPool().run(self.trace)
        ex = exact_allocator(self.trace)
        return PoolReport(
            peak_load=plan.peak_load,
            smartpool_footprint=plan.footprint,
            smartpool_ratio=plan.competitive_ratio,
            cnmem_footprint=cn.footprint,
            cnmem_ratio=cn.footprint / plan.peak_load if plan.peak_load else 1.0,
            exact_footprint=ex.footprint,
            num_variables=len([v for v in self.trace.variables if v.size > 0]),
        )

    # ------------------------------------------------------------ swapping
    def swap_report(
        self, limit: int, method: ScoreName | None = "swdoa", weights=None
    ) -> SwapReport:
        decisions = self.swap.select(limit, method, weights)
        sim = self.swap.evaluate(limit, method, weights)
        by_id = self.trace.by_id()
        per_name: dict[str, int] = {}
        for d in decisions:
            name = by_id[d.var].name or "?"
            per_name[name] = per_name.get(name, 0) + d.size
        return SwapReport(
            limit=limit,
            peak_load=self.swap.peak_load,
            load_min=self.swap.load_min(),
            selected_bytes=sum(d.size for d in decisions),
            num_selected=len(decisions),
            overhead=sim.overhead,
            stalls=sim.stalls,
            per_name_bytes=per_name,
        )

    # ------------------------------------------------------------- offload
    def offload_plan(
        self, limit: int, method: ScoreName | None = "swdoa", weights=None
    ) -> OffloadPlan:
        """Coarsen the per-variable selection to checkpoint_name classes.

        A name class is offloaded when the planner selected a majority of its
        candidate bytes — the scan-uniformity coarsening documented in
        DESIGN.md §2.
        """
        decisions = self.swap.select(limit, method, weights)
        by_id = self.trace.by_id()
        selected: dict[str, int] = {}
        total: dict[str, int] = {}
        for c in self.swap.candidates:
            name = by_id[c.var].name or ""
            if name in KNOWN_NAMES:
                total[name] = total.get(name, 0) + c.size
        chosen_vars = {d.var for d in decisions}
        for c in self.swap.candidates:
            name = by_id[c.var].name or ""
            if name in KNOWN_NAMES and c.var in chosen_vars:
                selected[name] = selected.get(name, 0) + c.size
        names = [n for n, b in selected.items() if b >= 0.5 * total.get(n, 1)]
        plan = OffloadPlan(offload_names=sorted(names))
        plan.predicted_savings = sum(selected.values())
        plan.transfer_bytes = 2 * plan.predicted_savings
        return plan
