"""MemoryPlanner: facade over the repro.plan pass pipeline.

    step_fn --TraceCapture--> MemoryProgram --PoolPlacement--> allocation plan
                                           \\--SwapSelection--> swap schedule
                                                            \\--> OffloadLowering

This is the model-transparent entry point: it needs only the step function
and example shapes (exactly like the paper's Device needs only the event
stream).  Since the pipeline refactor every stage is a pass over a
``repro.plan.MemoryProgram`` and the solved results can be cached on disk
(``cache=PlanCache(dir), key=PlanKey(arch, step_sig, hw)``): a second
process with the same key reloads the artifact and never re-traces.

Outputs:

  * ``report()``     — peak load omega(G), SmartPool chi(G) + competitive
                       ratio vs the CnMem-style online pool and the exact
                       allocator (paper Table I quantities);
  * ``swap_report(limit)`` — AutoSwap selection + simulated overhead at an
                       HBM budget (paper Fig 9 / Table II quantities);
  * ``offload_plan(limit)`` — the name-level offload set whose application
                       via core/offload.py realizes the plan under XLA.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

from ..plan.artifact import PlanCache
from ..plan.passes import (
    ArtifactSave,
    IterationDetect,
    OffloadLowering,
    PassContext,
    Pipeline,
    PoolPlacement,
    SwapSelection,
    TimingAssign,
    TraceCapture,
)
from ..plan.program import MemoryProgram, PlanKey, swap_key
from .autoswap import AutoSwapPlanner, ScoreName
from .events import IterationTrace
from .offload import OffloadPlan
from .simulator import TPU_V5E, HardwareSpec


@dataclass
class PoolReport:
    peak_load: int
    smartpool_footprint: int
    smartpool_ratio: float
    cnmem_footprint: int
    cnmem_ratio: float
    exact_footprint: int
    num_variables: int

    def as_dict(self) -> dict:
        return self.__dict__.copy()


@dataclass
class SwapReport:
    limit: int
    peak_load: int
    load_min: int
    selected_bytes: int
    num_selected: int
    overhead: float
    stalls: int
    per_name_bytes: dict[str, int] = field(default_factory=dict)


class MemoryPlanner:
    """Thin facade: builds the front-end pipeline once, then answers report
    queries by running the matching middle-end passes over the program."""

    def __init__(
        self,
        step_fn: Callable | None = None,
        *example_args,
        hw: HardwareSpec = TPU_V5E,
        max_scan_unroll: int = 16,
        size_threshold: int = 1 << 20,
        cache: PlanCache | str | None = None,
        key: PlanKey | None = None,
    ):
        self.hw = hw
        if isinstance(cache, str):
            cache = PlanCache(cache)
        if cache is not None and key is None:
            raise ValueError("a plan cache requires an explicit PlanKey")
        self.ctx = PassContext(
            hw=hw, cache=cache, key=key, size_threshold=size_threshold
        )
        self.program: MemoryProgram = Pipeline(
            [
                TraceCapture(step_fn, example_args, max_scan_unroll=max_scan_unroll),
                IterationDetect(),
                TimingAssign(),
            ]
        ).run(None, self.ctx)

    # ---------------------------------------------------------- IR accessors
    @property
    def trace(self) -> IterationTrace:
        return self.program.require_trace()

    @property
    def swap(self) -> AutoSwapPlanner:
        return self.program.swap_planner(self.hw, self.ctx.size_threshold)

    @property
    def from_cache(self) -> bool:
        return self.program.from_cache

    @property
    def solve_stats(self) -> dict[str, float]:
        """Wall ms per solved stage ("pool:<method>", "swap:<key>").  For a
        program restored from the plan cache these are the *solving*
        process's timings (persisted provenance) — this process paid only
        the cache read; check ``from_cache`` to tell the two apart."""
        return dict(self.program.solve_ms)

    def save(self) -> None:
        """Persist the program's solved artifacts now (also done per-query)."""
        self.program.dirty = True
        ArtifactSave().run(self.program, self.ctx)

    def _run(self, *passes) -> MemoryProgram:
        return Pipeline([*passes, ArtifactSave()]).run(self.program, self.ctx)

    # ------------------------------------------------------------- pooling
    def report(self, method: str = "best_fit") -> PoolReport:
        self._run(PoolPlacement((method, "cnmem", "exact")))
        if method not in self.program.pool_plans:
            raise ValueError(
                f"{method!r} is a baseline pool, not a placement method; "
                f"placement methods produce an AllocationPlan (e.g. best_fit, first_fit)"
            )
        plan = self.program.pool_plans[method]
        cn = self.program.baselines["cnmem"]
        ex = self.program.baselines["exact"]
        return PoolReport(
            peak_load=plan.peak_load,
            smartpool_footprint=plan.footprint,
            smartpool_ratio=plan.competitive_ratio,
            cnmem_footprint=cn.footprint,
            cnmem_ratio=cn.footprint / plan.peak_load if plan.peak_load else 1.0,
            exact_footprint=ex.footprint,
            num_variables=len([v for v in self.trace.variables if v.size > 0]),
        )

    # ------------------------------------------------------------ swapping
    def swap_report(
        self, limit: int, method: ScoreName | None = "swdoa", weights=None
    ) -> SwapReport:
        scorer = method or "swdoa"
        self._run(SwapSelection(limit, scorer, weights))
        s = self.program.swap_summaries[swap_key(scorer, limit, weights)]
        return SwapReport(
            limit=s.limit,
            peak_load=s.peak_load,
            load_min=s.load_min,
            selected_bytes=s.selected_bytes,
            num_selected=len(s.decisions),
            overhead=s.overhead,
            stalls=s.stalls,
            per_name_bytes=dict(s.per_name_bytes),
        )

    # ------------------------------------------------------------- offload
    def offload_plan(
        self, limit: int, method: ScoreName | None = "swdoa", weights=None
    ) -> OffloadPlan:
        """Coarsen the per-variable selection to checkpoint_name classes
        (the OffloadLowering pass; see repro/plan/passes.py)."""
        scorer = method or "swdoa"
        self._run(OffloadLowering(limit, scorer, weights))
        return self.program.offload_plans[swap_key(scorer, limit, weights)]
