"""Loop-aware cost accounting for roofline analysis.

``compiled.cost_analysis()`` counts a while/scan body ONCE regardless of trip
count, which undercounts a 36-layer scanned transformer by ~36x.  Two
correct sources instead:

1. ``jaxpr_flops_bytes(closed_jaxpr)`` — analytic traversal of the jaxpr with
   exact dot_general/conv math, scan bodies multiplied by their static trip
   count.  FLOPs are exact for matmul-dominated models; bytes are the
   *unfused* upper bound (every eqn's operands+results), which brackets HBM
   traffic from above.  These are GLOBAL (whole-program) numbers — divide by
   chip count for per-device roofline terms under balanced sharding.

2. ``loop_aware_collectives(hlo_text)`` — the per-device collective byte
   census of launch/dryrun.py, but with while-body computations scaled by
   their trip counts (parsed from the loop-condition constant), so
   collectives inserted inside scanned layers are counted once per layer.
"""

from __future__ import annotations

import math
import re
from collections import defaultdict

import numpy as np

from jax import core as _jcore_internal
from jax.extend import core as _jex_core

Literal = _jex_core.Literal
ClosedJaxpr = _jex_core.ClosedJaxpr


def _nbytes(aval) -> int:
    try:
        return int(math.prod(aval.shape)) * np.dtype(aval.dtype).itemsize
    except Exception:
        return 0


def _nelems(aval) -> float:
    try:
        return float(math.prod(aval.shape))
    except Exception:
        return 0.0


def _eqn_flops(eqn) -> float:
    name = eqn.primitive.name
    out_elems = sum(_nelems(o.aval) for o in eqn.outvars)
    if name == "dot_general":
        (contract, _), _ = eqn.params["dimension_numbers"], None
        lhs_c = eqn.params["dimension_numbers"][0][0]
        lhs = eqn.invars[0].aval.shape
        k = 1.0
        for d in lhs_c:
            k *= lhs[d]
        return 2.0 * out_elems * k
    if name == "conv_general_dilated":
        rhs = eqn.invars[1].aval.shape
        dn = eqn.params["dimension_numbers"]
        # kernel spatial dims * input-feature dim per output element
        rhs_spec = dn.rhs_spec  # (out_c, in_c, *spatial) indices into rhs
        k = rhs[rhs_spec[1]]
        for d in rhs_spec[2:]:
            k *= rhs[d]
        return 2.0 * out_elems * k
    if name in ("reduce_sum", "reduce_max", "reduce_min", "reduce_prod",
                "argmax", "argmin", "reduce_and", "reduce_or"):
        return sum(_nelems(i.aval) for i in eqn.invars if not isinstance(i, Literal))
    if name in ("exp", "log", "tanh", "logistic", "erf", "rsqrt", "sqrt",
                "sin", "cos", "pow", "cbrt", "log1p", "expm1"):
        return 4.0 * out_elems  # transcendental weight
    if name in ("sort",):
        n = max((_nelems(i.aval) for i in eqn.invars if not isinstance(i, Literal)), default=0.0)
        return n * max(1.0, math.log2(max(n, 2.0)))
    if name in ("gather", "scatter", "scatter-add", "scatter_add", "dynamic_slice",
                "dynamic_update_slice", "broadcast_in_dim", "reshape", "transpose",
                "convert_element_type", "slice", "concatenate", "pad", "iota",
                "copy", "squeeze", "rev"):
        return 0.0  # data movement only
    return out_elems  # elementwise default


def _eqn_bytes(eqn) -> float:
    b = sum(_nbytes(o.aval) for o in eqn.outvars)
    b += sum(_nbytes(i.aval) for i in eqn.invars if not isinstance(i, Literal))
    return float(b)


# Ops that force HBM round-trips on TPU (MXU feeds, data movement with
# materialization).  Elementwise/norm arithmetic fuses into its producers and
# is NOT charged — this gives the fusion-aware traffic estimate used for the
# roofline memory term (the unfused sum is kept as an upper bound).
_HEAVY = {
    "dot_general", "conv_general_dilated", "gather", "scatter", "scatter-add",
    "scatter_add", "dynamic_update_slice", "dynamic_slice", "sort",
    "transpose", "rev", "concatenate", "cumsum", "cumlogsumexp",
}


def _eqn_bytes_fused(eqn) -> float:
    if eqn.primitive.name not in _HEAVY:
        return 0.0
    return _eqn_bytes(eqn)


_CALL_KEYS = ("jaxpr", "call_jaxpr", "fun_jaxpr")


def _walk(jaxpr, mult: float, acc: dict) -> None:
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "shard_map":
            # body shapes are per-shard: scale by the number of shards so the
            # accumulated totals stay whole-program (global)
            mesh = eqn.params.get("mesh")
            shards = 1.0
            try:
                for v in dict(mesh.shape).values():
                    shards *= v
            except Exception:
                pass
            sub = eqn.params.get("jaxpr")
            if sub is not None:
                _walk(sub if not hasattr(sub, "jaxpr") else sub.jaxpr, mult * shards, acc)
                continue
        if name == "scan":
            body = eqn.params["jaxpr"]
            trips = float(eqn.params["length"])
            _walk(body.jaxpr, mult * trips, acc)
            continue
        if name == "while":
            body = eqn.params["body_jaxpr"]
            # trip count is dynamic; decode loops in this codebase are scans,
            # so a conservative 1x is recorded plus a flag.
            acc["dynamic_loops"] += 1
            _walk(body.jaxpr, mult, acc)
            continue
        if name == "cond":
            branches = eqn.params["branches"]
            if branches:
                _walk(branches[0].jaxpr, mult, acc)
            continue
        sub = None
        for k in _CALL_KEYS:
            if k in eqn.params:
                sub = eqn.params[k]
                break
        if sub is not None and hasattr(sub, "jaxpr"):
            _walk(sub.jaxpr, mult, acc)
            continue
        if sub is not None and hasattr(sub, "eqns"):
            _walk(sub, mult, acc)
            continue
        acc["flops"] += mult * _eqn_flops(eqn)
        acc["bytes"] += mult * _eqn_bytes(eqn)
        acc["bytes_fused"] += mult * _eqn_bytes_fused(eqn)


def jaxpr_flops_bytes(closed: ClosedJaxpr) -> dict:
    """Global analytic {flops, bytes, bytes_fused} with scan trip counts."""
    acc = defaultdict(float)
    _walk(closed.jaxpr, 1.0, acc)
    return {"flops": acc["flops"], "bytes": acc["bytes"],
            "bytes_fused": acc["bytes_fused"],
            "dynamic_loops": int(acc["dynamic_loops"])}


# ----------------------------------------------------------- HLO loop-aware
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute",
)


def _shape_bytes(expr: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(expr):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _split_computations(hlo: str) -> dict[str, list[str]]:
    """computation name -> its lines (ENTRY included under its own name)."""
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo.splitlines():
        s = line.strip()
        if s.endswith("{") and (s.startswith("%") or s.startswith("ENTRY")) and "=" not in s.split("(")[0]:
            tok = s.split()[1] if s.startswith("ENTRY") else s.split()[0]
            cur = tok.lstrip("%")
            comps[cur] = []
            continue
        if cur is not None:
            if s == "}":
                cur = None
                continue
            comps[cur].append(line)
    return comps


def loop_aware_collectives(hlo: str) -> dict:
    """Per-device collective bytes with while-body trip multiplication."""
    comps = _split_computations(hlo)

    # direct census per computation
    census: dict[str, dict[str, dict]] = {}
    for name, lines in comps.items():
        c = {op: {"count": 0, "bytes": 0} for op in _COLLECTIVES}
        for line in lines:
            if "=" not in line:
                continue
            _, _, rest = line.partition("=")
            rest = rest.strip()
            for op in _COLLECTIVES:
                m = re.search(rf"^(.*?)\s{op}(-start)?\(", rest)
                if m:
                    c[op]["count"] += 1
                    c[op]["bytes"] += _shape_bytes(m.group(1))
                    break
        census[name] = c

    # while ops: body/condition computation names + trip count from condition
    calls: dict[str, list[tuple[str, float]]] = defaultdict(list)  # caller -> (callee, mult)
    for name, lines in comps.items():
        for line in lines:
            if " while(" in line:
                mb = re.search(r"body=%?([\w\.\-]+)", line)
                mc = re.search(r"condition=%?([\w\.\-]+)", line)
                trips = 1.0
                if mc and mc.group(1) in comps:
                    consts = [
                        int(x)
                        for l in comps[mc.group(1)]
                        for x in re.findall(r"constant\((\d+)\)", l)
                    ]
                    if consts:
                        trips = float(max(consts))
                if mb:
                    calls[name].append((mb.group(1), trips))
            else:
                for mm in re.finditer(r"(?:calls|to_apply|body)=%?([\w\.\-]+)", line):
                    callee = mm.group(1)
                    if callee in comps:
                        calls[name].append((callee, 1.0))

    def total_of(name: str, seen: frozenset) -> dict[str, dict]:
        if name in seen:
            return {op: {"count": 0, "bytes": 0} for op in _COLLECTIVES}
        out = {op: dict(census[name][op]) for op in _COLLECTIVES}
        for callee, mult in calls.get(name, ()):  # recurse with multiplier
            sub = total_of(callee, seen | {name})
            for op in _COLLECTIVES:
                out[op]["count"] += int(sub[op]["count"] * mult)
                out[op]["bytes"] += int(sub[op]["bytes"] * mult)
        return out

    entry = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = re.match(r"ENTRY %?([\w\.\-]+)", line)
            if m:
                entry = m.group(1)
            break
    if entry is None or entry not in comps:
        # fall back: flat census over everything
        flat = {op: {"count": 0, "bytes": 0} for op in _COLLECTIVES}
        for c in census.values():
            for op in _COLLECTIVES:
                flat[op]["count"] += c[op]["count"]
                flat[op]["bytes"] += c[op]["bytes"]
        flat["total_bytes"] = sum(v["bytes"] for v in flat.values() if isinstance(v, dict))
        return flat

    out = total_of(entry, frozenset())
    out["total_bytes"] = sum(v["bytes"] for v in out.values() if isinstance(v, dict))
    return out
