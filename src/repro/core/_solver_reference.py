"""Frozen reference copies of the pre-fast-path solvers and simulator.

These are byte-for-byte behavioural pins: the production solvers in
``core/smartpool.py`` and ``core/autoswap.py`` were rewritten for
near-linear solve time, and every rewrite is validated against these copies —
``reference_solve`` placements must match bit-for-bit, reference SWDOA scores
to float tolerance (the incremental rescore accumulates O(k*eps) rounding).
``reference_simulate_swap_schedule`` is the pre-runtime event loop (one
serialized out stream + one serialized in stream, eager prefetch) that the
engine's 1-tenant/2-channel/eager path must reproduce exactly —
``tests/test_runtime.py`` and ``benchmarks/bench_churn.py`` both pin
against it.

Do NOT edit this module when changing the production solvers or the runtime
engine; that would defeat the pin.  ``benchmarks/bench_solvetime.py`` also
times these copies to report old-vs-new speedups.
"""

from __future__ import annotations

from typing import Literal, Sequence

import numpy as np

from .events import IterationTrace, VariableInfo
from .simulator import HardwareSpec, SimResult, SwapDecision, assign_times


# --------------------------------------------------------------- SmartPool
def reference_solve(
    trace: IterationTrace,
    method: Literal["best_fit", "first_fit"] = "best_fit",
    alignment: int = 256,
):
    """The original O(n^2) SmartPool solve (pairwise mask + per-placement
    re-sort), kept verbatim.  Returns the same AllocationPlan type as the
    production solver."""
    from .smartpool import AllocationPlan

    variables = [v for v in trace.variables if v.size > 0]
    order = sorted(variables, key=lambda v: (-v.size, v.alloc_index))

    n = len(order)
    alloc_t = np.fromiter((v.alloc_index for v in order), np.int64, n)
    free_t = np.fromiter((v.free_index for v in order), np.int64, n)
    sizes = np.fromiter((_align(v.size, alignment) for v in order), np.int64, n)
    offsets = np.zeros(n, np.int64)

    footprint = 0
    for i, v in enumerate(order):
        if i == 0:
            offsets[0] = 0
            footprint = int(sizes[0])
            continue
        mask = (alloc_t[:i] < free_t[i]) & (free_t[:i] > alloc_t[i])
        occ_off = offsets[:i][mask]
        occ_end = occ_off + sizes[:i][mask]
        offset = _reference_place(occ_off, occ_end, int(sizes[i]), footprint, method)
        offsets[i] = offset
        footprint = max(footprint, offset + int(sizes[i]))

    plan_offsets = {v.var: int(offsets[i]) for i, v in enumerate(order)}
    lookup = {v.alloc_index: plan_offsets[v.var] for v in order}
    return AllocationPlan(
        offsets=plan_offsets,
        footprint=int(footprint),
        peak_load=_aligned_peak(variables, alignment),
        method=method,
        lookup=lookup,
    )


def _align(x: int, a: int) -> int:
    return (x + a - 1) // a * a


def _aligned_peak(variables: list[VariableInfo], alignment: int) -> int:
    deltas: dict[int, int] = {}
    for v in variables:
        s = _align(v.size, alignment)
        deltas[v.alloc_index] = deltas.get(v.alloc_index, 0) + s
        deltas[v.free_index] = deltas.get(v.free_index, 0) - s
    cur = peak = 0
    for t in sorted(deltas):
        cur += deltas[t]
        peak = max(peak, cur)
    return peak


def _reference_place(
    occ_off: np.ndarray,
    occ_end: np.ndarray,
    size: int,
    footprint: int,
    method: str,
) -> int:
    if occ_off.size == 0:
        return 0
    order = np.argsort(occ_off, kind="stable")
    off_s, end_s = occ_off[order], occ_end[order]
    best_off = -1
    best_waste = None
    cursor = 0
    m = off_s.shape[0]
    for k in range(m):
        o, e = int(off_s[k]), int(end_s[k])
        if o > cursor:
            hole = o - cursor
            if hole >= size:
                if method == "first_fit":
                    return cursor
                waste = hole - size
                if best_waste is None or waste < best_waste:
                    best_off, best_waste = cursor, waste
        cursor = max(cursor, e)
    if method == "best_fit" and best_off >= 0:
        return best_off
    return cursor


# ---------------------------------------------------------------- AutoSwap
class ReferenceAutoSwapPlanner:
    """The original AutoSwapPlanner scoring/selection loop, kept verbatim:
    O(k) ``remaining.remove`` in the SWDOA loop, ``np.diff`` of the full time
    axis on every ``_load_area`` call, per-``select`` full-curve active masks.
    """

    def __init__(
        self,
        trace: IterationTrace,
        hw: HardwareSpec,
        size_threshold: int = 1 << 20,
        include_wrap: bool = True,
    ):
        from .autoswap import Candidate

        self._Candidate = Candidate
        self.trace = trace
        self.hw = hw
        if trace.op_times is None:
            assign_times(trace, hw)
        self.times = np.asarray(trace.op_times)
        self.load = np.asarray(trace.load_curve(), dtype=np.float64)
        self.peak_load = int(self.load.max()) if self.load.size else 0
        self.peak_time = int(self.load.argmax()) if self.load.size else 0
        self.size_threshold = size_threshold
        self.candidates = self._find_candidates(include_wrap)
        self._score_all()

    def _find_candidates(self, include_wrap: bool):
        out = []
        for v in self.trace.variables:
            if v.size < self.size_threshold:
                continue
            gap = self._largest_gap(v)
            if gap is not None:
                span = self._gap_spanning_peak(v)
                a, b = span if span is not None else gap
                out.append(self._Candidate(v.var, v.size, a, b))
            if include_wrap and v.free_index >= self.trace.num_indices and v.accesses:
                out.append(
                    self._Candidate(v.var, v.size, max(v.accesses), min(v.accesses), wraps=True)
                )
        return out

    def _largest_gap(self, v: VariableInfo):
        acc = sorted(v.accesses)
        best = None
        for a, b in zip(acc, acc[1:]):
            if b - a > 1 and (best is None or b - a > best[1] - best[0]):
                best = (a, b)
        return best

    def _gap_spanning_peak(self, v: VariableInfo):
        acc = sorted(v.accesses)
        for a, b in zip(acc, acc[1:]):
            if a <= self.peak_time < b:
                return (a, b)
        return None

    def _active(self, limit: int):
        over = self.load > limit
        if not over.any():
            return []
        return [c for c in self.candidates if bool((self._absence_mask(c) & over).any())]

    def _interval_seconds(self, c) -> float:
        if not c.wraps:
            return float(self.times[c.in_before] - self.times[c.out_after])
        total = float(self.times[-1])
        return (total - float(self.times[c.out_after])) + float(self.times[c.in_before])

    def _load_area(self, load: np.ndarray, c) -> float:
        dt = np.diff(self.times)
        if not c.wraps:
            sl = slice(c.out_after, c.in_before)
            return float((load[sl] * dt[sl]).sum())
        head = slice(0, c.in_before)
        tail = slice(c.out_after, len(load))
        return float((load[head] * dt[head]).sum() + (load[tail] * dt[tail]).sum())

    def _absence_mask(self, c) -> np.ndarray:
        m = np.zeros(len(self.load), dtype=bool)
        if not c.wraps:
            m[c.out_after : c.in_before] = True
        else:
            m[: c.in_before] = True
            m[c.out_after :] = True
        return m

    def _score_all(self) -> None:
        transfer = lambda c: 2.0 * c.size / self.hw.link_bw
        for c in self.candidates:
            doa = self._interval_seconds(c) - transfer(c)
            aoa = doa * c.size if doa >= 0 else doa / c.size
            wdoa = self._load_area(self.load, c)
            c.scores.update(doa=doa, aoa=aoa, wdoa=wdoa)
        work = self.load.copy()
        remaining = list(self.candidates)
        while remaining:
            scored = [(self._load_area(work, c), c) for c in remaining]
            best_score, best = max(scored, key=lambda s: s[0])
            best.scores["swdoa"] = best_score
            work = work - best.size * self._absence_mask(best)
            remaining.remove(best)

    def ranked(self, method=None, weights: Sequence[float] | None = None):
        if weights is not None:
            z = self.standardized()
            combo = (
                weights[0] * z["aoa"] + weights[1] * z["doa"]
                + weights[2] * z["wdoa"] + weights[3] * z["swdoa"]
            )
            order = np.argsort(-combo, kind="stable")
            return [self.candidates[i] for i in order]
        assert method is not None
        return sorted(self.candidates, key=lambda c: -c.scores[method])

    def standardized(self):
        out = {}
        for k in ("doa", "aoa", "wdoa", "swdoa"):
            x = np.array([c.scores[k] for c in self.candidates], dtype=np.float64)
            std = x.std()
            out[k] = (x - x.mean()) / std if std > 0 else np.zeros_like(x)
        return out

    def select(self, limit: int, method="swdoa", weights=None):
        active_set = {(c.var, c.wraps) for c in self._active(limit)}
        work = self.load.copy()
        chosen = []
        seen: set[int] = set()
        for c in self.ranked(method, weights):
            if work.max() <= limit:
                break
            if (c.var, c.wraps) not in active_set:
                continue
            if c.var in seen:
                continue
            seen.add(c.var)
            work = work - c.size * self._absence_mask(c)
            chosen.append(c.decision())
        return chosen

    def load_min(self) -> int:
        work = self.load.copy()
        seen: set[int] = set()
        for c in self.candidates:
            if c.var in seen:
                continue
            seen.add(c.var)
            work = work - c.size * self._absence_mask(c)
        return int(work.max()) if work.size else 0

    def evaluate(self, limit: int, method="swdoa", weights=None):
        from .simulator import simulate_swap_schedule

        decisions = self.select(limit, method, weights)
        return simulate_swap_schedule(self.trace, decisions, self.hw, limit)

    def max_zero_overhead_reduction(
        self, method="swdoa", weights=None, tol: float = 0.005, grid: int = 32
    ):
        lo, hi = self.load_min(), self.peak_load
        if hi <= lo:
            return hi, 0.0
        best_limit, best_ov = hi, 0.0
        for k in range(1, grid + 1):
            limit = int(hi - (hi - lo) * k / grid)
            r = self.evaluate(limit, method, weights)
            if r.overhead <= tol:
                best_limit, best_ov = limit, r.overhead
            elif r.overhead > 5 * tol and k > grid // 2:
                break
        return best_limit, best_ov


# ------------------------------------------------------------ swap simulator
def reference_simulate_swap_schedule(
    trace: IterationTrace,
    decisions: Sequence[SwapDecision],
    hw: HardwareSpec,
    limit: int | None = None,
) -> SimResult:
    """Frozen copy of the pre-runtime ``simulate_swap_schedule`` event loop
    (one serialized out stream + one serialized in stream, eager prefetch).
    The engine's 1-tenant/2-channel/eager path must match it exactly."""
    if trace.op_times is None:
        assign_times(trace, hw)
    times = trace.op_times
    baseline = times[-1]
    costs = trace.op_costs or {}

    def op_dur(i):
        flops, nbytes = costs.get(i, (0.0, 0.0))
        if flops or nbytes:
            return max(flops / hw.eff_flops, nbytes / hw.hbm_bw) + hw.op_overhead_s
        return 0.0

    out_at, in_at = {}, {}
    for d in decisions:
        out_at.setdefault(d.out_after, []).append(d)
        in_at.setdefault(d.in_before, []).append(d)
    delta = [0] * (trace.num_indices + 1)
    malloc_size_at = {}
    for v in trace.variables:
        delta[v.alloc_index] += v.size
        malloc_size_at[v.alloc_index] = v.size
        if v.free_index <= trace.num_indices:
            delta[v.free_index] -= v.size
    transfer = lambda size: size / hw.link_bw
    t = 0.0
    resident = peak_resident = 0
    out_stream_free = in_stream_free = 0.0
    out_done, in_done = {}, {}
    pending_outs = []
    stalls = delayed = 0
    res = SimResult(baseline_s=baseline, duration_s=0.0, peak_resident=0)
    for d in decisions:
        if d.wraps:
            resident -= d.size
            out_done[d.var] = 0.0
    for i in range(trace.num_indices):
        for d in in_at.get(i, ()):
            if d.var not in in_done:
                start = max(t, in_stream_free, out_done.get(d.var, 0.0))
                end = start + transfer(d.size)
                in_stream_free = end
                in_done[d.var] = end
                resident += d.size
                res.in_events.append((d.var, start, end))
            if in_done[d.var] > t:
                stalls += 1
                t = in_done[d.var]
        if limit is not None and delta[i] > 0 and i in malloc_size_at:
            while resident + delta[i] > limit and pending_outs:
                pending_outs.sort()
                done_t, var, size = pending_outs.pop(0)
                if done_t > t:
                    delayed += 1
                    t = done_t
                resident -= size
        resident += delta[i]
        peak_resident = max(peak_resident, resident)
        t += op_dur(i)
        for d in out_at.get(i, ()):
            start = max(t, out_stream_free)
            end = start + transfer(d.size)
            out_stream_free = end
            out_done[d.var] = end
            pending_outs.append((end, d.var, d.size))
            res.out_events.append((d.var, start, end))
        still = []
        for done_t, var, size in pending_outs:
            if done_t <= t:
                resident -= size
            else:
                still.append((done_t, var, size))
        pending_outs = still
        upcoming = sorted(
            (d for d in decisions
             if d.var in out_done and d.var not in in_done and d.in_before > i),
            key=lambda d: d.in_before,
        )
        for d in upcoming:
            need = transfer(d.size)
            if limit is not None and resident + d.size > limit:
                break
            start = max(t, in_stream_free, out_done[d.var])
            end = start + need
            in_stream_free = end
            in_done[d.var] = end
            resident += d.size
            peak_resident = max(peak_resident, resident)
            res.in_events.append((d.var, start, end))
    res.duration_s = t
    res.tail_spill_s = max(0.0, out_stream_free - t)
    res.peak_resident = peak_resident
    res.stalls = stalls
    res.delayed_mallocs = delayed
    return res
