"""Iteration detection: the paper's repeatability test (§V).

The Device records malloc/free/read/write requests into a list. "Once two
consecutive subsequences are detected to be repeating, the subsequence is fed
into PoolOpt" — i.e. we look for the smallest period ``p`` such that the last
``2p`` event signatures split into two identical halves.

Signatures are (kind, size) tuples (variable ids are fresh every iteration).
The scan is O(L * P) worst case for stream length L and max period P, run
incrementally as events arrive; in practice DNN iterations are found on the
second iteration exactly as the paper describes.
"""

from __future__ import annotations

from typing import Sequence

from .events import Event, EventKind


def detect_repeating_suffix(
    signatures: Sequence[tuple],
    min_period: int = 4,
    max_period: int | None = None,
) -> int | None:
    """Return the smallest period ``p`` with signatures[-2p:-p] == signatures[-p:].

    Returns None when no repetition is present yet.  ``min_period`` filters out
    degenerate micro-loops (e.g. a single op repeated); the paper's iterations
    contain thousands of events.  A valid training iteration must allocate and
    release memory, so candidate windows lacking a MALLOC or a FREE signature
    are rejected (guards against read/write micro-loops inside one layer).
    """
    n = len(signatures)
    limit = max_period if max_period is not None else n // 2
    for p in range(min_period, limit + 1):
        if 2 * p > n:
            break
        window = list(signatures[n - p :])
        if signatures[n - 2 * p : n - p] != window:
            continue
        kinds = {sig[0] for sig in window}
        if int(EventKind.MALLOC) in kinds and int(EventKind.FREE) in kinds:
            return p
    return None


class IterationDetector:
    """Incremental wrapper used by the recording Device (core/trace.py).

    Feed events one at a time; ``period`` becomes non-None once two full
    consecutive iterations have been observed, and ``iteration_events()``
    returns the canonical single-iteration event list (re-indexed to 0).
    """

    def __init__(self, min_period: int = 4, check_every: int = 64):
        self._events: list[Event] = []
        self._sigs: list[tuple] = []
        self.period: int | None = None
        self._min_period = min_period
        self._check_every = max(1, check_every)

    def feed(self, ev: Event) -> None:
        if self.period is not None:
            return
        self._events.append(ev)
        self._sigs.append(ev.signature())
        if len(self._sigs) % self._check_every == 0:
            self.period = detect_repeating_suffix(self._sigs, self._min_period)

    def finalize(self) -> None:
        if self.period is None:
            self.period = detect_repeating_suffix(self._sigs, self._min_period)

    def iteration_events(self) -> list[Event]:
        if self.period is None:
            raise ValueError("no repeating iteration detected yet")
        p = self.period
        tail = self._events[len(self._events) - p :]
        base = tail[0].index
        return [Event(e.kind, e.var, e.size, e.index - base) for e in tail]
