"""Bayesian optimization of the combined priority score (paper §IV-C).

BO = a*AOA + b*DOA + c*WDOA + d*SWDOA over standardized scores, with
(a, b, c, d) in [-1, 1]^4 tuned against the *simulated communication
overhead* of the resulting schedule.  Gaussian-process prior (RBF kernel),
expected-improvement acquisition maximized over random proposals; converges
in the paper's reported 30-40 evaluations.

Pure numpy — no dependency beyond the standard stack.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Sequence

import numpy as np


def _rbf(a: np.ndarray, b: np.ndarray, ls: float, var: float) -> np.ndarray:
    d2 = ((a[:, None, :] - b[None, :, :]) ** 2).sum(-1)
    return var * np.exp(-0.5 * d2 / ls**2)


@dataclass
class GaussianProcess:
    lengthscale: float = 0.6
    variance: float = 1.0
    noise: float = 1e-4

    def fit(self, x: np.ndarray, y: np.ndarray) -> "GaussianProcess":
        self._x = x
        self._ymean = float(y.mean())
        self._ystd = float(y.std()) or 1.0
        yn = (y - self._ymean) / self._ystd
        k = _rbf(x, x, self.lengthscale, self.variance)
        k[np.diag_indices_from(k)] += self.noise
        self._chol = np.linalg.cholesky(k)
        self._alpha = np.linalg.solve(
            self._chol.T, np.linalg.solve(self._chol, yn)
        )
        return self

    def predict(self, xq: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        ks = _rbf(xq, self._x, self.lengthscale, self.variance)
        mu = ks @ self._alpha
        v = np.linalg.solve(self._chol, ks.T)
        var = np.maximum(self.variance - (v**2).sum(0), 1e-12)
        return mu * self._ystd + self._ymean, np.sqrt(var) * self._ystd


def _norm_cdf(z: np.ndarray) -> np.ndarray:
    from math import erf

    return 0.5 * (1.0 + np.vectorize(erf)(z / np.sqrt(2.0)))


def _norm_pdf(z: np.ndarray) -> np.ndarray:
    return np.exp(-0.5 * z**2) / np.sqrt(2 * np.pi)


def expected_improvement(
    gp: GaussianProcess, xq: np.ndarray, best: float, xi: float = 1e-3
) -> np.ndarray:
    mu, sigma = gp.predict(xq)
    imp = best - mu - xi  # minimization
    z = imp / np.maximum(sigma, 1e-12)
    return imp * _norm_cdf(z) + sigma * _norm_pdf(z)


@dataclass
class BOResult:
    best_x: np.ndarray
    best_y: float
    history_x: np.ndarray
    history_y: np.ndarray


def minimize(
    objective: Callable[[Sequence[float]], float],
    dim: int = 4,
    bounds: tuple[float, float] = (-1.0, 1.0),
    n_init: int = 8,
    n_iter: int = 32,
    n_proposals: int = 512,
    seed: int = 0,
) -> BOResult:
    """GP-EI minimization of a black-box objective over a box."""
    rng = np.random.default_rng(seed)
    lo, hi = bounds
    xs = rng.uniform(lo, hi, size=(n_init, dim))
    ys = np.array([objective(x) for x in xs])
    for _ in range(n_iter):
        gp = GaussianProcess().fit(xs, ys)
        props = rng.uniform(lo, hi, size=(n_proposals, dim))
        # Local refinement around the incumbent helps late convergence.
        incumbent = xs[int(np.argmin(ys))]
        local = np.clip(
            incumbent + rng.normal(0, 0.1, size=(n_proposals // 4, dim)), lo, hi
        )
        props = np.concatenate([props, local])
        ei = expected_improvement(gp, props, float(ys.min()))
        x_next = props[int(np.argmax(ei))]
        xs = np.vstack([xs, x_next])
        ys = np.append(ys, objective(x_next))
    i = int(np.argmin(ys))
    return BOResult(xs[i], float(ys[i]), xs, ys)


def tune_swap_weights(planner, limit: int, n_iter: int = 32, seed: int = 0) -> BOResult:
    """Tune (a,b,c,d) for an AutoSwapPlanner at a given memory-load limit."""

    def objective(w) -> float:
        return planner.evaluate(limit, method=None, weights=list(w)).overhead

    return minimize(objective, dim=4, n_iter=n_iter, seed=seed)
