"""SmartPool: offline Dynamic Storage Allocation (paper §III).

Weighted-interval-coloring heuristic (Kierstead's WIC without power-of-two
rounding, paper §III-C):

  1. sort variables in descending order of size;
  2. for each variable, collect the already-placed variables whose *lifetime*
     overlaps it (the WIC neighbourhood), merge their occupied address
     intervals, and place the variable into a hole by best-fit (default) or
     first-fit; extend the pool when no hole fits.

The resulting footprint chi(G) is compared against the peak load omega(G)
(paper Eq. 1-2); chi/omega is the competitive ratio.  Sharing is many-to-many:
a large block's address range can host any number of small, pairwise
non-overlapping-in-lifetime variables and vice versa — strictly more general
than the one-to-one sharing of prior work.

The solve runs once per detected iteration; runtime allocation is then a hash
lookup ``op_index -> offset`` (paper §V), modelled by ``AllocationPlan.lookup``.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Literal

import numpy as np

from .events import IterationTrace, VariableInfo


@dataclass
class AllocationPlan:
    """Output of the offline DSA solve."""

    offsets: dict[int, int]              # var id -> byte offset in the pool
    footprint: int                       # chi(G): pool bytes actually needed
    peak_load: int                       # omega(G): lower bound
    method: str = "best_fit"
    # op index of the MALLOC -> offset: the paper's runtime hash table.
    lookup: dict[int, int] = field(default_factory=dict)

    @property
    def competitive_ratio(self) -> float:
        return self.footprint / self.peak_load if self.peak_load else 1.0


def solve(
    trace: IterationTrace,
    method: Literal["best_fit", "first_fit"] = "best_fit",
    alignment: int = 256,
) -> AllocationPlan:
    """Run the SmartPool heuristic over one iteration's lifetimes.

    ``alignment`` mirrors real allocator granularity (cudaMalloc aligns to
    256 B; XLA to 64 B) — sizes are rounded up before packing so that the
    reported footprint is achievable on hardware.
    """
    variables = [v for v in trace.variables if v.size > 0]
    order = sorted(variables, key=lambda v: (-v.size, v.alloc_index))

    n = len(order)
    # Vectorized neighbourhood queries over the already-placed prefix.
    alloc_t = np.fromiter((v.alloc_index for v in order), np.int64, n)
    free_t = np.fromiter((v.free_index for v in order), np.int64, n)
    sizes = np.fromiter(
        (_align(v.size, alignment) for v in order), np.int64, n
    )
    offsets = np.zeros(n, np.int64)

    footprint = 0
    for i, v in enumerate(order):
        if i == 0:
            offsets[0] = 0
            footprint = int(sizes[0])
            continue
        # Lifetime-overlapping placed variables: alloc_j < free_i and free_j > alloc_i.
        mask = (alloc_t[:i] < free_t[i]) & (free_t[:i] > alloc_t[i])
        occ_off = offsets[:i][mask]
        occ_end = occ_off + sizes[:i][mask]
        offset = _place(occ_off, occ_end, int(sizes[i]), footprint, method)
        offsets[i] = offset
        footprint = max(footprint, offset + int(sizes[i]))

    plan_offsets = {v.var: int(offsets[i]) for i, v in enumerate(order)}
    lookup = {v.alloc_index: plan_offsets[v.var] for v in order}
    return AllocationPlan(
        offsets=plan_offsets,
        footprint=int(footprint),
        peak_load=_aligned_peak(variables, alignment),
        method=method,
        lookup=lookup,
    )


def _align(x: int, a: int) -> int:
    return (x + a - 1) // a * a


def _aligned_peak(variables: list[VariableInfo], alignment: int) -> int:
    """omega(G) with allocator-granularity sizes (fair ratio denominator)."""
    deltas: dict[int, int] = {}
    for v in variables:
        s = _align(v.size, alignment)
        deltas[v.alloc_index] = deltas.get(v.alloc_index, 0) + s
        deltas[v.free_index] = deltas.get(v.free_index, 0) - s
    cur = peak = 0
    for t in sorted(deltas):
        cur += deltas[t]
        peak = max(peak, cur)
    return peak


def _place(
    occ_off: np.ndarray,
    occ_end: np.ndarray,
    size: int,
    footprint: int,
    method: str,
) -> int:
    """Choose an offset given the merged occupied intervals of the neighbours."""
    if occ_off.size == 0:
        return 0
    order = np.argsort(occ_off, kind="stable")
    off_s, end_s = occ_off[order], occ_end[order]
    # Merge overlapping occupied intervals, scanning holes on the way.
    best_off = -1
    best_waste = None
    cursor = 0  # end of merged occupancy so far
    m = off_s.shape[0]
    for k in range(m):
        o, e = int(off_s[k]), int(end_s[k])
        if o > cursor:
            hole = o - cursor
            if hole >= size:
                if method == "first_fit":
                    return cursor
                waste = hole - size
                if best_waste is None or waste < best_waste:
                    best_off, best_waste = cursor, waste
        cursor = max(cursor, e)
    if method == "best_fit" and best_off >= 0:
        return best_off
    # No interior hole fits: the tail region above the neighbours is free.
    # (This may lie below the current footprint — reuse — or extend the pool.)
    return cursor


def brute_force_optimal(trace: IterationTrace, alignment: int = 1) -> int:
    """Exhaustive-permutation offline DSA for tiny instances (tests only).

    Tries every placement order under first-fit; for <= 7 variables this
    covers enough of the search space to certify optimality gaps in tests.
    """
    import itertools

    variables = [v for v in trace.variables if v.size > 0]
    if len(variables) > 7:
        raise ValueError("brute force is for tiny test instances only")
    best = None
    for perm in itertools.permutations(range(len(variables))):
        placed: list[tuple[VariableInfo, int]] = []
        fp = 0
        for idx in perm:
            v = variables[idx]
            occ = sorted(
                (off, off + _align(u.size, alignment))
                for (u, off) in placed
                if u.overlaps(v)
            )
            cursor, chosen = 0, None
            for o, e in occ:
                if o - cursor >= _align(v.size, alignment):
                    chosen = cursor
                    break
                cursor = max(cursor, e)
            if chosen is None:
                chosen = cursor
            placed.append((v, chosen))
            fp = max(fp, chosen + _align(v.size, alignment))
        best = fp if best is None else min(best, fp)
    return int(best or 0)
