"""SmartPool: offline Dynamic Storage Allocation (paper §III).

Weighted-interval-coloring heuristic (Kierstead's WIC without power-of-two
rounding, paper §III-C):

  1. sort variables in descending order of size;
  2. for each variable, collect the already-placed variables whose *lifetime*
     overlaps it (the WIC neighbourhood), merge their occupied address
     intervals, and place the variable into a hole by best-fit (default) or
     first-fit; extend the pool when no hole fits.

The resulting footprint chi(G) is compared against the peak load omega(G)
(paper Eq. 1-2); chi/omega is the competitive ratio.  Sharing is many-to-many:
a large block's address range can host any number of small, pairwise
non-overlapping-in-lifetime variables and vice versa — strictly more general
than the one-to-one sharing of prior work.

The solve runs once per detected iteration; runtime allocation is then a hash
lookup ``op_index -> offset`` (paper §V), modelled by ``AllocationPlan.lookup``.

Solve-time fast path (paper: "equal time complexity" to the default pool).
The original solve (frozen in core/_solver_reference.py) materialized an
O(n^2) pairwise lifetime-overlap mask and re-sorted the neighbour intervals
from scratch per placement.  The rewrite is event-indexed: a variable's true
WIC neighbours are exactly

    {placed j alive at alloc_i}  ∪  {placed j with alloc_j in (alloc_i, free_i)}

so each placement queries (a) a segment tree over the alloc-event coordinate
— every placed lifetime is bucketed into O(log n) canonical nodes, and one
root-to-leaf walk reports the intervals stabbing alloc_i — and (b) one slice
of the alloc-sorted event order for the starts inside the lifetime.  Total
work is O((n + E) log n) for E true lifetime overlaps instead of O(n^2 + E);
production LM/MoE traces are sparse (E ~ 13n on the 20k-variable qwen3
trace), which makes placement near-linear.  Dense instances (E approaching
n^2) auto-fall back to the bulk vectorized path, which keeps the reference's
prefix masks but replaces its per-placement Python hole scan with a
vectorized skyline (running-max of merged interval ends).  Both paths choose
placements bit-for-bit identically to the reference — the hole scan visits
merged intervals in the same (offset, placement-rank) order and applies the
same first-fit/best-fit tie-breaks — which tests/test_solvetime.py pins on
randomized traces.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Literal

import numpy as np

from .events import IterationTrace, VariableInfo

Engine = Literal["auto", "event", "bulk"]


@dataclass
class AllocationPlan:
    """Output of the offline DSA solve."""

    offsets: dict[int, int]              # var id -> byte offset in the pool
    footprint: int                       # chi(G): pool bytes actually needed
    peak_load: int                       # omega(G): lower bound
    method: str = "best_fit"
    # op index of the MALLOC -> offset: the paper's runtime hash table.
    lookup: dict[int, int] = field(default_factory=dict)

    @property
    def competitive_ratio(self) -> float:
        return self.footprint / self.peak_load if self.peak_load else 1.0


def solve(
    trace: IterationTrace,
    method: Literal["best_fit", "first_fit"] = "best_fit",
    alignment: int = 256,
    engine: Engine = "auto",
) -> AllocationPlan:
    """Run the SmartPool heuristic over one iteration's lifetimes.

    ``alignment`` mirrors real allocator granularity (cudaMalloc aligns to
    256 B; XLA to 64 B) — sizes are rounded up before packing so that the
    reported footprint is achievable on hardware.

    ``engine`` selects the neighbour-query structure: ``"event"`` (segment
    tree + alloc-order slices, near-linear on sparse lifetime graphs),
    ``"bulk"`` (vectorized prefix masks + vectorized skyline, better when
    nearly everything overlaps), or ``"auto"`` (pick by the measured overlap
    density).  All engines return bit-identical plans.
    """
    variables = [v for v in trace.variables if v.size > 0]
    order = sorted(variables, key=lambda v: (-v.size, v.alloc_index))

    n = len(order)
    alloc_t = np.fromiter((v.alloc_index for v in order), np.int64, n)
    free_t = np.fromiter((v.free_index for v in order), np.int64, n)
    a1 = alignment - 1
    sizes = np.fromiter(
        ((v.size + a1) // alignment * alignment for v in order), np.int64, n
    )

    if method not in ("best_fit", "first_fit"):
        raise ValueError(f"unknown placement method {method!r}")
    if engine == "auto":
        engine = _pick_engine(alloc_t, free_t)
    if engine == "event":
        offsets, footprint = _solve_event(alloc_t, free_t, sizes, method)
    elif engine == "bulk":
        offsets, footprint = _solve_bulk(alloc_t, free_t, sizes, method)
    else:
        raise ValueError(f"unknown solve engine {engine!r}")

    plan_offsets = {v.var: int(offsets[i]) for i, v in enumerate(order)}
    lookup = {v.alloc_index: plan_offsets[v.var] for v in order}
    return AllocationPlan(
        offsets=plan_offsets,
        footprint=int(footprint),
        peak_load=_aligned_peak(variables, alignment),
        method=method,
        lookup=lookup,
    )


def _pick_engine(alloc_t: np.ndarray, free_t: np.ndarray) -> Engine:
    """Estimate the lifetime-overlap density from the event structure.

    ``starts``: pairs (i, j) with alloc_j strictly inside i's lifetime (the
    exact element count the event path's slice scan touches). ``stabs``: sum
    over i of variables alive at alloc_i (bounds the segment-tree reports).
    Both are O(n log n) to count.  The event path does O(starts + stabs)
    Python-level work; the bulk path does O(n^2 / 2) vectorized work — pick
    event unless the instance is dense enough that numpy's constant wins.
    """
    n = len(alloc_t)
    if n <= 512:
        return "event"
    asort = np.sort(alloc_t)
    starts = int(
        (np.searchsorted(asort, free_t, "left") - np.searchsorted(asort, alloc_t, "right"))
        .clip(min=0)
        .sum()
    )
    # variables alive at each alloc event: #(alloc_j <= t) - #(free_j <= t)
    stabs = int(
        (
            np.searchsorted(asort, alloc_t, "right")
            - np.searchsorted(np.sort(free_t), alloc_t, "right")
        ).sum()
    )
    return "event" if (starts + stabs) <= 64 * n + n * n // 64 else "bulk"


# ------------------------------------------------------------- event engine
def _solve_event(
    alloc_t: np.ndarray, free_t: np.ndarray, sizes: np.ndarray, method: str
) -> tuple[np.ndarray, int]:
    """Placement with event-indexed neighbour queries (module docstring)."""
    n = len(alloc_t)
    offsets = np.zeros(n, np.int64)
    if n == 0:
        return offsets, 0

    # Alloc-sorted event order: position p holds placement rank pos_rank[p].
    pos_rank = np.argsort(alloc_t, kind="stable")
    alloc_sorted = alloc_t[pos_rank]
    # Window bounds per rank, batched: positions with alloc in (alloc_i, free_i).
    win_lo = np.searchsorted(alloc_sorted, alloc_t, side="right")
    win_hi = np.searchsorted(alloc_sorted, free_t, side="left")

    # Segment tree over the distinct alloc coordinates; a placed lifetime
    # [alloc_j, free_j) is bucketed into O(log) canonical nodes, and the
    # stabbing set of alloc_i is read off the leaf-to-root path.
    uniq = np.unique(alloc_t)
    leaf = np.searchsorted(uniq, alloc_t)
    ins_hi = np.searchsorted(uniq, free_t, side="left")
    base = 1
    while base < len(uniq):
        base <<= 1
    buckets: list[list[int] | None] = [None] * (2 * base)

    pos_rank_l = pos_rank.tolist()
    alloc_l = alloc_t.tolist()
    free_l = free_t.tolist()
    sizes_l = sizes.tolist()
    win_lo_l = win_lo.tolist()
    win_hi_l = win_hi.tolist()
    leaf_l = leaf.tolist()
    ins_hi_l = ins_hi.tolist()

    off_r = [-1] * n       # placement-rank -> offset (-1: not yet placed)
    end_r = [0] * n
    first_fit = method == "first_fit"  # validated by solve()
    footprint = 0

    for i in range(n):
        a_i = alloc_l[i]
        f_i = free_l[i]
        size = sizes_l[i]

        # (a) placed lifetimes stabbing alloc_i: leaf-to-root bucket walk.
        occ: list[tuple[int, int, int]] = []
        idx = leaf_l[i] + base
        while idx:
            b = buckets[idx]
            if b:
                for r in b:
                    occ.append((off_r[r], r, end_r[r]))
            idx >>= 1
        if f_i <= a_i and occ:
            # Zero-length or inverted lifetime: the reference mask requires
            # alloc_j < free_i, which the stab set (alloc_j <= a_i) only
            # implies when f_i > a_i — filter the degenerate cases exactly.
            occ = [t for t in occ if alloc_l[t[1]] < f_i]
        # (b) placed variables whose alloc falls strictly inside (a_i, f_i).
        # The free_j > a_i check is implied for well-formed lifetimes; it
        # guards inverted (free < alloc) records to match the reference mask.
        for p in range(win_lo_l[i], win_hi_l[i]):
            r = pos_rank_l[p]
            o = off_r[r]
            if o >= 0 and free_l[r] > a_i:
                occ.append((o, r, end_r[r]))

        # Hole scan over neighbours merged in (offset, placement-rank) order
        # — exactly the reference's stable sort + running-max cursor.
        if not occ:
            offset = 0
        else:
            occ.sort()
            cursor = 0
            best_off = -1
            best_waste = -1
            offset = -1
            for o, _r, e in occ:
                if o > cursor:
                    hole = o - cursor
                    if hole >= size:
                        if first_fit:
                            offset = cursor
                            break
                        waste = hole - size
                        if best_waste < 0 or waste < best_waste:
                            best_off, best_waste = cursor, waste
                if e > cursor:
                    cursor = e
            if offset < 0:
                offset = best_off if best_off >= 0 else cursor

        off_r[i] = offset
        end = offset + size
        end_r[i] = end
        if end > footprint:
            footprint = end

        # Insert i's lifetime into its canonical segment-tree nodes.
        l = leaf_l[i] + base
        r_ = ins_hi_l[i] + base
        while l < r_:
            if l & 1:
                if buckets[l] is None:
                    buckets[l] = []
                buckets[l].append(i)
                l += 1
            if r_ & 1:
                r_ -= 1
                if buckets[r_] is None:
                    buckets[r_] = []
                buckets[r_].append(i)
            l >>= 1
            r_ >>= 1

    offsets[:] = off_r
    return offsets, footprint


# -------------------------------------------------------------- bulk engine
def _solve_bulk(
    alloc_t: np.ndarray, free_t: np.ndarray, sizes: np.ndarray, method: str
) -> tuple[np.ndarray, int]:
    """Reference-shaped prefix masks with a vectorized skyline placement."""
    n = len(alloc_t)
    offsets = np.zeros(n, np.int64)
    footprint = 0
    for i in range(n):
        if i == 0:
            footprint = int(sizes[0]) if n else 0
            continue
        mask = (alloc_t[:i] < free_t[i]) & (free_t[:i] > alloc_t[i])
        occ_off = offsets[:i][mask]
        occ_end = occ_off + sizes[:i][mask]
        offset = _place_vectorized(occ_off, occ_end, int(sizes[i]), method)
        offsets[i] = offset
        footprint = max(footprint, offset + int(sizes[i]))
    return offsets, footprint


def _place_vectorized(
    occ_off: np.ndarray, occ_end: np.ndarray, size: int, method: str
) -> int:
    """The reference hole scan as numpy: sort neighbours by offset (stable =
    placement order on ties), build the skyline cursor as a shifted running
    max of interval ends, and pick the first/best hole exactly as the
    reference's scalar loop does."""
    if occ_off.size == 0:
        return 0
    order = np.argsort(occ_off, kind="stable")
    off_s = occ_off[order]
    end_s = occ_end[order]
    cur = np.empty(len(off_s), np.int64)
    cur[0] = 0
    if len(off_s) > 1:
        np.maximum.accumulate(end_s[:-1], out=cur[1:])
        np.maximum(cur[1:], 0, out=cur[1:])
    holes = off_s - cur
    fits = holes >= size
    if fits.any():
        if method == "first_fit":
            return int(cur[int(np.argmax(fits))])
        waste = np.where(fits, holes - size, np.iinfo(np.int64).max)
        return int(cur[int(np.argmin(waste))])
    return int(max(0, int(end_s.max())))


def _align(x: int, a: int) -> int:
    return (x + a - 1) // a * a


def _aligned_peak(variables: list[VariableInfo], alignment: int) -> int:
    """omega(G) with allocator-granularity sizes (fair ratio denominator)."""
    n = len(variables)
    if not n:
        return 0
    alloc = np.fromiter((v.alloc_index for v in variables), np.int64, n)
    free = np.fromiter((v.free_index for v in variables), np.int64, n)
    sz = np.fromiter((_align(v.size, alignment) for v in variables), np.int64, n)
    bounds = np.concatenate([alloc, free])
    deltas = np.concatenate([sz, -sz])
    order = np.argsort(bounds, kind="stable")
    # Events at the same index must net out before the peak is read, exactly
    # like the reference's per-index delta dict: segment the sorted events by
    # boundary and take the running max at segment ends only.
    b = bounds[order]
    cum = np.cumsum(deltas[order])
    last_of_index = np.append(b[1:] != b[:-1], True)
    peak = int(cum[last_of_index].max())
    return max(peak, 0)


def brute_force_optimal(trace: IterationTrace, alignment: int = 1) -> int:
    """Exhaustive-permutation offline DSA for tiny instances (tests only).

    Tries every placement order under first-fit; for <= 7 variables this
    covers enough of the search space to certify optimality gaps in tests.
    """
    import itertools

    variables = [v for v in trace.variables if v.size > 0]
    if len(variables) > 7:
        raise ValueError("brute force is for tiny test instances only")
    best = None
    for perm in itertools.permutations(range(len(variables))):
        placed: list[tuple[VariableInfo, int]] = []
        fp = 0
        for idx in perm:
            v = variables[idx]
            occ = sorted(
                (off, off + _align(u.size, alignment))
                for (u, off) in placed
                if u.overlaps(v)
            )
            cursor, chosen = 0, None
            for o, e in occ:
                if o - cursor >= _align(v.size, alignment):
                    chosen = cursor
                    break
                cursor = max(cursor, e)
            if chosen is None:
                chosen = cursor
            placed.append((v, chosen))
            fp = max(fp, chosen + _align(v.size, alignment))
        best = fp if best is None else min(best, fp)
    return int(best or 0)
