"""AutoSwap -> XLA host offload: the TPU-native swap execution path.

The paper swaps tensors over PCIe from a runtime allocator.  Under XLA the
equivalent mechanism is the ``pinned_host`` memory space: a remat policy
(``save_and_offload_only_these_names``) tells XLA which named activations to
DMA to host after the forward pass and stream back during backward — the
same "swap out after last forward access, prefetch before backward access"
schedule the paper builds by hand, executed by the compiler's async copy
machinery (our two cudaStreams analog).

AutoSwap chooses WHICH names: the jaxpr trace aggregates per-name byte
volume + access gaps; names whose variables the planner selects (given the
HBM budget) become the offload set.  Model code exposes three stable names
per scanned block: ``block_in``, ``attn_out``, ``ffn_out``.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax

# Activation classes the models label with jax.ad_checkpoint.checkpoint_name.
KNOWN_NAMES = ("block_in", "attn_out", "ffn_out")


@dataclass
class OffloadPlan:
    offload_names: list[str] = field(default_factory=list)
    save_names: list[str] = field(default_factory=list)
    # planner-predicted per-device HBM relief (bytes) and transfer volume
    predicted_savings: int = 0
    transfer_bytes: int = 0

    def policy(self):
        """A jax.checkpoint policy executing this plan (offload via pinned_host)."""
        if not self.offload_names and not self.save_names:
            return None  # plain full remat
        return jax.checkpoint_policies.save_and_offload_only_these_names(
            names_which_can_be_saved=list(self.save_names),
            names_which_can_be_offloaded=list(self.offload_names),
            offload_src="device",
            offload_dst="pinned_host",
        )


def remat_policy_for(names: list[str]) -> OffloadPlan:
    unknown = [n for n in names if n not in KNOWN_NAMES]
    if unknown:
        raise ValueError(f"unlabelled activation classes {unknown}; known: {KNOWN_NAMES}")
    return OffloadPlan(offload_names=list(names))
