"""Baseline allocators the paper compares against (Table I).

* ``CnMemPool`` — Nvidia's CnMem-style *online* pool: a linked list of free
  holes searched first-fit at every malloc, coalescing on free, growing the
  arena when nothing fits.  No lifetime knowledge (it allocates as requests
  arrive), which is exactly why SmartPool's offline plan beats it.
* ``ExactAllocator`` — cudaMalloc-style: every variable gets its own exact
  allocation, footprint equals peak load (competitive ratio 1.0 by
  construction) but each malloc/free pays the driver round-trip, modelled by
  ``malloc_cost_s`` in the simulator's timing (paper Table I's ~1.8x speedup
  of pools over cudaMalloc).
"""

from __future__ import annotations

from dataclasses import dataclass

from .events import Event, EventKind, IterationTrace


@dataclass
class PoolStats:
    footprint: int
    peak_load: int
    num_mallocs: int

    @property
    def competitive_ratio(self) -> float:
        return self.footprint / self.peak_load if self.peak_load else 1.0


class CnMemPool:
    """Online first-fit arena with hole coalescing (CnMem analog)."""

    def __init__(self, alignment: int = 256):
        self.alignment = alignment
        # Free holes as sorted [offset, end) pairs; arena grows monotonically.
        self.holes: list[list[int]] = []
        self.arena_end = 0
        self.live: dict[int, tuple[int, int]] = {}  # var -> (offset, size)
        self.num_mallocs = 0

    def _align(self, x: int) -> int:
        a = self.alignment
        return (x + a - 1) // a * a

    def malloc(self, var: int, size: int) -> int:
        size = self._align(size)
        self.num_mallocs += 1
        for i, (off, end) in enumerate(self.holes):
            if end - off >= size:
                self.live[var] = (off, size)
                if end - off == size:
                    self.holes.pop(i)
                else:
                    self.holes[i][0] = off + size
                return off
        # Grow the arena. If the last hole touches the arena end, extend it.
        if self.holes and self.holes[-1][1] == self.arena_end:
            off = self.holes[-1][0]
            self.holes.pop()
        else:
            off = self.arena_end
        self.arena_end = off + size
        self.live[var] = (off, size)
        return off

    def free(self, var: int) -> None:
        if var not in self.live:
            return
        off, size = self.live.pop(var)
        end = off + size
        # Insert + coalesce (holes kept sorted by offset).
        import bisect

        idx = bisect.bisect_left([h[0] for h in self.holes], off)
        self.holes.insert(idx, [off, end])
        # Coalesce with neighbours.
        merged = []
        for h in self.holes:
            if merged and h[0] <= merged[-1][1]:
                merged[-1][1] = max(merged[-1][1], h[1])
            else:
                merged.append(h)
        self.holes = merged

    def run(self, trace: IterationTrace) -> PoolStats:
        """Replay one iteration's malloc/free sequence through the pool."""
        events: list[tuple[int, EventKind, int, int]] = []
        for v in trace.variables:
            if v.size <= 0:
                continue
            events.append((v.alloc_index, EventKind.MALLOC, v.var, v.size))
            events.append((v.free_index, EventKind.FREE, v.var, v.size))
        events.sort(key=lambda e: (e[0], e[1] != EventKind.FREE))  # frees first
        for _, kind, var, size in events:
            if kind == EventKind.MALLOC:
                self.malloc(var, size)
            else:
                self.free(var)
        return PoolStats(self.arena_end, trace.peak_load(), self.num_mallocs)


def exact_allocator(trace: IterationTrace) -> PoolStats:
    """cudaMalloc analog: footprint == peak load, one driver call per malloc."""
    n = sum(1 for v in trace.variables if v.size > 0)
    return PoolStats(trace.peak_load(), trace.peak_load(), n)
