"""Logical-axis sharding: mesh rules + activation constraints + param specs.

Models annotate tensors with *logical* axes ("batch", "seq", "embed", ...);
this module maps them to mesh axes under the active rule set.  With no mesh
active every annotation is a no-op, so models run unchanged on a single CPU
device (smoke tests) and under the 512-device dry-run.

Mesh axes: ("data", "model") single-pod, ("pod", "data", "model") multi-pod.
"pod" behaves as an outer data-parallel axis.
"""

from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> mesh axis (or tuple of mesh axes); None = replicated
DEFAULT_RULES: dict[str, object] = {
    "batch": ("pod", "data"),
    "seq": None,
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "ff": "model",
    "vocab": "model",
    "experts": "model",
    "expert_cap": None,
    "lora": None,
    "state": None,
    "conv": None,
    "layers": None,
    "fsdp": "data",     # FSDP param dim (llama4-scale models)
    "seq_model": "model",  # context-parallel fallback for attention scores
    # attention scores batch dim over the WHOLE mesh: attention is
    # embarrassingly parallel over batch, so when enough batch exists this
    # beats both head sharding (no output all-reduce) and seq sharding
    "batch_full": ("pod", "data", "model"),
    # MoE dispatch-row dim (token-expert pairs). Unmapped by default (no-op);
    # the "moe_local" perf profile maps it to ("pod", "data") so gathers and
    # scatters around the sort-based dispatch stay batch-local instead of
    # letting GSPMD replicate the token table per device.
    "tokens": None,
    # batch over (pod, data) REGARDLESS of profile: the chunked-CE logits must
    # keep vocab on "model" (otherwise the batch_full profile forces a full
    # embedding-table all-gather per CE chunk — 75 GB/dev/step on gemma3).
    "batch_pd": ("pod", "data"),
}


class _Ctx(threading.local):
    mesh: Mesh | None = None
    rules: dict | None = None


_CTX = _Ctx()


@contextmanager
def use_mesh(mesh: Mesh, rules: dict | None = None):
    """Activate a mesh + logical rules for model-internal constraints."""
    prev = (_CTX.mesh, _CTX.rules)
    _CTX.mesh, _CTX.rules = mesh, dict(DEFAULT_RULES, **(rules or {}))
    try:
        with mesh:
            yield
    finally:
        _CTX.mesh, _CTX.rules = prev


def _resolve(axes: Sequence[str | None]) -> P:
    rules = _CTX.rules or DEFAULT_RULES
    mesh_axes = set(_CTX.mesh.axis_names) if _CTX.mesh is not None else set()

    def one(a):
        if a is None:
            return None
        m = rules.get(a)
        if m is None:
            return None
        if isinstance(m, tuple):
            present = tuple(x for x in m if x in mesh_axes)
            return present or None
        return m if m in mesh_axes else None

    return P(*[one(a) for a in axes])


def logical_spec(axes: Sequence[str | None]) -> P:
    return _resolve(axes)


def _dedupe(spec: P) -> P:
    """Drop mesh axes already claimed by an earlier dim (left precedence) —
    profiles may map several logical axes onto overlapping mesh axes."""
    used: set = set()
    out = []
    for p in spec:
        parts = p if isinstance(p, tuple) else ((p,) if p else ())
        keep = tuple(a for a in parts if a not in used)
        used.update(keep)
        out.append(keep if len(keep) > 1 else (keep[0] if keep else None))
    return P(*out)


def shard(x, *axes: str | None):
    """with_sharding_constraint by logical axes; no-op without a mesh."""
    if _CTX.mesh is None or _CTX.mesh.empty:
        return x
    spec = _dedupe(_resolve(axes))
    return jax.lax.with_sharding_constraint(x, NamedSharding(_CTX.mesh, spec))


def _spec_divides(shape, spec: P) -> bool:
    mesh = _CTX.mesh
    for dim, part in zip(shape, spec):
        if part is None:
            continue
        parts = part if isinstance(part, tuple) else (part,)
        size = 1
        for p in parts:
            size *= mesh.shape[p]
        if dim % size != 0:
            return False
    return True


def shard_pick(x, *candidates: Sequence[str | None]):
    """Apply the first candidate logical-axes constraint that divides x's
    shape evenly; no-op if none do (or no mesh).  Used where the preferred
    sharding axis (attention heads) may not divide the mesh axis for some
    architectures (e.g. 40 or 25 heads on model=16) and a fallback dim
    (query/key sequence) must carry the partitioning instead."""
    if _CTX.mesh is None or _CTX.mesh.empty:
        return x
    for axes in candidates:
        spec = _resolve(axes)
        # a mesh axis may appear at most once across the whole spec (profiles
        # can map several logical axes onto overlapping mesh axes)
        used: list = []
        for p in spec:
            used += list(p) if isinstance(p, tuple) else ([p] if p else [])
        if len(used) != len(set(used)):
            continue
        if any(p is not None for p in spec) and _spec_divides(x.shape, spec):
            return jax.lax.with_sharding_constraint(x, NamedSharding(_CTX.mesh, spec))
    return x


def named_sharding(mesh: Mesh, *axes: str | None, rules: dict | None = None) -> NamedSharding:
    prev = (_CTX.mesh, _CTX.rules)
    _CTX.mesh, _CTX.rules = mesh, dict(DEFAULT_RULES, **(rules or {}))
    try:
        return NamedSharding(mesh, _resolve(axes))
    finally:
        _CTX.mesh, _CTX.rules = prev


def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def token_group_count() -> int:
    """Shard count the "tokens" logical axis maps to (1 = unmapped/no mesh).

    models/moe.py groups its dispatch by this count so sort/scatter indices
    stay shard-local (see the moe_local profile)."""
    if _CTX.mesh is None or _CTX.mesh.empty:
        return 1
    rules = _CTX.rules or DEFAULT_RULES
    m = rules.get("tokens")
    if not m:
        return 1
    axes = m if isinstance(m, tuple) else (m,)
    n = 1
    for a in axes:
        if a in _CTX.mesh.axis_names:
            n *= _CTX.mesh.shape[a]
    return n
