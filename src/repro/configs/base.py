"""Model/config schema shared by all assigned architectures.

A model is a *program* of layer segments.  Each segment is a unit of one or
more ``LayerSpec``s repeated R times; units with R > 1 are executed under
``jax.lax.scan`` with stacked parameters (keeps HLO small for 30-50-layer
models), units with R == 1 are applied directly.  This representation covers
every assigned pattern exactly:

  qwen3-4b      [(full,) x 36]
  gemma3-4b     [(l,l,l,l,l,g) x 5, (l,l,l,l) x 1]       5:1 local:global
  gemma2-9b     [(l,g) x 21]                              alternating
  llama4        [(moe, dense) x 24]                       interleaved MoE
  deepseek-v2   [(dense-mla,) x 1, (moe-mla,) x 26]       first layer dense
  hymba         [(hg,) 1, (hl,) 15, (hg,) 1, (hl,) 14, (hg,) 1]
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Literal

AttnKind = Literal["full", "window", "mla", "mamba", "hybrid", "none"]
FFNKind = Literal["dense", "moe", "none"]


@dataclass(frozen=True)
class LayerSpec:
    attn: AttnKind = "full"
    ffn: FFNKind = "dense"
    window: int | None = None     # sliding-window width when attn in (window, hybrid)
    cross_attn: bool = False      # decoder layers of enc-dec models


# A program segment: (unit of layer specs, repeat count).
Segment = tuple[tuple[LayerSpec, ...], int]


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | vlm | ssm | audio | hybrid
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    program: tuple[Segment, ...]

    # ---- attention options ----
    qk_norm: bool = False
    attn_softcap: float | None = None
    final_softcap: float | None = None
    rope_theta: float = 10_000.0
    mrope_sections: tuple[int, int, int] | None = None  # M-RoPE (qwen2-vl)
    attn_scale: float | None = None                     # override 1/sqrt(hd)

    # ---- MLA (deepseek) ----
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # ---- MoE ----
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    router_type: str = "softmax"  # softmax | sigmoid (llama4 top-1)

    # ---- FFN ----
    ffn_act: str = "swiglu"       # swiglu | gelu

    # ---- SSM (mamba2 / hymba) ----
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_chunk: int = 64
    ssm_groups: int = 1
    conv_kernel: int = 4

    # ---- enc-dec (whisper) ----
    is_encoder_decoder: bool = False
    enc_program: tuple[Segment, ...] = ()
    enc_seq: int = 0              # encoder frames (post-frontend stub)

    # ---- frontends (stubs: input_specs() provides the embeddings) ----
    frontend: str | None = None   # vision_stub | audio_stub
    num_patch_tokens: int = 0     # vlm: patch embeddings prepended to the text

    # ---- misc ----
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    norm_type: str = "rms"        # rms | layer (whisper)
    scale_embed: bool = False     # gemma-style sqrt(d_model) embedding scale
    dtype: str = "bfloat16"
    # post-attn/post-ffn extra norms (gemma2/gemma3 style sandwich norms)
    sandwich_norms: bool = False

    def __post_init__(self):
        n = sum(len(unit) * reps for unit, reps in self.program)
        if n != self.num_layers:
            raise ValueError(
                f"{self.name}: program covers {n} layers, config says {self.num_layers}"
            )

    @property
    def d_inner(self) -> int:  # mamba inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def reduced(self, **overrides) -> "ModelConfig":
        """A smoke-test-scale sibling of the same family (see configs/<arch>.py)."""
        return dataclasses.replace(self, **overrides)


@dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""

    name: str                     # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def uniform_program(spec: LayerSpec, n: int) -> tuple[Segment, ...]:
    return ((tuple([spec]), n),)
