"""Architecture registry: ``get_config(arch)`` / ``get_smoke_config(arch)``.

All 10 assigned architectures are selectable by id (``--arch <id>``); the
paper's own CNN benchmark families live in models/cnn.py and are addressed by
name ("vgg16", "resnet50", ...) in the benchmarks.
"""

from __future__ import annotations

from importlib import import_module

from .base import SHAPES, LayerSpec, ModelConfig, ShapeSpec, uniform_program  # noqa: F401
from .specs import cache_specs, input_specs, supports_shape  # noqa: F401

ARCHS: dict[str, str] = {
    "qwen3-4b": "qwen3_4b",
    "gemma3-4b": "gemma3_4b",
    "starcoder2-7b": "starcoder2_7b",
    "gemma2-9b": "gemma2_9b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "mamba2-370m": "mamba2_370m",
    "whisper-large-v3": "whisper_large_v3",
    "hymba-1.5b": "hymba_1_5b",
}


def _module(arch: str):
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    return import_module(f"repro.configs.{ARCHS[arch]}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).config()


def get_smoke_config(arch: str) -> ModelConfig:
    return _module(arch).smoke_config()


def list_archs() -> list[str]:
    return list(ARCHS)
