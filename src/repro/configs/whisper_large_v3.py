"""whisper-large-v3 [audio]: enc-dec, 32L+32L d_model=1280 20H d_ff=5120
vocab=51866.  [arXiv:2212.04356; unverified]

The conv frontend is a STUB per the assignment: ``input_specs()`` provides
post-conv frame embeddings [B, 1500, 1280].  LayerNorm + GELU MLP as in the
original; sinusoidal positions on both stacks (deviation: whisper's decoder
positions are learned — recorded in DESIGN.md).  Decoder layers cross-attend
the encoder output; decode shapes exercise the text decoder.
"""

from .base import LayerSpec, ModelConfig, uniform_program

_ENC = LayerSpec(attn="full", ffn="dense")
_DEC = LayerSpec(attn="full", ffn="dense", cross_attn=True)


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3",
        family="audio",
        num_layers=32,
        d_model=1280,
        num_heads=20,
        num_kv_heads=20,
        head_dim=64,
        d_ff=5120,
        vocab_size=51_866,
        program=uniform_program(_DEC, 32),
        is_encoder_decoder=True,
        enc_program=uniform_program(_ENC, 32),
        enc_seq=1500,
        frontend="audio_stub",
        ffn_act="gelu",
        norm_type="layer",
        norm_eps=1e-5,
        tie_embeddings=True,
        rope_theta=0.0,  # no rope; sinusoidal positions
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke",
        family="audio",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        program=uniform_program(_DEC, 2),
        is_encoder_decoder=True,
        enc_program=uniform_program(_ENC, 2),
        enc_seq=24,
        frontend="audio_stub",
        ffn_act="gelu",
        norm_type="layer",
        dtype="float32",
    )
