"""hymba-1.5b [hybrid]: 32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001,
ssm_state=16 — parallel attention + mamba heads.  [arXiv:2411.13676; hf]

Each layer runs attention and a Mamba-2 mixer *in parallel* on the same
normed input; branch outputs are RMS-normed and averaged.  Sliding-window
(1024) attention everywhere except three global layers (first / middle /
last), matching the paper's layout.  head_dim=64; d_inner=3200 (50 SSM heads
of dim 64).  Sub-quadratic decode -> runs the long_500k cell (window ring
caches + constant SSM state; the 3 global layers keep full caches).
"""

from .base import LayerSpec, ModelConfig

_HG = LayerSpec(attn="hybrid", ffn="dense")                  # global attn + ssm
_HL = LayerSpec(attn="hybrid", ffn="dense", window=1024)     # windowed + ssm


def config() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b",
        family="hybrid",
        num_layers=32,
        d_model=1600,
        num_heads=25,
        num_kv_heads=5,
        head_dim=64,
        d_ff=5504,
        vocab_size=32_001,
        program=(
            ((_HG,), 1),
            ((_HL,), 15),
            ((_HG,), 1),
            ((_HL,), 14),
            ((_HG,), 1),
        ),
        ssm_state=16,
        ssm_expand=2,
        ssm_headdim=64,
        ssm_chunk=64,
        conv_kernel=4,
        rope_theta=10_000.0,
        tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    hg = LayerSpec(attn="hybrid", ffn="dense")
    hl = LayerSpec(attn="hybrid", ffn="dense", window=16)
    return ModelConfig(
        name="hymba-smoke",
        family="hybrid",
        num_layers=4,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        program=(((hg,), 1), ((hl,), 2), ((hg,), 1)),
        ssm_state=8,
        ssm_expand=2,
        ssm_headdim=16,
        ssm_chunk=16,
        conv_kernel=4,
        dtype="float32",
    )
