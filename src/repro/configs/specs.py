"""ShapeDtypeStruct input stand-ins for every (arch x shape) cell.

``input_specs(cfg, shape)`` returns the kwargs for the step function the cell
lowers (train_step / prefill_step / serve_step) — weak-type-correct,
shardable, zero device allocation.  Modality frontends are stubs per the
assignment: VLM cells get precomputed patch embeddings, audio cells get
post-conv frame embeddings.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .base import SHAPES, ModelConfig, ShapeSpec


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def supports_shape(cfg: ModelConfig, shape: str) -> bool:
    """long_500k runs only for sub-quadratic (SSM/hybrid) archs; encoder-only
    models would skip decode shapes (none assigned here)."""
    sp = SHAPES[shape]
    if sp.name == "long_500k":
        return cfg.family in ("ssm", "hybrid")
    return True


def input_specs(cfg: ModelConfig, shape: str) -> dict:
    sp: ShapeSpec = SHAPES[shape]
    B, S = sp.global_batch, sp.seq_len
    act = jnp.dtype(cfg.dtype)
    i32 = jnp.int32

    if sp.kind in ("train", "prefill"):
        batch: dict = {}
        if cfg.is_encoder_decoder:
            batch["frames"] = _sds((B, cfg.enc_seq, cfg.d_model), act)
            batch["tokens"] = _sds((B, S), i32)
        elif cfg.frontend == "vision_stub":
            npatch = cfg.num_patch_tokens
            batch["tokens"] = _sds((B, S - npatch), i32)
            batch["patch_embeds"] = _sds((B, npatch, cfg.d_model), act)
            batch["positions"] = _sds((3, B, S), i32)
        else:
            batch["tokens"] = _sds((B, S), i32)
        if sp.kind == "train":
            batch["labels"] = _sds(batch["tokens"].shape, i32)
        return {"batch": batch}

    # decode: one new token against a seq_len cache
    return {
        "tokens": _sds((B, 1), i32),
        "pos": _sds((), i32),
    }


def cache_specs(model, cfg: ModelConfig, shape: str):
    """Shape-only KV/state cache pytree for a decode cell."""
    sp = SHAPES[shape]
    return jax.eval_shape(lambda: model.init_cache(sp.global_batch, sp.seq_len))
