"""starcoder2-7b [dense]: 32L d_model=4608 36H (GQA kv=4) d_ff=18432 vocab=49152.

GQA + RoPE per arXiv:2402.19173; LayerNorm and GELU MLP (StarCoder2 uses the
classic MLP, not SwiGLU), head_dim=128, rope theta 1e5.  [hf-verified]
"""

from .base import LayerSpec, ModelConfig, uniform_program

_SPEC = LayerSpec(attn="full", ffn="dense")


def config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-7b",
        family="dense",
        num_layers=32,
        d_model=4608,
        num_heads=36,
        num_kv_heads=4,
        head_dim=128,
        d_ff=18432,
        vocab_size=49_152,
        program=uniform_program(_SPEC, 32),
        ffn_act="gelu",
        norm_type="layer",
        rope_theta=100_000.0,
        tie_embeddings=True,
        norm_eps=1e-5,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-7b-smoke",
        family="dense",
        num_layers=3,
        d_model=72,
        num_heads=6,
        num_kv_heads=2,
        head_dim=16,
        d_ff=144,
        vocab_size=512,
        program=uniform_program(_SPEC, 3),
        ffn_act="gelu",
        norm_type="layer",
        dtype="float32",
    )
