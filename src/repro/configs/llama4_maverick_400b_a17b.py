"""llama4-maverick-400b-a17b [moe]: 48L d_model=5120 40H (GQA kv=8)
expert d_ff=8192, vocab=202048, 128 routed experts top-1 + 1 shared.

MoE interleaved every other layer (interleave_moe_layer_step=2 — this is what
lands total params at ~400B with 17B active); dense layers use d_ff=16384;
sigmoid top-1 router.  Early fusion refers to the multimodal variant — the
text backbone is what's specified and lowered here.
[hf:meta-llama/Llama-4 family; unverified]
"""

from .base import LayerSpec, ModelConfig

_DENSE = LayerSpec(attn="full", ffn="dense")
_MOE = LayerSpec(attn="full", ffn="moe")


def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-maverick-400b-a17b",
        family="moe",
        num_layers=48,
        d_model=5120,
        num_heads=40,
        num_kv_heads=8,
        head_dim=128,
        d_ff=16384,             # dense (non-MoE) layers
        vocab_size=202_048,
        program=(((_DENSE, _MOE), 24),),
        num_experts=128,
        num_shared_experts=1,
        top_k=1,
        moe_d_ff=8192,
        capacity_factor=1.25,
        router_type="sigmoid",
        rope_theta=500_000.0,
        tie_embeddings=False,
    )


def smoke_config() -> ModelConfig:
    dense = LayerSpec(attn="full", ffn="dense")
    moe = LayerSpec(attn="full", ffn="moe")
    return ModelConfig(
        name="llama4-maverick-smoke",
        family="moe",
        num_layers=4,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=160,
        vocab_size=512,
        program=(((dense, moe), 2),),
        num_experts=8,
        num_shared_experts=1,
        top_k=1,
        moe_d_ff=64,
        router_type="sigmoid",
        dtype="float32",
    )
