"""qwen3-4b [dense]: 36L d_model=2560 32H (GQA kv=8) d_ff=9728 vocab=151936.

qk_norm (per-head RMSNorm on q/k), GQA with explicit head_dim=128, SwiGLU,
tied embeddings, RoPE theta 1e6.  [hf:Qwen/Qwen3-8B family; hf-verified]
"""

from .base import LayerSpec, ModelConfig, uniform_program

_SPEC = LayerSpec(attn="full", ffn="dense")


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-4b",
        family="dense",
        num_layers=36,
        d_model=2560,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=9728,
        vocab_size=151_936,
        program=uniform_program(_SPEC, 36),
        qk_norm=True,
        rope_theta=1_000_000.0,
        tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-4b-smoke",
        family="dense",
        num_layers=3,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        program=uniform_program(_SPEC, 3),
        qk_norm=True,
        rope_theta=10_000.0,
        dtype="float32",
    )
