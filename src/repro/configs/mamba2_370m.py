"""mamba2-370m [ssm]: 48L d_model=1024, attention-free, vocab=50280,
ssm_state=128 — SSD (state-space duality).  [arXiv:2405.21060; unverified]

d_inner = 2*1024 = 2048, headdim 64 -> 32 SSM heads, chunk 256, conv kernel 4.
Decode cost is O(1) per token (constant [B,H,P,N] state), which is why this
arch runs the long_500k cell.
"""

from .base import LayerSpec, ModelConfig, uniform_program

_SPEC = LayerSpec(attn="mamba", ffn="none")


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m",
        family="ssm",
        num_layers=48,
        d_model=1024,
        num_heads=0,
        num_kv_heads=0,
        head_dim=0,
        d_ff=0,
        vocab_size=50_280,
        program=uniform_program(_SPEC, 48),
        ssm_state=128,
        ssm_expand=2,
        ssm_headdim=64,
        ssm_chunk=256,
        ssm_groups=1,
        conv_kernel=4,
        tie_embeddings=True,
        norm_eps=1e-5,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m-smoke",
        family="ssm",
        num_layers=3,
        d_model=64,
        num_heads=0,
        num_kv_heads=0,
        head_dim=0,
        d_ff=0,
        vocab_size=512,
        program=uniform_program(_SPEC, 3),
        ssm_state=16,
        ssm_expand=2,
        ssm_headdim=16,
        ssm_chunk=16,
        conv_kernel=4,
        dtype="float32",
    )
