"""gemma2-9b [dense]: 42L d_model=3584 16H (GQA kv=8) d_ff=14336 vocab=256000.

Alternating local(4096-window)/global attention, attention logit softcap 50,
final logit softcap 30, sandwich norms, sqrt(d) embedding scale, head_dim 256.
[arXiv:2408.00118; hf-verified]
"""

from .base import LayerSpec, ModelConfig

_L = LayerSpec(attn="window", ffn="dense", window=4096)
_G = LayerSpec(attn="full", ffn="dense")


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-9b",
        family="dense",
        num_layers=42,
        d_model=3584,
        num_heads=16,
        num_kv_heads=8,
        head_dim=256,
        d_ff=14336,
        vocab_size=256_000,
        program=(((_L, _G), 21),),
        attn_softcap=50.0,
        final_softcap=30.0,
        sandwich_norms=True,
        scale_embed=True,
        rope_theta=10_000.0,
        tie_embeddings=True,
        # gemma2 query_pre_attn_scalar = 224 for 9b (d_model/num_heads)
        attn_scale=224.0**-0.5,
    )


def smoke_config() -> ModelConfig:
    l = LayerSpec(attn="window", ffn="dense", window=16)
    g = LayerSpec(attn="full", ffn="dense")
    return ModelConfig(
        name="gemma2-9b-smoke",
        family="dense",
        num_layers=4,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        program=(((l, g), 2),),
        attn_softcap=50.0,
        final_softcap=30.0,
        sandwich_norms=True,
        scale_embed=True,
        dtype="float32",
    )
