"""deepseek-v2-lite-16b [moe]: 27L d_model=2048 16H, MLA kv_lora=512,
moe_d_ff=1408, vocab=102400, 64 routed experts top-6 + 2 shared.

MLA dims per arXiv:2405.04434 (lite): qk_nope=128, qk_rope=64, v_head=128,
no q-LoRA; first layer is dense (d_ff=10944), layers 1..26 are MoE.
[hf-verified]
"""

from .base import LayerSpec, ModelConfig

_DENSE = LayerSpec(attn="mla", ffn="dense")
_MOE = LayerSpec(attn="mla", ffn="moe")


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-16b",
        family="moe",
        num_layers=27,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        head_dim=128,           # v head dim; attention uses MLA dims below
        d_ff=10944,             # the single dense layer
        vocab_size=102_400,
        program=(((_DENSE,), 1), ((_MOE,), 26)),
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
        num_experts=64,
        num_shared_experts=2,
        top_k=6,
        moe_d_ff=1408,
        capacity_factor=1.5,
        router_type="softmax",
        rope_theta=10_000.0,
        tie_embeddings=False,
    )


def smoke_config() -> ModelConfig:
    dense = LayerSpec(attn="mla", ffn="dense")
    moe = LayerSpec(attn="mla", ffn="moe")
    return ModelConfig(
        name="deepseek-v2-lite-smoke",
        family="moe",
        num_layers=3,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=160,
        vocab_size=512,
        program=(((dense,), 1), ((moe,), 2)),
        kv_lora_rank=32,
        qk_nope_head_dim=16,
        qk_rope_head_dim=8,
        v_head_dim=16,
        num_experts=8,
        num_shared_experts=2,
        top_k=2,
        moe_d_ff=32,
        capacity_factor=1.5,
        dtype="float32",
    )
