"""gemma3-4b [dense]: 34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144.

5:1 local:global attention (sliding window 1024, every 6th layer global),
head_dim=256, sandwich norms, sqrt(d) embedding scale, 128k-context rope
(theta 1e6 on global layers; we use 1e6 throughout — deviation noted in
DESIGN.md).  [hf:google/gemma-3 family; unverified]
"""

from .base import LayerSpec, ModelConfig

_L = LayerSpec(attn="window", ffn="dense", window=1024)
_G = LayerSpec(attn="full", ffn="dense")


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-4b",
        family="dense",
        num_layers=34,
        d_model=2560,
        num_heads=8,
        num_kv_heads=4,
        head_dim=256,
        d_ff=10240,
        vocab_size=262_144,
        program=(((_L, _L, _L, _L, _L, _G), 5), ((_L, _L, _L, _L), 1)),
        rope_theta=1_000_000.0,
        sandwich_norms=True,
        scale_embed=True,
        qk_norm=True,
        tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    l = LayerSpec(attn="window", ffn="dense", window=16)
    g = LayerSpec(attn="full", ffn="dense")
    return ModelConfig(
        name="gemma3-4b-smoke",
        family="dense",
        num_layers=8,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        program=(((l, l, g), 2), ((l, l), 1)),
        sandwich_norms=True,
        scale_embed=True,
        qk_norm=True,
        dtype="float32",
    )
